# Convenience targets. The Rust crate is self-contained (`cd rust && cargo
# build`); `artifacts` needs a JAX-capable python for the optional PJRT
# data plane.

.PHONY: artifacts build test check bench-kernels bench-expr bench-service clean

artifacts:
	cd python && python -m compile.aot --out ../artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

check:
	scripts/check.sh

# Flat-kernel perf trajectory: run the old-vs-new hot-path bench and gate
# the result against the committed BENCH_kernels.json snapshot.
bench-kernels:
	cd rust && RC_BENCH_JSON=kernel_hotpaths.json cargo bench --bench kernel_hotpaths
	scripts/bench_check.sh rust/kernel_hotpaths.json

# Expression-optimizer payoff: optimized vs unoptimized plan at 1.2M rows
# (strictly fewer bytes + strictly faster, ratio-gated like the kernels).
bench-expr:
	cd rust && RC_BENCH_JSON=expr_pushdown.json cargo bench --bench expr_pushdown
	scripts/bench_check.sh rust/expr_pushdown.json

# Multi-tenant query service under Zipf load: result cache on vs off
# (hot must observe cache hits and be strictly faster, ratio-gated).
bench-service:
	cd rust && RC_BENCH_JSON=service_load.json cargo bench --bench service_load
	scripts/bench_check.sh rust/service_load.json

clean:
	cd rust && cargo clean
	rm -rf artifacts
