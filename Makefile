# Convenience targets. The Rust crate is self-contained (`cd rust && cargo
# build`); `artifacts` needs a JAX-capable python for the optional PJRT
# data plane.

.PHONY: artifacts build test check clean

artifacts:
	cd python && python -m compile.aot --out ../artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

check:
	scripts/check.sh

clean:
	cd rust && cargo clean
	rm -rf artifacts
