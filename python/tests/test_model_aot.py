"""L2 model shape/AOT contract tests: what Rust's ArtifactStore relies on."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import HASH_BLOCK, SORT_BLOCK


def test_shuffle_plan_shapes():
    out = jax.eval_shape(model.shuffle_plan, *model.shuffle_plan_spec())
    assert len(out) == 1
    assert out[0].shape == (HASH_BLOCK,) and out[0].dtype == jnp.int32


def test_block_sort_shapes():
    out = jax.eval_shape(model.block_sort, *model.block_sort_spec())
    assert out[0].shape == (SORT_BLOCK,) and out[0].dtype == jnp.int64
    assert out[1].shape == (SORT_BLOCK,) and out[1].dtype == jnp.int32


def test_hlo_text_is_parsable_and_tupled():
    lowered = jax.jit(model.shuffle_plan).lower(*model.shuffle_plan_spec())
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # return_tuple=True: the ROOT of the entry computation must be a tuple.
    entry = [l for l in text.splitlines() if "ROOT" in l and "tuple" in l]
    assert entry, "expected a tuple ROOT in the entry computation"


def test_manifest_written():
    with tempfile.TemporaryDirectory() as d:
        aot.lower_all(d)
        man = open(os.path.join(d, "manifest.txt")).read().strip().splitlines()
        names = {l.split("\t")[0] for l in man}
        assert names == set(aot.ENTRY_POINTS)
        for line in man:
            name, fname, args, outs = line.split("\t")
            assert os.path.exists(os.path.join(d, fname))
            assert args and outs


def test_shuffle_plan_numerics_via_jit():
    # The jitted L2 graph (what actually gets lowered) agrees with ref.
    from compile.kernels.ref import hash_partition_ref

    rng = np.random.default_rng(7)
    keys = jnp.asarray(rng.integers(-(2**62), 2**62, size=HASH_BLOCK), jnp.int64)
    nparts = jnp.asarray([42], dtype=jnp.uint32)
    (got,) = jax.jit(model.shuffle_plan)(keys, nparts)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(hash_partition_ref(keys, nparts))
    )
