import os
import sys

# Make `compile` (and its x64 config side-effect) importable from python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import compile  # noqa: F401  (enables jax x64 before any test traces)
