"""L1 bitonic block-sort kernel vs argsort oracle (+ hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import SORT_BLOCK, bitonic_sort_kernel
from compile.kernels.ref import bitonic_sort_ref


def _case(seed, dupes=False):
    rng = np.random.default_rng(seed)
    if dupes:
        keys = rng.integers(0, 16, size=SORT_BLOCK)
    else:
        keys = rng.permutation(SORT_BLOCK).astype(np.int64)
    payload = np.arange(SORT_BLOCK, dtype=np.int32)
    return jnp.asarray(keys, jnp.int64), jnp.asarray(payload)


def test_sorts_permutation():
    keys, payload = _case(0)
    sk, sp = bitonic_sort_kernel(keys, payload)
    rk, _ = bitonic_sort_ref(keys, payload)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(rk))
    # payload is the inverse permutation: keys[payload] == sorted keys
    np.testing.assert_array_equal(
        np.asarray(keys)[np.asarray(sp)], np.asarray(sk)
    )


def test_sorts_with_duplicates():
    keys, payload = _case(1, dupes=True)
    sk, sp = bitonic_sort_kernel(keys, payload)
    np.testing.assert_array_equal(np.asarray(sk), np.sort(np.asarray(keys)))
    np.testing.assert_array_equal(
        np.asarray(keys)[np.asarray(sp)], np.asarray(sk)
    )


def test_already_sorted_and_reversed():
    base = jnp.arange(SORT_BLOCK, dtype=jnp.int64)
    payload = jnp.arange(SORT_BLOCK, dtype=jnp.int32)
    sk, _ = bitonic_sort_kernel(base, payload)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(base))
    sk, sp = bitonic_sort_kernel(base[::-1], payload)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(base))
    np.testing.assert_array_equal(np.asarray(sp), np.asarray(payload)[::-1])


def test_extreme_values():
    keys = np.zeros(SORT_BLOCK, dtype=np.int64)
    keys[0] = np.iinfo(np.int64).max
    keys[1] = np.iinfo(np.int64).min
    keys[2] = -1
    sk, sp = bitonic_sort_kernel(jnp.asarray(keys), jnp.arange(SORT_BLOCK, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(sk), np.sort(keys))
    np.testing.assert_array_equal(keys[np.asarray(sp)], np.asarray(sk))


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    lo=st.integers(-(2**62), 0),
    hi=st.integers(1, 2**62),
)
def test_hypothesis_sweep(seed, lo, hi):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(lo, hi, size=SORT_BLOCK), jnp.int64)
    payload = jnp.arange(SORT_BLOCK, dtype=jnp.int32)
    sk, sp = bitonic_sort_kernel(keys, payload)
    np.testing.assert_array_equal(np.asarray(sk), np.sort(np.asarray(keys)))
    np.testing.assert_array_equal(
        np.asarray(keys)[np.asarray(sp)], np.asarray(sk)
    )
