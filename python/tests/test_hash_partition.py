"""L1 hash-partition kernel vs pure-jnp oracle (+ hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import HASH_BLOCK, hash_partition_kernel
from compile.kernels.ref import hash_partition_ref, splitmix64


def _keys(seed, n):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-(2**62), 2**62, size=n), dtype=jnp.int64)


@pytest.mark.parametrize("nparts", [1, 2, 3, 7, 8, 37, 42, 518])
def test_kernel_matches_ref(nparts):
    keys = _keys(nparts, HASH_BLOCK)
    np_arr = jnp.asarray([nparts], dtype=jnp.uint32)
    got = hash_partition_kernel(keys, np_arr)
    want = hash_partition_ref(keys, np_arr)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_multi_block_grid():
    keys = _keys(0, 4 * HASH_BLOCK)
    np_arr = jnp.asarray([13], dtype=jnp.uint32)
    got = hash_partition_kernel(keys, np_arr)
    want = hash_partition_ref(keys, np_arr)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_partition_ids_in_range():
    keys = _keys(1, HASH_BLOCK)
    got = np.asarray(hash_partition_kernel(keys, jnp.asarray([37], dtype=jnp.uint32)))
    assert got.min() >= 0 and got.max() < 37


def test_rejects_unaligned_length():
    with pytest.raises(AssertionError):
        hash_partition_kernel(
            jnp.zeros(100, jnp.int64), jnp.asarray([4], dtype=jnp.uint32)
        )


# Golden values pinned against the Rust util::hash::splitmix64 implementation
# (rust/src/util/hash.rs test_golden_matches_python) — bit-for-bit contract.
GOLDEN = {
    0: 0xE220A8397B1DCDAF,
    1: 0x910A2DEC89025CC1,
    42: 0xBDD732262FEB6E95,
    -1: 0xE4D971771B652C20,
}


@pytest.mark.parametrize("key,expect", sorted(GOLDEN.items()))
def test_splitmix64_golden(key, expect):
    got = int(splitmix64(jnp.asarray([key], dtype=jnp.int64).astype(jnp.uint64))[0])
    assert got == expect, hex(got)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    nparts=st.integers(1, 4096),
    blocks=st.integers(1, 3),
)
def test_hypothesis_sweep(seed, nparts, blocks):
    keys = _keys(seed, blocks * HASH_BLOCK)
    np_arr = jnp.asarray([nparts], dtype=jnp.uint32)
    got = np.asarray(hash_partition_kernel(keys, np_arr))
    want = np.asarray(hash_partition_ref(keys, np_arr))
    np.testing.assert_array_equal(got, want)
    assert got.min() >= 0 and got.max() < nparts


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(-(2**63), 2**63 - 1), min_size=1, max_size=64))
def test_hash_is_deterministic_and_total(raw):
    keys = jnp.asarray(np.asarray(raw, dtype=np.int64))
    h1 = np.asarray(splitmix64(keys.astype(jnp.uint64)))
    h2 = np.asarray(splitmix64(keys.astype(jnp.uint64)))
    np.testing.assert_array_equal(h1, h2)
