"""Layer-1 Pallas kernels for the Radical-Cylon data plane.

Both kernels are authored for the TPU-shaped Pallas model but lowered with
``interpret=True`` so the resulting HLO runs on any PJRT backend (the Rust
coordinator executes them on the CPU PJRT client).  See DESIGN.md
§Hardware-Adaptation for the VMEM/BlockSpec rationale.
"""

from .hash_partition import hash_partition_kernel, HASH_BLOCK
from .bitonic import bitonic_sort_kernel, SORT_BLOCK

__all__ = [
    "hash_partition_kernel",
    "bitonic_sort_kernel",
    "HASH_BLOCK",
    "SORT_BLOCK",
]
