"""Pallas kernel: bitonic block sort of (key, payload) pairs.

Local sort is the other data-plane hot-spot (Cylon sample-sort sorts each
rank's partition locally before/after the shuffle).  The bitonic network is
the canonical accelerator sort: oblivious (no data-dependent control flow),
every stage a vectorized compare-exchange over the whole VMEM-resident
block — the same role threadblock shared-memory sorts play in GPU shuffle
implementations (DESIGN.md §Hardware-Adaptation).

The payload column carries row indices so the Rust caller can apply the
permutation to arbitrarily-typed tables.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 1 Ki lanes * (8 B key + 4 B payload) = 12 KiB per block in VMEM; the
# network has log2(N)*(log2(N)+1)/2 = 55 unrolled stages at this size,
# keeping the lowered HLO compact enough for fast PJRT compile.
SORT_BLOCK = 1024


def _compare_exchange(keys, payload, j, k):
    """One bitonic stage: exchange lane i with lane i^j, direction by bit k."""
    n = keys.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    partner = idx ^ j
    pk = keys[partner]
    pp = payload[partner]
    ascending = (idx & k) == 0
    is_low = idx < partner
    # Lane keeps min if it is the low lane of an ascending pair (or the high
    # lane of a descending pair).
    keep_min = is_low == ascending
    swap = jnp.where(keep_min, keys > pk, keys < pk)
    new_keys = jnp.where(swap, pk, keys)
    new_payload = jnp.where(swap, pp, payload)
    return new_keys, new_payload


def _kernel(keys_ref, payload_ref, out_keys_ref, out_payload_ref):
    keys = keys_ref[...]
    payload = payload_ref[...]
    n = keys.shape[0]
    k = 2
    while k <= n:  # static python loops -> fully unrolled network
        j = k // 2
        while j >= 1:
            keys, payload = _compare_exchange(keys, payload, j, k)
            j //= 2
        k *= 2
    out_keys_ref[...] = keys
    out_payload_ref[...] = payload


def bitonic_sort_kernel(keys, payload):
    """Sort a SORT_BLOCK-sized block of i64 keys, permuting i32 payload."""
    n = keys.shape[0]
    assert n == SORT_BLOCK and (n & (n - 1)) == 0
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.int64),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ),
        interpret=True,
    )(keys, payload)
