"""Pallas kernel: SplitMix64 avalanche hash + partition assignment.

This is the compute hot-spot of Cylon's distributed shuffle: every row key is
hashed and mapped to a destination rank.  The kernel is bit-for-bit
compatible with the Rust ``util::hash::splitmix64`` implementation so the
Rust coordinator can interchange native and PJRT execution paths.

TPU mapping (DESIGN.md §Hardware-Adaptation): pure element-wise VPU work;
the grid tiles the key vector into VMEM-resident blocks of ``HASH_BLOCK``
int64 lanes (128 KiB per block, far under the ~16 MiB VMEM budget), one
HBM->VMEM round-trip per block, no MXU involvement.
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Keys per grid step.  16 Ki * 8 B = 128 KiB of VMEM per input block.
HASH_BLOCK = 16384

# numpy scalars (not jnp arrays): pallas_call rejects closure-captured
# constant *arrays*, while numpy scalars are inlined as jaxpr literals; raw
# python ints overflow the default int64 promotion path.
_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def splitmix64(z):
    """SplitMix64 finalizer over uint64 lanes (wrapping arithmetic)."""
    z = z + _GAMMA
    z = (z ^ (z >> np.uint64(30))) * _MIX1
    z = (z ^ (z >> np.uint64(27))) * _MIX2
    return z ^ (z >> np.uint64(31))


def _kernel(nparts_ref, keys_ref, out_ref):
    keys = keys_ref[...]
    h = splitmix64(keys.astype(jnp.uint64))
    nparts = nparts_ref[0].astype(jnp.uint64)
    out_ref[...] = (h % nparts).astype(jnp.int32)


def hash_partition_kernel(keys, nparts):
    """Map ``keys`` (i64[N]) to partition ids (i32[N]) in [0, nparts).

    ``nparts`` is a u32[1] runtime argument so a single AOT artifact serves
    every communicator size the coordinator constructs.  N must be a
    multiple of HASH_BLOCK (the Rust caller pads the tail block).
    """
    n = keys.shape[0]
    assert n % HASH_BLOCK == 0, f"N={n} must be a multiple of {HASH_BLOCK}"
    grid = (n // HASH_BLOCK,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            # nparts is broadcast to every block (scalar prefetch analogue).
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((HASH_BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((HASH_BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(nparts, keys)
