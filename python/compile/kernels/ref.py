"""Pure-jnp oracles for the Layer-1 kernels (correctness only, no Pallas)."""

import jax.numpy as jnp

from .hash_partition import splitmix64


def hash_partition_ref(keys, nparts):
    """Reference for hash_partition_kernel: i64[N], u32[1] -> i32[N]."""
    h = splitmix64(keys.astype(jnp.uint64))
    return (h % nparts[0].astype(jnp.uint64)).astype(jnp.int32)


def bitonic_sort_ref(keys, payload):
    """Reference for bitonic_sort_kernel: stable argsort by key."""
    order = jnp.argsort(keys, stable=True)
    return keys[order], payload[order].astype(jnp.int32)
