"""AOT-lower the Layer-2 entry points to HLO text artifacts.

HLO *text* (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla_extension
0.5.1 bundled with the Rust ``xla`` crate rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.

Usage: ``cd python && python -m compile.aot --out ../artifacts``

Also writes ``manifest.txt`` (one line per artifact:
``name<TAB>file<TAB>arg-shapes<TAB>result-shapes``) which the Rust
``runtime::ArtifactStore`` uses to validate call sites at load time.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# name -> (jitted fn, arg specs)
ENTRY_POINTS = {
    "shuffle_plan": (model.shuffle_plan, model.shuffle_plan_spec()),
    "block_sort": (model.block_sort, model.block_sort_spec()),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _fmt_specs(specs) -> str:
    return ",".join(f"{s.dtype}[{'x'.join(map(str, s.shape))}]" for s in specs)


def lower_all(out_dir: str) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    written = []
    for name, (fn, specs) in ENTRY_POINTS.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *specs)
        manifest_lines.append(
            f"{name}\t{fname}\t{_fmt_specs(specs)}\t{_fmt_specs(out_specs)}"
        )
        written.append(path)
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(out_dir, 'manifest.txt')}")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    lower_all(args.out)


if __name__ == "__main__":
    main()
