"""Build-time compile package: Layer-2 JAX model + Layer-1 Pallas kernels.

Nothing in this package is imported at runtime; ``python -m compile.aot``
lowers the jitted entry points to HLO text once, and the Rust coordinator
loads the artifacts through PJRT.
"""

import jax

# The data plane hashes/sorts int64 keys; 64-bit lanes must be enabled
# before any tracing happens.
jax.config.update("jax_enable_x64", True)
