"""Layer-2 JAX compute graph for the Radical-Cylon data plane.

Two jitted entry points wrap the Layer-1 Pallas kernels:

* ``shuffle_plan``  — partition assignment for Cylon's distributed shuffle
  (used by distributed join and sample-sort repartitioning).
* ``block_sort``    — local bitonic block sort feeding Cylon's local
  sort/merge phase.

Each is lowered once by ``aot.py`` to HLO text; the Rust coordinator
compiles the text on its PJRT CPU client and invokes the executables from
the data-plane hot path.  Python never runs at request time.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    HASH_BLOCK,
    SORT_BLOCK,
    bitonic_sort_kernel,
    hash_partition_kernel,
)


def shuffle_plan(keys, nparts):
    """Partition ids for a block of join/sort keys.

    Args:
      keys: i64[N] row keys (N a multiple of HASH_BLOCK; caller pads).
      nparts: u32[1] number of destination ranks in the task's private
        communicator.

    Returns:
      (part_ids,): i32[N] destination rank per row.
    """
    return (hash_partition_kernel(keys, nparts),)


def block_sort(keys, payload):
    """Sort one SORT_BLOCK of keys, permuting the i32 payload with them.

    Returns a 2-tuple ``(sorted_keys, permuted_payload)``; payload carries
    row indices so the caller can permute arbitrary table columns.
    """
    return bitonic_sort_kernel(keys, payload)


def shuffle_plan_spec(n=HASH_BLOCK):
    """ShapeDtypeStructs matching ``shuffle_plan``'s AOT signature."""
    return (
        jax.ShapeDtypeStruct((n,), jnp.int64),
        jax.ShapeDtypeStruct((1,), jnp.uint32),
    )


def block_sort_spec(n=SORT_BLOCK):
    """ShapeDtypeStructs matching ``block_sort``'s AOT signature."""
    return (
        jax.ShapeDtypeStruct((n,), jnp.int64),
        jax.ShapeDtypeStruct((n,), jnp.int32),
    )
