//! Timing, overhead accounting, and report tables.
//!
//! The paper's two metrics (§4): **Total Execution Time** (task time on N
//! ranks) and **Radical-Cylon Overheads** — (i) task-description time and
//! (ii) private-communicator construction + delivery time. Both are
//! first-class here so Table 2 can be regenerated mechanically.

use std::time::Instant;

pub use crate::util::stats::Stats;

/// Copy-vs-view accounting for the zero-copy columnar core.
///
/// Every fresh backing allocation in the `df` layer (builders, gathers,
/// compactions) reports its payload to `record_materialized`; every O(1)
/// window creation (`Buffer::slice`, `Utf8Buffer::slice`) reports the
/// window size to `record_viewed`. Two scopes are kept:
///
/// * **global** ([`mem::global`]) — process-wide atomics, exact for
///   single-workload processes (benches), where rank threads all feed one
///   total;
/// * **thread** ([`mem::thread`]) — thread-local counters, race-free for
///   in-test assertions even under a parallel test runner ("this slice
///   materialized zero bytes").
///
/// Counters only ever grow; measure an operation by delta:
///
/// ```
/// use radical_cylon::metrics::mem;
/// let before = mem::thread();
/// // ... do columnar work on this thread ...
/// let delta = mem::thread().since(before);
/// assert_eq!(delta.materialized, 0);
/// ```
pub mod mem {
    use std::cell::Cell;
    use std::sync::atomic::{AtomicU64, Ordering};

    static G_MATERIALIZED: AtomicU64 = AtomicU64::new(0);
    static G_VIEWED: AtomicU64 = AtomicU64::new(0);

    thread_local! {
        static T_MATERIALIZED: Cell<u64> = const { Cell::new(0) };
        static T_VIEWED: Cell<u64> = const { Cell::new(0) };
    }

    /// A snapshot of the two monotone counters, in bytes.
    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct MemCounters {
        /// Bytes written into fresh backing allocations (real copies).
        pub materialized: u64,
        /// Bytes made visible through O(1) window views (no copies).
        pub viewed: u64,
    }

    impl MemCounters {
        /// Delta relative to an earlier snapshot of the same scope.
        pub fn since(self, earlier: MemCounters) -> MemCounters {
            MemCounters {
                materialized: self.materialized.wrapping_sub(earlier.materialized),
                viewed: self.viewed.wrapping_sub(earlier.viewed),
            }
        }
    }

    /// Report `bytes` copied into a fresh backing allocation.
    pub fn record_materialized(bytes: usize) {
        G_MATERIALIZED.fetch_add(bytes as u64, Ordering::Relaxed);
        T_MATERIALIZED.with(|c| c.set(c.get() + bytes as u64));
    }

    /// Report `bytes` exposed through a zero-copy view.
    pub fn record_viewed(bytes: usize) {
        G_VIEWED.fetch_add(bytes as u64, Ordering::Relaxed);
        T_VIEWED.with(|c| c.set(c.get() + bytes as u64));
    }

    /// Process-wide totals (sum over all threads since start).
    pub fn global() -> MemCounters {
        MemCounters {
            materialized: G_MATERIALIZED.load(Ordering::Relaxed),
            viewed: G_VIEWED.load(Ordering::Relaxed),
        }
    }

    /// This thread's totals (race-free under parallel tests).
    pub fn thread() -> MemCounters {
        MemCounters {
            materialized: T_MATERIALIZED.with(|c| c.get()),
            viewed: T_VIEWED.with(|c| c.get()),
        }
    }

    /// Remove `delta` from the *executing* thread's counters so it can be
    /// credited elsewhere via [`transfer_in`].
    ///
    /// This is the thread-pool handoff: a pooled job measures its own
    /// delta, transfers it out of whichever thread ran it (a worker or
    /// the scope's helping caller — subtracting first makes both cases
    /// double-count-free), and the scope transfers the accumulated total
    /// into the calling thread. Globals are untouched; they were already
    /// exact. Uses wrapping arithmetic so a worker whose counters started
    /// at 0 stays consistent under `since`-style deltas.
    pub fn transfer_out(delta: MemCounters) {
        T_MATERIALIZED
            .with(|c| c.set(c.get().wrapping_sub(delta.materialized)));
        T_VIEWED.with(|c| c.set(c.get().wrapping_sub(delta.viewed)));
    }

    /// Credit `delta` (previously [`transfer_out`]-ed on other threads)
    /// to this thread's counters.
    pub fn transfer_in(delta: MemCounters) {
        T_MATERIALIZED
            .with(|c| c.set(c.get().wrapping_add(delta.materialized)));
        T_VIEWED.with(|c| c.set(c.get().wrapping_add(delta.viewed)));
    }
}

/// Plan/result-cache accounting for the query service.
///
/// The [`crate::service::QueryService`] maintains two caches: a plan cache
/// (fingerprint → lowered DAG, skips re-lowering) and an LRU result cache
/// (fingerprint → collected output table). Both report hits, misses, and
/// evictions here as process-wide monotone counters so tests and the
/// sustained-load bench can observe cache behaviour without reaching into
/// service internals. Like [`mem`], counters only grow — measure an
/// operation by delta:
///
/// ```
/// use radical_cylon::metrics::cache;
/// let before = cache::snapshot();
/// // ... submit queries ...
/// let delta = cache::snapshot().since(before);
/// assert_eq!(delta.result_evictions, 0);
/// ```
pub mod cache {
    use std::sync::atomic::{AtomicU64, Ordering};

    static PLAN_HITS: AtomicU64 = AtomicU64::new(0);
    static PLAN_MISSES: AtomicU64 = AtomicU64::new(0);
    static RESULT_HITS: AtomicU64 = AtomicU64::new(0);
    static RESULT_MISSES: AtomicU64 = AtomicU64::new(0);
    static RESULT_EVICTIONS: AtomicU64 = AtomicU64::new(0);

    /// Snapshot of the five monotone cache counters.
    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct CacheCounters {
        /// Plan-cache hits (lowering skipped).
        pub plan_hits: u64,
        /// Plan-cache misses (plan lowered and inserted).
        pub plan_misses: u64,
        /// Result-cache hits (execution skipped entirely).
        pub result_hits: u64,
        /// Result-cache misses among *cacheable* queries.
        pub result_misses: u64,
        /// Result-cache entries evicted to stay under the byte budget.
        pub result_evictions: u64,
    }

    impl CacheCounters {
        /// Delta relative to an earlier snapshot.
        pub fn since(self, earlier: CacheCounters) -> CacheCounters {
            CacheCounters {
                plan_hits: self.plan_hits.wrapping_sub(earlier.plan_hits),
                plan_misses: self.plan_misses.wrapping_sub(earlier.plan_misses),
                result_hits: self.result_hits.wrapping_sub(earlier.result_hits),
                result_misses: self
                    .result_misses
                    .wrapping_sub(earlier.result_misses),
                result_evictions: self
                    .result_evictions
                    .wrapping_sub(earlier.result_evictions),
            }
        }
    }

    pub fn record_plan_hit() {
        PLAN_HITS.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_plan_miss() {
        PLAN_MISSES.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_result_hit() {
        RESULT_HITS.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_result_miss() {
        RESULT_MISSES.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_result_evictions(n: u64) {
        RESULT_EVICTIONS.fetch_add(n, Ordering::Relaxed);
    }

    /// Process-wide totals since start.
    pub fn snapshot() -> CacheCounters {
        CacheCounters {
            plan_hits: PLAN_HITS.load(Ordering::Relaxed),
            plan_misses: PLAN_MISSES.load(Ordering::Relaxed),
            result_hits: RESULT_HITS.load(Ordering::Relaxed),
            result_misses: RESULT_MISSES.load(Ordering::Relaxed),
            result_evictions: RESULT_EVICTIONS.load(Ordering::Relaxed),
        }
    }
}

/// Fault-tolerance accounting (see `util::faults` and ARCHITECTURE.md
/// §Fault tolerance).
///
/// Process-wide monotone counters following the [`cache`] pattern:
/// injection sites report every fired fault, the retry layers report
/// retries and their outcomes, and the raptor watchdog reports deadline
/// kills and quarantined ranks. Measure an operation by delta:
///
/// ```
/// use radical_cylon::metrics::faults;
/// let before = faults::snapshot();
/// // ... run a chaos workload ...
/// let delta = faults::snapshot().since(before);
/// assert_eq!(delta.exhausted, 0, "every transient fault was recovered");
/// ```
pub mod faults {
    use std::sync::atomic::{AtomicU64, Ordering};

    static INJECTED: AtomicU64 = AtomicU64::new(0);
    static RETRIED: AtomicU64 = AtomicU64::new(0);
    static RECOVERED: AtomicU64 = AtomicU64::new(0);
    static EXHAUSTED: AtomicU64 = AtomicU64::new(0);
    static TIMED_OUT: AtomicU64 = AtomicU64::new(0);
    static QUARANTINED_RANKS: AtomicU64 = AtomicU64::new(0);

    /// Snapshot of the six monotone fault counters.
    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct FaultCounters {
        /// Faults fired by an armed `FaultPlan` (failures and delays).
        pub injected: u64,
        /// Transient failures re-attempted by a `RetryPolicy`.
        pub retried: u64,
        /// Retry loops that ended in success after >= 1 retry.
        pub recovered: u64,
        /// Retry loops that ran out of attempts on a transient failure.
        pub exhausted: u64,
        /// Tasks the raptor watchdog killed at their deadline.
        pub timed_out: u64,
        /// Ranks quarantined after hosting a failed/overdue task.
        pub quarantined_ranks: u64,
    }

    impl FaultCounters {
        /// Delta relative to an earlier snapshot.
        pub fn since(self, earlier: FaultCounters) -> FaultCounters {
            FaultCounters {
                injected: self.injected.wrapping_sub(earlier.injected),
                retried: self.retried.wrapping_sub(earlier.retried),
                recovered: self.recovered.wrapping_sub(earlier.recovered),
                exhausted: self.exhausted.wrapping_sub(earlier.exhausted),
                timed_out: self.timed_out.wrapping_sub(earlier.timed_out),
                quarantined_ranks: self
                    .quarantined_ranks
                    .wrapping_sub(earlier.quarantined_ranks),
            }
        }
    }

    pub fn record_injected() {
        INJECTED.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_retried() {
        RETRIED.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_recovered() {
        RECOVERED.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_exhausted() {
        EXHAUSTED.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_timed_out() {
        TIMED_OUT.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_quarantined_ranks(n: u64) {
        QUARANTINED_RANKS.fetch_add(n, Ordering::Relaxed);
    }

    /// Process-wide totals since start.
    pub fn snapshot() -> FaultCounters {
        FaultCounters {
            injected: INJECTED.load(Ordering::Relaxed),
            retried: RETRIED.load(Ordering::Relaxed),
            recovered: RECOVERED.load(Ordering::Relaxed),
            exhausted: EXHAUSTED.load(Ordering::Relaxed),
            timed_out: TIMED_OUT.load(Ordering::Relaxed),
            quarantined_ranks: QUARANTINED_RANKS.load(Ordering::Relaxed),
        }
    }
}

/// Out-of-core spill accounting (see `crate::spill` and ARCHITECTURE.md
/// §Out-of-core execution).
///
/// Process-wide monotone counters following the [`cache`] pattern: the
/// run writer reports every block spilled, the run reader reports every
/// block restored, and run seal time accumulates in nanoseconds. Like
/// the other scopes, measure an operation by delta:
///
/// ```
/// use radical_cylon::metrics::spill;
/// let before = spill::snapshot();
/// // ... run a budgeted sort/join ...
/// let delta = spill::snapshot().since(before);
/// assert_eq!(delta.runs, 0, "stayed in RAM");
/// ```
pub mod spill {
    use std::sync::atomic::{AtomicU64, Ordering};

    static BYTES_SPILLED: AtomicU64 = AtomicU64::new(0);
    static BYTES_RESTORED: AtomicU64 = AtomicU64::new(0);
    static RUNS: AtomicU64 = AtomicU64::new(0);
    static SPILL_NANOS: AtomicU64 = AtomicU64::new(0);

    /// Snapshot of the four monotone spill counters.
    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct SpillCounters {
        /// In-memory payload bytes written out as spill blocks.
        pub bytes_spilled: u64,
        /// In-memory payload bytes rebuilt from spill blocks.
        pub bytes_restored: u64,
        /// Spill runs sealed (one per finished `RunWriter`).
        pub runs: u64,
        /// Nanoseconds from run creation to seal (write-side time).
        pub spill_nanos: u64,
    }

    impl SpillCounters {
        /// Delta relative to an earlier snapshot.
        pub fn since(self, earlier: SpillCounters) -> SpillCounters {
            SpillCounters {
                bytes_spilled: self
                    .bytes_spilled
                    .wrapping_sub(earlier.bytes_spilled),
                bytes_restored: self
                    .bytes_restored
                    .wrapping_sub(earlier.bytes_restored),
                runs: self.runs.wrapping_sub(earlier.runs),
                spill_nanos: self.spill_nanos.wrapping_sub(earlier.spill_nanos),
            }
        }
    }

    pub fn record_spilled(bytes: u64) {
        BYTES_SPILLED.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn record_restored(bytes: u64) {
        BYTES_RESTORED.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn record_run() {
        RUNS.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_spill_nanos(nanos: u64) {
        SPILL_NANOS.fetch_add(nanos, Ordering::Relaxed);
    }

    /// Process-wide totals since start.
    pub fn snapshot() -> SpillCounters {
        SpillCounters {
            bytes_spilled: BYTES_SPILLED.load(Ordering::Relaxed),
            bytes_restored: BYTES_RESTORED.load(Ordering::Relaxed),
            runs: RUNS.load(Ordering::Relaxed),
            spill_nanos: SPILL_NANOS.load(Ordering::Relaxed),
        }
    }
}

/// Simple scope timer returning seconds.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// The paper's overhead decomposition (Table 2 "Overheads" column): the
/// time RP spends (i) describing the task object and (ii) constructing +
/// delivering the private MPI communicator, plus the master's dispatch
/// processing. Queue wait (resources busy with *other* tasks) is recorded
/// separately and deliberately NOT part of `total()` — it is utilization,
/// not runtime overhead.
#[derive(Clone, Copy, Debug, Default)]
pub struct OverheadBreakdown {
    /// (i) describing + submitting the task object (seconds).
    pub task_description: f64,
    /// (ii) constructing the private communicator and delivering it
    /// (seconds; real rendezvous + modeled per-rank cost).
    pub comm_construction: f64,
    /// Master dispatch processing: rank selection + work-order delivery.
    pub scheduling: f64,
    /// Time queued behind other tasks (diagnostic; excluded from total).
    pub queue_wait: f64,
}

impl OverheadBreakdown {
    pub fn total(&self) -> f64 {
        self.task_description + self.comm_construction + self.scheduling
    }
}

/// One completed execution measurement.
#[derive(Clone, Debug)]
pub struct ExecMeasurement {
    pub label: String,
    pub parallelism: usize,
    /// Wall-clock compute seconds (max across ranks).
    pub wall_s: f64,
    /// Simulated network seconds (max across ranks).
    pub sim_net_s: f64,
    pub overhead: OverheadBreakdown,
}

impl ExecMeasurement {
    /// Total modeled execution time the figures plot: real compute + the
    /// virtual network seconds the α–β model charged.
    pub fn total_s(&self) -> f64 {
        self.wall_s + self.sim_net_s
    }
}

/// Per-node record from a pipeline DAG execution (all times are seconds
/// relative to the pipeline's start).
#[derive(Clone, Debug)]
pub struct NodeMetric {
    pub name: String,
    /// Ranks the node's private communicator spanned.
    pub ranks: usize,
    /// When the executor submitted the node (dependencies resolved).
    pub submitted_s: f64,
    /// When the node's terminal result arrived back.
    pub finished_s: f64,
    /// Real compute wall seconds (max across the node's ranks).
    pub wall_s: f64,
    /// Modeled execution seconds (wall + simulated network).
    pub exec_s: f64,
    /// Seconds the node sat in the master's queue behind other tasks.
    pub queue_wait_s: f64,
    /// Execution attempts this node took (1 = clean first run; > 1 means
    /// the retry layer re-ran it after transient failures).
    pub attempts: u32,
}

/// Whole-DAG accounting from a pipeline execution — the observability half
/// of the dataflow scheduler (§4.4 "resource tracking").
#[derive(Clone, Debug, Default)]
pub struct PipelineMetrics {
    pub nodes: Vec<NodeMetric>,
    /// Real seconds from first submission to last completion.
    pub makespan_s: f64,
    /// Longest dependency chain weighted by measured wall seconds — the
    /// lower bound no scheduler can beat on this DAG.
    pub critical_path_s: f64,
    /// Σ ranks × wall over all nodes: rank-seconds actually computing.
    pub busy_rank_seconds: f64,
}

impl PipelineMetrics {
    /// Fraction of a `pilot_ranks`-wide pilot that sat idle over the
    /// makespan — the waste wave barriers create and dataflow reclaims.
    pub fn idle_fraction(&self, pilot_ranks: usize) -> f64 {
        let capacity = pilot_ranks as f64 * self.makespan_s;
        if capacity <= 0.0 {
            return 0.0;
        }
        ((capacity - self.busy_rank_seconds) / capacity).clamp(0.0, 1.0)
    }

    /// Seconds the schedule spent beyond the critical path (scheduling
    /// slack; 0 means the DAG ran as fast as its longest chain allows).
    pub fn slack_s(&self) -> f64 {
        (self.makespan_s - self.critical_path_s).max(0.0)
    }
}

/// Accumulates repeated iterations of the same configuration.
#[derive(Clone, Debug, Default)]
pub struct MeasurementSeries {
    pub totals: Vec<f64>,
    pub overheads: Vec<f64>,
}

impl MeasurementSeries {
    pub fn push(&mut self, m: &ExecMeasurement) {
        self.totals.push(m.total_s());
        self.overheads.push(m.overhead.total());
    }

    pub fn total_stats(&self) -> Stats {
        Stats::from_samples(&self.totals)
    }

    pub fn overhead_stats(&self) -> Stats {
        Stats::from_samples(&self.overheads)
    }
}

/// Fixed-width table printer used by the CLI and benches.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:<w$}"))
            .collect::<Vec<_>>()
            .join("  ")
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push('\n');
    out.push_str(
        &widths
            .iter()
            .map(|w| "-".repeat(*w))
            .collect::<Vec<_>>()
            .join("  "),
    );
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_s() >= 0.004);
    }

    #[test]
    fn overhead_total() {
        let o = OverheadBreakdown {
            task_description: 0.1,
            comm_construction: 0.2,
            scheduling: 0.3,
            queue_wait: 99.0, // excluded from total by design
        };
        assert!((o.total() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn series_stats() {
        let mut s = MeasurementSeries::default();
        for w in [1.0, 2.0, 3.0] {
            s.push(&ExecMeasurement {
                label: "x".into(),
                parallelism: 4,
                wall_s: w,
                sim_net_s: 1.0,
                overhead: OverheadBreakdown::default(),
            });
        }
        assert!((s.total_stats().mean - 3.0).abs() < 1e-12);
        assert_eq!(s.overhead_stats().mean, 0.0);
    }

    #[test]
    fn pipeline_metrics_accounting() {
        let m = PipelineMetrics {
            nodes: Vec::new(),
            makespan_s: 10.0,
            critical_path_s: 6.0,
            busy_rank_seconds: 20.0,
        };
        // 4 ranks x 10s = 40 rank-seconds capacity, 20 busy -> 50% idle.
        assert!((m.idle_fraction(4) - 0.5).abs() < 1e-12);
        assert!((m.slack_s() - 4.0).abs() < 1e-12);
        assert_eq!(PipelineMetrics::default().idle_fraction(8), 0.0);
    }

    #[test]
    fn mem_counters_accumulate() {
        let t0 = mem::thread();
        mem::record_materialized(100);
        mem::record_viewed(40);
        let d = mem::thread().since(t0);
        assert_eq!(d.materialized, 100);
        assert_eq!(d.viewed, 40);
        // Global totals include this thread's contribution.
        assert!(mem::global().materialized >= 100);
    }

    #[test]
    fn cache_counters_accumulate() {
        let before = cache::snapshot();
        cache::record_plan_hit();
        cache::record_plan_miss();
        cache::record_result_hit();
        cache::record_result_miss();
        cache::record_result_evictions(3);
        let d = cache::snapshot().since(before);
        assert!(d.plan_hits >= 1);
        assert!(d.plan_misses >= 1);
        assert!(d.result_hits >= 1);
        assert!(d.result_misses >= 1);
        assert!(d.result_evictions >= 3);
    }

    #[test]
    fn fault_counters_accumulate() {
        let before = faults::snapshot();
        faults::record_injected();
        faults::record_retried();
        faults::record_recovered();
        faults::record_exhausted();
        faults::record_timed_out();
        faults::record_quarantined_ranks(2);
        let d = faults::snapshot().since(before);
        assert!(d.injected >= 1);
        assert!(d.retried >= 1);
        assert!(d.recovered >= 1);
        assert!(d.exhausted >= 1);
        assert!(d.timed_out >= 1);
        assert!(d.quarantined_ranks >= 2);
    }

    #[test]
    fn spill_counters_accumulate() {
        let before = spill::snapshot();
        spill::record_spilled(512);
        spill::record_restored(512);
        spill::record_run();
        spill::record_spill_nanos(1_000);
        let d = spill::snapshot().since(before);
        assert!(d.bytes_spilled >= 512);
        assert!(d.bytes_restored >= 512);
        assert!(d.runs >= 1);
        assert!(d.spill_nanos >= 1_000);
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["a", "bbbb"],
            &[vec!["xx".into(), "y".into()], vec!["1".into(), "22222".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("a "));
        assert!(lines[1].starts_with("--"));
    }
}
