//! RADICAL-Pilot analogue (paper §3.1, §3.4, Fig 3): `Session`,
//! `PilotManager` (resource placeholders), `TaskManager` (task lifecycle),
//! and the task/pilot state machines. The RAPTOR master/worker subsystem
//! the agent bootstraps lives in [`crate::raptor`].

mod description;
mod session;
mod task;

pub use description::{DataDist, PilotDescription, RankClass, TaskDescription};
pub use session::{Pilot, PilotManager, PilotState, Session, TaskManager};
pub use task::{TaskHandle, TaskResult, TaskState};
