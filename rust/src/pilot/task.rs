//! Task state machine and completion handle.

use std::sync::{Arc, Condvar, Mutex};

use crate::util::lock_recover;

use crate::df::ChunkedTable;
use crate::error::{Error, Result};
use crate::metrics::ExecMeasurement;

/// RADICAL-Pilot task states (collapsed to the scheduling-relevant subset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    New,
    /// Submitted to the TaskManager, waiting for agent scheduling.
    Submitted,
    /// RAPTOR master is assembling ranks for it.
    AgentScheduling,
    /// Running on a private communicator.
    Executing,
    Done,
    Failed,
    Canceled,
}

impl TaskState {
    /// Legal forward transitions (the paper's loosely-coupled lifecycle).
    pub fn can_transition_to(self, next: TaskState) -> bool {
        use TaskState::*;
        matches!(
            (self, next),
            (New, Submitted)
                | (Submitted, AgentScheduling)
                | (Submitted, Canceled)
                // A queued task can fail before it ever executes: the
                // degraded-mode scheduler fails tasks that have become
                // unschedulable (every healthy rank quarantined).
                | (Submitted, Failed)
                | (AgentScheduling, Executing)
                | (AgentScheduling, Canceled)
                | (AgentScheduling, Failed)
                | (Executing, Done)
                | (Executing, Failed)
        )
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, TaskState::Done | TaskState::Failed | TaskState::Canceled)
    }
}

/// Final record of a task execution.
#[derive(Clone, Debug)]
pub struct TaskResult {
    pub task_id: u64,
    pub name: String,
    pub state: TaskState,
    pub measurement: ExecMeasurement,
    /// Rows in the task's output table(s), summed over ranks.
    pub output_rows: u64,
    /// The gathered output table, present only when the description set
    /// `keep_output` (pipeline table handoff). Kept as a [`ChunkedTable`]
    /// of per-rank parts — never flattened on the handoff path — and
    /// `Arc`-wrapped so clones stay cheap as it fans out to downstream
    /// consumers.
    pub output: Option<Arc<ChunkedTable>>,
    pub error: Option<String>,
}

impl TaskResult {
    pub fn is_done(&self) -> bool {
        self.state == TaskState::Done
    }
}

type TerminalCallback = Box<dyn FnOnce(Result<TaskResult>) + Send>;

struct TaskInner {
    state: Mutex<(TaskState, Option<TaskResult>)>,
    cv: Condvar,
    /// Callbacks fired once, on the terminal transition (under no lock).
    callbacks: Mutex<Vec<TerminalCallback>>,
}

/// Shared handle to a submitted task; `wait()` blocks until terminal.
#[derive(Clone)]
pub struct TaskHandle {
    pub id: u64,
    pub name: String,
    inner: Arc<TaskInner>,
}

impl std::fmt::Debug for TaskHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TaskHandle")
            .field("id", &self.id)
            .field("name", &self.name)
            .field("state", &self.state())
            .finish()
    }
}


impl TaskHandle {
    pub fn new(id: u64, name: &str) -> TaskHandle {
        TaskHandle {
            id,
            name: name.to_string(),
            inner: Arc::new(TaskInner {
                state: Mutex::new((TaskState::New, None)),
                cv: Condvar::new(),
                callbacks: Mutex::new(Vec::new()),
            }),
        }
    }

    pub fn state(&self) -> TaskState {
        lock_recover(&self.inner.state).0
    }

    /// Advance the state machine; panics on illegal transitions (these are
    /// coordinator bugs, not runtime conditions).
    ///
    /// A terminal transition *without* a result (e.g. `Canceled`) still
    /// fires registered [`on_terminal`](TaskHandle::on_terminal)
    /// callbacks — with the "terminal without result" error — so
    /// completion listeners can never hang on a canceled task.
    pub fn advance(&self, next: TaskState) {
        let mut st = lock_recover(&self.inner.state);
        assert!(
            st.0.can_transition_to(next),
            "illegal task transition {:?} -> {next:?} (task {})",
            st.0,
            self.id
        );
        st.0 = next;
        self.inner.cv.notify_all();
        drop(st);
        if next.is_terminal() {
            self.fire_callbacks();
        }
    }

    /// Terminal transition carrying the result; fires `on_terminal`
    /// callbacks after releasing the state lock.
    pub fn finish(&self, result: TaskResult) {
        let mut st = lock_recover(&self.inner.state);
        assert!(
            st.0.can_transition_to(result.state) && result.state.is_terminal(),
            "illegal terminal transition {:?} -> {:?}",
            st.0,
            result.state
        );
        st.0 = result.state;
        st.1 = Some(result);
        self.inner.cv.notify_all();
        drop(st);
        self.fire_callbacks();
    }

    /// What a completion listener receives: the stored result, or the
    /// "terminal without result" error for result-less terminal states.
    fn terminal_outcome(&self) -> Result<TaskResult> {
        let st = lock_recover(&self.inner.state);
        debug_assert!(st.0.is_terminal());
        st.1.clone().ok_or_else(|| {
            Error::Pilot(format!("task {} terminal without result", self.id))
        })
    }

    /// Drain and invoke the registered callbacks (no locks held while a
    /// callback runs — callbacks may take locks of their own).
    fn fire_callbacks(&self) {
        let drained: Vec<TerminalCallback> =
            std::mem::take(&mut *lock_recover(&self.inner.callbacks));
        for cb in drained {
            cb(self.terminal_outcome());
        }
    }

    /// Register a one-shot completion callback, invoked with the task's
    /// outcome when it reaches a terminal state (on whichever thread
    /// drives the terminal transition). If the task is already terminal,
    /// the callback runs inline before this returns.
    ///
    /// This is how the threaded pipeline executors observe completion
    /// without parking a waiter thread per node.
    pub fn on_terminal(&self, cb: impl FnOnce(Result<TaskResult>) + Send + 'static) {
        {
            let st = lock_recover(&self.inner.state);
            if !st.0.is_terminal() {
                lock_recover(&self.inner.callbacks).push(Box::new(cb));
                return;
            }
        }
        cb(self.terminal_outcome());
    }

    /// Block until the task reaches a terminal state; returns the result.
    pub fn wait(&self) -> Result<TaskResult> {
        let mut st = lock_recover(&self.inner.state);
        while !st.0.is_terminal() {
            st = self.inner.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.1.clone().ok_or_else(|| {
            Error::Pilot(format!("task {} terminal without result", self.id))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OverheadBreakdown;

    fn result(id: u64, state: TaskState) -> TaskResult {
        TaskResult {
            task_id: id,
            name: "t".into(),
            state,
            measurement: ExecMeasurement {
                label: "t".into(),
                parallelism: 1,
                wall_s: 0.1,
                sim_net_s: 0.0,
                overhead: OverheadBreakdown::default(),
            },
            output_rows: 0,
            output: None,
            error: None,
        }
    }

    #[test]
    fn legal_lifecycle() {
        let h = TaskHandle::new(1, "t");
        h.advance(TaskState::Submitted);
        h.advance(TaskState::AgentScheduling);
        h.advance(TaskState::Executing);
        h.finish(result(1, TaskState::Done));
        assert_eq!(h.state(), TaskState::Done);
        assert!(h.wait().unwrap().is_done());
    }

    #[test]
    #[should_panic(expected = "illegal task transition")]
    fn illegal_skip_rejected() {
        let h = TaskHandle::new(2, "t");
        h.advance(TaskState::Executing); // New -> Executing is illegal
    }

    #[test]
    fn wait_blocks_until_finish() {
        let h = TaskHandle::new(3, "t");
        h.advance(TaskState::Submitted);
        h.advance(TaskState::AgentScheduling);
        h.advance(TaskState::Executing);
        let h2 = h.clone();
        let waiter = std::thread::spawn(move || h2.wait().unwrap());
        std::thread::sleep(std::time::Duration::from_millis(20));
        h.finish(result(3, TaskState::Failed));
        let r = waiter.join().unwrap();
        assert_eq!(r.state, TaskState::Failed);
        assert!(!r.is_done());
    }

    #[test]
    fn on_terminal_fires_on_finish_and_inline_when_already_terminal() {
        let h = TaskHandle::new(5, "t");
        h.advance(TaskState::Submitted);
        h.advance(TaskState::AgentScheduling);
        h.advance(TaskState::Executing);
        let (tx, rx) = std::sync::mpsc::channel();
        let tx2 = tx.clone();
        h.on_terminal(move |r| tx2.send(r.unwrap().state).unwrap());
        h.finish(result(5, TaskState::Done));
        assert_eq!(rx.recv().unwrap(), TaskState::Done);
        // Already-terminal registration runs inline.
        h.on_terminal(move |r| tx.send(r.unwrap().state).unwrap());
        assert_eq!(rx.recv().unwrap(), TaskState::Done);
    }

    #[test]
    fn on_terminal_fires_err_for_resultless_cancel() {
        let h = TaskHandle::new(6, "t");
        h.advance(TaskState::Submitted);
        let (tx, rx) = std::sync::mpsc::channel();
        h.on_terminal(move |r| tx.send(r.is_err()).unwrap());
        // Canceled is terminal but carries no TaskResult: listeners must
        // still hear about it (as an error), not hang forever.
        h.advance(TaskState::Canceled);
        assert!(rx.recv().unwrap());
    }

    #[test]
    fn cancel_path() {
        let h = TaskHandle::new(4, "t");
        h.advance(TaskState::Submitted);
        assert!(TaskState::Submitted.can_transition_to(TaskState::Canceled));
        assert!(!TaskState::Done.can_transition_to(TaskState::Submitted));
        assert!(TaskState::Canceled.is_terminal());
    }
}
