//! Session, PilotManager, Pilot, TaskManager — the RADICAL-Pilot front end
//! (paper Fig 3 steps 1–3 and Fig 4's client/pilot-manager plane).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cluster::{rm_for, Allocation, MachineSpec, ResourceManager};
use crate::comm::CommWorld;
use crate::error::{Error, Result};
use crate::metrics::Timer;
use crate::ops::dist::KernelBackend;
use crate::raptor::{Agent, MasterMsg, SchedPolicy};
use crate::util::lock_recover;

use super::description::{PilotDescription, TaskDescription};
use super::task::{TaskHandle, TaskState};

/// Pilot lifecycle states (paper §3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PilotState {
    New,
    PmgrLaunching,
    Active,
    Done,
    Failed,
}

/// An active resource placeholder: allocation + bootstrapped agent.
pub struct Pilot {
    pub id: u64,
    pub desc: PilotDescription,
    pub allocation: Allocation,
    state: Mutex<PilotState>,
    agent: Mutex<Agent>,
    rm: Arc<dyn ResourceManager>,
}

impl Pilot {
    pub fn state(&self) -> PilotState {
        *lock_recover(&self.state)
    }

    pub fn cores(&self) -> usize {
        self.desc.cores()
    }

    /// Virtual seconds the resource manager took to start this pilot.
    pub fn startup_latency(&self) -> f64 {
        self.allocation.startup_latency
    }

    /// Resource-usage tracker (paper §4.4): busy rank-seconds accumulated
    /// by the RAPTOR master and completed-task count.
    pub fn utilization(&self) -> std::sync::Arc<crate::raptor::Utilization> {
        lock_recover(&self.agent).utilization()
    }

    /// World ranks currently quarantined after task-deadline expiries
    /// (degraded mode): held by a timed-out straggler that has not yet
    /// reported back. Drops to zero as stragglers recover.
    pub fn quarantined_ranks(&self) -> u64 {
        self.utilization().quarantined_ranks()
    }

    fn master_tx(&self) -> std::sync::mpsc::Sender<MasterMsg> {
        lock_recover(&self.agent).master_tx()
    }

    /// Tear down the agent and release the allocation.
    pub fn shutdown(&self) {
        self.finish(PilotState::Done);
    }

    /// Mark the pilot failed: the same teardown as [`Pilot::shutdown`]
    /// (agent stopped, allocation released), but the pilot lands in
    /// [`PilotState::Failed`] so task managers and clients can tell an
    /// aborted pilot from a cleanly retired one.
    pub fn fail(&self) {
        self.finish(PilotState::Failed);
    }

    /// Teardown exactly once. A pilot that is already `Done` **or**
    /// `Failed` keeps its terminal state and its agent/allocation are
    /// not touched again — in particular, dropping a failed pilot must
    /// not re-run agent shutdown or double-release its cores.
    fn finish(&self, terminal: PilotState) {
        // lock_recover: a tenant thread that panicked while holding the
        // state lock (e.g. under fault injection) must not make the
        // pilot un-shutdownable — teardown releases real resources.
        let mut st = lock_recover(&self.state);
        if matches!(*st, PilotState::Done | PilotState::Failed) {
            return;
        }
        lock_recover(&self.agent).shutdown();
        self.rm.release(&self.allocation);
        *st = terminal;
    }
}

impl Drop for Pilot {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Creates pilots on resource managers (paper Fig 3-2).
pub struct PilotManager {
    session: Arc<SessionInner>,
}

impl PilotManager {
    /// Submit a pilot with the native kernel backend and FIFO scheduling.
    pub fn submit(&self, desc: PilotDescription) -> Result<Arc<Pilot>> {
        self.submit_with(desc, KernelBackend::Native, SchedPolicy::Fifo)
    }

    /// Submit with explicit data-plane backend and master policy.
    pub fn submit_with(
        &self,
        desc: PilotDescription,
        backend: KernelBackend,
        policy: SchedPolicy,
    ) -> Result<Arc<Pilot>> {
        let cores = desc.cores();
        if cores == 0 {
            return Err(Error::Pilot("pilot with zero cores".into()));
        }
        let rm = self.session.rm(&desc.machine);
        let allocation = rm.allocate(cores, desc.exclusive)?;
        // Bootstrap the world + agent (Fig 3-4/5). World ranks: CPU pool
        // first, then the (simulated) GPU pool.
        let total = desc.total_ranks();
        let world = CommWorld::new(total, desc.machine.netmodel());
        let mut classes = vec![crate::pilot::RankClass::Cpu; cores];
        classes.extend(vec![crate::pilot::RankClass::Gpu; desc.gpu_ranks]);
        let agent = Agent::bootstrap_with_classes(world, backend, policy, classes);
        let pilot = Arc::new(Pilot {
            id: self.session.next_id(),
            desc,
            allocation,
            state: Mutex::new(PilotState::Active),
            agent: Mutex::new(agent),
            rm,
        });
        lock_recover(&self.session.pilots).push(Arc::downgrade(&pilot));
        Ok(pilot)
    }
}

/// Submits Cylon tasks to a pilot's RAPTOR master (paper Fig 3-3).
pub struct TaskManager {
    pilot: Arc<Pilot>,
    session: Arc<SessionInner>,
}

impl TaskManager {
    /// Submit one task; measures the paper's "(i) describing the task
    /// object" overhead component.
    pub fn submit(&self, td: TaskDescription) -> Result<TaskHandle> {
        if td.ranks == 0 {
            return Err(Error::Pilot(format!("task '{}' wants zero ranks", td.name)));
        }
        let pool = match td.rank_class {
            super::RankClass::Cpu => self.pilot.cores(),
            super::RankClass::Gpu => self.pilot.desc.gpu_ranks,
        };
        if td.ranks > pool {
            return Err(Error::Pilot(format!(
                "task '{}' wants {} {:?} ranks but pilot {} has {pool}",
                td.name, td.ranks, td.rank_class, self.pilot.id,
            )));
        }
        if self.pilot.state() != PilotState::Active {
            return Err(Error::Pilot(format!(
                "pilot {} is not active",
                self.pilot.id
            )));
        }
        let timer = Timer::start();
        let handle = TaskHandle::new(self.session.next_id(), &td.name);
        handle.advance(TaskState::Submitted);
        let description_s = timer.elapsed_s();
        self.pilot
            .master_tx()
            .send(MasterMsg::Submit { handle: handle.clone(), td, description_s })
            .map_err(|_| Error::Pilot("pilot agent is down".into()))?;
        Ok(handle)
    }

    /// Submit a batch and return the handles in order.
    pub fn submit_all(&self, tds: Vec<TaskDescription>) -> Result<Vec<TaskHandle>> {
        tds.into_iter().map(|td| self.submit(td)).collect()
    }

    /// Wait for all handles (order preserved).
    pub fn wait_all(&self, handles: &[TaskHandle]) -> Result<Vec<super::TaskResult>> {
        handles.iter().map(|h| h.wait()).collect()
    }
}

struct SessionInner {
    #[allow(dead_code)]
    name: String,
    rms: Mutex<HashMap<String, Arc<dyn ResourceManager>>>,
    pilots: Mutex<Vec<std::sync::Weak<Pilot>>>,
    ids: AtomicU64,
}

impl SessionInner {
    fn rm(&self, machine: &MachineSpec) -> Arc<dyn ResourceManager> {
        let mut rms = lock_recover(&self.rms);
        rms.entry(machine.name.clone())
            .or_insert_with(|| Arc::from(rm_for(machine.clone())))
            .clone()
    }

    fn next_id(&self) -> u64 {
        self.ids.fetch_add(1, Ordering::Relaxed)
    }
}

/// A RADICAL session: owns resource-manager views and id allocation.
pub struct Session {
    inner: Arc<SessionInner>,
}

impl Session {
    pub fn new(name: &str) -> Session {
        Session {
            inner: Arc::new(SessionInner {
                name: name.to_string(),
                rms: Mutex::new(HashMap::new()),
                pilots: Mutex::new(Vec::new()),
                ids: AtomicU64::new(1),
            }),
        }
    }

    pub fn pilot_manager(&self) -> PilotManager {
        PilotManager { session: self.inner.clone() }
    }

    pub fn task_manager(&self, pilot: &Arc<Pilot>) -> TaskManager {
        TaskManager { pilot: pilot.clone(), session: self.inner.clone() }
    }

    /// Free cores visible on a machine's RM (test/diagnostic hook).
    pub fn free_cores(&self, machine: &MachineSpec) -> usize {
        self.inner.rm(machine).free_cores()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pilot::DataDist;

    #[test]
    fn full_stack_join_roundtrip() {
        let session = Session::new("t");
        let pd = PilotDescription::new(MachineSpec::local(8), 1);
        let pilot = session.pilot_manager().submit(pd).unwrap();
        assert_eq!(pilot.state(), PilotState::Active);
        let tm = session.task_manager(&pilot);
        let h = tm
            .submit(TaskDescription::join("j", 8, 100, DataDist::Uniform))
            .unwrap();
        let r = h.wait().unwrap();
        assert!(r.is_done());
        assert!(r.output_rows > 0);
        pilot.shutdown();
    }

    #[test]
    fn pilot_releases_cores_on_shutdown() {
        let session = Session::new("t");
        let machine = MachineSpec::rivanna();
        let pd = PilotDescription::new(machine.clone(), 2);
        let pilot = session.pilot_manager().submit(pd).unwrap();
        assert_eq!(session.free_cores(&machine), 518 - 74);
        pilot.shutdown();
        assert_eq!(session.free_cores(&machine), 518);
    }

    #[test]
    fn failed_pilot_releases_once_and_stays_failed() {
        let session = Session::new("t");
        let machine = MachineSpec::rivanna();
        let pd = PilotDescription::new(machine.clone(), 2);
        let pilot = session.pilot_manager().submit(pd).unwrap();
        assert_eq!(session.free_cores(&machine), 518 - 74);
        pilot.fail();
        assert_eq!(pilot.state(), PilotState::Failed);
        assert_eq!(session.free_cores(&machine), 518);
        // Failed is terminal: a later shutdown (or drop) must neither
        // flip the state to Done nor release the allocation again.
        pilot.shutdown();
        assert_eq!(pilot.state(), PilotState::Failed);
        assert_eq!(session.free_cores(&machine), 518);
        let tm = session.task_manager(&pilot);
        assert!(tm
            .submit(TaskDescription::sort("late", 1, 10, DataDist::Uniform))
            .is_err());
        drop(pilot);
        assert_eq!(session.free_cores(&machine), 518);
    }

    #[test]
    fn oversized_task_rejected() {
        let session = Session::new("t");
        let pilot = session
            .pilot_manager()
            .submit(PilotDescription::new(MachineSpec::local(4), 1))
            .unwrap();
        let tm = session.task_manager(&pilot);
        let err = tm
            .submit(TaskDescription::sort("big", 5, 10, DataDist::Uniform))
            .unwrap_err();
        assert!(err.to_string().contains("wants 5 Cpu ranks"));
        assert!(tm
            .submit(TaskDescription::sort("zero", 0, 10, DataDist::Uniform))
            .is_err());
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let session = Session::new("t");
        let pilot = session
            .pilot_manager()
            .submit(PilotDescription::new(MachineSpec::local(2), 1))
            .unwrap();
        let tm = session.task_manager(&pilot);
        pilot.shutdown();
        assert!(tm
            .submit(TaskDescription::sort("late", 1, 10, DataDist::Uniform))
            .is_err());
    }

    #[test]
    fn two_pilots_on_one_machine_share_the_rm() {
        let session = Session::new("t");
        let machine = MachineSpec::rivanna();
        let p1 = session
            .pilot_manager()
            .submit(PilotDescription::new(machine.clone(), 7))
            .unwrap();
        let p2 = session
            .pilot_manager()
            .submit(PilotDescription::new(machine.clone(), 7))
            .unwrap();
        // 14 nodes total: a third 1-node pilot must fail.
        assert!(session
            .pilot_manager()
            .submit(PilotDescription::new(machine.clone(), 1))
            .is_err());
        p1.shutdown();
        p2.shutdown();
    }

    #[test]
    fn submit_all_and_wait_all() {
        let session = Session::new("t");
        let pilot = session
            .pilot_manager()
            .submit(PilotDescription::new(MachineSpec::local(4), 1))
            .unwrap();
        let tm = session.task_manager(&pilot);
        let tds = vec![
            TaskDescription::sort("a", 2, 50, DataDist::Uniform),
            TaskDescription::join("b", 2, 50, DataDist::Uniform),
            TaskDescription::sort("c", 4, 50, DataDist::Uniform),
        ];
        let hs = tm.submit_all(tds).unwrap();
        let rs = tm.wait_all(&hs).unwrap();
        assert_eq!(rs.len(), 3);
        assert!(rs.iter().all(|r| r.is_done()));
        pilot.shutdown();
    }
}
