//! Pilot and task descriptions — the `radical.pilot.PilotDescription` /
//! `TaskDescription` analogues (paper §3.4: "each Cylon task is represented
//! as a RadicalPilot.TaskDescription class with their resource
//! requirements").

use std::sync::Arc;

use crate::cluster::MachineSpec;
use crate::df::{ChunkedTable, Table};
use crate::ops::operator::{groupby_op, join_op, sort_op, OpHandle};

/// Key distribution of the generated workload (re-exported df type).
pub use crate::df::KeyDist as DataDist;

/// Resource placeholder request (paper Fig 3-2).
#[derive(Clone, Debug)]
pub struct PilotDescription {
    pub machine: MachineSpec,
    pub nodes: usize,
    /// Whole-node allocation (LSF batch semantics) vs core-granular.
    pub exclusive: bool,
    /// Exact core count override (RP core-granular pilots); `None` means
    /// `nodes * cores_per_node`.
    pub cores_override: Option<usize>,
    /// GPU ranks to provision *in addition to* the CPU cores (paper §4.4's
    /// heterogeneous CPU/GPU rank groups; simulated processing elements).
    pub gpu_ranks: usize,
}

impl PilotDescription {
    pub fn new(machine: MachineSpec, nodes: usize) -> PilotDescription {
        PilotDescription {
            machine,
            nodes,
            exclusive: false,
            cores_override: None,
            gpu_ranks: 0,
        }
    }

    /// Core-granular pilot of exactly `cores` ranks.
    pub fn with_cores(machine: MachineSpec, cores: usize) -> PilotDescription {
        let nodes = machine.nodes_for(cores);
        PilotDescription {
            machine,
            nodes,
            exclusive: false,
            cores_override: Some(cores),
            gpu_ranks: 0,
        }
    }

    /// Add a GPU rank pool to the pilot.
    pub fn with_gpus(mut self, gpu_ranks: usize) -> PilotDescription {
        self.gpu_ranks = gpu_ranks;
        self
    }

    /// CPU ranks.
    pub fn cores(&self) -> usize {
        self.cores_override
            .unwrap_or(self.nodes * self.machine.cores_per_node)
    }

    /// All ranks: CPU pool then GPU pool (world rank order).
    pub fn total_ranks(&self) -> usize {
        self.cores() + self.gpu_ranks
    }
}

/// Processing-element class a task's ranks must run on (paper §4.4:
/// "distinct groups of ranks equipped with specialized memory allocated
/// either on CPUs or GPUs").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RankClass {
    #[default]
    Cpu,
    Gpu,
}

/// A Cylon task: the operation + its resource requirements + workload spec.
#[derive(Clone, Debug)]
pub struct TaskDescription {
    pub name: String,
    /// Ranks (cores) the task's private communicator must span.
    pub ranks: usize,
    /// Rows generated per rank (weak scaling) — for strong scaling, set
    /// `rows_per_rank = total_rows / ranks` via [`Self::strong`].
    pub rows_per_rank: usize,
    /// Distinct-key space for generated keys.
    pub key_space: i64,
    pub dist: DataDist,
    /// The operator this task executes — any [`crate::ops::operator::Operator`]
    /// implementation (built-in or user-registered). The executor dispatches
    /// through this handle; there is no closed operation enum.
    pub op: OpHandle,
    pub seed: u64,
    /// Scheduling priority: higher dispatches first (§4.4 multi-tenancy).
    pub priority: i32,
    /// Which rank pool the private communicator is carved from.
    pub rank_class: RankClass,
    /// Staged input tables (pipeline table handoff), in operator-input
    /// order: the task's ranks consume contiguous row windows of each
    /// instead of generating synthetic data from the spec above. A join
    /// consumes **two** entries — both sides piped from upstream tasks.
    /// Each is held as a [`ChunkedTable`] so a gathered upstream output
    /// stays in its per-rank parts and the per-rank windowing copies
    /// nothing ([`crate::ops::dist::partition_slice`]).
    ///
    /// Staging *fewer* tables than [`crate::ops::operator::Operator::num_inputs`]
    /// is rejected at execution time unless the task explicitly opts into
    /// [`Self::allow_synthetic_fill`] — a partially-piped operator never
    /// silently regenerates its missing inputs.
    pub inputs: Vec<Arc<ChunkedTable>>,
    /// Opt-in: generate synthetic partitions for operator inputs beyond
    /// the staged ones (`inputs`), instead of failing. Off by default.
    pub synthetic_fill: bool,
    /// Collect the task's output table (gathered to group rank 0 and
    /// carried in [`super::TaskResult::output`]) — the producer side of the
    /// pipeline handoff. Off by default: gathering costs one extra
    /// collective per task.
    pub keep_output: bool,
    /// Execution attempt, 1-based. The retry layer bumps this on each
    /// re-submission so keyed fault-injection sites (`agent.task`,
    /// `op.execute`) re-draw their decision per attempt — a task that was
    /// failed by an armed probability can succeed on retry.
    pub attempt: u32,
    /// Per-task deadline: once dispatched longer than this, the raptor
    /// watchdog marks the task `Failed` with `Error::Timeout` and
    /// quarantines its ranks. `None` falls back to the process default
    /// (`util::faults::default_deadline`), which is itself off unless
    /// configured.
    pub deadline: Option<std::time::Duration>,
}

impl TaskDescription {
    pub fn new(name: &str, op: OpHandle, ranks: usize, rows_per_rank: usize) -> Self {
        TaskDescription {
            name: name.to_string(),
            ranks,
            rows_per_rank,
            key_space: (rows_per_rank as i64 * ranks as i64).max(16),
            dist: DataDist::Uniform,
            op,
            seed: 0xC71,
            priority: 0,
            rank_class: RankClass::Cpu,
            inputs: Vec::new(),
            synthetic_fill: false,
            keep_output: false,
            attempt: 1,
            deadline: None,
        }
    }

    /// Stage one input table (appended in operator-input order): ranks
    /// consume contiguous windows of it instead of generating synthetic
    /// data (pipeline table handoff). Call once per operator input.
    pub fn with_input(mut self, table: Arc<ChunkedTable>) -> Self {
        self.inputs.push(table);
        self
    }

    /// [`Self::with_input`] convenience for a contiguous table.
    pub fn with_input_table(self, table: Table) -> Self {
        self.with_input(Arc::new(ChunkedTable::from(table)))
    }

    /// Explicitly allow the executor to generate synthetic partitions for
    /// operator inputs that were not staged — e.g. a join piped only on
    /// its left side. Without this, a partial staging fails loudly.
    pub fn allow_synthetic_fill(mut self) -> Self {
        self.synthetic_fill = true;
        self
    }

    /// Request the output table be gathered and returned in the
    /// [`super::TaskResult`].
    pub fn collect_output(mut self) -> Self {
        self.keep_output = true;
        self
    }

    /// Scheduling priority (higher first).
    pub fn with_priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }

    /// Per-task deadline in seconds (watchdog kill + rank quarantine once
    /// overdue). Non-positive values clear it.
    pub fn with_deadline_s(mut self, seconds: f64) -> Self {
        self.deadline = (seconds > 0.0)
            .then(|| std::time::Duration::from_secs_f64(seconds));
        self
    }

    /// Target rank pool (CPU default; GPU pools per §4.4).
    pub fn on(mut self, class: RankClass) -> Self {
        self.rank_class = class;
        self
    }

    /// Weak-scaling join task: `rows_per_rank` on each of `ranks` ranks
    /// (default inner join on column 0 of both sides).
    pub fn join(name: &str, ranks: usize, rows_per_rank: usize, dist: DataDist) -> Self {
        let mut td = Self::new(name, join_op(), ranks, rows_per_rank);
        td.dist = dist;
        td
    }

    /// Weak-scaling sort task (default sort by column 0).
    pub fn sort(name: &str, ranks: usize, rows_per_rank: usize, dist: DataDist) -> Self {
        let mut td = Self::new(name, sort_op(), ranks, rows_per_rank);
        td.dist = dist;
        td
    }

    /// Weak-scaling groupby task (default sum of column 1 by column 0).
    pub fn groupby(name: &str, ranks: usize, rows_per_rank: usize) -> Self {
        Self::new(name, groupby_op(), ranks, rows_per_rank)
    }

    /// Strong scaling: `total_rows` divided across `ranks`.
    pub fn strong(name: &str, op: OpHandle, ranks: usize, total_rows: usize) -> Self {
        Self::new(name, op, ranks, total_rows.div_ceil(ranks.max(1)))
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_key_space(mut self, key_space: i64) -> Self {
        self.key_space = key_space;
        self
    }

    /// Total rows across all ranks.
    pub fn total_rows(&self) -> usize {
        self.ranks * self.rows_per_rank
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pilot_cores() {
        let pd = PilotDescription::new(MachineSpec::rivanna(), 2);
        assert_eq!(pd.cores(), 74);
    }

    #[test]
    fn strong_scaling_divides() {
        let td = TaskDescription::strong("s", sort_op(), 8, 1000);
        assert_eq!(td.rows_per_rank, 125);
        assert_eq!(td.total_rows(), 1000);
        let uneven = TaskDescription::strong("s", sort_op(), 3, 100);
        assert_eq!(uneven.rows_per_rank, 34); // ceil
    }

    #[test]
    fn builders() {
        let td = TaskDescription::join("j", 4, 100, DataDist::Uniform)
            .with_seed(9)
            .with_key_space(50);
        assert_eq!(td.op.name(), "join");
        assert_eq!(td.op.num_inputs(), 2);
        assert_eq!(td.seed, 9);
        assert_eq!(td.key_space, 50);
        assert_eq!(TaskDescription::groupby("g", 2, 10).op.name(), "groupby");
        assert!(!td.synthetic_fill);
        assert!(td.inputs.is_empty());
        assert_eq!(td.attempt, 1);
        assert!(td.deadline.is_none());
        let td = td.with_deadline_s(2.5);
        assert_eq!(td.deadline, Some(std::time::Duration::from_millis(2500)));
        assert!(td.with_deadline_s(0.0).deadline.is_none());
    }
}
