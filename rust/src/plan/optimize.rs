//! Plan-lowering optimizer: three rewrite passes applied by default in
//! [`Plan::lower`] (skip with [`Plan::without_optimizer`]).
//!
//! 1. **Normalization** — positional references ([`Expr::Idx`],
//!    [`ColRef::Index`]) are resolved to column names against the
//!    propagated schemas, so the later passes reason purely about names.
//! 2. **Filter fusion + predicate pushdown** (one combined pass) —
//!    adjacent filters conjoin into one evaluator walk
//!    (`Filter(p1, Filter(p2, X))` → `Filter(p2 && p1, X)`), and a filter
//!    sinks toward its source: below `sort` (fewer rows exchanged and
//!    sorted), through `project`/`derive` when the predicate only
//!    references surviving / pre-existing columns, and past **either**
//!    side of an *inner* join when every referenced column comes from
//!    exactly one input (the build side included). Filters never cross
//!    `groupby` (the predicate sees aggregated columns) or `union`
//!    (conservative; would duplicate the predicate).
//! 3. **Projection pruning** — a top-down
//!    required-column analysis: `groupby` needs only its key/value,
//!    `project` only its list, and every expression contributes its
//!    references; a `derive` whose output no consumer reads is dropped
//!    entirely, and a `generate`/`scan-csv` source feeding a strict
//!    subset of its columns gets a zero-copy `project` inserted above it
//!    so only referenced columns survive the scan. Pruning stops at
//!    `union` (both sides must keep identical schemas) and at joins with
//!    colliding column names (suffix renaming would shift downstream
//!    names).
//!
//! **Safety contract.** Every pass preserves the result *multiset* — the
//! same correctness contract the distributed operators themselves
//! provide (shuffles and joins promise bag equality, not row order).
//! `tests/prop_expr.rs` pins optimized vs [`Plan::without_optimizer`]
//! fingerprint equality across engines and scheduling policies. Two
//! sharp edges are intentionally part of the contract:
//!
//! * fused/pushed predicates evaluate on different row sets than their
//!   unfused originals, so an expression that *errors* on rows another
//!   predicate would have removed (int64 division by zero) can surface
//!   that error in the optimized plan — `and`/`or` are documented as
//!   eager, not guards ([`crate::ops::local::eval_expr`]);
//! * rewrites preserve each logical node's attributes (name, rank
//!   override, collect flag); when a filter sinks below the plan's sink
//!   node, the collect flag transfers to whatever node now sits on top.

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::df::{ColRef, Schema};
use crate::error::Result;
use crate::ops::local::JoinType;

use super::{LogicalOp, Plan};

/// Apply all passes; returns the rewritten plan (the input is untouched —
/// unchanged subtrees are shared via `Arc`). Called by [`Plan::lower`]
/// after schema validation, so the tree is known well-typed.
pub fn optimize(plan: &Plan) -> Result<Plan> {
    let mut memo: RewriteMemo = Vec::new();
    let normalized = normalize(plan, &mut memo)?;
    let mut memo: RewriteMemo = Vec::new();
    let pushed = push_filters(&normalized, &mut memo)?;
    let mut memo: PruneMemo = Vec::new();
    let pruned = prune(&pushed, None, &mut memo)?;
    let mut out = (*pruned).clone();
    // The plan's root is its sink: whatever node the rewrites left on top
    // must carry the original sink's collect flag.
    out.collect = plan.collect;
    out.optimize = plan.optimize;
    Ok(out)
}

/// Per-pass rewrite memo keyed by `Arc` pointer identity of the *input*
/// tree, so shared subtrees (diamonds) are rewritten once and stay
/// shared in the output. Linear scan: plans are small.
type RewriteMemo = Vec<(*const Plan, Arc<Plan>)>;

/// Pruning memo additionally keyed by the required-column set (the same
/// subtree may be consumed with different requirements).
type PruneMemo = Vec<(*const Plan, String, Arc<Plan>)>;

fn rewrite_children<F>(
    p: &Plan,
    memo: &mut RewriteMemo,
    f: F,
) -> Result<Vec<Arc<Plan>>>
where
    F: Fn(&Plan, &mut RewriteMemo) -> Result<Arc<Plan>>,
{
    let mut out = Vec::with_capacity(p.inputs.len());
    for c in &p.inputs {
        let ptr = Arc::as_ptr(c);
        let hit = memo.iter().find(|(q, _)| *q == ptr).map(|(_, a)| a.clone());
        let a = match hit {
            Some(a) => a,
            None => {
                let a = f(c.as_ref(), memo)?;
                memo.push((ptr, a.clone()));
                a
            }
        };
        out.push(a);
    }
    Ok(out)
}

/// Resolve a key reference to its column name.
fn named_ref(key: &ColRef, schema: &Schema) -> Result<ColRef> {
    Ok(ColRef::Name(schema.field(key.resolve(schema)?).name.clone()))
}

/// The name a (post-normalization) key refers to, if it is name-based.
fn key_name(key: &ColRef) -> Option<&str> {
    match key {
        ColRef::Name(n) => Some(n),
        ColRef::Index(_) => None,
    }
}

// ---------------------------------------------------------------------------
// Pass 1: normalize positional references to names
// ---------------------------------------------------------------------------

fn normalize(p: &Plan, memo: &mut RewriteMemo) -> Result<Arc<Plan>> {
    let inputs = rewrite_children(p, memo, normalize)?;
    let mut node = p.with_inputs(inputs);
    match &mut node.op {
        LogicalOp::Filter { predicate } => {
            let s = node.inputs[0].output_schema()?;
            *predicate = predicate.normalized(&s)?;
        }
        LogicalOp::Derive { expr, .. } => {
            let s = node.inputs[0].output_schema()?;
            *expr = expr.normalized(&s)?;
        }
        LogicalOp::Sort { key } => {
            let s = node.inputs[0].output_schema()?;
            *key = named_ref(key, &s)?;
        }
        LogicalOp::Groupby { key, val, .. } => {
            let s = node.inputs[0].output_schema()?;
            *key = named_ref(key, &s)?;
            *val = named_ref(val, &s)?;
        }
        LogicalOp::Join { left_key, right_key, .. } => {
            let l = node.inputs[0].output_schema()?;
            let r = node.inputs[1].output_schema()?;
            *left_key = named_ref(left_key, &l)?;
            *right_key = named_ref(right_key, &r)?;
        }
        LogicalOp::Generate { .. }
        | LogicalOp::ScanCsv { .. }
        | LogicalOp::Project { .. }
        | LogicalOp::Union => {}
    }
    Ok(Arc::new(node))
}

// ---------------------------------------------------------------------------
// Pass 2: filter fusion + predicate pushdown
// ---------------------------------------------------------------------------

fn push_filters(p: &Plan, memo: &mut RewriteMemo) -> Result<Arc<Plan>> {
    let mut inputs = rewrite_children(p, memo, push_filters)?;
    if matches!(p.op, LogicalOp::Filter { .. }) {
        let child = inputs.pop().expect("filter has one input");
        let filter = p.with_inputs(Vec::new());
        return sink(filter, child).map(Arc::new);
    }
    Ok(Arc::new(p.with_inputs(inputs)))
}

/// Sink `filter` (a `Filter` node with no inputs attached yet) as deep
/// into `child` as the rewrite rules allow; returns the new subtree
/// equivalent to `Filter(child)`.
fn sink(mut filter: Plan, child: Arc<Plan>) -> Result<Plan> {
    let pred = match &filter.op {
        LogicalOp::Filter { predicate } => predicate.clone(),
        _ => unreachable!("sink only called on filter nodes"),
    };
    let mut refs = BTreeSet::new();
    pred.references(&mut refs);
    // Positional references pin the predicate to one schema layout;
    // normalization removes them, but stay safe if callers skip it.
    let movable = !pred.uses_indices();
    let fcollect = filter.collect;

    // Swap the filter below `child` and keep sinking into `child`'s
    // input: Filter(Op(X)) -> Op(Filter(X)).
    let swap_below = |filter: Plan, child: &Arc<Plan>| -> Result<Plan> {
        let inner = sink(filter, child.inputs[0].clone())?;
        let mut parent =
            child.with_inputs(vec![Arc::new(inner)]);
        parent.collect |= fcollect;
        Ok(parent)
    };

    match &child.op {
        // Fusion: Filter(p1, Filter(p2, X)) -> Filter(p2 && p1, X) — the
        // inner predicate keeps first position (it ran first originally).
        LogicalOp::Filter { predicate: inner } => {
            filter.op =
                LogicalOp::Filter { predicate: inner.clone().and(pred) };
            filter.collect |= child.collect;
            if filter.name.is_none() {
                filter.name = child.name.clone();
            }
            if filter.ranks.is_none() {
                filter.ranks = child.ranks;
            }
            sink(filter, child.inputs[0].clone())
        }
        // Sort keeps the schema, so even positional predicates sink:
        // filtering before the sample-sort shrinks the exchange.
        LogicalOp::Sort { .. } => swap_below(filter, &child),
        // Through a projection when every referenced column survives it
        // (projection preserves names; positions may shift, hence the
        // name-only guard).
        LogicalOp::Project { columns }
            if movable && refs.iter().all(|n| columns.contains(n)) =>
        {
            swap_below(filter, &child)
        }
        // Through a derive that the predicate does not read.
        LogicalOp::Derive { name, .. }
            if movable && !refs.contains(name) =>
        {
            swap_below(filter, &child)
        }
        // Past one side of an inner join when every referenced column
        // resolves in exactly that input. Left columns keep their names
        // post-join, so "resolves in left" is decisive even under
        // collisions (the right side's collided column was suffixed).
        LogicalOp::Join { how: JoinType::Inner, .. }
            if movable && !refs.is_empty() =>
        {
            let l = child.inputs[0].output_schema()?;
            let r = child.inputs[1].output_schema()?;
            let all_left = refs.iter().all(|n| l.index_of(n).is_ok());
            let all_right_only = refs
                .iter()
                .all(|n| l.index_of(n).is_err() && r.index_of(n).is_ok());
            if all_left {
                let inner = sink(filter, child.inputs[0].clone())?;
                let mut parent = child.with_inputs(vec![
                    Arc::new(inner),
                    child.inputs[1].clone(),
                ]);
                parent.collect |= fcollect;
                Ok(parent)
            } else if all_right_only {
                let inner = sink(filter, child.inputs[1].clone())?;
                let mut parent = child.with_inputs(vec![
                    child.inputs[0].clone(),
                    Arc::new(inner),
                ]);
                parent.collect |= fcollect;
                Ok(parent)
            } else {
                filter.inputs = vec![child];
                Ok(filter)
            }
        }
        // Everything else (sources, groupby, union, outer joins, guarded
        // cases above): the filter stays put.
        _ => {
            filter.inputs = vec![child];
            Ok(filter)
        }
    }
}

// ---------------------------------------------------------------------------
// Pass 3: projection pruning
// ---------------------------------------------------------------------------

fn req_key(req: Option<&BTreeSet<String>>) -> String {
    match req {
        None => "*".to_string(),
        Some(r) => r.iter().cloned().collect::<Vec<_>>().join(","),
    }
}

fn prune_child(
    c: &Arc<Plan>,
    req: Option<&BTreeSet<String>>,
    memo: &mut PruneMemo,
) -> Result<Arc<Plan>> {
    let ptr = Arc::as_ptr(c);
    let key = req_key(req);
    if let Some((_, _, res)) =
        memo.iter().find(|(q, k, _)| *q == ptr && *k == key)
    {
        return Ok(res.clone());
    }
    let res = prune(c, req, memo)?;
    memo.push((ptr, key, res.clone()));
    Ok(res)
}

/// Rewrite `p` so that only columns in `req` (plus whatever `p` itself
/// reads) survive below it; `None` means "everything" (the sink's own
/// schema is part of the user contract and never narrowed).
fn prune(
    p: &Plan,
    req: Option<&BTreeSet<String>>,
    memo: &mut PruneMemo,
) -> Result<Arc<Plan>> {
    match &p.op {
        LogicalOp::Generate { .. } | LogicalOp::ScanCsv { .. } => {
            let schema = match &p.op {
                LogicalOp::Generate { .. } => crate::df::GenSpec::schema(),
                LogicalOp::ScanCsv { schema, .. } => schema.clone(),
                _ => unreachable!(),
            };
            if let Some(r) = req {
                let keep: Vec<String> = schema
                    .fields()
                    .iter()
                    .filter(|f| r.contains(&f.name))
                    .map(|f| f.name.clone())
                    .collect();
                // Strict subset and the source is not itself the
                // collected sink: insert a zero-copy projection so only
                // the referenced columns flow downstream.
                let narrows = !keep.is_empty() && keep.len() < schema.len();
                if narrows && !p.collect {
                    let src = Arc::new(p.clone());
                    return Ok(Arc::new(Plan {
                        op: LogicalOp::Project { columns: keep },
                        inputs: vec![src],
                        ranks: None,
                        name: None,
                        collect: false,
                        optimize: p.optimize,
                    }));
                }
            }
            Ok(Arc::new(p.clone()))
        }
        LogicalOp::Filter { predicate } => {
            let child_req = req.map(|r| {
                let mut out = r.clone();
                predicate.references(&mut out);
                out
            });
            let c = prune_child(&p.inputs[0], child_req.as_ref(), memo)?;
            Ok(Arc::new(p.with_inputs(vec![c])))
        }
        LogicalOp::Derive { name, expr } => {
            if let Some(r) = req {
                if !r.contains(name) {
                    // Dead derive: no consumer reads the computed column,
                    // so the whole node disappears.
                    let res = prune_child(&p.inputs[0], req, memo)?;
                    if p.collect && !res.collect {
                        let mut keep = (*res).clone();
                        keep.collect = true;
                        return Ok(Arc::new(keep));
                    }
                    return Ok(res);
                }
            }
            let child_req = req.map(|r| {
                let mut out = r.clone();
                out.remove(name);
                expr.references(&mut out);
                out
            });
            let c = prune_child(&p.inputs[0], child_req.as_ref(), memo)?;
            Ok(Arc::new(p.with_inputs(vec![c])))
        }
        LogicalOp::Project { columns } => {
            let child_req: BTreeSet<String> = columns.iter().cloned().collect();
            let c = prune_child(&p.inputs[0], Some(&child_req), memo)?;
            Ok(Arc::new(p.with_inputs(vec![c])))
        }
        LogicalOp::Sort { key } => {
            let child_req = match (req, key_name(key)) {
                (Some(r), Some(k)) => {
                    let mut out = r.clone();
                    out.insert(k.to_string());
                    Some(out)
                }
                _ => None,
            };
            let c = prune_child(&p.inputs[0], child_req.as_ref(), memo)?;
            Ok(Arc::new(p.with_inputs(vec![c])))
        }
        LogicalOp::Groupby { key, val, .. } => {
            // The aggregation consumes exactly its key and value columns,
            // regardless of what downstream asks of the aggregate.
            let child_req = match (key_name(key), key_name(val)) {
                (Some(k), Some(v)) => {
                    let mut out = BTreeSet::new();
                    out.insert(k.to_string());
                    out.insert(v.to_string());
                    Some(out)
                }
                _ => None,
            };
            let c = prune_child(&p.inputs[0], child_req.as_ref(), memo)?;
            Ok(Arc::new(p.with_inputs(vec![c])))
        }
        LogicalOp::Join { left_key, right_key, .. } => {
            let l = p.inputs[0].output_schema()?;
            let r = p.inputs[1].output_schema()?;
            let collision = r
                .fields()
                .iter()
                .any(|f| l.index_of(&f.name).is_ok());
            let reqs = match (req, key_name(left_key), key_name(right_key)) {
                (Some(want), Some(lk), Some(rk)) if !collision => {
                    let side = |s: &Schema, key: &str| {
                        let mut out: BTreeSet<String> = want
                            .iter()
                            .filter(|n| s.index_of(n).is_ok())
                            .cloned()
                            .collect();
                        out.insert(key.to_string());
                        out
                    };
                    Some((side(&l, lk), side(&r, rk)))
                }
                _ => None,
            };
            let (lr, rr) = match &reqs {
                Some((a, b)) => (Some(a), Some(b)),
                None => (None, None),
            };
            let cl = prune_child(&p.inputs[0], lr, memo)?;
            let cr = prune_child(&p.inputs[1], rr, memo)?;
            Ok(Arc::new(p.with_inputs(vec![cl, cr])))
        }
        // Both union sides must keep identical schemas, so nothing is
        // narrowed below a union.
        LogicalOp::Union => {
            let cl = prune_child(&p.inputs[0], None, memo)?;
            let cr = prune_child(&p.inputs[1], None, memo)?;
            Ok(Arc::new(p.with_inputs(vec![cl, cr])))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::expr::{col, idx, lit};
    use super::*;
    use crate::df::GenSpec;
    use crate::ops::local::AggFn;

    fn gen(seed: u64) -> Plan {
        Plan::generate(2, GenSpec::uniform(100, 64, seed))
    }

    fn names(plan: &Plan) -> Vec<String> {
        let lowered = plan.lower().unwrap();
        lowered
            .pipeline
            .node_names()
            .iter()
            .map(|s| s.to_string())
            .collect()
    }

    #[test]
    fn adjacent_filters_fuse_into_one_node() {
        let plan = gen(1)
            .filter(col("val").ge(lit(0.25)))
            .filter(col("key").ne(lit(0)))
            .filter(col("val").lt(lit(0.75)))
            .collect();
        assert_eq!(names(&plan), vec!["generate-0", "filter-1"]);
        let un = plan.without_optimizer().lower().unwrap();
        assert_eq!(un.pipeline.len(), 4);
    }

    #[test]
    fn filter_sinks_below_sort() {
        let plan = gen(1).sort("key").filter(col("val").ge(lit(0.5))).collect();
        // Optimized: generate -> filter -> sort (sort becomes the sink).
        assert_eq!(names(&plan), vec!["generate-0", "filter-1", "sort-2"]);
    }

    #[test]
    fn filter_sinks_through_project_and_derive() {
        let plan = gen(1)
            .derive("scaled", col("val") * lit(2.0))
            .project(&["key", "val", "scaled"])
            .filter(col("key").ne(lit(0)))
            .collect();
        assert_eq!(
            names(&plan),
            vec!["generate-0", "filter-1", "derive-2", "project-3"]
        );
        // A predicate on the derived column cannot cross its derive.
        let blocked = gen(1)
            .derive("scaled", col("val") * lit(2.0))
            .filter(col("scaled").ge(lit(1.0)))
            .collect();
        assert_eq!(
            names(&blocked),
            vec!["generate-0", "derive-1", "filter-2"]
        );
    }

    #[test]
    fn filter_pushes_past_the_matching_join_side() {
        // "val" resolves on the left (right's collided copy is suffixed),
        // so the filter sinks into the left input.
        let plan = gen(1)
            .join(gen(2), "key", "key")
            .filter(col("val").ge(lit(0.5)))
            .collect();
        assert_eq!(
            names(&plan),
            vec!["generate-0", "filter-1", "generate-2", "join-3"]
        );
        // "val_right" exists only post-join: the filter stays above.
        let stays = gen(1)
            .join(gen(2), "key", "key")
            .filter(col("val_right").ge(lit(0.5)))
            .collect();
        assert_eq!(
            names(&stays),
            vec!["generate-0", "generate-1", "join-2", "filter-3"]
        );
    }

    #[test]
    fn filter_pushes_to_right_side_when_names_are_disjoint() {
        let right = gen(2)
            .derive("extra", col("val") * lit(3.0))
            .project(&["key", "extra"]);
        let plan = gen(1)
            .join(right, "key", "key")
            .filter(col("extra").ge(lit(1.0)))
            .collect();
        let got = names(&plan);
        // The filter must sit somewhere inside the right branch, below
        // the join.
        let join_pos = got.iter().position(|n| n.starts_with("join")).unwrap();
        let filter_pos =
            got.iter().position(|n| n.starts_with("filter")).unwrap();
        assert!(filter_pos < join_pos, "{got:?}");
    }

    #[test]
    fn groupby_prunes_source_columns() {
        // groupby needs only key/val — but generate has exactly those, so
        // nothing to prune here; with a derive in between the derived
        // column is dead the moment the groupby ignores it.
        let plan = gen(1)
            .derive("noise", col("val") * lit(9.0))
            .groupby("key", "val", AggFn::Sum)
            .collect();
        assert_eq!(names(&plan), vec!["generate-0", "groupby-1"]);
    }

    #[test]
    fn dead_derive_is_eliminated_and_scan_projected() {
        // The final projection reads key/val only: the derive is dead.
        let plan = gen(1)
            .derive("heavy", col("val") * lit(3.5))
            .sort("key")
            .project(&["key", "val"])
            .collect();
        assert_eq!(
            names(&plan),
            vec!["generate-0", "sort-1", "project-2"]
        );
        // Projecting a strict subset inserts a pruning projection above
        // the source.
        let plan = gen(1).sort("key").project(&["key"]).collect();
        assert_eq!(
            names(&plan),
            vec!["generate-0", "project-1", "sort-2", "project-3"]
        );
    }

    #[test]
    fn union_blocks_pruning_and_pushdown_stops() {
        let plan = gen(1)
            .union(gen(2))
            .filter(col("val").ge(lit(0.5)))
            .project(&["key"])
            .collect();
        let got = names(&plan);
        assert!(
            got.iter().any(|n| n.starts_with("union")),
            "{got:?}"
        );
        // The filter stays above the union.
        let union_pos = got.iter().position(|n| n.starts_with("union")).unwrap();
        let filter_pos =
            got.iter().position(|n| n.starts_with("filter")).unwrap();
        assert!(filter_pos > union_pos, "{got:?}");
    }

    #[test]
    fn normalization_rewrites_positional_references() {
        // An index-based predicate and sort key still optimize: normalize
        // maps them to names first, so the filter fuses and sinks.
        #[allow(deprecated)]
        let plan = gen(1)
            .sort(0)
            .filter_scalar(1, crate::ops::local::CmpOp::Ge, 0.5)
            .filter(idx(0).ne(lit(0)))
            .collect();
        assert_eq!(names(&plan), vec!["generate-0", "filter-1", "sort-2"]);
    }

    #[test]
    fn collect_flag_survives_restructuring() {
        // The sink was the filter; after pushdown the sort is on top and
        // must carry the collect flag (lower() asserts it's set on the
        // root via the engine tests; here we check the rewritten tree).
        let plan = gen(1).sort("key").filter(col("val").ge(lit(0.5))).collect();
        let opt = optimize(&plan).unwrap();
        assert!(opt.collect, "sink collect flag must survive pushdown");
    }
}
