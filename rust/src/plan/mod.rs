//! Logical plans: a fluent builder over the operator algebra that lowers
//! deterministically to the [`Pipeline`] DAG with piped table handoff.
//!
//! The paper's pipeline is "a collection of data frame operators arranged
//! in a DAG" (§4.4); a [`Plan`] *is* that arrangement, written the way a
//! dataframe user thinks — predicates and derived columns are typed
//! [`Expr`] trees ([`expr`]), and key arguments take column **names** (or
//! legacy indices):
//!
//! ```
//! use radical_cylon::plan::expr::{col, lit};
//! use radical_cylon::plan::Plan;
//! use radical_cylon::df::GenSpec;
//!
//! let users = Plan::generate(2, GenSpec::uniform(1_000, 500, 7))
//!     .filter(col("val").ge(lit(0.5)));
//! let events = Plan::generate(2, GenSpec::uniform(1_000, 500, 8));
//! let report = users
//!     .join(events, "key", "key") // both sides piped from upstream tasks
//!     .sort("key")
//!     .collect();
//! let lowered = report.lower().unwrap();
//! assert_eq!(lowered.pipeline.len(), 5); // gen, gen, filter, join, sort
//! ```
//!
//! **Lowering** ([`Plan::lower`]) first validates the whole tree against
//! the propagated schemas ([`Plan::output_schema`] — unknown columns and
//! type mismatches fail here with did-you-mean diagnostics, before any
//! task runs), then applies the [`optimize`] passes (filter fusion,
//! predicate pushdown, projection pruning — skipped via
//! [`Plan::without_optimizer`]), and finally walks the tree bottom-up and
//! emits one [`TaskDescription`] per distinct logical node:
//!
//! * every node's operator becomes an [`OpHandle`] (the same registry
//!   entries the executor dispatches through — no separate lowering per
//!   engine);
//! * every edge becomes a piped handoff
//!   ([`Pipeline::add_piped_multi`]): the producer gathers its output
//!   zero-copy, the consumer's ranks carve per-rank windows — a join
//!   consumes **both** sides from upstream tasks;
//! * structurally identical subtrees are emitted **once** (common
//!   subexpression elimination), so `let g = Plan::generate(..);
//!   g.clone().sort(0).union(g.clone().groupby(..))` runs one generate
//!   task, not two;
//! * node ids are assigned in deterministic post-order (left input first),
//!   so the same plan always lowers to the same DAG — the property the
//!   plan-vs-hand-built equivalence tests pin down.
//!
//! Execution goes through [`crate::exec::Engine::run_plan`] on any engine;
//! the heterogeneous engine drives the lowered DAG through the
//! event-driven dataflow scheduler. Optimized and unoptimized plans
//! produce identical result multisets (`tests/prop_expr.rs` pins the
//! fingerprints across engines and scheduling policies).

pub mod expr;
pub mod optimize;

use std::path::PathBuf;
use std::sync::Arc;

use crate::df::{ColRef, DataType, Field, GenSpec, Schema};
use crate::error::{Error, Result};
use crate::ops::local::{AggFn, CmpOp, JoinType};
use crate::ops::operator::{
    DeriveOp, FilterOp, GenerateOp, GroupbyOp, JoinOp, OpHandle, ProjectOp,
    ScanCsvOp, SortOp, UnionOp,
};
use crate::pilot::TaskDescription;
use crate::pipeline::Pipeline;

use expr::Expr;

/// The logical operation at one plan node.
#[derive(Clone, Debug)]
enum LogicalOp {
    Generate { spec: GenSpec },
    ScanCsv { path: PathBuf, schema: Schema },
    Filter { predicate: Expr },
    Project { columns: Vec<String> },
    Derive { name: String, expr: Expr },
    Join { left_key: ColRef, right_key: ColRef, how: JoinType },
    Sort { key: ColRef },
    Groupby { key: ColRef, val: ColRef, agg: AggFn },
    Union,
}

impl LogicalOp {
    fn op_name(&self) -> &'static str {
        match self {
            LogicalOp::Generate { .. } => "generate",
            LogicalOp::ScanCsv { .. } => "scan-csv",
            LogicalOp::Filter { .. } => "filter",
            LogicalOp::Project { .. } => "project",
            LogicalOp::Derive { .. } => "derive",
            LogicalOp::Join { .. } => "join",
            LogicalOp::Sort { .. } => "sort",
            LogicalOp::Groupby { .. } => "groupby",
            LogicalOp::Union => "union",
        }
    }

    fn handle(&self) -> OpHandle {
        match self {
            LogicalOp::Generate { .. } => Arc::new(GenerateOp),
            LogicalOp::ScanCsv { path, schema } => Arc::new(ScanCsvOp {
                path: path.clone(),
                schema: schema.clone(),
            }),
            LogicalOp::Filter { predicate } => {
                Arc::new(FilterOp { predicate: predicate.clone() })
            }
            LogicalOp::Project { columns } => Arc::new(ProjectOp {
                columns: columns.clone(),
            }),
            LogicalOp::Derive { name, expr } => Arc::new(DeriveOp {
                name: name.clone(),
                expr: expr.clone(),
            }),
            LogicalOp::Join { left_key, right_key, how } => Arc::new(JoinOp {
                left_key: left_key.clone(),
                right_key: right_key.clone(),
                how: *how,
            }),
            LogicalOp::Sort { key } => Arc::new(SortOp { key: key.clone() }),
            LogicalOp::Groupby { key, val, agg } => Arc::new(GroupbyOp {
                key: key.clone(),
                val: val.clone(),
                agg: *agg,
            }),
            LogicalOp::Union => Arc::new(UnionOp),
        }
    }
}

/// A logical dataframe plan — an expression tree of operators. Build one
/// from a source ([`Plan::generate`] / [`Plan::scan_csv`]), chain
/// transformations fluently, finish with [`Plan::collect`], and hand it to
/// [`crate::exec::Engine::run_plan`] (or [`Plan::lower`] it yourself).
///
/// `Clone` is cheap and safe to use for sharing: children are held behind
/// [`Arc`], so cloning copies one node, and lowering deduplicates both by
/// pointer identity (a shared subtree is visited once) and by structure
/// (separately-built identical subtrees emit one DAG node) — a cloned
/// source runs once.
#[derive(Clone, Debug)]
pub struct Plan {
    op: LogicalOp,
    inputs: Vec<Arc<Plan>>,
    /// Explicit rank override; sources require one, derived nodes default
    /// to the max over their inputs.
    ranks: Option<usize>,
    /// Explicit node name; auto-derived (`"{op}-{id}"`) when unset.
    name: Option<String>,
    /// Gather this node's output into the final [`crate::pilot::TaskResult`].
    collect: bool,
    /// Run the [`optimize`] passes in [`Plan::lower`] (default `true`;
    /// cleared by [`Plan::without_optimizer`]).
    optimize: bool,
}

/// A [`Plan`] lowered to the physical DAG: the [`Pipeline`] plus the node
/// id of the plan's sink (whose result carries the collected output).
#[derive(Clone, Debug)]
pub struct LoweredPlan {
    pub pipeline: Pipeline,
    /// Node id of the plan root in `pipeline`.
    pub sink: usize,
}

impl Plan {
    fn node(op: LogicalOp, inputs: Vec<Plan>) -> Plan {
        Plan {
            op,
            inputs: inputs.into_iter().map(Arc::new).collect(),
            ranks: None,
            name: None,
            collect: false,
            optimize: true,
        }
    }

    /// Same node with replaced inputs (attributes preserved) — the
    /// optimizer's rebuild primitive.
    fn with_inputs(&self, inputs: Vec<Arc<Plan>>) -> Plan {
        Plan {
            op: self.op.clone(),
            inputs,
            ranks: self.ranks,
            name: self.name.clone(),
            collect: self.collect,
            optimize: self.optimize,
        }
    }

    // ---- sources --------------------------------------------------------

    /// Source: `ranks` ranks each generating the deterministic synthetic
    /// partition described by `spec` (`spec.rows` rows *per rank*; schema
    /// `(key: int64, val: float64)` — [`GenSpec::schema`]).
    pub fn generate(ranks: usize, spec: GenSpec) -> Plan {
        let mut p = Plan::node(LogicalOp::Generate { spec }, vec![]);
        p.ranks = Some(ranks);
        p
    }

    /// Source: parallel CSV scan on `ranks` ranks; each rank keeps its own
    /// contiguous row window of the file.
    pub fn scan_csv(ranks: usize, path: impl Into<PathBuf>, schema: Schema) -> Plan {
        let mut p = Plan::node(
            LogicalOp::ScanCsv { path: path.into(), schema },
            vec![],
        );
        p.ranks = Some(ranks);
        p
    }

    // ---- transformations ------------------------------------------------

    /// Keep rows where the boolean `predicate` holds (zero-copy,
    /// rank-local). Build predicates from [`expr::col`] / [`expr::lit`]
    /// with comparisons and `and`/`or`/`not`:
    ///
    /// ```
    /// # use radical_cylon::plan::Plan;
    /// # use radical_cylon::plan::expr::{col, lit};
    /// # use radical_cylon::df::GenSpec;
    /// let p = Plan::generate(2, GenSpec::uniform(100, 64, 1))
    ///     .filter(col("val").ge(lit(0.5)).and(col("key").ne(lit(0))));
    /// ```
    ///
    /// Non-boolean predicates and unknown columns are rejected by
    /// [`Plan::lower`] with [`Error::Config`] diagnostics.
    pub fn filter(self, predicate: Expr) -> Plan {
        Plan::node(LogicalOp::Filter { predicate }, vec![self])
    }

    /// Legacy scalar filter: keep rows where `column <cmp> scalar`.
    ///
    /// Thin shim over [`Plan::filter`] that builds the equivalent
    /// expression (`idx(column) <cmp> lit(scalar)`); see
    /// [`FilterOp::scalar`] for the one NaN-related semantic difference
    /// from the pre-`Expr` kernel.
    #[deprecated(
        since = "0.2.0",
        note = "build a typed predicate with plan::expr::{col, lit} and \
                use Plan::filter"
    )]
    pub fn filter_scalar(self, column: usize, cmp: CmpOp, scalar: f64) -> Plan {
        self.filter(FilterOp::scalar(column, cmp, scalar).predicate)
    }

    /// Keep only the named columns (zero-copy, rank-local).
    pub fn project(self, columns: &[&str]) -> Plan {
        Plan::node(
            LogicalOp::Project {
                columns: columns.iter().map(|c| c.to_string()).collect(),
            },
            vec![self],
        )
    }

    /// Materialize a computed column appended under `name` (rank-local;
    /// existing columns stay zero-copy):
    ///
    /// ```
    /// # use radical_cylon::plan::Plan;
    /// # use radical_cylon::plan::expr::{col, lit};
    /// # use radical_cylon::df::GenSpec;
    /// let p = Plan::generate(2, GenSpec::uniform(100, 64, 1))
    ///     .derive("scaled", col("val") * lit(2.0) + lit(1.0));
    /// ```
    pub fn derive(self, name: &str, expr: Expr) -> Plan {
        Plan::node(
            LogicalOp::Derive { name: name.to_string(), expr },
            vec![self],
        )
    }

    /// Inner hash join with `other` on the given key columns (names or
    /// legacy indices) — **both** sides are piped from upstream tasks.
    pub fn join(
        self,
        other: Plan,
        left_key: impl Into<ColRef>,
        right_key: impl Into<ColRef>,
    ) -> Plan {
        self.join_how(other, left_key, right_key, JoinType::Inner)
    }

    /// [`Plan::join`] with an explicit [`JoinType`].
    pub fn join_how(
        self,
        other: Plan,
        left_key: impl Into<ColRef>,
        right_key: impl Into<ColRef>,
        how: JoinType,
    ) -> Plan {
        Plan::node(
            LogicalOp::Join {
                left_key: left_key.into(),
                right_key: right_key.into(),
                how,
            },
            vec![self, other],
        )
    }

    /// Globally sort by an int64 column — name or legacy index
    /// (distributed sample-sort).
    pub fn sort(self, key: impl Into<ColRef>) -> Plan {
        Plan::node(LogicalOp::Sort { key: key.into() }, vec![self])
    }

    /// Group by `key`, aggregating `val` with `agg` (two-phase distributed
    /// aggregation). Keys take names or legacy indices.
    pub fn groupby(
        self,
        key: impl Into<ColRef>,
        val: impl Into<ColRef>,
        agg: AggFn,
    ) -> Plan {
        Plan::node(
            LogicalOp::Groupby { key: key.into(), val: val.into(), agg },
            vec![self],
        )
    }

    /// Concatenate with `other` (zero-copy chunk adoption, rank-local).
    /// Schemas must match — validated at lowering time.
    pub fn union(self, other: Plan) -> Plan {
        Plan::node(LogicalOp::Union, vec![self, other])
    }

    // ---- node attributes ------------------------------------------------

    /// Override the rank count for **this** node (derived nodes otherwise
    /// inherit the max over their inputs).
    pub fn with_ranks(mut self, ranks: usize) -> Plan {
        self.ranks = Some(ranks);
        self
    }

    /// Name this node's task (auto-derived `"{op}-{id}"` otherwise).
    pub fn named(mut self, name: &str) -> Plan {
        self.name = Some(name.to_string());
        self
    }

    /// Mark the plan's result for collection: the sink task gathers its
    /// output table and the engine returns it in
    /// [`crate::exec::PlanRun::output`].
    pub fn collect(mut self) -> Plan {
        self.collect = true;
        self
    }

    /// Escape hatch: lower **without** the [`optimize`] passes. The
    /// optimizer preserves result multisets, so optimized and
    /// unoptimized runs of the same plan produce identical table
    /// fingerprints — this switch exists for debugging and for the
    /// invariance tests that prove exactly that.
    pub fn without_optimizer(mut self) -> Plan {
        self.optimize = false;
        self
    }

    // ---- introspection --------------------------------------------------

    /// Whether the plan root gathers its output ([`Plan::collect`]) — the
    /// precondition for the query service's result cache to hold anything
    /// worth returning.
    pub fn collects(&self) -> bool {
        self.collect
    }

    /// Whether any source node reads external, mutable state
    /// ([`Plan::scan_csv`] — the file can change between runs).
    /// Diagnostic only: the query service's result cache no longer gates
    /// on this, because [`Plan::fingerprint`] folds each scanned file's
    /// content identity (length + mtime) into the key, so a changed file
    /// misses the cache naturally.
    pub fn reads_external_sources(&self) -> bool {
        let mut seen: Vec<*const Plan> = Vec::new();
        self.reads_external_inner(&mut seen)
    }

    fn reads_external_inner(&self, seen: &mut Vec<*const Plan>) -> bool {
        if matches!(self.op, LogicalOp::ScanCsv { .. }) {
            return true;
        }
        for input in &self.inputs {
            let ptr = Arc::as_ptr(input);
            if seen.contains(&ptr) {
                continue;
            }
            seen.push(ptr);
            if input.reads_external_inner(seen) {
                return true;
            }
        }
        false
    }

    // ---- fingerprinting -------------------------------------------------

    /// Canonical fingerprint of the **optimized** plan — the query
    /// service's cache key.
    ///
    /// Validates the tree ([`Plan::output_schema`]), applies the
    /// [`optimize`] passes (which normalize legacy index column refs to
    /// names, so `idx(1)` and `col("val")` variants of the same plan
    /// fingerprint identically), then emits the structural-CSE node keys
    /// from the lowering memo in canonical post-order — *without*
    /// constructing the physical DAG. Two plans share a fingerprint iff
    /// they lower to the same pipeline, so a plan-cache hit can reuse the
    /// cached [`LoweredPlan`] and skip re-lowering entirely.
    pub fn fingerprint(&self) -> Result<String> {
        self.output_schema()?;
        if self.optimize {
            optimize::optimize(self)?.fingerprint_raw()
        } else {
            self.fingerprint_raw()
        }
    }

    fn fingerprint_raw(&self) -> Result<String> {
        let mut keys: Vec<String> = Vec::new();
        let mut memo: Vec<(String, usize, usize)> = Vec::new();
        let mut ptr_memo: Vec<(*const Plan, (usize, usize))> = Vec::new();
        self.fingerprint_into(&mut keys, &mut memo, &mut ptr_memo)?;
        Ok(keys.join("\n"))
    }

    /// Content-identity suffix for a scan-csv fingerprint key: the
    /// source file's byte length and mtime, so the same path with
    /// different contents yields a different fingerprint and the query
    /// service's result cache invalidates when the file changes. An
    /// unreadable file gets the distinct `src=?` marker (never equal to
    /// any readable identity) instead of an error — the scan itself
    /// still surfaces the real IO failure at execution time.
    fn csv_identity(path: &std::path::Path) -> String {
        match std::fs::metadata(path) {
            Ok(md) => {
                let mtime = md
                    .modified()
                    .ok()
                    .and_then(|t| {
                        t.duration_since(std::time::UNIX_EPOCH).ok()
                    })
                    .map(|d| d.as_nanos())
                    .unwrap_or(0);
                format!("|src={}:{mtime}", md.len())
            }
            Err(_) => "|src=?".to_string(),
        }
    }

    /// Mirror of [`Plan::lower_into`]'s memoized walk that accumulates
    /// the structural keys instead of building pipeline nodes — same id
    /// assignment, same CSE, so key `i` describes DAG node `i`.
    fn fingerprint_into(
        &self,
        keys: &mut Vec<String>,
        memo: &mut Vec<(String, usize, usize)>,
        ptr_memo: &mut Vec<(*const Plan, (usize, usize))>,
    ) -> Result<(usize, usize)> {
        let mut child_ids = Vec::with_capacity(self.inputs.len());
        let mut child_ranks = 0usize;
        for input in &self.inputs {
            let ptr = Arc::as_ptr(input);
            let (id, ranks) = match ptr_memo.iter().find(|(p, _)| *p == ptr) {
                Some(&(_, hit)) => hit,
                None => {
                    let v = input.fingerprint_into(keys, memo, ptr_memo)?;
                    ptr_memo.push((ptr, v));
                    v
                }
            };
            child_ids.push(id);
            child_ranks = child_ranks.max(ranks);
        }
        let ranks = self.resolved_ranks(child_ranks)?;
        let ranks = self.op.handle().plan_ranks(ranks);
        let mut key = format!(
            "{:?}|ranks={ranks}|name={:?}|collect={}|children={child_ids:?}",
            self.op, self.name, self.collect
        );
        if let LogicalOp::ScanCsv { path, .. } = &self.op {
            key.push_str(&Self::csv_identity(path));
        }
        if let Some((_, id, r)) = memo.iter().find(|(k, _, _)| *k == key) {
            return Ok((*id, *r));
        }
        let id = keys.len();
        keys.push(key.clone());
        memo.push((key, id, ranks));
        Ok((id, ranks))
    }

    /// Rank resolution shared by lowering and fingerprinting: explicit
    /// override, else inherit the max over inputs; sources must be
    /// explicit and zero is rejected.
    fn resolved_ranks(&self, child_ranks: usize) -> Result<usize> {
        match self.ranks {
            Some(r) if r > 0 => Ok(r),
            Some(_) => Err(Error::Config(format!(
                "plan node '{}' requests zero ranks",
                self.op.op_name()
            ))),
            None if child_ranks > 0 => Ok(child_ranks),
            None => Err(Error::Config(format!(
                "plan source '{}' needs an explicit rank count",
                self.op.op_name()
            ))),
        }
    }

    // ---- schema propagation ---------------------------------------------

    /// The schema this node's output table will carry, computed by
    /// propagating source schemas through the operator tree without
    /// running anything. Unknown columns, type mismatches, non-boolean
    /// filter predicates, derive-name collisions, and union schema
    /// mismatches all surface here as [`Error::Config`] — [`Plan::lower`]
    /// runs this validation over the whole tree first.
    pub fn output_schema(&self) -> Result<Schema> {
        let mut memo: Vec<(*const Plan, Schema)> = Vec::new();
        self.schema_memo(&mut memo)
    }

    fn schema_memo(
        &self,
        memo: &mut Vec<(*const Plan, Schema)>,
    ) -> Result<Schema> {
        let mut child_schemas = Vec::with_capacity(self.inputs.len());
        for input in &self.inputs {
            let ptr = Arc::as_ptr(input);
            let s = match memo.iter().find(|(p, _)| *p == ptr) {
                Some((_, s)) => s.clone(),
                None => {
                    let s = input.schema_memo(memo)?;
                    memo.push((ptr, s.clone()));
                    s
                }
            };
            child_schemas.push(s);
        }
        let cfg = Error::Config;
        let in0 = child_schemas.first();
        match &self.op {
            LogicalOp::Generate { .. } => Ok(GenSpec::schema()),
            LogicalOp::ScanCsv { schema, .. } => Ok(schema.clone()),
            LogicalOp::Filter { predicate } => {
                let s = in0.expect("filter has one input");
                match predicate.infer_type(s)? {
                    DataType::Bool => Ok(s.clone()),
                    other => Err(cfg(format!(
                        "filter predicate must be bool, got {other} in \
                         {predicate}"
                    ))),
                }
            }
            LogicalOp::Project { columns } => {
                let s = in0.expect("project has one input");
                let mut fields = Vec::with_capacity(columns.len());
                for name in columns {
                    match s.index_of(name) {
                        Ok(i) => fields.push(s.field(i).clone()),
                        Err(e) => return Err(cfg(format!("in project: {e}"))),
                    }
                }
                Ok(Schema::new(fields))
            }
            LogicalOp::Derive { name, expr } => {
                let s = in0.expect("derive has one input");
                if s.index_of(name).is_ok() {
                    return Err(cfg(format!(
                        "derive '{name}' would shadow an existing column \
                         of schema {s}"
                    )));
                }
                let dtype = expr.infer_type(s)?;
                let mut fields = s.fields().to_vec();
                fields.push(Field::new(name, dtype));
                Ok(Schema::new(fields))
            }
            LogicalOp::Join { left_key, right_key, .. } => {
                let (l, r) = (&child_schemas[0], &child_schemas[1]);
                for (key, side, s) in
                    [(left_key, "left", l), (right_key, "right", r)]
                {
                    let i = key
                        .resolve(s)
                        .map_err(|e| cfg(format!("in join {side} key: {e}")))?;
                    if s.field(i).dtype != DataType::Int64 {
                        return Err(cfg(format!(
                            "join {side} key '{key}' must be int64, got {}",
                            s.field(i).dtype
                        )));
                    }
                }
                Ok(l.join(r))
            }
            LogicalOp::Sort { key } => {
                let s = in0.expect("sort has one input");
                let i = key
                    .resolve(s)
                    .map_err(|e| cfg(format!("in sort key: {e}")))?;
                if s.field(i).dtype != DataType::Int64 {
                    return Err(cfg(format!(
                        "sort key '{key}' must be int64, got {}",
                        s.field(i).dtype
                    )));
                }
                Ok(s.clone())
            }
            LogicalOp::Groupby { key, val, agg } => {
                let s = in0.expect("groupby has one input");
                let ki = key
                    .resolve(s)
                    .map_err(|e| cfg(format!("in groupby key: {e}")))?;
                let vi = val
                    .resolve(s)
                    .map_err(|e| cfg(format!("in groupby value: {e}")))?;
                if s.field(ki).dtype != DataType::Int64 {
                    return Err(cfg(format!(
                        "groupby key '{key}' must be int64, got {}",
                        s.field(ki).dtype
                    )));
                }
                if s.field(vi).dtype != DataType::Float64 {
                    return Err(cfg(format!(
                        "groupby value '{val}' must be float64, got {}",
                        s.field(vi).dtype
                    )));
                }
                // Mirrors ops::local::groupby::agg_output's shape.
                let agg_name =
                    format!("{}_{}", s.field(vi).name, agg.name());
                Ok(Schema::new(vec![
                    Field::new(&s.field(ki).name, DataType::Int64),
                    Field::new(&agg_name, DataType::Float64),
                ]))
            }
            LogicalOp::Union => {
                let (l, r) = (&child_schemas[0], &child_schemas[1]);
                if l != r {
                    return Err(cfg(format!(
                        "union schema mismatch: {l} vs {r}"
                    )));
                }
                Ok(l.clone())
            }
        }
    }

    // ---- lowering -------------------------------------------------------

    /// Lower to the physical [`Pipeline`] DAG. Deterministic: identical
    /// plans produce identical pipelines (stable post-order ids, CSE over
    /// structurally identical subtrees).
    ///
    /// Three steps: validate the tree against propagated schemas
    /// ([`Plan::output_schema`]); run the [`optimize`] passes unless
    /// [`Plan::without_optimizer`] was called; emit the DAG.
    pub fn lower(&self) -> Result<LoweredPlan> {
        self.output_schema()?;
        if self.optimize {
            optimize::optimize(self)?.lower_raw()
        } else {
            self.lower_raw()
        }
    }

    /// Lowering without validation or optimization (the emit step).
    fn lower_raw(&self) -> Result<LoweredPlan> {
        let mut pipeline = Pipeline::new();
        let mut memo: Vec<(String, usize, usize)> = Vec::new(); // (key, id, ranks)
        let mut ptr_memo: Vec<(*const Plan, (usize, usize))> = Vec::new();
        let (sink, _) = self.lower_into(&mut pipeline, &mut memo, &mut ptr_memo)?;
        Ok(LoweredPlan { pipeline, sink })
    }

    /// Recursive lowering; returns `(node id, ranks)`.
    ///
    /// Two memo layers keep this linear in the number of *distinct* nodes:
    /// `ptr_memo` short-circuits on `Arc` pointer identity **before**
    /// recursing (a subtree shared via clone is traversed once, so deeply
    /// shared diamonds do not explode), and the structural `memo` merges
    /// separately-built identical subtrees after parameters are known.
    fn lower_into(
        &self,
        pipeline: &mut Pipeline,
        memo: &mut Vec<(String, usize, usize)>,
        ptr_memo: &mut Vec<(*const Plan, (usize, usize))>,
    ) -> Result<(usize, usize)> {
        let mut child_ids = Vec::with_capacity(self.inputs.len());
        let mut child_ranks = 0usize;
        for input in &self.inputs {
            let ptr = Arc::as_ptr(input);
            let (id, ranks) = match ptr_memo.iter().find(|(p, _)| *p == ptr) {
                Some(&(_, hit)) => hit,
                None => {
                    let v = input.lower_into(pipeline, memo, ptr_memo)?;
                    ptr_memo.push((ptr, v));
                    v
                }
            };
            child_ids.push(id);
            child_ranks = child_ranks.max(ranks);
        }
        let ranks = self.resolved_ranks(child_ranks)?;
        let op = self.op.handle();
        let ranks = op.plan_ranks(ranks);
        // Structural identity: operator parameters + ranks + name + the
        // children's *canonical node ids*. Memoization already assigns one
        // id per distinct subtree, so keying on child ids is equivalent to
        // embedding full child keys while keeping keys O(fanout) — a
        // deeply shared diamond does not blow the key up exponentially.
        // Two nodes with equal keys compute the same table, so the second
        // one reuses the first's DAG node.
        let key = format!(
            "{:?}|ranks={ranks}|name={:?}|collect={}|children={child_ids:?}",
            self.op, self.name, self.collect
        );
        if let Some((_, id, r)) = memo.iter().find(|(k, _, _)| *k == key) {
            return Ok((*id, *r));
        }

        let mut td = match &self.op {
            LogicalOp::Generate { spec } => {
                let mut td = TaskDescription::new(
                    self.name.as_deref().unwrap_or(""),
                    op,
                    ranks,
                    spec.rows,
                );
                td.key_space = spec.key_space;
                td.dist = spec.dist;
                td.seed = spec.seed;
                td
            }
            // Non-source nodes carry no synthetic workload: their input is
            // entirely the staged handoff (rows_per_rank stays 0, which
            // also lets the critical-path estimator inherit the producer's
            // size).
            _ => TaskDescription::new(self.name.as_deref().unwrap_or(""), op, ranks, 0),
        };
        if self.collect {
            td.keep_output = true;
        }
        let id = pipeline.len();
        if td.name.is_empty() {
            td.name = format!("{}-{id}", self.op.op_name());
        }
        let node_id = if child_ids.is_empty() {
            pipeline.add(td, &[])
        } else {
            pipeline.add_piped_multi(td, &child_ids, &child_ids)
        };
        debug_assert_eq!(node_id, id);
        memo.push((key, node_id, ranks));
        Ok((node_id, ranks))
    }
}

#[cfg(test)]
mod tests {
    use super::expr::{col, lit};
    use super::*;

    fn etl() -> Plan {
        let left = Plan::generate(2, GenSpec::uniform(100, 64, 1))
            .filter(col("val").ge(lit(0.25)));
        let right = Plan::generate(2, GenSpec::uniform(100, 64, 2));
        left.join(right, "key", "key").sort("key").collect()
    }

    #[test]
    fn lowering_is_deterministic() {
        let a = etl().lower().unwrap();
        let b = etl().lower().unwrap();
        assert_eq!(a.pipeline.len(), b.pipeline.len());
        assert_eq!(a.sink, b.sink);
        assert_eq!(a.pipeline.len(), 5); // 2 gens, filter, join, sort
        assert_eq!(a.sink, 4); // post-order: sink is last
        assert!(a.pipeline.validate().is_ok());
    }

    #[test]
    fn optimized_and_unoptimized_lower_to_same_shape_for_simple_chains() {
        // Nothing to fuse/push/prune here, so both paths emit 5 nodes.
        let a = etl().lower().unwrap();
        let b = etl().without_optimizer().lower().unwrap();
        assert_eq!(a.pipeline.len(), b.pipeline.len());
        assert_eq!(a.sink, b.sink);
    }

    #[test]
    fn cse_merges_identical_subtrees() {
        let g = Plan::generate(2, GenSpec::uniform(50, 32, 3));
        let plan = g
            .clone()
            .sort(0)
            .union(g.clone().groupby(0, 1, AggFn::Sum))
            .collect();
        let lowered = plan.lower().unwrap();
        // generate emitted once: gen, sort, groupby, union.
        assert_eq!(lowered.pipeline.len(), 4);
    }

    #[test]
    fn deep_shared_diamond_lowers_in_linear_time() {
        // 40 levels of `p union p`: Arc-shared children keep each clone
        // O(1), the pointer memos (schema propagation, optimizer passes,
        // lowering) traverse every shared subtree once, and canonical
        // child-id keys keep structural keys O(fanout) — so this lowers
        // to 41 DAG nodes (one per distinct level) in linear time instead
        // of hanging on ~2^40 work.
        let mut p = Plan::generate(1, GenSpec::uniform(4, 4, 0));
        for _ in 0..40 {
            p = p.clone().union(p);
        }
        let lowered = p.lower().unwrap();
        assert_eq!(lowered.pipeline.len(), 41);
    }

    #[test]
    fn distinct_seeds_stay_distinct() {
        let a = Plan::generate(2, GenSpec::uniform(50, 32, 3));
        let b = Plan::generate(2, GenSpec::uniform(50, 32, 4));
        let lowered = a.union(b).lower().unwrap();
        assert_eq!(lowered.pipeline.len(), 3);
    }

    #[test]
    fn derived_nodes_inherit_ranks() {
        let plan = Plan::generate(4, GenSpec::uniform(10, 8, 0)).sort(0);
        let lowered = plan.lower().unwrap();
        assert_eq!(lowered.pipeline.len(), 2);
        // No direct accessor for ranks on Pipeline nodes; the invariant is
        // covered end-to-end by exec::tests::run_plan_* — here we only pin
        // that lowering succeeds without an explicit rank override.
        let explicit = Plan::generate(4, GenSpec::uniform(10, 8, 0))
            .sort(0)
            .with_ranks(2)
            .lower()
            .unwrap();
        assert_eq!(explicit.pipeline.len(), 2);
    }

    #[test]
    fn source_without_ranks_rejected() {
        let p = Plan::generate(0, GenSpec::uniform(10, 8, 0));
        let err = p.lower().unwrap_err().to_string();
        assert!(err.contains("zero ranks"), "{err}");
    }

    #[test]
    fn names_are_stable_and_overridable() {
        let plan = Plan::generate(1, GenSpec::uniform(5, 4, 0))
            .named("src")
            .sort(0);
        let lowered = plan.lower().unwrap();
        assert_eq!(lowered.pipeline.len(), 2);
    }

    #[test]
    fn filter_scalar_shim_builds_the_equivalent_expression() {
        #[allow(deprecated)]
        let shim = Plan::generate(2, GenSpec::uniform(100, 64, 1))
            .filter_scalar(1, CmpOp::Ge, 0.25);
        let lowered = shim.lower().unwrap();
        assert_eq!(lowered.pipeline.len(), 2);
    }

    #[test]
    fn fingerprints_are_canonical_and_discriminating() {
        // Structurally identical plans built twice share a fingerprint.
        assert_eq!(etl().fingerprint().unwrap(), etl().fingerprint().unwrap());
        // idx vs name column refs normalize to the same fingerprint (the
        // optimizer rewrites legacy indices to names before keying).
        let by_name = Plan::generate(2, GenSpec::uniform(50, 32, 3))
            .sort("key")
            .collect();
        let by_idx =
            Plan::generate(2, GenSpec::uniform(50, 32, 3)).sort(0).collect();
        assert_eq!(
            by_name.fingerprint().unwrap(),
            by_idx.fingerprint().unwrap()
        );
        // Different seeds, ranks, collect flags, and shapes all diverge.
        let base = Plan::generate(2, GenSpec::uniform(50, 32, 3)).sort("key");
        let seeds = Plan::generate(2, GenSpec::uniform(50, 32, 4)).sort("key");
        assert_ne!(
            base.clone().collect().fingerprint().unwrap(),
            seeds.collect().fingerprint().unwrap()
        );
        assert_ne!(
            base.clone().collect().fingerprint().unwrap(),
            base.clone().fingerprint().unwrap(),
            "collect flag is part of the key"
        );
        assert_ne!(
            base.clone().collect().fingerprint().unwrap(),
            base.with_ranks(4).collect().fingerprint().unwrap()
        );
        // One key line per distinct DAG node, matching the lowered shape.
        let fp = etl().fingerprint().unwrap();
        assert_eq!(fp.lines().count(), etl().lower().unwrap().pipeline.len());
        // Invalid plans fail fingerprinting the same way they fail lower().
        assert!(Plan::generate(2, GenSpec::uniform(10, 8, 0))
            .sort("val")
            .fingerprint()
            .is_err());
    }

    #[test]
    fn external_source_detection() {
        let gen = Plan::generate(2, GenSpec::uniform(10, 8, 0));
        assert!(!gen.clone().sort("key").collect().reads_external_sources());
        let scan = Plan::scan_csv(2, "/tmp/x.csv", GenSpec::schema());
        assert!(scan.clone().reads_external_sources());
        assert!(gen.join(scan, "key", "key").reads_external_sources());
        // Deep shared diamonds stay linear (pointer-dedup, not 2^40 walks).
        let mut p = Plan::generate(1, GenSpec::uniform(4, 4, 0));
        for _ in 0..40 {
            p = p.clone().union(p);
        }
        assert!(!p.reads_external_sources());
    }

    #[test]
    fn scan_csv_fingerprint_tracks_file_content_identity() {
        let dir = std::env::temp_dir().join("rc-plan-fp-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fp.csv");
        std::fs::write(&path, "key,val\n1,0.5\n").unwrap();
        let plan =
            || Plan::scan_csv(1, path.clone(), GenSpec::schema()).collect();
        let a = plan().fingerprint().unwrap();
        assert_eq!(a, plan().fingerprint().unwrap(), "same file, same key");
        assert!(a.contains("|src="), "{a}");
        // Rewriting the file (different length) changes the fingerprint.
        std::fs::write(&path, "key,val\n1,0.5\n2,0.25\n").unwrap();
        let b = plan().fingerprint().unwrap();
        assert_ne!(a, b, "changed file must change the cache key");
        // A missing file fingerprints distinctly rather than erroring.
        let gone = Plan::scan_csv(1, dir.join("nope.csv"), GenSpec::schema())
            .collect()
            .fingerprint()
            .unwrap();
        assert!(gone.contains("|src=?"), "{gone}");
        assert_ne!(gone, b);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn schema_propagates_through_the_tree() {
        let s = etl().output_schema().unwrap();
        // join renames the right side's colliding columns.
        let names: Vec<&str> =
            s.fields().iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["key", "val", "key_right", "val_right"]);
        let g = Plan::generate(2, GenSpec::uniform(10, 8, 0))
            .derive("scaled", col("val") * lit(2.0))
            .groupby("key", "scaled", AggFn::Mean);
        let s = g.output_schema().unwrap();
        let names: Vec<&str> =
            s.fields().iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["key", "scaled_mean"]);
    }

    #[test]
    fn lowering_rejects_bad_plans_with_config_diagnostics() {
        // Unknown filter column, with a did-you-mean hint.
        let p = Plan::generate(2, GenSpec::uniform(10, 8, 0))
            .filter(col("vall").ge(lit(0.5)));
        let err = p.lower().unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("did you mean 'val'?"), "{err}");
        // Non-boolean predicate.
        let p = Plan::generate(2, GenSpec::uniform(10, 8, 0))
            .filter(col("val") * lit(2.0));
        let err = p.lower().unwrap_err().to_string();
        assert!(err.contains("must be bool"), "{err}");
        // Sorting a float column.
        let p = Plan::generate(2, GenSpec::uniform(10, 8, 0)).sort("val");
        let err = p.lower().unwrap_err().to_string();
        assert!(err.contains("must be int64"), "{err}");
        // Derive shadowing an existing column.
        let p = Plan::generate(2, GenSpec::uniform(10, 8, 0))
            .derive("val", col("val") * lit(2.0));
        let err = p.lower().unwrap_err().to_string();
        assert!(err.contains("shadow"), "{err}");
        // Union of mismatched schemas.
        let a = Plan::generate(2, GenSpec::uniform(10, 8, 0));
        let b = Plan::generate(2, GenSpec::uniform(10, 8, 1)).project(&["key"]);
        let err = a.union(b).lower().unwrap_err().to_string();
        assert!(err.contains("union schema mismatch"), "{err}");
        // Unknown groupby value column.
        let p = Plan::generate(2, GenSpec::uniform(10, 8, 0))
            .groupby("key", "vals", AggFn::Sum);
        let err = p.lower().unwrap_err().to_string();
        assert!(err.contains("groupby value"), "{err}");
    }
}
