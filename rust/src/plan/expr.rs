//! Typed expression AST — the query-facing IR behind `filter`/`derive`.
//!
//! An [`Expr`] is a small tree over column references, literals,
//! arithmetic, comparisons, and boolean connectives:
//!
//! ```
//! use radical_cylon::plan::expr::{col, lit};
//!
//! let pred = (col("a") * lit(2) + col("b"))
//!     .gt(lit(10))
//!     .and(col("k").ne(lit(0)));
//! assert_eq!(pred.to_string(), "((((a * 2) + b) > 10) && (k != 0))");
//! ```
//!
//! Build leaves with [`col`] (by name), [`idx`] (by position — the legacy
//! addressing mode the deprecated scalar-filter shim uses), and [`lit`];
//! combine with the `+ - * /` operator overloads, the comparison methods
//! ([`Expr::eq`], [`Expr::lt`], ...), and the boolean connectives
//! ([`Expr::and`], [`Expr::or`], and `!expr` / [`Expr::not`]).
//!
//! **Typing.** [`Expr::infer_type`] resolves names against a [`Schema`]
//! and computes the output [`DataType`], reporting unknown columns and
//! type mismatches as [`Error::Config`] with did-you-mean diagnostics.
//! The rules:
//!
//! * arithmetic takes numeric operands; `Int64 op Int64 -> Int64`, any
//!   `Float64` operand promotes the whole operation to `Float64`;
//! * comparisons take numeric operands (mixed int/float compares as
//!   `f64`) and produce `Bool`;
//! * `and`/`or`/`not` take `Bool` operands and produce `Bool`.
//!
//! **Evaluation** is vectorized in
//! [`crate::ops::local::eval_expr`] — flat value/mask buffers, one kernel
//! dispatch per node, never per row. Children are [`Arc`]-shared, so
//! cloning an expression is O(1).

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::df::{DataType, Schema};
use crate::error::{Error, Result};
use crate::ops::local::{BinOp, CmpOp};

/// A literal value embedded in an expression.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scalar {
    Int64(i64),
    Float64(f64),
    Bool(bool),
}

impl Scalar {
    /// The literal's dataframe type.
    pub fn dtype(&self) -> DataType {
        match self {
            Scalar::Int64(_) => DataType::Int64,
            Scalar::Float64(_) => DataType::Float64,
            Scalar::Bool(_) => DataType::Bool,
        }
    }
}

impl From<i64> for Scalar {
    fn from(v: i64) -> Scalar {
        Scalar::Int64(v)
    }
}

impl From<f64> for Scalar {
    fn from(v: f64) -> Scalar {
        Scalar::Float64(v)
    }
}

impl From<bool> for Scalar {
    fn from(v: bool) -> Scalar {
        Scalar::Bool(v)
    }
}

impl std::fmt::Display for Scalar {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Scalar::Int64(v) => write!(f, "{v}"),
            Scalar::Float64(v) => write!(f, "{v}"),
            Scalar::Bool(v) => write!(f, "{v}"),
        }
    }
}

/// A typed expression over one table's columns.
///
/// See the [module docs](self) for the building blocks and typing rules.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    /// Column reference by name (preferred — survives projections).
    Col(String),
    /// Column reference by position (legacy shim addressing; the
    /// optimizer normalizes these to names when the schema is known).
    Idx(usize),
    /// Literal.
    Lit(Scalar),
    /// Arithmetic: `lhs op rhs`.
    Bin { op: BinOp, lhs: Arc<Expr>, rhs: Arc<Expr> },
    /// Comparison: `lhs op rhs`, producing `Bool`.
    Cmp { op: CmpOp, lhs: Arc<Expr>, rhs: Arc<Expr> },
    /// Boolean conjunction.
    And(Arc<Expr>, Arc<Expr>),
    /// Boolean disjunction.
    Or(Arc<Expr>, Arc<Expr>),
    /// Boolean negation.
    Not(Arc<Expr>),
}

/// Reference a column by name: `col("val")`.
pub fn col(name: &str) -> Expr {
    Expr::Col(name.to_string())
}

/// Reference a column by position: `idx(1)`. Legacy addressing used by
/// the deprecated scalar-filter shim; prefer [`col`].
pub fn idx(i: usize) -> Expr {
    Expr::Idx(i)
}

/// Embed a literal: `lit(2)`, `lit(0.5)`, `lit(true)`.
pub fn lit(v: impl Into<Scalar>) -> Expr {
    Expr::Lit(v.into())
}

fn cmp(op: CmpOp, lhs: Expr, rhs: Expr) -> Expr {
    Expr::Cmp { op, lhs: Arc::new(lhs), rhs: Arc::new(rhs) }
}

impl Expr {
    /// Build a comparison node from a runtime [`CmpOp`] — the single
    /// dispatch point shared by the comparison methods below and the
    /// legacy scalar-filter shim.
    pub(crate) fn cmp_op(op: CmpOp, lhs: Expr, rhs: Expr) -> Expr {
        cmp(op, lhs, rhs)
    }
}

// The comparison methods intentionally shadow `PartialEq::eq`/`ne`: they
// consume `self` by value and build AST nodes, the dataframe-DSL idiom.
#[allow(clippy::should_implement_trait)]
impl Expr {
    /// `self == other` (produces `Bool`).
    pub fn eq(self, other: Expr) -> Expr {
        cmp(CmpOp::Eq, self, other)
    }

    /// `self != other`. On floats this follows IEEE semantics: `NaN != x`
    /// is `true` for every `x`, including `NaN`.
    pub fn ne(self, other: Expr) -> Expr {
        cmp(CmpOp::Ne, self, other)
    }

    /// `self < other`.
    pub fn lt(self, other: Expr) -> Expr {
        cmp(CmpOp::Lt, self, other)
    }

    /// `self <= other`.
    pub fn le(self, other: Expr) -> Expr {
        cmp(CmpOp::Le, self, other)
    }

    /// `self > other`.
    pub fn gt(self, other: Expr) -> Expr {
        cmp(CmpOp::Gt, self, other)
    }

    /// `self >= other`.
    pub fn ge(self, other: Expr) -> Expr {
        cmp(CmpOp::Ge, self, other)
    }

    /// Boolean AND. Evaluation is eager on both sides except when the
    /// left mask is uniformly decisive (see
    /// [`crate::ops::local::eval_expr`]); do not rely on `and` to guard
    /// the right side against evaluation errors.
    pub fn and(self, other: Expr) -> Expr {
        Expr::And(Arc::new(self), Arc::new(other))
    }

    /// Boolean OR (same evaluation caveat as [`Expr::and`]).
    pub fn or(self, other: Expr) -> Expr {
        Expr::Or(Arc::new(self), Arc::new(other))
    }

    /// Boolean NOT (also available as the `!` operator).
    pub fn not(self) -> Expr {
        Expr::Not(Arc::new(self))
    }

    /// Resolve column references and compute the output type against
    /// `schema`. Unknown columns and type mismatches are
    /// [`Error::Config`] with the offending sub-expression in the
    /// message.
    pub fn infer_type(&self, schema: &Schema) -> Result<DataType> {
        match self {
            Expr::Col(name) => match schema.index_of(name) {
                Ok(i) => Ok(schema.field(i).dtype),
                Err(e) => Err(Error::Config(format!("in expression: {e}"))),
            },
            Expr::Idx(i) if *i < schema.len() => Ok(schema.field(*i).dtype),
            Expr::Idx(i) => Err(Error::Config(format!(
                "in expression: column index {i} out of bounds for schema \
                 {schema}"
            ))),
            Expr::Lit(s) => Ok(s.dtype()),
            Expr::Bin { op, lhs, rhs } => {
                let (l, r) =
                    (lhs.infer_type(schema)?, rhs.infer_type(schema)?);
                match (l, r) {
                    (DataType::Int64, DataType::Int64) => Ok(DataType::Int64),
                    (DataType::Int64 | DataType::Float64, DataType::Int64 | DataType::Float64) => {
                        Ok(DataType::Float64)
                    }
                    _ => Err(Error::Config(format!(
                        "arithmetic '{op:?}' needs numeric operands, got \
                         {l}/{r} in {self}"
                    ))),
                }
            }
            Expr::Cmp { op, lhs, rhs } => {
                let (l, r) =
                    (lhs.infer_type(schema)?, rhs.infer_type(schema)?);
                match (l, r) {
                    (
                        DataType::Int64 | DataType::Float64,
                        DataType::Int64 | DataType::Float64,
                    ) => Ok(DataType::Bool),
                    _ => Err(Error::Config(format!(
                        "comparison '{op:?}' needs numeric operands, got \
                         {l}/{r} in {self}"
                    ))),
                }
            }
            Expr::And(a, b) | Expr::Or(a, b) => {
                for side in [a, b] {
                    let t = side.infer_type(schema)?;
                    if t != DataType::Bool {
                        return Err(Error::Config(format!(
                            "boolean connective needs bool operands, got \
                             {t} in {self}"
                        )));
                    }
                }
                Ok(DataType::Bool)
            }
            Expr::Not(a) => {
                let t = a.infer_type(schema)?;
                if t != DataType::Bool {
                    return Err(Error::Config(format!(
                        "'!' needs a bool operand, got {t} in {self}"
                    )));
                }
                Ok(DataType::Bool)
            }
        }
    }

    /// Collect every column **name** the expression references into
    /// `out` (positional [`Expr::Idx`] references are not names; see
    /// [`Expr::uses_indices`]).
    pub fn references(&self, out: &mut BTreeSet<String>) {
        match self {
            Expr::Col(name) => {
                out.insert(name.clone());
            }
            Expr::Idx(_) | Expr::Lit(_) => {}
            Expr::Bin { lhs, rhs, .. } | Expr::Cmp { lhs, rhs, .. } => {
                lhs.references(out);
                rhs.references(out);
            }
            Expr::And(a, b) | Expr::Or(a, b) => {
                a.references(out);
                b.references(out);
            }
            Expr::Not(a) => a.references(out),
        }
    }

    /// Does the expression address any column positionally? Positional
    /// references pin the expression to one exact schema layout, so the
    /// optimizer refuses to move them across schema-changing operators
    /// until they are normalized to names.
    pub fn uses_indices(&self) -> bool {
        match self {
            Expr::Idx(_) => true,
            Expr::Col(_) | Expr::Lit(_) => false,
            Expr::Bin { lhs, rhs, .. } | Expr::Cmp { lhs, rhs, .. } => {
                lhs.uses_indices() || rhs.uses_indices()
            }
            Expr::And(a, b) | Expr::Or(a, b) => {
                a.uses_indices() || b.uses_indices()
            }
            Expr::Not(a) => a.uses_indices(),
        }
    }

    /// Rewrite positional references to names using `schema` (the
    /// optimizer's normalization step). Returns a structurally shared
    /// copy; out-of-bounds indices are [`Error::Config`].
    pub fn normalized(&self, schema: &Schema) -> Result<Expr> {
        Ok(match self {
            Expr::Idx(i) if *i < schema.len() => {
                Expr::Col(schema.field(*i).name.clone())
            }
            Expr::Idx(i) => {
                return Err(Error::Config(format!(
                    "in expression: column index {i} out of bounds for \
                     schema {schema}"
                )))
            }
            Expr::Col(_) | Expr::Lit(_) => self.clone(),
            Expr::Bin { op, lhs, rhs } => Expr::Bin {
                op: *op,
                lhs: Arc::new(lhs.normalized(schema)?),
                rhs: Arc::new(rhs.normalized(schema)?),
            },
            Expr::Cmp { op, lhs, rhs } => Expr::Cmp {
                op: *op,
                lhs: Arc::new(lhs.normalized(schema)?),
                rhs: Arc::new(rhs.normalized(schema)?),
            },
            Expr::And(a, b) => Expr::And(
                Arc::new(a.normalized(schema)?),
                Arc::new(b.normalized(schema)?),
            ),
            Expr::Or(a, b) => Expr::Or(
                Arc::new(a.normalized(schema)?),
                Arc::new(b.normalized(schema)?),
            ),
            Expr::Not(a) => Expr::Not(Arc::new(a.normalized(schema)?)),
        })
    }
}

macro_rules! arith_overload {
    ($trait:ident, $method:ident, $op:expr) => {
        impl std::ops::$trait for Expr {
            type Output = Expr;
            fn $method(self, rhs: Expr) -> Expr {
                Expr::Bin { op: $op, lhs: Arc::new(self), rhs: Arc::new(rhs) }
            }
        }
    };
}

arith_overload!(Add, add, BinOp::Add);
arith_overload!(Sub, sub, BinOp::Sub);
arith_overload!(Mul, mul, BinOp::Mul);
arith_overload!(Div, div, BinOp::Div);

impl std::ops::Not for Expr {
    type Output = Expr;
    fn not(self) -> Expr {
        Expr::Not(Arc::new(self))
    }
}

impl std::fmt::Display for Expr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Expr::Col(n) => write!(f, "{n}"),
            Expr::Idx(i) => write!(f, "#{i}"),
            Expr::Lit(s) => write!(f, "{s}"),
            Expr::Bin { op, lhs, rhs } => {
                let sym = match op {
                    BinOp::Add => "+",
                    BinOp::Sub => "-",
                    BinOp::Mul => "*",
                    BinOp::Div => "/",
                };
                write!(f, "({lhs} {sym} {rhs})")
            }
            Expr::Cmp { op, lhs, rhs } => {
                let sym = match op {
                    CmpOp::Eq => "==",
                    CmpOp::Ne => "!=",
                    CmpOp::Lt => "<",
                    CmpOp::Le => "<=",
                    CmpOp::Gt => ">",
                    CmpOp::Ge => ">=",
                };
                write!(f, "({lhs} {sym} {rhs})")
            }
            Expr::And(a, b) => write!(f, "({a} && {b})"),
            Expr::Or(a, b) => write!(f, "({a} || {b})"),
            Expr::Not(a) => write!(f, "!{a}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn schema() -> Schema {
        Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)])
    }

    #[test]
    fn display_round_trip() {
        let e = (col("a") * lit(2) + col("b")).gt(lit(10)).and(col("k").ne(lit(0)));
        assert_eq!(e.to_string(), "((((a * 2) + b) > 10) && (k != 0))");
        assert_eq!((!col("p")).to_string(), "!p");
    }

    #[test]
    fn type_inference() {
        let s = schema();
        assert_eq!((col("key") + lit(1)).infer_type(&s).unwrap(), DataType::Int64);
        // Mixed int/float promotes to float.
        assert_eq!(
            (col("key") * col("val")).infer_type(&s).unwrap(),
            DataType::Float64
        );
        assert_eq!(
            col("key").ge(lit(0.5)).infer_type(&s).unwrap(),
            DataType::Bool
        );
        assert_eq!(
            col("key").eq(lit(1)).and(col("val").lt(lit(0.5))).infer_type(&s).unwrap(),
            DataType::Bool
        );
        assert_eq!(idx(1).infer_type(&s).unwrap(), DataType::Float64);
    }

    #[test]
    fn type_errors_are_config_with_context() {
        let s = schema();
        let err = col("vall").infer_type(&s).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("did you mean 'val'?"), "{err}");
        let err = (col("key") + lit(true)).infer_type(&s).unwrap_err().to_string();
        assert!(err.contains("numeric operands"), "{err}");
        let err = col("key").and(col("val").lt(lit(0.5))).infer_type(&s).unwrap_err();
        assert!(err.to_string().contains("bool operands"), "{err}");
        let err = (!col("val")).infer_type(&s).unwrap_err().to_string();
        assert!(err.contains("'!'"), "{err}");
        assert!(idx(7).infer_type(&s).is_err());
    }

    #[test]
    fn references_and_indices() {
        let e = (col("a") + idx(1)).gt(col("b"));
        let mut refs = BTreeSet::new();
        e.references(&mut refs);
        assert_eq!(
            refs.iter().map(String::as_str).collect::<Vec<_>>(),
            vec!["a", "b"]
        );
        assert!(e.uses_indices());
        assert!(!col("a").gt(lit(0)).uses_indices());
    }

    #[test]
    fn normalization_resolves_indices() {
        let s = schema();
        let e = idx(0).ge(idx(1));
        let n = e.normalized(&s).unwrap();
        assert_eq!(n, col("key").ge(col("val")));
        assert!(!n.uses_indices());
        assert!(idx(9).normalized(&s).is_err());
        // Name-only expressions normalize to themselves.
        let e = col("key").lt(lit(3));
        assert_eq!(e.normalized(&s).unwrap(), e);
    }

    #[test]
    fn literal_inference_types() {
        assert_eq!(lit(2), Expr::Lit(Scalar::Int64(2)));
        assert_eq!(lit(0.5), Expr::Lit(Scalar::Float64(0.5)));
        assert_eq!(lit(true), Expr::Lit(Scalar::Bool(true)));
    }
}
