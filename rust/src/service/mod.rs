//! Always-on multi-tenant query service — the front door for the
//! "millions of users" trajectory (ROADMAP) and the paper's persistent-
//! runtime thesis: many analysis tasks share **one** pilot allocation
//! instead of re-acquiring resources per batch (Deep RC extends exactly
//! this into a long-lived pipeline-as-a-service shape).
//!
//! A [`QueryService`] owns a long-lived [`Session`] + [`Pilot`] (the hot
//! rank pool) and accepts [`Plan`] submissions from many client threads
//! concurrently:
//!
//! ```text
//!   submit(Plan) ──► fingerprint ──► plan cache ──► admission ──► pooled DAG
//!        │               │         (hit: reuse      (in-flight +     (run_pooled
//!        │               │          LoweredPlan)     byte bounds,     on the shared
//!        │               ▼                           FIFO/cost        rank pool)
//!        │          result cache ──────────────────► queue)
//!        │          (hit: return cached table, no execution)
//!        ▼
//!   QueryHandle — status() / poll() / join() / cancel()
//! ```
//!
//! * **Admission** bounds concurrently executing queries
//!   ([`crate::config::ServiceConfig::max_inflight`]) and their summed
//!   estimated source bytes
//!   ([`crate::pipeline::Pipeline::estimated_source_bytes`]); excess work
//!   queues up to `queue_depth` deep and is promoted under an
//!   [`AdmitPolicy`] (FIFO vs cost-aware — the admission-side mirror of
//!   the pipeline's [`ReadyPolicy`] split). A saturated queue rejects
//!   with the typed [`Error::Admission`] instead of blocking the caller.
//! * **Plan cache**: [`Plan::fingerprint`] (canonical structural keys of
//!   the optimized plan) → [`LoweredPlan`]; a hit skips re-lowering.
//! * **Result cache**: LRU over collected output tables, byte-bounded by
//!   `result_cache_bytes`; a hit completes the query without touching
//!   the rank pool. Every collecting plan qualifies: CSV scans no longer
//!   bypass the cache because [`Plan::fingerprint`] folds the source
//!   file's content identity (byte length + mtime) into the key, so
//!   editing the file changes the fingerprint and invalidates naturally.
//!   Hit/miss/eviction counters live in [`crate::metrics::cache`].
//! * **Execution**: each admitted query drives its lowered DAG through
//!   [`crate::pipeline::Pipeline::run_pooled`] on the global
//!   [`ThreadPool`](crate::util::pool::ThreadPool), with every node
//!   submitted to the shared pilot's RAPTOR master — the master
//!   multiplexes rank groups across all in-flight queries and queues
//!   work orders when ranks are busy, so tenants share the pool without
//!   interfering: a panic or per-node error fails only the owning query
//!   (contained by `run_pooled`'s catch-unwind), and results are
//!   bit-identical to a solo [`crate::exec::Engine::run_plan`].
//! * **Fault tolerance**: a query whose failure classifies as transient
//!   ([`Error::is_transient`] — worker panics, injected faults, comm
//!   hiccups, deadline expiries) is re-executed up to
//!   [`ServiceConfig::retry_max_attempts`] times with the process backoff
//!   policy ([`crate::util::faults::retry_policy`]); deterministic plans
//!   re-run bit-identically. [`QueryService::shutdown`] drains in-flight
//!   work up to [`ServiceConfig::shutdown_timeout_s`], then cancels
//!   stragglers and reports them via [`Error::Timeout`].
//!
//! ```no_run
//! use radical_cylon::config::ServiceConfig;
//! use radical_cylon::service::QueryService;
//! use radical_cylon::plan::Plan;
//! use radical_cylon::df::GenSpec;
//!
//! let svc = QueryService::start(ServiceConfig::default()).unwrap();
//! let plan = Plan::generate(2, GenSpec::uniform(10_000, 5_000, 7))
//!     .sort("key")
//!     .collect();
//! let handle = svc.submit(plan).unwrap();          // non-blocking
//! let result = handle.join().unwrap();             // blocking
//! println!("{} rows", result.output_rows);
//! svc.shutdown().unwrap();
//! ```

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::cluster::MachineSpec;
use crate::config::ServiceConfig;
use crate::df::ChunkedTable;
use crate::error::{Error, Result};
use crate::metrics::cache as cache_metrics;
use crate::metrics::faults as fault_metrics;
use crate::pilot::{Pilot, PilotDescription, Session};
use crate::plan::{LoweredPlan, Plan};
use crate::raptor::ReadyPolicy;
use crate::util::faults;
use crate::util::{lock_recover, pool};

/// Queue ordering when in-flight capacity frees up — the admission-side
/// mirror of the pipeline's [`ReadyPolicy`] split.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitPolicy {
    /// Strict arrival order.
    Fifo,
    /// Smallest estimated source bytes first: cheap interactive queries
    /// jump ahead of bulk work. Arrival order breaks ties, so equal-cost
    /// queries still run FIFO.
    CostAware,
}

/// Monotone per-service query identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct QueryId(pub u64);

impl std::fmt::Display for QueryId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "q{}", self.0)
    }
}

/// Query lifecycle — deliberately smaller than the task-level
/// [`crate::pilot::TaskState`]: a query is Queued (admission or the
/// admission queue), Running (its DAG is executing), or terminal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryState {
    Queued,
    Running,
    Done,
    Failed,
    Canceled,
}

impl QueryState {
    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            QueryState::Done | QueryState::Failed | QueryState::Canceled
        )
    }
}

/// How the service satisfied a query.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Lowered fresh, executed on the rank pool.
    Cold,
    /// Reused a cached [`LoweredPlan`] (lowering skipped), executed.
    PlanHit,
    /// Served straight from the result cache — no execution at all.
    ResultHit,
}

/// Final record of a successful query.
#[derive(Clone, Debug)]
pub struct QueryResult {
    pub id: QueryId,
    /// The sink's gathered output table (plans built with
    /// [`Plan::collect`]; `None` otherwise).
    pub output: Option<Arc<ChunkedTable>>,
    /// Rows in the sink's output, summed over ranks.
    pub output_rows: u64,
    pub cache: CacheOutcome,
    /// Seconds from admission to completion (0 for result-cache hits).
    pub exec_s: f64,
    /// Seconds spent queued behind other tenants before admission.
    pub queue_wait_s: f64,
}

/// Internal terminal outcome. [`Error`] is not `Clone`, so failures are
/// stored as their rendered message and re-typed on read.
#[derive(Clone, Debug)]
enum Outcome {
    Ok(QueryResult),
    Failed(String),
    Canceled,
}

struct QueryInner {
    id: QueryId,
    state: Mutex<(QueryState, Option<Outcome>)>,
    cv: Condvar,
    /// Best-effort cancellation flag, checked before every DAG node.
    cancel: AtomicBool,
    /// Back-pointer for queue-slot release on cancel (weak: a handle
    /// must not keep the whole service alive).
    svc: Weak<Inner>,
}

impl QueryInner {
    /// Queued → Running; `false` if already terminal (canceled).
    fn begin_running(&self) -> bool {
        let mut st = lock_recover(&self.state);
        if st.0 != QueryState::Queued {
            return false;
        }
        st.0 = QueryState::Running;
        self.cv.notify_all();
        true
    }

    /// Record the terminal outcome (first writer wins).
    fn complete(&self, outcome: Outcome) {
        let mut st = lock_recover(&self.state);
        if st.0.is_terminal() {
            return;
        }
        st.0 = match &outcome {
            Outcome::Ok(_) => QueryState::Done,
            Outcome::Failed(_) => QueryState::Failed,
            Outcome::Canceled => QueryState::Canceled,
        };
        st.1 = Some(outcome);
        self.cv.notify_all();
    }

    /// Queued → Canceled (no effect once running or terminal).
    fn cancel_if_queued(&self) {
        let mut st = lock_recover(&self.state);
        if st.0 == QueryState::Queued {
            st.0 = QueryState::Canceled;
            st.1 = Some(Outcome::Canceled);
            self.cv.notify_all();
        }
    }

    fn to_result(&self, o: &Outcome) -> Result<QueryResult> {
        match o {
            Outcome::Ok(r) => Ok(r.clone()),
            Outcome::Failed(m) => Err(Error::TaskFailed(m.clone())),
            Outcome::Canceled => Err(Error::TaskFailed(format!(
                "query {} canceled before completion",
                self.id
            ))),
        }
    }
}

/// Shared handle to a submitted query. All accessors are safe from any
/// thread; `join` blocks, everything else is non-blocking.
#[derive(Clone)]
pub struct QueryHandle {
    inner: Arc<QueryInner>,
}

impl std::fmt::Debug for QueryHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("QueryHandle")
            .field("id", &self.inner.id)
            .field("state", &self.status())
            .finish()
    }
}

impl QueryHandle {
    pub fn id(&self) -> QueryId {
        self.inner.id
    }

    /// Current lifecycle state (non-blocking).
    pub fn status(&self) -> QueryState {
        lock_recover(&self.inner.state).0
    }

    /// The outcome if the query is terminal, `None` while it is still
    /// queued or running (non-blocking).
    pub fn poll(&self) -> Option<Result<QueryResult>> {
        let st = lock_recover(&self.inner.state);
        st.1.as_ref().map(|o| self.inner.to_result(o))
    }

    /// Block until terminal and return the outcome. Failed queries
    /// surface as [`Error::TaskFailed`]; canceled queries as a
    /// `TaskFailed` whose message names the cancellation (check
    /// [`QueryHandle::status`] to distinguish).
    pub fn join(&self) -> Result<QueryResult> {
        let mut st = lock_recover(&self.inner.state);
        while st.1.is_none() {
            st = self.inner.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        self.inner.to_result(st.1.as_ref().expect("terminal outcome"))
    }

    /// [`QueryHandle::join`] with a deadline: block until the query is
    /// terminal or `timeout` elapses, whichever comes first. A timeout
    /// returns [`Error::Timeout`] and leaves the query running — call
    /// [`QueryHandle::cancel`] to stop it, or `join_timeout` again to
    /// keep waiting.
    pub fn join_timeout(&self, timeout: Duration) -> Result<QueryResult> {
        let t0 = Instant::now();
        let mut st = lock_recover(&self.inner.state);
        while st.1.is_none() {
            let elapsed = t0.elapsed();
            if elapsed >= timeout {
                return Err(Error::Timeout(format!(
                    "query {} still {:?} after {:.3}s",
                    self.inner.id,
                    st.0,
                    timeout.as_secs_f64()
                )));
            }
            let (s, _) = self
                .inner
                .cv
                .wait_timeout(st, timeout - elapsed)
                .unwrap_or_else(|e| e.into_inner());
            st = s;
        }
        self.inner.to_result(st.1.as_ref().expect("terminal outcome"))
    }

    /// Best-effort cancellation. A still-queued query is removed from
    /// the admission queue immediately (releasing its slot) and turns
    /// `Canceled`; a running query stops at its next DAG-node boundary.
    /// Completed queries are unaffected.
    pub fn cancel(&self) {
        self.inner.cancel.store(true, Ordering::Release);
        if let Some(svc) = self.inner.svc.upgrade() {
            let mut sched = lock_recover(&svc.sched);
            if let Some(pos) = sched
                .queue
                .iter()
                .position(|q| q.query.id == self.inner.id)
            {
                sched.queue.remove(pos);
            }
        }
        self.inner.cancel_if_queued();
    }
}

/// One admitted-or-queued query, carrying everything execution needs.
struct Queued {
    query: Arc<QueryInner>,
    lowered: Arc<LoweredPlan>,
    est_bytes: u64,
    /// `Some(fingerprint)` when the completed output should populate the
    /// result cache (collect plan over deterministic sources).
    result_key: Option<Arc<str>>,
    cache: CacheOutcome,
    queued_at: Instant,
    seq: u64,
}

/// Admission state: the in-flight set and the bounded wait queue.
struct Sched {
    inflight: usize,
    inflight_bytes: u64,
    queue: VecDeque<Queued>,
    seq: u64,
    /// The queries executing right now (weak — an abandoned handle must
    /// not pin the query record). Shutdown uses this to cancel
    /// stragglers once the drain deadline expires.
    running: Vec<(QueryId, Weak<QueryInner>)>,
}

struct PlanCache {
    cap: usize,
    /// Front = least recently used.
    entries: VecDeque<(Arc<str>, Arc<LoweredPlan>)>,
}

impl PlanCache {
    fn get(&mut self, key: &str) -> Option<Arc<LoweredPlan>> {
        let pos = self.entries.iter().position(|(k, _)| k.as_ref() == key)?;
        let e = self.entries.remove(pos).expect("position just found");
        let hit = e.1.clone();
        self.entries.push_back(e);
        Some(hit)
    }

    fn insert(&mut self, key: Arc<str>, lowered: Arc<LoweredPlan>) {
        if self.entries.iter().any(|(k, _)| k.as_ref() == key.as_ref()) {
            return;
        }
        if self.entries.len() >= self.cap {
            self.entries.pop_front();
        }
        self.entries.push_back((key, lowered));
    }
}

struct ResultEntry {
    key: Arc<str>,
    output: Option<Arc<ChunkedTable>>,
    rows: u64,
    bytes: u64,
}

struct ResultCache {
    budget: u64,
    bytes: u64,
    /// Front = least recently used.
    entries: VecDeque<ResultEntry>,
}

impl ResultCache {
    fn get(&mut self, key: &str) -> Option<(Option<Arc<ChunkedTable>>, u64)> {
        let pos = self.entries.iter().position(|e| e.key.as_ref() == key)?;
        let e = self.entries.remove(pos).expect("position just found");
        let hit = (e.output.clone(), e.rows);
        self.entries.push_back(e);
        Some(hit)
    }

    fn insert(
        &mut self,
        key: Arc<str>,
        output: Option<Arc<ChunkedTable>>,
        rows: u64,
    ) {
        if self.budget == 0 {
            return;
        }
        if self.entries.iter().any(|e| e.key.as_ref() == key.as_ref()) {
            return;
        }
        let bytes = output.as_ref().map(|t| t.byte_size() as u64).unwrap_or(0);
        if bytes > self.budget {
            // One oversized result must not flush the whole cache.
            return;
        }
        let mut evicted = 0u64;
        while self.bytes + bytes > self.budget {
            let Some(e) = self.entries.pop_front() else { break };
            self.bytes -= e.bytes;
            evicted += 1;
        }
        if evicted > 0 {
            cache_metrics::record_result_evictions(evicted);
        }
        self.bytes += bytes;
        self.entries.push_back(ResultEntry { key, output, rows, bytes });
    }
}

/// Plan-cache capacity (entries). Lowered DAGs are small — a few hundred
/// bytes per node — so a fixed generous cap beats another config knob.
const PLAN_CACHE_ENTRIES: usize = 256;

struct Inner {
    cfg: ServiceConfig,
    session: Session,
    pilot: Arc<Pilot>,
    ready_policy: ReadyPolicy,
    sched: Mutex<Sched>,
    /// Signaled whenever `inflight` drops to zero (shutdown drain).
    idle_cv: Condvar,
    plan_cache: Mutex<PlanCache>,
    result_cache: Mutex<ResultCache>,
    ids: AtomicU64,
    closed: AtomicBool,
}

impl Inner {
    /// Plan-cache lookup, lowering on miss (outside the cache lock).
    fn lowered_for(
        &self,
        plan: &Plan,
        fp: &Arc<str>,
    ) -> Result<(Arc<LoweredPlan>, CacheOutcome)> {
        if let Some(hit) = lock_recover(&self.plan_cache).get(fp) {
            cache_metrics::record_plan_hit();
            return Ok((hit, CacheOutcome::PlanHit));
        }
        let lowered = Arc::new(plan.lower()?);
        cache_metrics::record_plan_miss();
        lock_recover(&self.plan_cache).insert(fp.clone(), lowered.clone());
        Ok((lowered, CacheOutcome::Cold))
    }

    /// Does a query of `est` bytes fit the in-flight byte bound right
    /// now? An empty in-flight set always fits, so a query larger than
    /// the whole bound can still run (alone) instead of starving. When
    /// the process-global spill governor is bounded
    /// ([`crate::spill::global`]), admission additionally holds work
    /// whose estimated source bytes exceed the governor's *current*
    /// headroom — in-flight out-of-core operators release their
    /// reservations as they spill, so held queries are promoted on the
    /// next scheduling pass rather than starving.
    fn bytes_fit(&self, sched: &Sched, est: u64) -> bool {
        if sched.inflight == 0 {
            return true;
        }
        let cap_ok = self.cfg.max_inflight_bytes == 0
            || sched.inflight_bytes + est <= self.cfg.max_inflight_bytes;
        cap_ok && est <= crate::spill::global().headroom()
    }

    /// Run one admitted query's DAG on the shared pool + pilot.
    fn execute(
        &self,
        q: &Queued,
    ) -> Result<(Option<Arc<ChunkedTable>>, u64)> {
        let tm = self.session.task_manager(&self.pilot);
        let cancel = &q.query.cancel;
        let id = q.query.id;
        let results = q.lowered.pipeline.run_pooled(
            pool::global(),
            self.ready_policy,
            |td| {
                if cancel.load(Ordering::Acquire) {
                    return Err(Error::TaskFailed(format!(
                        "query {id} canceled"
                    )));
                }
                tm.submit(td)?.wait()
            },
        )?;
        let sink = &results[q.lowered.sink];
        Ok((sink.output.clone(), sink.output_rows))
    }
}

/// Thread-per-admitted-query: the thread drives the DAG (helping the
/// global pool while its nodes run) and releases its admission slot on
/// the way out.
fn spawn_query(inner: Arc<Inner>, q: Queued) {
    std::thread::Builder::new()
        .name(format!("svc-{}", q.query.id))
        .spawn(move || run_query(inner, q))
        .expect("spawn query thread");
}

fn run_query(inner: Arc<Inner>, q: Queued) {
    let queue_wait_s = q.queued_at.elapsed().as_secs_f64();
    let outcome = if !q.query.begin_running() {
        // Canceled between admission and startup.
        Outcome::Canceled
    } else {
        let t0 = Instant::now();
        match execute_with_retry(&inner, &q) {
            Ok((output, output_rows)) => Outcome::Ok(QueryResult {
                id: q.query.id,
                output,
                output_rows,
                cache: q.cache,
                exec_s: t0.elapsed().as_secs_f64(),
                queue_wait_s,
            }),
            Err(_) if q.query.cancel.load(Ordering::Acquire) => {
                Outcome::Canceled
            }
            Err(e) => Outcome::Failed(e.to_string()),
        }
    };
    if let (Outcome::Ok(r), Some(key)) = (&outcome, &q.result_key) {
        lock_recover(&inner.result_cache).insert(
            key.clone(),
            r.output.clone(),
            r.output_rows,
        );
    }
    q.query.complete(outcome);
    retire(&inner, q.query.id, q.est_bytes);
}

/// Query-level retry: re-execute the whole DAG on transient failure, up
/// to `cfg.retry_max_attempts` total attempts with the process backoff
/// policy. Cancellation is never retried (a cancel error renders as
/// transient `TaskFailed`, so the cancel flag gates explicitly), and
/// deterministic plans re-run bit-identically.
fn execute_with_retry(
    inner: &Arc<Inner>,
    q: &Queued,
) -> Result<(Option<Arc<ChunkedTable>>, u64)> {
    let policy = faults::RetryPolicy {
        max_attempts: inner.cfg.retry_max_attempts.max(1),
        ..faults::retry_policy()
    };
    let mut attempt = 1u32;
    loop {
        match inner.execute(q) {
            Ok(out) => {
                if attempt > 1 {
                    fault_metrics::record_recovered();
                }
                return Ok(out);
            }
            Err(e)
                if e.is_transient()
                    && attempt < policy.max_attempts
                    && !q.query.cancel.load(Ordering::Acquire) =>
            {
                fault_metrics::record_retried();
                let ms = policy.backoff_ms(attempt, q.query.id.0);
                if ms > 0 {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                attempt += 1;
            }
            Err(e) => {
                if e.is_transient() && attempt > 1 {
                    fault_metrics::record_exhausted();
                }
                return Err(e);
            }
        }
    }
}

/// Release an admission slot and promote queued work per policy.
fn retire(inner: &Arc<Inner>, id: QueryId, est_bytes: u64) {
    let mut sched = lock_recover(&inner.sched);
    sched.inflight -= 1;
    sched.inflight_bytes -= est_bytes;
    sched.running.retain(|(qid, _)| *qid != id);
    promote_locked(inner, &mut sched);
    if sched.inflight == 0 {
        inner.idle_cv.notify_all();
    }
}

/// Fill freed in-flight slots from the queue. Canceled entries are
/// dropped; [`AdmitPolicy::CostAware`] picks the smallest estimated
/// bytes (arrival order on ties), FIFO the front.
fn promote_locked(inner: &Arc<Inner>, sched: &mut Sched) {
    while sched.inflight < inner.cfg.max_inflight {
        sched
            .queue
            .retain(|q| !q.query.cancel.load(Ordering::Acquire));
        let idx = match inner.cfg.admit {
            AdmitPolicy::Fifo => sched
                .queue
                .iter()
                .position(|q| inner.bytes_fit(sched, q.est_bytes)),
            AdmitPolicy::CostAware => sched
                .queue
                .iter()
                .enumerate()
                .filter(|(_, q)| inner.bytes_fit(sched, q.est_bytes))
                .min_by_key(|(_, q)| (q.est_bytes, q.seq))
                .map(|(i, _)| i),
        };
        let Some(idx) = idx else { break };
        let q = sched.queue.remove(idx).expect("index just found");
        sched.inflight += 1;
        sched.inflight_bytes += q.est_bytes;
        sched.running.push((q.query.id, Arc::downgrade(&q.query)));
        spawn_query(inner.clone(), q);
    }
}

/// The long-lived multi-tenant front door: one shared pilot + thread
/// pool, many concurrent [`Plan`]s. See the module docs for the full
/// submission → admission → cache → pooled-DAG walk-through.
pub struct QueryService {
    inner: Arc<Inner>,
}

impl QueryService {
    /// Boot the service: validate `cfg`, allocate the long-lived pilot
    /// (`cfg.ranks` cores on a local machine spec), and open admission.
    pub fn start(cfg: ServiceConfig) -> Result<QueryService> {
        cfg.validate()?;
        cfg.apply_memory_budget();
        let session = Session::new("query-service");
        let pd = PilotDescription::new(MachineSpec::local(cfg.ranks), 1);
        let pilot = session.pilot_manager().submit(pd)?;
        let result_budget = cfg.result_cache_bytes;
        Ok(QueryService {
            inner: Arc::new(Inner {
                cfg,
                session,
                pilot,
                ready_policy: ReadyPolicy::Fifo,
                sched: Mutex::new(Sched {
                    inflight: 0,
                    inflight_bytes: 0,
                    queue: VecDeque::new(),
                    seq: 0,
                    running: Vec::new(),
                }),
                idle_cv: Condvar::new(),
                plan_cache: Mutex::new(PlanCache {
                    cap: PLAN_CACHE_ENTRIES,
                    entries: VecDeque::new(),
                }),
                result_cache: Mutex::new(ResultCache {
                    budget: result_budget,
                    bytes: 0,
                    entries: VecDeque::new(),
                }),
                ids: AtomicU64::new(1),
                closed: AtomicBool::new(false),
            }),
        })
    }

    /// [`QueryService::start`] with [`ServiceConfig::default`].
    pub fn start_default() -> Result<QueryService> {
        QueryService::start(ServiceConfig::default())
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.inner.cfg
    }

    /// Queries executing right now (diagnostic).
    pub fn inflight(&self) -> usize {
        lock_recover(&self.inner.sched).inflight
    }

    /// Queries waiting for admission (diagnostic).
    pub fn queue_len(&self) -> usize {
        lock_recover(&self.inner.sched).queue.len()
    }

    /// Submit a plan for execution. Non-blocking: returns a
    /// [`QueryHandle`] once the query is admitted *or queued*, and
    /// [`Error::Admission`] when the in-flight set and queue are both
    /// full (typed back-pressure — callers retry or shed load;
    /// submission never blocks on other tenants). Invalid plans fail
    /// here with their usual [`Error::Config`] diagnostics, and plans
    /// wider than the service's rank pool are rejected up front.
    pub fn submit(&self, plan: Plan) -> Result<QueryHandle> {
        let inner = &self.inner;
        if inner.closed.load(Ordering::Acquire) {
            return Err(Error::Admission("query service is shut down".into()));
        }
        let fp: Arc<str> = Arc::from(plan.fingerprint()?);
        let (lowered, cache) = inner.lowered_for(&plan, &fp)?;
        let widest = lowered.pipeline.max_ranks();
        if widest > inner.pilot.cores() {
            return Err(Error::Admission(format!(
                "plan needs {widest} ranks but the service pool has {}",
                inner.pilot.cores()
            )));
        }
        let est_bytes = lowered.pipeline.estimated_source_bytes();
        // CSV-backed plans are cacheable too: the fingerprint carries the
        // source file's length + mtime, so a changed file misses.
        let cacheable = plan.collects() && inner.cfg.result_cache_bytes > 0;
        let id = QueryId(inner.ids.fetch_add(1, Ordering::Relaxed));
        let query = Arc::new(QueryInner {
            id,
            state: Mutex::new((QueryState::Queued, None)),
            cv: Condvar::new(),
            cancel: AtomicBool::new(false),
            svc: Arc::downgrade(inner),
        });
        if cacheable {
            if let Some((output, rows)) =
                lock_recover(&inner.result_cache).get(&fp)
            {
                cache_metrics::record_result_hit();
                query.complete(Outcome::Ok(QueryResult {
                    id,
                    output,
                    output_rows: rows,
                    cache: CacheOutcome::ResultHit,
                    exec_s: 0.0,
                    queue_wait_s: 0.0,
                }));
                return Ok(QueryHandle { inner: query });
            }
            cache_metrics::record_result_miss();
        }

        let mut sched = lock_recover(&inner.sched);
        let q = Queued {
            query: query.clone(),
            lowered,
            est_bytes,
            result_key: if cacheable { Some(fp) } else { None },
            cache,
            queued_at: Instant::now(),
            seq: sched.seq,
        };
        sched.seq += 1;
        if sched.inflight < inner.cfg.max_inflight
            && inner.bytes_fit(&sched, est_bytes)
        {
            sched.inflight += 1;
            sched.inflight_bytes += est_bytes;
            sched.running.push((q.query.id, Arc::downgrade(&q.query)));
            drop(sched);
            spawn_query(inner.clone(), q);
        } else if sched.queue.len() < inner.cfg.queue_depth {
            sched.queue.push_back(q);
        } else {
            return Err(Error::Admission(format!(
                "{} queries in flight and the queue is full ({} of {})",
                sched.inflight,
                sched.queue.len(),
                inner.cfg.queue_depth
            )));
        }
        Ok(QueryHandle { inner: query })
    }

    /// Submit and block for the outcome (convenience).
    pub fn run(&self, plan: Plan) -> Result<QueryResult> {
        self.submit(plan)?.join()
    }

    /// Block until no query is in flight and the queue is empty.
    pub fn drain(&self) {
        let mut sched = lock_recover(&self.inner.sched);
        while sched.inflight > 0 || !sched.queue.is_empty() {
            sched = self.inner.idle_cv.wait(sched).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Close admission, cancel queued work, drain in-flight queries,
    /// and release the pilot. Idempotent; concurrent and subsequent
    /// [`QueryService::submit`] calls get [`Error::Admission`].
    ///
    /// With [`ServiceConfig::shutdown_timeout_s`] `> 0` the drain is
    /// bounded: queries still in flight when the deadline expires are
    /// canceled (they stop at their next DAG-node boundary) and given
    /// one more window of the same length to unwind, and the call
    /// returns [`Error::Timeout`] naming the stragglers. The pilot is
    /// released only once the pool is actually quiet — if a straggler
    /// outlives even the grace window it is left running (detached) so
    /// shutdown can never hang. `0` (the default) waits forever, the
    /// pre-deadline behavior.
    pub fn shutdown(&self) -> Result<()> {
        let inner = &self.inner;
        if inner.closed.swap(true, Ordering::AcqRel) {
            return Ok(());
        }
        let mut sched = lock_recover(&inner.sched);
        for q in sched.queue.drain(..) {
            q.query.cancel_if_queued();
        }
        let Some(t) = inner.cfg.shutdown_timeout() else {
            while sched.inflight > 0 {
                sched =
                    inner.idle_cv.wait(sched).unwrap_or_else(|e| e.into_inner());
            }
            drop(sched);
            inner.pilot.shutdown();
            return Ok(());
        };
        let t0 = Instant::now();
        while sched.inflight > 0 && t0.elapsed() < t {
            let (s, _) = inner
                .idle_cv
                .wait_timeout(sched, t - t0.elapsed())
                .unwrap_or_else(|e| e.into_inner());
            sched = s;
        }
        if sched.inflight == 0 {
            drop(sched);
            inner.pilot.shutdown();
            return Ok(());
        }
        // Deadline blown: cancel every straggler, then grant one grace
        // window of the same length for them to reach a node boundary
        // and unwind.
        let mut stragglers = Vec::new();
        for (id, w) in &sched.running {
            if let Some(q) = w.upgrade() {
                q.cancel.store(true, Ordering::Release);
            }
            stragglers.push(id.to_string());
        }
        let t1 = Instant::now();
        while sched.inflight > 0 && t1.elapsed() < t {
            let (s, _) = inner
                .idle_cv
                .wait_timeout(sched, t - t1.elapsed())
                .unwrap_or_else(|e| e.into_inner());
            sched = s;
        }
        let drained = sched.inflight == 0;
        drop(sched);
        if drained {
            inner.pilot.shutdown();
        }
        Err(Error::Timeout(format!(
            "service shutdown drain deadline ({:.3}s) expired with {} \
             in flight [{}]; stragglers canceled{}",
            t.as_secs_f64(),
            stragglers.len(),
            stragglers.join(", "),
            if drained {
                " and since unwound"
            } else {
                "; pilot left running (detached)"
            },
        )))
    }
}

impl Drop for QueryService {
    fn drop(&mut self) {
        // A drain-deadline expiry during drop has nowhere to report; the
        // straggler queries were still canceled and detached.
        let _ = self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::df::GenSpec;
    use crate::exec::{Engine, HeterogeneousEngine};
    use crate::ops::dist::KernelBackend;

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            ranks: 2,
            max_inflight: 2,
            queue_depth: 4,
            ..ServiceConfig::default()
        }
    }

    fn sorted_plan(rows: usize, seed: u64) -> Plan {
        Plan::generate(2, GenSpec::uniform(rows, rows as i64, seed))
            .sort("key")
            .collect()
    }

    #[test]
    fn run_matches_solo_engine() {
        let svc = QueryService::start(small_cfg()).unwrap();
        let r = svc.run(sorted_plan(500, 7)).unwrap();
        assert_eq!(r.cache, CacheOutcome::Cold);
        let engine = HeterogeneousEngine::new(
            MachineSpec::local(2),
            KernelBackend::Native,
            2,
        );
        let solo = engine.run_plan(&sorted_plan(500, 7)).unwrap();
        assert_eq!(
            r.output.unwrap().multiset_fingerprint(),
            solo.output.unwrap().multiset_fingerprint()
        );
        svc.shutdown().unwrap();
    }

    #[test]
    fn handle_poll_and_status_are_nonblocking() {
        let svc = QueryService::start(small_cfg()).unwrap();
        let h = svc.submit(sorted_plan(300, 3)).unwrap();
        // Whatever the interleaving, poll never blocks and join agrees.
        let _ = h.status();
        let _ = h.poll();
        let r = h.join().unwrap();
        assert!(r.output_rows > 0);
        assert!(h.poll().unwrap().is_ok());
        assert_eq!(h.status(), QueryState::Done);
        svc.shutdown().unwrap();
    }

    #[test]
    fn second_submission_hits_the_caches() {
        let svc = QueryService::start(small_cfg()).unwrap();
        let before = cache_metrics::snapshot();
        let cold = svc.run(sorted_plan(400, 9)).unwrap();
        let hot = svc.run(sorted_plan(400, 9)).unwrap();
        assert_eq!(cold.cache, CacheOutcome::Cold);
        assert_eq!(hot.cache, CacheOutcome::ResultHit);
        assert_eq!(
            cold.output.unwrap().multiset_fingerprint(),
            hot.output.unwrap().multiset_fingerprint()
        );
        let d = cache_metrics::snapshot().since(before);
        assert!(d.result_hits >= 1, "{d:?}");
        assert!(d.result_misses >= 1, "{d:?}");
        svc.shutdown().unwrap();
    }

    #[test]
    fn too_wide_plans_rejected_up_front() {
        let svc = QueryService::start(small_cfg()).unwrap();
        let wide = Plan::generate(8, GenSpec::uniform(10, 8, 0)).collect();
        let err = svc.submit(wide).unwrap_err();
        assert!(matches!(err, Error::Admission(_)), "{err}");
        svc.shutdown().unwrap();
    }

    #[test]
    fn shutdown_is_idempotent_and_closes_admission() {
        let svc = QueryService::start(small_cfg()).unwrap();
        svc.shutdown().unwrap();
        svc.shutdown().unwrap();
        let err = svc.submit(sorted_plan(10, 0)).unwrap_err();
        assert!(matches!(err, Error::Admission(_)), "{err}");
    }

    #[test]
    fn scan_csv_plans_hit_the_result_cache_until_the_file_changes() {
        let svc = QueryService::start(small_cfg()).unwrap();
        let dir = std::env::temp_dir().join("rc-service-cache-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("content-id.csv");
        std::fs::write(&path, "key,val\n2,0.25\n1,0.5\n").unwrap();
        let plan = || {
            Plan::scan_csv(1, path.clone(), GenSpec::schema())
                .sort("key")
                .collect()
        };
        let a = svc.run(plan()).unwrap();
        let b = svc.run(plan()).unwrap();
        // The fingerprint carries the file's content identity, so an
        // unchanged file is served straight from the result cache.
        assert_eq!(b.cache, CacheOutcome::ResultHit);
        assert_eq!(a.output_rows, b.output_rows);
        // Rewriting the file changes the fingerprint: the next run must
        // re-execute (a cold/plan-level outcome, never a stale hit) and
        // see the new contents.
        std::fs::write(&path, "key,val\n3,0.125\n2,0.25\n1,0.5\n").unwrap();
        let c = svc.run(plan()).unwrap();
        assert_ne!(c.cache, CacheOutcome::ResultHit);
        assert_eq!(c.output_rows, 3);
        svc.shutdown().unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn transient_query_failure_retries_and_recovers() {
        use crate::util::faults::{FaultPlan, FireMode};
        let _g = faults::test_guard();
        // The first "svcretry" job fails (counted @1 trigger — names that
        // don't match the filter don't advance the count); the query-level
        // re-execution passes.
        faults::arm(
            FaultPlan::new(21)
                .with_arm("pool.job", FireMode::Nth(1))
                .with_only("svcretry"),
        );
        let svc = QueryService::start(ServiceConfig {
            retry_max_attempts: 3,
            ..small_cfg()
        })
        .unwrap();
        let before = crate::metrics::faults::snapshot();
        let plan = Plan::generate(2, GenSpec::uniform(400, 400, 5))
            .sort("key")
            .collect()
            .named("svcretry-sort");
        let r = svc.run(plan).unwrap();
        assert!(r.output_rows > 0);
        let d = crate::metrics::faults::snapshot().since(before);
        assert!(d.injected >= 1, "{d:?}");
        assert!(d.retried >= 1, "{d:?}");
        assert!(d.recovered >= 1, "{d:?}");
        // The recovered result is bit-identical to a clean solo run.
        faults::disarm();
        let clean = svc
            .run(
                Plan::generate(2, GenSpec::uniform(400, 400, 5))
                    .sort("key")
                    .collect()
                    .named("clean-twin-sort"),
            )
            .unwrap();
        assert_eq!(
            r.output.unwrap().multiset_fingerprint(),
            clean.output.unwrap().multiset_fingerprint()
        );
        svc.shutdown().unwrap();
    }

    #[test]
    fn retry_disabled_surfaces_the_transient_error() {
        use crate::util::faults::{FaultPlan, FireMode};
        let _g = faults::test_guard();
        faults::arm(
            FaultPlan::new(22)
                .with_arm("agent.task", FireMode::Prob(1.0))
                .with_only("svcnoretry"),
        );
        let svc = QueryService::start(small_cfg()).unwrap();
        let plan = Plan::generate(2, GenSpec::uniform(100, 100, 1))
            .collect()
            .named("svcnoretry-gen");
        let err = svc.run(plan).unwrap_err();
        assert!(err.to_string().contains("injected fault"), "{err}");
        faults::disarm();
        svc.shutdown().unwrap();
    }

    #[test]
    fn join_timeout_times_out_then_joins() {
        use crate::util::faults::{FaultPlan, FireMode};
        let _g = faults::test_guard();
        // Slow the query down (~200ms) so the first join_timeout expires.
        faults::arm(
            FaultPlan::new(23)
                .with_arm("agent.task", FireMode::Prob(1.0))
                .with_delay_ms(200)
                .with_only("svcslow"),
        );
        let svc = QueryService::start(small_cfg()).unwrap();
        let plan = Plan::generate(2, GenSpec::uniform(100, 100, 2))
            .collect()
            .named("svcslow-gen");
        let h = svc.submit(plan).unwrap();
        let err = h.join_timeout(Duration::from_millis(10)).unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "{err}");
        assert!(err.is_transient(), "a join timeout is retryable");
        let r = h.join_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.output_rows > 0);
        faults::disarm();
        svc.shutdown().unwrap();
    }

    #[test]
    fn shutdown_deadline_cancels_stragglers() {
        use crate::util::faults::{FaultPlan, FireMode};
        let _g = faults::test_guard();
        // The "svcdrain" source dawdles 150ms but the drain deadline is
        // 30ms, so shutdown must cancel the query (it stops at the next
        // node boundary, before the sort) and report it by id.
        faults::arm(
            FaultPlan::new(24)
                .with_arm("agent.task", FireMode::Prob(1.0))
                .with_delay_ms(150)
                .with_only("svcdrain"),
        );
        let svc = QueryService::start(ServiceConfig {
            shutdown_timeout_s: 0.03,
            ..small_cfg()
        })
        .unwrap();
        let plan = Plan::generate(2, GenSpec::uniform(100, 100, 3))
            .named("svcdrain-gen")
            .sort("key")
            .collect();
        let h = svc.submit(plan).unwrap();
        let err = svc.shutdown().unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "{err}");
        assert!(err.to_string().contains(&h.id().to_string()), "{err}");
        // The canceled straggler still reaches a terminal state.
        let joined = h.join();
        assert!(joined.is_err(), "canceled or failed, never Ok");
        faults::disarm();
    }
}
