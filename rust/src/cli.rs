//! Launcher CLI (hand-rolled parser; clap is unavailable offline).
//!
//! ```text
//! radical-cylon info [--experiments]
//! radical-cylon run --experiment <id> [--engine bm|batch|rp] [--backend native|pjrt]
//!                   [--iterations N] [--parallelisms 2,4,8] [--config file.ini]
//! radical-cylon plan [--ranks N] [--rows N] [--engine bm|batch|rp]
//!                    [--policy fifo|cpf] [--backend native|pjrt] [--expr]
//! radical-cylon serve [--clients N] [--queries N] [--rows N] [--ranks N]
//!                     [--config file.ini]
//! ```
//!
//! `plan --expr` runs the typed-expression demo: a derived column plus a
//! compound predicate, optimized by the plan-lowering passes (filter
//! fusion, predicate pushdown, projection pruning).

use crate::cluster::MachineSpec;
use crate::config::{
    apply_faults, parse_ini, preset, preset_ids, ExperimentConfig,
    ServiceConfig, SCALE_NOTE,
};
use crate::df::GenSpec;
use crate::error::{Error, Result};
use crate::exec::{
    run_hetero_vs_batch, run_scaling, BareMetalEngine, BatchEngine, Engine,
    EngineKind, HeterogeneousEngine, PlanRun,
};
use crate::metrics::{
    cache as cache_metrics, faults as fault_metrics, render_table,
};
use crate::ops::dist::KernelBackend;
use crate::plan::expr::{col, lit};
use crate::plan::Plan;
use crate::raptor::ReadyPolicy;
use crate::runtime::{ArtifactStore, KernelService};
use crate::service::{CacheOutcome, QueryService};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    /// Parse `--key value` / `--key` / bare-command argument lists.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".into());
        let mut flags = Vec::new();
        while let Some(arg) = it.next() {
            let Some(key) = arg.strip_prefix("--") else {
                return Err(Error::Config(format!(
                    "unexpected positional argument '{arg}'"
                )));
            };
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => Some(it.next().unwrap()),
                _ => None,
            };
            flags.push((key.to_string(), value));
        }
        Ok(Args { command, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_deref())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == key)
    }
}

fn backend_from(args: &Args) -> Result<KernelBackend> {
    match args.get("backend").unwrap_or("native") {
        "native" => Ok(KernelBackend::Native),
        "pjrt" => {
            let svc = KernelService::start(&ArtifactStore::default_dir(), 2)?;
            Ok(KernelBackend::Pjrt(svc))
        }
        other => Err(Error::Config(format!("unknown backend '{other}'"))),
    }
}

fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let mut config = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)?;
        let doc = parse_ini(&text)?;
        apply_faults(&doc)?;
        ExperimentConfig::from_ini(&doc)
    } else {
        let id = args
            .get("experiment")
            .ok_or_else(|| Error::Config("--experiment <id> required".into()))?;
        preset(id).ok_or_else(|| {
            Error::Config(format!(
                "unknown experiment '{id}' (try: {})",
                preset_ids().join(", ")
            ))
        })
    }?;
    if let Some(iters) = args.get("iterations") {
        config.iterations = iters
            .parse()
            .map_err(|_| Error::Config("bad --iterations".into()))?;
    }
    if let Some(ps) = args.get("parallelisms") {
        config.parallelisms = ps
            .split(',')
            .map(|s| s.trim().parse().map_err(|_| Error::Config("bad --parallelisms".into())))
            .collect::<Result<_>>()?;
    }
    Ok(config)
}

fn cmd_info(args: &Args) -> Result<String> {
    let mut out = String::new();
    out.push_str("radical-cylon: Radical-Cylon reproduction (CS.DC 2024)\n");
    out.push_str(&format!("{SCALE_NOTE}\n\n"));
    if args.has("experiments") {
        out.push_str("experiments (paper Table 1 + Figs 5-11):\n");
        let rows: Vec<Vec<String>> = preset_ids()
            .iter()
            .filter_map(|id| preset(id))
            .map(|c| {
                vec![
                    c.id.clone(),
                    c.machine.clone(),
                    c.op.clone(),
                    c.scaling.name().into(),
                    format!("{:?}", c.parallelisms),
                ]
            })
            .collect();
        out.push_str(&render_table(
            &["id", "machine", "op", "scaling", "parallelisms"],
            &rows,
        ));
    }
    Ok(out)
}

fn cmd_run(args: &Args) -> Result<String> {
    let config = config_from(args)?;
    let backend = backend_from(args)?;
    let mut out = format!(
        "experiment {} on {} ({} scaling), {} iterations [{}]\n",
        config.id,
        config.machine,
        config.scaling.name(),
        config.iterations,
        backend.name(),
    );
    if config.op == "hetero" {
        let rows = run_hetero_vs_batch(&config, &backend, config.iterations)?;
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    r.parallelism.to_string(),
                    r.hetero_makespan.pm(),
                    r.batch_makespan.pm(),
                    format!("{:+.1}%", r.improvement_pct()),
                ]
            })
            .collect();
        out.push_str(&render_table(
            &["ranks", "radical-cylon (s)", "batch (s)", "improvement"],
            &table,
        ));
    } else {
        let kind = match args.get("engine").unwrap_or("rp") {
            "bm" => EngineKind::BareMetal,
            "batch" => EngineKind::Batch,
            "rp" => EngineKind::Heterogeneous,
            other => return Err(Error::Config(format!("unknown engine '{other}'"))),
        };
        let rows = run_scaling(&config, kind, &backend)?;
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    r.parallelism.to_string(),
                    r.rows_per_rank.to_string(),
                    r.total.pm(),
                    r.overhead.pm(),
                    r.output_rows.to_string(),
                ]
            })
            .collect();
        out.push_str(&render_table(
            &["ranks", "rows/rank", "exec time (s)", "overhead (s)", "out rows"],
            &table,
        ));
    }
    Ok(out)
}

/// Demo ETL chain for `radical-cylon plan`: two generated sources, a
/// zero-copy expression filter on the left, a join piped on **both**
/// sides, a global sort, and a collected result.
fn demo_plan(ranks: usize, rows: usize) -> Plan {
    let key_space = (rows as i64 * ranks as i64).max(16);
    let left = Plan::generate(ranks, GenSpec::uniform(rows, key_space, 0xE71))
        .named("gen-left")
        .filter(col("val").ge(lit(0.5)))
        .named("filter-left");
    let right = Plan::generate(ranks, GenSpec::uniform(rows, key_space, 0xB0B))
        .named("gen-right");
    left.join(right, "key", "key")
        .named("join-both-piped")
        .sort("key")
        .named("sort-result")
        .collect()
}

/// `plan --expr` demo: derived column + compound predicate. The two
/// adjacent filters fuse, the fused predicate references only base
/// columns so it sinks below the derive, and the sort runs on the
/// filtered rows — the optimizer's three passes in one chain.
fn demo_expr_plan(ranks: usize, rows: usize) -> Plan {
    let key_space = (rows as i64 * ranks as i64).max(16);
    Plan::generate(ranks, GenSpec::uniform(rows, key_space, 0xE71))
        .named("gen-src")
        .derive("boosted", col("val") * lit(2.0) + lit(1.0))
        .filter((col("key") * lit(2)).gt(lit(16)).and(col("key").ne(lit(0))))
        .filter(col("val").lt(lit(0.75)))
        .sort("key")
        .named("sort-result")
        .collect()
}

fn cmd_plan(args: &Args) -> Result<String> {
    let parse = |key: &str, default: usize| -> Result<usize> {
        match args.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("bad --{key} '{v}'"))),
        }
    };
    let ranks = parse("ranks", 4)?;
    let rows = parse("rows", 20_000)?;
    let backend = backend_from(args)?;
    let policy = match args.get("policy").unwrap_or("fifo") {
        "fifo" => ReadyPolicy::Fifo,
        "cpf" => ReadyPolicy::CriticalPathFirst,
        other => return Err(Error::Config(format!("unknown policy '{other}'"))),
    };
    let expr_demo = args.has("expr");
    let plan = if expr_demo {
        demo_expr_plan(ranks, rows)
    } else {
        demo_plan(ranks, rows)
    };
    let machine = MachineSpec::local(ranks.max(2));
    let engine_name = args.get("engine").unwrap_or("rp");
    // --policy configures the dataflow scheduler's ready-set ordering;
    // the sequential engines have no such knob — reject rather than
    // silently ignore.
    if engine_name != "rp" && args.has("policy") {
        return Err(Error::Config(format!(
            "--policy applies only to the rp engine (got --engine {engine_name})"
        )));
    }
    let run: PlanRun = match engine_name {
        "bm" => BareMetalEngine::new(machine, backend).run_plan(&plan)?,
        "batch" => BatchEngine::new(machine, backend)
            .core_granular()
            .run_plan(&plan)?,
        "rp" => HeterogeneousEngine::new(machine, backend, ranks)
            .with_ready_policy(policy)
            .run_plan(&plan)?,
        other => return Err(Error::Config(format!("unknown engine '{other}'"))),
    };
    let mut out = if expr_demo {
        format!(
            "logical plan: generate -> derive(boosted) -> filter(compound \
             expr, fused+pushed) -> sort -> collect  [{engine_name}, \
             {ranks} ranks, {rows} rows/rank]\n",
        )
    } else {
        format!(
            "logical plan: generate -> filter -> join (both sides piped) -> \
             sort -> collect  [{engine_name}, {ranks} ranks, {rows} \
             rows/rank]\n",
        )
    };
    let table: Vec<Vec<String>> = run
        .results
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                r.measurement.parallelism.to_string(),
                format!("{:.4}", r.measurement.total_s()),
                r.output_rows.to_string(),
            ]
        })
        .collect();
    out.push_str(&render_table(
        &["node", "ranks", "exec (s)", "out rows"],
        &table,
    ));
    if let Some(m) = &run.metrics {
        out.push_str(&format!(
            "makespan {:.4}s, critical path {:.4}s\n",
            m.makespan_s, m.critical_path_s
        ));
    }
    if let Some(sink) = &run.output {
        out.push_str(&format!("\nresult ({} rows):\n", sink.num_rows()));
        out.push_str(&sink.compact().head(5));
    }
    Ok(out)
}

/// `serve` — boot a [`QueryService`] and drive it with concurrent client
/// threads submitting a small working set of distinct plans with a hot
/// head (most clients re-ask the same query), then report throughput and
/// cache behaviour. This is the service's smoke-test face; the sustained
/// Zipf-load benchmark lives in `benches/service_load.rs`.
fn cmd_serve(args: &Args) -> Result<String> {
    let parse = |key: &str, default: usize| -> Result<usize> {
        match args.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("bad --{key} '{v}'"))),
        }
    };
    let clients = parse("clients", 4)?.max(1);
    let queries = parse("queries", 16)?.max(1); // per client
    let rows = parse("rows", 5_000)?.max(1);
    let mut cfg = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)?;
        let doc = parse_ini(&text)?;
        apply_faults(&doc)?;
        ServiceConfig::from_ini(&doc)
    } else {
        ServiceConfig::from_env()
    }?;
    if args.has("ranks") {
        cfg.ranks = parse("ranks", cfg.ranks)?;
    }
    let ranks = cfg.ranks.clamp(1, 2);
    let svc = QueryService::start(cfg)?;
    // Working set: 4 distinct sorted-generate plans; index 0 is hot.
    let plan_for = move |i: usize| {
        let seed = 0xC11 + i as u64;
        Plan::generate(ranks, GenSpec::uniform(rows, rows as i64, seed))
            .sort("key")
            .collect()
    };
    let before = cache_metrics::snapshot();
    let faults_before = fault_metrics::snapshot();
    let t0 = std::time::Instant::now();
    use std::sync::atomic::{AtomicU64, Ordering};
    let done = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let failed = AtomicU64::new(0);
    let result_hits = AtomicU64::new(0);
    std::thread::scope(|s| {
        for c in 0..clients {
            let svc = &svc;
            let done = &done;
            let rejected = &rejected;
            let failed = &failed;
            let result_hits = &result_hits;
            s.spawn(move || {
                for q in 0..queries {
                    // 3-in-4 submissions hit the hot plan; the rest
                    // rotate through the cold tail.
                    let idx = if (c + q) % 4 != 0 { 0 } else { 1 + q % 3 };
                    match svc.submit(plan_for(idx)) {
                        Err(Error::Admission(_)) => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(h) => match h.join() {
                            Ok(r) => {
                                done.fetch_add(1, Ordering::Relaxed);
                                if r.cache == CacheOutcome::ResultHit {
                                    result_hits.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            Err(_) => {
                                failed.fetch_add(1, Ordering::Relaxed);
                            }
                        },
                    }
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let drain = svc.shutdown();
    let d = cache_metrics::snapshot().since(before);
    let fd = fault_metrics::snapshot().since(faults_before);
    let completed = done.load(Ordering::Relaxed);
    let mut out = format!(
        "query service: {clients} clients x {queries} queries \
         ({ranks}-rank plans, {rows} rows/rank)\n"
    );
    out.push_str(&render_table(
        &["completed", "rejected", "failed", "elapsed (s)", "QPS"],
        &[vec![
            completed.to_string(),
            rejected.load(Ordering::Relaxed).to_string(),
            failed.load(Ordering::Relaxed).to_string(),
            format!("{elapsed:.3}"),
            format!("{:.1}", completed as f64 / elapsed),
        ]],
    ));
    out.push_str(&format!(
        "result-cache hits {} (observed {}), misses {}, evictions {}; \
         plan-cache hits {}, misses {}\n",
        d.result_hits,
        result_hits.load(Ordering::Relaxed),
        d.result_misses,
        d.result_evictions,
        d.plan_hits,
        d.plan_misses,
    ));
    out.push_str(&format!(
        "faults: injected {}, retried {}, recovered {}, exhausted {}, \
         timed out {}, quarantined ranks {}\n",
        fd.injected,
        fd.retried,
        fd.recovered,
        fd.exhausted,
        fd.timed_out,
        fd.quarantined_ranks,
    ));
    if let Err(e) = drain {
        out.push_str(&format!("shutdown: {e}\n"));
    }
    Ok(out)
}

fn cmd_help() -> String {
    "usage:\n  radical-cylon info [--experiments]\n  radical-cylon run --experiment <id> \
     [--engine bm|batch|rp] [--backend native|pjrt] [--iterations N] \
     [--parallelisms 2,4,8] [--config file.ini]\n  radical-cylon plan [--ranks N] \
     [--rows N] [--engine bm|batch|rp] [--policy fifo|cpf] [--backend native|pjrt] \
     [--expr]\n  radical-cylon serve [--clients N] [--queries N] [--rows N] [--ranks N] \
     [--config file.ini]\n\nfault injection / retry (chaos testing): add a [faults] section to \
     --config\n  (sites: agent.task, op.execute, comm.alltoall, comm.send, pool.job), or \
     set\n  RC_FAULTS=\"agent.task=0.05,seed=7\" RC_RETRY_MAX=3 RC_TASK_DEADLINE_S=5\n"
        .to_string()
}

/// CLI entrypoint: returns the text to print, or an error.
pub fn dispatch(argv: Vec<String>) -> Result<String> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "info" => cmd_info(&args),
        "run" => cmd_run(&args),
        "plan" => cmd_plan(&args),
        "serve" => cmd_serve(&args),
        "help" | "--help" | "-h" => Ok(cmd_help()),
        other => Err(Error::Config(format!(
            "unknown command '{other}'\n{}",
            cmd_help()
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_flags() {
        let a = Args::parse(argv("run --experiment fig5-weak --iterations 3 --flag")).unwrap();
        assert_eq!(a.command, "run");
        assert_eq!(a.get("experiment"), Some("fig5-weak"));
        assert_eq!(a.get("iterations"), Some("3"));
        assert!(a.has("flag"));
        assert!(!a.has("nope"));
    }

    #[test]
    fn info_lists_experiments() {
        let out = dispatch(argv("info --experiments")).unwrap();
        assert!(out.contains("fig10-weak"));
        assert!(out.contains("table2-join-weak"));
    }

    #[test]
    fn run_small_experiment_end_to_end() {
        let out = dispatch(argv(
            "run --experiment overhead --iterations 2 --parallelisms 2,3",
        ))
        .unwrap();
        assert!(out.contains("exec time"), "{out}");
        // two parallelism rows
        assert!(out.lines().count() >= 4, "{out}");
    }

    #[test]
    fn plan_subcommand_end_to_end() {
        let out = dispatch(argv("plan --ranks 2 --rows 400")).unwrap();
        assert!(out.contains("join-both-piped"), "{out}");
        assert!(out.contains("sort-result"), "{out}");
        assert!(out.contains("result ("), "carries the sink table: {out}");
        // Sequential engines drive the same plan.
        let bm = dispatch(argv("plan --ranks 2 --rows 200 --engine bm")).unwrap();
        assert!(bm.contains("sort-result"), "{bm}");
        let err = dispatch(argv("plan --policy sideways")).unwrap_err().to_string();
        assert!(err.contains("unknown policy"), "{err}");
    }

    #[test]
    fn plan_expr_demo_end_to_end() {
        let out = dispatch(argv("plan --ranks 2 --rows 400 --expr")).unwrap();
        assert!(out.contains("derive(boosted)"), "{out}");
        assert!(out.contains("sort-result"), "{out}");
        // The fused+pushed filter runs as one task below the derive.
        assert!(out.contains("filter"), "{out}");
        assert!(out.contains("result ("), "{out}");
        // The derived column appears in the sink schema.
        assert!(out.contains("boosted"), "{out}");
    }

    #[test]
    fn serve_smoke() {
        let out =
            dispatch(argv("serve --clients 2 --queries 6 --rows 300 --ranks 2"))
                .unwrap();
        assert!(out.contains("QPS"), "{out}");
        assert!(out.contains("result-cache hits"), "{out}");
        assert!(out.contains("completed"), "{out}");
        assert!(out.contains("faults: injected"), "{out}");
        let e = dispatch(argv("serve --clients zero")).unwrap_err().to_string();
        assert!(e.contains("bad --clients"), "{e}");
    }

    #[test]
    fn errors_are_helpful() {
        let e = dispatch(argv("run")).unwrap_err().to_string();
        assert!(e.contains("--experiment"), "{e}");
        let e2 = dispatch(argv("run --experiment nope")).unwrap_err().to_string();
        assert!(e2.contains("unknown experiment"), "{e2}");
        let e3 = dispatch(argv("frobnicate")).unwrap_err().to_string();
        assert!(e3.contains("unknown command"), "{e3}");
    }

    #[test]
    fn help_shown() {
        assert!(dispatch(argv("help")).unwrap().contains("usage"));
    }
}
