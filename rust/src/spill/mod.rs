//! Out-of-core execution substrate: a process-wide memory-budget
//! governor and a columnar on-disk run format.
//!
//! The paper's headline experiments sort and join 35M–3.5B rows; holding
//! a working set that size in RAM is exactly what a bounded machine
//! cannot do. This module gives the data plane a disk tier:
//!
//! * [`MemoryBudget`] — a byte governor (config key `mem_budget_bytes`,
//!   env `RC_MEM_BUDGET`, default unbounded). Out-of-core operators
//!   **reserve** bytes before materializing ([`MemoryBudget::reserve`] /
//!   [`MemoryBudget::try_reserve`]); the RAII [`Reservation`] releases on
//!   drop and the governor tracks a high-water mark ([`MemoryBudget::peak`])
//!   that benches assert against (`benches/out_of_core.rs`).
//! * [`RunWriter`] / [`RunReader`] — length-prefixed, CRC-checked column
//!   blocks (the `RCSP` format below) that round-trip a [`Table`]
//!   **bit-identically**, including NaN payloads (f64 travels as raw
//!   bit patterns) and Utf8 arenas (per-row strings, rebuilt into a fresh
//!   arena on restore).
//! * [`SpilledTable`] — a handle to a run on disk: schema + row count +
//!   byte sizes stay in RAM, rows live in a temp file that is deleted
//!   when the last handle drops.
//!
//! Spill traffic is accounted in [`crate::metrics::spill`]
//! (bytes_spilled / bytes_restored / runs / spill time), alongside the
//! existing bytes-materialized accounting in [`crate::metrics::mem`]
//! (restores rebuild columns through the normal builders, so they are
//! counted as materializations like any other copy).
//!
//! ## On-disk run format
//!
//! A run is a sequence of blocks, each holding a row range of one table:
//!
//! ```text
//! block   := magic:u32 ("RCSP") ncols:u32 nrows:u64 column*
//! column  := dtype_tag:u8 payload_len:u64 payload crc32:u32
//! payload := i64/f64: raw LE words of the visible window
//!            bool:    one byte per row (0/1)
//!            utf8:    len_i:u32 per row, then the concatenated bytes
//! ```
//!
//! All integers are little-endian. The CRC covers the payload only; a
//! mismatch (or a tag/arity mismatch against the expected schema) is a
//! typed error, never silent corruption. End-of-run is a clean EOF at a
//! block boundary.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::df::{Column, DataType, Schema, Table, Utf8Builder};
use crate::error::{Error, Result};
use crate::metrics::spill as spill_metrics;

/// Block magic: "RCSP" (radical-cylon spill).
const MAGIC: u32 = 0x5243_5350;

// ---------------------------------------------------------------------------
// Memory budget governor
// ---------------------------------------------------------------------------

/// Byte governor for out-of-core operators. `limit == 0` means
/// unbounded (the default — nothing spills until a budget is set).
///
/// The governor is **advisory by protocol**: operators call
/// [`MemoryBudget::reserve`] before materializing a batch, run, bucket,
/// or output chunk, and the [`Reservation`] releases the bytes when the
/// allocation dies. [`MemoryBudget::peak`] is the resulting high-water
/// mark — the number the out-of-core bench hard-asserts stays under
/// budget + one morsel of slack.
#[derive(Debug)]
pub struct MemoryBudget {
    limit: u64,
    in_use: AtomicU64,
    peak: AtomicU64,
}

impl MemoryBudget {
    /// Budget of `limit` bytes; `0` = unbounded.
    pub fn new(limit: u64) -> MemoryBudget {
        MemoryBudget {
            limit,
            in_use: AtomicU64::new(0),
            peak: AtomicU64::new(0),
        }
    }

    /// The unbounded governor (never trips).
    pub fn unbounded() -> MemoryBudget {
        MemoryBudget::new(0)
    }

    /// `Some(bytes)` when bounded, `None` when unbounded.
    pub fn limit(&self) -> Option<u64> {
        (self.limit > 0).then_some(self.limit)
    }

    /// Currently reserved bytes.
    pub fn in_use(&self) -> u64 {
        self.in_use.load(Ordering::Relaxed)
    }

    /// High-water mark of reserved bytes since creation (or the last
    /// [`MemoryBudget::reset_peak`]).
    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    /// Reset the high-water mark to the current reservation level (bench
    /// scoping between phases).
    pub fn reset_peak(&self) {
        self.peak.store(self.in_use(), Ordering::Relaxed);
    }

    /// Would reserving `bytes` more stay within the limit?
    pub fn would_fit(&self, bytes: u64) -> bool {
        self.limit == 0 || self.in_use().saturating_add(bytes) <= self.limit
    }

    /// Bytes left under the limit (`u64::MAX` when unbounded).
    pub fn headroom(&self) -> u64 {
        if self.limit == 0 {
            u64::MAX
        } else {
            self.limit.saturating_sub(self.in_use())
        }
    }

    /// Reserve `bytes` unconditionally (overdraft allowed — the caller
    /// has decided it must materialize; the peak records the overdraft
    /// honestly). Prefer [`MemoryBudget::try_reserve`] when the caller
    /// can spill instead.
    pub fn reserve(&self, bytes: u64) -> Reservation<'_> {
        self.charge(bytes);
        Reservation { budget: self, bytes }
    }

    /// Reserve `bytes` only if they fit under the limit; `None` means
    /// the caller should spill.
    pub fn try_reserve(&self, bytes: u64) -> Option<Reservation<'_>> {
        if self.limit == 0 {
            return Some(self.reserve(bytes));
        }
        let mut cur = self.in_use.load(Ordering::Relaxed);
        loop {
            if cur.saturating_add(bytes) > self.limit {
                return None;
            }
            match self.in_use.compare_exchange_weak(
                cur,
                cur + bytes,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.peak.fetch_max(cur + bytes, Ordering::Relaxed);
                    return Some(Reservation { budget: self, bytes });
                }
                Err(now) => cur = now,
            }
        }
    }

    fn charge(&self, bytes: u64) {
        let now = self.in_use.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    fn release(&self, bytes: u64) {
        self.in_use.fetch_sub(bytes, Ordering::Relaxed);
    }
}

/// RAII byte reservation against a [`MemoryBudget`]; releases on drop.
#[derive(Debug)]
pub struct Reservation<'a> {
    budget: &'a MemoryBudget,
    bytes: u64,
}

impl Reservation<'_> {
    /// Bytes currently held by this reservation.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Grow the reservation by `more` bytes (overdraft allowed).
    pub fn grow(&mut self, more: u64) {
        self.budget.charge(more);
        self.bytes += more;
    }

    /// Return `less` bytes to the budget (saturating at zero).
    pub fn shrink(&mut self, less: u64) {
        let less = less.min(self.bytes);
        self.budget.release(less);
        self.bytes -= less;
    }
}

impl Drop for Reservation<'_> {
    fn drop(&mut self) {
        self.budget.release(self.bytes);
    }
}

// ---------------------------------------------------------------------------
// Process-global budget (config `mem_budget_bytes` / env RC_MEM_BUDGET)
// ---------------------------------------------------------------------------

static GLOBAL: OnceLock<MemoryBudget> = OnceLock::new();

/// Latch the process-global budget. First caller wins (same contract as
/// [`crate::util::pool::configure`]); returns `false` when the budget was
/// already resolved, in which case the earlier value stays in force.
pub fn configure(limit_bytes: u64) -> bool {
    GLOBAL.set(MemoryBudget::new(limit_bytes)).is_ok()
}

/// The process-global budget. Resolved once: an explicit [`configure`]
/// wins, else the `RC_MEM_BUDGET` env variable (sizes like `268435456`,
/// `256M`, `1G`), else unbounded.
pub fn global() -> &'static MemoryBudget {
    GLOBAL.get_or_init(|| {
        let limit = std::env::var("RC_MEM_BUDGET")
            .ok()
            .and_then(|s| parse_byte_size(&s))
            .unwrap_or(0);
        MemoryBudget::new(limit)
    })
}

/// Parse a human byte size: a plain integer, optionally suffixed with
/// `K`/`M`/`G`/`T` (binary multiples) and an optional trailing `B`, case
/// insensitive: `4096`, `64K`, `256M`, `1gb`. Returns `None` on
/// malformed input (the caller falls back to unbounded).
pub fn parse_byte_size(s: &str) -> Option<u64> {
    let t = s.trim().to_ascii_uppercase();
    if t.is_empty() {
        return None;
    }
    let t = t.strip_suffix('B').unwrap_or(&t);
    let (digits, mult) = match t.as_bytes().last()? {
        b'K' => (&t[..t.len() - 1], 1u64 << 10),
        b'M' => (&t[..t.len() - 1], 1u64 << 20),
        b'G' => (&t[..t.len() - 1], 1u64 << 30),
        b'T' => (&t[..t.len() - 1], 1u64 << 40),
        _ => (t, 1u64),
    };
    digits.trim().parse::<u64>().ok().map(|v| v.saturating_mul(mult))
}

// ---------------------------------------------------------------------------
// Spill files
// ---------------------------------------------------------------------------

static FILE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Directory spill runs land in: `RC_SPILL_DIR` if set, else the system
/// temp directory.
pub fn spill_dir() -> PathBuf {
    std::env::var_os("RC_SPILL_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(std::env::temp_dir)
}

/// A temp file owned by the spill subsystem; deleted when the last
/// handle drops. Shared as `Arc<SpillFile>` so readers and spilled
/// chunks keep the file alive independently.
#[derive(Debug)]
pub struct SpillFile {
    path: PathBuf,
}

impl SpillFile {
    fn fresh() -> SpillFile {
        let seq = FILE_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = spill_dir().join(format!(
            "rc-spill-{}-{}.run",
            std::process::id(),
            seq
        ));
        SpillFile { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

// ---------------------------------------------------------------------------
// Run writer
// ---------------------------------------------------------------------------

fn dtype_tag(dt: DataType) -> u8 {
    match dt {
        DataType::Int64 => 0,
        DataType::Float64 => 1,
        DataType::Bool => 2,
        DataType::Utf8 => 3,
    }
}

fn tag_dtype(tag: u8) -> Option<DataType> {
    match tag {
        0 => Some(DataType::Int64),
        1 => Some(DataType::Float64),
        2 => Some(DataType::Bool),
        3 => Some(DataType::Utf8),
        _ => None,
    }
}

/// Writes a run: a sequence of schema-identical table blocks. Create with
/// the run's schema, feed blocks with [`RunWriter::write_table`], then
/// [`RunWriter::finish`] into a [`SpilledTable`] handle. Dropping an
/// unfinished writer deletes the partial file.
pub struct RunWriter {
    w: BufWriter<File>,
    file: SpillFile,
    schema: Schema,
    nrows: u64,
    mem_bytes: u64,
    file_bytes: u64,
    blocks: u32,
    started: Instant,
}

impl RunWriter {
    pub fn create(schema: Schema) -> Result<RunWriter> {
        let file = SpillFile::fresh();
        let w = BufWriter::new(File::create(file.path())?);
        Ok(RunWriter {
            w,
            file,
            schema,
            nrows: 0,
            mem_bytes: 0,
            file_bytes: 0,
            blocks: 0,
            started: Instant::now(),
        })
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// Rows written so far.
    pub fn num_rows(&self) -> u64 {
        self.nrows
    }

    /// Append one block. Empty tables are skipped (a run's schema is
    /// carried by the handle, not the file). The block is serialized
    /// window-aware: only the visible rows of each column travel.
    pub fn write_table(&mut self, t: &Table) -> Result<()> {
        if t.schema() != &self.schema {
            return Err(Error::DataFrame(format!(
                "spill: block schema mismatch: {} vs {}",
                t.schema(),
                self.schema
            )));
        }
        if t.num_rows() == 0 {
            return Ok(());
        }
        let mut written = 0u64;
        let mut buf = [0u8; 16];
        buf[..4].copy_from_slice(&MAGIC.to_le_bytes());
        buf[4..8].copy_from_slice(&(t.num_columns() as u32).to_le_bytes());
        buf[8..16].copy_from_slice(&(t.num_rows() as u64).to_le_bytes());
        self.w.write_all(&buf)?;
        written += 16;
        for col in t.columns() {
            let payload = serialize_column(col);
            self.w.write_all(&[dtype_tag(col.dtype())])?;
            self.w.write_all(&(payload.len() as u64).to_le_bytes())?;
            self.w.write_all(&payload)?;
            self.w.write_all(&crc32(&payload).to_le_bytes())?;
            written += 1 + 8 + payload.len() as u64 + 4;
        }
        self.nrows += t.num_rows() as u64;
        self.mem_bytes += t.byte_size() as u64;
        self.file_bytes += written;
        self.blocks += 1;
        spill_metrics::record_spilled(t.byte_size() as u64);
        Ok(())
    }

    /// Flush and seal the run, returning the disk-backed handle.
    pub fn finish(mut self) -> Result<SpilledTable> {
        self.w.flush()?;
        spill_metrics::record_run();
        spill_metrics::record_spill_nanos(
            self.started.elapsed().as_nanos() as u64
        );
        Ok(SpilledTable {
            file: Arc::new(self.file),
            schema: self.schema,
            nrows: self.nrows as usize,
            mem_bytes: self.mem_bytes as usize,
            file_bytes: self.file_bytes,
            blocks: self.blocks,
        })
    }
}

/// Serialize one column's visible window into a payload buffer.
fn serialize_column(col: &Column) -> Vec<u8> {
    match col {
        Column::Int64(v) => {
            let s = v.as_slice();
            let mut out = Vec::with_capacity(s.len() * 8);
            for &x in s {
                out.extend_from_slice(&x.to_le_bytes());
            }
            out
        }
        Column::Float64(v) => {
            let s = v.as_slice();
            let mut out = Vec::with_capacity(s.len() * 8);
            for &x in s {
                // Raw bit pattern: NaNs round-trip bit-identically.
                out.extend_from_slice(&x.to_bits().to_le_bytes());
            }
            out
        }
        Column::Bool(v) => v.as_slice().iter().map(|&b| b as u8).collect(),
        Column::Utf8(v) => {
            let mut out =
                Vec::with_capacity(v.len() * 4 + v.str_bytes());
            for s in v.iter() {
                out.extend_from_slice(&(s.len() as u32).to_le_bytes());
            }
            for s in v.iter() {
                out.extend_from_slice(s.as_bytes());
            }
            out
        }
    }
}

fn deserialize_column(
    dt: DataType,
    nrows: usize,
    payload: &[u8],
) -> Result<Column> {
    let bad = |what: &str| {
        Err(Error::DataFrame(format!(
            "spill: corrupt {dt} payload ({what}; {} bytes, {nrows} rows)",
            payload.len()
        )))
    };
    match dt {
        DataType::Int64 => {
            if payload.len() != nrows * 8 {
                return bad("length");
            }
            let v: Vec<i64> = payload
                .chunks_exact(8)
                .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Column::from_i64(v))
        }
        DataType::Float64 => {
            if payload.len() != nrows * 8 {
                return bad("length");
            }
            let v: Vec<f64> = payload
                .chunks_exact(8)
                .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
                .collect();
            Ok(Column::from_f64(v))
        }
        DataType::Bool => {
            if payload.len() != nrows {
                return bad("length");
            }
            Ok(Column::from_bool(payload.iter().map(|&b| b != 0).collect()))
        }
        DataType::Utf8 => {
            if payload.len() < nrows * 4 {
                return bad("offset header");
            }
            let (lens, mut rest) = payload.split_at(nrows * 4);
            let mut b = Utf8Builder::with_capacity(
                nrows,
                payload.len() - nrows * 4,
            );
            for c in lens.chunks_exact(4) {
                let len = u32::from_le_bytes(c.try_into().unwrap()) as usize;
                if rest.len() < len {
                    return bad("string bytes");
                }
                let (s, tail) = rest.split_at(len);
                let s = std::str::from_utf8(s).map_err(|_| {
                    Error::DataFrame("spill: non-utf8 string payload".into())
                })?;
                b.push(s);
                rest = tail;
            }
            if !rest.is_empty() {
                return bad("trailing bytes");
            }
            Ok(Column::Utf8(b.finish()))
        }
    }
}

// ---------------------------------------------------------------------------
// Run reader
// ---------------------------------------------------------------------------

/// Streams a run's blocks back as [`Table`]s, validating magic, arity,
/// dtype tags, and per-column CRCs. Holds the file alive via its
/// `Arc<SpillFile>`.
pub struct RunReader {
    r: BufReader<File>,
    schema: Schema,
    _file: Arc<SpillFile>,
}

impl RunReader {
    fn open(file: Arc<SpillFile>, schema: Schema) -> Result<RunReader> {
        let r = BufReader::new(File::open(file.path())?);
        Ok(RunReader { r, schema, _file: file })
    }

    /// The next block, or `None` at a clean end-of-run.
    pub fn next_block(&mut self) -> Result<Option<Table>> {
        let mut head = [0u8; 16];
        match read_exact_or_eof(&mut self.r, &mut head)? {
            false => return Ok(None),
            true => {}
        }
        let magic = u32::from_le_bytes(head[..4].try_into().unwrap());
        if magic != MAGIC {
            return Err(Error::DataFrame(format!(
                "spill: bad block magic {magic:#x}"
            )));
        }
        let ncols = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
        let nrows = u64::from_le_bytes(head[8..16].try_into().unwrap()) as usize;
        if ncols != self.schema.len() {
            return Err(Error::DataFrame(format!(
                "spill: block has {ncols} columns, schema {} expects {}",
                self.schema,
                self.schema.len()
            )));
        }
        let mut cols = Vec::with_capacity(ncols);
        for i in 0..ncols {
            let mut tag = [0u8; 1];
            self.r.read_exact(&mut tag)?;
            let dt = tag_dtype(tag[0]).ok_or_else(|| {
                Error::DataFrame(format!("spill: unknown dtype tag {}", tag[0]))
            })?;
            let expect = self.schema.field(i).dtype;
            if dt != expect {
                return Err(Error::DataFrame(format!(
                    "spill: column {i} is {dt}, schema expects {expect}"
                )));
            }
            let mut len = [0u8; 8];
            self.r.read_exact(&mut len)?;
            let len = u64::from_le_bytes(len) as usize;
            let mut payload = vec![0u8; len];
            self.r.read_exact(&mut payload)?;
            let mut crc = [0u8; 4];
            self.r.read_exact(&mut crc)?;
            if u32::from_le_bytes(crc) != crc32(&payload) {
                return Err(Error::DataFrame(format!(
                    "spill: CRC mismatch on column {i}"
                )));
            }
            cols.push(deserialize_column(dt, nrows, &payload)?);
        }
        let t = Table::new(self.schema.clone(), cols)?;
        spill_metrics::record_restored(t.byte_size() as u64);
        Ok(Some(t))
    }
}

/// `Ok(true)` when `buf` was filled, `Ok(false)` on EOF before the first
/// byte; a partial read mid-buffer is a corruption error.
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Result<bool> {
    let mut got = 0usize;
    while got < buf.len() {
        let n = r.read(&mut buf[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(false);
            }
            return Err(Error::DataFrame(
                "spill: truncated block header".into(),
            ));
        }
        got += n;
    }
    Ok(true)
}

// ---------------------------------------------------------------------------
// Spilled tables
// ---------------------------------------------------------------------------

/// A table whose rows live in a spill run on disk. Schema and sizes are
/// resident metadata; [`SpilledTable::restore`] reads the rows back
/// (bit-identical to what was written) and
/// [`SpilledTable::fingerprint_streamed`] folds the content fingerprint
/// one block at a time without ever holding more than one block.
#[derive(Clone, Debug)]
pub struct SpilledTable {
    file: Arc<SpillFile>,
    schema: Schema,
    nrows: usize,
    mem_bytes: usize,
    file_bytes: u64,
    blocks: u32,
}

impl SpilledTable {
    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn num_rows(&self) -> usize {
        self.nrows
    }

    /// In-memory payload bytes of the original visible windows — what
    /// restoring will materialize.
    pub fn byte_size(&self) -> usize {
        self.mem_bytes
    }

    /// Bytes the run occupies on disk (headers + payloads + CRCs).
    pub fn file_bytes(&self) -> u64 {
        self.file_bytes
    }

    pub fn num_blocks(&self) -> u32 {
        self.blocks
    }

    /// Stream the run block by block.
    pub fn reader(&self) -> Result<RunReader> {
        RunReader::open(self.file.clone(), self.schema.clone())
    }

    /// Read the whole run back into one contiguous table.
    pub fn restore(&self) -> Result<Table> {
        let mut r = self.reader()?;
        let mut parts = Vec::new();
        while let Some(t) = r.next_block()? {
            parts.push(t);
        }
        match parts.len() {
            0 => Ok(Table::empty(self.schema.clone())),
            1 => Ok(parts.pop().expect("one part")),
            _ => Table::concat(&parts),
        }
    }

    /// Order-insensitive content fingerprint, folded one block at a time
    /// ([`Table::multiset_fingerprint`] is additive over disjoint row
    /// sets) — never holds more than one block in RAM.
    pub fn fingerprint_streamed(&self) -> Result<u64> {
        let mut r = self.reader()?;
        let mut acc = 0u64;
        while let Some(t) = r.next_block()? {
            acc = acc.wrapping_add(t.multiset_fingerprint());
        }
        Ok(acc)
    }
}

/// Spill one table as a single-block run.
pub fn spill_table(t: &Table) -> Result<SpilledTable> {
    let mut w = RunWriter::create(t.schema().clone())?;
    w.write_table(t)?;
    w.finish()
}

// ---------------------------------------------------------------------------
// CRC-32 (IEEE), nibble-table variant — zero dependencies
// ---------------------------------------------------------------------------

const CRC_TABLE: [u32; 16] = {
    let mut table = [0u32; 16];
    let mut i = 0;
    while i < 16 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 4 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 (IEEE 802.3 polynomial) over `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in data {
        crc = (crc >> 4) ^ CRC_TABLE[((crc ^ b as u32) & 0xF) as usize];
        crc = (crc >> 4) ^ CRC_TABLE[((crc ^ (b as u32 >> 4)) & 0xF) as usize];
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::spill as m;

    fn mixed_table(n: usize) -> Table {
        let keys: Vec<i64> = (0..n as i64).map(|i| i * 37 % 101 - 50).collect();
        let vals: Vec<f64> = (0..n)
            .map(|i| if i % 7 == 0 { f64::NAN } else { i as f64 * 0.5 })
            .collect();
        let strs: Vec<String> =
            (0..n).map(|i| if i % 3 == 0 { String::new() } else { format!("s{i}") }).collect();
        let bools: Vec<bool> = (0..n).map(|i| i % 2 == 0).collect();
        Table::new(
            Schema::of(&[
                ("k", DataType::Int64),
                ("f", DataType::Float64),
                ("s", DataType::Utf8),
                ("b", DataType::Bool),
            ]),
            vec![
                Column::from_i64(keys),
                Column::from_f64(vals),
                Column::from_utf8(&strs),
                Column::from_bool(bools),
            ],
        )
        .unwrap()
    }

    /// Bit-level equality (PartialEq treats NaN != NaN; compare bits).
    fn bits_equal(a: &Table, b: &Table) -> bool {
        if a.schema() != b.schema() || a.num_rows() != b.num_rows() {
            return false;
        }
        for j in 0..a.num_columns() {
            for i in 0..a.num_rows() {
                if a.column(j).value_hash(i) != b.column(j).value_hash(i) {
                    return false;
                }
            }
        }
        true
    }

    #[test]
    fn parse_byte_sizes() {
        assert_eq!(parse_byte_size("4096"), Some(4096));
        assert_eq!(parse_byte_size("64K"), Some(64 << 10));
        assert_eq!(parse_byte_size("256M"), Some(256 << 20));
        assert_eq!(parse_byte_size("1G"), Some(1 << 30));
        assert_eq!(parse_byte_size("2tb"), Some(2u64 << 40));
        assert_eq!(parse_byte_size(" 8 MB "), Some(8 << 20));
        assert_eq!(parse_byte_size(""), None);
        assert_eq!(parse_byte_size("x12"), None);
        assert_eq!(parse_byte_size("12Q"), None);
    }

    #[test]
    fn budget_reserve_release_peak() {
        let b = MemoryBudget::new(100);
        assert_eq!(b.limit(), Some(100));
        assert!(b.would_fit(100));
        {
            let mut r = b.reserve(60);
            assert_eq!(b.in_use(), 60);
            assert_eq!(b.headroom(), 40);
            assert!(b.try_reserve(50).is_none(), "over limit must refuse");
            let r2 = b.try_reserve(40).expect("fits exactly");
            assert_eq!(b.in_use(), 100);
            drop(r2);
            r.grow(70); // overdraft allowed, recorded in peak
            assert_eq!(b.in_use(), 130);
            r.shrink(100);
            assert_eq!(b.in_use(), 30);
        }
        assert_eq!(b.in_use(), 0);
        assert_eq!(b.peak(), 130);
        b.reset_peak();
        assert_eq!(b.peak(), 0);
        // Unbounded never refuses.
        let u = MemoryBudget::unbounded();
        assert_eq!(u.limit(), None);
        assert!(u.try_reserve(u64::MAX / 2).is_some());
        assert_eq!(u.headroom(), u64::MAX);
    }

    #[test]
    fn crc32_known_vector() {
        // The classic IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn run_round_trips_bit_identically() {
        let t = mixed_table(500);
        let before = m::snapshot();
        let st = spill_table(&t).unwrap();
        assert_eq!(st.num_rows(), 500);
        assert_eq!(st.byte_size(), t.byte_size());
        assert!(st.file_bytes() > 0);
        let back = st.restore().unwrap();
        assert!(bits_equal(&t, &back), "restore must be bit-identical");
        assert_eq!(
            st.fingerprint_streamed().unwrap(),
            t.multiset_fingerprint()
        );
        let d = m::snapshot().since(before);
        assert!(d.bytes_spilled >= t.byte_size() as u64);
        assert!(d.bytes_restored >= t.byte_size() as u64);
        assert!(d.runs >= 1);
    }

    #[test]
    fn multi_block_runs_stream_in_order() {
        let t = mixed_table(300);
        let mut w = RunWriter::create(t.schema().clone()).unwrap();
        for start in (0..300).step_by(100) {
            w.write_table(&t.slice(start, 100)).unwrap();
        }
        assert_eq!(w.num_rows(), 300);
        let st = w.finish().unwrap();
        assert_eq!(st.num_blocks(), 3);
        let mut r = st.reader().unwrap();
        let mut rows = 0usize;
        while let Some(block) = r.next_block().unwrap() {
            assert!(bits_equal(&t.slice(rows, block.num_rows()), &block));
            rows += block.num_rows();
        }
        assert_eq!(rows, 300);
        // Blocks concatenated == original.
        assert!(bits_equal(&st.restore().unwrap(), &t));
    }

    #[test]
    fn empty_and_sliced_tables_round_trip() {
        let t = mixed_table(10);
        let empty = t.slice(0, 0);
        let st = spill_table(&empty).unwrap();
        assert_eq!(st.num_rows(), 0);
        assert_eq!(st.restore().unwrap().num_rows(), 0);
        // A mid-table window serializes only its visible rows.
        let win = t.slice(3, 4);
        let st = spill_table(&win).unwrap();
        assert!(bits_equal(&st.restore().unwrap(), &win));
    }

    #[test]
    fn corruption_is_detected() {
        let t = mixed_table(64);
        let st = spill_table(&t).unwrap();
        // Flip one payload byte (first i64 column byte, after the 16-byte
        // block header + 9-byte column header).
        let path = st.file.path().to_path_buf();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[16 + 9] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = st.restore().unwrap_err().to_string();
        assert!(err.contains("CRC mismatch"), "{err}");
        // Truncation is a typed error too.
        bytes.truncate(bytes.len() - 3);
        std::fs::write(&path, &bytes).unwrap();
        assert!(st.restore().is_err());
    }

    #[test]
    fn schema_is_validated_on_write_and_read() {
        let t = mixed_table(8);
        let mut w = RunWriter::create(t.schema().clone()).unwrap();
        let other = Table::new(
            Schema::of(&[("x", DataType::Int64)]),
            vec![Column::from_i64(vec![1])],
        )
        .unwrap();
        assert!(w.write_table(&other).is_err());
        w.write_table(&t).unwrap();
        let st = w.finish().unwrap();
        // Reading under a wrong schema fails fast on arity/tag checks.
        let wrong = RunReader::open(
            st.file.clone(),
            Schema::of(&[("x", DataType::Int64)]),
        )
        .unwrap();
        let mut wrong = wrong;
        assert!(wrong.next_block().is_err());
    }

    #[test]
    fn spill_file_deleted_on_drop() {
        let t = mixed_table(4);
        let st = spill_table(&t).unwrap();
        let path = st.file.path().to_path_buf();
        assert!(path.exists());
        drop(st);
        assert!(!path.exists(), "temp run must be deleted with its handle");
    }
}
