//! Synthetic workload generation — the stand-in for the paper's 35 M/3.5 B
//! row datasets (DESIGN.md §2 substitution log). Deterministic per
//! (seed, rank) so every execution mode sees identical data.

use crate::util::rng::Rng;

use super::column::{Column, DataType};
use super::schema::Schema;
use super::table::Table;

/// Key distribution for generated tables.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KeyDist {
    /// Uniform over `[0, key_space)`.
    Uniform,
    /// Zipf-ish skew (power-law over the key space) — stresses shuffle
    /// imbalance the way real joins do.
    Skewed { exponent: f64 },
    /// Sequential keys (pre-sorted input edge case).
    Sequential,
}

/// Generation spec for one rank's partition.
#[derive(Clone, Debug)]
pub struct GenSpec {
    pub rows: usize,
    /// Number of distinct keys to draw from (controls join hit rate).
    pub key_space: i64,
    pub dist: KeyDist,
    pub seed: u64,
}

impl GenSpec {
    pub fn uniform(rows: usize, key_space: i64, seed: u64) -> GenSpec {
        GenSpec { rows, key_space, dist: KeyDist::Uniform, seed }
    }

    /// Schema every generated partition carries: `(key: int64, val:
    /// float64)`. The plan optimizer uses this to propagate schemas
    /// through `generate` sources without running them.
    pub fn schema() -> Schema {
        Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)])
    }
}

/// Standard two-column table `(key: int64, val: float64)` — the shape the
/// paper's join/sort micro-benchmarks use.
pub fn gen_table(spec: &GenSpec, rank: usize) -> Table {
    // Mix rank into the seed so partitions are independent but reproducible.
    let mut rng = Rng::new(spec.seed ^ crate::util::hash::splitmix64(rank as u64));
    let mut keys = Vec::with_capacity(spec.rows);
    match spec.dist {
        KeyDist::Uniform => {
            for _ in 0..spec.rows {
                keys.push(rng.gen_i64(0, spec.key_space.max(1)));
            }
        }
        KeyDist::Skewed { exponent } => {
            // k = floor(ks * u^exponent): for exponent > 1 the mass
            // concentrates near key 0 (power-law-ish head-heavy skew).
            let ks = spec.key_space.max(2) as f64;
            for _ in 0..spec.rows {
                let u = rng.gen_f64();
                let k = (ks * u.powf(exponent)) as i64;
                keys.push(k.clamp(0, spec.key_space - 1));
            }
        }
        KeyDist::Sequential => {
            let base = rank as i64 * spec.rows as i64;
            for i in 0..spec.rows {
                keys.push(base + i as i64);
            }
        }
    }
    let vals: Vec<f64> = (0..spec.rows).map(|_| rng.gen_f64()).collect();
    Table::new(
        GenSpec::schema(),
        vec![Column::from_i64(keys), Column::from_f64(vals)],
    )
    .expect("generated table is well-formed")
}

/// Left/right tables for a join with overlapping key spaces.
pub fn gen_two_tables(spec: &GenSpec, rank: usize) -> (Table, Table) {
    let left = gen_table(spec, rank);
    let right_spec = GenSpec { seed: spec.seed.wrapping_add(0x5eed), ..spec.clone() };
    let right = gen_table(&right_spec, rank);
    (left, right)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_rank() {
        let spec = GenSpec::uniform(100, 1000, 7);
        assert_eq!(gen_table(&spec, 3), gen_table(&spec, 3));
        assert_ne!(gen_table(&spec, 3), gen_table(&spec, 4));
    }

    #[test]
    fn keys_in_range() {
        let spec = GenSpec::uniform(1000, 50, 1);
        let t = gen_table(&spec, 0);
        for &k in t.column(0).as_i64().unwrap() {
            assert!((0..50).contains(&k));
        }
    }

    #[test]
    fn skewed_is_skewed() {
        let spec = GenSpec {
            rows: 20_000,
            key_space: 1000,
            dist: KeyDist::Skewed { exponent: 1.5 },
            seed: 2,
        };
        let t = gen_table(&spec, 0);
        let keys = t.column(0).as_i64().unwrap();
        let low = keys.iter().filter(|&&k| k < 100).count();
        // Power-law: the low decile should hold far more than 10% of mass.
        assert!(low > keys.len() / 5, "low-decile count {low}");
        for &k in keys {
            assert!((0..1000).contains(&k));
        }
    }

    #[test]
    fn sequential_is_globally_unique() {
        let spec = GenSpec {
            rows: 10,
            key_space: i64::MAX,
            dist: KeyDist::Sequential,
            seed: 0,
        };
        let a = gen_table(&spec, 0);
        let b = gen_table(&spec, 1);
        assert_eq!(a.column(0).as_i64().unwrap()[9], 9);
        assert_eq!(b.column(0).as_i64().unwrap()[0], 10);
    }

    #[test]
    fn join_pair_overlaps() {
        let spec = GenSpec::uniform(500, 100, 3);
        let (l, r) = gen_two_tables(&spec, 0);
        assert_eq!(l.num_rows(), 500);
        assert_eq!(r.num_rows(), 500);
        assert_ne!(l, r);
    }
}
