//! [`ChunkedTable`]: a logical table made of row-disjoint chunks — the
//! zero-copy form of concat/gather, and the unit of out-of-core handoff.
//!
//! Shuffle receives, gathered pipeline outputs, and per-rank input
//! partitions are all naturally *lists* of tables. Historically every one
//! of those lists was immediately flattened with [`Table::concat`], deep-
//! copying each row once per hop. A `ChunkedTable` keeps the parts as
//! they arrived and defers the copy to [`ChunkedTable::compact`], which
//! runs only when an operator genuinely needs contiguous column access —
//! and is skipped entirely when the view already has a single chunk.
//!
//! Since the spill subsystem landed, a chunk is a [`Chunk`]: either
//! resident ([`Chunk::Ram`], an `Arc`-backed [`Table`] view) or
//! disk-backed ([`Chunk::Spilled`], a [`SpilledTable`] run restored
//! lazily on first access and cached). Metadata — schema, row count,
//! byte size — is always resident, so admission control, the network
//! model, and slicing never touch disk. [`ChunkedTable::spill_over`]
//! converts resident chunks to spilled ones until the view fits a
//! [`MemoryBudget`]; content is unchanged, so every fingerprint and
//! ordering property is trivially preserved.
//!
//! Row order is chunk order then in-chunk order, so slicing by global row
//! index is well-defined and O(#chunks).
//!
//! **Lazy-restore failure policy:** infallible accessors ([`Chunk::table`],
//! [`ChunkedTable::compact`], [`ChunkedTable::multiset_fingerprint`])
//! panic if the spill run cannot be read back (deleted tmpdir, disk
//! corruption). The pipeline executor contains node panics to per-node
//! errors, so this surfaces as a failed task, not a crashed process.
//! Operators that want a typed error use [`Chunk::load`] /
//! [`ChunkedTable::load_chunk`].

use std::sync::{Arc, OnceLock};

use super::schema::Schema;
use super::table::Table;
use crate::error::{Error, Result};
use crate::spill::{spill_table, MemoryBudget, SpilledTable};

/// A disk-backed chunk: the run handle plus a lazy restore cache and
/// optional sort-key metadata (min/max of the run's key column, kept by
/// budgeted sort so distributed splitters can be chosen without restoring).
#[derive(Debug)]
pub struct SpilledChunk {
    spilled: SpilledTable,
    cache: OnceLock<Table>,
    key_range: Option<(i64, i64)>,
}

/// One chunk of a [`ChunkedTable`]: resident rows, or a spill run
/// restored lazily on first access.
#[derive(Clone, Debug)]
pub enum Chunk {
    /// Resident rows (an `Arc`-backed zero-copy view).
    Ram(Table),
    /// Rows living in a spill run; `Arc`-shared so clones and slices of
    /// the chunked view keep one cache and one temp file.
    Spilled(Arc<SpilledChunk>),
}

impl Chunk {
    /// Wrap a spill run as a chunk.
    pub fn spilled(st: SpilledTable, key_range: Option<(i64, i64)>) -> Chunk {
        Chunk::Spilled(Arc::new(SpilledChunk {
            spilled: st,
            cache: OnceLock::new(),
            key_range,
        }))
    }

    pub fn schema(&self) -> &Schema {
        match self {
            Chunk::Ram(t) => t.schema(),
            Chunk::Spilled(s) => s.spilled.schema(),
        }
    }

    pub fn num_rows(&self) -> usize {
        match self {
            Chunk::Ram(t) => t.num_rows(),
            Chunk::Spilled(s) => s.spilled.num_rows(),
        }
    }

    /// Payload bytes of the chunk's visible window — resident metadata
    /// for both variants (never restores).
    pub fn byte_size(&self) -> usize {
        match self {
            Chunk::Ram(t) => t.byte_size(),
            Chunk::Spilled(s) => s.spilled.byte_size(),
        }
    }

    /// Bytes this chunk holds in RAM right now. Spilled chunks report 0
    /// even when a lazy restore has populated their cache: the governor
    /// charges restores at the access site (reservations), not here, so
    /// spill decisions stay stable.
    pub fn resident_bytes(&self) -> usize {
        match self {
            Chunk::Ram(t) => t.byte_size(),
            Chunk::Spilled(_) => 0,
        }
    }

    pub fn is_spilled(&self) -> bool {
        matches!(self, Chunk::Spilled(_))
    }

    /// Sort-key min/max metadata, if the producer recorded it.
    pub fn key_range(&self) -> Option<(i64, i64)> {
        match self {
            Chunk::Ram(_) => None,
            Chunk::Spilled(s) => s.key_range,
        }
    }

    /// The underlying spill run, when disk-backed (streaming access).
    pub fn spilled_table(&self) -> Option<&SpilledTable> {
        match self {
            Chunk::Spilled(s) => Some(&s.spilled),
            Chunk::Ram(_) => None,
        }
    }

    /// Resident access: restores a spilled chunk on first call and caches
    /// the result for the chunk's lifetime. Panics on spill-read failure
    /// (see module docs); use [`Chunk::load`] for a typed error.
    pub fn table(&self) -> &Table {
        match self {
            Chunk::Ram(t) => t,
            Chunk::Spilled(s) => s.cache.get_or_init(|| {
                s.spilled.restore().expect("restore spilled chunk")
            }),
        }
    }

    /// Non-caching access: clones a resident chunk's view (cheap `Arc`
    /// bumps) or restores a spilled chunk **without** populating the
    /// cache — the caller's copy is freed when dropped, so streaming
    /// consumers never pin more than the chunk in flight.
    pub fn load(&self) -> Result<Table> {
        match self {
            Chunk::Ram(t) => Ok(t.clone()),
            Chunk::Spilled(s) => match s.cache.get() {
                Some(t) => Ok(t.clone()),
                None => s.spilled.restore(),
            },
        }
    }

    /// Owning form of [`Chunk::table`] (no clone for resident chunks).
    pub fn into_table(self) -> Table {
        match self {
            Chunk::Ram(t) => t,
            Chunk::Spilled(s) => match s.cache.get() {
                Some(t) => t.clone(),
                None => s.spilled.restore().expect("restore spilled chunk"),
            },
        }
    }

    /// Order-insensitive content fingerprint; uncached spilled chunks
    /// stream block-by-block instead of restoring.
    pub fn multiset_fingerprint(&self) -> u64 {
        match self {
            Chunk::Ram(t) => t.multiset_fingerprint(),
            Chunk::Spilled(s) => match s.cache.get() {
                Some(t) => t.multiset_fingerprint(),
                None => s
                    .spilled
                    .fingerprint_streamed()
                    .expect("fingerprint spilled chunk"),
            },
        }
    }
}

impl From<Table> for Chunk {
    fn from(t: Table) -> Chunk {
        Chunk::Ram(t)
    }
}

/// Row-disjoint chunks sharing one schema; concat deferred until needed.
#[derive(Clone, Debug, Default)]
pub struct ChunkedTable {
    schema: Schema,
    chunks: Vec<Chunk>,
    nrows: usize,
}

impl ChunkedTable {
    /// Empty chunked table with the given schema.
    pub fn empty(schema: Schema) -> ChunkedTable {
        ChunkedTable { schema, chunks: Vec::new(), nrows: 0 }
    }

    /// Adopt a list of schema-identical tables as chunks (zero-copy: the
    /// parts are moved, not flattened).
    pub fn from_tables(parts: Vec<Table>) -> Result<ChunkedTable> {
        let Some(first) = parts.first() else {
            return Err(Error::DataFrame("chunked table of zero parts".into()));
        };
        let schema = first.schema().clone();
        ChunkedTable::from_chunk_list(
            schema,
            parts.into_iter().map(Chunk::Ram).collect(),
        )
    }

    /// Adopt a list of chunks (resident or spilled) under an explicit
    /// schema — the out-of-core constructor (an empty list is fine, the
    /// schema travels separately).
    pub fn from_chunk_list(
        schema: Schema,
        chunks: Vec<Chunk>,
    ) -> Result<ChunkedTable> {
        let mut nrows = 0;
        for c in &chunks {
            if c.schema() != &schema {
                return Err(Error::DataFrame(format!(
                    "chunk schema mismatch: {} vs {}",
                    c.schema(),
                    schema
                )));
            }
            nrows += c.num_rows();
        }
        Ok(ChunkedTable { schema, chunks, nrows })
    }

    /// Append one resident chunk (zero-copy).
    pub fn push(&mut self, t: Table) -> Result<()> {
        self.push_chunk(Chunk::Ram(t))
    }

    /// Append one chunk, resident or spilled.
    pub fn push_chunk(&mut self, c: Chunk) -> Result<()> {
        if self.chunks.is_empty() && self.schema.is_empty() {
            self.schema = c.schema().clone();
        } else if c.schema() != &self.schema {
            return Err(Error::DataFrame(format!(
                "chunk schema mismatch: {} vs {}",
                c.schema(),
                self.schema
            )));
        }
        self.nrows += c.num_rows();
        self.chunks.push(c);
        Ok(())
    }

    /// Append a spill run as a disk-backed chunk.
    pub fn push_spilled(
        &mut self,
        st: SpilledTable,
        key_range: Option<(i64, i64)>,
    ) -> Result<()> {
        self.push_chunk(Chunk::spilled(st, key_range))
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn num_rows(&self) -> usize {
        self.nrows
    }

    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Resident access to every chunk (restores and caches spilled ones —
    /// use [`ChunkedTable::chunk_list`] / [`ChunkedTable::load_chunk`] on
    /// the out-of-core path).
    pub fn chunks(&self) -> Vec<&Table> {
        self.chunks.iter().map(|c| c.table()).collect()
    }

    /// The chunk list itself — metadata-only, never restores.
    pub fn chunk_list(&self) -> &[Chunk] {
        &self.chunks
    }

    /// Resident access to chunk `i` (restores + caches if spilled).
    pub fn chunk(&self, i: usize) -> &Table {
        self.chunks[i].table()
    }

    /// Non-caching load of chunk `i` (see [`Chunk::load`]).
    pub fn load_chunk(&self, i: usize) -> Result<Table> {
        self.chunks[i].load()
    }

    pub fn is_empty(&self) -> bool {
        self.nrows == 0
    }

    /// O(#chunks) zero-copy row window `[start, start+len)`: fully
    /// covered chunks are kept as-is (spilled ones stay on disk, sharing
    /// the run), partially covered ones are sliced (restoring a spilled
    /// boundary chunk if needed), non-overlapping ones dropped.
    pub fn slice(&self, start: usize, len: usize) -> ChunkedTable {
        assert!(
            start + len <= self.nrows,
            "chunked slice [{start}, {start}+{len}) out of {} rows",
            self.nrows
        );
        let mut out = Vec::new();
        let mut skip = start;
        let mut want = len;
        for c in &self.chunks {
            let n = c.num_rows();
            if skip >= n {
                skip -= n;
                continue;
            }
            if want == 0 {
                break;
            }
            let take = (n - skip).min(want);
            if skip == 0 && take == n {
                out.push(c.clone());
            } else {
                let t = c.load().expect("restore spilled chunk");
                out.push(Chunk::Ram(t.slice(skip, take)));
            }
            want -= take;
            skip = 0;
        }
        ChunkedTable { schema: self.schema.clone(), chunks: out, nrows: len }
    }

    /// Contiguous form. Zero-copy when a single resident chunk already is
    /// the whole view (column `Arc` clones); otherwise materializes.
    pub fn compact(&self) -> Table {
        match self.chunks.len() {
            0 => Table::empty(self.schema.clone()),
            1 => self.chunks[0].load().expect("restore spilled chunk"),
            _ => {
                let parts: Vec<Table> = self
                    .chunks
                    .iter()
                    .map(|c| c.load().expect("restore spilled chunk"))
                    .collect();
                Table::concat(&parts).expect("chunk schemas validated")
            }
        }
    }

    /// Take ownership of the chunk list as resident tables (restores
    /// spilled chunks; legacy callers — the out-of-core path uses
    /// [`ChunkedTable::into_chunk_list`]).
    pub fn into_chunks(self) -> Vec<Table> {
        self.chunks.into_iter().map(Chunk::into_table).collect()
    }

    /// Take ownership of the chunk list without restoring anything.
    pub fn into_chunk_list(self) -> Vec<Chunk> {
        self.chunks
    }

    /// Consuming [`ChunkedTable::compact`] (skips the clone on the
    /// single-resident-chunk fast path).
    pub fn into_table(mut self) -> Table {
        match self.chunks.len() {
            0 => Table::empty(self.schema),
            1 => self.chunks.pop().expect("one chunk").into_table(),
            _ => self.compact(),
        }
    }

    /// Payload bytes of all visible windows, resident or not (drives the
    /// network model; resident metadata, never restores).
    pub fn byte_size(&self) -> usize {
        self.chunks.iter().map(|c| c.byte_size()).sum()
    }

    /// Bytes currently held in RAM (spilled chunks count 0).
    pub fn resident_bytes(&self) -> usize {
        self.chunks.iter().map(|c| c.resident_bytes()).sum()
    }

    /// Convert resident chunks to spilled ones, front to back, until the
    /// resident footprint fits `budget` (no-op when unbounded). Content
    /// and chunk order are unchanged — only residency moves — so every
    /// fingerprint/order property is preserved by construction. Returns
    /// the bytes moved to disk.
    pub fn spill_over(&mut self, budget: &MemoryBudget) -> Result<u64> {
        let Some(limit) = budget.limit() else {
            return Ok(0);
        };
        let mut resident: u64 = self.resident_bytes() as u64;
        let mut moved = 0u64;
        for c in self.chunks.iter_mut() {
            if resident <= limit {
                break;
            }
            if let Chunk::Ram(t) = c {
                let bytes = t.byte_size() as u64;
                if bytes == 0 {
                    continue;
                }
                let st = spill_table(t)?;
                *c = Chunk::spilled(st, None);
                resident -= bytes;
                moved += bytes;
            }
        }
        Ok(moved)
    }

    /// Order-insensitive content fingerprint. [`Table::multiset_fingerprint`]
    /// is additive over disjoint row sets, so summing per-chunk values
    /// equals the compacted table's fingerprint. Uncached spilled chunks
    /// are streamed, never restored whole.
    pub fn multiset_fingerprint(&self) -> u64 {
        self.chunks
            .iter()
            .fold(0u64, |acc, c| acc.wrapping_add(c.multiset_fingerprint()))
    }
}

impl From<Table> for ChunkedTable {
    fn from(t: Table) -> ChunkedTable {
        let schema = t.schema().clone();
        let nrows = t.num_rows();
        ChunkedTable { schema, chunks: vec![Chunk::Ram(t)], nrows }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::df::{Column, DataType};

    fn t(keys: Vec<i64>) -> Table {
        let n = keys.len();
        Table::new(
            Schema::of(&[("k", DataType::Int64), ("v", DataType::Float64)]),
            vec![Column::from_i64(keys), Column::from_f64(vec![0.5; n])],
        )
        .unwrap()
    }

    fn keys_of(table: &Table) -> Vec<i64> {
        table.column(0).as_i64().unwrap().to_vec()
    }

    #[test]
    fn from_tables_and_compact() {
        let ct =
            ChunkedTable::from_tables(vec![t(vec![1, 2]), t(vec![]), t(vec![3])])
                .unwrap();
        assert_eq!(ct.num_rows(), 3);
        assert_eq!(ct.num_chunks(), 3);
        let flat = ct.compact();
        assert_eq!(keys_of(&flat), vec![1, 2, 3]);
        assert_eq!(ct.multiset_fingerprint(), flat.multiset_fingerprint());
        assert_eq!(ct.byte_size(), flat.byte_size());
    }

    #[test]
    fn single_chunk_compact_shares_buffers() {
        let table = t(vec![7, 8, 9]);
        let ct = ChunkedTable::from(table.clone());
        let back = ct.compact();
        assert!(back.column(0).shares_buffer(table.column(0)));
        let owned = ct.into_table();
        assert!(owned.column(0).shares_buffer(table.column(0)));
    }

    #[test]
    fn slice_crosses_chunk_boundaries_without_copying() {
        let ct = ChunkedTable::from_tables(vec![
            t(vec![0, 1, 2]),
            t(vec![3, 4]),
            t(vec![5, 6, 7]),
        ])
        .unwrap();
        let mid = ct.slice(2, 4); // rows 2..6 span all three chunks
        assert_eq!(mid.num_rows(), 4);
        assert_eq!(keys_of(&mid.compact()), vec![2, 3, 4, 5]);
        // Each produced chunk is a view over the original chunk's buffer.
        assert!(mid.chunks()[0]
            .column(0)
            .shares_buffer(ct.chunks()[0].column(0)));
        // Edge windows.
        assert_eq!(ct.slice(0, 0).num_rows(), 0);
        assert_eq!(keys_of(&ct.slice(7, 1).compact()), vec![7]);
    }

    #[test]
    fn push_validates_schema() {
        let mut ct = ChunkedTable::from(t(vec![1]));
        assert!(ct.push(t(vec![2])).is_ok());
        let other = Table::empty(Schema::of(&[("x", DataType::Bool)]));
        assert!(ct.push(other).is_err());
        assert_eq!(ct.num_rows(), 2);
    }

    #[test]
    fn empty_cases() {
        assert!(ChunkedTable::from_tables(vec![]).is_err());
        let e = ChunkedTable::empty(t(vec![]).schema().clone());
        assert_eq!(e.num_rows(), 0);
        assert_eq!(e.compact().num_rows(), 0);
        assert_eq!(e.multiset_fingerprint(), 0);
        // The chunk-list constructor accepts an empty list.
        let e2 =
            ChunkedTable::from_chunk_list(t(vec![]).schema().clone(), vec![])
                .unwrap();
        assert_eq!(e2.num_rows(), 0);
    }

    #[test]
    fn spilled_chunks_restore_lazily_and_identically() {
        let a = t(vec![1, 2, 3]);
        let b = t(vec![4, 5]);
        let mut ct = ChunkedTable::from(a.clone());
        ct.push_spilled(
            crate::spill::spill_table(&b).unwrap(),
            Some((4, 5)),
        )
        .unwrap();
        assert_eq!(ct.num_rows(), 5);
        assert_eq!(ct.byte_size(), a.byte_size() + b.byte_size());
        assert_eq!(ct.resident_bytes(), a.byte_size());
        assert!(ct.chunk_list()[1].is_spilled());
        assert_eq!(ct.chunk_list()[1].key_range(), Some((4, 5)));
        // Fingerprint streams the spilled chunk; equals the flat table's.
        let flat = Table::concat(&[a, b]).unwrap();
        assert_eq!(ct.multiset_fingerprint(), flat.multiset_fingerprint());
        // Resident access restores bit-identically.
        assert_eq!(keys_of(&ct.compact()), vec![1, 2, 3, 4, 5]);
        assert_eq!(keys_of(ct.chunk(1)), vec![4, 5]);
    }

    #[test]
    fn spill_over_moves_bytes_until_budget_fits() {
        let parts = vec![t(vec![1, 2]), t(vec![3, 4]), t(vec![5, 6])];
        let chunk_bytes = parts[0].byte_size() as u64;
        let mut ct = ChunkedTable::from_tables(parts).unwrap();
        let fp = ct.multiset_fingerprint();

        // Unbounded budget: no-op.
        let b = MemoryBudget::unbounded();
        assert_eq!(ct.spill_over(&b).unwrap(), 0);
        assert_eq!(ct.resident_bytes() as u64, 3 * chunk_bytes);

        // Budget of one chunk: two chunks move to disk, front first.
        let b = MemoryBudget::new(chunk_bytes);
        let moved = ct.spill_over(&b).unwrap();
        assert_eq!(moved, 2 * chunk_bytes);
        assert!(ct.chunk_list()[0].is_spilled());
        assert!(ct.chunk_list()[1].is_spilled());
        assert!(!ct.chunk_list()[2].is_spilled());
        assert!(ct.resident_bytes() as u64 <= chunk_bytes);
        // Content and order are untouched.
        assert_eq!(ct.multiset_fingerprint(), fp);
        assert_eq!(keys_of(&ct.compact()), vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn slice_keeps_covered_spilled_chunks_on_disk() {
        let mut ct = ChunkedTable::from(t(vec![0, 1]));
        ct.push_spilled(
            crate::spill::spill_table(&t(vec![2, 3, 4])).unwrap(),
            None,
        )
        .unwrap();
        // Rows 1..5: partial first chunk, whole (spilled) second chunk.
        let s = ct.slice(1, 4);
        assert_eq!(s.num_rows(), 4);
        assert!(!s.chunk_list()[0].is_spilled());
        assert!(s.chunk_list()[1].is_spilled(), "covered chunk stays on disk");
        assert_eq!(keys_of(&s.compact()), vec![1, 2, 3, 4]);
    }
}
