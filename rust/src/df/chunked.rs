//! [`ChunkedTable`]: a logical table made of row-disjoint [`Table`]
//! chunks — the zero-copy form of concat/gather.
//!
//! Shuffle receives, gathered pipeline outputs, and per-rank input
//! partitions are all naturally *lists* of tables. Historically every one
//! of those lists was immediately flattened with [`Table::concat`], deep-
//! copying each row once per hop. A `ChunkedTable` keeps the parts as
//! they arrived (each an `Arc`-backed view) and defers the copy to
//! [`ChunkedTable::compact`], which runs only when an operator genuinely
//! needs contiguous column access — and is skipped entirely when the view
//! already has a single chunk.
//!
//! Row order is chunk order then in-chunk order, so slicing by global row
//! index is well-defined and O(#chunks).

use super::schema::Schema;
use super::table::Table;
use crate::error::{Error, Result};

/// Row-disjoint chunks sharing one schema; concat deferred until needed.
#[derive(Clone, Debug, Default)]
pub struct ChunkedTable {
    schema: Schema,
    chunks: Vec<Table>,
    nrows: usize,
}

impl ChunkedTable {
    /// Empty chunked table with the given schema.
    pub fn empty(schema: Schema) -> ChunkedTable {
        ChunkedTable { schema, chunks: Vec::new(), nrows: 0 }
    }

    /// Adopt a list of schema-identical tables as chunks (zero-copy: the
    /// parts are moved, not flattened).
    pub fn from_tables(parts: Vec<Table>) -> Result<ChunkedTable> {
        let Some(first) = parts.first() else {
            return Err(Error::DataFrame("chunked table of zero parts".into()));
        };
        let schema = first.schema().clone();
        let mut nrows = 0;
        for p in &parts {
            if p.schema() != &schema {
                return Err(Error::DataFrame(format!(
                    "chunk schema mismatch: {} vs {}",
                    p.schema(),
                    schema
                )));
            }
            nrows += p.num_rows();
        }
        Ok(ChunkedTable { schema, chunks: parts, nrows })
    }

    /// Append one chunk (zero-copy).
    pub fn push(&mut self, t: Table) -> Result<()> {
        if self.chunks.is_empty() && self.schema.is_empty() {
            self.schema = t.schema().clone();
        } else if t.schema() != &self.schema {
            return Err(Error::DataFrame(format!(
                "chunk schema mismatch: {} vs {}",
                t.schema(),
                self.schema
            )));
        }
        self.nrows += t.num_rows();
        self.chunks.push(t);
        Ok(())
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn num_rows(&self) -> usize {
        self.nrows
    }

    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    pub fn chunks(&self) -> &[Table] {
        &self.chunks
    }

    pub fn is_empty(&self) -> bool {
        self.nrows == 0
    }

    /// O(#chunks) zero-copy row window `[start, start+len)`: overlapping
    /// chunks are sliced (views), non-overlapping ones dropped.
    pub fn slice(&self, start: usize, len: usize) -> ChunkedTable {
        assert!(
            start + len <= self.nrows,
            "chunked slice [{start}, {start}+{len}) out of {} rows",
            self.nrows
        );
        let mut out = Vec::new();
        let mut skip = start;
        let mut want = len;
        for c in &self.chunks {
            let n = c.num_rows();
            if skip >= n {
                skip -= n;
                continue;
            }
            if want == 0 {
                break;
            }
            let take = (n - skip).min(want);
            out.push(c.slice(skip, take));
            want -= take;
            skip = 0;
        }
        ChunkedTable { schema: self.schema.clone(), chunks: out, nrows: len }
    }

    /// Contiguous form. Zero-copy when a single chunk already is the whole
    /// view (column `Arc` clones); otherwise materializes one fresh table.
    pub fn compact(&self) -> Table {
        match self.chunks.len() {
            0 => Table::empty(self.schema.clone()),
            1 => self.chunks[0].clone(),
            _ => Table::concat(&self.chunks).expect("chunk schemas validated"),
        }
    }

    /// Take ownership of the chunk list (zero-copy; the schema is dropped,
    /// so an empty view yields an empty list).
    pub fn into_chunks(self) -> Vec<Table> {
        self.chunks
    }

    /// Consuming [`ChunkedTable::compact`] (skips the clone on the
    /// single-chunk fast path).
    pub fn into_table(mut self) -> Table {
        match self.chunks.len() {
            0 => Table::empty(self.schema),
            1 => self.chunks.pop().expect("one chunk"),
            _ => Table::concat(&self.chunks).expect("chunk schemas validated"),
        }
    }

    /// Payload bytes of all visible windows (drives the network model).
    pub fn byte_size(&self) -> usize {
        self.chunks.iter().map(|c| c.byte_size()).sum()
    }

    /// Order-insensitive content fingerprint. [`Table::multiset_fingerprint`]
    /// is additive over disjoint row sets, so summing per-chunk values
    /// equals the compacted table's fingerprint.
    pub fn multiset_fingerprint(&self) -> u64 {
        self.chunks
            .iter()
            .fold(0u64, |acc, c| acc.wrapping_add(c.multiset_fingerprint()))
    }
}

impl From<Table> for ChunkedTable {
    fn from(t: Table) -> ChunkedTable {
        let schema = t.schema().clone();
        let nrows = t.num_rows();
        ChunkedTable { schema, chunks: vec![t], nrows }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::df::{Column, DataType};

    fn t(keys: Vec<i64>) -> Table {
        let n = keys.len();
        Table::new(
            Schema::of(&[("k", DataType::Int64), ("v", DataType::Float64)]),
            vec![Column::from_i64(keys), Column::from_f64(vec![0.5; n])],
        )
        .unwrap()
    }

    fn keys_of(table: &Table) -> Vec<i64> {
        table.column(0).as_i64().unwrap().to_vec()
    }

    #[test]
    fn from_tables_and_compact() {
        let ct =
            ChunkedTable::from_tables(vec![t(vec![1, 2]), t(vec![]), t(vec![3])])
                .unwrap();
        assert_eq!(ct.num_rows(), 3);
        assert_eq!(ct.num_chunks(), 3);
        let flat = ct.compact();
        assert_eq!(keys_of(&flat), vec![1, 2, 3]);
        assert_eq!(ct.multiset_fingerprint(), flat.multiset_fingerprint());
        assert_eq!(ct.byte_size(), flat.byte_size());
    }

    #[test]
    fn single_chunk_compact_shares_buffers() {
        let table = t(vec![7, 8, 9]);
        let ct = ChunkedTable::from(table.clone());
        let back = ct.compact();
        assert!(back.column(0).shares_buffer(table.column(0)));
        let owned = ct.into_table();
        assert!(owned.column(0).shares_buffer(table.column(0)));
    }

    #[test]
    fn slice_crosses_chunk_boundaries_without_copying() {
        let ct = ChunkedTable::from_tables(vec![
            t(vec![0, 1, 2]),
            t(vec![3, 4]),
            t(vec![5, 6, 7]),
        ])
        .unwrap();
        let mid = ct.slice(2, 4); // rows 2..6 span all three chunks
        assert_eq!(mid.num_rows(), 4);
        assert_eq!(keys_of(&mid.compact()), vec![2, 3, 4, 5]);
        // Each produced chunk is a view over the original chunk's buffer.
        assert!(mid.chunks()[0]
            .column(0)
            .shares_buffer(ct.chunks()[0].column(0)));
        // Edge windows.
        assert_eq!(ct.slice(0, 0).num_rows(), 0);
        assert_eq!(keys_of(&ct.slice(7, 1).compact()), vec![7]);
    }

    #[test]
    fn push_validates_schema() {
        let mut ct = ChunkedTable::from(t(vec![1]));
        assert!(ct.push(t(vec![2])).is_ok());
        let other = Table::empty(Schema::of(&[("x", DataType::Bool)]));
        assert!(ct.push(other).is_err());
        assert_eq!(ct.num_rows(), 2);
    }

    #[test]
    fn empty_cases() {
        assert!(ChunkedTable::from_tables(vec![]).is_err());
        let e = ChunkedTable::empty(t(vec![]).schema().clone());
        assert_eq!(e.num_rows(), 0);
        assert_eq!(e.compact().num_rows(), 0);
        assert_eq!(e.multiset_fingerprint(), 0);
    }
}
