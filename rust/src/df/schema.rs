//! Table schema: ordered, named, typed fields.

use crate::error::{Error, Result};

use super::column::DataType;

/// A named, typed field.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Field {
    pub name: String,
    pub dtype: DataType,
}

impl Field {
    pub fn new(name: &str, dtype: DataType) -> Field {
        Field { name: name.to_string(), dtype }
    }
}

/// Ordered collection of fields.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct Schema {
    fields: Vec<Field>,
}

impl Schema {
    pub fn new(fields: Vec<Field>) -> Schema {
        Schema { fields }
    }

    /// Builder-style convenience: `Schema::of(&[("k", Int64), ...])`.
    pub fn of(spec: &[(&str, DataType)]) -> Schema {
        Schema {
            fields: spec.iter().map(|(n, t)| Field::new(n, *t)).collect(),
        }
    }

    pub fn fields(&self) -> &[Field] {
        &self.fields
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    /// Index of a column by name. Unknown names produce a did-you-mean
    /// diagnostic listing every available column (and the closest match by
    /// edit distance, when one is near enough to be a plausible typo).
    pub fn index_of(&self, name: &str) -> Result<usize> {
        if let Some(i) = self.fields.iter().position(|f| f.name == name) {
            return Ok(i);
        }
        let available: Vec<&str> =
            self.fields.iter().map(|f| f.name.as_str()).collect();
        let suggestion = self
            .fields
            .iter()
            .map(|f| (edit_distance(name, &f.name), &f.name))
            .min()
            .filter(|(d, _)| *d <= 2.max(name.len() / 3))
            .map(|(_, n)| format!("; did you mean '{n}'?"))
            .unwrap_or_default();
        Err(Error::DataFrame(format!(
            "no column named '{name}' (available: {}{suggestion})",
            available.join(", ")
        )))
    }

    pub fn field(&self, i: usize) -> &Field {
        &self.fields[i]
    }

    /// Concatenate two schemas, suffixing right-side name collisions with
    /// `_right` (Cylon's join behaviour).
    pub fn join(&self, right: &Schema) -> Schema {
        let mut fields = self.fields.clone();
        for f in &right.fields {
            let name = if self.index_of(&f.name).is_ok() {
                format!("{}_right", f.name)
            } else {
                f.name.clone()
            };
            fields.push(Field::new(&name, f.dtype));
        }
        Schema { fields }
    }
}

/// Levenshtein distance (two-row DP) — powers the did-you-mean hint in
/// [`Schema::index_of`]. Column names are short, so O(a·b) is fine.
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// A column reference: by position (the legacy addressing mode) or by
/// name (the preferred one — survives projections and reads better).
///
/// Operator and [`crate::plan::Plan`] key arguments take
/// `impl Into<ColRef>`, so existing `usize` call sites keep compiling
/// while new code passes `&str` names. Resolution against the actual
/// input [`Schema`] happens at execute time via [`ColRef::resolve`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ColRef {
    /// Positional index into the schema (legacy; blocks some optimizer
    /// rewrites until normalized to a name).
    Index(usize),
    /// Column name, resolved with [`Schema::index_of`] diagnostics.
    Name(String),
}

impl ColRef {
    /// Resolve to a concrete column index against `schema`.
    pub fn resolve(&self, schema: &Schema) -> Result<usize> {
        match self {
            ColRef::Index(i) if *i < schema.len() => Ok(*i),
            ColRef::Index(i) => Err(Error::DataFrame(format!(
                "column index {i} out of bounds for schema {schema} \
                 ({} columns)",
                schema.len()
            ))),
            ColRef::Name(n) => schema.index_of(n),
        }
    }
}

impl Default for ColRef {
    fn default() -> ColRef {
        ColRef::Index(0)
    }
}

impl From<usize> for ColRef {
    fn from(i: usize) -> ColRef {
        ColRef::Index(i)
    }
}

impl From<&str> for ColRef {
    fn from(n: &str) -> ColRef {
        ColRef::Name(n.to_string())
    }
}

impl From<String> for ColRef {
    fn from(n: String) -> ColRef {
        ColRef::Name(n)
    }
}

impl std::fmt::Display for ColRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ColRef::Index(i) => write!(f, "#{i}"),
            ColRef::Name(n) => write!(f, "{n}"),
        }
    }
}

impl std::fmt::Display for Schema {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let parts: Vec<String> = self
            .fields
            .iter()
            .map(|fl| format!("{}:{}", fl.name, fl.dtype))
            .collect();
        write!(f, "[{}]", parts.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_lookup() {
        let s = Schema::of(&[("k", DataType::Int64), ("v", DataType::Float64)]);
        assert_eq!(s.index_of("v").unwrap(), 1);
        assert!(s.index_of("zzz").is_err());
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn unknown_column_lists_available_and_suggests() {
        let s = Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)]);
        let err = s.index_of("vall").unwrap_err().to_string();
        assert!(err.contains("no column named 'vall'"), "{err}");
        assert!(err.contains("available: key, val"), "{err}");
        assert!(err.contains("did you mean 'val'?"), "{err}");
        // A name nothing like any column gets the listing but no guess.
        let err = s.index_of("zzzzzzzz").unwrap_err().to_string();
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn colref_resolution() {
        let s = Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)]);
        assert_eq!(ColRef::from(1usize).resolve(&s).unwrap(), 1);
        assert_eq!(ColRef::from("val").resolve(&s).unwrap(), 1);
        assert_eq!(ColRef::from("key".to_string()).resolve(&s).unwrap(), 0);
        let err = ColRef::from(9usize).resolve(&s).unwrap_err().to_string();
        assert!(err.contains("out of bounds"), "{err}");
        assert!(ColRef::from("nope").resolve(&s).is_err());
        assert_eq!(ColRef::default(), ColRef::Index(0));
        assert_eq!(ColRef::from("val").to_string(), "val");
        assert_eq!(ColRef::from(2usize).to_string(), "#2");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("val", "val"), 0);
        assert_eq!(edit_distance("vall", "val"), 1);
        assert_eq!(edit_distance("kye", "key"), 2);
        assert_eq!(edit_distance("", "abc"), 3);
    }

    #[test]
    fn join_renames_collisions() {
        let l = Schema::of(&[("k", DataType::Int64), ("v", DataType::Int64)]);
        let r = Schema::of(&[("k", DataType::Int64), ("w", DataType::Utf8)]);
        let j = l.join(&r);
        let names: Vec<&str> = j.fields().iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["k", "v", "k_right", "w"]);
    }

    #[test]
    fn display() {
        let s = Schema::of(&[("k", DataType::Int64)]);
        assert_eq!(s.to_string(), "[k:int64]");
    }
}
