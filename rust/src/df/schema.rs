//! Table schema: ordered, named, typed fields.

use crate::error::{Error, Result};

use super::column::DataType;

/// A named, typed field.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Field {
    pub name: String,
    pub dtype: DataType,
}

impl Field {
    pub fn new(name: &str, dtype: DataType) -> Field {
        Field { name: name.to_string(), dtype }
    }
}

/// Ordered collection of fields.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct Schema {
    fields: Vec<Field>,
}

impl Schema {
    pub fn new(fields: Vec<Field>) -> Schema {
        Schema { fields }
    }

    /// Builder-style convenience: `Schema::of(&[("k", Int64), ...])`.
    pub fn of(spec: &[(&str, DataType)]) -> Schema {
        Schema {
            fields: spec.iter().map(|(n, t)| Field::new(n, *t)).collect(),
        }
    }

    pub fn fields(&self) -> &[Field] {
        &self.fields
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    /// Index of a column by name.
    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.fields
            .iter()
            .position(|f| f.name == name)
            .ok_or_else(|| Error::DataFrame(format!("no column named '{name}'")))
    }

    pub fn field(&self, i: usize) -> &Field {
        &self.fields[i]
    }

    /// Concatenate two schemas, suffixing right-side name collisions with
    /// `_right` (Cylon's join behaviour).
    pub fn join(&self, right: &Schema) -> Schema {
        let mut fields = self.fields.clone();
        for f in &right.fields {
            let name = if self.index_of(&f.name).is_ok() {
                format!("{}_right", f.name)
            } else {
                f.name.clone()
            };
            fields.push(Field::new(&name, f.dtype));
        }
        Schema { fields }
    }
}

impl std::fmt::Display for Schema {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let parts: Vec<String> = self
            .fields
            .iter()
            .map(|fl| format!("{}:{}", fl.name, fl.dtype))
            .collect();
        write!(f, "[{}]", parts.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_lookup() {
        let s = Schema::of(&[("k", DataType::Int64), ("v", DataType::Float64)]);
        assert_eq!(s.index_of("v").unwrap(), 1);
        assert!(s.index_of("zzz").is_err());
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn join_renames_collisions() {
        let l = Schema::of(&[("k", DataType::Int64), ("v", DataType::Int64)]);
        let r = Schema::of(&[("k", DataType::Int64), ("w", DataType::Utf8)]);
        let j = l.join(&r);
        let names: Vec<&str> = j.fields().iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["k", "v", "k_right", "w"]);
    }

    #[test]
    fn display() {
        let s = Schema::of(&[("k", DataType::Int64)]);
        assert_eq!(s.to_string(), "[k:int64]");
    }
}
