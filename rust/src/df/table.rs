//! `Table`: the unit every Cylon operator consumes and produces.
//!
//! Columns are `Arc`-backed views ([`super::buffer`]), so `clone`,
//! [`Table::slice`], and [`Table::project`] are O(columns) and copy no row
//! data; only [`Table::take`] / [`Table::filter`] / [`Table::concat`]
//! materialize fresh buffers.

use crate::error::{Error, Result};

use super::column::Column;
#[cfg(test)]
use super::column::DataType;
use super::schema::Schema;

/// An immutable columnar table (schema + equal-length column views).
#[derive(Clone, Debug, PartialEq)]
pub struct Table {
    schema: Schema,
    columns: Vec<Column>,
    nrows: usize,
}

impl Table {
    /// Build a table, validating schema/column agreement.
    pub fn new(schema: Schema, columns: Vec<Column>) -> Result<Table> {
        if schema.len() != columns.len() {
            return Err(Error::DataFrame(format!(
                "schema has {} fields but {} columns given",
                schema.len(),
                columns.len()
            )));
        }
        let nrows = columns.first().map(|c| c.len()).unwrap_or(0);
        for (f, c) in schema.fields().iter().zip(&columns) {
            if f.dtype != c.dtype() {
                return Err(Error::DataFrame(format!(
                    "column '{}' declared {} but holds {}",
                    f.name,
                    f.dtype,
                    c.dtype()
                )));
            }
            if c.len() != nrows {
                return Err(Error::DataFrame(format!(
                    "ragged table: column '{}' has {} rows, expected {nrows}",
                    f.name,
                    c.len()
                )));
            }
        }
        Ok(Table { schema, columns, nrows })
    }

    /// Empty table with the given schema.
    pub fn empty(schema: Schema) -> Table {
        let columns = schema
            .fields()
            .iter()
            .map(|f| Column::empty(f.dtype))
            .collect();
        Table { schema, columns, nrows: 0 }
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn num_rows(&self) -> usize {
        self.nrows
    }

    pub fn num_columns(&self) -> usize {
        self.columns.len()
    }

    pub fn column(&self, i: usize) -> &Column {
        &self.columns[i]
    }

    pub fn column_by_name(&self, name: &str) -> Result<&Column> {
        Ok(&self.columns[self.schema.index_of(name)?])
    }

    pub fn columns(&self) -> &[Column] {
        &self.columns
    }

    /// Gather rows by index into a new table (materializes fresh buffers —
    /// arbitrary gathers cannot be expressed as windows).
    pub fn take(&self, idx: &[usize]) -> Table {
        Table {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.take(idx)).collect(),
            nrows: idx.len(),
        }
    }

    /// [`Table::take`] over `u32` row ids — the index width the flat
    /// join/sort/shuffle kernels produce (see EXPERIMENTS.md §Perf).
    pub fn take_u32(&self, idx: &[u32]) -> Table {
        Table {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.take_u32(idx)).collect(),
            nrows: idx.len(),
        }
    }

    /// Contiguous row window — O(columns), zero rows copied. The result
    /// shares every backing buffer with `self`.
    pub fn slice(&self, start: usize, len: usize) -> Table {
        Table {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.slice(start, len)).collect(),
            nrows: len,
        }
    }

    /// Concatenate tables with identical schemas into one contiguous table
    /// (materializes; [`super::ChunkedTable`] defers this copy).
    pub fn concat(parts: &[Table]) -> Result<Table> {
        let Some(first) = parts.first() else {
            return Err(Error::DataFrame("concat of zero tables".into()));
        };
        for part in parts {
            if part.schema != first.schema {
                return Err(Error::DataFrame(format!(
                    "concat schema mismatch: {} vs {}",
                    part.schema, first.schema
                )));
            }
        }
        if parts.len() == 1 {
            // Single part: Arc clones only, no row copies.
            return Ok(first.clone());
        }
        let mut columns = Vec::with_capacity(first.columns.len());
        for j in 0..first.columns.len() {
            let cols: Vec<&Column> = parts.iter().map(|p| p.column(j)).collect();
            columns.push(Column::concat(&cols)?);
        }
        let nrows = parts.iter().map(|p| p.nrows).sum();
        Ok(Table { schema: first.schema.clone(), columns, nrows })
    }

    /// Keep only rows where `mask` is true.
    pub fn filter(&self, mask: &[bool]) -> Result<Table> {
        if mask.len() != self.nrows {
            return Err(Error::DataFrame(format!(
                "mask length {} != row count {}",
                mask.len(),
                self.nrows
            )));
        }
        let idx: Vec<usize> = mask
            .iter()
            .enumerate()
            .filter_map(|(i, &keep)| keep.then_some(i))
            .collect();
        Ok(self.take(&idx))
    }

    /// Project a subset of columns by name (Arc clones — zero-copy).
    pub fn project(&self, names: &[&str]) -> Result<Table> {
        let mut fields = Vec::with_capacity(names.len());
        let mut columns = Vec::with_capacity(names.len());
        for name in names {
            let i = self.schema.index_of(name)?;
            fields.push(self.schema.field(i).clone());
            columns.push(self.columns[i].clone());
        }
        Ok(Table { schema: Schema::new(fields), columns, nrows: self.nrows })
    }

    /// Order-insensitive content fingerprint: wrapping sum of per-row
    /// hashes. **Additive over disjoint row sets**, so the sum of per-rank
    /// partition fingerprints equals the whole-table fingerprint — the
    /// property every distributed-op invariance test relies on.
    pub fn multiset_fingerprint(&self) -> u64 {
        use crate::util::hash::splitmix64;
        let mut acc = 0u64;
        for r in 0..self.nrows {
            let mut rh = 0x9E37_79B9_7F4A_7C15u64;
            for c in &self.columns {
                rh = splitmix64(rh ^ c.value_hash(r));
            }
            acc = acc.wrapping_add(rh);
        }
        acc
    }

    /// Approximate payload bytes of the **visible windows** (drives the
    /// network cost model): a slice view charges only its window, never
    /// the backing buffer it shares.
    pub fn byte_size(&self) -> usize {
        self.columns.iter().map(|c| c.byte_size()).sum()
    }

    /// Bytes of backing allocations this table keeps alive (diagnostics;
    /// `byte_size() <= backing_byte_size()`).
    pub fn backing_byte_size(&self) -> usize {
        self.columns.iter().map(|c| c.backing_byte_size()).sum()
    }

    /// First `n` rows rendered for debugging/examples.
    pub fn head(&self, n: usize) -> String {
        let n = n.min(self.nrows);
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.schema));
        for r in 0..n {
            let cells: Vec<String> = self
                .columns
                .iter()
                .map(|c| c.value_to_string(r))
                .collect();
            out.push_str(&format!("  {}\n", cells.join(", ")));
        }
        if self.nrows > n {
            out.push_str(&format!("  ... ({} rows total)\n", self.nrows));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::mem;

    fn t2() -> Table {
        Table::new(
            Schema::of(&[("k", DataType::Int64), ("v", DataType::Float64)]),
            vec![
                Column::from_i64(vec![3, 1, 2]),
                Column::from_f64(vec![0.3, 0.1, 0.2]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn validates_shape_and_types() {
        assert!(Table::new(
            Schema::of(&[("k", DataType::Int64)]),
            vec![Column::from_f64(vec![1.0])],
        )
        .is_err());
        assert!(Table::new(
            Schema::of(&[("k", DataType::Int64), ("v", DataType::Int64)]),
            vec![Column::from_i64(vec![1]), Column::from_i64(vec![1, 2])],
        )
        .is_err());
        assert!(Table::new(Schema::of(&[("k", DataType::Int64)]), vec![]).is_err());
    }

    #[test]
    fn take_slice_filter_project() {
        let t = t2();
        let taken = t.take(&[1, 1]);
        assert_eq!(taken.column(0).as_i64().unwrap(), &[1, 1]);
        let sl = t.slice(1, 2);
        assert_eq!(sl.column(0).as_i64().unwrap(), &[1, 2]);
        let f = t.filter(&[true, false, true]).unwrap();
        assert_eq!(f.column(0).as_i64().unwrap(), &[3, 2]);
        let p = t.project(&["v"]).unwrap();
        assert_eq!(p.num_columns(), 1);
        assert_eq!(p.num_rows(), 3);
        assert!(t.project(&["nope"]).is_err());
    }

    #[test]
    fn slice_is_zero_copy() {
        let t = t2();
        let before = mem::thread();
        let sl = t.slice(0, 2);
        let delta = mem::thread().since(before);
        assert_eq!(delta.materialized, 0, "slice must not copy rows");
        assert!(delta.viewed > 0, "slice must be counted as a view");
        // Structural proof: both columns share their backing buffers.
        for j in 0..t.num_columns() {
            assert!(sl.column(j).shares_buffer(t.column(j)));
        }
        // Projection is Arc clones only.
        let before = mem::thread();
        let p = t.project(&["k"]).unwrap();
        assert_eq!(mem::thread().since(before).materialized, 0);
        assert!(p.column(0).shares_buffer(t.column(0)));
    }

    #[test]
    fn concat_and_fingerprint() {
        let t = t2();
        let c = Table::concat(&[t.slice(0, 1), t.slice(1, 2)]).unwrap();
        assert_eq!(c.num_rows(), 3);
        assert_eq!(c.multiset_fingerprint(), t.multiset_fingerprint());
        // reordering rows keeps the fingerprint
        assert_eq!(
            t.take(&[2, 0, 1]).multiset_fingerprint(),
            t.multiset_fingerprint()
        );
        // changing a value does not
        let other = Table::new(
            t.schema().clone(),
            vec![
                Column::from_i64(vec![3, 1, 99]),
                Column::from_f64(vec![0.3, 0.1, 0.2]),
            ],
        )
        .unwrap();
        assert_ne!(other.multiset_fingerprint(), t.multiset_fingerprint());
    }

    #[test]
    fn single_part_concat_is_zero_copy() {
        let t = t2();
        let before = mem::thread();
        let c = Table::concat(std::slice::from_ref(&t)).unwrap();
        assert_eq!(mem::thread().since(before).materialized, 0);
        assert!(c.column(0).shares_buffer(t.column(0)));
        assert_eq!(c, t);
    }

    #[test]
    fn byte_size_charges_window_only() {
        let t = t2();
        let full = t.byte_size(); // 3 * (8 + 8)
        assert_eq!(full, 48);
        let sl = t.slice(1, 1);
        assert_eq!(sl.byte_size(), 16);
        assert_eq!(sl.backing_byte_size(), 48); // keeps the backing alive
    }

    #[test]
    fn empty_and_head() {
        let e = Table::empty(Schema::of(&[("k", DataType::Int64)]));
        assert_eq!(e.num_rows(), 0);
        let h = t2().head(2);
        assert!(h.contains("(3 rows total)"));
    }

    #[test]
    fn concat_rejects_mismatched_schema() {
        let a = t2();
        let b = Table::empty(Schema::of(&[("x", DataType::Int64)]));
        assert!(Table::concat(&[a, b]).is_err());
    }
}
