//! Minimal CSV reader/writer for the examples (header row, no quoting —
//! sufficient for the synthetic numeric workloads the paper evaluates).
//!
//! Utf8 cells are appended straight into one [`Utf8Builder`] arena, so a
//! string column costs two allocations total instead of one `String` per
//! cell.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::error::{Error, Result};

use super::buffer::Utf8Builder;
use super::column::{Column, DataType};
use super::schema::Schema;
use super::table::Table;

/// Write `table` as CSV with a header row.
pub fn write_csv(table: &Table, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    let header: Vec<&str> = table
        .schema()
        .fields()
        .iter()
        .map(|f| f.name.as_str())
        .collect();
    writeln!(w, "{}", header.join(","))?;
    for r in 0..table.num_rows() {
        let cells: Vec<String> = table
            .columns()
            .iter()
            .map(|c| c.value_to_string(r))
            .collect();
        writeln!(w, "{}", cells.join(","))?;
    }
    w.flush()?;
    Ok(())
}

/// Per-column ingest state: typed vectors for the fixed-width types, the
/// shared-arena builder for strings.
enum ColBuilder {
    I64(Vec<i64>),
    F64(Vec<f64>),
    Utf8(Utf8Builder),
    Bool(Vec<bool>),
}

impl ColBuilder {
    fn new(dtype: DataType) -> ColBuilder {
        match dtype {
            DataType::Int64 => ColBuilder::I64(Vec::new()),
            DataType::Float64 => ColBuilder::F64(Vec::new()),
            DataType::Utf8 => ColBuilder::Utf8(Utf8Builder::new()),
            DataType::Bool => ColBuilder::Bool(Vec::new()),
        }
    }

    fn finish(self) -> Column {
        match self {
            ColBuilder::I64(v) => Column::from_i64(v),
            ColBuilder::F64(v) => Column::from_f64(v),
            ColBuilder::Utf8(b) => Column::Utf8(b.finish()),
            ColBuilder::Bool(v) => Column::from_bool(v),
        }
    }
}

/// Read a CSV produced by [`write_csv`] with an explicit schema.
pub fn read_csv(path: &Path, schema: Schema) -> Result<Table> {
    let f = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(f);
    let mut lines = reader.lines();

    let header = lines
        .next()
        .ok_or_else(|| Error::DataFrame("empty csv".into()))??;
    let names: Vec<&str> = header.split(',').collect();
    if names.len() != schema.len() {
        return Err(Error::DataFrame(format!(
            "csv has {} columns, schema expects {}",
            names.len(),
            schema.len()
        )));
    }
    for (name, field) in names.iter().zip(schema.fields()) {
        if *name != field.name {
            return Err(Error::DataFrame(format!(
                "csv header '{name}' != schema field '{}'",
                field.name
            )));
        }
    }

    let mut cols: Vec<ColBuilder> = schema
        .fields()
        .iter()
        .map(|f| ColBuilder::new(f.dtype))
        .collect();
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        let cells: Vec<&str> = line.split(',').collect();
        if cells.len() != schema.len() {
            return Err(Error::DataFrame(format!(
                "row {} has {} cells, expected {}",
                lineno + 2,
                cells.len(),
                schema.len()
            )));
        }
        for (cell, col) in cells.iter().zip(cols.iter_mut()) {
            let parse_err = |what: &str| {
                Error::DataFrame(format!(
                    "row {}: cannot parse '{cell}' as {what}",
                    lineno + 2
                ))
            };
            match col {
                ColBuilder::I64(v) => {
                    v.push(cell.parse().map_err(|_| parse_err("int64"))?)
                }
                ColBuilder::F64(v) => {
                    v.push(cell.parse().map_err(|_| parse_err("float64"))?)
                }
                ColBuilder::Utf8(b) => b.push(cell),
                ColBuilder::Bool(v) => {
                    v.push(cell.parse().map_err(|_| parse_err("bool"))?)
                }
            }
        }
    }
    Table::new(schema, cols.into_iter().map(ColBuilder::finish).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        Table::new(
            Schema::of(&[
                ("k", DataType::Int64),
                ("x", DataType::Float64),
                ("tag", DataType::Utf8),
                ("ok", DataType::Bool),
            ]),
            vec![
                Column::from_i64(vec![1, -2]),
                Column::from_f64(vec![0.5, 2.25]),
                Column::from_utf8(&["a", "b"]),
                Column::from_bool(vec![true, false]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("rc_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let t = sample();
        write_csv(&t, &path).unwrap();
        let back = read_csv(&path, t.schema().clone()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn utf8_arena_roundtrip_with_views() {
        // Round-trip through a *sliced view* (non-zero arena offsets) and
        // tricky strings: empties and repeated values.
        let dir = std::env::temp_dir().join("rc_csv_test_arena");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let full = Table::new(
            Schema::of(&[("k", DataType::Int64), ("tag", DataType::Utf8)]),
            vec![
                Column::from_i64(vec![0, 1, 2, 3]),
                Column::from_utf8(&["skip", "", "same", "same"]),
            ],
        )
        .unwrap();
        let view = full.slice(1, 3);
        write_csv(&view, &path).unwrap();
        let back = read_csv(&path, view.schema().clone()).unwrap();
        assert_eq!(back, view);
        let tags = back.column(1).as_utf8().unwrap();
        assert_eq!(tags.iter().collect::<Vec<_>>(), vec!["", "same", "same"]);
        // The re-read column is one compact arena, not a view.
        assert!(!back.column(1).as_utf8().unwrap().is_view());
    }

    #[test]
    fn header_mismatch_detected() {
        let dir = std::env::temp_dir().join("rc_csv_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        write_csv(&sample(), &path).unwrap();
        let bad = Schema::of(&[
            ("WRONG", DataType::Int64),
            ("x", DataType::Float64),
            ("tag", DataType::Utf8),
            ("ok", DataType::Bool),
        ]);
        assert!(read_csv(&path, bad).is_err());
    }

    #[test]
    fn parse_error_reported_with_row() {
        let dir = std::env::temp_dir().join("rc_csv_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        std::fs::write(&path, "k\nnotanint\n").unwrap();
        let err = read_csv(&path, Schema::of(&[("k", DataType::Int64)]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("row 2"), "{err}");
    }
}
