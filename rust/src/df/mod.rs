//! Columnar dataframe substrate — the Cylon table abstraction (paper §3.2,
//! Fig 1): typed columns in a columnar layout, a schema, and a `Table` that
//! local and distributed operators consume. Stands in for Cylon's Apache
//! Arrow foundation, including Arrow's zero-copy memory model: columns are
//! `Arc`-backed buffer views ([`Buffer`]/[`Utf8Buffer`]), slices are O(1)
//! windows, and [`ChunkedTable`] defers concat/gather copies until an
//! operator actually needs contiguous access.

mod buffer;
mod chunked;
mod column;
mod csv;
mod gen;
mod schema;
mod table;

pub use buffer::{Buffer, Utf8Buffer, Utf8Builder};
pub use chunked::{Chunk, ChunkedTable, SpilledChunk};
pub use column::{Column, DataType};
pub use csv::{read_csv, write_csv};
pub use gen::{gen_table, gen_two_tables, GenSpec, KeyDist};
pub use schema::{ColRef, Field, Schema};
pub use table::Table;
