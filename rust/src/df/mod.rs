//! Columnar dataframe substrate — the Cylon table abstraction (paper §3.2,
//! Fig 1): typed columns in a columnar layout, a schema, and a `Table` that
//! local and distributed operators consume. Stands in for Cylon's Apache
//! Arrow foundation.

mod column;
mod csv;
mod gen;
mod schema;
mod table;

pub use column::{Column, DataType};
pub use csv::{read_csv, write_csv};
pub use gen::{gen_table, gen_two_tables, GenSpec, KeyDist};
pub use schema::{Field, Schema};
pub use table::Table;
