//! Shared immutable buffers — the zero-copy substrate under [`Column`].
//!
//! A [`Buffer<T>`] is an `Arc`-backed window `{data, offset, len}` over one
//! immutable allocation: cloning and slicing are O(1) pointer/arithmetic
//! operations, and every view created from the same allocation shares it
//! (the Arrow buffer/array-slice model the paper's Cylon layer inherits
//! from Apache Arrow). Strings get the same treatment via [`Utf8Buffer`]:
//! one contiguous byte arena plus an `u32` offset table, so a table of a
//! million short strings costs two allocations, not a million.
//!
//! Every *new* allocation (builders, gathers, compactions) is reported to
//! [`crate::metrics::mem::record_materialized`]; every O(1) window
//! creation to [`crate::metrics::mem::record_viewed`]. The pair of
//! counters is how benches and tests prove a path copies nothing.
//!
//! [`Column`]: super::column::Column

use std::sync::Arc;

use crate::metrics::mem;

/// An immutable, shareable window over a typed allocation.
///
/// Dereferences to `&[T]` (the visible window only), so indexing,
/// iteration, and `len()` all see window semantics.
#[derive(Clone, Debug)]
pub struct Buffer<T> {
    data: Arc<Vec<T>>,
    offset: usize,
    len: usize,
}

impl<T> Buffer<T> {
    /// Wrap a freshly-built vector (counted as materialized bytes).
    pub fn from_vec(v: Vec<T>) -> Buffer<T> {
        mem::record_materialized(v.len() * std::mem::size_of::<T>());
        let len = v.len();
        Buffer { data: Arc::new(v), offset: 0, len }
    }

    /// The visible window as a slice.
    pub fn as_slice(&self) -> &[T] {
        &self.data[self.offset..self.offset + self.len]
    }

    /// O(1) sub-window `[start, start+len)` of this view. Shares the
    /// backing allocation; no element is copied.
    pub fn slice(&self, start: usize, len: usize) -> Buffer<T> {
        assert!(
            start + len <= self.len,
            "buffer slice [{start}, {start}+{len}) out of window of {}",
            self.len
        );
        mem::record_viewed(len * std::mem::size_of::<T>());
        Buffer {
            data: self.data.clone(),
            offset: self.offset + start,
            len,
        }
    }

    /// Payload bytes of the visible window (what a send must carry).
    pub fn byte_size(&self) -> usize {
        self.len * std::mem::size_of::<T>()
    }

    /// Bytes of the whole backing allocation (diagnostics; a view keeps
    /// the full allocation alive).
    pub fn backing_byte_size(&self) -> usize {
        self.data.len() * std::mem::size_of::<T>()
    }

    /// Do two views share one backing allocation?
    pub fn shares_buffer(&self, other: &Buffer<T>) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Is this a proper window (not the whole allocation)?
    pub fn is_view(&self) -> bool {
        self.offset != 0 || self.len != self.data.len()
    }
}

impl<T> std::ops::Deref for Buffer<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T> From<Vec<T>> for Buffer<T> {
    fn from(v: Vec<T>) -> Buffer<T> {
        Buffer::from_vec(v)
    }
}

/// Content equality over the visible windows (layout-independent).
impl<T: PartialEq> PartialEq for Buffer<T> {
    fn eq(&self, other: &Buffer<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// String-arena view: one shared byte buffer holding every string
/// back-to-back, plus `n+1` offsets. `{start, len}` selects a window of
/// logical strings, so slicing is O(1) exactly like [`Buffer`].
///
/// Offsets are `u32`: a single arena is capped at 4 GiB of string payload
/// (enforced by [`Utf8Builder::push`], which panics past the cap), which
/// halves the offset-table footprint versus `usize` — the same trade
/// Arrow's 32-bit `StringArray` makes. Billion-row scale is reached by
/// keeping data in *many* arenas, not one: every partition, shuffle chunk,
/// and [`ChunkedTable`](super::chunked::ChunkedTable) chunk carries its
/// own arena, so per-arena payload stays far below the cap under the
/// paper's workloads.
#[derive(Clone, Debug)]
pub struct Utf8Buffer {
    bytes: Arc<Vec<u8>>,
    /// `offsets[start + i] .. offsets[start + i + 1]` is string `i`.
    offsets: Arc<Vec<u32>>,
    start: usize,
    len: usize,
}

impl Utf8Buffer {
    /// Build an arena from a slice of strings.
    pub fn from_strs<S: AsRef<str>>(vals: &[S]) -> Utf8Buffer {
        let total: usize = vals.iter().map(|s| s.as_ref().len()).sum();
        let mut b = Utf8Builder::with_capacity(vals.len(), total);
        for s in vals {
            b.push(s.as_ref());
        }
        b.finish()
    }

    /// Number of strings in the visible window.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// String `i` of the window.
    pub fn get(&self, i: usize) -> &str {
        assert!(i < self.len, "utf8 index {i} out of window of {}", self.len);
        let a = self.offsets[self.start + i] as usize;
        let b = self.offsets[self.start + i + 1] as usize;
        std::str::from_utf8(&self.bytes[a..b]).expect("arena holds valid utf8")
    }

    /// Iterate the window's strings.
    pub fn iter(&self) -> impl Iterator<Item = &str> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }

    /// O(1) sub-window of `len` strings starting at `start`. Shares both
    /// the byte arena and the offset table.
    pub fn slice(&self, start: usize, len: usize) -> Utf8Buffer {
        assert!(
            start + len <= self.len,
            "utf8 slice [{start}, {start}+{len}) out of window of {}",
            self.len
        );
        let out = Utf8Buffer {
            bytes: self.bytes.clone(),
            offsets: self.offsets.clone(),
            start: self.start + start,
            len,
        };
        mem::record_viewed(out.byte_size());
        out
    }

    /// String payload bytes of the visible window.
    pub fn str_bytes(&self) -> usize {
        let a = self.offsets[self.start] as usize;
        let b = self.offsets[self.start + self.len] as usize;
        b - a
    }

    /// Window payload: string bytes + the visible offset entries.
    pub fn byte_size(&self) -> usize {
        self.str_bytes() + self.len * std::mem::size_of::<u32>()
    }

    /// Whole-arena footprint (kept alive by any view over it).
    pub fn backing_byte_size(&self) -> usize {
        self.bytes.len() + self.offsets.len() * std::mem::size_of::<u32>()
    }

    pub fn shares_buffer(&self, other: &Utf8Buffer) -> bool {
        Arc::ptr_eq(&self.bytes, &other.bytes)
    }

    pub fn is_view(&self) -> bool {
        self.start != 0 || self.len + 1 != self.offsets.len()
    }
}

/// Content equality over the visible windows.
impl PartialEq for Utf8Buffer {
    fn eq(&self, other: &Utf8Buffer) -> bool {
        self.len == other.len && self.iter().eq(other.iter())
    }
}

/// Incremental [`Utf8Buffer`] constructor — the one place string payloads
/// are copied. CSV ingest, gathers, and joins all build through this, so
/// no path ever allocates one `String` per cell.
#[derive(Debug)]
pub struct Utf8Builder {
    bytes: Vec<u8>,
    /// Invariant: always holds the leading `0` sentinel plus one entry per
    /// pushed string.
    offsets: Vec<u32>,
}

impl Default for Utf8Builder {
    fn default() -> Utf8Builder {
        Utf8Builder::new()
    }
}

impl Utf8Builder {
    pub fn new() -> Utf8Builder {
        Utf8Builder::with_capacity(0, 0)
    }

    /// Pre-size for `strings` entries totalling ~`bytes` payload bytes.
    pub fn with_capacity(strings: usize, bytes: usize) -> Utf8Builder {
        let mut offsets = Vec::with_capacity(strings + 1);
        offsets.push(0u32);
        Utf8Builder { bytes: Vec::with_capacity(bytes), offsets }
    }

    /// Append one string to the arena.
    pub fn push(&mut self, s: &str) {
        self.bytes.extend_from_slice(s.as_bytes());
        assert!(
            self.bytes.len() <= u32::MAX as usize,
            "utf8 arena exceeds the u32 offset range (4 GiB)"
        );
        self.offsets.push(self.bytes.len() as u32);
    }

    /// Number of strings pushed so far.
    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Seal the arena (counted as materialized bytes: string payload plus
    /// one offset entry per string — the sentinel entry is structural
    /// overhead, not row payload, so an empty arena counts zero).
    pub fn finish(self) -> Utf8Buffer {
        let len = self.offsets.len() - 1;
        mem::record_materialized(self.bytes.len() + len * std::mem::size_of::<u32>());
        Utf8Buffer {
            bytes: Arc::new(self.bytes),
            offsets: Arc::new(self.offsets),
            start: 0,
            len,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_is_a_shared_window() {
        let b = Buffer::from_vec(vec![10i64, 20, 30, 40, 50]);
        let s = b.slice(1, 3);
        assert_eq!(s.as_slice(), &[20, 30, 40]);
        assert_eq!(s.len(), 3); // Deref len = window len
        assert!(s.shares_buffer(&b));
        assert!(s.is_view() && !b.is_view());
        // Nested slicing composes offsets.
        let ss = s.slice(2, 1);
        assert_eq!(ss.as_slice(), &[40]);
        assert!(ss.shares_buffer(&b));
        // Window vs backing accounting.
        assert_eq!(s.byte_size(), 24);
        assert_eq!(s.backing_byte_size(), 40);
    }

    #[test]
    fn buffer_equality_is_content_based() {
        let a = Buffer::from_vec(vec![1i64, 2, 3]);
        let b = Buffer::from_vec(vec![0i64, 1, 2, 3, 9]).slice(1, 3);
        assert_eq!(a, b);
        assert!(!a.shares_buffer(&b));
    }

    #[test]
    #[should_panic(expected = "out of window")]
    fn slice_bounds_checked() {
        Buffer::from_vec(vec![1i64]).slice(0, 2);
    }

    #[test]
    fn utf8_arena_roundtrip() {
        let u = Utf8Buffer::from_strs(&["alpha", "", "gamma"]);
        assert_eq!(u.len(), 3);
        assert_eq!(u.get(0), "alpha");
        assert_eq!(u.get(1), "");
        assert_eq!(u.get(2), "gamma");
        assert_eq!(u.iter().collect::<Vec<_>>(), vec!["alpha", "", "gamma"]);
        assert_eq!(u.str_bytes(), 10);
        assert_eq!(u.byte_size(), 10 + 3 * 4);
    }

    #[test]
    fn utf8_slice_shares_arena() {
        let u = Utf8Buffer::from_strs(&["a", "bb", "ccc", "dddd"]);
        let s = u.slice(1, 2);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec!["bb", "ccc"]);
        assert!(s.shares_buffer(&u));
        assert!(s.is_view());
        assert_eq!(s.str_bytes(), 5);
        let ss = s.slice(1, 1);
        assert_eq!(ss.get(0), "ccc");
        // Empty window is legal.
        let e = u.slice(4, 0);
        assert_eq!(e.len(), 0);
        assert_eq!(e.str_bytes(), 0);
    }

    #[test]
    fn utf8_equality_is_content_based() {
        let a = Utf8Buffer::from_strs(&["x", "y"]);
        let b = Utf8Buffer::from_strs(&["w", "x", "y"]).slice(1, 2);
        assert_eq!(a, b);
        assert_ne!(a, Utf8Buffer::from_strs(&["x", "z"]));
    }

    #[test]
    fn builder_incremental() {
        let mut b = Utf8Builder::new();
        assert!(b.is_empty());
        b.push("one");
        b.push("two");
        assert_eq!(b.len(), 2);
        let u = b.finish();
        assert_eq!(u.get(1), "two");
    }
}
