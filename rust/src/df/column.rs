//! Typed columns over shared immutable buffers. Values are dense (no
//! validity bitmap — the paper's workloads are null-free synthetic tables;
//! adding a bitmap is orthogonal).
//!
//! Every variant holds an `Arc`-backed view ([`Buffer`] / [`Utf8Buffer`]),
//! so `clone` and [`Column::slice`] are O(1) and copy nothing; only
//! [`Column::take`] and [`Column::concat`] materialize fresh allocations
//! (reported to [`crate::metrics::mem`]). Equality is content-based over
//! the visible windows, independent of layout.

use crate::error::{Error, Result};

use super::buffer::{Buffer, Utf8Buffer, Utf8Builder};

/// Logical column type.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DataType {
    Int64,
    Float64,
    Utf8,
    Bool,
}

impl std::fmt::Display for DataType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            DataType::Int64 => "int64",
            DataType::Float64 => "float64",
            DataType::Utf8 => "utf8",
            DataType::Bool => "bool",
        };
        f.write_str(s)
    }
}

/// A dense, typed column view over a shared buffer.
#[derive(Clone, Debug, PartialEq)]
pub enum Column {
    Int64(Buffer<i64>),
    Float64(Buffer<f64>),
    Utf8(Utf8Buffer),
    Bool(Buffer<bool>),
}

impl Column {
    /// Wrap an owned vector of int64 values.
    pub fn from_i64(v: Vec<i64>) -> Column {
        Column::Int64(Buffer::from_vec(v))
    }

    /// Wrap an owned vector of float64 values.
    pub fn from_f64(v: Vec<f64>) -> Column {
        Column::Float64(Buffer::from_vec(v))
    }

    /// Build a string column into a fresh arena.
    pub fn from_utf8<S: AsRef<str>>(vals: &[S]) -> Column {
        Column::Utf8(Utf8Buffer::from_strs(vals))
    }

    /// Wrap an owned vector of bools.
    pub fn from_bool(v: Vec<bool>) -> Column {
        Column::Bool(Buffer::from_vec(v))
    }

    pub fn dtype(&self) -> DataType {
        match self {
            Column::Int64(_) => DataType::Int64,
            Column::Float64(_) => DataType::Float64,
            Column::Utf8(_) => DataType::Utf8,
            Column::Bool(_) => DataType::Bool,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Column::Int64(v) => v.len(),
            Column::Float64(v) => v.len(),
            Column::Utf8(v) => v.len(),
            Column::Bool(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// New empty column of the same type.
    pub fn empty_like(&self) -> Column {
        Column::empty(self.dtype())
    }

    pub fn empty(dtype: DataType) -> Column {
        match dtype {
            DataType::Int64 => Column::from_i64(Vec::new()),
            DataType::Float64 => Column::from_f64(Vec::new()),
            DataType::Utf8 => Column::Utf8(Utf8Builder::new().finish()),
            DataType::Bool => Column::from_bool(Vec::new()),
        }
    }

    /// Gather rows by index (indices may repeat / reorder). Materializes a
    /// fresh buffer — arbitrary gathers cannot be expressed as a window.
    pub fn take(&self, idx: &[usize]) -> Column {
        self.take_impl(idx.len(), idx.iter().copied())
    }

    /// [`Column::take`] over `u32` row ids — the index width the flat
    /// join/sort/shuffle kernels produce (half the index memory of
    /// `&[usize]` at 1M+ rows; see EXPERIMENTS.md §Perf).
    pub fn take_u32(&self, idx: &[u32]) -> Column {
        self.take_impl(idx.len(), idx.iter().map(|&i| i as usize))
    }

    /// Shared gather core for [`Column::take`] / [`Column::take_u32`] —
    /// monomorphized per index width, so neither entry point pays dynamic
    /// dispatch.
    fn take_impl<I>(&self, len: usize, idx: I) -> Column
    where
        I: Iterator<Item = usize> + Clone,
    {
        match self {
            Column::Int64(v) => Column::from_i64(idx.map(|i| v[i]).collect()),
            Column::Float64(v) => {
                Column::from_f64(idx.map(|i| v[i]).collect())
            }
            Column::Utf8(v) => {
                // Pre-size the arena from the source offsets (O(k)) so the
                // gather copies each string exactly once.
                let bytes: usize = idx.clone().map(|i| v.get(i).len()).sum();
                let mut b = Utf8Builder::with_capacity(len, bytes);
                for i in idx {
                    b.push(v.get(i));
                }
                Column::Utf8(b.finish())
            }
            Column::Bool(v) => Column::from_bool(idx.map(|i| v[i]).collect()),
        }
    }

    /// Concatenate same-typed columns into one fresh buffer (the
    /// materializing path; [`crate::df::ChunkedTable`] defers it).
    pub fn concat(parts: &[&Column]) -> Result<Column> {
        let Some(first) = parts.first() else {
            return Err(Error::DataFrame("concat of zero columns".into()));
        };
        let dtype = first.dtype();
        for p in parts {
            if p.dtype() != dtype {
                return Err(Error::DataFrame(format!(
                    "concat dtype mismatch: {} vs {}",
                    dtype,
                    p.dtype()
                )));
            }
        }
        let total: usize = parts.iter().map(|p| p.len()).sum();
        Ok(match first {
            Column::Int64(_) => {
                let mut v = Vec::with_capacity(total);
                for p in parts {
                    v.extend_from_slice(p.as_i64()?);
                }
                Column::from_i64(v)
            }
            Column::Float64(_) => {
                let mut v = Vec::with_capacity(total);
                for p in parts {
                    v.extend_from_slice(p.as_f64()?);
                }
                Column::from_f64(v)
            }
            Column::Utf8(_) => {
                let bytes: usize = parts
                    .iter()
                    .map(|p| match p {
                        Column::Utf8(u) => u.str_bytes(),
                        _ => 0,
                    })
                    .sum();
                let mut b = Utf8Builder::with_capacity(total, bytes);
                for p in parts {
                    for s in p.as_utf8()?.iter() {
                        b.push(s);
                    }
                }
                Column::Utf8(b.finish())
            }
            Column::Bool(_) => {
                let mut v = Vec::with_capacity(total);
                for p in parts {
                    v.extend_from_slice(p.as_bool()?);
                }
                Column::from_bool(v)
            }
        })
    }

    /// Append all values of `other` (must be same dtype). Rebuilds the
    /// backing buffer on every call — kept as the naive baseline for the
    /// perf probes; bulk paths should use [`Column::concat`].
    pub fn extend(&mut self, other: &Column) -> Result<()> {
        if self.dtype() != other.dtype() {
            return Err(Error::DataFrame(format!(
                "extend dtype mismatch: {} vs {}",
                self.dtype(),
                other.dtype()
            )));
        }
        let merged = Column::concat(&[&*self, other])?;
        *self = merged;
        Ok(())
    }

    /// O(1) window `[start, start+len)` over the shared buffer. No row is
    /// copied; the result keeps the backing allocation alive.
    pub fn slice(&self, start: usize, len: usize) -> Column {
        match self {
            Column::Int64(v) => Column::Int64(v.slice(start, len)),
            Column::Float64(v) => Column::Float64(v.slice(start, len)),
            Column::Utf8(v) => Column::Utf8(v.slice(start, len)),
            Column::Bool(v) => Column::Bool(v.slice(start, len)),
        }
    }

    /// Borrow as i64 values, erroring on other types.
    pub fn as_i64(&self) -> Result<&[i64]> {
        match self {
            Column::Int64(v) => Ok(v.as_slice()),
            other => Err(Error::DataFrame(format!(
                "expected int64 column, got {}",
                other.dtype()
            ))),
        }
    }

    pub fn as_f64(&self) -> Result<&[f64]> {
        match self {
            Column::Float64(v) => Ok(v.as_slice()),
            other => Err(Error::DataFrame(format!(
                "expected float64 column, got {}",
                other.dtype()
            ))),
        }
    }

    /// Borrow the string-arena view, erroring on other types.
    pub fn as_utf8(&self) -> Result<&Utf8Buffer> {
        match self {
            Column::Utf8(v) => Ok(v),
            other => Err(Error::DataFrame(format!(
                "expected utf8 column, got {}",
                other.dtype()
            ))),
        }
    }

    pub fn as_bool(&self) -> Result<&[bool]> {
        match self {
            Column::Bool(v) => Ok(v.as_slice()),
            other => Err(Error::DataFrame(format!(
                "expected bool column, got {}",
                other.dtype()
            ))),
        }
    }

    /// Do two columns share one backing allocation (same variant, same
    /// `Arc`)? The structural proof a view performed no copy.
    pub fn shares_buffer(&self, other: &Column) -> bool {
        match (self, other) {
            (Column::Int64(a), Column::Int64(b)) => a.shares_buffer(b),
            (Column::Float64(a), Column::Float64(b)) => a.shares_buffer(b),
            (Column::Utf8(a), Column::Utf8(b)) => a.shares_buffer(b),
            (Column::Bool(a), Column::Bool(b)) => a.shares_buffer(b),
            _ => false,
        }
    }

    /// Render a single value for CSV / display.
    pub fn value_to_string(&self, i: usize) -> String {
        match self {
            Column::Int64(v) => v[i].to_string(),
            Column::Float64(v) => format!("{}", v[i]),
            Column::Utf8(v) => v.get(i).to_string(),
            Column::Bool(v) => v[i].to_string(),
        }
    }

    /// Hash of one value (used by the table-level row fingerprint).
    pub fn value_hash(&self, i: usize) -> u64 {
        use crate::util::hash::splitmix64;
        match self {
            Column::Int64(v) => splitmix64(v[i] as u64),
            Column::Float64(v) => splitmix64(v[i].to_bits()),
            Column::Utf8(v) => {
                let mut h = 0xcbf2_9ce4_8422_2325u64;
                for b in v.get(i).bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x1000_0000_01b3);
                }
                splitmix64(h)
            }
            Column::Bool(v) => splitmix64(v[i] as u64),
        }
    }

    /// Order-insensitive content fingerprint (for distributed-op checks:
    /// shuffles/joins preserve multisets, not order).
    pub fn multiset_fingerprint(&self) -> u64 {
        use crate::util::hash::splitmix64;
        let mut acc = 0u64;
        match self {
            Column::Int64(v) => {
                for &x in v.iter() {
                    acc = acc.wrapping_add(splitmix64(x as u64));
                }
            }
            Column::Float64(v) => {
                for &x in v.iter() {
                    acc = acc.wrapping_add(splitmix64(x.to_bits()));
                }
            }
            Column::Utf8(v) => {
                for s in v.iter() {
                    let mut h = 0xcbf2_9ce4_8422_2325u64;
                    for b in s.bytes() {
                        h ^= b as u64;
                        h = h.wrapping_mul(0x1000_0000_01b3);
                    }
                    acc = acc.wrapping_add(splitmix64(h));
                }
            }
            Column::Bool(v) => {
                for &x in v.iter() {
                    acc = acc.wrapping_add(splitmix64(x as u64));
                }
            }
        }
        acc
    }

    /// Payload bytes of the **visible window** — what a send must actually
    /// carry. A view over a huge buffer charges only its window (the
    /// network model depends on this staying honest).
    pub fn byte_size(&self) -> usize {
        match self {
            Column::Int64(v) => v.byte_size(),
            Column::Float64(v) => v.byte_size(),
            Column::Utf8(v) => v.byte_size(),
            Column::Bool(v) => v.byte_size(),
        }
    }

    /// Bytes of the whole backing allocation this column keeps alive
    /// (diagnostics: `byte_size <= backing_byte_size`).
    pub fn backing_byte_size(&self) -> usize {
        match self {
            Column::Int64(v) => v.backing_byte_size(),
            Column::Float64(v) => v.backing_byte_size(),
            Column::Utf8(v) => v.backing_byte_size(),
            Column::Bool(v) => v.backing_byte_size(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_and_slice() {
        let c = Column::from_i64(vec![10, 20, 30, 40]);
        assert_eq!(c.take(&[3, 0, 0]), Column::from_i64(vec![40, 10, 10]));
        assert_eq!(c.slice(1, 2), Column::from_i64(vec![20, 30]));
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn take_u32_matches_take() {
        let idx_us: Vec<usize> = vec![3, 0, 0, 2];
        let idx_32: Vec<u32> = idx_us.iter().map(|&i| i as u32).collect();
        for c in [
            Column::from_i64(vec![10, 20, 30, 40]),
            Column::from_f64(vec![0.1, 0.2, 0.3, 0.4]),
            Column::from_utf8(&["a", "bb", "ccc", "dddd"]),
            Column::from_bool(vec![true, false, true, false]),
        ] {
            assert_eq!(c.take_u32(&idx_32), c.take(&idx_us));
        }
    }

    #[test]
    fn slice_shares_take_copies() {
        let c = Column::from_i64(vec![1, 2, 3, 4]);
        let view = c.slice(1, 2);
        assert!(view.shares_buffer(&c));
        let gathered = c.take(&[1, 2]);
        assert!(!gathered.shares_buffer(&c));
        assert_eq!(view, gathered); // same content, different layout
    }

    #[test]
    fn extend_checks_dtype() {
        let mut a = Column::from_i64(vec![1]);
        assert!(a.extend(&Column::from_i64(vec![2])).is_ok());
        assert_eq!(a.len(), 2);
        assert!(a.extend(&Column::from_f64(vec![1.0])).is_err());
    }

    #[test]
    fn concat_materializes() {
        let a = Column::from_i64(vec![1, 2]);
        let b = a.slice(1, 1);
        let c = Column::concat(&[&a, &b]).unwrap();
        assert_eq!(c, Column::from_i64(vec![1, 2, 2]));
        assert!(!c.shares_buffer(&a));
        assert!(Column::concat(&[&a, &Column::from_f64(vec![0.0])]).is_err());
        assert!(Column::concat(&[]).is_err());
        // Utf8 concat rebuilds one arena.
        let u = Column::from_utf8(&["x", "yy"]);
        let v = Column::concat(&[&u, &u]).unwrap();
        assert_eq!(v, Column::from_utf8(&["x", "yy", "x", "yy"]));
    }

    #[test]
    fn accessors() {
        let c = Column::from_f64(vec![1.5]);
        assert!(c.as_f64().is_ok());
        assert!(c.as_i64().is_err());
        assert_eq!(c.dtype(), DataType::Float64);
        assert!(Column::from_bool(vec![true]).as_bool().is_ok());
        assert!(c.as_bool().is_err());
    }

    #[test]
    fn fingerprint_order_insensitive() {
        let a = Column::from_i64(vec![1, 2, 3]);
        let b = Column::from_i64(vec![3, 1, 2]);
        assert_eq!(a.multiset_fingerprint(), b.multiset_fingerprint());
        let c = Column::from_i64(vec![1, 2, 4]);
        assert_ne!(a.multiset_fingerprint(), c.multiset_fingerprint());
        // A view's fingerprint equals the equivalent owned column's.
        assert_eq!(
            a.slice(1, 2).multiset_fingerprint(),
            Column::from_i64(vec![2, 3]).multiset_fingerprint()
        );
    }

    #[test]
    fn byte_sizes_charge_the_window() {
        assert_eq!(Column::from_i64(vec![0; 4]).byte_size(), 32);
        assert_eq!(Column::from_bool(vec![true; 4]).byte_size(), 4);
        // Utf8: string payload + 4 bytes of visible offset per entry.
        assert_eq!(Column::from_utf8(&["ab"]).byte_size(), 6);
        // A window charges only itself; the backing stays visible via
        // backing_byte_size.
        let c = Column::from_i64(vec![0; 100]);
        let v = c.slice(10, 5);
        assert_eq!(v.byte_size(), 40);
        assert_eq!(v.backing_byte_size(), 800);
        assert!(c.byte_size() <= c.backing_byte_size());
    }

    #[test]
    fn utf8_roundtrip() {
        let c = Column::from_utf8(&["x", "y"]);
        assert_eq!(c.value_to_string(1), "y");
        assert_eq!(c.take(&[1, 0]).as_utf8().unwrap().get(0), "y");
        // Utf8 slicing is a window over the same arena.
        let s = c.slice(1, 1);
        assert!(s.shares_buffer(&c));
        assert_eq!(s.as_utf8().unwrap().get(0), "y");
    }

    #[test]
    fn empty_columns() {
        for dt in [DataType::Int64, DataType::Float64, DataType::Utf8, DataType::Bool] {
            let c = Column::empty(dt);
            assert_eq!(c.len(), 0);
            assert_eq!(c.dtype(), dt);
            assert_eq!(c.byte_size(), 0);
        }
    }
}
