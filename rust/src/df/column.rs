//! Typed columns. Values are dense (no validity bitmap — the paper's
//! workloads are null-free synthetic tables; adding a bitmap is orthogonal).

use crate::error::{Error, Result};

/// Logical column type.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DataType {
    Int64,
    Float64,
    Utf8,
    Bool,
}

impl std::fmt::Display for DataType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            DataType::Int64 => "int64",
            DataType::Float64 => "float64",
            DataType::Utf8 => "utf8",
            DataType::Bool => "bool",
        };
        f.write_str(s)
    }
}

/// A dense, typed column of values.
#[derive(Clone, Debug, PartialEq)]
pub enum Column {
    Int64(Vec<i64>),
    Float64(Vec<f64>),
    Utf8(Vec<String>),
    Bool(Vec<bool>),
}

impl Column {
    pub fn dtype(&self) -> DataType {
        match self {
            Column::Int64(_) => DataType::Int64,
            Column::Float64(_) => DataType::Float64,
            Column::Utf8(_) => DataType::Utf8,
            Column::Bool(_) => DataType::Bool,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Column::Int64(v) => v.len(),
            Column::Float64(v) => v.len(),
            Column::Utf8(v) => v.len(),
            Column::Bool(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// New empty column of the same type.
    pub fn empty_like(&self) -> Column {
        Column::empty(self.dtype())
    }

    pub fn empty(dtype: DataType) -> Column {
        match dtype {
            DataType::Int64 => Column::Int64(Vec::new()),
            DataType::Float64 => Column::Float64(Vec::new()),
            DataType::Utf8 => Column::Utf8(Vec::new()),
            DataType::Bool => Column::Bool(Vec::new()),
        }
    }

    /// Gather rows by index (indices may repeat / reorder).
    pub fn take(&self, idx: &[usize]) -> Column {
        match self {
            Column::Int64(v) => Column::Int64(idx.iter().map(|&i| v[i]).collect()),
            Column::Float64(v) => Column::Float64(idx.iter().map(|&i| v[i]).collect()),
            Column::Utf8(v) => Column::Utf8(idx.iter().map(|&i| v[i].clone()).collect()),
            Column::Bool(v) => Column::Bool(idx.iter().map(|&i| v[i]).collect()),
        }
    }

    /// Append all values of `other` (must be same dtype).
    pub fn extend(&mut self, other: &Column) -> Result<()> {
        match (self, other) {
            (Column::Int64(a), Column::Int64(b)) => a.extend_from_slice(b),
            (Column::Float64(a), Column::Float64(b)) => a.extend_from_slice(b),
            (Column::Utf8(a), Column::Utf8(b)) => a.extend_from_slice(b),
            (Column::Bool(a), Column::Bool(b)) => a.extend_from_slice(b),
            (a, b) => {
                return Err(Error::DataFrame(format!(
                    "extend dtype mismatch: {} vs {}",
                    a.dtype(),
                    b.dtype()
                )))
            }
        }
        Ok(())
    }

    /// Slice `[start, start+len)` into a new column.
    pub fn slice(&self, start: usize, len: usize) -> Column {
        match self {
            Column::Int64(v) => Column::Int64(v[start..start + len].to_vec()),
            Column::Float64(v) => Column::Float64(v[start..start + len].to_vec()),
            Column::Utf8(v) => Column::Utf8(v[start..start + len].to_vec()),
            Column::Bool(v) => Column::Bool(v[start..start + len].to_vec()),
        }
    }

    /// Borrow as i64 values, erroring on other types.
    pub fn as_i64(&self) -> Result<&[i64]> {
        match self {
            Column::Int64(v) => Ok(v),
            other => Err(Error::DataFrame(format!(
                "expected int64 column, got {}",
                other.dtype()
            ))),
        }
    }

    pub fn as_f64(&self) -> Result<&[f64]> {
        match self {
            Column::Float64(v) => Ok(v),
            other => Err(Error::DataFrame(format!(
                "expected float64 column, got {}",
                other.dtype()
            ))),
        }
    }

    pub fn as_utf8(&self) -> Result<&[String]> {
        match self {
            Column::Utf8(v) => Ok(v),
            other => Err(Error::DataFrame(format!(
                "expected utf8 column, got {}",
                other.dtype()
            ))),
        }
    }

    /// Render a single value for CSV / display.
    pub fn value_to_string(&self, i: usize) -> String {
        match self {
            Column::Int64(v) => v[i].to_string(),
            Column::Float64(v) => format!("{}", v[i]),
            Column::Utf8(v) => v[i].clone(),
            Column::Bool(v) => v[i].to_string(),
        }
    }

    /// Hash of one value (used by the table-level row fingerprint).
    pub fn value_hash(&self, i: usize) -> u64 {
        use crate::util::hash::splitmix64;
        match self {
            Column::Int64(v) => splitmix64(v[i] as u64),
            Column::Float64(v) => splitmix64(v[i].to_bits()),
            Column::Utf8(v) => {
                let mut h = 0xcbf2_9ce4_8422_2325u64;
                for b in v[i].bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x1000_0000_01b3);
                }
                splitmix64(h)
            }
            Column::Bool(v) => splitmix64(v[i] as u64),
        }
    }

    /// Order-insensitive content fingerprint (for distributed-op checks:
    /// shuffles/joins preserve multisets, not order).
    pub fn multiset_fingerprint(&self) -> u64 {
        use crate::util::hash::splitmix64;
        let mut acc = 0u64;
        match self {
            Column::Int64(v) => {
                for &x in v {
                    acc = acc.wrapping_add(splitmix64(x as u64));
                }
            }
            Column::Float64(v) => {
                for &x in v {
                    acc = acc.wrapping_add(splitmix64(x.to_bits()));
                }
            }
            Column::Utf8(v) => {
                for s in v {
                    let mut h = 0xcbf2_9ce4_8422_2325u64;
                    for b in s.bytes() {
                        h ^= b as u64;
                        h = h.wrapping_mul(0x1000_0000_01b3);
                    }
                    acc = acc.wrapping_add(splitmix64(h));
                }
            }
            Column::Bool(v) => {
                for &x in v {
                    acc = acc.wrapping_add(splitmix64(x as u64));
                }
            }
        }
        acc
    }

    /// Approximate in-memory payload size in bytes (for the network model).
    pub fn byte_size(&self) -> usize {
        match self {
            Column::Int64(v) => v.len() * 8,
            Column::Float64(v) => v.len() * 8,
            Column::Utf8(v) => v.iter().map(|s| s.len() + 8).sum(),
            Column::Bool(v) => v.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_and_slice() {
        let c = Column::Int64(vec![10, 20, 30, 40]);
        assert_eq!(c.take(&[3, 0, 0]), Column::Int64(vec![40, 10, 10]));
        assert_eq!(c.slice(1, 2), Column::Int64(vec![20, 30]));
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn extend_checks_dtype() {
        let mut a = Column::Int64(vec![1]);
        assert!(a.extend(&Column::Int64(vec![2])).is_ok());
        assert_eq!(a.len(), 2);
        assert!(a.extend(&Column::Float64(vec![1.0])).is_err());
    }

    #[test]
    fn accessors() {
        let c = Column::Float64(vec![1.5]);
        assert!(c.as_f64().is_ok());
        assert!(c.as_i64().is_err());
        assert_eq!(c.dtype(), DataType::Float64);
    }

    #[test]
    fn fingerprint_order_insensitive() {
        let a = Column::Int64(vec![1, 2, 3]);
        let b = Column::Int64(vec![3, 1, 2]);
        assert_eq!(a.multiset_fingerprint(), b.multiset_fingerprint());
        let c = Column::Int64(vec![1, 2, 4]);
        assert_ne!(a.multiset_fingerprint(), c.multiset_fingerprint());
    }

    #[test]
    fn byte_sizes() {
        assert_eq!(Column::Int64(vec![0; 4]).byte_size(), 32);
        assert_eq!(Column::Bool(vec![true; 4]).byte_size(), 4);
        assert_eq!(
            Column::Utf8(vec!["ab".into()]).byte_size(),
            10
        );
    }

    #[test]
    fn utf8_roundtrip() {
        let c = Column::Utf8(vec!["x".into(), "y".into()]);
        assert_eq!(c.value_to_string(1), "y");
        assert_eq!(c.take(&[1, 0]).as_utf8().unwrap()[0], "y");
    }
}
