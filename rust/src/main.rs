//! `radical-cylon` launcher binary. See `cli` module for usage.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match radical_cylon::cli::dispatch(argv) {
        Ok(out) => println!("{out}"),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
