//! Minimal INI parser: `[section]` headers, `key = value` pairs, `#`/`;`
//! comments, blank lines. Sufficient for experiment configs without a
//! serde dependency (offline environment — DESIGN.md §2).

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Parsed INI document: section name -> key -> value.
#[derive(Clone, Debug, Default)]
pub struct IniDoc {
    sections: HashMap<String, HashMap<String, String>>,
}

impl IniDoc {
    pub fn section(&self, name: &str) -> Option<&HashMap<String, String>> {
        self.sections.get(name)
    }

    pub fn section_names(&self) -> Vec<&str> {
        self.sections.keys().map(|s| s.as_str()).collect()
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }
}

/// Parse INI text. Keys outside any section go into section `""`.
pub fn parse_ini(text: &str) -> Result<IniDoc> {
    let mut doc = IniDoc::default();
    let mut current = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                return Err(Error::Config(format!(
                    "line {}: unterminated section header '{raw}'",
                    lineno + 1
                )));
            };
            current = name.trim().to_string();
            doc.sections.entry(current.clone()).or_default();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(Error::Config(format!(
                "line {}: expected 'key = value', got '{raw}'",
                lineno + 1
            )));
        };
        doc.sections
            .entry(current.clone())
            .or_default()
            .insert(key.trim().to_string(), value.trim().to_string());
    }
    Ok(doc)
}

/// Typed config lookup with environment fallback: the INI value wins,
/// else the `env` variable, else `default`. A value that is *present* but
/// unparsable — from either source — is [`Error::Config`], never silently
/// defaulted (a typo'd `RC_MAX_INFLIGHT=lots` must not mean 4).
pub fn lookup<T: std::str::FromStr>(
    doc: &IniDoc,
    section: &str,
    key: &str,
    env: &str,
    default: T,
) -> Result<T> {
    let (raw, origin) = match doc.get(section, key) {
        Some(v) => (v.to_string(), format!("[{section}] {key}")),
        None => match std::env::var(env) {
            Ok(v) => (v, format!("env {env}")),
            Err(_) => return Ok(default),
        },
    };
    raw.parse().map_err(|_| {
        Error::Config(format!("{origin} value '{raw}' is not a valid {key}"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_prefers_ini_then_env_then_default() {
        let doc = parse_ini("[service]\nmax_inflight = 7\n").unwrap();
        let v: usize =
            lookup(&doc, "service", "max_inflight", "RC_TEST_NO_SUCH_VAR", 4)
                .unwrap();
        assert_eq!(v, 7);
        // Absent key + absent env -> default.
        let v: usize =
            lookup(&doc, "service", "queue_depth", "RC_TEST_NO_SUCH_VAR", 16)
                .unwrap();
        assert_eq!(v, 16);
        // Present-but-garbage INI value errors instead of defaulting.
        let bad = parse_ini("[service]\nmax_inflight = lots\n").unwrap();
        let err = lookup::<usize>(
            &bad,
            "service",
            "max_inflight",
            "RC_TEST_NO_SUCH_VAR",
            4,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("max_inflight"), "{err}");
    }

    #[test]
    fn sections_and_comments() {
        let doc = parse_ini(
            "# top\nglobal = 1\n[a]\nx = 2\n; note\ny = hello world\n[b]\nx = 3\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "global"), Some("1"));
        assert_eq!(doc.get("a", "x"), Some("2"));
        assert_eq!(doc.get("a", "y"), Some("hello world"));
        assert_eq!(doc.get("b", "x"), Some("3"));
        assert_eq!(doc.get("b", "zzz"), None);
        assert!(doc.section("missing").is_none());
    }

    #[test]
    fn whitespace_tolerant() {
        let doc = parse_ini("  [ sec ]  \n  k =  v  \n").unwrap();
        assert_eq!(doc.get("sec", "k"), Some("v"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_ini("[ok]\nnot-a-kv\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        let err2 = parse_ini("[broken\n").unwrap_err().to_string();
        assert!(err2.contains("unterminated"), "{err2}");
    }

    #[test]
    fn value_may_contain_equals() {
        let doc = parse_ini("[s]\nexpr = a = b\n").unwrap();
        assert_eq!(doc.get("s", "expr"), Some("a = b"));
    }
}
