//! Experiment configuration: an INI-subset parser (offline substitute for
//! serde-based config) plus presets for every experiment in the paper
//! (Table 1 and Figs 5–11), scaled per DESIGN.md §2.

mod parser;
mod presets;

pub use parser::{lookup, parse_ini, IniDoc};
pub use presets::{
    preset, preset_ids, RIVANNA_PAPER_RANKS, RIVANNA_SCALED_RANKS, SCALE_NOTE,
    SUMMIT_PAPER_RANKS, SUMMIT_SCALED_RANKS,
};

use crate::error::{Error, Result};

/// Weak vs strong scaling (paper Table 1 WS/SS).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scaling {
    Weak,
    Strong,
}

impl Scaling {
    pub fn name(&self) -> &'static str {
        match self {
            Scaling::Weak => "weak",
            Scaling::Strong => "strong",
        }
    }
}

/// One experiment: which machine, op mix, scaling mode, rank sweep, data
/// sizes, and iteration count.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Experiment id ("table2", "fig5", ... — DESIGN.md §4).
    pub id: String,
    /// "rivanna" | "summit" | "local".
    pub machine: String,
    /// "join" | "sort" | "hetero" (join+sort mix).
    pub op: String,
    pub scaling: Scaling,
    /// Rank counts to sweep (scaled-down from the paper's).
    pub parallelisms: Vec<usize>,
    /// Rows per rank for weak scaling (scaled: paper 35 M -> 35 K).
    pub rows_per_rank: usize,
    /// Total rows for strong scaling (scaled: paper 3.5 B -> 3.5 M).
    pub total_rows: usize,
    /// Repetitions per configuration (paper: 10).
    pub iterations: usize,
    pub seed: u64,
    /// Shared-memory worker threads for the in-process data plane (the
    /// morsel-parallel kernels and `Pipeline::run_pooled`). `0` = auto
    /// (one worker per available core), `1` = sequential. Distinct from
    /// `parallelisms`, which sweeps simulated *rank* counts.
    pub parallelism: usize,
    /// Morsel threshold: kernels dispatch to their parallel twins only
    /// at or above this many rows. Defaults to
    /// [`crate::util::pool::DEFAULT_PAR_MIN_ROWS`]; tests lower it to
    /// force the parallel path on small fixtures.
    pub par_min_rows: usize,
}

impl ExperimentConfig {
    /// Parse from an INI document with an `[experiment]` section.
    pub fn from_ini(doc: &IniDoc) -> Result<ExperimentConfig> {
        let sec = doc
            .section("experiment")
            .ok_or_else(|| Error::Config("missing [experiment] section".into()))?;
        let get = |k: &str| {
            sec.get(k)
                .ok_or_else(|| Error::Config(format!("missing key '{k}'")))
        };
        let parse_usize = |k: &str| -> Result<usize> {
            get(k)?
                .parse()
                .map_err(|_| Error::Config(format!("key '{k}' is not an integer")))
        };
        let scaling = match get("scaling")?.as_str() {
            "weak" => Scaling::Weak,
            "strong" => Scaling::Strong,
            other => {
                return Err(Error::Config(format!("unknown scaling '{other}'")))
            }
        };
        let parallelisms: Vec<usize> = get("parallelisms")?
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| Error::Config(format!("bad parallelism '{s}'")))
            })
            .collect::<Result<_>>()?;
        if parallelisms.is_empty() {
            return Err(Error::Config("empty parallelism sweep".into()));
        }
        Ok(ExperimentConfig {
            id: get("id")?.clone(),
            machine: get("machine")?.clone(),
            op: get("op")?.clone(),
            scaling,
            parallelisms,
            rows_per_rank: parse_usize("rows_per_rank")?,
            total_rows: parse_usize("total_rows")?,
            iterations: parse_usize("iterations")?,
            seed: sec
                .get("seed")
                .map(|s| s.parse().unwrap_or(0xC71))
                .unwrap_or(0xC71),
            parallelism: match sec.get("parallelism") {
                None => 1,
                Some(s) => s.parse().map_err(|_| {
                    Error::Config("key 'parallelism' is not an integer".into())
                })?,
            },
            par_min_rows: match sec.get("par_min_rows") {
                None => crate::util::pool::DEFAULT_PAR_MIN_ROWS,
                Some(s) => s.parse().map_err(|_| {
                    Error::Config("key 'par_min_rows' is not an integer".into())
                })?,
            },
        })
    }

    /// Size the global thread pool from this config's `parallelism` knob
    /// and latch the morsel threshold from `par_min_rows` (first caller
    /// wins for both — they are process-global).
    pub fn apply_parallelism(&self) {
        crate::util::pool::configure(self.parallelism);
        crate::util::pool::configure_par_min_rows(self.par_min_rows);
    }

    /// Rows per rank at a given parallelism under this config's scaling.
    pub fn rows_at(&self, ranks: usize) -> usize {
        match self.scaling {
            Scaling::Weak => self.rows_per_rank,
            Scaling::Strong => self.total_rows.div_ceil(ranks.max(1)),
        }
    }

    /// The machine spec this experiment targets.
    pub fn machine_spec(&self) -> Result<crate::cluster::MachineSpec> {
        match self.machine.as_str() {
            "rivanna" => Ok(crate::cluster::MachineSpec::rivanna()),
            "summit" => Ok(crate::cluster::MachineSpec::summit()),
            "local" => Ok(crate::cluster::MachineSpec::local(8)),
            other => Err(Error::Config(format!("unknown machine '{other}'"))),
        }
    }
}

/// Apply an optional `[faults]` INI section to the process-global fault
/// machinery ([`crate::util::faults`]). Three keys are routed to the
/// retry/deadline knobs rather than the injection plan:
///
/// | key                  | effect                                        |
/// |----------------------|-----------------------------------------------|
/// | `retry_max_attempts` | [`faults::configure_retry`] `max_attempts`    |
/// | `retry_base_ms`      | [`faults::configure_retry`] backoff base      |
/// | `task_deadline_s`    | [`faults::configure_deadline`] (0 = none)     |
///
/// Every other key is fed through [`FaultPlan::apply_key`]
/// (`<site> = <prob>|@N`, `<site>.delay_ms`, `<site>.only`, `seed`), and
/// if any site ends up armed the plan is installed via [`faults::arm`].
/// Returns `true` when a plan was armed. With no `[faults]` section this
/// is a no-op (env fallbacks like `RC_FAULTS` are read lazily by the
/// faults module itself).
///
/// [`faults::configure_retry`]: crate::util::faults::configure_retry
/// [`faults::configure_deadline`]: crate::util::faults::configure_deadline
/// [`faults::arm`]: crate::util::faults::arm
/// [`FaultPlan::apply_key`]: crate::util::faults::FaultPlan::apply_key
pub fn apply_faults(doc: &IniDoc) -> Result<bool> {
    use crate::util::faults::{self, FaultPlan};
    let Some(sec) = doc.section("faults") else { return Ok(false) };
    let mut plan = FaultPlan::new(0xC4A05);
    let mut armed_sites = false;
    let mut retry = faults::retry_policy();
    let mut retry_touched = false;
    for (key, value) in sec {
        match key.as_str() {
            "retry_max_attempts" => {
                retry.max_attempts = value.parse().map_err(|_| {
                    Error::Config(format!(
                        "[faults] retry_max_attempts value '{value}' is not \
                         an integer"
                    ))
                })?;
                retry_touched = true;
            }
            "retry_base_ms" => {
                retry.base_ms = value.parse().map_err(|_| {
                    Error::Config(format!(
                        "[faults] retry_base_ms value '{value}' is not an \
                         integer"
                    ))
                })?;
                retry_touched = true;
            }
            "task_deadline_s" => {
                let s: f64 = value.parse().map_err(|_| {
                    Error::Config(format!(
                        "[faults] task_deadline_s value '{value}' is not a \
                         number"
                    ))
                })?;
                faults::configure_deadline(s);
            }
            _ => {
                plan.apply_key(key, value)?;
                armed_sites = armed_sites || key != "seed";
            }
        }
    }
    if retry_touched {
        faults::configure_retry(retry);
    }
    if armed_sites {
        faults::arm(plan);
    }
    Ok(armed_sites)
}

/// Query-service knobs: rank-pool width, admission bounds, cache budget,
/// and fault-tolerance policy. Parsed from an optional `[service]` INI
/// section with per-key environment fallbacks (INI wins, then env, then
/// the default):
///
/// | key                  | env                     | default    |
/// |----------------------|-------------------------|------------|
/// | `ranks`              | `RC_SERVICE_RANKS`      | 4          |
/// | `max_inflight`       | `RC_MAX_INFLIGHT`       | 4          |
/// | `queue_depth`        | `RC_QUEUE_DEPTH`        | 16         |
/// | `max_inflight_bytes` | `RC_MAX_INFLIGHT_BYTES` | 0 (off)    |
/// | `result_cache_bytes` | `RC_RESULT_CACHE_BYTES` | 64 MiB     |
/// | `mem_budget_bytes`   | `RC_MEM_BUDGET`         | 0 (unbounded)|
/// | `admit`              | `RC_ADMIT_POLICY`       | `fifo`     |
/// | `retry_max_attempts` | `RC_RETRY_MAX`          | 1 (off)    |
/// | `shutdown_timeout_s` | `RC_SHUTDOWN_TIMEOUT`   | 0 (forever)|
///
/// `mem_budget_bytes` accepts byte-size suffixes (`256M`, `4G`, `512K`,
/// plain integers) via [`crate::spill::parse_byte_size`]; it feeds the
/// process-global spill governor, not a per-service knob.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// CPU ranks in the service's long-lived pilot (the shared rank pool
    /// every admitted query's DAG nodes are multiplexed across).
    pub ranks: usize,
    /// Queries executing concurrently; further admissions queue.
    pub max_inflight: usize,
    /// Queued submissions beyond the in-flight set; a full queue rejects
    /// with [`Error::Admission`]. `0` = reject-when-busy (no queueing).
    pub queue_depth: usize,
    /// Bound on the summed estimated source bytes of in-flight queries
    /// ([`crate::pipeline::Pipeline::estimated_source_bytes`]); `0`
    /// disables the byte bound. A single query larger than the bound is
    /// still admitted when it is alone, so it cannot starve forever.
    pub max_inflight_bytes: u64,
    /// LRU result-cache budget (bytes of cached collected tables,
    /// [`crate::comm::CommData::approx_bytes`]-style window accounting);
    /// `0` disables result caching.
    pub result_cache_bytes: u64,
    /// Process-wide materialized-memory budget for the out-of-core data
    /// plane ([`crate::spill::MemoryBudget`]). `0` = unbounded (never
    /// spill). [`Self::apply_memory_budget`] latches it into the global
    /// governor (first caller wins — it is process-global).
    pub mem_budget_bytes: u64,
    /// Queue ordering when capacity frees up.
    pub admit: crate::service::AdmitPolicy,
    /// Total attempts (including the first) the service gives a query
    /// whose failure is transient ([`crate::error::Error::is_transient`]).
    /// `1` disables query-level retry.
    pub retry_max_attempts: u32,
    /// How long [`crate::service::QueryService::shutdown`] waits for
    /// in-flight queries to drain before cancelling the stragglers and
    /// returning [`crate::error::Error::Timeout`]. `0` = wait forever
    /// (the pre-deadline behavior).
    pub shutdown_timeout_s: f64,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            ranks: 4,
            max_inflight: 4,
            queue_depth: 16,
            max_inflight_bytes: 0,
            result_cache_bytes: 64 * 1024 * 1024,
            mem_budget_bytes: 0,
            admit: crate::service::AdmitPolicy::Fifo,
            retry_max_attempts: 1,
            shutdown_timeout_s: 0.0,
        }
    }
}

impl ServiceConfig {
    /// Parse from an INI document's optional `[service]` section, with
    /// env fallbacks per key (see the type docs), then [`Self::validate`].
    pub fn from_ini(doc: &IniDoc) -> Result<ServiceConfig> {
        let d = ServiceConfig::default();
        let s = "service";
        let cfg = ServiceConfig {
            ranks: lookup(doc, s, "ranks", "RC_SERVICE_RANKS", d.ranks)?,
            max_inflight: lookup(
                doc,
                s,
                "max_inflight",
                "RC_MAX_INFLIGHT",
                d.max_inflight,
            )?,
            queue_depth: lookup(
                doc,
                s,
                "queue_depth",
                "RC_QUEUE_DEPTH",
                d.queue_depth,
            )?,
            max_inflight_bytes: lookup(
                doc,
                s,
                "max_inflight_bytes",
                "RC_MAX_INFLIGHT_BYTES",
                d.max_inflight_bytes,
            )?,
            result_cache_bytes: lookup(
                doc,
                s,
                "result_cache_bytes",
                "RC_RESULT_CACHE_BYTES",
                d.result_cache_bytes,
            )?,
            mem_budget_bytes: {
                // Unlike the plain-integer knobs this one accepts byte
                // suffixes ("256M"), so route the raw string through
                // `spill::parse_byte_size` instead of `FromStr`.
                let raw =
                    lookup(doc, s, "mem_budget_bytes", "RC_MEM_BUDGET", String::new())?;
                if raw.is_empty() {
                    d.mem_budget_bytes
                } else {
                    crate::spill::parse_byte_size(&raw).ok_or_else(|| {
                        Error::Config(format!(
                            "service.mem_budget_bytes value '{raw}' is not a \
                             byte size (try 268435456, 256M, or 4G)"
                        ))
                    })?
                }
            },
            admit: match lookup(
                doc,
                s,
                "admit",
                "RC_ADMIT_POLICY",
                "fifo".to_string(),
            )?
            .as_str()
            {
                "fifo" => crate::service::AdmitPolicy::Fifo,
                "cost" => crate::service::AdmitPolicy::CostAware,
                other => {
                    return Err(Error::Config(format!(
                        "unknown admit policy '{other}' (expected fifo|cost)"
                    )))
                }
            },
            retry_max_attempts: lookup(
                doc,
                s,
                "retry_max_attempts",
                "RC_RETRY_MAX",
                d.retry_max_attempts,
            )?,
            shutdown_timeout_s: lookup(
                doc,
                s,
                "shutdown_timeout_s",
                "RC_SHUTDOWN_TIMEOUT",
                d.shutdown_timeout_s,
            )?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse from environment fallbacks only (no INI file).
    pub fn from_env() -> Result<ServiceConfig> {
        ServiceConfig::from_ini(&IniDoc::default())
    }

    /// Reject configurations that could never run anything.
    pub fn validate(&self) -> Result<()> {
        if self.ranks == 0 {
            return Err(Error::Config(
                "service.ranks must be >= 1 (the shared pilot needs a rank \
                 pool)"
                    .into(),
            ));
        }
        if self.max_inflight == 0 {
            return Err(Error::Config(format!(
                "service.max_inflight must be >= 1: with 0 in-flight slots \
                 nothing ever executes (queue_depth {} would just fill up \
                 and reject)",
                self.queue_depth
            )));
        }
        if self.retry_max_attempts == 0 {
            return Err(Error::Config(
                "service.retry_max_attempts must be >= 1 (1 = no retry; 0 \
                 would mean queries never even run once)"
                    .into(),
            ));
        }
        if !self.shutdown_timeout_s.is_finite() || self.shutdown_timeout_s < 0.0
        {
            return Err(Error::Config(format!(
                "service.shutdown_timeout_s must be a finite value >= 0 \
                 (0 = wait forever), got {}",
                self.shutdown_timeout_s
            )));
        }
        Ok(())
    }

    /// Latch this config's `mem_budget_bytes` into the process-global
    /// spill governor ([`crate::spill::configure`], first caller wins).
    /// A `0` budget is a no-op: the governor stays on its lazy
    /// `RC_MEM_BUDGET` env default instead of being pinned unbounded.
    /// Returns whether this call installed the limit.
    pub fn apply_memory_budget(&self) -> bool {
        if self.mem_budget_bytes == 0 {
            return false;
        }
        crate::spill::configure(self.mem_budget_bytes)
    }

    /// The drain deadline as a `Duration`, `None` when 0 (wait forever).
    pub fn shutdown_timeout(&self) -> Option<std::time::Duration> {
        if self.shutdown_timeout_s > 0.0 {
            Some(std::time::Duration::from_secs_f64(self.shutdown_timeout_s))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment definition
[experiment]
id = custom
machine = rivanna
op = join
scaling = strong
parallelisms = 8, 12, 16
rows_per_rank = 35000
total_rows = 3500000
iterations = 5
"#;

    #[test]
    fn parses_full_config() {
        let doc = parse_ini(SAMPLE).unwrap();
        let c = ExperimentConfig::from_ini(&doc).unwrap();
        assert_eq!(c.id, "custom");
        assert_eq!(c.scaling, Scaling::Strong);
        assert_eq!(c.parallelisms, vec![8, 12, 16]);
        assert_eq!(c.rows_at(8), 437_500);
        assert_eq!(c.machine_spec().unwrap().cores_per_node, 37);
    }

    #[test]
    fn weak_scaling_rows_constant() {
        let doc = parse_ini(&SAMPLE.replace("strong", "weak")).unwrap();
        let c = ExperimentConfig::from_ini(&doc).unwrap();
        assert_eq!(c.rows_at(8), 35000);
        assert_eq!(c.rows_at(64), 35000);
    }

    #[test]
    fn missing_key_is_informative() {
        let doc = parse_ini("[experiment]\nid = x\n").unwrap();
        let err = ExperimentConfig::from_ini(&doc).unwrap_err().to_string();
        assert!(err.contains("missing key"), "{err}");
    }

    #[test]
    fn parallelism_knob_defaults_and_parses() {
        let doc = parse_ini(SAMPLE).unwrap();
        let c = ExperimentConfig::from_ini(&doc).unwrap();
        assert_eq!(c.parallelism, 1, "absent key means sequential");

        let with_knob = SAMPLE.replace("iterations = 5", "iterations = 5\nparallelism = 4");
        let doc = parse_ini(&with_knob).unwrap();
        let c = ExperimentConfig::from_ini(&doc).unwrap();
        assert_eq!(c.parallelism, 4);

        let bad = SAMPLE.replace("iterations = 5", "iterations = 5\nparallelism = lots");
        let doc = parse_ini(&bad).unwrap();
        let err = ExperimentConfig::from_ini(&doc).unwrap_err().to_string();
        assert!(err.contains("parallelism"), "{err}");
    }

    #[test]
    fn par_min_rows_knob_defaults_and_parses() {
        let doc = parse_ini(SAMPLE).unwrap();
        let c = ExperimentConfig::from_ini(&doc).unwrap();
        assert_eq!(
            c.par_min_rows,
            crate::util::pool::DEFAULT_PAR_MIN_ROWS,
            "absent key means the built-in morsel threshold"
        );

        let with_knob =
            SAMPLE.replace("iterations = 5", "iterations = 5\npar_min_rows = 64");
        let doc = parse_ini(&with_knob).unwrap();
        let c = ExperimentConfig::from_ini(&doc).unwrap();
        assert_eq!(c.par_min_rows, 64);

        let bad =
            SAMPLE.replace("iterations = 5", "iterations = 5\npar_min_rows = tiny");
        let doc = parse_ini(&bad).unwrap();
        let err = ExperimentConfig::from_ini(&doc).unwrap_err().to_string();
        assert!(err.contains("par_min_rows"), "{err}");
    }

    #[test]
    fn service_config_defaults_and_parses() {
        // No [service] section at all -> defaults.
        let c = ServiceConfig::from_ini(&parse_ini(SAMPLE).unwrap()).unwrap();
        assert_eq!(c.ranks, 4);
        assert_eq!(c.max_inflight, 4);
        assert_eq!(c.queue_depth, 16);
        assert_eq!(c.max_inflight_bytes, 0);
        assert_eq!(c.result_cache_bytes, 64 * 1024 * 1024);
        assert_eq!(c.admit, crate::service::AdmitPolicy::Fifo);
        assert_eq!(c.retry_max_attempts, 1, "retry is off by default");
        assert_eq!(c.shutdown_timeout_s, 0.0, "drain forever by default");
        assert_eq!(c.shutdown_timeout(), None);

        let ini = "[service]\nranks = 8\nmax_inflight = 2\nqueue_depth = 0\n\
                   max_inflight_bytes = 1048576\nresult_cache_bytes = 0\n\
                   mem_budget_bytes = 256M\nadmit = cost\n\
                   retry_max_attempts = 3\nshutdown_timeout_s = 2.5\n";
        let c = ServiceConfig::from_ini(&parse_ini(ini).unwrap()).unwrap();
        assert_eq!(c.ranks, 8);
        assert_eq!(c.max_inflight, 2);
        assert_eq!(c.queue_depth, 0, "0 = reject-when-busy is legal");
        assert_eq!(c.max_inflight_bytes, 1_048_576);
        assert_eq!(c.result_cache_bytes, 0);
        assert_eq!(c.mem_budget_bytes, 256 << 20, "byte suffixes accepted");
        assert_eq!(c.admit, crate::service::AdmitPolicy::CostAware);
        assert_eq!(c.retry_max_attempts, 3);
        assert_eq!(
            c.shutdown_timeout(),
            Some(std::time::Duration::from_millis(2500))
        );
    }

    #[test]
    fn mem_budget_parses_plain_and_suffixed_and_rejects_garbage() {
        // INI wins over any env fallback, so these are deterministic even
        // under a low-memory CI leg that exports RC_MEM_BUDGET.
        for (raw, want) in
            [("4096", 4096u64), ("512K", 512 << 10), ("2G", 2 << 30)]
        {
            let ini = format!("[service]\nmem_budget_bytes = {raw}\n");
            let c = ServiceConfig::from_ini(&parse_ini(&ini).unwrap()).unwrap();
            assert_eq!(c.mem_budget_bytes, want, "{raw}");
        }
        // An explicit 0 means unbounded and must not latch the governor.
        let ini = "[service]\nmem_budget_bytes = 0\n";
        let c = ServiceConfig::from_ini(&parse_ini(ini).unwrap()).unwrap();
        assert_eq!(c.mem_budget_bytes, 0);
        assert!(!c.apply_memory_budget(), "0 budget leaves the governor be");
        let ini = "[service]\nmem_budget_bytes = plenty\n";
        let err =
            ServiceConfig::from_ini(&parse_ini(ini).unwrap()).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("mem_budget_bytes"), "{err}");
    }

    #[test]
    fn service_config_rejects_nonsense() {
        // 0 in-flight with 0 queue: nothing could ever run.
        let ini = "[service]\nmax_inflight = 0\nqueue_depth = 0\n";
        let err = ServiceConfig::from_ini(&parse_ini(ini).unwrap()).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("max_inflight"), "{err}");
        // 0 in-flight with a queue: queued work would never be promoted.
        let ini = "[service]\nmax_inflight = 0\nqueue_depth = 8\n";
        assert!(ServiceConfig::from_ini(&parse_ini(ini).unwrap()).is_err());
        // Zero-rank pool.
        let ini = "[service]\nranks = 0\n";
        assert!(ServiceConfig::from_ini(&parse_ini(ini).unwrap()).is_err());
        // Unknown policy and unparsable numbers are Config errors too.
        let ini = "[service]\nadmit = lifo\n";
        assert!(ServiceConfig::from_ini(&parse_ini(ini).unwrap()).is_err());
        let ini = "[service]\nqueue_depth = deep\n";
        assert!(ServiceConfig::from_ini(&parse_ini(ini).unwrap()).is_err());
        // 0 retry attempts would mean the first run never happens.
        let ini = "[service]\nretry_max_attempts = 0\n";
        assert!(ServiceConfig::from_ini(&parse_ini(ini).unwrap()).is_err());
        // Negative drain deadlines are nonsense, not "forever".
        let ini = "[service]\nshutdown_timeout_s = -1\n";
        assert!(ServiceConfig::from_ini(&parse_ini(ini).unwrap()).is_err());
    }

    #[test]
    fn faults_section_arms_plan_and_routes_policy_keys() {
        use crate::util::faults;
        let _g = faults::test_guard();
        // No [faults] section: nothing armed, nothing touched.
        assert!(!apply_faults(&parse_ini(SAMPLE).unwrap()).unwrap());

        let ini = "[faults]\nseed = 11\nagent.task = 0.25\n\
                   agent.task.only = chaos\npool.job = @2\n\
                   retry_max_attempts = 3\nretry_base_ms = 5\n\
                   task_deadline_s = 1.5\n";
        let armed = apply_faults(&parse_ini(ini).unwrap()).unwrap();
        assert!(armed, "site keys present -> plan armed");
        assert!(faults::armed());
        let policy = faults::retry_policy();
        assert_eq!(policy.max_attempts, 3);
        assert_eq!(policy.base_ms, 5);
        assert_eq!(
            faults::default_deadline(),
            Some(std::time::Duration::from_millis(1500))
        );
        // Restore process defaults for neighboring tests.
        faults::disarm();
        faults::configure_retry(faults::RetryPolicy::none());
        faults::configure_deadline(0.0);

        // A seed alone arms nothing; an unknown site is a typed error.
        assert!(!apply_faults(&parse_ini("[faults]\nseed = 3\n").unwrap())
            .unwrap());
        assert!(!faults::armed());
        let err = apply_faults(
            &parse_ini("[faults]\nagent.nap = 0.5\n").unwrap(),
        )
        .unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("unknown fault site"), "{err}");
        // Policy-key typos are Config errors too, not silent defaults.
        assert!(apply_faults(
            &parse_ini("[faults]\nretry_max_attempts = lots\n").unwrap()
        )
        .is_err());
    }

    #[test]
    fn bad_scaling_rejected() {
        let doc =
            parse_ini(&SAMPLE.replace("scaling = strong", "scaling = diagonal"))
                .unwrap();
        assert!(ExperimentConfig::from_ini(&doc).is_err());
    }
}
