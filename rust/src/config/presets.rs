//! Experiment presets matching the paper's Table 1 and Figs 5–11, scaled
//! down per DESIGN.md §2 (rows ÷1000; Rivanna ranks 148–518 → 8–28 threads,
//! Summit ranks 84–2688 → 2–64 threads).

use super::{ExperimentConfig, Scaling};

/// The scale mapping documented in every report header.
pub const SCALE_NOTE: &str =
    "scaled reproduction: rows /1000 (35M->35K per rank weak, 3.5B->3.5M strong); \
     Rivanna ranks {148..518}->{8..28}; Summit ranks {84..2688}->{2..64}";

/// Paper parallelisms (Rivanna Table 2): 148,222,296,370,444,518.
pub const RIVANNA_PAPER_RANKS: [usize; 6] = [148, 222, 296, 370, 444, 518];
/// Scaled Rivanna sweep (÷18.5, node-multiples of the scaled machine).
pub const RIVANNA_SCALED_RANKS: [usize; 6] = [8, 12, 16, 20, 24, 28];

/// Paper parallelisms (Summit): 84..2688 (2-64 nodes x 42).
pub const SUMMIT_PAPER_RANKS: [usize; 6] = [84, 168, 336, 672, 1344, 2688];
/// Scaled Summit sweep (÷42).
pub const SUMMIT_SCALED_RANKS: [usize; 6] = [2, 4, 8, 16, 32, 64];

pub const ROWS_PER_RANK_SCALED: usize = 35_000; // paper: 35M

/// Strong-scaling totals: the paper divides 3.5 B rows over its rank
/// sweep; we divide a total chosen so rows-per-rank at each *scaled*
/// parallelism equals the paper's rows-per-rank at the corresponding
/// parallelism ÷1000 (ranks were scaled by ~18.5x Rivanna / 42x Summit,
/// rows by 1000x — the quotient keeps per-rank load consistent).
pub const TOTAL_ROWS_SCALED_RIVANNA: usize = 190_000; // ≈ 3.5B/1000/18.5
pub const TOTAL_ROWS_SCALED_SUMMIT: usize = 84_000; // ≈ 3.5B/1000/42

fn base(id: &str, machine: &str, op: &str, scaling: Scaling) -> ExperimentConfig {
    let (parallelisms, total_rows) = match machine {
        "rivanna" => (RIVANNA_SCALED_RANKS.to_vec(), TOTAL_ROWS_SCALED_RIVANNA),
        _ => (SUMMIT_SCALED_RANKS.to_vec(), TOTAL_ROWS_SCALED_SUMMIT),
    };
    ExperimentConfig {
        id: id.to_string(),
        machine: machine.to_string(),
        op: op.to_string(),
        scaling,
        parallelisms,
        rows_per_rank: ROWS_PER_RANK_SCALED,
        total_rows,
        iterations: 10,
        seed: 0xC71,
        parallelism: 1,
    }
}

/// All experiment ids with a preset.
pub fn preset_ids() -> Vec<&'static str> {
    vec![
        "table2-join-weak",
        "table2-join-strong",
        "table2-sort-weak",
        "table2-sort-strong",
        "fig5-weak",
        "fig5-strong",
        "fig6-weak",
        "fig6-strong",
        "fig7-weak",
        "fig7-strong",
        "fig8-weak",
        "fig8-strong",
        "fig9",
        "fig10-weak",
        "fig10-strong",
        "fig11",
        "overhead",
    ]
}

/// Look up a preset by experiment id (DESIGN.md §4 index).
pub fn preset(id: &str) -> Option<ExperimentConfig> {
    let c = match id {
        // Table 2: RP-Cylon execution time + overheads on Rivanna.
        "table2-join-weak" => base(id, "rivanna", "join", Scaling::Weak),
        "table2-join-strong" => base(id, "rivanna", "join", Scaling::Strong),
        "table2-sort-weak" => base(id, "rivanna", "sort", Scaling::Weak),
        "table2-sort-strong" => base(id, "rivanna", "sort", Scaling::Strong),
        // Fig 5/7: BM vs RP on Rivanna (join / sort).
        "fig5-weak" => base(id, "rivanna", "join", Scaling::Weak),
        "fig5-strong" => base(id, "rivanna", "join", Scaling::Strong),
        "fig7-weak" => base(id, "rivanna", "sort", Scaling::Weak),
        "fig7-strong" => base(id, "rivanna", "sort", Scaling::Strong),
        // Fig 6/8: BM vs RP on Summit (join / sort).
        "fig6-weak" => base(id, "summit", "join", Scaling::Weak),
        "fig6-strong" => base(id, "summit", "join", Scaling::Strong),
        "fig8-weak" => base(id, "summit", "sort", Scaling::Weak),
        "fig8-strong" => base(id, "summit", "sort", Scaling::Strong),
        // Fig 9: 4-op heterogeneous scaling on Summit.
        "fig9" => base(id, "summit", "hetero", Scaling::Weak),
        // Fig 10/11: heterogeneous vs batch on Summit.
        "fig10-weak" => base(id, "summit", "hetero", Scaling::Weak),
        "fig10-strong" => base(id, "summit", "hetero", Scaling::Strong),
        "fig11" => base(id, "summit", "hetero", Scaling::Weak),
        // §4.4 communicator-construction overhead microbench.
        "overhead" => {
            let mut c = base(id, "rivanna", "sort", Scaling::Weak);
            c.rows_per_rank = 1000;
            c
        }
        _ => return None,
    };
    Some(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_id_has_a_preset() {
        for id in preset_ids() {
            let c = preset(id).unwrap_or_else(|| panic!("no preset for {id}"));
            assert_eq!(c.id, id);
            assert!(!c.parallelisms.is_empty());
            assert!(c.iterations > 0);
            assert!(c.machine_spec().is_ok());
        }
        assert!(preset("nope").is_none());
    }

    #[test]
    fn scaled_sweeps_fit_the_machines() {
        let r = preset("fig5-weak").unwrap();
        let m = r.machine_spec().unwrap();
        assert!(r.parallelisms.iter().all(|&p| p <= m.total_cores()));
        let s = preset("fig8-strong").unwrap();
        let m = s.machine_spec().unwrap();
        assert!(s.parallelisms.iter().all(|&p| p <= m.total_cores()));
    }

    #[test]
    fn scaling_modes_match_table1() {
        assert_eq!(preset("table2-join-weak").unwrap().scaling, Scaling::Weak);
        assert_eq!(
            preset("table2-sort-strong").unwrap().scaling,
            Scaling::Strong
        );
        assert_eq!(preset("fig10-weak").unwrap().op, "hetero");
    }
}
