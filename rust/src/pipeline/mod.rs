//! Dataframe-task DAG (paper §4.4: "A collection of data frame operators
//! can be arranged in a directed acyclic graph (DAG). Execution of this DAG
//! can further be improved by identifying independent branches ... and
//! executing such independent tasks parallelly.").
//!
//! A [`Pipeline`] is a DAG of [`TaskDescription`]s. Three executors ship:
//!
//! * **Dataflow** ([`Pipeline::run_dataflow`], the default behind
//!   [`Pipeline::execute`]) — an event-driven, dependency-counting
//!   scheduler. Every node is submitted to the pilot's TaskManager the
//!   moment its in-degree drops to zero, so an independent ready branch
//!   never waits on an unrelated slow task, and ranks freed by one node are
//!   reused by the next immediately. Ready-set ordering is pluggable via
//!   [`ReadyPolicy`] (FIFO vs critical-path-first). Completion events feed
//!   the dependency counters over a channel, posted by per-task
//!   [`on_terminal`](crate::pilot::TaskHandle::on_terminal) callbacks — no
//!   parked waiter thread per node.
//! * **Pooled** ([`Pipeline::run_pooled`]) — the same dependency-counting
//!   scheduler, but the ready set executes **concurrently on a
//!   [`ThreadPool`](crate::util::pool::ThreadPool)** through a caller
//!   -supplied task closure (no pilot required): independent ready nodes
//!   run in parallel under the chosen [`ReadyPolicy`] submission order,
//!   completions flow back over a channel, and a panicking task surfaces
//!   as a failed node instead of wedging the scheduler.
//! * **Waves** ([`Pipeline::run_waves`]) — the original topological-wave
//!   executor, kept as the comparison baseline: every wave is a barrier, so
//!   a slow task in wave *k* stalls ready tasks in wave *k+1*
//!   (`benches/pipeline_dataflow.rs` measures the gap).
//!
//! **Table handoff:** a node added with [`Pipeline::add_piped`] (one
//! upstream) or [`Pipeline::add_piped_multi`] (one per operator input — a
//! join consumes **both** sides from upstream tasks) consumes the gathered
//! output tables of upstream nodes instead of regenerating synthetic data —
//! the executor marks each producer with `keep_output`, threads the
//! resulting [`Arc<ChunkedTable>`](crate::df::ChunkedTable)s into the
//! consumer's [`TaskDescription::inputs`], and the consumer's ranks each
//! carve a contiguous window zero-copy
//! ([`crate::ops::dist::partition_slice`]). The producer's gathered parts
//! are never flattened on this path; a consumer rank materializes at most
//! its own window.
//!
//! Both executors fill a [`PipelineMetrics`] with per-node timings,
//! critical-path, and rank-idle accounting.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::df::ChunkedTable;
#[cfg(test)]
use crate::df::Table;
use crate::error::{Error, Result};
use crate::metrics::{NodeMetric, PipelineMetrics};
use crate::pilot::{TaskDescription, TaskManager, TaskResult};
use crate::raptor::ReadyPolicy;

/// A node in the pipeline DAG.
#[derive(Clone, Debug)]
struct Node {
    td: TaskDescription,
    deps: Vec<usize>,
    /// Dependencies whose gathered output tables become this node's staged
    /// inputs, in operator-input order (a join lists left then right).
    pipe_from: Vec<usize>,
}

/// Results plus scheduling metrics from one pipeline execution.
#[derive(Clone, Debug)]
pub struct PipelineRun {
    /// Per-node results in node-id order.
    pub results: Vec<TaskResult>,
    pub metrics: PipelineMetrics,
}

/// DAG of Cylon tasks with explicit dependencies.
#[derive(Clone, Debug, Default)]
pub struct Pipeline {
    nodes: Vec<Node>,
}

impl Pipeline {
    pub fn new() -> Pipeline {
        Pipeline::default()
    }

    /// Add a task depending on previously-added node ids; returns its id.
    pub fn add(&mut self, td: TaskDescription, deps: &[usize]) -> usize {
        let id = self.nodes.len();
        self.nodes.push(Node { td, deps: deps.to_vec(), pipe_from: Vec::new() });
        id
    }

    /// Add a task that consumes the output table of dependency `from` as
    /// its (single) staged input (table handoff). `from` must be listed in
    /// `deps`; violations are reported by [`Pipeline::validate`].
    pub fn add_piped(
        &mut self,
        td: TaskDescription,
        deps: &[usize],
        from: usize,
    ) -> usize {
        self.add_piped_multi(td, deps, &[from])
    }

    /// Add a task that consumes the output tables of several dependencies,
    /// one per operator input in order — e.g. a join piped on **both**
    /// sides lists `&[left, right]`. Every source must be listed in
    /// `deps`; violations are reported by [`Pipeline::validate`].
    pub fn add_piped_multi(
        &mut self,
        td: TaskDescription,
        deps: &[usize],
        from: &[usize],
    ) -> usize {
        let id = self.nodes.len();
        self.nodes.push(Node {
            td,
            deps: deps.to_vec(),
            pipe_from: from.to_vec(),
        });
        id
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Task names in node-id order (diagnostics; lets tests pin the shape
    /// a plan lowered to, since auto-derived names are `"{op}-{id}"`).
    pub fn node_names(&self) -> Vec<&str> {
        self.nodes.iter().map(|n| n.td.name.as_str()).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Widest private communicator any node requests — a DAG can only run
    /// on a pilot with at least this many ranks, so the query service
    /// rejects wider plans at submission instead of failing mid-DAG.
    pub fn max_ranks(&self) -> usize {
        self.nodes.iter().map(|n| n.td.ranks).max().unwrap_or(0)
    }

    /// Rough bytes the DAG's synthetic sources will materialize: Σ over
    /// source nodes of `rows_per_rank × ranks × 16` (the generated
    /// `(key: int64, val: float64)` row is 16 bytes — the same accounting
    /// [`crate::comm::CommData::approx_bytes`] charges for a two-column
    /// table window). Derived nodes declare no synthetic workload, so
    /// this is a floor on the query's working set, which is exactly what
    /// the service's byte-bounded admission controller needs: an
    /// estimate available *before* anything runs.
    pub fn estimated_source_bytes(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| n.td.rows_per_rank as u64 * n.td.ranks as u64 * 16)
            .sum()
    }

    /// Validate: deps reference earlier nodes only (DAG by construction —
    /// forward refs and self-cycles are impossible to express, so rejecting
    /// them here rejects every cycle), and pipe sources are dependencies.
    pub fn validate(&self) -> Result<()> {
        for (i, n) in self.nodes.iter().enumerate() {
            for &d in &n.deps {
                if d >= i {
                    return Err(Error::Pilot(format!(
                        "node {i} ('{}') depends on {d}, which is not an earlier node",
                        n.td.name
                    )));
                }
            }
            for &src in &n.pipe_from {
                if !n.deps.contains(&src) {
                    return Err(Error::Pilot(format!(
                        "node {i} ('{}') pipes from {src}, which is not one of its \
                         dependencies",
                        n.td.name
                    )));
                }
            }
        }
        Ok(())
    }

    /// Topological waves: wave k contains every node whose dependencies all
    /// sit in waves < k. Independent branches land in the same wave.
    pub fn waves(&self) -> Result<Vec<Vec<usize>>> {
        self.validate()?;
        let mut wave_of = vec![0usize; self.nodes.len()];
        let mut maxw = 0;
        for (i, n) in self.nodes.iter().enumerate() {
            let w = n
                .deps
                .iter()
                .map(|&d| wave_of[d] + 1)
                .max()
                .unwrap_or(0);
            wave_of[i] = w;
            maxw = maxw.max(w);
        }
        let mut waves = vec![Vec::new(); maxw + 1];
        for (i, &w) in wave_of.iter().enumerate() {
            waves[w].push(i);
        }
        Ok(waves)
    }

    /// Execute the DAG (dataflow scheduler, FIFO ready order) and return
    /// the per-node results. See [`Pipeline::run_dataflow`] for metrics.
    pub fn execute(&self, tm: &TaskManager) -> Result<Vec<TaskResult>> {
        self.run_dataflow(tm, ReadyPolicy::Fifo).map(|run| run.results)
    }

    /// Execute wave-by-wave (the barrier baseline) and return the results.
    pub fn execute_waves(&self, tm: &TaskManager) -> Result<Vec<TaskResult>> {
        self.run_waves(tm).map(|run| run.results)
    }

    /// Execute every node serially in topological (id) order through an
    /// arbitrary task executor, threading the table handoff between nodes
    /// exactly like the pilot executors do. This is how engines without a
    /// shared pilot (bare-metal, batch) drive a DAG: one independent launch
    /// per node, outputs carried across launches. Fails fast on the first
    /// node that does not finish `Done`.
    pub fn run_sequential<F>(&self, mut exec: F) -> Result<Vec<TaskResult>>
    where
        F: FnMut(TaskDescription) -> Result<TaskResult>,
    {
        self.validate()?;
        let keep = self.keep_flags();
        let n = self.nodes.len();
        let mut outputs: Vec<Option<Arc<ChunkedTable>>> =
            (0..n).map(|_| None).collect();
        let mut results = Vec::with_capacity(n);
        // Node ids are topological by construction (deps reference earlier
        // ids only), so id order is a valid serial schedule.
        for i in 0..n {
            let td = self.prepared_td(i, &keep, &outputs);
            let r = exec(td)?;
            if !r.is_done() {
                return Err(Error::TaskFailed(format!(
                    "pipeline node {i} ('{}') failed: {}",
                    r.name,
                    r.error.clone().unwrap_or_default()
                )));
            }
            outputs[i] = r.output.clone();
            results.push(r);
        }
        Ok(results)
    }

    /// Nodes that must keep (gather) their output for downstream pipes.
    fn keep_flags(&self) -> Vec<bool> {
        let mut keep: Vec<bool> = self.nodes.iter().map(|n| n.td.keep_output).collect();
        for n in &self.nodes {
            for &src in &n.pipe_from {
                keep[src] = true;
            }
        }
        keep
    }

    /// Per-node longest-remaining-chain estimate (critical-path priority).
    /// Duration is estimated as per-rank rows — the per-rank work each
    /// node's BSP kernels process. A piped node that declares no synthetic
    /// workload (`rows_per_rank == 0`) inherits its producers' combined
    /// total rows spread over its own ranks, since those staged tables
    /// *are* its input.
    fn chain_estimates(&self) -> Vec<f64> {
        let mut est: Vec<f64> = Vec::with_capacity(self.nodes.len());
        for n in &self.nodes {
            let e = if n.td.rows_per_rank == 0 {
                if n.pipe_from.is_empty() {
                    1.0
                } else {
                    // Producers precede consumers, so est[src] is settled.
                    let staged: f64 = n
                        .pipe_from
                        .iter()
                        .map(|&src| est[src] * self.nodes[src].td.ranks.max(1) as f64)
                        .sum();
                    staged / n.td.ranks.max(1) as f64
                }
            } else {
                n.td.rows_per_rank as f64
            };
            est.push(e.max(1.0));
        }
        let mut cp = est.clone();
        // Dependents always carry larger ids, so one reverse sweep settles
        // every chain before it is consumed.
        for j in (0..self.nodes.len()).rev() {
            for &d in &self.nodes[j].deps {
                cp[d] = cp[d].max(est[d] + cp[j]);
            }
        }
        cp
    }

    /// Clone node `i`'s description, wiring handoff inputs and output
    /// collection for this execution.
    fn prepared_td(
        &self,
        i: usize,
        keep: &[bool],
        outputs: &[Option<Arc<ChunkedTable>>],
    ) -> TaskDescription {
        let mut td = self.nodes[i].td.clone();
        if keep[i] {
            td.keep_output = true;
        }
        if !self.nodes[i].pipe_from.is_empty() {
            // Piped nodes take their staged inputs from the DAG (replacing
            // any manually staged tables on the description).
            td.inputs = self.nodes[i]
                .pipe_from
                .iter()
                .map(|&src| {
                    outputs[src].clone().expect(
                        "pipe source finished before its consumer became ready",
                    )
                })
                .collect();
        }
        td
    }

    fn metrics_from(
        &self,
        results: &[TaskResult],
        submitted_s: &[f64],
        finished_s: &[f64],
        makespan_s: f64,
        attempts: &[u32],
    ) -> PipelineMetrics {
        let nodes: Vec<NodeMetric> = results
            .iter()
            .enumerate()
            .map(|(i, r)| NodeMetric {
                name: r.name.clone(),
                ranks: r.measurement.parallelism,
                submitted_s: submitted_s[i],
                finished_s: finished_s[i],
                wall_s: r.measurement.wall_s,
                exec_s: r.measurement.total_s(),
                queue_wait_s: r.measurement.overhead.queue_wait,
                attempts: attempts[i],
            })
            .collect();
        // Longest wall-weighted dependency chain (deps precede, so one
        // forward sweep suffices).
        let mut chain = vec![0.0f64; results.len()];
        let mut critical = 0.0f64;
        for (i, r) in results.iter().enumerate() {
            let upstream = self.nodes[i]
                .deps
                .iter()
                .map(|&d| chain[d])
                .fold(0.0f64, f64::max);
            chain[i] = upstream + r.measurement.wall_s;
            critical = critical.max(chain[i]);
        }
        let busy: f64 = results
            .iter()
            .map(|r| r.measurement.parallelism as f64 * r.measurement.wall_s)
            .sum();
        PipelineMetrics {
            nodes,
            makespan_s,
            critical_path_s: critical,
            busy_rank_seconds: busy,
        }
    }

    /// Event-driven dataflow execution: dependency counting + a completion
    /// channel. Each node is submitted the instant its last dependency
    /// finishes; the RAPTOR master overlaps whatever fits on free ranks and
    /// recycles ranks as nodes retire.
    ///
    /// A node that fails with a *transient* error ([`Error::is_transient`]
    /// on the classified error string) is retried in place — resubmitted
    /// with a bumped `attempt` so keyed fault-injection sites re-draw —
    /// up to the ambient [`crate::util::faults::retry_policy`]'s
    /// `max_attempts`, with deterministic capped-exponential backoff. The
    /// default policy is a single attempt, so behavior without explicit
    /// configuration is unchanged. A permanent failure (or an exhausted
    /// transient one) fails the pipeline after in-flight nodes drain
    /// (fail-fast: nothing new is submitted).
    pub fn run_dataflow(
        &self,
        tm: &TaskManager,
        policy: ReadyPolicy,
    ) -> Result<PipelineRun> {
        self.validate()?;
        let n = self.nodes.len();
        if n == 0 {
            return Ok(PipelineRun {
                results: Vec::new(),
                metrics: PipelineMetrics::default(),
            });
        }
        let keep = self.keep_flags();
        let cp = self.chain_estimates();
        let mut indeg: Vec<usize> = self.nodes.iter().map(|x| x.deps.len()).collect();
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, node) in self.nodes.iter().enumerate() {
            for &d in &node.deps {
                dependents[d].push(i);
            }
        }

        let t0 = Instant::now();
        let (tx, rx) = mpsc::channel::<(usize, Result<TaskResult>)>();
        let mut results: Vec<Option<TaskResult>> = (0..n).map(|_| None).collect();
        let mut outputs: Vec<Option<Arc<ChunkedTable>>> =
            (0..n).map(|_| None).collect();
        let mut submitted_s = vec![0.0f64; n];
        let mut finished_s = vec![0.0f64; n];
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut inflight = 0usize;
        let mut failure: Option<String> = None;
        let retry = crate::util::faults::retry_policy();
        let mut attempts = vec![1u32; n];

        loop {
            if failure.is_none() {
                match policy {
                    ReadyPolicy::Fifo => ready.sort_unstable(),
                    ReadyPolicy::CriticalPathFirst => ready.sort_by(|&a, &b| {
                        cp[b]
                            .partial_cmp(&cp[a])
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(a.cmp(&b))
                    }),
                }
                for i in std::mem::take(&mut ready) {
                    let mut td = self.prepared_td(i, &keep, &outputs);
                    td.attempt = attempts[i];
                    if attempts[i] == 1 {
                        submitted_s[i] = t0.elapsed().as_secs_f64();
                    }
                    match tm.submit(td) {
                        Ok(handle) => {
                            // Completion callback, not a parked waiter
                            // thread: the terminal transition itself posts
                            // the event to the scheduler's channel.
                            let tx = tx.clone();
                            handle.on_terminal(move |res| {
                                let _ = tx.send((i, res));
                            });
                            inflight += 1;
                        }
                        Err(e) => {
                            failure = Some(format!(
                                "pipeline node {i} ('{}') rejected at submission: {e}",
                                self.nodes[i].td.name
                            ));
                            break;
                        }
                    }
                }
            }
            if inflight == 0 {
                break;
            }
            let (i, res) = rx.recv().expect("completion waiter alive");
            inflight -= 1;
            finished_s[i] = t0.elapsed().as_secs_f64();
            match res {
                Ok(r) => {
                    if r.is_done() {
                        if attempts[i] > 1 {
                            crate::metrics::faults::record_recovered();
                        }
                        outputs[i] = r.output.clone();
                        for &j in &dependents[i] {
                            indeg[j] -= 1;
                            if indeg[j] == 0 {
                                ready.push(j);
                            }
                        }
                        results[i] = Some(r);
                    } else {
                        let err = r.error.clone().unwrap_or_default();
                        let transient = Error::classify(&err).is_transient();
                        if transient
                            && attempts[i] < retry.max_attempts
                            && failure.is_none()
                        {
                            // Transient failure with budget left: back off
                            // (deterministically jittered; buffered events
                            // keep draining once we wake) and resubmit with
                            // a bumped attempt so keyed fault sites re-draw.
                            crate::metrics::faults::record_retried();
                            let ms = retry.backoff_ms(attempts[i], i as u64);
                            if ms > 0 {
                                std::thread::sleep(
                                    std::time::Duration::from_millis(ms),
                                );
                            }
                            attempts[i] += 1;
                            ready.push(i);
                        } else {
                            if transient && retry.max_attempts > 1 {
                                crate::metrics::faults::record_exhausted();
                            }
                            if failure.is_none() {
                                failure = Some(format!(
                                    "pipeline node {i} ('{}') failed: {err}",
                                    r.name,
                                ));
                            }
                            results[i] = Some(r);
                        }
                    }
                }
                Err(e) => {
                    if failure.is_none() {
                        failure =
                            Some(format!("pipeline node {i} lost its result: {e}"));
                    }
                }
            }
        }
        if let Some(msg) = failure {
            return Err(Error::TaskFailed(msg));
        }
        let results: Vec<TaskResult> =
            results.into_iter().map(|r| r.expect("node executed")).collect();
        let makespan = t0.elapsed().as_secs_f64();
        let metrics = self.metrics_from(
            &results,
            &submitted_s,
            &finished_s,
            makespan,
            &attempts,
        );
        Ok(PipelineRun { results, metrics })
    }

    /// Dependency-counting execution on a shared-memory [`ThreadPool`]
    /// (no pilot): the ready set runs **concurrently** through `exec`,
    /// with nodes handed to the pool in [`ReadyPolicy`] order the moment
    /// their last dependency completes. Completion events flow back over
    /// a channel and drive the dependency counters, exactly like
    /// [`Pipeline::run_dataflow`]. Table handoff works identically —
    /// outputs are wired into consumers' staged inputs on the scheduler
    /// thread, before the consumer job is enqueued.
    ///
    /// Results come back in node-id order, so for a deterministic `exec`
    /// the returned vector is identical to [`Pipeline::run_sequential`]'s
    /// regardless of pool size, policy, or completion interleaving.
    ///
    /// A task that panics inside `exec` is caught and surfaced as that
    /// node's failure (fail-fast, like any failed node) — it never wedges
    /// the scheduler or poisons the pool.
    ///
    /// Transient node failures (including the `pool.job` fault-injection
    /// site, which fires at job entry inside the panic containment) are
    /// retried with the same bump-the-attempt/backoff scheme as
    /// [`Pipeline::run_dataflow`], bounded by the ambient
    /// [`crate::util::faults::retry_policy`].
    ///
    /// [`ThreadPool`]: crate::util::pool::ThreadPool
    pub fn run_pooled<F>(
        &self,
        pool: &crate::util::pool::ThreadPool,
        policy: ReadyPolicy,
        exec: F,
    ) -> Result<Vec<TaskResult>>
    where
        F: Fn(TaskDescription) -> Result<TaskResult> + Send + Sync,
    {
        use std::panic::{catch_unwind, AssertUnwindSafe};

        self.validate()?;
        let n = self.nodes.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let keep = self.keep_flags();
        let cp = self.chain_estimates();
        let mut indeg: Vec<usize> = self.nodes.iter().map(|x| x.deps.len()).collect();
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, node) in self.nodes.iter().enumerate() {
            for &d in &node.deps {
                dependents[d].push(i);
            }
        }

        let (tx, rx) = mpsc::channel::<(usize, Result<TaskResult>)>();
        let mut results: Vec<Option<TaskResult>> = (0..n).map(|_| None).collect();
        let mut outputs: Vec<Option<Arc<ChunkedTable>>> =
            (0..n).map(|_| None).collect();
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut inflight = 0usize;
        let mut failure: Option<String> = None;
        let retry = crate::util::faults::retry_policy();
        let mut attempts = vec![1u32; n];
        let exec = &exec;

        pool.scope(|s| {
            loop {
                if failure.is_none() {
                    match policy {
                        ReadyPolicy::Fifo => ready.sort_unstable(),
                        ReadyPolicy::CriticalPathFirst => ready.sort_by(|&a, &b| {
                            cp[b]
                                .partial_cmp(&cp[a])
                                .unwrap_or(std::cmp::Ordering::Equal)
                                .then(a.cmp(&b))
                        }),
                    }
                    for i in std::mem::take(&mut ready) {
                        let mut td = self.prepared_td(i, &keep, &outputs);
                        td.attempt = attempts[i];
                        let name = td.name.clone();
                        let tx = tx.clone();
                        s.spawn(move || {
                            // Catch panics *inside* the job so the scope
                            // never re-panics for a task failure and the
                            // scheduler always receives a completion event.
                            // The `pool.job` fault site fires here — inside
                            // the containment — as a transient error at job
                            // entry.
                            let res = match catch_unwind(AssertUnwindSafe(|| {
                                crate::util::faults::inject("pool.job", &name)?;
                                exec(td)
                            })) {
                                Ok(r) => r,
                                Err(payload) => {
                                    let msg = payload
                                        .downcast_ref::<&str>()
                                        .map(|s| s.to_string())
                                        .or_else(|| {
                                            payload.downcast_ref::<String>().cloned()
                                        })
                                        .unwrap_or_else(|| {
                                            "unknown panic payload".to_string()
                                        });
                                    Err(Error::TaskFailed(format!(
                                        "pipeline node '{name}' panicked: {msg}"
                                    )))
                                }
                            };
                            let _ = tx.send((i, res));
                        });
                        inflight += 1;
                    }
                }
                if inflight == 0 {
                    break;
                }
                let (i, res) = rx.recv().expect("pool job sends completion");
                inflight -= 1;
                let done = matches!(&res, Ok(r) if r.is_done());
                if done {
                    let r = res.expect("checked done");
                    if attempts[i] > 1 {
                        crate::metrics::faults::record_recovered();
                    }
                    outputs[i] = r.output.clone();
                    for &j in &dependents[i] {
                        indeg[j] -= 1;
                        if indeg[j] == 0 {
                            ready.push(j);
                        }
                    }
                    results[i] = Some(r);
                } else {
                    let transient = match &res {
                        Ok(r) => Error::classify(
                            r.error.as_deref().unwrap_or_default(),
                        )
                        .is_transient(),
                        Err(e) => e.is_transient(),
                    };
                    if transient
                        && attempts[i] < retry.max_attempts
                        && failure.is_none()
                    {
                        crate::metrics::faults::record_retried();
                        let ms = retry.backoff_ms(attempts[i], i as u64);
                        if ms > 0 {
                            std::thread::sleep(
                                std::time::Duration::from_millis(ms),
                            );
                        }
                        attempts[i] += 1;
                        ready.push(i);
                    } else {
                        if transient && retry.max_attempts > 1 {
                            crate::metrics::faults::record_exhausted();
                        }
                        match res {
                            Ok(r) => {
                                if failure.is_none() {
                                    failure = Some(format!(
                                        "pipeline node {i} ('{}') failed: {}",
                                        r.name,
                                        r.error.clone().unwrap_or_default()
                                    ));
                                }
                                results[i] = Some(r);
                            }
                            Err(e) => {
                                if failure.is_none() {
                                    failure = Some(format!(
                                        "pipeline node {i} failed: {e}"
                                    ));
                                }
                            }
                        }
                    }
                }
            }
        });
        if let Some(msg) = failure {
            return Err(Error::TaskFailed(msg));
        }
        Ok(results
            .into_iter()
            .map(|r| r.expect("node executed"))
            .collect())
    }

    /// Wave-barrier execution (baseline): within a wave, tasks are all
    /// submitted before any is awaited; the next wave starts only when the
    /// whole wave has drained. Supports the same table handoff (a pipe
    /// source always sits in an earlier wave than its consumer).
    pub fn run_waves(&self, tm: &TaskManager) -> Result<PipelineRun> {
        let waves = self.waves()?;
        let n = self.nodes.len();
        let keep = self.keep_flags();
        let t0 = Instant::now();
        let mut results: Vec<Option<TaskResult>> = (0..n).map(|_| None).collect();
        let mut outputs: Vec<Option<Arc<ChunkedTable>>> =
            (0..n).map(|_| None).collect();
        let mut submitted_s = vec![0.0f64; n];
        let mut finished_s = vec![0.0f64; n];
        for wave in waves {
            // Completion callbacks + a channel so finished_s reflects
            // each node's actual completion, not the serial wait order.
            let (tx, rx) = mpsc::channel::<(usize, Result<TaskResult>)>();
            let mut inflight = 0usize;
            for &i in &wave {
                let td = self.prepared_td(i, &keep, &outputs);
                submitted_s[i] = t0.elapsed().as_secs_f64();
                let handle = tm.submit(td)?;
                let tx = tx.clone();
                handle.on_terminal(move |res| {
                    let _ = tx.send((i, res));
                });
                inflight += 1;
            }
            let mut failure: Option<String> = None;
            while inflight > 0 {
                let (i, res) = rx.recv().expect("completion waiter alive");
                inflight -= 1;
                finished_s[i] = t0.elapsed().as_secs_f64();
                match res {
                    Ok(r) => {
                        if r.is_done() {
                            outputs[i] = r.output.clone();
                        } else if failure.is_none() {
                            failure = Some(format!(
                                "pipeline node {i} ('{}') failed: {}",
                                r.name,
                                r.error.clone().unwrap_or_default()
                            ));
                        }
                        results[i] = Some(r);
                    }
                    Err(e) => {
                        if failure.is_none() {
                            failure = Some(format!(
                                "pipeline node {i} lost its result: {e}"
                            ));
                        }
                    }
                }
            }
            if let Some(msg) = failure {
                return Err(Error::TaskFailed(msg));
            }
        }
        let results: Vec<TaskResult> =
            results.into_iter().map(|r| r.expect("node executed")).collect();
        let makespan = t0.elapsed().as_secs_f64();
        // Waves is the no-retry baseline: every node ran exactly once.
        let attempts = vec![1u32; n];
        let metrics = self.metrics_from(
            &results,
            &submitted_s,
            &finished_s,
            makespan,
            &attempts,
        );
        Ok(PipelineRun { results, metrics })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::MachineSpec;
    use crate::df::gen_table;
    use crate::df::GenSpec;
    use crate::ops::local::groupby_agg;
    use crate::pilot::{DataDist, Pilot, PilotDescription, Session};

    fn td(name: &str, ranks: usize) -> TaskDescription {
        TaskDescription::sort(name, ranks, 40, DataDist::Uniform)
    }

    fn pilot_of(cores: usize, name: &str) -> (Session, Arc<Pilot>) {
        let session = Session::new(name);
        let pilot = session
            .pilot_manager()
            .submit(PilotDescription::with_cores(MachineSpec::local(cores), cores))
            .unwrap();
        (session, pilot)
    }

    #[test]
    fn waves_group_independent_branches() {
        let mut p = Pipeline::new();
        let a = p.add(td("a", 1), &[]);
        let b = p.add(td("b", 1), &[]);
        let c = p.add(td("c", 1), &[a, b]);
        let d = p.add(td("d", 1), &[a]);
        let _e = p.add(td("e", 1), &[c, d]);
        let waves = p.waves().unwrap();
        assert_eq!(waves, vec![vec![0, 1], vec![2, 3], vec![4]]);
    }

    #[test]
    fn forward_dependency_rejected() {
        let mut p = Pipeline::new();
        let _a = p.add(td("a", 1), &[3]); // nonexistent / forward
        assert!(p.validate().is_err());
    }

    #[test]
    fn pipe_from_non_dependency_rejected() {
        let mut p = Pipeline::new();
        let a = p.add(td("a", 1), &[]);
        let b = p.add(td("b", 1), &[]);
        let _c = p.add_piped(td("c", 1), &[b], a); // pipes from a non-dep
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("not one of its dependencies"), "{err}");
    }

    #[test]
    fn executes_dag_through_pilot() {
        let session = Session::new("pipe");
        let pilot = session
            .pilot_manager()
            .submit(PilotDescription::new(MachineSpec::local(4), 1))
            .unwrap();
        let tm = session.task_manager(&pilot);
        let mut p = Pipeline::new();
        let a = p.add(td("extract-1", 2), &[]);
        let b = p.add(td("extract-2", 2), &[]);
        let c = p.add(
            TaskDescription::join("merge", 4, 60, DataDist::Uniform),
            &[a, b],
        );
        let _d = p.add(TaskDescription::groupby("report", 2, 60), &[c]);
        let rs = p.execute(&tm).unwrap();
        assert_eq!(rs.len(), 4);
        assert!(rs.iter().all(|r| r.is_done()));
        pilot.shutdown();
    }

    #[test]
    fn failed_node_fails_pipeline() {
        use crate::util::faults::{self, FaultPlan, FireMode};
        let _guard = faults::test_guard();
        faults::arm(
            FaultPlan::new(41)
                .with_arm("agent.task", FireMode::Prob(1.0))
                .with_only("pfail"),
        );
        let session = Session::new("pipe");
        let pilot = session
            .pilot_manager()
            .submit(PilotDescription::new(MachineSpec::local(2), 1))
            .unwrap();
        let tm = session.task_manager(&pilot);
        let mut p = Pipeline::new();
        let a = p.add(td("pfail-x", 2), &[]);
        let _b = p.add(td("never", 2), &[a]);
        let err = p.execute(&tm).unwrap_err().to_string();
        assert!(err.contains("pfail-x"), "{err}");
        pilot.shutdown();
        faults::disarm();
    }

    #[test]
    fn failed_node_fails_wave_pipeline() {
        use crate::util::faults::{self, FaultPlan, FireMode};
        let _guard = faults::test_guard();
        faults::arm(
            FaultPlan::new(43)
                .with_arm("agent.task", FireMode::Prob(1.0))
                .with_only("pfail"),
        );
        let (_s, pilot) = pilot_of(2, "pipe-waves");
        let tm = _s.task_manager(&pilot);
        let mut p = Pipeline::new();
        let a = p.add(td("pfail-w", 2), &[]);
        let _b = p.add(td("never", 2), &[a]);
        let err = p.execute_waves(&tm).unwrap_err().to_string();
        assert!(err.contains("pfail-w"), "{err}");
        pilot.shutdown();
        faults::disarm();
    }

    /// The acceptance property of the dataflow scheduler: an independent
    /// ready branch is submitted while an unrelated slow task from an
    /// earlier "wave" is still running. The wave executor, by contrast,
    /// cannot submit it before the slow task completes.
    #[test]
    fn independent_branch_submits_before_slow_task_completes() {
        let build = || {
            let mut p = Pipeline::new();
            // slow: large per-rank workload; fast chain is tiny.
            let _slow = p.add(
                TaskDescription::sort("slow", 2, 200_000, DataDist::Uniform),
                &[],
            );
            let fast = p.add(td("fast", 2), &[]);
            let _child = p.add(td("child-of-fast", 2), &[fast]);
            p
        };
        const SLOW: usize = 0;
        const CHILD: usize = 2;

        let (s1, pilot1) = pilot_of(4, "dataflow");
        let run = build().run_dataflow(&s1.task_manager(&pilot1), ReadyPolicy::Fifo).unwrap();
        pilot1.shutdown();
        assert!(run.results.iter().all(|r| r.is_done()));
        let m = &run.metrics;
        assert!(
            m.nodes[CHILD].submitted_s < m.nodes[SLOW].finished_s,
            "dataflow must submit the ready child (at {:.4}s) before the \
             unrelated slow task finishes (at {:.4}s)",
            m.nodes[CHILD].submitted_s,
            m.nodes[SLOW].finished_s
        );

        let (s2, pilot2) = pilot_of(4, "waves");
        let wrun = build().run_waves(&s2.task_manager(&pilot2)).unwrap();
        pilot2.shutdown();
        let wm = &wrun.metrics;
        assert!(
            wm.nodes[CHILD].submitted_s >= wm.nodes[SLOW].finished_s,
            "the wave barrier must hold the child until the slow task is done"
        );
    }

    #[test]
    fn critical_path_first_orders_ready_set() {
        // Two roots: a short chain head and a long chain head. Under
        // CriticalPathFirst the long head must reach the master first.
        let mut p = Pipeline::new();
        let short = p.add(td("short", 1), &[]);
        let long_head = p.add(td("long-head", 1), &[]);
        let mid = p.add(
            TaskDescription::sort("long-mid", 1, 20_000, DataDist::Uniform),
            &[long_head],
        );
        let _tail = p.add(
            TaskDescription::sort("long-tail", 1, 20_000, DataDist::Uniform),
            &[mid],
        );
        let cp = p.chain_estimates();
        assert!(cp[long_head] > cp[short]);

        // A 1-rank pilot serializes everything, making submission order
        // observable through completion order.
        let (s, pilot) = pilot_of(1, "cpf");
        let run = p
            .run_dataflow(&s.task_manager(&pilot), ReadyPolicy::CriticalPathFirst)
            .unwrap();
        pilot.shutdown();
        let m = &run.metrics;
        assert!(
            m.nodes[long_head].finished_s < m.nodes[short].finished_s,
            "critical-path head must run before the short root"
        );
    }

    #[test]
    fn table_handoff_propagates_schema_and_rows() {
        let (s, pilot) = pilot_of(4, "handoff");
        let tm = s.task_manager(&pilot);
        let mut p = Pipeline::new();
        let gen = p.add(
            TaskDescription::sort("gen", 2, 100, DataDist::Uniform).with_seed(0xC71),
            &[],
        );
        let agg = p.add_piped(
            TaskDescription::groupby("agg", 2, 9999).collect_output(),
            &[gen],
            gen,
        );
        let run = p.run_dataflow(&tm, ReadyPolicy::Fifo).unwrap();
        pilot.shutdown();
        let out = run.results[agg]
            .output
            .as_ref()
            .expect("collect_output() carries the table")
            .compact();

        // Oracle: the groupby must have consumed gen's actual output (the
        // sorted synthetic partitions), not fresh 9999-row synthetic data.
        let spec = GenSpec {
            rows: 100,
            key_space: (100i64 * 2).max(16),
            dist: DataDist::Uniform,
            seed: 0xC71,
        };
        let all = Table::concat(&[gen_table(&spec, 0), gen_table(&spec, 1)]).unwrap();
        let oracle = groupby_agg(&all, 0, 1, crate::ops::local::AggFn::Sum).unwrap();

        assert_eq!(out.num_rows(), oracle.num_rows());
        assert_eq!(out.schema().field(0).name, "key");
        assert_eq!(out.schema().field(1).name, "val_sum");
        // Exact key-set equality (keys are integers; float sums may round
        // differently across partial-aggregation orders).
        let mut got: Vec<i64> = out.column(0).as_i64().unwrap().to_vec();
        let mut want: Vec<i64> = oracle.column(0).as_i64().unwrap().to_vec();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
        assert_eq!(run.results[agg].output_rows, oracle.num_rows() as u64);
    }

    /// The multi-input handoff acceptance property: a join consumes **both**
    /// sides from upstream tasks — neither side is regenerated.
    #[test]
    fn join_pipes_both_sides_from_upstream() {
        let (s, pilot) = pilot_of(4, "handoff2");
        let tm = s.task_manager(&pilot);
        let mut p = Pipeline::new();
        let left = p.add(
            TaskDescription::sort("left", 2, 80, DataDist::Uniform).with_seed(0xA),
            &[],
        );
        let right = p.add(
            TaskDescription::sort("right", 2, 80, DataDist::Uniform).with_seed(0xB),
            &[],
        );
        let join = p.add_piped_multi(
            TaskDescription::join("merge", 2, 9999, DataDist::Uniform)
                .collect_output(),
            &[left, right],
            &[left, right],
        );
        let run = p.run_dataflow(&tm, ReadyPolicy::Fifo).unwrap();
        pilot.shutdown();

        // Oracle: join the producers' actual synthetic partitions.
        let spec = |seed| GenSpec {
            rows: 80,
            key_space: (80i64 * 2).max(16),
            dist: DataDist::Uniform,
            seed,
        };
        let l = Table::concat(&[gen_table(&spec(0xA), 0), gen_table(&spec(0xA), 1)])
            .unwrap();
        let r = Table::concat(&[gen_table(&spec(0xB), 0), gen_table(&spec(0xB), 1)])
            .unwrap();
        let oracle = crate::ops::local::hash_join(
            &l,
            &r,
            0,
            0,
            crate::ops::local::JoinType::Inner,
        )
        .unwrap();
        assert_eq!(run.results[join].output_rows, oracle.num_rows() as u64);
        let got = run.results[join].output.as_ref().unwrap();
        assert_eq!(got.multiset_fingerprint(), oracle.multiset_fingerprint());
    }

    /// A join piped on one side only must fail loudly (no silent synthetic
    /// right side) — unless the description opts into synthetic fill.
    #[test]
    fn half_piped_join_fails_without_opt_in() {
        let (s, pilot) = pilot_of(4, "half-pipe");
        let tm = s.task_manager(&pilot);
        let build = |fill: bool| {
            let mut p = Pipeline::new();
            let left = p.add(td("left", 2), &[]);
            let mut merge = TaskDescription::join("merge", 2, 40, DataDist::Uniform);
            if fill {
                merge = merge.allow_synthetic_fill();
            }
            p.add_piped(merge, &[left], left);
            p
        };
        let err = build(false)
            .run_dataflow(&tm, ReadyPolicy::Fifo)
            .unwrap_err()
            .to_string();
        assert!(err.contains("allow_synthetic_fill"), "{err}");
        let run = build(true).run_dataflow(&tm, ReadyPolicy::Fifo).unwrap();
        assert!(run.results.iter().all(|r| r.is_done()));
        pilot.shutdown();
    }

    #[test]
    fn run_sequential_matches_dataflow_outputs() {
        let (s, pilot) = pilot_of(4, "seq");
        let tm = s.task_manager(&pilot);
        let mut p = Pipeline::new();
        let gen = p.add(
            TaskDescription::sort("gen", 2, 120, DataDist::Uniform).with_seed(3),
            &[],
        );
        let agg = p.add_piped(
            TaskDescription::groupby("agg", 2, 0).collect_output(),
            &[gen],
            gen,
        );
        let dataflow = p.run_dataflow(&tm, ReadyPolicy::Fifo).unwrap();
        let seq = p
            .run_sequential(|prepared| tm.submit(prepared)?.wait())
            .unwrap();
        pilot.shutdown();
        assert_eq!(seq.len(), dataflow.results.len());
        assert_eq!(
            seq[agg].output.as_ref().unwrap().multiset_fingerprint(),
            dataflow.results[agg]
                .output
                .as_ref()
                .unwrap()
                .multiset_fingerprint()
        );
    }

    #[test]
    fn metrics_account_for_every_node() {
        let (s, pilot) = pilot_of(4, "metrics");
        let tm = s.task_manager(&pilot);
        let mut p = Pipeline::new();
        let a = p.add(td("a", 2), &[]);
        let b = p.add(td("b", 2), &[]);
        let _c = p.add(td("c", 4), &[a, b]);
        let run = p.run_dataflow(&tm, ReadyPolicy::Fifo).unwrap();
        pilot.shutdown();
        let m = &run.metrics;
        assert_eq!(m.nodes.len(), 3);
        assert!(m.makespan_s > 0.0);
        assert!(m.critical_path_s > 0.0);
        assert!(m.busy_rank_seconds > 0.0);
        let idle = m.idle_fraction(4);
        assert!((0.0..=1.0).contains(&idle));
        for node in &m.nodes {
            assert!(node.finished_s >= node.submitted_s, "{}", node.name);
            assert_eq!(node.attempts, 1, "clean run is a single attempt");
        }
    }

    /// Retry layer, exhaustion path: a node whose fault site fires on
    /// every attempt is retried `max_attempts` times and then fails the
    /// pipeline with the transient error surfaced.
    #[test]
    fn transient_node_failure_retries_until_exhausted_in_dataflow() {
        use crate::util::faults::{self, FaultPlan, FireMode, RetryPolicy};
        let _g = faults::test_guard();
        faults::configure_retry(RetryPolicy {
            max_attempts: 3,
            base_ms: 0,
            cap_ms: 0,
            seed: 1,
        });
        // Name-filtered arm: lib tests run concurrently, so the armed
        // plan must not perturb unrelated tasks.
        faults::arm(
            FaultPlan::new(11)
                .with_arm("agent.task", FireMode::Prob(1.0))
                .with_only("pl-flaky"),
        );
        let before = crate::metrics::faults::snapshot();
        let (s, pilot) = pilot_of(2, "retry-exhaust");
        let tm = s.task_manager(&pilot);
        let mut p = Pipeline::new();
        p.add(td("pl-flaky-sort", 2), &[]);
        let err = p.run_dataflow(&tm, ReadyPolicy::Fifo).unwrap_err().to_string();
        pilot.shutdown();
        faults::disarm();
        faults::configure_retry(RetryPolicy::none());
        assert!(err.contains("pl-flaky-sort"), "{err}");
        assert!(err.contains("agent.task"), "{err}");
        let d = crate::metrics::faults::snapshot().since(&before);
        assert!(d.retried >= 2, "{d:?}");
        assert!(d.exhausted >= 1, "{d:?}");
    }

    /// Retry layer, recovery path through `run_pooled`: the `pool.job`
    /// site fires exactly once (`@1`, scoped by name), the retried attempt
    /// succeeds, and the pipeline result is indistinguishable from a
    /// clean run.
    #[test]
    fn pooled_node_recovers_after_injected_pool_fault() {
        use crate::metrics::{ExecMeasurement, OverheadBreakdown};
        use crate::pilot::TaskState;
        use crate::util::faults::{self, FaultPlan, FireMode, RetryPolicy};
        let exec = |td: TaskDescription| -> crate::error::Result<TaskResult> {
            Ok(TaskResult {
                task_id: 0,
                name: td.name.clone(),
                state: TaskState::Done,
                measurement: ExecMeasurement {
                    label: td.name,
                    parallelism: 1,
                    wall_s: 0.0,
                    sim_net_s: 0.0,
                    overhead: OverheadBreakdown::default(),
                },
                output_rows: 1,
                output: None,
                error: None,
            })
        };
        let _g = faults::test_guard();
        faults::configure_retry(RetryPolicy {
            max_attempts: 3,
            base_ms: 0,
            cap_ms: 0,
            seed: 1,
        });
        faults::arm(
            FaultPlan::new(5)
                .with_arm("pool.job", FireMode::Nth(1))
                .with_only("pj-flaky"),
        );
        let before = crate::metrics::faults::snapshot();
        let pool = crate::util::pool::ThreadPool::new(2);
        let mut p = Pipeline::new();
        let a = p.add(td("pj-flaky-gen", 1), &[]);
        let _b = p.add(td("clean-child", 1), &[a]);
        let results = p.run_pooled(&pool, ReadyPolicy::Fifo, exec).unwrap();
        faults::disarm();
        faults::configure_retry(RetryPolicy::none());
        assert!(results.iter().all(|r| r.is_done()));
        let d = crate::metrics::faults::snapshot().since(&before);
        assert!(d.injected >= 1, "{d:?}");
        assert!(d.retried >= 1, "{d:?}");
        assert!(d.recovered >= 1, "{d:?}");
    }

    #[test]
    fn empty_pipeline_is_a_noop() {
        let (s, pilot) = pilot_of(1, "empty");
        let tm = s.task_manager(&pilot);
        let p = Pipeline::new();
        assert!(p.execute(&tm).unwrap().is_empty());
        pilot.shutdown();
    }
}
