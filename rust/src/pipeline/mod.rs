//! Dataframe-task DAG (paper §4.4: "A collection of data frame operators
//! can be arranged in a directed acyclic graph (DAG). Execution of this DAG
//! can further be improved by identifying independent branches ... and
//! executing such independent tasks parallelly.").
//!
//! A [`Pipeline`] is a DAG of [`TaskDescription`]s; `execute` submits it in
//! topological waves to a pilot's TaskManager, so independent branches run
//! concurrently on disjoint private communicators.

use std::collections::VecDeque;

use crate::error::{Error, Result};
use crate::pilot::{TaskDescription, TaskManager, TaskResult};

/// A node in the pipeline DAG.
#[derive(Clone, Debug)]
struct Node {
    td: TaskDescription,
    deps: Vec<usize>,
}

/// DAG of Cylon tasks with explicit dependencies.
#[derive(Clone, Debug, Default)]
pub struct Pipeline {
    nodes: Vec<Node>,
}

impl Pipeline {
    pub fn new() -> Pipeline {
        Pipeline::default()
    }

    /// Add a task depending on previously-added node ids; returns its id.
    pub fn add(&mut self, td: TaskDescription, deps: &[usize]) -> usize {
        let id = self.nodes.len();
        self.nodes.push(Node { td, deps: deps.to_vec() });
        id
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Validate: deps reference earlier nodes only (DAG by construction,
    /// since `add` can only reference existing ids — forward refs rejected).
    pub fn validate(&self) -> Result<()> {
        for (i, n) in self.nodes.iter().enumerate() {
            for &d in &n.deps {
                if d >= i {
                    return Err(Error::Pilot(format!(
                        "node {i} ('{}') depends on {d}, which is not an earlier node",
                        n.td.name
                    )));
                }
            }
        }
        Ok(())
    }

    /// Topological waves: wave k contains every node whose dependencies all
    /// sit in waves < k. Independent branches land in the same wave.
    pub fn waves(&self) -> Result<Vec<Vec<usize>>> {
        self.validate()?;
        let mut wave_of = vec![0usize; self.nodes.len()];
        let mut maxw = 0;
        for (i, n) in self.nodes.iter().enumerate() {
            let w = n
                .deps
                .iter()
                .map(|&d| wave_of[d] + 1)
                .max()
                .unwrap_or(0);
            wave_of[i] = w;
            maxw = maxw.max(w);
        }
        let mut waves = vec![Vec::new(); maxw + 1];
        for (i, &w) in wave_of.iter().enumerate() {
            waves[w].push(i);
        }
        Ok(waves)
    }

    /// Execute the DAG through a TaskManager, wave by wave. Within a wave,
    /// tasks are all submitted before any is awaited (the RAPTOR master
    /// overlaps them on disjoint rank groups). A failed task fails the
    /// pipeline after its wave completes.
    pub fn execute(&self, tm: &TaskManager) -> Result<Vec<TaskResult>> {
        let waves = self.waves()?;
        let mut results: Vec<Option<TaskResult>> = vec![None; self.nodes.len()];
        for wave in waves {
            let mut handles = VecDeque::new();
            for &i in &wave {
                handles.push_back((i, tm.submit(self.nodes[i].td.clone())?));
            }
            let mut failure: Option<String> = None;
            for (i, h) in handles {
                let r = h.wait()?;
                if !r.is_done() && failure.is_none() {
                    failure = Some(format!(
                        "pipeline node {i} ('{}') failed: {}",
                        r.name,
                        r.error.clone().unwrap_or_default()
                    ));
                }
                results[i] = Some(r);
            }
            if let Some(msg) = failure {
                return Err(Error::TaskFailed(msg));
            }
        }
        Ok(results.into_iter().map(|r| r.unwrap()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::MachineSpec;
    use crate::pilot::{CylonOp, DataDist, PilotDescription, Session};

    fn td(name: &str, ranks: usize) -> TaskDescription {
        TaskDescription::sort(name, ranks, 40, DataDist::Uniform)
    }

    #[test]
    fn waves_group_independent_branches() {
        let mut p = Pipeline::new();
        let a = p.add(td("a", 1), &[]);
        let b = p.add(td("b", 1), &[]);
        let c = p.add(td("c", 1), &[a, b]);
        let d = p.add(td("d", 1), &[a]);
        let _e = p.add(td("e", 1), &[c, d]);
        let waves = p.waves().unwrap();
        assert_eq!(waves, vec![vec![0, 1], vec![2, 3], vec![4]]);
    }

    #[test]
    fn forward_dependency_rejected() {
        let mut p = Pipeline::new();
        let _a = p.add(td("a", 1), &[3]); // nonexistent / forward
        assert!(p.validate().is_err());
    }

    #[test]
    fn executes_dag_through_pilot() {
        let session = Session::new("pipe");
        let pilot = session
            .pilot_manager()
            .submit(PilotDescription::new(MachineSpec::local(4), 1))
            .unwrap();
        let tm = session.task_manager(&pilot);
        let mut p = Pipeline::new();
        let a = p.add(td("extract-1", 2), &[]);
        let b = p.add(td("extract-2", 2), &[]);
        let c = p.add(
            TaskDescription::join("merge", 4, 60, DataDist::Uniform),
            &[a, b],
        );
        let _d = p.add(
            TaskDescription::new("report", CylonOp::Groupby, 2, 60),
            &[c],
        );
        let rs = p.execute(&tm).unwrap();
        assert_eq!(rs.len(), 4);
        assert!(rs.iter().all(|r| r.is_done()));
        pilot.shutdown();
    }

    #[test]
    fn failed_node_fails_pipeline() {
        let session = Session::new("pipe");
        let pilot = session
            .pilot_manager()
            .submit(PilotDescription::new(MachineSpec::local(2), 1))
            .unwrap();
        let tm = session.task_manager(&pilot);
        let mut p = Pipeline::new();
        let a = p.add(td("__fail__x", 2), &[]);
        let _b = p.add(td("never", 2), &[a]);
        let err = p.execute(&tm).unwrap_err().to_string();
        assert!(err.contains("__fail__x"), "{err}");
        pilot.shutdown();
    }
}
