//! # radical-cylon
//!
//! Reproduction of *"Design and Implementation of an Analysis Pipeline for
//! Heterogeneous Data"* (Sarker et al., CS.DC 2024): the **Radical-Cylon**
//! system — a pilot-job runtime (RADICAL-Pilot analogue) driving a BSP
//! distributed dataframe engine (Cylon analogue), with the data-plane
//! hot-spots (shuffle hash partitioning, local block sort) compiled
//! ahead-of-time from JAX/Pallas to XLA HLO and executed from Rust via PJRT.
//!
//! ## Layers
//!
//! * **L3 (this crate)** — coordination: pilots, tasks, RAPTOR
//!   master/worker, private communicator construction, execution engines
//!   (bare-metal / batch / heterogeneous), plus every substrate the paper
//!   depends on (columnar tables, local+distributed operators, communicator
//!   with a calibrated network cost model, simulated SLURM/LSF clusters).
//! * **L2** — `python/compile/model.py`: JAX graph calling the L1 kernels,
//!   AOT-lowered to HLO text in `artifacts/`.
//! * **L1** — `python/compile/kernels/`: Pallas kernels (interpret mode).
//!
//! Python never runs on the request path: [`runtime::ArtifactStore`] loads
//! the HLO artifacts once and serves compiled executables to the data plane.
//!
//! ## Quickstart
//!
//! ```no_run
//! use radical_cylon::prelude::*;
//!
//! // An 8-rank distributed join through the full pilot stack.
//! let session = Session::new("quickstart");
//! let pd = PilotDescription::new(MachineSpec::rivanna(), 1); // 1 node = 37 cores
//! let pilot = session.pilot_manager().submit(pd).unwrap();
//! let tm = session.task_manager(&pilot);
//! let td = TaskDescription::join("join-demo", 8, 10_000, DataDist::Uniform);
//! let result = tm.submit(td).unwrap().wait().unwrap();
//! assert!(result.is_done());
//! ```
//!
//! Dataframe *pipelines* are written as logical [`plan::Plan`]s: start
//! from a source, chain operators fluently — predicates and derived
//! columns are typed [`plan::expr::Expr`] trees, keys are column names —
//! and run the plan on any engine. Lowering validates the plan against
//! the propagated schemas, applies the [`plan::optimize`] passes (filter
//! fusion, predicate pushdown, projection pruning), and emits a task DAG
//! with zero-copy table handoff between stages (a join consumes **both**
//! sides from its upstream tasks):
//!
//! ```no_run
//! use radical_cylon::prelude::*;
//!
//! let users = Plan::generate(2, GenSpec::uniform(100_000, 50_000, 7))
//!     .filter(col("val").ge(lit(0.5)).and(col("key").ne(lit(0))));
//! let events = Plan::generate(2, GenSpec::uniform(100_000, 50_000, 8));
//! let report = users
//!     .join(events, "key", "key")
//!     .derive("boosted", col("val") * lit(2.0))
//!     .sort("key")
//!     .collect();
//!
//! let engine = HeterogeneousEngine::new(MachineSpec::local(4), KernelBackend::Native, 4);
//! let run = engine.run_plan(&report).unwrap();
//! println!("{}", run.output.unwrap().compact().head(5));
//! ```
//!
//! The task layer underneath stays fully accessible: build
//! [`pipeline::Pipeline`] DAGs by hand with `add`/`add_piped_multi`, or
//! submit single [`pilot::TaskDescription`]s whose operator is any
//! [`ops::operator::Operator`] implementation (built-in or registered via
//! [`ops::operator::registry`]).

pub mod cli;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod df;
pub mod error;
pub mod exec;
pub mod metrics;
pub mod ops;
pub mod pilot;
pub mod pipeline;
pub mod plan;
pub mod raptor;
pub mod runtime;
pub mod service;
pub mod spill;
pub mod util;

/// Convenience re-exports covering the public API surface used by the
/// examples and benches.
pub mod prelude {
    pub use crate::cluster::{MachineSpec, ResourceManager};
    pub use crate::comm::{CommWorld, Communicator, NetModel};
    pub use crate::config::{ExperimentConfig, ServiceConfig};
    pub use crate::df::{
        Chunk, ChunkedTable, ColRef, Column, DataType, GenSpec, Schema, Table,
    };
    pub use crate::error::{Error, Result};
    pub use crate::exec::{
        BareMetalEngine, BatchEngine, Engine, EngineKind, HeterogeneousEngine,
        PipelineSuite, PlanRun,
    };
    pub use crate::metrics::{OverheadBreakdown, PipelineMetrics, Stats};
    pub use crate::ops::dist::KernelBackend;
    pub use crate::ops::local::{AggFn, CmpOp, JoinType};
    pub use crate::ops::operator::{registry, OpHandle, Operator};
    pub use crate::pilot::{
        DataDist, PilotDescription, Session, TaskDescription, TaskState,
    };
    pub use crate::pipeline::{Pipeline, PipelineRun};
    pub use crate::plan::expr::{col, idx, lit, Expr};
    pub use crate::plan::{LoweredPlan, Plan};
    pub use crate::raptor::{ReadyPolicy, SchedPolicy};
    pub use crate::runtime::ArtifactStore;
    pub use crate::spill::{MemoryBudget, Reservation, SpilledTable};
    pub use crate::util::faults::{FaultPlan, FireMode, RetryPolicy};
    pub use crate::service::{
        AdmitPolicy, CacheOutcome, QueryHandle, QueryId, QueryResult,
        QueryService, QueryState,
    };
}
