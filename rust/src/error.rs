//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the crate
//! builds with zero dependencies offline; see DESIGN.md §2).

/// Unified error for every subsystem (df, comm, pilot, runtime, ...).
#[derive(Debug)]
pub enum Error {
    /// Schema/type mismatches and other dataframe misuse.
    DataFrame(String),

    /// Communicator misuse or a peer that went away.
    Comm(String),

    /// Resource manager could not satisfy an allocation.
    Resource(String),

    /// Pilot/task lifecycle violations (illegal state transitions, ...).
    Pilot(String),

    /// Task execution failed on a worker.
    TaskFailed(String),

    /// Query service admission control rejected a submission (in-flight
    /// limit + queue saturated, or the query can never be admitted).
    Admission(String),

    /// PJRT runtime / artifact problems.
    Runtime(String),

    /// Configuration parse/validation errors.
    Config(String),

    /// Runtime faults inside a vectorized compute kernel (e.g. int64
    /// division by zero in the expression evaluator).
    Compute(String),

    Io(std::io::Error),

    /// Errors bubbling out of the `xla` crate.
    Xla(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::DataFrame(m) => write!(f, "dataframe error: {m}"),
            Error::Comm(m) => write!(f, "communicator error: {m}"),
            Error::Resource(m) => write!(f, "resource error: {m}"),
            Error::Pilot(m) => write!(f, "pilot error: {m}"),
            Error::TaskFailed(m) => write!(f, "task failed: {m}"),
            Error::Admission(m) => write!(f, "admission rejected: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Compute(m) => write!(f, "compute error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[macro_export]
macro_rules! bail {
    ($variant:ident, $($arg:tt)*) => {
        return Err($crate::error::Error::$variant(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Comm("rank 3 vanished".into());
        assert!(e.to_string().contains("rank 3"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
