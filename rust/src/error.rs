//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every subsystem (df, comm, pilot, runtime, ...).
#[derive(Error, Debug)]
pub enum Error {
    /// Schema/type mismatches and other dataframe misuse.
    #[error("dataframe error: {0}")]
    DataFrame(String),

    /// Communicator misuse or a peer that went away.
    #[error("communicator error: {0}")]
    Comm(String),

    /// Resource manager could not satisfy an allocation.
    #[error("resource error: {0}")]
    Resource(String),

    /// Pilot/task lifecycle violations (illegal state transitions, ...).
    #[error("pilot error: {0}")]
    Pilot(String),

    /// Task execution failed on a worker.
    #[error("task failed: {0}")]
    TaskFailed(String),

    /// PJRT runtime / artifact problems.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Configuration parse/validation errors.
    #[error("config error: {0}")]
    Config(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// Errors bubbling out of the `xla` crate.
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[macro_export]
macro_rules! bail {
    ($variant:ident, $($arg:tt)*) => {
        return Err($crate::error::Error::$variant(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Comm("rank 3 vanished".into());
        assert!(e.to_string().contains("rank 3"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
