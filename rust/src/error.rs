//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the crate
//! builds with zero dependencies offline; see DESIGN.md §2).

/// Unified error for every subsystem (df, comm, pilot, runtime, ...).
#[derive(Debug)]
pub enum Error {
    /// Schema/type mismatches and other dataframe misuse.
    DataFrame(String),

    /// Communicator misuse or a peer that went away.
    Comm(String),

    /// Resource manager could not satisfy an allocation.
    Resource(String),

    /// Pilot/task lifecycle violations (illegal state transitions, ...).
    Pilot(String),

    /// Task execution failed on a worker.
    TaskFailed(String),

    /// Query service admission control rejected a submission (in-flight
    /// limit + queue saturated, or the query can never be admitted).
    Admission(String),

    /// PJRT runtime / artifact problems.
    Runtime(String),

    /// Configuration parse/validation errors.
    Config(String),

    /// Runtime faults inside a vectorized compute kernel (e.g. int64
    /// division by zero in the expression evaluator).
    Compute(String),

    /// A deadline expired: an overdue task marked failed by the raptor
    /// watchdog, a query still running at the service's shutdown drain
    /// deadline, or a `join_timeout` that ran out.
    Timeout(String),

    Io(std::io::Error),

    /// Errors bubbling out of the `xla` crate.
    Xla(String),
}

impl Error {
    /// Retry taxonomy: is this failure worth re-executing?
    ///
    /// * **Transient** — `Comm` (a peer hiccuped), `TaskFailed` (worker
    ///   panic / injected fault), `Timeout` (overdue, the work itself may
    ///   be fine): a deterministic re-run can succeed.
    /// * **Permanent** — everything else (`Config`, `DataFrame`,
    ///   `Compute`, ...): re-running the same inputs reproduces the same
    ///   error, so retrying only wastes the pool.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            Error::Comm(_) | Error::TaskFailed(_) | Error::Timeout(_)
        )
    }

    /// Recover the typed variant from a rendered [`Display`] message.
    ///
    /// The pilot report path carries failures as strings
    /// (`TaskResult.error`, `service::Outcome::Failed`), which loses the
    /// variant — and with it [`Error::is_transient`]. Every `Display` arm
    /// uses a stable `"<kind>: "` prefix, so the variant round-trips;
    /// unknown prefixes conservatively classify as `TaskFailed`
    /// (transient), matching the pre-taxonomy behaviour of the report
    /// path.
    pub fn classify(message: &str) -> Error {
        let m = message.to_string();
        for (prefix, make) in [
            ("dataframe error: ", Error::DataFrame as fn(String) -> Error),
            ("communicator error: ", Error::Comm),
            ("resource error: ", Error::Resource),
            ("pilot error: ", Error::Pilot),
            ("task failed: ", Error::TaskFailed),
            ("admission rejected: ", Error::Admission),
            ("runtime error: ", Error::Runtime),
            ("config error: ", Error::Config),
            ("compute error: ", Error::Compute),
            ("timeout: ", Error::Timeout),
            ("xla error: ", Error::Xla),
        ] {
            if let Some(rest) = message.strip_prefix(prefix) {
                return make(rest.to_string());
            }
        }
        Error::TaskFailed(m)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::DataFrame(m) => write!(f, "dataframe error: {m}"),
            Error::Comm(m) => write!(f, "communicator error: {m}"),
            Error::Resource(m) => write!(f, "resource error: {m}"),
            Error::Pilot(m) => write!(f, "pilot error: {m}"),
            Error::TaskFailed(m) => write!(f, "task failed: {m}"),
            Error::Admission(m) => write!(f, "admission rejected: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Compute(m) => write!(f, "compute error: {m}"),
            Error::Timeout(m) => write!(f, "timeout: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[macro_export]
macro_rules! bail {
    ($variant:ident, $($arg:tt)*) => {
        return Err($crate::error::Error::$variant(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Comm("rank 3 vanished".into());
        assert!(e.to_string().contains("rank 3"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }

    #[test]
    fn transient_taxonomy() {
        assert!(Error::Comm("x".into()).is_transient());
        assert!(Error::TaskFailed("x".into()).is_transient());
        assert!(Error::Timeout("x".into()).is_transient());
        assert!(!Error::Config("x".into()).is_transient());
        assert!(!Error::DataFrame("x".into()).is_transient());
        assert!(!Error::Compute("x".into()).is_transient());
        assert!(!Error::Admission("x".into()).is_transient());
    }

    #[test]
    fn classify_round_trips_display() {
        for e in [
            Error::DataFrame("a".into()),
            Error::Comm("b".into()),
            Error::Resource("c".into()),
            Error::Pilot("d".into()),
            Error::TaskFailed("e".into()),
            Error::Admission("f".into()),
            Error::Runtime("g".into()),
            Error::Config("h".into()),
            Error::Compute("i".into()),
            Error::Timeout("j".into()),
            Error::Xla("k".into()),
        ] {
            let back = Error::classify(&e.to_string());
            assert_eq!(
                std::mem::discriminant(&back),
                std::mem::discriminant(&e),
                "{e}"
            );
            assert_eq!(back.is_transient(), e.is_transient(), "{e}");
        }
        // Unknown prefixes stay transient (pre-taxonomy report behaviour).
        assert!(Error::classify("mystery").is_transient());
    }
}
