//! Simulated HPC clusters and their resource managers — the stand-in for
//! UVA-Rivanna (SLURM, 37 cores/node) and ORNL-Summit (LSF, 42 cores/node)
//! from the paper's Table 1 (DESIGN.md §2 substitution log).

mod machine;
mod rm;

pub use machine::{FabricClass, MachineSpec};
pub use rm::{rm_for, Allocation, LsfRM, ResourceManager, RmPolicy, SlurmRM};
