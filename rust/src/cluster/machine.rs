//! Machine specifications (paper Table 1).

use crate::comm::{Backend, NetModel};

/// Rows were scaled down 1000x from the paper's datasets (DESIGN.md §2), so
/// the network model charges each simulated byte as 1000 real bytes. This
/// keeps modeled communication seconds at paper-comparable magnitude
/// relative to compute, which is what gives the figures their shapes
/// (near-constant weak-scaling curves, ~1/p strong scaling).
pub const SIM_DATA_SCALE: f64 = 1000.0;

/// Interconnect class, used to scale the α–β network model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FabricClass {
    /// EDR InfiniBand-class (Summit's fat-tree): fastest.
    Edr,
    /// Mellanox FDR-class (Rivanna parallel partition): moderately slower.
    Fdr,
    /// Commodity ethernet (cloud deployments, paper's "dual capability").
    Ethernet,
}

impl FabricClass {
    /// Multiplier applied to backend α–β parameters.
    pub fn scale(&self) -> f64 {
        match self {
            FabricClass::Edr => 1.0,
            FabricClass::Fdr => 1.6,
            FabricClass::Ethernet => 8.0,
        }
    }
}

/// A cluster model: homogeneous nodes, cores per node, fabric.
#[derive(Clone, Debug)]
pub struct MachineSpec {
    pub name: String,
    pub cores_per_node: usize,
    pub max_nodes: usize,
    pub fabric: FabricClass,
    /// Default communication backend for Cylon tasks on this machine.
    pub backend: Backend,
    /// Mean resource-manager dispatch latency (seconds, modeled).
    pub rm_dispatch_latency: f64,
}

impl MachineSpec {
    /// UVA Rivanna, parallel queue: 37 usable cores/node, ≤14 nodes
    /// (paper Table 1), SLURM.
    pub fn rivanna() -> MachineSpec {
        MachineSpec {
            name: "rivanna".into(),
            cores_per_node: 37,
            max_nodes: 14,
            fabric: FabricClass::Fdr,
            backend: Backend::Mpi,
            // Calibrated to the scaled workload (DESIGN.md §2): dispatch is
            // a few percent of a scaled task's execution time, mirroring
            // srun latency vs the paper's 100-200s tasks.
            rm_dispatch_latency: 0.08,
        }
    }

    /// ORNL Summit: 42 cores/node, ≤64 nodes used in the paper, LSF.
    pub fn summit() -> MachineSpec {
        MachineSpec {
            name: "summit".into(),
            cores_per_node: 42,
            max_nodes: 64,
            fabric: FabricClass::Edr,
            backend: Backend::Ucx,
            // LSF bsub dispatch, calibrated to the scaled workload so the
            // batch-vs-heterogeneous gap reproduces the paper's 4-15% band
            // (EXPERIMENTS.md Fig 10/11).
            rm_dispatch_latency: 0.2,
        }
    }

    /// A small local machine for unit tests and the quickstart example.
    pub fn local(cores: usize) -> MachineSpec {
        MachineSpec {
            name: "local".into(),
            cores_per_node: cores,
            max_nodes: 1,
            fabric: FabricClass::Ethernet,
            backend: Backend::Gloo,
            rm_dispatch_latency: 0.0,
        }
    }

    pub fn total_cores(&self) -> usize {
        self.cores_per_node * self.max_nodes
    }

    /// Nodes needed for `ranks` cores (paper: parallelism = nodes × cores).
    pub fn nodes_for(&self, ranks: usize) -> usize {
        ranks.div_ceil(self.cores_per_node)
    }

    /// Data-scale substitution factor for this machine: the paper machines
    /// carry the rows-/1000 byte-cost scaling; the local test machine runs
    /// the raw model.
    pub fn data_scale(&self) -> f64 {
        match self.name.as_str() {
            "rivanna" | "summit" => SIM_DATA_SCALE,
            _ => 1.0,
        }
    }

    /// Network model for this machine's default backend (β carries the
    /// [`SIM_DATA_SCALE`] substitution; α is per-hop and unscaled).
    pub fn netmodel(&self) -> NetModel {
        NetModel::new(self.backend, self.fabric.scale())
            .with_data_scale(self.data_scale())
    }

    /// Network model for an explicit backend choice.
    pub fn netmodel_with(&self, backend: Backend) -> NetModel {
        NetModel::new(backend, self.fabric.scale())
            .with_data_scale(self.data_scale())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table1_constants() {
        let r = MachineSpec::rivanna();
        assert_eq!(r.cores_per_node, 37);
        assert_eq!(r.max_nodes, 14);
        assert_eq!(r.total_cores(), 518); // the paper's max Rivanna parallelism
        let s = MachineSpec::summit();
        assert_eq!(s.cores_per_node, 42);
        assert_eq!(s.total_cores(), 2688); // the paper's max Summit parallelism
    }

    #[test]
    fn nodes_for_rounds_up() {
        let r = MachineSpec::rivanna();
        assert_eq!(r.nodes_for(37), 1);
        assert_eq!(r.nodes_for(38), 2);
        assert_eq!(r.nodes_for(518), 14);
        assert_eq!(r.nodes_for(1), 1);
    }

    #[test]
    fn fabric_ordering() {
        assert!(FabricClass::Edr.scale() < FabricClass::Fdr.scale());
        assert!(FabricClass::Fdr.scale() < FabricClass::Ethernet.scale());
    }

    #[test]
    fn netmodel_reflects_fabric() {
        let summit = MachineSpec::summit().netmodel();
        let rivanna = MachineSpec::rivanna().netmodel();
        // Summit UCX over EDR has lower latency than Rivanna MPI over FDR.
        assert!(summit.alpha < rivanna.alpha);
    }
}
