//! Resource managers: SLURM-like (Rivanna) and LSF-like (Summit) allocation
//! semantics over a simulated cluster.
//!
//! The scheduling-relevant differences the paper's batch-vs-heterogeneous
//! comparison depends on are modeled: every *job* (allocation) pays a
//! dispatch latency before its resources are usable, separate jobs never
//! share cores, and core accounting is per-node. Latencies are *virtual*
//! seconds (recorded, not slept) so experiments stay fast and deterministic.

use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::util::rng::Rng;

use super::machine::MachineSpec;

/// A granted set of cores with exact per-node bookkeeping.
#[derive(Clone, Debug)]
pub struct Allocation {
    pub id: u64,
    /// (node index, cores taken on that node).
    pub taken: Vec<(usize, usize)>,
    /// Cores the caller asked for (exclusive jobs may consume more).
    pub requested: usize,
    /// Virtual seconds spent queued + dispatching before the allocation
    /// became usable.
    pub startup_latency: f64,
}

impl Allocation {
    pub fn nodes(&self) -> Vec<usize> {
        self.taken.iter().map(|(n, _)| *n).collect()
    }

    /// Cores actually consumed (≥ requested for exclusive jobs).
    pub fn cores_taken(&self) -> usize {
        self.taken.iter().map(|(_, c)| *c).sum()
    }
}

/// Dispatch-latency policy knobs shared by both RM flavors.
#[derive(Clone, Copy, Debug)]
pub struct RmPolicy {
    /// Mean dispatch latency per job (virtual seconds).
    pub dispatch_mean: f64,
    /// Extra per-node dispatch cost (virtual seconds).
    pub per_node: f64,
    /// Deterministic seed for latency jitter.
    pub seed: u64,
}

impl RmPolicy {
    pub fn for_machine(m: &MachineSpec) -> RmPolicy {
        RmPolicy { dispatch_mean: m.rm_dispatch_latency, per_node: 0.02, seed: 0x5eed }
    }
}

struct RmState {
    free_cores_per_node: Vec<usize>,
    next_id: u64,
    rng: Rng,
}

/// Common allocation interface; SLURM/LSF differ in latency shape.
pub trait ResourceManager: Send + Sync {
    fn machine(&self) -> &MachineSpec;

    /// Request `cores` cores; `exclusive` jobs take whole nodes (LSF batch
    /// semantics on Summit).
    fn allocate(&self, cores: usize, exclusive: bool) -> Result<Allocation>;

    /// Return an allocation's cores to the pool.
    fn release(&self, alloc: &Allocation);

    /// Cores currently available.
    fn free_cores(&self) -> usize;

    /// Scheduler flavor name ("slurm" / "lsf").
    fn flavor(&self) -> &'static str;
}

fn new_state(m: &MachineSpec, policy: &RmPolicy) -> Mutex<RmState> {
    Mutex::new(RmState {
        free_cores_per_node: vec![m.cores_per_node; m.max_nodes],
        next_id: 1,
        rng: Rng::new(policy.seed),
    })
}

fn do_allocate(
    m: &MachineSpec,
    policy: &RmPolicy,
    st: &mut RmState,
    cores: usize,
    exclusive: bool,
    latency_shape: fn(&mut Rng, f64) -> f64,
) -> Result<Allocation> {
    if cores == 0 {
        return Err(Error::Resource("allocation of zero cores".into()));
    }
    let mut taken: Vec<(usize, usize)> = Vec::new();
    if exclusive {
        // Whole fully-free nodes until the request is covered.
        let nodes_needed = cores.div_ceil(m.cores_per_node);
        for (n, free) in st.free_cores_per_node.iter().enumerate() {
            if taken.len() == nodes_needed {
                break;
            }
            if *free == m.cores_per_node {
                taken.push((n, m.cores_per_node));
            }
        }
        if taken.len() < nodes_needed {
            return Err(Error::Resource(format!(
                "cannot satisfy {cores} cores exclusively ({} free nodes, need {nodes_needed})",
                st.free_cores_per_node
                    .iter()
                    .filter(|&&f| f == m.cores_per_node)
                    .count()
            )));
        }
    } else {
        // First-fit over partially-free nodes.
        let mut remaining = cores;
        for (n, free) in st.free_cores_per_node.iter().enumerate() {
            if remaining == 0 {
                break;
            }
            if *free > 0 {
                let take = (*free).min(remaining);
                taken.push((n, take));
                remaining -= take;
            }
        }
        if remaining > 0 {
            return Err(Error::Resource(format!(
                "cannot satisfy {cores} cores (free={})",
                st.free_cores_per_node.iter().sum::<usize>()
            )));
        }
    }
    // Commit.
    for &(n, c) in &taken {
        st.free_cores_per_node[n] -= c;
    }
    let latency = latency_shape(&mut st.rng, policy.dispatch_mean)
        + policy.per_node * taken.len() as f64;
    let id = st.next_id;
    st.next_id += 1;
    Ok(Allocation { id, taken, requested: cores, startup_latency: latency })
}

fn do_release(st: &mut RmState, alloc: &Allocation) {
    for &(n, c) in &alloc.taken {
        st.free_cores_per_node[n] += c;
    }
}

/// SLURM-flavored RM (Rivanna): shared nodes, near-deterministic dispatch.
pub struct SlurmRM {
    machine: MachineSpec,
    policy: RmPolicy,
    state: Mutex<RmState>,
}

impl SlurmRM {
    pub fn new(machine: MachineSpec) -> SlurmRM {
        let policy = RmPolicy::for_machine(&machine);
        let state = new_state(&machine, &policy);
        SlurmRM { machine, policy, state }
    }
}

impl ResourceManager for SlurmRM {
    fn machine(&self) -> &MachineSpec {
        &self.machine
    }

    fn allocate(&self, cores: usize, exclusive: bool) -> Result<Allocation> {
        let mut st = self.state.lock().unwrap();
        // SLURM srun dispatch: low jitter around the mean.
        do_allocate(&self.machine, &self.policy, &mut st, cores, exclusive, |rng, mean| {
            mean * (0.9 + 0.2 * rng.gen_f64())
        })
    }

    fn release(&self, alloc: &Allocation) {
        do_release(&mut self.state.lock().unwrap(), alloc);
    }

    fn free_cores(&self) -> usize {
        self.state.lock().unwrap().free_cores_per_node.iter().sum()
    }

    fn flavor(&self) -> &'static str {
        "slurm"
    }
}

/// LSF-flavored RM (Summit): exponential-tailed dispatch latency (bsub
/// queue behaviour).
pub struct LsfRM {
    machine: MachineSpec,
    policy: RmPolicy,
    state: Mutex<RmState>,
}

impl LsfRM {
    pub fn new(machine: MachineSpec) -> LsfRM {
        let policy = RmPolicy::for_machine(&machine);
        let state = new_state(&machine, &policy);
        LsfRM { machine, policy, state }
    }
}

impl ResourceManager for LsfRM {
    fn machine(&self) -> &MachineSpec {
        &self.machine
    }

    fn allocate(&self, cores: usize, exclusive: bool) -> Result<Allocation> {
        let mut st = self.state.lock().unwrap();
        do_allocate(&self.machine, &self.policy, &mut st, cores, exclusive, |rng, mean| {
            rng.gen_exp(mean)
        })
    }

    fn release(&self, alloc: &Allocation) {
        do_release(&mut self.state.lock().unwrap(), alloc);
    }

    fn free_cores(&self) -> usize {
        self.state.lock().unwrap().free_cores_per_node.iter().sum()
    }

    fn flavor(&self) -> &'static str {
        "lsf"
    }
}

/// RM for a machine, by its native flavor (Table 1).
pub fn rm_for(machine: MachineSpec) -> Box<dyn ResourceManager> {
    match machine.name.as_str() {
        "summit" => Box::new(LsfRM::new(machine)),
        _ => Box::new(SlurmRM::new(machine)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit;

    #[test]
    fn allocate_and_release_roundtrip() {
        let rm = SlurmRM::new(MachineSpec::rivanna());
        let total = rm.free_cores();
        assert_eq!(total, 518);
        let a = rm.allocate(100, false).unwrap();
        assert_eq!(rm.free_cores(), total - 100);
        assert_eq!(a.cores_taken(), 100);
        assert!(a.startup_latency > 0.0);
        rm.release(&a);
        assert_eq!(rm.free_cores(), total);
    }

    #[test]
    fn over_allocation_fails() {
        let rm = SlurmRM::new(MachineSpec::rivanna());
        assert!(rm.allocate(519, false).is_err());
        let _a = rm.allocate(518, false).unwrap();
        assert!(rm.allocate(1, false).is_err());
    }

    #[test]
    fn exclusive_takes_whole_nodes() {
        let rm = LsfRM::new(MachineSpec::summit());
        let a = rm.allocate(50, true).unwrap(); // 50 cores -> 2 whole nodes
        assert_eq!(a.nodes().len(), 2);
        assert_eq!(a.cores_taken(), 84);
        assert_eq!(rm.free_cores(), 2688 - 84);
        rm.release(&a);
        assert_eq!(rm.free_cores(), 2688);
    }

    #[test]
    fn exclusive_needs_free_nodes() {
        let rm = LsfRM::new(MachineSpec::local(4));
        let _a = rm.allocate(1, false).unwrap(); // dirty the only node
        assert!(rm.allocate(1, true).is_err());
    }

    #[test]
    fn zero_core_request_rejected() {
        let rm = SlurmRM::new(MachineSpec::local(4));
        assert!(rm.allocate(0, false).is_err());
    }

    #[test]
    fn separate_jobs_never_share_cores() {
        let rm = SlurmRM::new(MachineSpec::local(8));
        let a = rm.allocate(5, false).unwrap();
        let b = rm.allocate(3, false).unwrap();
        assert_eq!(rm.free_cores(), 0);
        assert!(rm.allocate(1, false).is_err());
        rm.release(&a);
        rm.release(&b);
        assert_eq!(rm.free_cores(), 8);
    }

    #[test]
    fn prop_alloc_release_conserves_cores() {
        testkit::check("rm conservation", 16, |rng| {
            let rm = SlurmRM::new(MachineSpec::rivanna());
            let total = rm.free_cores();
            let mut live = Vec::new();
            for _ in 0..20 {
                if rng.gen_f64() < 0.6 {
                    let want = 1 + rng.gen_range(60) as usize;
                    if let Ok(a) = rm.allocate(want, false) {
                        live.push(a);
                    }
                } else if !live.is_empty() {
                    let i = rng.gen_range(live.len() as u64) as usize;
                    let a = live.swap_remove(i);
                    rm.release(&a);
                }
                let used: usize = live.iter().map(|a| a.cores_taken()).sum();
                assert_eq!(rm.free_cores(), total - used);
            }
            for a in &live {
                rm.release(a);
            }
            assert_eq!(rm.free_cores(), total);
        });
    }

    #[test]
    fn lsf_latency_is_variable() {
        let rm = LsfRM::new(MachineSpec::summit());
        let a = rm.allocate(42, false).unwrap();
        let b = rm.allocate(42, false).unwrap();
        assert_ne!(a.startup_latency, b.startup_latency);
    }
}
