//! Communicator substrate — the stand-in for Cylon's MPI/UCX/GLOO channel
//! abstraction (paper §3.2, Fig 2).
//!
//! Ranks are OS threads inside one process; point-to-point messages travel
//! through per-rank mailboxes (mutex + condvar), and MPI-style collectives
//! are composed from them. Every collective also charges the calling rank's
//! *simulated clock* via [`NetModel`], which is how cluster-scale network
//! behaviour (the part we cannot run on real InfiniBand) enters the
//! reproduced figures.
//!
//! The key capability the paper gets from RAPTOR — **private communicators
//! of task-requested size carved out of a bigger world at runtime** — is
//! [`Communicator::subgroup`]: any subset of world ranks can rendezvous into
//! a fresh, isolated communication context without involving other ranks.

mod netmodel;

pub use netmodel::{Backend, NetModel};

use std::any::Any;
use std::cell::Cell;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

use crate::df::Table;
use crate::error::{Error, Result};
use crate::util::faults;
// Every comm lock recovers from poison: a rank that panics with an
// injected fault may still hold a mailbox/barrier lock, and both its
// blocked peers and the post-failure world reset must keep going (the
// explicit `poisoned` marks, set before any panic, carry the fault).
use crate::util::lock_recover;

/// Payloads that can travel through the communicator. `approx_bytes` feeds
/// the network cost model.
pub trait CommData: Send + 'static {
    fn approx_bytes(&self) -> usize;
}

macro_rules! fixed_size {
    ($($t:ty),*) => {$(
        impl CommData for $t {
            fn approx_bytes(&self) -> usize { std::mem::size_of::<$t>() }
        }
    )*};
}
fixed_size!(u8, u32, u64, i32, i64, f64, usize, bool, ());

macro_rules! vec_size {
    ($($t:ty),*) => {$(
        impl CommData for Vec<$t> {
            fn approx_bytes(&self) -> usize { self.len() * std::mem::size_of::<$t>() }
        }
    )*};
}
vec_size!(u8, u32, u64, i32, i64, f64, usize);

impl CommData for String {
    fn approx_bytes(&self) -> usize {
        self.len()
    }
}

/// Charges the **visible window** only (`Table::byte_size`): a slice view
/// over a large buffer costs what it would actually put on the wire, not
/// the backing allocation it shares — keeping [`NetModel`] honest now that
/// tables are zero-copy views.
impl CommData for Table {
    fn approx_bytes(&self) -> usize {
        self.byte_size()
    }
}

impl CommData for Vec<Table> {
    fn approx_bytes(&self) -> usize {
        self.iter().map(|t| t.byte_size()).sum()
    }
}

/// Charges the chunk's **logical** bytes whether it is resident or
/// spilled: the receiver will eventually restore and read all of it, so
/// the cost model sees the real payload. (In-process transfer itself is
/// an `Arc` move either way — a spilled chunk travels as a file handle
/// and stays on disk across the hop.)
impl CommData for crate::df::Chunk {
    fn approx_bytes(&self) -> usize {
        self.byte_size()
    }
}

impl CommData for Vec<crate::df::Chunk> {
    fn approx_bytes(&self) -> usize {
        self.iter().map(|c| c.byte_size()).sum()
    }
}

impl<A: CommData, B: CommData> CommData for (A, B) {
    fn approx_bytes(&self) -> usize {
        self.0.approx_bytes() + self.1.approx_bytes()
    }
}

impl CommData for Vec<(i64, i64)> {
    fn approx_bytes(&self) -> usize {
        self.len() * 16
    }
}

type MailKey = (u64, usize, u64); // (context, src group-rank, tag)
type Payload = Box<dyn Any + Send>;

/// One rank's incoming-message store.
///
/// Fault propagation: a fired comm fault *poisons* its context in every
/// mailbox (and barrier) before panicking, so a rank blocked in
/// [`Mailbox::take`] on that context wakes and panics instead of waiting
/// forever on a message its peer will never send. Poison marks are never
/// cleared for private contexts (ids are allocated fresh per task, so a
/// poisoned id is never reused); [`CommWorld::run`] resets everything
/// after a failed run so pooled worlds stay reusable.
#[derive(Default)]
struct MailState {
    slots: HashMap<MailKey, VecDeque<Payload>>,
    /// Contexts poisoned by an injected comm fault.
    poisoned: HashSet<u64>,
}

#[derive(Default)]
struct Mailbox {
    state: Mutex<MailState>,
    cv: Condvar,
}

impl Mailbox {
    fn put(&self, key: MailKey, payload: Payload) {
        let mut st = lock_recover(&self.state);
        st.slots.entry(key).or_default().push_back(payload);
        self.cv.notify_all();
    }

    fn take(&self, key: MailKey) -> Payload {
        let mut st = lock_recover(&self.state);
        loop {
            if st.poisoned.contains(&key.0) {
                panic!("injected fault: communicator ctx {} poisoned", key.0);
            }
            if let Some(q) = st.slots.get_mut(&key) {
                if let Some(p) = q.pop_front() {
                    if q.is_empty() {
                        st.slots.remove(&key);
                    }
                    return p;
                }
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn poison(&self, ctx: u64) {
        let mut st = lock_recover(&self.state);
        st.poisoned.insert(ctx);
        self.cv.notify_all();
    }

    /// Drop all messages and poison marks (only safe with no rank threads
    /// active — the post-failure reset of [`CommWorld::run`]).
    fn reset(&self) {
        let mut st = lock_recover(&self.state);
        st.slots.clear();
        st.poisoned.clear();
    }
}

/// Rendezvous state for one communication context (barrier generations).
struct GroupShared {
    barrier: Mutex<BarrierState>,
    cv: Condvar,
}

#[derive(Default)]
struct BarrierState {
    arrived: usize,
    generation: u64,
    poisoned: bool,
}

impl GroupShared {
    fn new() -> GroupShared {
        GroupShared {
            barrier: Mutex::new(BarrierState::default()),
            cv: Condvar::new(),
        }
    }

    fn wait(&self, group_size: usize) {
        let mut st = lock_recover(&self.barrier);
        let gen = st.generation;
        st.arrived += 1;
        if st.arrived == group_size {
            st.arrived = 0;
            st.generation = st.generation.wrapping_add(1);
            self.cv.notify_all();
        } else {
            while st.generation == gen {
                if st.poisoned {
                    panic!("injected fault: communicator barrier poisoned");
                }
                st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        }
    }

    fn poison(&self) {
        let mut st = lock_recover(&self.barrier);
        st.poisoned = true;
        self.cv.notify_all();
    }
}

/// Process-wide state shared by every rank of a world.
struct WorldInner {
    size: usize,
    mailboxes: Vec<Mailbox>,
    groups: Mutex<HashMap<u64, Arc<GroupShared>>>,
    netmodel: NetModel,
}

impl WorldInner {
    fn group(&self, ctx: u64) -> Arc<GroupShared> {
        let mut groups = lock_recover(&self.groups);
        groups
            .entry(ctx)
            .or_insert_with(|| Arc::new(GroupShared::new()))
            .clone()
    }

    /// Poison `ctx` everywhere: every mailbox and the context's barrier.
    /// Ranks blocked on the context wake and panic; ranks touching it
    /// later panic at that touch. Called by a fired comm fault before the
    /// firing rank panics itself.
    fn poison_ctx(&self, ctx: u64) {
        for mb in &self.mailboxes {
            mb.poison(ctx);
        }
        self.group(ctx).poison();
    }
}

/// A communication world of `size` ranks (the pilot's full allocation).
#[derive(Clone)]
pub struct CommWorld {
    inner: Arc<WorldInner>,
}

/// World context id; subgroup contexts must be distinct from this.
pub const WORLD_CTX: u64 = 0;

impl CommWorld {
    pub fn new(size: usize, netmodel: NetModel) -> CommWorld {
        assert!(size > 0, "world of zero ranks");
        CommWorld {
            inner: Arc::new(WorldInner {
                size,
                mailboxes: (0..size).map(|_| Mailbox::default()).collect(),
                groups: Mutex::new(HashMap::new()),
                netmodel,
            }),
        }
    }

    pub fn size(&self) -> usize {
        self.inner.size
    }

    /// Communicator handle for `world_rank` over the full world.
    pub fn communicator(&self, world_rank: usize) -> Communicator {
        assert!(world_rank < self.inner.size);
        Communicator {
            world: self.inner.clone(),
            ctx: WORLD_CTX,
            ranks: Arc::new((0..self.inner.size).collect()),
            my_rank: world_rank,
            seq: Cell::new(0),
            clock: Cell::new(0.0),
        }
    }

    /// Run `f(rank_communicator)` on every rank (one thread each), BSP
    /// style, and collect the per-rank results in rank order. Panics on any
    /// rank surface as `Error::TaskFailed`.
    pub fn run<R, F>(&self, f: F) -> Result<Vec<R>>
    where
        R: Send + 'static,
        F: Fn(Communicator) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<_> = (0..self.inner.size)
            .map(|rank| {
                let comm = self.communicator(rank);
                let f = f.clone();
                std::thread::Builder::new()
                    .name(format!("rank-{rank}"))
                    .spawn(move || f(comm))
                    .expect("spawn rank thread")
            })
            .collect();
        let mut out = Vec::with_capacity(self.inner.size);
        let mut failure = None;
        for (rank, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(r) => out.push(r),
                Err(p) => {
                    let msg = p
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "<panic>".into());
                    failure.get_or_insert(format!("rank {rank} panicked: {msg}"));
                }
            }
        }
        match failure {
            None => Ok(out),
            Some(msg) => {
                // A panicked run can leave undelivered messages, poison
                // marks, and half-arrived barriers behind. Every rank
                // thread has been joined, so resetting here is race-free —
                // and required for pooled worlds that the engines reuse
                // across queries (a retried run must start clean).
                for mb in &self.inner.mailboxes {
                    mb.reset();
                }
                lock_recover(&self.inner.groups).clear();
                Err(Error::TaskFailed(msg))
            }
        }
    }
}

/// One rank's handle on a communication context (world or private group).
///
/// Not `Sync`: each rank thread owns its communicator, mirroring MPI rank
/// semantics. Collective calls must be made by *all* group members in the
/// same order (standard MPI contract).
pub struct Communicator {
    world: Arc<WorldInner>,
    ctx: u64,
    /// Group-rank -> world-rank translation (sorted, unique).
    ranks: Arc<Vec<usize>>,
    my_rank: usize,
    seq: Cell<u64>,
    clock: Cell<f64>,
}

impl Communicator {
    pub fn rank(&self) -> usize {
        self.my_rank
    }

    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// World rank behind a group rank.
    pub fn world_rank(&self, group_rank: usize) -> usize {
        self.ranks[group_rank]
    }

    /// Accumulated simulated network seconds for this rank.
    pub fn sim_clock(&self) -> f64 {
        self.clock.get()
    }

    /// Reset the simulated clock (engines do this per task iteration).
    pub fn reset_sim_clock(&self) {
        self.clock.set(0.0);
    }

    pub fn netmodel(&self) -> &NetModel {
        &self.world.netmodel
    }

    fn charge(&self, cost: f64) {
        self.clock.set(self.clock.get() + cost);
    }

    fn next_tag(&self) -> u64 {
        let t = self.seq.get();
        self.seq.set(t + 1);
        t
    }

    /// Fault-injection seam for the comm sites (`comm.send`,
    /// `comm.alltoall`). The verdict is keyed so that every rank touching
    /// the same faulted exchange decides identically; on a failure verdict
    /// the whole context is poisoned *before* this rank panics, so peers
    /// blocked anywhere on the context wake and panic instead of hanging.
    /// Latency verdicts sleep on the initiating side only. One relaxed
    /// atomic load when no plan is armed.
    #[inline]
    fn inject(&self, site: &'static str, key: u64, initiator: bool) {
        if let Some(delay_ms) = faults::comm_verdict(site, key) {
            if delay_ms > 0 {
                if initiator {
                    std::thread::sleep(std::time::Duration::from_millis(
                        delay_ms,
                    ));
                }
            } else {
                self.world.poison_ctx(self.ctx);
                panic!(
                    "injected fault at {site}: communicator ctx {} poisoned",
                    self.ctx
                );
            }
        }
    }

    /// Point-to-point send to a group rank (charges the α–β p2p cost).
    pub fn send<T: CommData>(&self, dst: usize, tag: u64, value: T) {
        debug_assert!(dst < self.size(), "send to rank {dst} of {}", self.size());
        // Keyed by ctx alone: a fired `comm.send` fails the whole
        // point-to-point channel of this private communicator, not one
        // message — see util::faults for why per-message faults could
        // strand third ranks of the group.
        self.inject("comm.send", self.ctx, true);
        self.charge(self.world.netmodel.p2p(value.approx_bytes()));
        let world_dst = self.ranks[dst];
        self.world.mailboxes[world_dst].put(
            (self.ctx, self.my_rank, tag),
            Box::new(value),
        );
    }

    /// Blocking typed receive from a group rank.
    pub fn recv<T: CommData>(&self, src: usize, tag: u64) -> T {
        debug_assert!(src < self.size());
        // Same ctx-keyed verdict as `send`: both endpoints of the faulted
        // channel reach it independently.
        self.inject("comm.send", self.ctx, false);
        let payload =
            self.world.mailboxes[self.ranks[self.my_rank]].take((self.ctx, src, tag));
        *payload
            .downcast::<T>()
            .unwrap_or_else(|_| panic!("recv type mismatch (src={src}, tag={tag})"))
    }

    /// Barrier across the group.
    pub fn barrier(&self) {
        self.charge(self.world.netmodel.barrier(self.size()));
        self.world.group(self.ctx).wait(self.size());
    }

    /// Broadcast `value` from `root` to every group member.
    pub fn bcast<T: CommData + Clone>(&self, root: usize, value: Option<T>) -> T {
        let tag = self.next_tag();
        self.charge(self.world.netmodel.bcast(
            self.size(),
            value.as_ref().map(|v| v.approx_bytes()).unwrap_or(0),
        ));
        if self.my_rank == root {
            let v = value.expect("root must supply a value to bcast");
            for dst in 0..self.size() {
                if dst != root {
                    // bytes already charged via the tree model above; use a
                    // zero-cost raw put to avoid double-charging.
                    let world_dst = self.ranks[dst];
                    self.world.mailboxes[world_dst]
                        .put((self.ctx, self.my_rank, tag), Box::new(v.clone()));
                }
            }
            v
        } else {
            self.recv::<T>(root, tag)
        }
    }

    /// Gather every rank's value at `root` (rank order). Non-roots get None.
    pub fn gather<T: CommData>(&self, root: usize, value: T) -> Option<Vec<T>> {
        let tag = self.next_tag();
        self.charge(
            self.world
                .netmodel
                .gather(self.size(), value.approx_bytes()),
        );
        if self.my_rank == root {
            let mut out: Vec<Option<T>> = (0..self.size()).map(|_| None).collect();
            out[root] = Some(value);
            for src in 0..self.size() {
                if src != root {
                    let world_me = self.ranks[self.my_rank];
                    let payload =
                        self.world.mailboxes[world_me].take((self.ctx, src, tag));
                    out[src] = Some(*payload.downcast::<T>().unwrap_or_else(|_| {
                        panic!("gather type mismatch from {src}")
                    }));
                }
            }
            Some(out.into_iter().map(|v| v.unwrap()).collect())
        } else {
            let world_root = self.ranks[root];
            self.world.mailboxes[world_root]
                .put((self.ctx, self.my_rank, tag), Box::new(value));
            None
        }
    }

    /// Allgather: every rank receives every rank's value, in rank order.
    pub fn allgather<T: CommData + Clone>(&self, value: T) -> Vec<T> {
        self.charge(
            self.world
                .netmodel
                .allgather(self.size(), value.approx_bytes()),
        );
        // Implemented as gather-to-0 + bcast over raw puts (cost charged
        // once above with the ring-algorithm model).
        let tag = self.next_tag();
        let root = 0usize;
        if self.my_rank == root {
            let mut out: Vec<Option<T>> = (0..self.size()).map(|_| None).collect();
            out[root] = Some(value);
            for src in 1..self.size() {
                let world_me = self.ranks[self.my_rank];
                let payload = self.world.mailboxes[world_me].take((self.ctx, src, tag));
                out[src] = Some(*payload.downcast::<T>().unwrap());
            }
            let all: Vec<T> = out.into_iter().map(|v| v.unwrap()).collect();
            let tag2 = self.next_tag();
            for dst in 1..self.size() {
                let world_dst = self.ranks[dst];
                self.world.mailboxes[world_dst]
                    .put((self.ctx, root, tag2), Box::new(all.clone()));
            }
            all
        } else {
            let world_root = self.ranks[root];
            self.world.mailboxes[world_root]
                .put((self.ctx, self.my_rank, tag), Box::new(value));
            let tag2 = self.next_tag();
            let world_me = self.ranks[self.my_rank];
            let payload = self.world.mailboxes[world_me].take((self.ctx, root, tag2));
            *payload.downcast::<Vec<T>>().unwrap()
        }
    }

    /// Alltoall: `sends[d]` goes to rank `d`; returns what each rank sent to
    /// us, in rank order. The workhorse of the distributed shuffle.
    pub fn alltoall<T: CommData>(&self, sends: Vec<T>) -> Vec<T> {
        assert_eq!(
            sends.len(),
            self.size(),
            "alltoall requires one payload per rank"
        );
        let mut sends: Vec<Option<T>> = sends.into_iter().map(Some).collect();
        self.alltoall_with(|d| sends[d].take().expect("alltoall slot"))
    }

    /// [`Communicator::alltoall`] with compute/exchange overlap: `make(d)`
    /// builds the payload for rank `d`, and each payload is posted to its
    /// destination's mailbox **as soon as it exists** instead of after the
    /// whole send set is assembled. A receiver whose partition happens to
    /// be carved first can pick it up while this rank is still gathering
    /// the later ones — that is the shuffle's compute/exchange overlap.
    ///
    /// NetModel accounting is schedule-independent: the collective charges
    /// once, by total payload bytes, exactly as [`Communicator::alltoall`]
    /// does — *when* a payload was produced or posted never changes the
    /// simulated clock.
    pub fn alltoall_with<T: CommData>(
        &self,
        mut make: impl FnMut(usize) -> T,
    ) -> Vec<T> {
        let tag = self.next_tag();
        // Keyed by (ctx, tag): collective call order is symmetric across
        // the group (MPI contract), so every rank of this alltoall draws
        // the same verdict at entry, before any payload is posted.
        self.inject(
            "comm.alltoall",
            self.ctx ^ crate::util::splitmix64(tag.wrapping_add(1)),
            true,
        );
        let mut mine: Option<T> = None;
        let mut total = 0usize;
        for dst in 0..self.size() {
            let payload = make(dst);
            total += payload.approx_bytes();
            if dst == self.my_rank {
                mine = Some(payload);
            } else {
                let world_dst = self.ranks[dst];
                self.world.mailboxes[world_dst]
                    .put((self.ctx, self.my_rank, tag), Box::new(payload));
            }
        }
        self.charge(self.world.netmodel.alltoall(self.size(), total));
        let world_me = self.ranks[self.my_rank];
        (0..self.size())
            .map(|src| {
                if src == self.my_rank {
                    mine.take().expect("own alltoall slot")
                } else {
                    let payload =
                        self.world.mailboxes[world_me].take((self.ctx, src, tag));
                    *payload.downcast::<T>().unwrap_or_else(|_| {
                        panic!("alltoall type mismatch from {src}")
                    })
                }
            })
            .collect()
    }

    /// Allreduce a f64 with the given associative op.
    pub fn allreduce_f64(&self, value: f64, op: ReduceOp) -> f64 {
        self.charge(self.world.netmodel.allreduce(self.size(), 8));
        let all = self.allgather_uncharged(value);
        all.into_iter().reduce(|a, b| op.apply(a, b)).unwrap()
    }

    /// Allreduce a u64.
    pub fn allreduce_u64(&self, value: u64, op: ReduceOp) -> u64 {
        self.charge(self.world.netmodel.allreduce(self.size(), 8));
        let all = self.allgather_uncharged(value);
        all.into_iter()
            .reduce(|a, b| op.apply_u64(a, b))
            .unwrap()
    }

    /// Allgather without charging the model (internal building block for
    /// already-charged composite collectives).
    fn allgather_uncharged<T: CommData + Clone>(&self, value: T) -> Vec<T> {
        let saved = self.clock.get();
        let out = self.allgather(value);
        self.clock.set(saved); // discard allgather's charge; caller charged already
        out
    }

    /// Rendezvous a subset of *world* ranks into a private communicator —
    /// the RAPTOR capability (paper §3.4, Fig 3-6). All listed ranks must
    /// call with identical `ctx_id` and `world_ranks`; `ctx_id` must be
    /// unique per construction (the raptor master allocates them).
    pub fn subgroup(&self, ctx_id: u64, world_ranks: &[usize]) -> Result<Communicator> {
        if ctx_id == WORLD_CTX {
            return Err(Error::Comm("subgroup ctx must not be WORLD_CTX".into()));
        }
        let mut sorted = world_ranks.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != world_ranks.len() {
            return Err(Error::Comm("duplicate ranks in subgroup".into()));
        }
        let my_world_rank = self.ranks[self.my_rank];
        let Some(my_rank) = sorted.iter().position(|&r| r == my_world_rank) else {
            return Err(Error::Comm(format!(
                "rank {my_world_rank} not a member of subgroup {ctx_id}"
            )));
        };
        if sorted.iter().any(|&r| r >= self.world.size) {
            return Err(Error::Comm("subgroup rank out of world range".into()));
        }
        let sub = Communicator {
            world: self.world.clone(),
            ctx: ctx_id,
            ranks: Arc::new(sorted),
            my_rank,
            seq: Cell::new(0),
            clock: Cell::new(0.0),
        };
        // Construction rendezvous: mirrors MPI_Comm_create_group semantics
        // and is what the paper measures as communicator-construction
        // overhead.
        sub.charge(self.world.netmodel.barrier(sub.size()));
        self.world.group(ctx_id).wait(sub.size());
        Ok(sub)
    }

    /// Drop the context registry entry for a finished task's communicator
    /// (master calls this after collecting results).
    pub fn release_ctx(&self, ctx_id: u64) {
        lock_recover(&self.world.groups).remove(&ctx_id);
    }
}

/// Reduction operators for allreduce.
#[derive(Clone, Copy, Debug)]
pub enum ReduceOp {
    Sum,
    Min,
    Max,
}

impl ReduceOp {
    fn apply(&self, a: f64, b: f64) -> f64 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Min => a.min(b),
            ReduceOp::Max => a.max(b),
        }
    }
    fn apply_u64(&self, a: u64, b: u64) -> u64 {
        match self {
            ReduceOp::Sum => a.wrapping_add(b), // fingerprint sums wrap by design
            ReduceOp::Min => a.min(b),
            ReduceOp::Max => a.max(b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit;

    fn world(p: usize) -> CommWorld {
        CommWorld::new(p, NetModel::disabled())
    }

    #[test]
    fn p2p_roundtrip() {
        let w = world(2);
        let out = w
            .run(|c| {
                if c.rank() == 0 {
                    c.send(1, 7, vec![1i64, 2, 3]);
                    0i64
                } else {
                    let v: Vec<i64> = c.recv(0, 7);
                    v.iter().sum()
                }
            })
            .unwrap();
        assert_eq!(out, vec![0, 6]);
    }

    #[test]
    fn barrier_and_bcast() {
        let w = world(4);
        let out = w
            .run(|c| {
                c.barrier();
                let v = c.bcast(2, (c.rank() == 2).then_some(41u64));
                c.barrier();
                v + 1
            })
            .unwrap();
        assert_eq!(out, vec![42; 4]);
    }

    #[test]
    fn gather_and_allgather() {
        let w = world(5);
        let out = w
            .run(|c| {
                let g = c.gather(0, c.rank() as u64);
                let all = c.allgather(c.rank() as u64 * 10);
                (g, all)
            })
            .unwrap();
        assert_eq!(out[0].0, Some(vec![0, 1, 2, 3, 4]));
        for (i, (g, all)) in out.iter().enumerate() {
            if i != 0 {
                assert!(g.is_none());
            }
            assert_eq!(all, &vec![0, 10, 20, 30, 40]);
        }
    }

    #[test]
    fn alltoall_transposes() {
        let w = world(3);
        let out = w
            .run(|c| {
                let sends: Vec<u64> =
                    (0..3).map(|d| (c.rank() * 10 + d) as u64).collect();
                c.alltoall(sends)
            })
            .unwrap();
        // rank r receives [0r, 10+r, 20+r]
        assert_eq!(out[0], vec![0, 10, 20]);
        assert_eq!(out[1], vec![1, 11, 21]);
        assert_eq!(out[2], vec![2, 12, 22]);
    }

    #[test]
    fn allreduce_ops() {
        let w = world(4);
        let out = w
            .run(|c| {
                let s = c.allreduce_f64(c.rank() as f64, ReduceOp::Sum);
                let mx = c.allreduce_u64(c.rank() as u64, ReduceOp::Max);
                let mn = c.allreduce_u64(c.rank() as u64 + 5, ReduceOp::Min);
                (s, mx, mn)
            })
            .unwrap();
        for (s, mx, mn) in out {
            assert_eq!(s, 6.0);
            assert_eq!(mx, 3);
            assert_eq!(mn, 5);
        }
    }

    #[test]
    fn subgroup_isolated_contexts() {
        // Two disjoint subgroups run concurrent collectives without
        // interference — the RAPTOR private-communicator property.
        let w = world(6);
        let out = w
            .run(|c| {
                let my_world = c.rank();
                let (ctx, members) = if my_world < 3 {
                    (1u64, vec![0usize, 1, 2])
                } else {
                    (2u64, vec![3usize, 4, 5])
                };
                let sub = c.subgroup(ctx, &members).unwrap();
                assert_eq!(sub.size(), 3);
                let sum = sub.allreduce_u64(my_world as u64, ReduceOp::Sum);
                sub.barrier();
                sum
            })
            .unwrap();
        assert_eq!(out, vec![3, 3, 3, 12, 12, 12]);
    }

    #[test]
    fn subgroup_validation() {
        let w = world(2);
        let out = w
            .run(|c| {
                if c.rank() == 0 {
                    let dup = c.subgroup(5, &[0, 0]).err().map(|e| e.to_string());
                    let non_member =
                        c.subgroup(6, &[1]).err().map(|e| e.to_string());
                    let world_ctx =
                        c.subgroup(WORLD_CTX, &[0]).err().map(|e| e.to_string());
                    (dup, non_member, world_ctx)
                } else {
                    (None, None, None)
                }
            })
            .unwrap();
        let (dup, non_member, world_ctx) = &out[0];
        assert!(dup.as_ref().unwrap().contains("duplicate"));
        assert!(non_member.as_ref().unwrap().contains("not a member"));
        assert!(world_ctx.as_ref().unwrap().contains("WORLD_CTX"));
    }

    #[test]
    fn panic_in_rank_becomes_error() {
        let w = world(2);
        let err = w
            .run(|c| {
                if c.rank() == 1 {
                    panic!("injected fault");
                }
            })
            .unwrap_err();
        assert!(err.to_string().contains("injected fault"), "{err}");
    }

    #[test]
    fn netmodel_charges_clock() {
        let w = CommWorld::new(4, NetModel::new(Backend::Mpi, 1.0));
        let clocks = w
            .run(|c| {
                let _ = c.allgather(vec![0u8; 1024]);
                let _ = c.alltoall(vec![vec![0u8; 256]; 4]);
                c.sim_clock()
            })
            .unwrap();
        for clk in clocks {
            assert!(clk > 0.0);
        }
    }

    #[test]
    fn alltoall_with_matches_alltoall_and_charges_identically() {
        // The overlap entry point must return the same payloads AND the
        // same simulated clock as the assemble-then-send baseline: the
        // model charges by bytes, never by when work was scheduled.
        let w = CommWorld::new(4, NetModel::new(Backend::Mpi, 1.0));
        let out = w
            .run(|c| {
                let sends: Vec<Vec<u8>> = (0..4)
                    .map(|d| vec![c.rank() as u8; (d + 1) * 64])
                    .collect();
                let eager = c.alltoall(sends.clone());
                let clk_after_first = c.sim_clock();
                let lazy = c.alltoall_with(|d| sends[d].clone());
                let clk_after_second = c.sim_clock();
                (eager == lazy, clk_after_first, clk_after_second)
            })
            .unwrap();
        for (same, first, second) in out {
            assert!(same, "alltoall_with must deliver identical payloads");
            assert!(first > 0.0);
            // The second collective added exactly the first one's cost.
            assert!(((second - first) - first).abs() < 1e-12);
        }
    }

    #[test]
    fn approx_bytes_charges_window_not_backing() {
        use crate::df::{Column, DataType, Schema};
        let t = Table::new(
            Schema::of(&[("k", DataType::Int64)]),
            vec![Column::from_i64((0..100).collect())],
        )
        .unwrap();
        assert_eq!(t.approx_bytes(), 800);
        // A slice view charges only its window, not the 800-byte backing
        // buffer it keeps alive.
        let window = t.slice(10, 5);
        assert_eq!(window.approx_bytes(), 40);
        assert_eq!(window.backing_byte_size(), 800);
        // A per-destination send vector charges the window sum.
        let sends = vec![t.slice(0, 2), t.slice(2, 2)];
        assert_eq!(sends.approx_bytes(), 32);
    }

    #[test]
    fn prop_alltoall_conservation() {
        testkit::check("alltoall conserves elements", 8, |rng| {
            let p = 2 + rng.gen_range(4) as usize;
            let seed = rng.next_u64();
            let w = world(p);
            let results = w
                .run(move |c| {
                    let mut rng = crate::util::Rng::new(
                        seed ^ crate::util::splitmix64(c.rank() as u64),
                    );
                    let sends: Vec<Vec<i64>> = (0..c.size())
                        .map(|_| {
                            (0..rng.gen_range(20)).map(|_| rng.gen_i64(0, 100)).collect()
                        })
                        .collect();
                    let sent_total: i64 =
                        sends.iter().flat_map(|v| v.iter()).sum();
                    let recvd = c.alltoall(sends);
                    let recv_total: i64 =
                        recvd.iter().flat_map(|v| v.iter()).sum();
                    let global_sent =
                        c.allreduce_u64(sent_total as u64, ReduceOp::Sum);
                    let global_recv =
                        c.allreduce_u64(recv_total as u64, ReduceOp::Sum);
                    (global_sent, global_recv)
                })
                .unwrap();
            for (s, r) in results {
                assert_eq!(s, r);
            }
        });
    }
}
