//! Network cost model — the calibrated stand-in for the paper's
//! MPI/UCX/GLOO over InfiniBand fabrics (DESIGN.md §2).
//!
//! Every collective charges virtual seconds to the calling rank's simulated
//! clock using the classic α–β (latency–bandwidth) model with per-algorithm
//! terms (ring allgather, pairwise alltoall, binomial broadcast/reduce).
//! `alpha`/`beta` are per *backend* (MPI / UCX / GLOO channel, paper Fig 2)
//! and scaled by a per-*fabric* factor (Rivanna vs Summit interconnects).
//! The model is what makes weak-scaling curves rise gently with rank count
//! (α·p allgather terms) while strong-scaling falls ~1/p — the shapes the
//! paper reports.

/// Communication backend flavor (paper Fig 2: Open-MPI / UCX / GLOO).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Mpi,
    Ucx,
    Gloo,
}

impl Backend {
    /// (alpha seconds/hop, beta seconds/byte) — relative magnitudes follow
    /// published microbenchmarks: UCX lowest latency, GLOO highest; all
    /// scaled so modeled times land in the same range as the paper's
    /// scaled-down workloads.
    fn params(&self) -> (f64, f64) {
        match self {
            Backend::Ucx => (4.0e-6, 0.8e-9),
            Backend::Mpi => (6.0e-6, 1.0e-9),
            Backend::Gloo => (18.0e-6, 1.6e-9),
        }
    }
}

/// α–β network model with per-fabric scaling.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// Per-hop latency in seconds.
    pub alpha: f64,
    /// Per-byte transfer cost in seconds.
    pub beta: f64,
    /// Disabled models charge nothing (pure in-memory execution).
    pub enabled: bool,
}

impl NetModel {
    pub fn disabled() -> NetModel {
        NetModel { alpha: 0.0, beta: 0.0, enabled: false }
    }

    /// Model for a backend on a fabric with the given scaling factor
    /// (1.0 = EDR InfiniBand-class; larger = slower fabric).
    pub fn new(backend: Backend, fabric_scale: f64) -> NetModel {
        let (alpha, beta) = backend.params();
        NetModel {
            alpha: alpha * fabric_scale,
            beta: beta * fabric_scale,
            enabled: true,
        }
    }

    /// Scale only the per-byte term: used for the rows-/1000 substitution
    /// (each simulated byte stands for `scale` real bytes; per-hop latency
    /// is unaffected because message *counts* are preserved).
    pub fn with_data_scale(mut self, scale: f64) -> NetModel {
        self.beta *= scale;
        self
    }

    #[inline]
    fn on(&self, cost: f64) -> f64 {
        if self.enabled {
            cost
        } else {
            0.0
        }
    }

    /// Point-to-point message.
    pub fn p2p(&self, bytes: usize) -> f64 {
        self.on(self.alpha + self.beta * bytes as f64)
    }

    /// Binomial-tree broadcast of `bytes` to `p` ranks.
    pub fn bcast(&self, p: usize, bytes: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let stages = (p as f64).log2().ceil();
        self.on(stages * (self.alpha + self.beta * bytes as f64))
    }

    /// Ring allgather: each rank contributes `bytes`, receives from p-1.
    pub fn allgather(&self, p: usize, bytes: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let steps = (p - 1) as f64;
        self.on(steps * self.alpha + steps * self.beta * bytes as f64)
    }

    /// Gather to root (binomial): root pays the aggregate receive.
    pub fn gather(&self, p: usize, bytes_per_rank: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let stages = (p as f64).log2().ceil();
        self.on(
            stages * self.alpha
                + self.beta * ((p - 1) as f64) * bytes_per_rank as f64,
        )
    }

    /// Pairwise-exchange alltoall: p-1 steps, `total_send_bytes` leaves the
    /// rank over the whole exchange.
    pub fn alltoall(&self, p: usize, total_send_bytes: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        self.on(
            (p - 1) as f64 * self.alpha + self.beta * total_send_bytes as f64,
        )
    }

    /// Recursive-doubling allreduce of `bytes`.
    pub fn allreduce(&self, p: usize, bytes: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let stages = (p as f64).log2().ceil();
        self.on(stages * (self.alpha + self.beta * bytes as f64))
    }

    /// Barrier = zero-byte allreduce.
    pub fn barrier(&self, p: usize) -> f64 {
        self.allreduce(p, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_charges_nothing() {
        let m = NetModel::disabled();
        assert_eq!(m.p2p(1 << 20), 0.0);
        assert_eq!(m.alltoall(64, 1 << 20), 0.0);
    }

    #[test]
    fn costs_scale_with_bytes_and_ranks() {
        let m = NetModel::new(Backend::Mpi, 1.0);
        assert!(m.p2p(1 << 20) > m.p2p(1 << 10));
        assert!(m.allgather(64, 1024) > m.allgather(8, 1024));
        assert!(m.alltoall(8, 1 << 20) > m.alltoall(8, 1 << 10));
        assert_eq!(m.bcast(1, 1 << 20), 0.0);
    }

    #[test]
    fn backend_ordering() {
        // Latency: UCX < MPI < GLOO, per the channel microbenchmarks the
        // Cylon papers report.
        let (ucx, mpi, gloo) = (
            NetModel::new(Backend::Ucx, 1.0),
            NetModel::new(Backend::Mpi, 1.0),
            NetModel::new(Backend::Gloo, 1.0),
        );
        assert!(ucx.alpha < mpi.alpha && mpi.alpha < gloo.alpha);
        assert!(ucx.beta <= mpi.beta && mpi.beta <= gloo.beta);
    }

    #[test]
    fn fabric_scale_multiplies() {
        let fast = NetModel::new(Backend::Mpi, 1.0);
        let slow = NetModel::new(Backend::Mpi, 4.0);
        assert!((slow.p2p(1000) - 4.0 * fast.p2p(1000)).abs() < 1e-12);
    }
}
