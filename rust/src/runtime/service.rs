//! Cross-thread kernel facade.
//!
//! `PjRtClient` is `Rc`-based and thread-bound, so rank worker threads
//! cannot hold executables directly. [`KernelService`] spawns a small pool
//! of server threads, each owning its own [`ArtifactStore`] (client +
//! compiled executables); rank threads submit requests over a shared queue
//! and block on a per-request reply channel. Pool size trades compile time
//! and memory for hot-path parallelism (see EXPERIMENTS.md §Perf).

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};

use super::artifact::ArtifactStore;

enum Request {
    ShufflePlan {
        keys: Vec<i64>,
        nparts: u32,
        reply: mpsc::SyncSender<Result<Vec<i32>>>,
    },
    BlockSort {
        keys: Vec<i64>,
        payload: Vec<i32>,
        reply: mpsc::SyncSender<Result<(Vec<i64>, Vec<i32>)>>,
    },
    Shutdown,
}

struct Shared {
    tx: Mutex<mpsc::Sender<Request>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    pool: usize,
    closed: AtomicBool,
}

/// Cloneable handle to the kernel server pool.
#[derive(Clone)]
pub struct KernelService {
    shared: Arc<Shared>,
}

impl KernelService {
    /// Start `pool` server threads, each loading + compiling the artifacts
    /// in `dir`. Fails fast if any server cannot load the artifacts.
    pub fn start(dir: &Path, pool: usize) -> Result<KernelService> {
        assert!(pool > 0);
        let (tx, rx) = mpsc::channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(pool);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for i in 0..pool {
            let rx = rx.clone();
            let dir = dir.to_path_buf();
            let ready = ready_tx.clone();
            let h = std::thread::Builder::new()
                .name(format!("kernel-server-{i}"))
                .spawn(move || {
                    let store = match ArtifactStore::load(&dir) {
                        Ok(s) => {
                            let _ = ready.send(Ok(()));
                            s
                        }
                        Err(e) => {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    };
                    loop {
                        let req = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match req {
                            Ok(Request::ShufflePlan { keys, nparts, reply }) => {
                                let _ = reply.send(store.shuffle_plan(&keys, nparts));
                            }
                            Ok(Request::BlockSort { keys, payload, reply }) => {
                                let _ =
                                    reply.send(store.block_sort(&keys, &payload));
                            }
                            Ok(Request::Shutdown) | Err(_) => break,
                        }
                    }
                })
                .expect("spawn kernel server");
            workers.push(h);
        }
        drop(ready_tx);
        for _ in 0..pool {
            ready_rx
                .recv()
                .map_err(|_| Error::Runtime("kernel server died at startup".into()))??;
        }
        Ok(KernelService {
            shared: Arc::new(Shared {
                tx: Mutex::new(tx),
                workers: Mutex::new(workers),
                pool,
                closed: AtomicBool::new(false),
            }),
        })
    }

    /// Start with the default artifact dir and a pool sized for the host.
    pub fn start_default() -> Result<KernelService> {
        let pool = std::thread::available_parallelism()
            .map(|p| p.get().min(4))
            .unwrap_or(2);
        KernelService::start(&ArtifactStore::default_dir(), pool)
    }

    pub fn pool_size(&self) -> usize {
        self.shared.pool
    }

    fn send(&self, req: Request) -> Result<()> {
        if self.shared.closed.load(Ordering::Acquire) {
            return Err(Error::Runtime(
                "kernel service is shut down".into(),
            ));
        }
        self.shared.tx.lock().unwrap().send(req).map_err(|_| {
            Error::Runtime("kernel service workers are gone".into())
        })
    }

    /// Partition ids via the PJRT `shuffle_plan` artifact.
    pub fn shuffle_plan(&self, keys: Vec<i64>, nparts: u32) -> Result<Vec<i32>> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.send(Request::ShufflePlan { keys, nparts, reply })?;
        rx.recv()
            .map_err(|_| Error::Runtime("kernel server dropped request".into()))?
    }

    /// Block sort via the PJRT `block_sort` artifact.
    pub fn block_sort(
        &self,
        keys: Vec<i64>,
        payload: Vec<i32>,
    ) -> Result<(Vec<i64>, Vec<i32>)> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.send(Request::BlockSort { keys, payload, reply })?;
        rx.recv()
            .map_err(|_| Error::Runtime("kernel server dropped request".into()))?
    }

    /// Stop the pool (joins all server threads). Idempotent: the first
    /// call drains the pool, later calls are no-ops, and any
    /// [`KernelService::shuffle_plan`] / [`KernelService::block_sort`]
    /// after shutdown returns [`Error::Runtime`] instead of panicking.
    pub fn shutdown(&self) {
        if self.shared.closed.swap(true, Ordering::AcqRel) {
            return;
        }
        {
            let tx = self.shared.tx.lock().unwrap();
            for _ in 0..self.shared.pool {
                let _ = tx.send(Request::Shutdown);
            }
        }
        let mut workers = self.shared.workers.lock().unwrap();
        for h in workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hash::partition_ids;

    fn service() -> Option<KernelService> {
        let dir = ArtifactStore::default_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(KernelService::start(&dir, 2).unwrap())
    }

    #[test]
    fn concurrent_requests_from_many_threads() {
        let Some(svc) = service() else { return };
        let mut handles = Vec::new();
        for t in 0..8 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                let keys: Vec<i64> = (0..500).map(|i| (i * 31 + t) as i64).collect();
                let got = svc.shuffle_plan(keys.clone(), 7).unwrap();
                assert_eq!(got, partition_ids(&keys, 7));
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn block_sort_via_service() {
        let Some(svc) = service() else { return };
        let keys = vec![3i64, 1, 2];
        let (sk, sp) = svc.block_sort(keys, vec![0, 1, 2]).unwrap();
        assert_eq!(sk, vec![1, 2, 3]);
        assert_eq!(sp, vec![1, 2, 0]);
        svc.shutdown();
    }

    #[test]
    fn startup_failure_is_reported() {
        assert!(KernelService::start(Path::new("/no-such-dir"), 1).is_err());
    }

    #[test]
    fn shutdown_is_idempotent_and_post_shutdown_calls_error() {
        let Some(svc) = service() else { return };
        svc.shutdown();
        svc.shutdown(); // second call must be a no-op, not a panic
        let err = svc.shuffle_plan(vec![1, 2, 3], 2).unwrap_err();
        assert!(
            err.to_string().contains("shut down"),
            "expected typed shutdown error, got: {err}"
        );
        let err = svc.block_sort(vec![3, 1], vec![0, 1]).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
        // Clones share the closed flag.
        let clone = svc.clone();
        assert!(clone.shuffle_plan(vec![1], 1).is_err());
        clone.shutdown();
    }
}
