//! Artifact loading and single-thread kernel execution.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (thread-bound), so an
//! [`ArtifactStore`] lives on one thread; [`super::KernelService`] provides
//! the cross-thread facade the rank workers use.
//!
//! Interchange format is HLO **text** (never serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and aot.py).
//!
//! The `xla` dependency is optional (`pjrt` cargo feature). Without it the
//! manifest/metadata handling still works, but [`ArtifactStore::load`]
//! reports that the PJRT data plane is unavailable — the native kernel path
//! ([`crate::util::hash::partition_ids`], local sort) is always present.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Block sizes fixed at AOT time — must match python/compile/kernels.
pub const HASH_BLOCK: usize = 16384;
pub const SORT_BLOCK: usize = 1024;

/// One manifest entry: artifact name, file, and declared signatures.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub arg_spec: String,
    pub out_spec: String,
}

/// Parse `manifest.txt` (written by aot.py).
pub fn read_manifest(dir: &Path) -> Result<Vec<ArtifactMeta>> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        Error::Runtime(format!(
            "cannot read {} — run `make artifacts` first ({e})",
            path.display()
        ))
    })?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split('\t').collect();
        if parts.len() != 4 {
            return Err(Error::Runtime(format!(
                "manifest line {} malformed: '{line}'",
                i + 1
            )));
        }
        out.push(ArtifactMeta {
            name: parts[0].into(),
            file: parts[1].into(),
            arg_spec: parts[2].into(),
            out_spec: parts[3].into(),
        });
    }
    Ok(out)
}

/// Thread-bound store of compiled kernel executables.
pub struct ArtifactStore {
    #[cfg(feature = "pjrt")]
    #[allow(dead_code)]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    #[cfg(not(feature = "pjrt"))]
    #[allow(dead_code)]
    exes: HashMap<String, ()>,
    pub metas: Vec<ArtifactMeta>,
    pub dir: PathBuf,
}

impl ArtifactStore {
    /// Load + compile every artifact in `dir` on a fresh CPU PJRT client.
    #[cfg(feature = "pjrt")]
    pub fn load(dir: &Path) -> Result<ArtifactStore> {
        let metas = read_manifest(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut exes = HashMap::new();
        for m in &metas {
            let path = dir.join(&m.file);
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            exes.insert(m.name.clone(), exe);
        }
        Ok(ArtifactStore { client, exes, metas, dir: dir.to_path_buf() })
    }

    /// Without the `pjrt` feature the artifacts cannot be compiled; loading
    /// fails with a descriptive error (the manifest check comes first so the
    /// "run `make artifacts`" guidance still fires on missing files).
    #[cfg(not(feature = "pjrt"))]
    pub fn load(dir: &Path) -> Result<ArtifactStore> {
        let _metas = read_manifest(dir)?;
        Err(Error::Runtime(
            "PJRT data plane unavailable: built without the `pjrt` cargo \
             feature (rebuild with `--features pjrt`)"
                .into(),
        ))
    }

    /// Default artifact directory: `$RC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("RC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    #[cfg(feature = "pjrt")]
    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.exes
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("no artifact named '{name}'")))
    }

    /// Run the `shuffle_plan` artifact over one padded block of exactly
    /// [`HASH_BLOCK`] keys; returns the partition ids.
    #[cfg(feature = "pjrt")]
    fn shuffle_plan_block(&self, keys: &[i64], nparts: u32) -> Result<Vec<i32>> {
        debug_assert_eq!(keys.len(), HASH_BLOCK);
        let exe = self.exe("shuffle_plan")?;
        let k = xla::Literal::vec1(keys);
        let p = xla::Literal::vec1(&[nparts]);
        let result = exe.execute::<xla::Literal>(&[k, p])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?; // return_tuple=True on the python side
        Ok(out.to_vec::<i32>()?)
    }

    /// Partition ids for arbitrarily many keys (pads the tail block; the
    /// PJRT twin of `util::hash::partition_ids`).
    #[cfg(feature = "pjrt")]
    pub fn shuffle_plan(&self, keys: &[i64], nparts: u32) -> Result<Vec<i32>> {
        if nparts == 0 {
            return Err(Error::Runtime("shuffle_plan with nparts=0".into()));
        }
        let mut out = Vec::with_capacity(keys.len());
        let mut buf = [0i64; HASH_BLOCK];
        for chunk in keys.chunks(HASH_BLOCK) {
            if chunk.len() == HASH_BLOCK {
                out.extend(self.shuffle_plan_block(chunk, nparts)?);
            } else {
                buf[..chunk.len()].copy_from_slice(chunk);
                buf[chunk.len()..].fill(0);
                let ids = self.shuffle_plan_block(&buf, nparts)?;
                out.extend(&ids[..chunk.len()]);
            }
        }
        Ok(out)
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn shuffle_plan(&self, _keys: &[i64], _nparts: u32) -> Result<Vec<i32>> {
        Err(Error::Runtime("built without the `pjrt` feature".into()))
    }

    /// Run the `block_sort` artifact on exactly [`SORT_BLOCK`] (key,
    /// payload) lanes; returns (sorted keys, permuted payload).
    #[cfg(feature = "pjrt")]
    fn block_sort_exact(
        &self,
        keys: &[i64],
        payload: &[i32],
    ) -> Result<(Vec<i64>, Vec<i32>)> {
        debug_assert_eq!(keys.len(), SORT_BLOCK);
        let exe = self.exe("block_sort")?;
        let k = xla::Literal::vec1(keys);
        let p = xla::Literal::vec1(payload);
        let result = exe.execute::<xla::Literal>(&[k, p])?[0][0].to_literal_sync()?;
        let (sk, sp) = result.to_tuple2()?;
        Ok((sk.to_vec::<i64>()?, sp.to_vec::<i32>()?))
    }

    /// Sort up to [`SORT_BLOCK`] keys (padding with `i64::MAX`, truncating
    /// after); payload carries caller row indices.
    #[cfg(feature = "pjrt")]
    pub fn block_sort(
        &self,
        keys: &[i64],
        payload: &[i32],
    ) -> Result<(Vec<i64>, Vec<i32>)> {
        if keys.len() != payload.len() {
            return Err(Error::Runtime("block_sort ragged inputs".into()));
        }
        if keys.len() > SORT_BLOCK {
            return Err(Error::Runtime(format!(
                "block_sort of {} lanes exceeds SORT_BLOCK={SORT_BLOCK}",
                keys.len()
            )));
        }
        if keys.len() == SORT_BLOCK {
            return self.block_sort_exact(keys, payload);
        }
        let n = keys.len();
        let mut kbuf = vec![i64::MAX; SORT_BLOCK];
        let mut pbuf = vec![-1i32; SORT_BLOCK];
        kbuf[..n].copy_from_slice(keys);
        pbuf[..n].copy_from_slice(payload);
        let (sk, sp) = self.block_sort_exact(&kbuf, &pbuf)?;
        // Padding keys are i64::MAX and sort to the tail. Real i64::MAX keys
        // (payload >= 0) must be kept; filter by payload sentinel instead of
        // simple truncation.
        let mut out_k = Vec::with_capacity(n);
        let mut out_p = Vec::with_capacity(n);
        for (k, p) in sk.into_iter().zip(sp) {
            if p >= 0 {
                out_k.push(k);
                out_p.push(p);
            }
        }
        debug_assert_eq!(out_k.len(), n);
        Ok((out_k, out_p))
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn block_sort(
        &self,
        _keys: &[i64],
        _payload: &[i32],
    ) -> Result<(Vec<i64>, Vec<i32>)> {
        Err(Error::Runtime("built without the `pjrt` feature".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    #[cfg(feature = "pjrt")]
    use crate::util::hash::partition_ids;

    #[cfg(feature = "pjrt")]
    fn store() -> Option<ArtifactStore> {
        let dir = ArtifactStore::default_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(ArtifactStore::load(&dir).expect("artifact store loads"))
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn manifest_has_both_kernels() {
        let Some(s) = store() else { return };
        let names: Vec<&str> = s.metas.iter().map(|m| m.name.as_str()).collect();
        assert!(names.contains(&"shuffle_plan"));
        assert!(names.contains(&"block_sort"));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_matches_native_hash() {
        let Some(s) = store() else { return };
        // The L3<->L1 bit-compatibility contract.
        let keys: Vec<i64> = (0..HASH_BLOCK as i64)
            .map(|i| i.wrapping_mul(0x9E3779B97F4A7C15u64 as i64) ^ (i << 7))
            .collect();
        for nparts in [1u32, 2, 7, 37, 518] {
            let pjrt = s.shuffle_plan(&keys, nparts).unwrap();
            let native = partition_ids(&keys, nparts);
            assert_eq!(pjrt, native, "nparts={nparts}");
        }
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn shuffle_plan_pads_tail() {
        let Some(s) = store() else { return };
        let keys: Vec<i64> = (0..100).collect();
        let pjrt = s.shuffle_plan(&keys, 4).unwrap();
        assert_eq!(pjrt, partition_ids(&keys, 4));
        assert_eq!(pjrt.len(), 100);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn block_sort_sorts() {
        let Some(s) = store() else { return };
        let mut rng = crate::util::Rng::new(3);
        let keys: Vec<i64> = (0..SORT_BLOCK).map(|_| rng.gen_i64(-1000, 1000)).collect();
        let payload: Vec<i32> = (0..SORT_BLOCK as i32).collect();
        let (sk, sp) = s.block_sort(&keys, &payload).unwrap();
        let mut expect = keys.clone();
        expect.sort_unstable();
        assert_eq!(sk, expect);
        for (i, &p) in sp.iter().enumerate() {
            assert_eq!(keys[p as usize], sk[i]);
        }
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn block_sort_partial_block() {
        let Some(s) = store() else { return };
        let keys = vec![5i64, -3, i64::MAX, 0];
        let payload = vec![0i32, 1, 2, 3];
        let (sk, sp) = s.block_sort(&keys, &payload).unwrap();
        assert_eq!(sk, vec![-3, 0, 5, i64::MAX]);
        assert_eq!(sp, vec![1, 3, 0, 2]);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn block_sort_rejects_oversize() {
        let Some(s) = store() else { return };
        let keys = vec![0i64; SORT_BLOCK + 1];
        let payload = vec![0i32; SORT_BLOCK + 1];
        assert!(s.block_sort(&keys, &payload).is_err());
    }

    #[test]
    fn missing_manifest_is_informative() {
        let err = ArtifactStore::load(Path::new("/nonexistent-dir"))
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn manifest_parses_well_formed_lines() {
        let dir = std::env::temp_dir().join("rc-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "shuffle_plan\tshuffle.hlo\ti64[16384],u32[1]\ti32[16384]\n\n",
        )
        .unwrap();
        let metas = read_manifest(&dir).unwrap();
        assert_eq!(metas.len(), 1);
        assert_eq!(metas[0].name, "shuffle_plan");
        std::fs::write(dir.join("manifest.txt"), "only-two\tfields\n").unwrap();
        assert!(read_manifest(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
