//! PJRT runtime: load the AOT HLO artifacts produced by
//! `python/compile/aot.py` and serve compiled executables to the data-plane
//! hot path. Python never runs here — the artifacts are plain HLO text.

mod artifact;
mod service;

pub use artifact::{ArtifactMeta, ArtifactStore, HASH_BLOCK, SORT_BLOCK};
pub use service::KernelService;
