//! Extensible operator API — the open replacement for the old closed
//! `CylonOp` enum.
//!
//! The paper's pipeline is "a collection of data frame operators arranged
//! in a DAG" (§4.4); this module is where that collection is allowed to
//! *grow*. A task's operation is an [`OpHandle`] (`Arc<dyn Operator>`)
//! carried inside its [`TaskDescription`]; the RAPTOR executor
//! ([`crate::raptor`]) resolves staged inputs, calls
//! [`Operator::execute`] on every rank of the private communicator, and
//! handles the common scaffolding (gather, stats aggregation) — so a new
//! operator never touches pilot/raptor internals.
//!
//! Eight operators ship built in:
//!
//! | name       | inputs | kernel |
//! |------------|--------|--------|
//! | `generate` | 0      | deterministic synthetic partition ([`gen_table`]) |
//! | `scan-csv` | 0      | parallel CSV scan, per-rank window (zero-copy slice) |
//! | `join`     | 2      | [`dist_hash_join_chunked`] (grace hash join past the spill budget) |
//! | `sort`     | 1      | [`dist_sort_chunked`] (sample-sort; external past the spill budget) |
//! | `groupby`  | 1      | [`dist_groupby`] (two-phase) |
//! | `filter`   | 1      | [`Expr`] predicate mask + zero-copy run-sliced [`filter_view`] (rank-local) |
//! | `project`  | 1      | zero-copy [`Table::project`] (rank-local) |
//! | `derive`   | 1      | vectorized [`eval_expr`], appends one computed column (rank-local) |
//!
//! `filter` and `project` are the proof of extensibility: purely local
//! (embarrassingly parallel, no collective) and **zero-copy** — their
//! outputs are windows over their inputs, so piping them between pipeline
//! stages materializes nothing. `filter` takes a typed boolean
//! [`Expr`] (`col("val").ge(lit(0.5))`), `derive` materializes a
//! computed column, and the key arguments of `sort`/`groupby`/`join` are
//! [`ColRef`]s — names or legacy positional indices — resolved against
//! the actual input schema at execute time.
//!
//! Name-based construction (CLI, INI experiment configs) goes through the
//! process-wide [`registry`]; [`OperatorRegistry::register`] adds new
//! operators at runtime:
//!
//! ```
//! use radical_cylon::ops::operator::{registry, Operator, OpHandle};
//! use radical_cylon::comm::Communicator;
//! use radical_cylon::df::{ChunkedTable, Table};
//! use radical_cylon::error::Result;
//! use radical_cylon::ops::dist::KernelBackend;
//! use radical_cylon::pilot::TaskDescription;
//! use std::sync::Arc;
//!
//! #[derive(Debug)]
//! struct Head(usize);
//! impl Operator for Head {
//!     fn name(&self) -> &str { "head" }
//!     fn num_inputs(&self) -> usize { 1 }
//!     fn execute(
//!         &self,
//!         _comm: &Communicator,
//!         _td: &TaskDescription,
//!         inputs: Vec<Table>,
//!         _backend: &KernelBackend,
//!     ) -> Result<ChunkedTable> {
//!         let t = &inputs[0];
//!         Ok(ChunkedTable::from(t.slice(0, self.0.min(t.num_rows()))))
//!     }
//! }
//! registry().register("head", || Arc::new(Head(10)));
//! let op: OpHandle = registry().resolve("head").unwrap();
//! assert_eq!(op.name(), "head");
//! ```

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};

use crate::comm::Communicator;
use crate::df::{gen_table, read_csv, ChunkedTable, ColRef, GenSpec, Schema, Table};
use crate::error::{Error, Result};
use crate::ops::dist::{
    dist_groupby, dist_hash_join_chunked, dist_sort_chunked, KernelBackend,
};
use crate::ops::local::{
    eval_expr, eval_mask, filter_view, with_column, AggFn, CmpOp, JoinType,
};
use crate::pilot::TaskDescription;
use crate::plan::expr::{col, idx, lit, Expr};

/// Shared handle to an operator instance (parameters included). Cloning a
/// [`TaskDescription`] clones the handle, not the operator.
pub type OpHandle = Arc<dyn Operator>;

/// One distributed dataframe operator — the unit a pipeline composes.
///
/// Implementations carry their own parameters (key columns, predicates,
/// ...) and must be cheap to share across rank threads (`Send + Sync`).
/// Everything around the kernel — staged-input windowing, synthetic
/// fallback, output gather, stats aggregation — is common scaffolding in
/// [`crate::raptor::run_cylon_task_full`]; an operator only supplies the
/// per-rank kernel.
pub trait Operator: std::fmt::Debug + Send + Sync {
    /// Registry/report name (`"join"`, `"filter"`, ...).
    fn name(&self) -> &str;

    /// How many input tables the kernel consumes. Sources return 0; a
    /// piped task must stage exactly this many upstream outputs (or opt
    /// into synthetic fill, see
    /// [`TaskDescription::allow_synthetic_fill`]).
    fn num_inputs(&self) -> usize;

    /// Ranks to plan for this operator given the builder's hint — the
    /// hook a plan lowering uses so an operator can veto degenerate
    /// layouts (e.g. an accelerator op capping its group size). The
    /// default accepts the hint, floored at one rank.
    fn plan_ranks(&self, hint: usize) -> usize {
        hint.max(1)
    }

    /// Run the kernel on this rank of the private communicator `comm`.
    ///
    /// `inputs` holds this rank's window of each input table, already
    /// resolved by the executor (staged handoff window or synthetic
    /// partition), with exactly [`Operator::num_inputs`] entries. The
    /// result is this rank's output partition, as a [`ChunkedTable`] so
    /// zero-copy operators can return windows instead of materializing.
    /// Collective kernels must keep all ranks in lockstep (every rank
    /// calls, symmetric errors).
    fn execute(
        &self,
        comm: &Communicator,
        td: &TaskDescription,
        inputs: Vec<Table>,
        backend: &KernelBackend,
    ) -> Result<ChunkedTable>;
}

/// Distributed hash join of two staged (or generated) inputs. Keys are
/// [`ColRef`]s resolved against each side's schema at execute time.
#[derive(Clone, Debug)]
pub struct JoinOp {
    pub left_key: ColRef,
    pub right_key: ColRef,
    pub how: JoinType,
}

impl Default for JoinOp {
    fn default() -> JoinOp {
        JoinOp {
            left_key: ColRef::Index(0),
            right_key: ColRef::Index(0),
            how: JoinType::Inner,
        }
    }
}

impl Operator for JoinOp {
    fn name(&self) -> &str {
        "join"
    }

    fn num_inputs(&self) -> usize {
        2
    }

    fn execute(
        &self,
        comm: &Communicator,
        _td: &TaskDescription,
        inputs: Vec<Table>,
        backend: &KernelBackend,
    ) -> Result<ChunkedTable> {
        let [l, r]: [Table; 2] = inputs.try_into().expect("arity checked");
        // Every rank sees the same schemas, so a resolution failure is
        // symmetric across the collective.
        let lk = self.left_key.resolve(l.schema())?;
        let rk = self.right_key.resolve(r.schema())?;
        // Budget-aware: consults the global spill governor; unbounded
        // budgets take the classic in-memory dist_hash_join path.
        dist_hash_join_chunked(
            comm,
            &ChunkedTable::from(l),
            &ChunkedTable::from(r),
            lk,
            rk,
            self.how,
            backend,
        )
    }
}

/// Distributed sample-sort by one int64 column (default: column 0). The
/// key is a [`ColRef`] resolved against the input schema at execute time.
#[derive(Clone, Debug, Default)]
pub struct SortOp {
    pub key: ColRef,
}

impl Operator for SortOp {
    fn name(&self) -> &str {
        "sort"
    }

    fn num_inputs(&self) -> usize {
        1
    }

    fn execute(
        &self,
        comm: &Communicator,
        _td: &TaskDescription,
        inputs: Vec<Table>,
        backend: &KernelBackend,
    ) -> Result<ChunkedTable> {
        let key = self.key.resolve(inputs[0].schema())?;
        // Budget-aware: consults the global spill governor; unbounded
        // budgets take the classic in-memory dist_sort path.
        let input = ChunkedTable::from(inputs.into_iter().next().expect("arity"));
        dist_sort_chunked(comm, &input, key, backend)
    }
}

/// Distributed two-phase groupby-aggregate. Key/value columns are
/// [`ColRef`]s resolved against the input schema at execute time.
#[derive(Clone, Debug)]
pub struct GroupbyOp {
    pub key: ColRef,
    pub val: ColRef,
    pub agg: AggFn,
}

impl Default for GroupbyOp {
    fn default() -> GroupbyOp {
        GroupbyOp {
            key: ColRef::Index(0),
            val: ColRef::Index(1),
            agg: AggFn::Sum,
        }
    }
}

impl Operator for GroupbyOp {
    fn name(&self) -> &str {
        "groupby"
    }

    fn num_inputs(&self) -> usize {
        1
    }

    fn execute(
        &self,
        comm: &Communicator,
        _td: &TaskDescription,
        inputs: Vec<Table>,
        backend: &KernelBackend,
    ) -> Result<ChunkedTable> {
        let key = self.key.resolve(inputs[0].schema())?;
        let val = self.val.resolve(inputs[0].schema())?;
        dist_groupby(comm, &inputs[0], key, val, self.agg, backend)
            .map(ChunkedTable::from)
    }
}

/// Zero-copy expression filter: keep rows where the boolean
/// [`Expr`] holds. Purely rank-local (no collective): the predicate is
/// evaluated vectorized into a flat mask
/// ([`eval_mask`]) and the kept rows are run-sliced — the output is
/// a [`ChunkedTable`] of windows over the input, so beyond the mask the
/// filter materializes zero bytes.
#[derive(Clone, Debug)]
pub struct FilterOp {
    pub predicate: Expr,
}

impl FilterOp {
    /// Shim for the legacy `(column index, comparison, f64 scalar)`
    /// filter: builds the equivalent [`Expr`]
    /// (`idx(col) <cmp> lit(scalar)`). Semantics match the old kernel on
    /// every NaN-free input; on NaN cells the expression path follows
    /// IEEE (`NaN < x` etc. are `false`) while the legacy
    /// [`crate::ops::local::compare_scalar`] treated NaN as greater than
    /// any scalar.
    pub fn scalar(column: usize, cmp: CmpOp, scalar: f64) -> FilterOp {
        FilterOp { predicate: Expr::cmp_op(cmp, idx(column), lit(scalar)) }
    }
}

impl Default for FilterOp {
    fn default() -> FilterOp {
        // `val >= 0.5` on the synthetic-workload schema.
        FilterOp { predicate: col("val").ge(lit(0.5)) }
    }
}

impl Operator for FilterOp {
    fn name(&self) -> &str {
        "filter"
    }

    fn num_inputs(&self) -> usize {
        1
    }

    fn execute(
        &self,
        _comm: &Communicator,
        _td: &TaskDescription,
        inputs: Vec<Table>,
        _backend: &KernelBackend,
    ) -> Result<ChunkedTable> {
        let t = &inputs[0];
        let mask = eval_mask(t, &self.predicate)?;
        filter_view(t, mask.as_bool()?)
    }
}

/// Materialize one computed column: evaluates `expr` vectorized
/// ([`eval_expr`]) and appends the result under `name`. Rank-local; the
/// existing columns stay `Arc`-shared — only the derived buffer is fresh.
#[derive(Clone, Debug)]
pub struct DeriveOp {
    pub name: String,
    pub expr: Expr,
}

impl Default for DeriveOp {
    fn default() -> DeriveOp {
        // `val * 2` on the synthetic-workload schema.
        DeriveOp { name: "derived".into(), expr: col("val") * lit(2.0) }
    }
}

impl Operator for DeriveOp {
    fn name(&self) -> &str {
        "derive"
    }

    fn num_inputs(&self) -> usize {
        1
    }

    fn execute(
        &self,
        _comm: &Communicator,
        _td: &TaskDescription,
        inputs: Vec<Table>,
        _backend: &KernelBackend,
    ) -> Result<ChunkedTable> {
        let t = &inputs[0];
        let derived = eval_expr(t, &self.expr)?;
        with_column(t, &self.name, derived).map(ChunkedTable::from)
    }
}

/// Zero-copy column projection by name. Rank-local; the output columns are
/// `Arc` clones of the input's, materializing zero bytes.
#[derive(Clone, Debug)]
pub struct ProjectOp {
    pub columns: Vec<String>,
}

impl Default for ProjectOp {
    fn default() -> ProjectOp {
        // Matches the synthetic-workload schema (`key`, `val`).
        ProjectOp { columns: vec!["key".into(), "val".into()] }
    }
}

impl Operator for ProjectOp {
    fn name(&self) -> &str {
        "project"
    }

    fn num_inputs(&self) -> usize {
        1
    }

    fn execute(
        &self,
        _comm: &Communicator,
        _td: &TaskDescription,
        inputs: Vec<Table>,
        _backend: &KernelBackend,
    ) -> Result<ChunkedTable> {
        let names: Vec<&str> = self.columns.iter().map(String::as_str).collect();
        inputs[0].project(&names).map(ChunkedTable::from)
    }
}

/// Source: this rank's deterministic synthetic partition, from the task's
/// workload spec (`rows_per_rank`, `key_space`, `dist`, `seed`).
#[derive(Clone, Debug, Default)]
pub struct GenerateOp;

impl Operator for GenerateOp {
    fn name(&self) -> &str {
        "generate"
    }

    fn num_inputs(&self) -> usize {
        0
    }

    fn execute(
        &self,
        comm: &Communicator,
        td: &TaskDescription,
        _inputs: Vec<Table>,
        _backend: &KernelBackend,
    ) -> Result<ChunkedTable> {
        let spec = GenSpec {
            rows: td.rows_per_rank,
            key_space: td.key_space,
            dist: td.dist,
            seed: td.seed,
        };
        Ok(ChunkedTable::from(gen_table(&spec, comm.rank())))
    }
}

/// Source: parallel CSV scan. Every rank parses the file and keeps its own
/// contiguous row window — a zero-copy slice of the rank-local parse, the
/// thread-per-rank analogue of a parallel file scan.
///
/// Cost note: each rank pays a full parse before slicing (O(ranks × file)
/// work, transiently O(ranks × table) memory in this shared-process
/// simulator). Fine for the example-scale files this crate reads; a
/// production scan would byte-range-partition the file per rank instead.
#[derive(Clone, Debug)]
pub struct ScanCsvOp {
    pub path: PathBuf,
    pub schema: Schema,
}

impl Operator for ScanCsvOp {
    fn name(&self) -> &str {
        "scan-csv"
    }

    fn num_inputs(&self) -> usize {
        0
    }

    fn execute(
        &self,
        comm: &Communicator,
        _td: &TaskDescription,
        _inputs: Vec<Table>,
        _backend: &KernelBackend,
    ) -> Result<ChunkedTable> {
        let t = read_csv(&self.path, self.schema.clone())?;
        let (rank, size) = (comm.rank(), comm.size());
        let n = t.num_rows();
        let start = rank * n / size;
        let end = (rank + 1) * n / size;
        Ok(ChunkedTable::from(t.slice(start, end - start)))
    }
}

/// Zero-copy union of two inputs: both per-rank windows are adopted as
/// chunks of one logical table (row order: left then right). Rank-local.
#[derive(Clone, Debug, Default)]
pub struct UnionOp;

impl Operator for UnionOp {
    fn name(&self) -> &str {
        "union"
    }

    fn num_inputs(&self) -> usize {
        2
    }

    fn execute(
        &self,
        _comm: &Communicator,
        _td: &TaskDescription,
        inputs: Vec<Table>,
        _backend: &KernelBackend,
    ) -> Result<ChunkedTable> {
        ChunkedTable::from_tables(inputs)
    }
}

/// Convenience handles for the built-in operators (default parameters).
pub fn join_op() -> OpHandle {
    Arc::new(JoinOp::default())
}

/// Default [`SortOp`] handle (sort by column 0).
pub fn sort_op() -> OpHandle {
    Arc::new(SortOp::default())
}

/// Default [`GroupbyOp`] handle (sum of column 1 grouped by column 0).
pub fn groupby_op() -> OpHandle {
    Arc::new(GroupbyOp::default())
}

/// Default [`FilterOp`] handle (`val >= 0.5` on the synthetic schema).
pub fn filter_op() -> OpHandle {
    Arc::new(FilterOp::default())
}

/// Default [`DeriveOp`] handle (`derived = val * 2` on the synthetic
/// schema).
pub fn derive_op() -> OpHandle {
    Arc::new(DeriveOp::default())
}

/// Default [`ProjectOp`] handle (identity projection of `key`, `val`).
pub fn project_op() -> OpHandle {
    Arc::new(ProjectOp::default())
}

/// [`GenerateOp`] handle.
pub fn generate_op() -> OpHandle {
    Arc::new(GenerateOp)
}

/// [`UnionOp`] handle.
pub fn union_op() -> OpHandle {
    Arc::new(UnionOp)
}

type OpFactory = Arc<dyn Fn() -> OpHandle + Send + Sync>;

/// Name → operator-factory table. One process-wide instance lives behind
/// [`registry`]; the factories produce default-parameter instances (the
/// CLI/INI path), while programmatic users hand parameterized handles to
/// [`TaskDescription::new`] directly.
#[derive(Default)]
pub struct OperatorRegistry {
    factories: Mutex<HashMap<String, OpFactory>>,
}

impl OperatorRegistry {
    /// Register (or replace) the factory behind `name`.
    pub fn register<F>(&self, name: &str, factory: F)
    where
        F: Fn() -> OpHandle + Send + Sync + 'static,
    {
        self.factories
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::new(factory));
    }

    /// Instantiate the operator registered under `name`.
    /// Unknown names are a configuration error, never a panic.
    pub fn resolve(&self, name: &str) -> Result<OpHandle> {
        // Clone the factory out and drop the lock before invoking it, so a
        // factory may itself consult the registry (composite operators)
        // without deadlocking on the non-reentrant mutex.
        let factory = {
            let factories = self.factories.lock().unwrap();
            match factories.get(name) {
                Some(f) => f.clone(),
                None => {
                    let mut known: Vec<&str> =
                        factories.keys().map(String::as_str).collect();
                    known.sort_unstable();
                    return Err(Error::Config(format!(
                        "unknown operator '{name}' (registered: {})",
                        known.join(", ")
                    )));
                }
            }
        };
        Ok(factory())
    }

    /// Registered operator names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.factories.lock().unwrap().keys().cloned().collect();
        names.sort_unstable();
        names
    }
}

/// The process-wide operator registry, pre-seeded with the built-ins
/// (`scan-csv` is excluded: it has no meaningful default parameters and is
/// constructed through the plan builder instead).
pub fn registry() -> &'static OperatorRegistry {
    static REGISTRY: OnceLock<OperatorRegistry> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let r = OperatorRegistry::default();
        r.register("join", join_op);
        r.register("sort", sort_op);
        r.register("groupby", groupby_op);
        r.register("filter", filter_op);
        r.register("derive", derive_op);
        r.register("project", project_op);
        r.register("generate", generate_op);
        r.register("union", union_op);
        r
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, NetModel};
    use crate::df::{Column, DataType};
    use crate::metrics::mem;
    use crate::ops::local::compare_scalar;
    use crate::pilot::DataDist;

    fn kv_table(keys: Vec<i64>, vals: Vec<f64>) -> Table {
        Table::new(
            Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)]),
            vec![Column::from_i64(keys), Column::from_f64(vals)],
        )
        .unwrap()
    }

    /// Run a rank-local (collective-free) operator on **this** thread over
    /// a 1-rank world, so `mem::thread()` deltas observe its allocations.
    fn run_local(op: &dyn Operator, inputs: Vec<Table>) -> ChunkedTable {
        let w = CommWorld::new(1, NetModel::disabled());
        let c = w.communicator(0);
        let td = TaskDescription::sort("t", 1, 0, DataDist::Uniform);
        op.execute(&c, &td, inputs, &KernelBackend::Native).unwrap()
    }

    #[test]
    fn registry_resolves_builtins_and_rejects_unknown() {
        for name in [
            "join", "sort", "groupby", "filter", "derive", "project",
            "generate", "union",
        ] {
            let op = registry().resolve(name).unwrap();
            assert_eq!(op.name(), name);
        }
        let err = registry().resolve("frobnicate").unwrap_err().to_string();
        assert!(err.contains("unknown operator 'frobnicate'"), "{err}");
        assert!(err.contains("join"), "lists known names: {err}");
    }

    #[test]
    fn registry_accepts_user_operators() {
        #[derive(Debug)]
        struct Noop;
        impl Operator for Noop {
            fn name(&self) -> &str {
                "noop"
            }
            fn num_inputs(&self) -> usize {
                1
            }
            fn execute(
                &self,
                _comm: &Communicator,
                _td: &TaskDescription,
                inputs: Vec<Table>,
                _backend: &KernelBackend,
            ) -> Result<ChunkedTable> {
                Ok(ChunkedTable::from(inputs.into_iter().next().unwrap()))
            }
        }
        let local = OperatorRegistry::default();
        local.register("noop", || Arc::new(Noop));
        assert_eq!(local.resolve("noop").unwrap().num_inputs(), 1);
        assert_eq!(local.names(), vec!["noop"]);
    }

    #[test]
    fn filter_on_sliced_view_materializes_only_the_mask() {
        let base = kv_table((0..100).collect(), (0..100).map(|i| i as f64 / 100.0).collect());
        // A sliced view (rows 20..80) — the handoff shape a piped rank sees.
        let window = base.slice(20, 60);
        let op = FilterOp { predicate: col("val").ge(lit(0.5)) };
        let before = mem::thread();
        let mask = eval_mask(&window, &op.predicate).unwrap();
        let out = filter_view(&window, mask.as_bool().unwrap()).unwrap();
        let delta = mem::thread().since(before);
        assert!(
            delta.materialized <= window.num_rows() as u64,
            "expression filter may materialize only the bool mask, got {}",
            delta.materialized
        );
        assert_eq!(out.num_rows(), 30); // vals 0.50..0.79
        assert!(out.chunks()[0].column(0).shares_buffer(base.column(0)));
    }

    #[test]
    fn filter_op_distributed_matches_local_oracle() {
        let op = FilterOp { predicate: col("val").lt(lit(0.25)) };
        let t = kv_table((0..40).collect(), (0..40).map(|i| (i % 4) as f64 / 4.0).collect());
        let oracle = t
            .filter(&compare_scalar(t.column(1), 0.25, CmpOp::Lt).unwrap())
            .unwrap();
        let out = run_local(&op, vec![t]);
        assert_eq!(out.num_rows(), oracle.num_rows());
        assert_eq!(out.multiset_fingerprint(), oracle.multiset_fingerprint());
    }

    #[test]
    fn filter_scalar_shim_matches_legacy_semantics() {
        let op = FilterOp::scalar(1, CmpOp::Lt, 0.25);
        assert_eq!(op.predicate.to_string(), "(#1 < 0.25)");
        let t = kv_table((0..40).collect(), (0..40).map(|i| (i % 4) as f64 / 4.0).collect());
        let oracle = t
            .filter(&compare_scalar(t.column(1), 0.25, CmpOp::Lt).unwrap())
            .unwrap();
        let out = run_local(&op, vec![t]);
        assert_eq!(out.multiset_fingerprint(), oracle.multiset_fingerprint());
        // Every comparison maps through.
        for cmp in [CmpOp::Eq, CmpOp::Ne, CmpOp::Lt, CmpOp::Le, CmpOp::Gt, CmpOp::Ge] {
            let _ = FilterOp::scalar(0, cmp, 1.0);
        }
    }

    #[test]
    fn derive_op_appends_computed_column_and_shares_the_rest() {
        let t = kv_table(vec![1, 2, 3], vec![0.25, 0.5, 0.75]);
        let op = DeriveOp {
            name: "scaled".into(),
            expr: col("val") * lit(4.0) + col("key"),
        };
        let out = run_local(&op, vec![t.clone()]).into_table();
        assert_eq!(out.num_columns(), 3);
        assert_eq!(out.schema().field(2).name, "scaled");
        assert_eq!(out.column(2).as_f64().unwrap(), &[2.0, 4.0, 6.0]);
        // The pre-existing columns are Arc clones, not copies.
        assert!(out.column(0).shares_buffer(t.column(0)));
        assert!(out.column(1).shares_buffer(t.column(1)));
        // Unknown columns surface the did-you-mean diagnostic.
        let bad = DeriveOp { name: "x".into(), expr: col("vall") * lit(2.0) };
        let w = CommWorld::new(1, NetModel::disabled());
        let c = w.communicator(0);
        let td = TaskDescription::sort("t", 1, 0, DataDist::Uniform);
        let err = bad
            .execute(&c, &td, vec![t], &KernelBackend::Native)
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean 'val'?"), "{err}");
    }

    #[test]
    fn sort_and_groupby_accept_names() {
        let t = kv_table(vec![3, 1, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let sort = SortOp { key: "key".into() };
        let out = run_local(&sort, vec![t.clone()]).into_table();
        assert_eq!(out.column(0).as_i64().unwrap(), &[1, 1, 2, 3]);
        let gb = GroupbyOp { key: "key".into(), val: "val".into(), agg: AggFn::Sum };
        let out = run_local(&gb, vec![t.clone()]).into_table();
        assert_eq!(out.column(0).as_i64().unwrap(), &[1, 2, 3]);
        assert_eq!(out.column(1).as_f64().unwrap(), &[6.0, 3.0, 1.0]);
        // Unknown key names error with diagnostics instead of panicking.
        let bad = SortOp { key: "kye".into() };
        let w = CommWorld::new(1, NetModel::disabled());
        let c = w.communicator(0);
        let td = TaskDescription::sort("t", 1, 0, DataDist::Uniform);
        let err = bad
            .execute(&c, &td, vec![t], &KernelBackend::Native)
            .unwrap_err()
            .to_string();
        assert!(err.contains("no column named 'kye'"), "{err}");
    }

    #[test]
    fn project_on_chunked_window_materializes_zero_bytes() {
        let base = kv_table((0..50).collect(), vec![0.0; 50]);
        let staged = ChunkedTable::from_tables(vec![base.slice(0, 30), base.slice(30, 20)])
            .unwrap();
        // A consumer rank's window carved from a chunked (gathered-shape)
        // table; it lands inside chunk 0, so into_table() is the zero-copy
        // single-chunk fast path.
        let window = staged.slice(5, 20).into_table();
        let op = ProjectOp { columns: vec!["key".into()] };
        let before = mem::thread();
        let out = run_local(&op, vec![window]);
        assert_eq!(
            mem::thread().since(before).materialized,
            0,
            "projection must be Arc clones only"
        );
        assert_eq!(out.num_rows(), 20);
        assert_eq!(out.schema().len(), 1);
        assert!(out.chunks()[0].column(0).shares_buffer(base.column(0)));
    }

    #[test]
    fn union_adopts_both_inputs_zero_copy() {
        let l = kv_table(vec![1, 2], vec![0.0; 2]);
        let r = kv_table(vec![3], vec![0.0; 1]);
        let before = mem::thread();
        let out = run_local(&UnionOp, vec![l.clone(), r.clone()]);
        assert_eq!(mem::thread().since(before).materialized, 0);
        assert_eq!(out.num_chunks(), 2);
        assert_eq!(out.compact().column(0).as_i64().unwrap(), &[1, 2, 3]);
    }

    #[test]
    fn scan_csv_partitions_across_ranks() {
        let dir = std::env::temp_dir().join("rc-scan-csv-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("scan.csv");
        let t = kv_table((0..9).collect(), (0..9).map(|i| i as f64).collect());
        crate::df::write_csv(&t, &path).unwrap();
        let schema = t.schema().clone();
        let op = ScanCsvOp { path: path.clone(), schema };
        let w = CommWorld::new(3, NetModel::disabled());
        let td = TaskDescription::sort("scan", 3, 0, DataDist::Uniform);
        let out = w
            .run(move |c| op.execute(&c, &td, vec![], &KernelBackend::Native))
            .unwrap();
        let rows: usize = out.iter().map(|r| r.as_ref().unwrap().num_rows()).sum();
        assert_eq!(rows, 9);
        assert_eq!(
            out[1].as_ref().unwrap().compact().column(0).as_i64().unwrap(),
            &[3, 4, 5]
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn plan_ranks_defaults_to_hint() {
        assert_eq!(SortOp::default().plan_ranks(4), 4);
        assert_eq!(SortOp::default().plan_ranks(0), 1);
    }
}
