//! Distributed operators (paper §3.2): compose the local operators with
//! communicator collectives. The workhorse is the hash **shuffle**
//! ([`shuffle_by_key`]): route every row to the rank that owns its key, so
//! join/groupby become embarrassingly local afterwards. [`dist_sort`] is a
//! sample-sort (local sort → splitter selection → range exchange → k-way
//! merge).
//!
//! **Zero-copy data plane:** exchanges move `Arc`-backed column views, and
//! receives land in a [`ChunkedTable`] ([`shuffle_by_key_chunked`],
//! [`gather_table_chunked`]) instead of being flattened eagerly — the copy
//! is deferred to `compact()`, which runs only when an operator needs
//! contiguous access. `dist_sort`'s range exchange sends O(1) slice views
//! (its partitions are contiguous after the local sort), and
//! [`partition_slice`] carves per-rank input chunks without touching a row.
//!
//! Every operator takes a [`KernelBackend`] selecting the data-plane
//! implementation for its hot spots:
//!
//! * [`KernelBackend::Native`] — pure-Rust kernels
//!   ([`crate::util::hash::partition_ids`], [`sort_table`]).
//! * [`KernelBackend::Pjrt`] — the AOT-compiled Pallas artifacts served by a
//!   [`KernelService`] pool (bit-compatible with the native path; asserted
//!   by `tests/integration_runtime.rs`).

use crate::comm::Communicator;
use crate::df::{Chunk, ChunkedTable, DataType, Schema, Table};
use crate::error::Result;
use crate::ops::local::{
    groupby_agg, hash_join, hash_join_budgeted, merge_block_streams,
    merge_sorted, morsel_ranges, sort_table, sort_table_budgeted, AggFn,
    BlockStream, FillPolicy, JoinType, MergeSpec, SortKey,
};
use crate::runtime::{KernelService, SORT_BLOCK};
use crate::spill::{self, MemoryBudget};
use crate::util::hash::{partition_ids, partition_ids_par};
use crate::util::pool::{self, SharedSlice, ThreadPool};

/// Data-plane kernel selection for the distributed operators.
#[derive(Clone)]
pub enum KernelBackend {
    /// Pure-Rust kernels (always available).
    Native,
    /// AOT Pallas/HLO artifacts executed through a PJRT server pool.
    Pjrt(KernelService),
}

impl KernelBackend {
    pub fn name(&self) -> &'static str {
        match self {
            KernelBackend::Native => "native",
            KernelBackend::Pjrt(_) => "pjrt",
        }
    }
}

impl std::fmt::Debug for KernelBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Partition ids for `keys` over `nparts` buckets via the selected
/// backend. The native path hashes morsels on the global pool above the
/// morsel threshold (bit-identical — the hash is per-row pure).
fn partition_plan(
    keys: &[i64],
    nparts: u32,
    backend: &KernelBackend,
) -> Result<Vec<i32>> {
    match backend {
        KernelBackend::Native => {
            if keys.len() >= pool::par_min_rows() && pool::parallelism() > 1 {
                Ok(partition_ids_par(keys, nparts, pool::global()))
            } else {
                Ok(partition_ids(keys, nparts))
            }
        }
        KernelBackend::Pjrt(svc) => svc.shuffle_plan(keys.to_vec(), nparts),
    }
}

/// Local sort by an int64 column via the selected backend. The PJRT path
/// sorts [`SORT_BLOCK`]-sized chunks on the `block_sort` artifact and k-way
/// merges them (the merge tree of a block-sorting accelerator kernel).
fn local_sort(t: &Table, col: usize, backend: &KernelBackend) -> Result<Table> {
    match backend {
        KernelBackend::Native => sort_table(t, SortKey::asc(col)),
        KernelBackend::Pjrt(svc) => {
            let keys = t.column(col).as_i64()?;
            if keys.len() <= 1 {
                return Ok(t.clone());
            }
            let mut chunks = Vec::with_capacity(keys.len().div_ceil(SORT_BLOCK));
            let mut start = 0usize;
            while start < keys.len() {
                let len = (keys.len() - start).min(SORT_BLOCK);
                let payload: Vec<i32> = (0..len as i32).collect();
                let (_, perm) =
                    svc.block_sort(keys[start..start + len].to_vec(), payload)?;
                let idx: Vec<usize> =
                    perm.into_iter().map(|p| start + p as usize).collect();
                chunks.push(t.take(&idx));
                start += len;
            }
            merge_sorted(&chunks, col)
        }
    }
}

/// Count → exclusive-prefix-sum → scatter over destination ids: one flat
/// `u32` row-id array grouped by destination, plus the per-destination
/// offsets (`nparts + 1` entries). Destination `d` owns
/// `rows[offsets[d]..offsets[d + 1]]` in **ascending row order** (the
/// scatter is stable), so per-destination gathers slice the flat array
/// without reallocation and see the rows in the same order the legacy
/// push-grown lists produced. Two allocations regardless of `nparts`
/// (counting-scatter perf pass, EXPERIMENTS.md §Perf).
///
/// `ids[row]` must lie in `[0, nparts)` and `ids.len()` must fit a `u32`.
///
/// NOTE: [`crate::util::hash::CsrIndex::build`] implements the same count
/// → prefix-sum → scatter → offsets-shift scheme over hashed keys (with
/// `u32` offsets); a fix to the cursor-undo shift in either must be
/// mirrored in the other.
pub fn counting_scatter(ids: &[i32], nparts: usize) -> (Vec<u32>, Vec<usize>) {
    if ids.len() >= pool::par_min_rows() && pool::parallelism() > 1 {
        return counting_scatter_par(ids, nparts, pool::global());
    }
    counting_scatter_seq(ids, nparts)
}

fn counting_scatter_seq(ids: &[i32], nparts: usize) -> (Vec<u32>, Vec<usize>) {
    assert!(
        ids.len() < u32::MAX as usize,
        "counting_scatter row ids are u32 ({} rows given)",
        ids.len()
    );
    let mut offsets = vec![0usize; nparts + 1];
    for &d in ids {
        offsets[d as usize + 1] += 1;
    }
    for d in 0..nparts {
        offsets[d + 1] += offsets[d];
    }
    // Scatter forward using offsets[d] itself as destination d's write
    // cursor, then undo the advance by shifting one slot right — no third
    // (cursor) allocation.
    let mut rows = vec![0u32; ids.len()];
    for (row, &d) in ids.iter().enumerate() {
        let d = d as usize;
        rows[offsets[d]] = row as u32;
        offsets[d] += 1;
    }
    for d in (1..=nparts).rev() {
        offsets[d] = offsets[d - 1];
    }
    offsets[0] = 0;
    (rows, offsets)
}

/// Morsel-parallel twin of [`counting_scatter`], mirroring
/// [`crate::util::hash::CsrIndex::build_par`]: per-morsel destination
/// histograms in parallel, one serial (destination, morsel) prefix sum
/// assigning every morsel a private absolute write range per destination,
/// then a parallel scatter through a [`SharedSlice`].
///
/// **Determinism:** write ranges are morsel-major within each
/// destination and morsels are contiguous ascending row ranges, so every
/// destination receives its rows in ascending row order — exactly what
/// the sequential stable forward scatter produces, for any morsel split.
pub fn counting_scatter_par(
    ids: &[i32],
    nparts: usize,
    pool: &ThreadPool,
) -> (Vec<u32>, Vec<usize>) {
    let nt = pool.size().min(ids.len() / pool::par_min_rows()).max(1);
    if nt <= 1 {
        return counting_scatter_seq(ids, nparts);
    }
    assert!(
        ids.len() < u32::MAX as usize,
        "counting_scatter row ids are u32 ({} rows given)",
        ids.len()
    );
    let morsels = morsel_ranges(ids.len(), nt);
    // Pass 1 (parallel): per-morsel destination histograms.
    let mut counts: Vec<Vec<usize>> = pool.run_indexed(nt, |t| {
        let (lo, hi) = morsels[t];
        let mut c = vec![0usize; nparts];
        for &d in &ids[lo..hi] {
            c[d as usize] += 1;
        }
        c
    });
    // Pass 2 (serial): prefix sum over (destination, morsel) — absolute
    // disjoint write cursors, morsel-major within each destination.
    let mut offsets = vec![0usize; nparts + 1];
    let mut running = 0usize;
    for d in 0..nparts {
        offsets[d] = running;
        for c in counts.iter_mut() {
            let start = running;
            running += c[d];
            c[d] = start; // becomes morsel-local cursor for destination d
        }
    }
    offsets[nparts] = running;
    // Pass 3 (parallel): scatter row ids through the private cursors.
    let mut rows = vec![0u32; ids.len()];
    {
        let shared = SharedSlice::new(&mut rows);
        let cursors: Vec<std::sync::Mutex<Vec<usize>>> =
            counts.into_iter().map(std::sync::Mutex::new).collect();
        pool.run_indexed(nt, |t| {
            let (lo, hi) = morsels[t];
            let mut cur = cursors[t].lock().unwrap();
            for (i, &d) in ids[lo..hi].iter().enumerate() {
                let d = d as usize;
                // SAFETY: cur[d] ranges over this morsel's private slot
                // range for destination d (disjoint by the prefix sum);
                // reads happen only after run_indexed joins.
                unsafe { shared.write(cur[d], (lo + i) as u32) };
                cur[d] += 1;
            }
        });
    }
    (rows, offsets)
}

/// Pre-scatter destination routing: one push-grown `Vec<usize>` per
/// destination. Kept as the `kernel_hotpaths` bench baseline and oracle
/// for [`counting_scatter`] (identical per-destination row lists).
pub fn destination_lists(ids: &[i32], nparts: usize) -> Vec<Vec<usize>> {
    let mut dest: Vec<Vec<usize>> = vec![Vec::new(); nparts];
    for (row, &d) in ids.iter().enumerate() {
        dest[d as usize].push(row);
    }
    dest
}

/// Hash-shuffle `t` by its int64 `key` column, returning the received
/// partitions as a zero-copy [`ChunkedTable`] (one chunk per sender; the
/// concat is deferred until a consumer compacts). Every row travels to rank
/// `splitmix64(key) % p`, so all rows sharing a key land on one rank.
/// Row routing is a flat [`counting_scatter`] plan; each destination's
/// gather slices it without reallocation.
///
/// **Parallelism:** above the morsel threshold the routing plan and the
/// counting scatter run morsel-parallel on the global pool, and the
/// per-destination gathers become pool morsels (one slice carve per
/// destination). Below it, the gathers overlap with the exchange instead:
/// each destination's partition is posted to the simulated wire the moment
/// it is gathered ([`Communicator::alltoall_with`]), so downstream ranks'
/// receives are already staged while later gathers still run. Both paths
/// are bit-identical to the sequential gather-then-exchange schedule.
/// Collective — every rank of `comm` must call with its own partition.
pub fn shuffle_by_key_chunked(
    comm: &Communicator,
    t: &Table,
    key: usize,
    backend: &KernelBackend,
) -> Result<ChunkedTable> {
    let p = comm.size();
    if p == 1 {
        // Identity shuffle: Arc clones only, no row moves.
        return Ok(ChunkedTable::from(t.clone()));
    }
    let keys = t.column(key).as_i64()?;
    let ids = partition_plan(keys, p as u32, backend)?;
    // The gather per destination is the one unavoidable materialization of
    // a hash shuffle (arbitrary row routing); everything after is views.
    let parts: Vec<Table> = if ids.len() < u32::MAX as usize {
        let (rows, offsets) = counting_scatter(&ids, p);
        if ids.len() >= pool::par_min_rows() && pool::parallelism() > 1 {
            // Pool morsels: each destination's gather is an independent
            // slice carve of the flat plan — disjoint reads, no sync.
            let sends = pool::global()
                .run_indexed(p, |d| t.take_u32(&rows[offsets[d]..offsets[d + 1]]));
            comm.alltoall(sends)
        } else {
            // Small input: overlap each gather with the exchange instead
            // of batching all p gathers before the first send.
            comm.alltoall_with(|d| t.take_u32(&rows[offsets[d]..offsets[d + 1]]))
        }
    } else {
        // Row ids no longer fit the flat u32 plan; degrade to the legacy
        // lists like sort/groupby fall back on oversized inputs.
        let dest = destination_lists(&ids, p);
        comm.alltoall_with(|d| t.take(&dest[d]))
    };
    ChunkedTable::from_tables(parts)
}

/// [`shuffle_by_key_chunked`] compacted to a contiguous [`Table`] — for
/// consumers that need contiguous column access immediately.
pub fn shuffle_by_key(
    comm: &Communicator,
    t: &Table,
    key: usize,
    backend: &KernelBackend,
) -> Result<Table> {
    Ok(shuffle_by_key_chunked(comm, t, key, backend)?.into_table())
}

/// Distributed sample-sort by an int64 column. Postcondition: each rank's
/// partition is sorted and rank `r`'s keys all precede rank `r+1`'s (global
/// order across the communicator); the global row multiset is preserved.
///
/// The range exchange sends **O(1) slice views** of the locally-sorted
/// table (zero row copies before the wire), and the k-way merge consumes
/// the received parts directly — no intermediate concat on either side.
///
/// **Parallelism:** the local sort runs morsel-parallel above the morsel
/// threshold (see [`sort_table`]); each range is posted to the wire the
/// moment it is carved ([`Communicator::alltoall_with`] — the carves are
/// O(1) views, but posting early lets receivers' merges see staged parts
/// sooner in the simulated schedule); and the final k-way merge splits the
/// received runs into disjoint global key ranges merged independently on
/// the pool ([`merge_sorted`] dispatching to
/// [`crate::ops::local::merge_sorted_par`]). All bit-identical to the
/// sequential schedule.
pub fn dist_sort(
    comm: &Communicator,
    t: &Table,
    col: usize,
    backend: &KernelBackend,
) -> Result<Table> {
    let sorted = local_sort(t, col, backend)?;
    let p = comm.size();
    if p == 1 {
        return Ok(sorted);
    }
    let keys = sorted.column(col).as_i64()?;

    // Regular sampling: p evenly-spaced local keys from every rank.
    let n = keys.len();
    let mut samples = Vec::with_capacity(p);
    for i in 0..p {
        if n > 0 {
            samples.push(keys[i * n / p]);
        }
    }
    let mut flat: Vec<i64> = comm.allgather(samples).into_iter().flatten().collect();
    flat.sort_unstable();
    // p-1 splitters; keys <= splitter[r] belong to ranks <= r+... (range r).
    let mut splitters = Vec::with_capacity(p.saturating_sub(1));
    if !flat.is_empty() {
        for i in 1..p {
            splitters.push(flat[(i * flat.len() / p).min(flat.len() - 1)]);
        }
    }

    // Carve the locally-sorted table into p contiguous key ranges — pure
    // window views over the sorted table's buffers — and post each range
    // the moment it is carved (compute/exchange overlap).
    let mut start = 0usize;
    let parts = comm.alltoall_with(|r| {
        let end = match splitters.get(r) {
            Some(&s) => keys.partition_point(|&k| k <= s).max(start),
            None => keys.len(), // last range (or empty global input)
        };
        let send = sorted.slice(start, end - start);
        start = end;
        send
    });
    merge_sorted(&parts, col)
}

/// Out-of-core [`dist_sort`] over chunked (possibly disk-backed) inputs,
/// consulting the global [`spill`] budget: local sort goes through
/// [`sort_table_budgeted`] (external sample-sort past the budget), the
/// range exchange ships **whole spilled chunks as file handles** (only
/// splitter-straddling chunks are restored to carve), and the receive-side
/// k-way merge streams one block per sender with spilled outputs — so a
/// paper-scale sort never holds more than budget + one morsel in RAM.
///
/// **Bit-identity with [`dist_sort`]'s global output:** range carves never
/// split an equal-key group across ranks (`k <= splitter` boundaries on
/// the locally-sorted runs), so the concatenation of all ranks' outputs is
/// sorted by `(key, sender rank, local position)` — the same total order
/// [`dist_sort`] produces — regardless of where the splitters land. Only
/// per-rank partition *sizes* may differ (chunk-metadata sampling vs exact
/// row sampling).
///
/// The PJRT backend's block-sort artifact has no external path, so it
/// stays on the in-memory pipeline (documented boundary).
pub fn dist_sort_chunked(
    comm: &Communicator,
    t: &ChunkedTable,
    col: usize,
    backend: &KernelBackend,
) -> Result<ChunkedTable> {
    dist_sort_chunked_with(comm, t, col, backend, spill::global())
}

/// [`dist_sort_chunked`] with an explicit budget (tests and benches).
pub fn dist_sort_chunked_with(
    comm: &Communicator,
    t: &ChunkedTable,
    col: usize,
    backend: &KernelBackend,
    budget: &MemoryBudget,
) -> Result<ChunkedTable> {
    let limit = match (budget.limit(), backend) {
        (Some(l), KernelBackend::Native) => l,
        _ => {
            return dist_sort(comm, &t.compact(), col, backend)
                .map(ChunkedTable::from)
        }
    };
    let sorted = sort_table_budgeted(t, SortKey::asc(col), budget)?;
    let p = comm.size();
    if p == 1 {
        return Ok(sorted);
    }
    let n = sorted.num_rows();

    // Regular sampling from chunk metadata: the min key of the chunk
    // containing each target row (key_range for spilled chunks, first key
    // for resident ones — no restores). Splitters only move rank seams;
    // the bit-identity argument above is splitter-independent.
    let mut samples = Vec::with_capacity(p);
    if n > 0 {
        let list = sorted.chunk_list();
        let mut starts = Vec::with_capacity(list.len());
        let mut mins = Vec::with_capacity(list.len());
        let mut acc = 0usize;
        for c in list {
            starts.push(acc);
            acc += c.num_rows();
            mins.push(chunk_key_bounds(c, col)?.0);
        }
        for i in 0..p {
            let target = i * n / p;
            let ci = starts.partition_point(|&s| s <= target).saturating_sub(1);
            samples.push(mins[ci]);
        }
    }
    let mut flat: Vec<i64> =
        comm.allgather(samples).into_iter().flatten().collect();
    flat.sort_unstable();
    let mut splitters = Vec::with_capacity(p.saturating_sub(1));
    if !flat.is_empty() {
        for i in 1..p {
            splitters.push(flat[(i * flat.len() / p).min(flat.len() - 1)]);
        }
    }

    // Row boundaries per destination (k <= splitter), resolved against
    // chunk key bounds so fully-in-range spilled chunks never restore.
    let mut bounds = Vec::with_capacity(p);
    let mut prev = 0usize;
    for r in 0..p {
        let end = match splitters.get(r) {
            Some(&s) => rows_leq(&sorted, col, s)?.max(prev),
            None => n,
        };
        bounds.push((prev, end));
        prev = end;
    }
    // Exchange chunk lists: covered spilled chunks travel as file handles.
    let parts: Vec<Vec<Chunk>> = comm.alltoall_with(|r| {
        let (lo, hi) = bounds[r];
        sorted.slice(lo, hi - lo).into_chunk_list()
    });

    let total_bytes: usize = parts.iter().flatten().map(Chunk::byte_size).sum();
    let total_rows: usize = parts.iter().flatten().map(Chunk::num_rows).sum();
    let avg_row = (total_bytes / total_rows.max(1)).max(1);
    let spec = MergeSpec {
        key_col: col,
        strip_key: false,
        out_chunk_rows: ((limit / 8) as usize / avg_row).max(1),
        spill_outputs: true,
    };
    let schema = sorted.schema().clone();
    drop(sorted);
    let streams: Vec<BlockStream> = parts
        .into_iter()
        .map(|chunks| BlockStream::Chunks(chunks.into_iter()))
        .collect();
    merge_block_streams(&schema, streams, &spec, budget)
}

/// `(min, max)` key of a chunk without restoring it when metadata
/// suffices: spilled chunks carry their sorted key range; resident chunks
/// of a sorted table read their first/last key in place.
fn chunk_key_bounds(c: &Chunk, col: usize) -> Result<(i64, i64)> {
    if let Some(r) = c.key_range() {
        return Ok(r);
    }
    let t = c.load()?;
    let keys = t.column(col).as_i64()?;
    Ok((
        keys.first().copied().unwrap_or(i64::MAX),
        keys.last().copied().unwrap_or(i64::MIN),
    ))
}

/// Global row count with key `<= s` in a sorted chunked table. Whole
/// chunks resolve from their key bounds; only the single straddling chunk
/// (if any) is loaded for an exact `partition_point`.
fn rows_leq(sorted: &ChunkedTable, col: usize, s: i64) -> Result<usize> {
    let mut acc = 0usize;
    for c in sorted.chunk_list() {
        if c.num_rows() == 0 {
            continue;
        }
        let (lo, hi) = chunk_key_bounds(c, col)?;
        if hi <= s {
            acc += c.num_rows();
            continue;
        }
        if lo > s {
            break;
        }
        let t = c.load()?;
        let keys = t.column(col).as_i64()?;
        return Ok(acc + keys.partition_point(|&k| k <= s));
    }
    Ok(acc)
}

/// Out-of-core [`dist_hash_join`] consulting the global [`spill`] budget:
/// shuffled receives are spilled back under budget
/// ([`ChunkedTable::spill_over`]) and the local join goes through the
/// grace [`hash_join_budgeted`]. The hash shuffle itself routes individual
/// rows, so each side is resident for its exchange — that compact is the
/// honest out-of-core boundary of the in-process data plane.
#[allow(clippy::too_many_arguments)]
pub fn dist_hash_join_chunked(
    comm: &Communicator,
    left: &ChunkedTable,
    right: &ChunkedTable,
    left_key: usize,
    right_key: usize,
    how: JoinType,
    backend: &KernelBackend,
) -> Result<ChunkedTable> {
    dist_hash_join_chunked_with(
        comm,
        left,
        right,
        left_key,
        right_key,
        how,
        backend,
        spill::global(),
    )
}

/// [`dist_hash_join_chunked`] with an explicit budget (tests and benches).
#[allow(clippy::too_many_arguments)]
pub fn dist_hash_join_chunked_with(
    comm: &Communicator,
    left: &ChunkedTable,
    right: &ChunkedTable,
    left_key: usize,
    right_key: usize,
    how: JoinType,
    backend: &KernelBackend,
    budget: &MemoryBudget,
) -> Result<ChunkedTable> {
    let fill = FillPolicy::zeros();
    if comm.size() == 1 {
        return hash_join_budgeted(
            left, right, left_key, right_key, how, &fill, budget,
        );
    }
    let mut ls = shuffle_by_key_chunked(comm, &left.compact(), left_key, backend)?;
    ls.spill_over(budget)?;
    let mut rs =
        shuffle_by_key_chunked(comm, &right.compact(), right_key, backend)?;
    rs.spill_over(budget)?;
    hash_join_budgeted(&ls, &rs, left_key, right_key, how, &fill, budget)
}

/// Distributed hash join: co-locate both sides by key hash, then join
/// locally. Key columns keep their positions through the shuffle, so
/// `left_key`/`right_key` refer to the original tables. The local hash
/// join needs contiguous key columns, so each shuffled side is compacted
/// exactly once, at this operator boundary (the single-rank/single-chunk
/// cases compact for free).
#[allow(clippy::too_many_arguments)]
pub fn dist_hash_join(
    comm: &Communicator,
    left: &Table,
    right: &Table,
    left_key: usize,
    right_key: usize,
    how: JoinType,
    backend: &KernelBackend,
) -> Result<Table> {
    if comm.size() == 1 {
        return hash_join(left, right, left_key, right_key, how);
    }
    let ls = shuffle_by_key(comm, left, left_key, backend)?;
    let rs = shuffle_by_key(comm, right, right_key, backend)?;
    hash_join(&ls, &rs, left_key, right_key, how)
}

/// Distributed groupby-aggregate. Decomposable aggregations (sum, count,
/// min, max) run **two-phase**: local partial aggregation shrinks the data
/// to one row per (rank, key) before the shuffle, then a combine pass
/// merges partials — the standard pre-aggregation optimization. `Mean` is
/// not decomposable by a single combine and falls back to shuffle-then-
/// aggregate.
///
/// **Parallelism:** both the partial and the final/combine stage go
/// through [`groupby_agg`], which dispatches to its morsel-parallel twin
/// above the morsel threshold — so each stage is pool-parallel with no
/// extra wiring here, and bit-identical to the sequential stages.
pub fn dist_groupby(
    comm: &Communicator,
    t: &Table,
    key_col: usize,
    val_col: usize,
    agg: AggFn,
    backend: &KernelBackend,
) -> Result<Table> {
    if comm.size() == 1 {
        return groupby_agg(t, key_col, val_col, agg);
    }
    if agg == AggFn::Mean {
        let shuffled = shuffle_by_key(comm, t, key_col, backend)?;
        return groupby_agg(&shuffled, key_col, val_col, agg);
    }
    let partial = groupby_agg(t, key_col, val_col, agg)?; // (key, partial)
    let shuffled = shuffle_by_key(comm, &partial, 0, backend)?;
    let combine = match agg {
        AggFn::Count => AggFn::Sum, // partial counts add up
        other => other,
    };
    let combined = groupby_agg(&shuffled, 0, 1, combine)?;
    // Restore the single-phase output schema (`{val}_{agg}`), hiding the
    // partial stage's suffix stacking.
    let schema = Schema::of(&[
        (t.schema().field(key_col).name.as_str(), DataType::Int64),
        (
            format!("{}_{}", t.schema().field(val_col).name, agg.name()).as_str(),
            DataType::Float64,
        ),
    ]);
    Table::new(schema, combined.columns().to_vec())
}

/// Gather every rank's partition of `t` to group rank 0 as a zero-copy
/// [`ChunkedTable`] (one chunk per rank, rank order — nothing is
/// flattened). Collective; non-roots receive `None`. This is the producer
/// side of the pipeline table handoff.
pub fn gather_table_chunked(
    comm: &Communicator,
    t: Table,
) -> Result<Option<ChunkedTable>> {
    match comm.gather(0, t) {
        Some(parts) => Ok(Some(ChunkedTable::from_tables(parts)?)),
        None => Ok(None),
    }
}

/// Gather every rank's **chunked** partition to group rank 0, adopting
/// all chunk lists in rank order — the fully zero-copy producer gather:
/// a rank whose output is already a list of windows (run-sliced filters,
/// projections, unions) ships those windows as-is; nothing is flattened
/// on either side. Disk-backed chunks travel as spill-file handles and
/// stay on disk across the hop — the root restores them lazily on first
/// access. Collective; non-roots receive `None`.
pub fn gather_chunked(
    comm: &Communicator,
    t: ChunkedTable,
) -> Result<Option<ChunkedTable>> {
    let schema = t.schema().clone();
    match comm.gather(0, t.into_chunk_list()) {
        Some(lists) => {
            let chunks: Vec<Chunk> = lists.into_iter().flatten().collect();
            // An all-empty gather keeps the schema (from_chunk_list
            // accepts an empty list).
            Ok(Some(ChunkedTable::from_chunk_list(schema, chunks)?))
        }
        None => Ok(None),
    }
}

/// Convenience: [`gather_table_chunked`] compacted to one contiguous
/// table at the root.
pub fn gather_table(comm: &Communicator, t: Table) -> Result<Option<Table>> {
    Ok(gather_table_chunked(comm, t)?.map(ChunkedTable::into_table))
}

/// Split a chunked table into `parts` near-equal contiguous row windows
/// and return window `index` — how a staged pipeline input (handed off
/// from an upstream task) is distributed across a downstream task's ranks.
/// Zero-copy: the result is a window of views over the staged chunks.
pub fn partition_slice(
    t: &ChunkedTable,
    index: usize,
    parts: usize,
) -> ChunkedTable {
    debug_assert!(index < parts && parts > 0);
    let n = t.num_rows();
    let start = index * n / parts;
    let end = (index + 1) * n / parts;
    t.slice(start, end - start)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, NetModel, ReduceOp};
    use crate::df::{gen_table, gen_two_tables, Column, GenSpec};
    use crate::metrics::mem;
    use crate::ops::local::is_sorted_by_key;

    fn world(p: usize) -> CommWorld {
        CommWorld::new(p, NetModel::disabled())
    }

    fn int_table(keys: Vec<i64>, vals: Vec<f64>) -> Table {
        Table::new(
            Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)]),
            vec![Column::from_i64(keys), Column::from_f64(vals)],
        )
        .unwrap()
    }

    #[test]
    fn shuffle_conserves_and_colocates() {
        let p = 4;
        let out = world(p)
            .run(move |c| {
                let t = gen_table(&GenSpec::uniform(600, 40, 9), c.rank());
                let before =
                    c.allreduce_u64(t.multiset_fingerprint(), ReduceOp::Sum);
                let s = shuffle_by_key(&c, &t, 0, &KernelBackend::Native).unwrap();
                let after =
                    c.allreduce_u64(s.multiset_fingerprint(), ReduceOp::Sum);
                assert_eq!(before, after, "shuffle lost or duplicated rows");
                // Co-location: every local key hashes to this rank.
                for &k in s.column(0).as_i64().unwrap() {
                    assert_eq!(
                        crate::util::hash::partition_of(k, p as u32) as usize,
                        c.rank()
                    );
                }
                s.num_rows()
            })
            .unwrap();
        assert_eq!(out.iter().sum::<usize>(), 600 * p);
    }

    #[test]
    fn chunked_shuffle_defers_the_concat() {
        let p = 4;
        let out = world(p)
            .run(move |c| {
                let t = gen_table(&GenSpec::uniform(600, 40, 9), c.rank());
                let before =
                    c.allreduce_u64(t.multiset_fingerprint(), ReduceOp::Sum);
                let s = shuffle_by_key_chunked(&c, &t, 0, &KernelBackend::Native)
                    .unwrap();
                // One chunk per sender, no flattening yet.
                assert_eq!(s.num_chunks(), p);
                let after = c.allreduce_u64(s.multiset_fingerprint(), ReduceOp::Sum);
                assert_eq!(before, after);
                // Compacting yields the same table the eager path builds.
                let flat = s.compact();
                assert_eq!(flat.num_rows(), s.num_rows());
                assert_eq!(flat.multiset_fingerprint(), s.multiset_fingerprint());
                s.num_rows()
            })
            .unwrap();
        assert_eq!(out.iter().sum::<usize>(), 600 * p);
    }

    #[test]
    fn counting_scatter_matches_destination_lists() {
        let keys: Vec<i64> = (0..500).map(|i| i * 17 % 97).collect();
        for nparts in [1usize, 2, 7, 16] {
            let ids = crate::util::hash::partition_ids(&keys, nparts as u32);
            let (rows, offsets) = counting_scatter(&ids, nparts);
            let legacy = destination_lists(&ids, nparts);
            assert_eq!(offsets.len(), nparts + 1);
            assert_eq!(offsets[0], 0);
            assert_eq!(offsets[nparts], keys.len());
            for d in 0..nparts {
                let flat: Vec<usize> = rows[offsets[d]..offsets[d + 1]]
                    .iter()
                    .map(|&r| r as usize)
                    .collect();
                assert_eq!(flat, legacy[d], "destination {d}");
            }
        }
        // Degenerate: no rows.
        let (rows, offsets) = counting_scatter(&[], 4);
        assert!(rows.is_empty());
        assert_eq!(offsets, vec![0; 5]);
    }

    #[test]
    fn counting_scatter_par_is_bit_identical_to_sequential() {
        let pmr = pool::par_min_rows();
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            for n in [0usize, 100, pmr, 3 * pmr] {
                let keys: Vec<i64> = (0..n as i64).map(|i| i * 17 % 97).collect();
                for nparts in [1usize, 2, 7, 16] {
                    let ids =
                        crate::util::hash::partition_ids(&keys, nparts as u32);
                    let par = counting_scatter_par(&ids, nparts, &pool);
                    let seq = counting_scatter_seq(&ids, nparts);
                    assert_eq!(par, seq, "threads={threads} n={n} p={nparts}");
                }
                // Skew: every row routes to one destination.
                let ids = vec![2i32; n];
                let par = counting_scatter_par(&ids, 4, &pool);
                let seq = counting_scatter_seq(&ids, 4);
                assert_eq!(par, seq, "all-one-destination threads={threads} n={n}");
            }
        }
    }

    #[test]
    fn shuffle_single_rank_is_identity() {
        let out = world(1)
            .run(|c| {
                let t = gen_table(&GenSpec::uniform(50, 10, 3), 0);
                // p == 1: pure Arc clones — zero bytes materialized.
                let before = mem::thread();
                let s = shuffle_by_key(&c, &t, 0, &KernelBackend::Native).unwrap();
                assert_eq!(mem::thread().since(before).materialized, 0);
                assert!(s.column(0).shares_buffer(t.column(0)));
                s == t
            })
            .unwrap();
        assert!(out[0]);
    }

    #[test]
    fn dist_sort_globally_ordered() {
        let p = 3;
        let out = world(p)
            .run(move |c| {
                let t = gen_table(&GenSpec::uniform(400, 5_000, 11), c.rank());
                let before =
                    c.allreduce_u64(t.multiset_fingerprint(), ReduceOp::Sum);
                let s = dist_sort(&c, &t, 0, &KernelBackend::Native).unwrap();
                assert!(is_sorted_by_key(&s, 0).unwrap());
                let after =
                    c.allreduce_u64(s.multiset_fingerprint(), ReduceOp::Sum);
                assert_eq!(before, after);
                let keys = s.column(0).as_i64().unwrap();
                let bounds = (
                    keys.first().copied().unwrap_or(i64::MAX),
                    keys.last().copied().unwrap_or(i64::MIN),
                );
                (c.allgather(vec![bounds.0, bounds.1]), s.num_rows())
            })
            .unwrap();
        // Rank r's max key <= rank r+1's min key (ignoring empty ranks).
        let bounds = &out[0].0;
        let mut last_max = i64::MIN;
        for b in bounds {
            let (min, max) = (b[0], b[1]);
            if min <= max {
                assert!(min >= last_max, "ranges overlap: {min} < {last_max}");
                last_max = max;
            }
        }
        assert_eq!(out.iter().map(|(_, n)| n).sum::<usize>(), 400 * p);
    }

    #[test]
    fn dist_sort_handles_skew_and_empty() {
        // One rank holds everything; the others start empty.
        let out = world(3)
            .run(|c| {
                let t = if c.rank() == 0 {
                    gen_table(&GenSpec::uniform(300, 20, 5), 0)
                } else {
                    Table::empty(Schema::of(&[
                        ("key", DataType::Int64),
                        ("val", DataType::Float64),
                    ]))
                };
                let s = dist_sort(&c, &t, 0, &KernelBackend::Native).unwrap();
                assert!(is_sorted_by_key(&s, 0).unwrap());
                s.num_rows()
            })
            .unwrap();
        assert_eq!(out.iter().sum::<usize>(), 300);
    }

    #[test]
    fn dist_join_matches_local_oracle() {
        let p = 2;
        let spec = GenSpec::uniform(300, 60, 21);
        // Local oracle: join the concatenation of all partitions.
        let mut lefts = Vec::new();
        let mut rights = Vec::new();
        for r in 0..p {
            let (l, rt) = gen_two_tables(&spec, r);
            lefts.push(l);
            rights.push(rt);
        }
        let oracle = hash_join(
            &Table::concat(&lefts).unwrap(),
            &Table::concat(&rights).unwrap(),
            0,
            0,
            JoinType::Inner,
        )
        .unwrap();

        let spec2 = spec.clone();
        let out = world(p)
            .run(move |c| {
                let (l, r) = gen_two_tables(&spec2, c.rank());
                let j = dist_hash_join(
                    &c, &l, &r, 0, 0,
                    JoinType::Inner,
                    &KernelBackend::Native,
                )
                .unwrap();
                let rows = c.allreduce_u64(j.num_rows() as u64, ReduceOp::Sum);
                let fp = c.allreduce_u64(j.multiset_fingerprint(), ReduceOp::Sum);
                (rows, fp)
            })
            .unwrap();
        assert_eq!(out[0].0, oracle.num_rows() as u64);
        assert_eq!(out[0].1, oracle.multiset_fingerprint());
    }

    #[test]
    fn dist_groupby_matches_local_oracle() {
        // Whole-number vals keep float sums exact under any addition order,
        // so two-phase and single-pass aggregation agree bit-for-bit.
        let p = 3;
        let parts: Vec<Table> = (0..p)
            .map(|r| {
                let keys: Vec<i64> = (0..120).map(|i| (i * 7 + r as i64) % 15).collect();
                let vals: Vec<f64> = (0..120).map(|i| (i % 9) as f64).collect();
                int_table(keys, vals)
            })
            .collect();
        for agg in [AggFn::Sum, AggFn::Count, AggFn::Min, AggFn::Max, AggFn::Mean] {
            let oracle =
                groupby_agg(&Table::concat(&parts).unwrap(), 0, 1, agg).unwrap();
            let parts2 = parts.clone();
            let out = world(p as usize)
                .run(move |c| {
                    let g = dist_groupby(
                        &c,
                        &parts2[c.rank()],
                        0,
                        1,
                        agg,
                        &KernelBackend::Native,
                    )
                    .unwrap();
                    let rows = c.allreduce_u64(g.num_rows() as u64, ReduceOp::Sum);
                    let fp =
                        c.allreduce_u64(g.multiset_fingerprint(), ReduceOp::Sum);
                    (rows, fp, g.schema().field(1).name.clone())
                })
                .unwrap();
            assert_eq!(out[0].0, oracle.num_rows() as u64, "{agg:?} group count");
            if agg != AggFn::Mean {
                // Mean divides per-key on one rank vs globally — same values
                // here (exact arithmetic), but only compare the decomposable
                // aggs bit-for-bit to stay robust.
                assert_eq!(out[0].1, oracle.multiset_fingerprint(), "{agg:?}");
            }
            assert_eq!(out[0].2, oracle.schema().field(1).name, "{agg:?} schema");
        }
    }

    #[test]
    fn dist_sort_chunked_spills_and_matches_dist_sort() {
        let p = 3;
        let out = world(p)
            .run(move |c| {
                let t = gen_table(&GenSpec::uniform(400, 5_000, 11), c.rank());
                // Four resident chunks per rank.
                let parts: Vec<Table> =
                    (0..4).map(|i| t.slice(i * 100, 100)).collect();
                let chunked = ChunkedTable::from_tables(parts).unwrap();
                let budget = MemoryBudget::new(t.byte_size() as u64 / 4);
                let s = dist_sort_chunked_with(
                    &c, &chunked, 0, &KernelBackend::Native, &budget,
                )
                .unwrap();
                let spilled = s.chunk_list().iter().any(|ch| ch.is_spilled());
                let local = s.compact();
                assert!(is_sorted_by_key(&local, 0).unwrap());
                // Per-rank partitions may differ from dist_sort (metadata
                // sampling); the *global* concatenation must be identical.
                let base = dist_sort(&c, &t, 0, &KernelBackend::Native).unwrap();
                let g =
                    gather_table(&c, local).unwrap().map(|g| (g, spilled));
                let gb = gather_table(&c, base).unwrap();
                (g, gb)
            })
            .unwrap();
        let (got, spilled) = out[0].0.clone().unwrap();
        let base = out[0].1.clone().unwrap();
        assert!(spilled, "a quarter budget must spill the sorted output");
        assert_eq!(got, base, "global sorted output must be bit-identical");
    }

    #[test]
    fn dist_sort_chunked_unbounded_falls_back() {
        let out = world(2)
            .run(|c| {
                let t = gen_table(&GenSpec::uniform(200, 500, 3), c.rank());
                let s = dist_sort_chunked_with(
                    &c,
                    &ChunkedTable::from(t.clone()),
                    0,
                    &KernelBackend::Native,
                    &MemoryBudget::unbounded(),
                )
                .unwrap();
                let base = dist_sort(&c, &t, 0, &KernelBackend::Native).unwrap();
                s.compact() == base
            })
            .unwrap();
        assert!(out.iter().all(|&ok| ok));
    }

    #[test]
    fn dist_join_chunked_matches_dist_join() {
        let p = 2;
        let spec = GenSpec::uniform(300, 60, 21);
        let out = world(p)
            .run(move |c| {
                let (l, r) = gen_two_tables(&spec, c.rank());
                let budget = MemoryBudget::new(
                    (l.byte_size() + r.byte_size()) as u64 / 4,
                );
                let j = dist_hash_join_chunked_with(
                    &c,
                    &ChunkedTable::from(l.clone()),
                    &ChunkedTable::from(r.clone()),
                    0,
                    0,
                    JoinType::Inner,
                    &KernelBackend::Native,
                    &budget,
                )
                .unwrap();
                // Shuffle routes identically, so the per-rank result must
                // be bit-identical to the in-memory distributed join.
                let base = dist_hash_join(
                    &c, &l, &r, 0, 0,
                    JoinType::Inner,
                    &KernelBackend::Native,
                )
                .unwrap();
                let spilled = j.chunk_list().iter().any(|ch| ch.is_spilled());
                (j.compact() == base, spilled)
            })
            .unwrap();
        assert!(out.iter().all(|(ok, _)| *ok));
        assert!(
            out.iter().any(|(_, spilled)| *spilled),
            "a quarter budget must take the grace path somewhere"
        );
    }

    #[test]
    fn gather_chunked_keeps_spilled_chunks_on_disk() {
        use crate::spill::spill_table;
        let out = world(2)
            .run(|c| {
                let t = int_table(
                    vec![c.rank() as i64, 10 + c.rank() as i64],
                    vec![0.0; 2],
                );
                let st = spill_table(&t).unwrap();
                let mut v = ChunkedTable::empty(t.schema().clone());
                v.push_spilled(st, None);
                gather_chunked(&c, v).unwrap()
            })
            .unwrap();
        let root = out[0].as_ref().unwrap();
        assert_eq!(root.num_chunks(), 2);
        assert!(root.chunk_list().iter().all(|ch| ch.is_spilled()));
        assert_eq!(root.resident_bytes(), 0, "nothing restored by the gather");
        assert_eq!(
            root.compact().column(0).as_i64().unwrap(),
            &[0, 10, 1, 11]
        );
    }

    #[test]
    fn gather_table_concatenates_in_rank_order() {
        let out = world(3)
            .run(|c| {
                let t = int_table(vec![c.rank() as i64], vec![0.0]);
                gather_table(&c, t).unwrap()
            })
            .unwrap();
        let root = out[0].as_ref().unwrap();
        assert_eq!(root.column(0).as_i64().unwrap(), &[0, 1, 2]);
        assert!(out[1].is_none() && out[2].is_none());
    }

    #[test]
    fn gather_table_chunked_keeps_parts() {
        let out = world(3)
            .run(|c| {
                let t = int_table(vec![c.rank() as i64, 10 + c.rank() as i64], vec![0.0; 2]);
                gather_table_chunked(&c, t).unwrap()
            })
            .unwrap();
        let root = out[0].as_ref().unwrap();
        assert_eq!(root.num_chunks(), 3); // one per rank, nothing flattened
        assert_eq!(root.num_rows(), 6);
        assert_eq!(
            root.compact().column(0).as_i64().unwrap(),
            &[0, 10, 1, 11, 2, 12]
        );
    }

    #[test]
    fn gather_chunked_adopts_all_windows() {
        // Each rank ships a 2-chunk view; the root adopts all 6 windows
        // without flattening anything.
        let out = world(3)
            .run(|c| {
                let t = int_table(
                    vec![c.rank() as i64, 10 + c.rank() as i64],
                    vec![0.0; 2],
                );
                let v = ChunkedTable::from_tables(vec![t.slice(0, 1), t.slice(1, 1)])
                    .unwrap();
                gather_chunked(&c, v).unwrap()
            })
            .unwrap();
        let root = out[0].as_ref().unwrap();
        assert_eq!(root.num_chunks(), 6);
        assert_eq!(
            root.compact().column(0).as_i64().unwrap(),
            &[0, 10, 1, 11, 2, 12]
        );
        assert!(out[1].is_none() && out[2].is_none());
    }

    #[test]
    fn gather_chunked_of_empty_views_keeps_schema() {
        let out = world(2)
            .run(|c| {
                let schema =
                    Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)]);
                gather_chunked(&c, ChunkedTable::empty(schema)).unwrap()
            })
            .unwrap();
        let root = out[0].as_ref().unwrap();
        assert_eq!(root.num_rows(), 0);
        assert_eq!(root.schema().field(0).name, "key");
    }

    #[test]
    fn partition_slice_covers_table() {
        let t = ChunkedTable::from(int_table((0..10).collect(), vec![0.0; 10]));
        let parts: Vec<Table> =
            (0..3).map(|i| partition_slice(&t, i, 3).into_table()).collect();
        assert_eq!(parts.iter().map(|p| p.num_rows()).sum::<usize>(), 10);
        let back = Table::concat(&parts).unwrap();
        assert_eq!(back.column(0).as_i64().unwrap(), &(0..10).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn partition_slice_is_zero_copy() {
        // The acceptance property: per-rank input chunking of a staged
        // (single-chunk) table materializes zero bytes — it is windows all
        // the way down, including the final into_table().
        let staged = ChunkedTable::from(int_table((0..1000).collect(), vec![0.0; 1000]));
        let before = mem::thread();
        let mut total = 0;
        for i in 0..4 {
            let chunk = partition_slice(&staged, i, 4);
            total += chunk.num_rows();
            let t = chunk.into_table(); // single-chunk fast path: no concat
            assert!(t.column(0).shares_buffer(staged.chunks()[0].column(0)));
        }
        let delta = mem::thread().since(before);
        assert_eq!(delta.materialized, 0, "per-rank chunking must not copy");
        assert!(delta.viewed > 0);
        assert_eq!(total, 1000);
    }

    #[test]
    fn partition_slice_spans_chunks() {
        // A gathered (multi-chunk) staged table still partitions correctly
        // when rank windows straddle chunk boundaries.
        let staged = ChunkedTable::from_tables(vec![
            int_table((0..4).collect(), vec![0.0; 4]),
            int_table((4..7).collect(), vec![0.0; 7 - 4]),
            int_table((7..10).collect(), vec![0.0; 3]),
        ])
        .unwrap();
        let mut all = Vec::new();
        for i in 0..4 {
            let part = partition_slice(&staged, i, 4).into_table();
            all.extend_from_slice(part.column(0).as_i64().unwrap());
        }
        assert_eq!(all, (0..10).collect::<Vec<i64>>());
    }
}
