//! Cylon operator algebra (paper §3.2): *local operators* act on one rank's
//! partition; *distributed operators* compose local operators with
//! communicator collectives (shuffle/allgather/...); the [`operator`]
//! module packages both behind the extensible [`operator::Operator`] trait
//! the task executor dispatches through.

pub mod dist;
pub mod local;
pub mod operator;
