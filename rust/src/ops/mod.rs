//! Cylon operator algebra (paper §3.2): *local operators* act on one rank's
//! partition; *distributed operators* compose local operators with
//! communicator collectives (shuffle/allgather/...).

pub mod dist;
pub mod local;
