//! Local sort and k-way merge.
//!
//! Single-key int64 sorts — the paper's headline sort workload — run on a
//! flat LSD radix kernel ([`radix_sort_rows`]) in both directions; every
//! other shape (multi-key, float/utf8/bool keys) takes the generic
//! comparator path, which survives as [`sort_table_comparator`], the
//! radix kernel's bench baseline and bit-identical oracle (EXPERIMENTS.md
//! §Perf). The k-way merge advances whole duplicate-key runs per heap
//! operation; its one-pop-per-row predecessor survives as
//! [`merge_sorted_per_row`].

use crate::df::{
    Chunk, ChunkedTable, Column, DataType, Schema, Table, Utf8Builder,
};
use crate::error::{Error, Result};
use crate::spill::{
    spill_table, MemoryBudget, Reservation, RunReader, RunWriter, SpilledTable,
};
use crate::util::pool::{self, SharedSlice, ThreadPool};

/// Below this row count the parallel kernels fall back to their
/// sequential twins: morsel scheduling and per-thread histogram merges
/// don't amortize on small inputs. Resolved once per process from the
/// `par_min_rows` config knob / `RC_PAR_MIN_ROWS` env variable (default
/// 4096) — see [`pool::par_min_rows`]. Tests lower it to force the
/// parallel path on small fixtures.
pub(crate) fn par_min_rows() -> usize {
    pool::par_min_rows()
}

/// Split `0..n` into `nt` contiguous morsels (last may be short).
pub(crate) fn morsel_ranges(n: usize, nt: usize) -> Vec<(usize, usize)> {
    let chunk = n.div_ceil(nt.max(1)).max(1);
    (0..nt).map(|t| ((t * chunk).min(n), ((t + 1) * chunk).min(n))).collect()
}

/// A sort key: column index + direction.
#[derive(Clone, Copy, Debug)]
pub struct SortKey {
    pub col: usize,
    pub ascending: bool,
}

impl SortKey {
    pub fn asc(col: usize) -> SortKey {
        SortKey { col, ascending: true }
    }
    pub fn desc(col: usize) -> SortKey {
        SortKey { col, ascending: false }
    }
}

fn cmp_values(c: &Column, a: usize, b: usize) -> std::cmp::Ordering {
    match c {
        Column::Int64(v) => v[a].cmp(&v[b]),
        // total_cmp, not partial_cmp-or-Equal: with NaN present the latter
        // is not a total order (NaN "equal" to everything but 1.0 < 2.0),
        // which modern `sort_by` implementations may reject at runtime.
        // total_cmp orders -NaN < -inf < ... < +inf < +NaN.
        Column::Float64(v) => v[a].total_cmp(&v[b]),
        Column::Utf8(v) => v.get(a).cmp(v.get(b)),
        Column::Bool(v) => v[a].cmp(&v[b]),
    }
}

fn validate_keys(t: &Table, keys: &[SortKey]) -> Result<()> {
    if keys.is_empty() {
        return Err(Error::DataFrame("sort with zero keys".into()));
    }
    for k in keys {
        if k.col >= t.num_columns() {
            return Err(Error::DataFrame(format!(
                "sort key column {} out of range ({} columns)",
                k.col,
                t.num_columns()
            )));
        }
    }
    Ok(())
}

/// Row order of a single-key int64 sort — LSD radix over `(u64 key, u32
/// row)` pairs (radix perf pass, EXPERIMENTS.md §Perf).
///
/// Keys are sign-flipped to `u64` (`^ i64::MIN`) so unsigned byte order
/// equals signed order; descending inverts all bits, so one ascending
/// kernel serves both directions without a reversal step (a plain reverse
/// would break stability on duplicate keys). 8-bit digits; a single pass
/// builds all eight digit histograms up front, passes whose digit is
/// constant across the input are skipped, and the scatter ping-pongs
/// between the pair array and one reused scratch buffer — two allocations
/// regardless of pass count. The forward counting scatter is stable, so
/// equal keys keep ascending row order, matching the stable comparator
/// path bit-for-bit.
fn radix_sort_rows(keys: &[i64], ascending: bool) -> Vec<u32> {
    let n = keys.len();
    let dir = if ascending { 0u64 } else { !0u64 };
    let mut src: Vec<(u64, u32)> = keys
        .iter()
        .enumerate()
        .map(|(i, &k)| (((k as u64) ^ (1u64 << 63)) ^ dir, i as u32))
        .collect();
    if n < 256 {
        // Counting passes don't amortize on tiny inputs; the pair sort is
        // stable-equivalent (rows make every pair distinct).
        src.sort_unstable();
        return src.into_iter().map(|(_, i)| i).collect();
    }
    let mut hist = [[0u32; 256]; 8];
    for &(u, _) in &src {
        for (d, h) in hist.iter_mut().enumerate() {
            h[((u >> (d * 8)) & 0xFF) as usize] += 1;
        }
    }
    // Scratch allocated lazily on the first executed pass: all-equal or
    // otherwise digit-constant inputs skip every pass and never pay for
    // it (n >= 256 here, so is_empty() means "not yet allocated").
    let mut dst: Vec<(u64, u32)> = Vec::new();
    for (d, h) in hist.iter().enumerate() {
        // A constant digit permutes nothing — skip the pass (narrow key
        // ranges sort in 2-3 passes instead of 8).
        if h.iter().any(|&c| c as usize == n) {
            continue;
        }
        if dst.is_empty() {
            dst = vec![(0, 0); n];
        }
        let mut cursors = [0u32; 256];
        let mut sum = 0u32;
        for (c, &count) in cursors.iter_mut().zip(h.iter()) {
            *c = sum;
            sum += count;
        }
        let shift = d * 8;
        for &(u, i) in &src {
            let digit = ((u >> shift) & 0xFF) as usize;
            dst[cursors[digit] as usize] = (u, i);
            cursors[digit] += 1;
        }
        std::mem::swap(&mut src, &mut dst);
    }
    src.into_iter().map(|(_, i)| i).collect()
}

/// Morsel-parallel twin of [`radix_sort_rows`]: every counting pass runs
/// per-thread histograms over contiguous morsels, a serial prefix-sum
/// merge assigns each (morsel, digit) a private absolute write range,
/// and the scatter writes through [`SharedSlice`] into disjoint ranges.
///
/// **Determinism:** within one digit value the write ranges are laid out
/// in morsel order, and morsels are contiguous ascending ranges of the
/// pass input — so the parallel scatter places pairs in exactly the
/// order the sequential stable forward scan would, for *any* morsel
/// split. Pass-skip decisions reuse the merged global histograms, whose
/// totals are permutation-invariant, so both kernels execute the same
/// passes. The result is bit-identical to [`radix_sort_rows`].
///
/// Per-pass digit counts are **recomputed** each pass (the pass input is
/// a new permutation every time); only the upfront 8-digit histograms
/// can be built once.
fn radix_sort_rows_par(
    keys: &[i64],
    ascending: bool,
    pool: &ThreadPool,
) -> Vec<u32> {
    let n = keys.len();
    let nt = pool.size().min(n / par_min_rows()).max(1);
    if nt <= 1 {
        return radix_sort_rows(keys, ascending);
    }
    let dir = if ascending { 0u64 } else { !0u64 };
    let morsels = morsel_ranges(n, nt);
    let mut src: Vec<(u64, u32)> = vec![(0, 0); n];
    {
        let shared = SharedSlice::new(&mut src);
        pool.run_indexed(nt, |t| {
            let (lo, hi) = morsels[t];
            for (off, &k) in keys[lo..hi].iter().enumerate() {
                let i = lo + off;
                // SAFETY: morsels are disjoint index ranges; reads only
                // after the join.
                unsafe {
                    shared.write(
                        i,
                        (((k as u64) ^ (1u64 << 63)) ^ dir, i as u32),
                    )
                };
            }
        });
    }
    // Upfront global 8-digit histograms (per-morsel, then merged): used
    // only for the pass-skip decision, which is permutation-invariant.
    let partials: Vec<Vec<[u32; 256]>> = pool.run_indexed(nt, |t| {
        let (lo, hi) = morsels[t];
        let mut h = vec![[0u32; 256]; 8];
        for &(u, _) in &src[lo..hi] {
            for (d, hd) in h.iter_mut().enumerate() {
                hd[((u >> (d * 8)) & 0xFF) as usize] += 1;
            }
        }
        h
    });
    let mut hist = vec![[0u32; 256]; 8];
    for p in &partials {
        for (hd, pd) in hist.iter_mut().zip(p) {
            for (c, &a) in hd.iter_mut().zip(pd.iter()) {
                *c += a;
            }
        }
    }
    let mut dst: Vec<(u64, u32)> = vec![(0, 0); n];
    for (d, h) in hist.iter().enumerate() {
        if h.iter().any(|&c| c as usize == n) {
            continue;
        }
        let shift = d * 8;
        // Per-pass per-morsel digit counts over the *current* src.
        let mut counts: Vec<[u32; 256]> = pool.run_indexed(nt, |t| {
            let (lo, hi) = morsels[t];
            let mut c = [0u32; 256];
            for &(u, _) in &src[lo..hi] {
                c[((u >> shift) & 0xFF) as usize] += 1;
            }
            c
        });
        // Serial merge: cursor[t][digit] = Σ_{digit' < digit} total +
        // Σ_{t' < t} counts[t'][digit] — absolute disjoint write ranges,
        // morsel-major within each digit.
        let mut running = 0u32;
        for digit in 0..256 {
            for c in counts.iter_mut() {
                let start = running;
                running += c[digit];
                c[digit] = start;
            }
        }
        {
            let shared = SharedSlice::new(&mut dst);
            let cursors: Vec<std::sync::Mutex<[u32; 256]>> =
                counts.into_iter().map(std::sync::Mutex::new).collect();
            pool.run_indexed(nt, |t| {
                let (lo, hi) = morsels[t];
                let mut cur = cursors[t].lock().unwrap();
                for &(u, i) in &src[lo..hi] {
                    let digit = ((u >> shift) & 0xFF) as usize;
                    // SAFETY: each (morsel, digit) owns a private range
                    // by the prefix merge; reads only after the join.
                    unsafe { shared.write(cur[digit] as usize, (u, i)) };
                    cur[digit] += 1;
                }
            });
        }
        std::mem::swap(&mut src, &mut dst);
    }
    src.into_iter().map(|(_, i)| i).collect()
}

/// Stable sort by a single int64/utf8/float column.
pub fn sort_table(t: &Table, key: SortKey) -> Result<Table> {
    sort_table_multi(t, &[key])
}

/// Stable sort by multiple keys (lexicographic). Single-key int64 sorts of
/// **either direction** dispatch to the LSD radix kernel — morsel-parallel
/// on the global pool when it has >1 worker and the input is large enough
/// to amortize (bit-identical either way); everything else takes
/// [`sort_table_comparator`].
pub fn sort_table_multi(t: &Table, keys: &[SortKey]) -> Result<Table> {
    validate_keys(t, keys)?;
    if let [k] = keys {
        if let Column::Int64(v) = t.column(k.col) {
            if v.len() < u32::MAX as usize {
                let s = v.as_slice();
                let order =
                    if s.len() >= par_min_rows() && pool::parallelism() > 1 {
                        radix_sort_rows_par(s, k.ascending, pool::global())
                    } else {
                        radix_sort_rows(s, k.ascending)
                    };
                return Ok(t.take_u32(&order));
            }
        }
    }
    sort_table_comparator(t, keys)
}

/// [`sort_table`] on an explicit thread pool: single-key int64 sorts run
/// the morsel-parallel radix kernel whenever `pool` has more than one
/// worker (bit-identical to the sequential kernel — see
/// [`radix_sort_rows_par`]); other shapes fall back to the comparator.
pub fn sort_table_par(t: &Table, key: SortKey, pool: &ThreadPool) -> Result<Table> {
    validate_keys(t, &[key])?;
    if let Column::Int64(v) = t.column(key.col) {
        if v.len() < u32::MAX as usize {
            let order = radix_sort_rows_par(v.as_slice(), key.ascending, pool);
            return Ok(t.take_u32(&order));
        }
    }
    sort_table_comparator(t, &[key])
}

/// The generic comparator sort: index `sort_by` indirecting into the key
/// columns per comparison. Handles every dtype and key combination; kept
/// `pub` as the radix kernel's bench baseline and bit-identical oracle.
pub fn sort_table_comparator(t: &Table, keys: &[SortKey]) -> Result<Table> {
    validate_keys(t, keys)?;
    let mut idx: Vec<usize> = (0..t.num_rows()).collect();
    idx.sort_by(|&a, &b| {
        for k in keys {
            let ord = cmp_values(t.column(k.col), a, b);
            let ord = if k.ascending { ord } else { ord.reverse() };
            if ord != std::cmp::Ordering::Equal {
                return ord;
            }
        }
        std::cmp::Ordering::Equal
    });
    Ok(t.take(&idx))
}

/// Is the table sorted ascending on the given int64 column?
pub fn is_sorted_by_key(t: &Table, col: usize) -> Result<bool> {
    let keys = t.column(col).as_i64()?;
    Ok(keys.windows(2).all(|w| w[0] <= w[1]))
}

/// Validate schemas and borrow every part's key column.
fn merge_prep<'a>(parts: &'a [Table], col: usize) -> Result<Vec<&'a [i64]>> {
    if parts.is_empty() {
        return Err(Error::DataFrame("merge of zero tables".into()));
    }
    for p in parts {
        if p.schema() != parts[0].schema() {
            return Err(Error::DataFrame(format!(
                "merge schema mismatch: {} vs {}",
                p.schema(),
                parts[0].schema()
            )));
        }
    }
    parts.iter().map(|p| p.column(col).as_i64()).collect()
}

/// Global interleave order via a binary heap of `(key, part, row)`
/// cursors, advancing **whole duplicate-key runs** per heap operation
/// (run perf pass, EXPERIMENTS.md §Perf): after popping a cursor, the run
/// of equal keys on that part is emitted directly and only the first
/// differing key re-enters the heap. Equal keys on *other* parts
/// tie-break on the larger part index, so they pop afterwards either way
/// — the output order is bit-identical to the per-row baseline.
fn merge_order_runs(keys: &[&[i64]]) -> Vec<(u32, u32)> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    let total: usize = keys.iter().map(|k| k.len()).sum();
    let mut heap: BinaryHeap<Reverse<(i64, usize, usize)>> = BinaryHeap::new();
    for (pi, k) in keys.iter().enumerate() {
        if !k.is_empty() {
            heap.push(Reverse((k[0], pi, 0)));
        }
    }
    let mut order: Vec<(u32, u32)> = Vec::with_capacity(total);
    while let Some(Reverse((key, pi, ri))) = heap.pop() {
        let part = keys[pi];
        let mut end = ri + 1;
        while end < part.len() && part[end] == key {
            end += 1;
        }
        for r in ri..end {
            order.push((pi as u32, r as u32));
        }
        if end < part.len() {
            heap.push(Reverse((part[end], pi, end)));
        }
    }
    order
}

/// The per-row baseline: one heap push + pop for every output row. Kept
/// for [`merge_sorted_per_row`] (bench baseline / oracle).
fn merge_order_per_row(keys: &[&[i64]]) -> Vec<(u32, u32)> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    let total: usize = keys.iter().map(|k| k.len()).sum();
    let mut heap: BinaryHeap<Reverse<(i64, usize, usize)>> = BinaryHeap::new();
    for (pi, k) in keys.iter().enumerate() {
        if !k.is_empty() {
            heap.push(Reverse((k[0], pi, 0)));
        }
    }
    let mut order: Vec<(u32, u32)> = Vec::with_capacity(total);
    while let Some(Reverse((_, pi, ri))) = heap.pop() {
        order.push((pi as u32, ri as u32));
        let next = ri + 1;
        if next < keys[pi].len() {
            heap.push(Reverse((keys[pi][next], pi, next)));
        }
    }
    order
}

/// Columnar gather straight from the interleave order (perf pass,
/// EXPERIMENTS.md §Perf: replaces a row-at-a-time slice+extend stitch
/// that allocated one Column per row).
fn gather_interleave(parts: &[Table], order: &[(u32, u32)]) -> Result<Table> {
    let total = order.len();
    let ncols = parts[0].num_columns();
    let mut out_cols: Vec<Column> = Vec::with_capacity(ncols);
    for j in 0..ncols {
        let col = match parts[0].column(j) {
            Column::Int64(_) => {
                let srcs: Vec<&[i64]> =
                    parts.iter().map(|p| p.column(j).as_i64().unwrap()).collect();
                let mut v = Vec::with_capacity(total);
                for &(pi, ri) in order {
                    v.push(srcs[pi as usize][ri as usize]);
                }
                Column::from_i64(v)
            }
            Column::Float64(_) => {
                let srcs: Vec<&[f64]> =
                    parts.iter().map(|p| p.column(j).as_f64().unwrap()).collect();
                let mut v = Vec::with_capacity(total);
                for &(pi, ri) in order {
                    v.push(srcs[pi as usize][ri as usize]);
                }
                Column::from_f64(v)
            }
            Column::Utf8(_) => {
                // Gather straight into one output arena.
                let srcs: Vec<&crate::df::Utf8Buffer> = parts
                    .iter()
                    .map(|p| p.column(j).as_utf8().unwrap())
                    .collect();
                let bytes: usize = srcs.iter().map(|s| s.str_bytes()).sum();
                let mut b = Utf8Builder::with_capacity(total, bytes);
                for &(pi, ri) in order {
                    b.push(srcs[pi as usize].get(ri as usize));
                }
                Column::Utf8(b.finish())
            }
            Column::Bool(_) => {
                let mut v = Vec::with_capacity(total);
                for &(pi, ri) in order {
                    match parts[pi as usize].column(j) {
                        Column::Bool(b) => v.push(b[ri as usize]),
                        _ => unreachable!("schemas validated identical"),
                    }
                }
                Column::from_bool(v)
            }
        };
        out_cols.push(col);
    }
    Table::new(parts[0].schema().clone(), out_cols)
}

/// K-way merge of tables each already sorted ascending on int64 `col`
/// (the merge phase of distributed sample-sort). Duplicate-key runs on a
/// part advance in a single heap operation. Large merges dispatch to the
/// splitter-parallel twin [`merge_sorted_par`] when the global pool has
/// more than one worker — bit-identical either way.
pub fn merge_sorted(parts: &[Table], col: usize) -> Result<Table> {
    let total: usize = parts.iter().map(|p| p.num_rows()).sum();
    if total >= par_min_rows() && parts.len() > 1 && pool::parallelism() > 1 {
        return merge_sorted_par(parts, col, pool::global());
    }
    let keys = merge_prep(parts, col)?;
    let order = merge_order_runs(&keys);
    gather_interleave(parts, &order)
}

/// Splitter-parallel twin of [`merge_sorted`]: the k sorted runs are cut
/// into `nt` disjoint global key ranges by binary-searching one common
/// splitter set in every run (`partition_point(key <= splitter)`), each
/// range is merged independently on the pool, and the per-range outputs
/// are concatenated in range order.
///
/// **Determinism:** a `key <= splitter` cut puts every duplicate of a
/// splitter key on the same side in *every* run, so no duplicate-key run
/// straddles a range boundary. When the sequential merge first emits a
/// key above a cut, it has already emitted every row at or below it (the
/// heap pops keys in ascending order), so each run's cursor sits exactly
/// at that cut — the global merge restricted to a key range *is* the
/// range's own merge, part-index tie-break included. Concatenating the
/// ranges in order is therefore bit-identical to [`merge_sorted`] for
/// any splitter set; the split only chooses where the seams fall.
pub fn merge_sorted_par(
    parts: &[Table],
    col: usize,
    pool: &ThreadPool,
) -> Result<Table> {
    let keys = merge_prep(parts, col)?;
    let total: usize = keys.iter().map(|k| k.len()).sum();
    let nt = pool.size().min(total / par_min_rows()).max(1);
    if nt <= 1 || parts.len() <= 1 {
        let order = merge_order_runs(&keys);
        return gather_interleave(parts, &order);
    }
    // Regular sampling of every non-empty run -> one common splitter
    // set. Sample quality only affects balance, never correctness.
    let mut cand: Vec<i64> = Vec::with_capacity(keys.len() * (nt - 1));
    for k in &keys {
        if k.is_empty() {
            continue;
        }
        for i in 1..nt {
            cand.push(k[(i * k.len() / nt).min(k.len() - 1)]);
        }
    }
    cand.sort_unstable();
    let splitters: Vec<i64> = (1..nt)
        .map(|i| cand[(i * cand.len() / nt).min(cand.len() - 1)])
        .collect();
    // cuts[j][r] = first row of run j belonging to range r; range r
    // holds keys in (splitter[r-1], splitter[r]] (open-ended outermost).
    let cuts: Vec<Vec<usize>> = keys
        .iter()
        .map(|k| {
            let mut c = Vec::with_capacity(nt + 1);
            c.push(0usize);
            for &s in &splitters {
                c.push(k.partition_point(|&v| v <= s));
            }
            c.push(k.len());
            c
        })
        .collect();
    // Merge each key range independently; row ids are globalized by the
    // run's cut offset so the per-range orders index the full tables.
    let orders: Vec<Vec<(u32, u32)>> = pool.run_indexed(nt, |r| {
        let subs: Vec<&[i64]> = keys
            .iter()
            .enumerate()
            .map(|(j, k)| &k[cuts[j][r]..cuts[j][r + 1]])
            .collect();
        merge_order_runs(&subs)
            .into_iter()
            .map(|(pi, ri)| (pi, ri + cuts[pi as usize][r] as u32))
            .collect()
    });
    gather_interleave_par(parts, &orders, pool)
}

/// Parallel gather for [`merge_sorted_par`]: fixed-width columns scatter
/// per-range through a [`SharedSlice`] into one preallocated buffer
/// (ranges own disjoint output spans, so writes never collide); the
/// variable-width Utf8 arena appends ranges in order on the caller.
/// Materialized bytes equal the sequential [`gather_interleave`] exactly:
/// both count one output buffer per column at its final size.
fn gather_interleave_par(
    parts: &[Table],
    orders: &[Vec<(u32, u32)>],
    pool: &ThreadPool,
) -> Result<Table> {
    let total: usize = orders.iter().map(|o| o.len()).sum();
    let mut starts = Vec::with_capacity(orders.len());
    let mut acc = 0usize;
    for o in orders {
        starts.push(acc);
        acc += o.len();
    }
    let ncols = parts[0].num_columns();
    let mut out_cols: Vec<Column> = Vec::with_capacity(ncols);
    for j in 0..ncols {
        let col = match parts[0].column(j) {
            Column::Int64(_) => {
                let srcs: Vec<&[i64]> =
                    parts.iter().map(|p| p.column(j).as_i64().unwrap()).collect();
                let mut v = vec![0i64; total];
                {
                    let shared = SharedSlice::new(&mut v);
                    pool.run_indexed(orders.len(), |r| {
                        let base = starts[r];
                        for (off, &(pi, ri)) in orders[r].iter().enumerate() {
                            // SAFETY: range r owns output span
                            // [base, base + len) — disjoint across r;
                            // reads only after the join.
                            unsafe {
                                shared.write(
                                    base + off,
                                    srcs[pi as usize][ri as usize],
                                )
                            };
                        }
                    });
                }
                Column::from_i64(v)
            }
            Column::Float64(_) => {
                let srcs: Vec<&[f64]> =
                    parts.iter().map(|p| p.column(j).as_f64().unwrap()).collect();
                let mut v = vec![0f64; total];
                {
                    let shared = SharedSlice::new(&mut v);
                    pool.run_indexed(orders.len(), |r| {
                        let base = starts[r];
                        for (off, &(pi, ri)) in orders[r].iter().enumerate() {
                            // SAFETY: disjoint spans, reads after join.
                            unsafe {
                                shared.write(
                                    base + off,
                                    srcs[pi as usize][ri as usize],
                                )
                            };
                        }
                    });
                }
                Column::from_f64(v)
            }
            Column::Utf8(_) => {
                let srcs: Vec<&crate::df::Utf8Buffer> = parts
                    .iter()
                    .map(|p| p.column(j).as_utf8().unwrap())
                    .collect();
                let bytes: usize = srcs.iter().map(|s| s.str_bytes()).sum();
                let mut b = Utf8Builder::with_capacity(total, bytes);
                for o in orders {
                    for &(pi, ri) in o {
                        b.push(srcs[pi as usize].get(ri as usize));
                    }
                }
                Column::Utf8(b.finish())
            }
            Column::Bool(_) => {
                let srcs: Vec<&[bool]> = parts
                    .iter()
                    .map(|p| p.column(j).as_bool().unwrap())
                    .collect();
                let mut v = vec![false; total];
                {
                    let shared = SharedSlice::new(&mut v);
                    pool.run_indexed(orders.len(), |r| {
                        let base = starts[r];
                        for (off, &(pi, ri)) in orders[r].iter().enumerate() {
                            // SAFETY: disjoint spans, reads after join.
                            unsafe {
                                shared.write(
                                    base + off,
                                    srcs[pi as usize][ri as usize],
                                )
                            };
                        }
                    });
                }
                Column::from_bool(v)
            }
        };
        out_cols.push(col);
    }
    Table::new(parts[0].schema().clone(), out_cols)
}

/// [`merge_sorted`]'s one-heap-operation-per-row predecessor — kept as
/// the `kernel_hotpaths` bench baseline and bit-identical oracle for the
/// run-advancing merge.
pub fn merge_sorted_per_row(parts: &[Table], col: usize) -> Result<Table> {
    let keys = merge_prep(parts, col)?;
    let order = merge_order_per_row(&keys);
    gather_interleave(parts, &order)
}

// ---------------------------------------------------------------------------
// Out-of-core: external sample-sort + streaming k-way block merge
// ---------------------------------------------------------------------------

/// A source of sorted table blocks for the streaming merge: either a
/// spill-run reader (one block resident at a time) or a chunk list whose
/// members load lazily (spilled chunks restore per-access, resident ones
/// clone `Arc` views). Empty blocks are skipped transparently.
pub(crate) enum BlockStream {
    Reader(RunReader),
    Chunks(std::vec::IntoIter<Chunk>),
}

impl BlockStream {
    fn next_block(&mut self) -> Result<Option<Table>> {
        loop {
            let t = match self {
                BlockStream::Reader(r) => r.next_block()?,
                BlockStream::Chunks(it) => match it.next() {
                    Some(c) => Some(c.load()?),
                    None => None,
                },
            };
            match t {
                Some(t) if t.num_rows() == 0 => continue,
                other => return Ok(other),
            }
        }
    }
}

/// How [`merge_block_streams`] shapes its output.
pub(crate) struct MergeSpec {
    /// Int64 key column index in the incoming block schema; every stream
    /// must be globally sorted ascending on it.
    pub key_col: usize,
    /// Drop the key column from the output (grace join strips its
    /// `__lrow` merge key after restoring global emission order).
    pub strip_key: bool,
    /// Rows per output chunk before a flush.
    pub out_chunk_rows: usize,
    /// Spill flushed output chunks instead of keeping them resident.
    pub spill_outputs: bool,
}

/// Per-column value appender for the streaming merge's output batches.
enum ColApp {
    I64(Vec<i64>),
    F64(Vec<f64>),
    Bool(Vec<bool>),
    Utf8(Utf8Builder),
}

impl ColApp {
    fn new(dt: DataType) -> ColApp {
        match dt {
            DataType::Int64 => ColApp::I64(Vec::new()),
            DataType::Float64 => ColApp::F64(Vec::new()),
            DataType::Bool => ColApp::Bool(Vec::new()),
            DataType::Utf8 => ColApp::Utf8(Utf8Builder::new()),
        }
    }

    fn push(&mut self, col: &Column, i: usize) {
        match (self, col) {
            (ColApp::I64(v), Column::Int64(c)) => v.push(c[i]),
            (ColApp::F64(v), Column::Float64(c)) => v.push(c[i]),
            (ColApp::Bool(v), Column::Bool(c)) => v.push(c[i]),
            (ColApp::Utf8(b), Column::Utf8(c)) => b.push(c.get(i)),
            _ => unreachable!("merge schemas validated identical"),
        }
    }

    fn finish(self) -> Column {
        match self {
            ColApp::I64(v) => Column::from_i64(v),
            ColApp::F64(v) => Column::from_f64(v),
            ColApp::Bool(v) => Column::from_bool(v),
            ColApp::Utf8(b) => Column::Utf8(b.finish()),
        }
    }
}

fn new_appenders(schema: &Schema, kept: &[usize], src: &Schema) -> Vec<ColApp> {
    debug_assert_eq!(schema.len(), kept.len());
    kept.iter().map(|&j| ColApp::new(src.field(j).dtype)).collect()
}

/// Approximate payload bytes of one row restricted to `kept` columns
/// (reservation accounting for the pending output chunk).
fn row_payload_bytes(t: &Table, row: usize, kept: &[usize]) -> u64 {
    kept.iter()
        .map(|&j| match t.column(j) {
            Column::Int64(_) | Column::Float64(_) => 8u64,
            Column::Bool(_) => 1,
            Column::Utf8(v) => 4 + v.get(row).len() as u64,
        })
        .sum()
}

/// One merge cursor: the stream, its current resident block (with the
/// key column copied out so the heap never re-borrows the table), and a
/// reservation covering exactly that block.
struct MergeCursor<'b> {
    stream: BlockStream,
    block: Table,
    keys: Vec<i64>,
    pos: usize,
    budget: &'b MemoryBudget,
    res: Option<Reservation<'b>>,
}

impl<'b> MergeCursor<'b> {
    fn load_next(&mut self, key_col: usize) -> Result<bool> {
        self.res = None; // release the old block before loading the next
        match self.stream.next_block()? {
            Some(t) => {
                let budget: &'b MemoryBudget = self.budget;
                self.res = Some(budget.reserve(t.byte_size() as u64));
                self.keys = t.column(key_col).as_i64()?.to_vec();
                self.block = t;
                self.pos = 0;
                Ok(true)
            }
            None => {
                self.keys.clear();
                self.pos = 0;
                Ok(false)
            }
        }
    }
}

/// Streaming k-way merge over block streams, each globally sorted
/// ascending on `spec.key_col`. Never holds more than one block per
/// stream plus one pending output chunk in RAM; every resident piece is
/// covered by a reservation against `budget`.
///
/// **Bit-identity:** the heap pops `(key, stream_index)` pairs, and after
/// a pop the *whole duplicate-key run* of that stream is emitted —
/// continuing across the stream's block boundaries — before the first
/// differing key re-enters the heap. Equal keys on other streams
/// tie-break on the larger stream index and pop afterwards either way.
/// These are exactly the semantics of [`merge_sorted`]'s
/// `merge_order_runs` with parts in stream order, so the merged row order
/// equals the in-memory k-way merge of the fully-restored streams.
pub(crate) fn merge_block_streams(
    schema: &Schema,
    streams: Vec<BlockStream>,
    spec: &MergeSpec,
    budget: &MemoryBudget,
) -> Result<ChunkedTable> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    if spec.key_col >= schema.len() {
        return Err(Error::DataFrame(format!(
            "merge key column {} out of range ({} columns)",
            spec.key_col,
            schema.len()
        )));
    }
    let kept: Vec<usize> = (0..schema.len())
        .filter(|&j| !(spec.strip_key && j == spec.key_col))
        .collect();
    let out_schema = Schema::of(
        &kept
            .iter()
            .map(|&j| {
                let f = schema.field(j);
                (f.name.as_str(), f.dtype)
            })
            .collect::<Vec<_>>(),
    );

    let mut cursors: Vec<MergeCursor<'_>> = streams
        .into_iter()
        .map(|s| MergeCursor {
            stream: s,
            block: Table::empty(schema.clone()),
            keys: Vec::new(),
            pos: 0,
            budget,
            res: None,
        })
        .collect();
    let mut heap: BinaryHeap<Reverse<(i64, usize)>> = BinaryHeap::new();
    for (si, c) in cursors.iter_mut().enumerate() {
        if c.load_next(spec.key_col)? {
            heap.push(Reverse((c.keys[0], si)));
        }
    }

    let mut out: Vec<Chunk> = Vec::new();
    let mut apps = new_appenders(&out_schema, &kept, schema);
    let mut pending_rows = 0usize;
    let mut pending_bytes = 0u64;
    let mut key_range: Option<(i64, i64)> = None;
    let mut out_res = budget.reserve(0);

    let mut flush = |apps: &mut Vec<ColApp>,
                     pending_rows: &mut usize,
                     pending_bytes: &mut u64,
                     key_range: &mut Option<(i64, i64)>,
                     out_res: &mut Reservation<'_>,
                     out: &mut Vec<Chunk>|
     -> Result<()> {
        if *pending_rows == 0 {
            return Ok(());
        }
        let cols: Vec<Column> =
            std::mem::replace(apps, new_appenders(&out_schema, &kept, schema))
                .into_iter()
                .map(ColApp::finish)
                .collect();
        let t = Table::new(out_schema.clone(), cols)?;
        if spec.spill_outputs {
            let st = spill_table(&t)?;
            out.push(Chunk::spilled(st, *key_range));
        } else {
            out.push(Chunk::Ram(t));
        }
        out_res.shrink(*pending_bytes);
        *pending_rows = 0;
        *pending_bytes = 0;
        *key_range = None;
        Ok(())
    };

    while let Some(Reverse((key, si))) = heap.pop() {
        let cur = &mut cursors[si];
        loop {
            while cur.pos < cur.keys.len() && cur.keys[cur.pos] == key {
                for (app, &cj) in apps.iter_mut().zip(&kept) {
                    app.push(cur.block.column(cj), cur.pos);
                }
                let rb = row_payload_bytes(&cur.block, cur.pos, &kept);
                out_res.grow(rb);
                pending_bytes += rb;
                pending_rows += 1;
                key_range = Some(match key_range {
                    None => (key, key),
                    Some((lo, _)) => (lo, key),
                });
                cur.pos += 1;
            }
            if cur.pos < cur.keys.len() {
                heap.push(Reverse((cur.keys[cur.pos], si)));
                break;
            }
            // Block exhausted mid-run: the run may continue in the
            // stream's next block.
            if !cur.load_next(spec.key_col)? {
                break;
            }
        }
        if pending_rows >= spec.out_chunk_rows {
            flush(
                &mut apps,
                &mut pending_rows,
                &mut pending_bytes,
                &mut key_range,
                &mut out_res,
                &mut out,
            )?;
        }
    }
    flush(
        &mut apps,
        &mut pending_rows,
        &mut pending_bytes,
        &mut key_range,
        &mut out_res,
        &mut out,
    )?;
    ChunkedTable::from_chunk_list(out_schema, out)
}

/// Floor for a sorted run's target size: below this, sort+spill overhead
/// dwarfs the IO it saves (also keeps pathological budgets from emitting
/// a run per row).
pub(crate) const MIN_RUN_BYTES: u64 = 4 << 10;

/// Floor for an individual spill block. Deliberately tiny: the merge
/// holds one block per run resident, so its working set is
/// `num_runs * block_bytes` — a large floor would multiply by the run
/// count and blow the ceiling the whole design promises. 256 bytes keeps
/// per-block header overhead ~5% worst case while letting the working
/// set track `run_budget` even for many-run merges.
pub(crate) const MIN_BLOCK_BYTES: u64 = 256;

/// Spill `t` as one run of ~`block_bytes` blocks (row count derived from
/// the table's average row width), so downstream merges stream it one
/// block at a time.
pub(crate) fn spill_in_blocks(t: &Table, block_bytes: u64) -> Result<SpilledTable> {
    let n = t.num_rows();
    let row_bytes = (t.byte_size() / n.max(1)).max(1);
    let rows_per_block = ((block_bytes as usize) / row_bytes).max(1);
    let mut w = RunWriter::create(t.schema().clone())?;
    let mut start = 0usize;
    while start < n {
        let len = rows_per_block.min(n - start);
        w.write_table(&t.slice(start, len))?;
        start += len;
    }
    w.finish()
}

/// Budget-aware stable sort of a chunked input by one key.
///
/// Dispatch: unbounded budget, inputs no larger than half the limit, or
/// key shapes outside the external kernel's coverage (non-int64 or
/// descending — the paper's at-scale workload is ascending int64) sort
/// in memory via [`sort_table`], with the transient input+output copy
/// reserved against the budget. Everything else runs external
/// sample-sort: sorted runs generated with the radix/morsel-parallel
/// kernel, spilled in blocks, then streamed through
/// [`merge_block_streams`] — peak residency is one run batch (plus its
/// sorted copy) during run generation, and one block per run plus one
/// output chunk during the merge, all tracked by reservations so
/// `budget.peak()` is the machine-checked ceiling.
pub fn sort_table_budgeted(
    input: &ChunkedTable,
    key: SortKey,
    budget: &MemoryBudget,
) -> Result<ChunkedTable> {
    if key.col >= input.schema().len() {
        return Err(Error::DataFrame(format!(
            "sort key column {} out of range ({} columns)",
            key.col,
            input.schema().len()
        )));
    }
    let total = input.byte_size() as u64;
    let external = match budget.limit() {
        None => false,
        Some(limit) => total > limit / 2,
    };
    let i64_asc = key.ascending
        && input.schema().field(key.col).dtype == DataType::Int64;
    if !external || !i64_asc {
        let _res = budget.reserve(2 * total); // input + sorted copy
        let flat = input.compact();
        return Ok(ChunkedTable::from(sort_table(&flat, key)?));
    }
    sort_table_external(input, key, budget)
}

/// Seal the accumulated batch as one sorted spilled run.
fn spill_sorted_run(
    runs: &mut Vec<SpilledTable>,
    batch: &mut Vec<Table>,
    batch_bytes: &mut u64,
    key: SortKey,
    block_bytes: u64,
    res: &mut Reservation<'_>,
) -> Result<()> {
    if batch.is_empty() {
        return Ok(());
    }
    let flat = if batch.len() == 1 {
        batch.pop().expect("one part")
    } else {
        let t = Table::concat(batch)?;
        batch.clear();
        t
    };
    res.grow(*batch_bytes); // the sorted copy (take_u32 materializes)
    let sorted = sort_table(&flat, key)?;
    drop(flat);
    runs.push(spill_in_blocks(&sorted, block_bytes)?);
    res.shrink(2 * *batch_bytes);
    *batch_bytes = 0;
    Ok(())
}

/// External sample-sort (ascending int64 key, bounded budget): generate
/// sorted runs of about half the budget each, spill them in blocks sized
/// so the merge's one-block-per-run working set also fits half the
/// budget, and stream the k-way merge over run readers. Output chunks
/// are spilled with their key ranges, so downstream distributed sorts
/// can pick splitters from metadata alone.
///
/// **Bit-identity vs the in-memory sort:** runs cover contiguous input
/// windows in input order and are sorted with the same stable kernel, so
/// within a run equal keys keep input order; the merge's
/// `(key, run_index)` tie-break orders equal keys across runs by input
/// position (runs are in input order); and whole equal-key runs advance
/// per heap pop exactly as `merge_order_runs` does. The merged order is
/// therefore the stable global sort order — bit-identical to
/// `sort_table(&input.compact(), key)`.
fn sort_table_external(
    input: &ChunkedTable,
    key: SortKey,
    budget: &MemoryBudget,
) -> Result<ChunkedTable> {
    let limit = budget
        .limit()
        .expect("external sort dispatched only under a bounded budget");
    let total = input.byte_size() as u64;
    let run_budget = (limit / 2).max(MIN_RUN_BYTES);
    let est_runs = total.div_ceil(run_budget).max(1);
    let block_bytes = (run_budget / est_runs).max(MIN_BLOCK_BYTES);

    // --- Run generation: batch input chunks up to ~run_budget, sort,
    // spill. The reservation tracks batch + sorted copy.
    let mut runs: Vec<SpilledTable> = Vec::new();
    let mut batch: Vec<Table> = Vec::new();
    let mut batch_bytes = 0u64;
    let mut res = budget.reserve(0);
    for (i, c) in input.chunk_list().iter().enumerate() {
        let next_bytes = c.byte_size() as u64;
        if batch_bytes > 0 && batch_bytes + next_bytes > run_budget {
            spill_sorted_run(
                &mut runs,
                &mut batch,
                &mut batch_bytes,
                key,
                block_bytes,
                &mut res,
            )?;
        }
        let t = input.load_chunk(i)?;
        res.grow(next_bytes);
        batch_bytes += next_bytes;
        batch.push(t);
    }
    spill_sorted_run(
        &mut runs,
        &mut batch,
        &mut batch_bytes,
        key,
        block_bytes,
        &mut res,
    )?;
    drop(res);

    // --- Merge: one block per run + one pending output chunk resident.
    let row_bytes =
        (input.byte_size() / input.num_rows().max(1)).max(1);
    let out_chunk_rows = ((block_bytes as usize) / row_bytes).max(1);
    let streams: Vec<BlockStream> = runs
        .iter()
        .map(|r| r.reader().map(BlockStream::Reader))
        .collect::<Result<_>>()?;
    merge_block_streams(
        input.schema(),
        streams,
        &MergeSpec {
            key_col: key.col,
            strip_key: false,
            out_chunk_rows,
            spill_outputs: true,
        },
        budget,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::df::{DataType, Schema};
    use crate::util::testkit;

    fn table(keys: Vec<i64>, vals: Vec<f64>) -> Table {
        Table::new(
            Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)]),
            vec![Column::from_i64(keys), Column::from_f64(vals)],
        )
        .unwrap()
    }

    #[test]
    fn sorts_ascending_and_descending() {
        let t = table(vec![3, 1, 2], vec![0.3, 0.1, 0.2]);
        let asc = sort_table(&t, SortKey::asc(0)).unwrap();
        assert_eq!(asc.column(0).as_i64().unwrap(), &[1, 2, 3]);
        assert_eq!(asc.column(1).as_f64().unwrap(), &[0.1, 0.2, 0.3]);
        let desc = sort_table(&t, SortKey::desc(0)).unwrap();
        assert_eq!(desc.column(0).as_i64().unwrap(), &[3, 2, 1]);
    }

    #[test]
    fn multi_key_breaks_ties() {
        let t = Table::new(
            Schema::of(&[("a", DataType::Int64), ("b", DataType::Int64)]),
            vec![
                Column::from_i64(vec![1, 1, 0]),
                Column::from_i64(vec![5, 3, 9]),
            ],
        )
        .unwrap();
        let s = sort_table_multi(&t, &[SortKey::asc(0), SortKey::desc(1)]).unwrap();
        assert_eq!(s.column(0).as_i64().unwrap(), &[0, 1, 1]);
        assert_eq!(s.column(1).as_i64().unwrap(), &[9, 5, 3]);
    }

    #[test]
    fn stability() {
        // Equal keys keep original relative order of the value column —
        // in both directions (the descending fast path must not reverse
        // duplicate runs).
        let t = table(vec![1, 1, 1], vec![0.1, 0.2, 0.3]);
        let s = sort_table(&t, SortKey::asc(0)).unwrap();
        assert_eq!(s.column(1).as_f64().unwrap(), &[0.1, 0.2, 0.3]);
        let d = sort_table(&t, SortKey::desc(0)).unwrap();
        assert_eq!(d.column(1).as_f64().unwrap(), &[0.1, 0.2, 0.3]);
    }

    #[test]
    fn float_sort_with_nan_is_total() {
        // total_cmp order: -1.0 < 1.0 < NaN; stable on the NaN run.
        let t = Table::new(
            Schema::of(&[("f", DataType::Float64), ("v", DataType::Int64)]),
            vec![
                Column::from_f64(vec![f64::NAN, 1.0, -1.0, f64::NAN]),
                Column::from_i64(vec![0, 1, 2, 3]),
            ],
        )
        .unwrap();
        let s = sort_table(&t, SortKey::asc(0)).unwrap();
        let f = s.column(0).as_f64().unwrap();
        assert_eq!(&f[..2], &[-1.0, 1.0]);
        assert!(f[2].is_nan() && f[3].is_nan());
        assert_eq!(s.column(1).as_i64().unwrap(), &[2, 1, 0, 3]);
        let d = sort_table(&t, SortKey::desc(0)).unwrap();
        let f = d.column(0).as_f64().unwrap();
        assert!(f[0].is_nan() && f[1].is_nan());
        assert_eq!(&f[2..], &[1.0, -1.0]);
        // Stable: the two NaNs keep their original relative order.
        assert_eq!(d.column(1).as_i64().unwrap(), &[0, 3, 1, 2]);
    }

    #[test]
    fn radix_handles_extreme_and_negative_keys() {
        let keys = vec![i64::MAX, -1, 0, i64::MIN, 1, i64::MIN + 1, -1];
        let t = table(keys, vec![0.0; 7]);
        let s = sort_table(&t, SortKey::asc(0)).unwrap();
        assert_eq!(
            s.column(0).as_i64().unwrap(),
            &[i64::MIN, i64::MIN + 1, -1, -1, 0, 1, i64::MAX]
        );
        let d = sort_table(&t, SortKey::desc(0)).unwrap();
        assert_eq!(
            d.column(0).as_i64().unwrap(),
            &[i64::MAX, 1, 0, -1, -1, i64::MIN + 1, i64::MIN]
        );
    }

    #[test]
    fn prop_radix_is_bit_identical_to_comparator() {
        // Above and below the 256-row small-input cutoff, both directions.
        testkit::check("radix == comparator", 24, |rng| {
            let n = rng.gen_range(600) as usize;
            let keys: Vec<i64> = (0..n).map(|_| rng.gen_i64(-40, 40)).collect();
            let vals: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let t = table(keys, vals);
            for key in [SortKey::asc(0), SortKey::desc(0)] {
                let fast = sort_table(&t, key).unwrap();
                let oracle = sort_table_comparator(&t, &[key]).unwrap();
                assert_eq!(fast, oracle, "ascending={}", key.ascending);
            }
        });
    }

    #[test]
    fn parallel_radix_is_bit_identical_to_sequential() {
        // Straddle the nt>1 threshold (needs n >= 2 * the morsel
        // threshold) and include duplicate-heavy keys so stability is
        // observable.
        let pmr = par_min_rows();
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            for n in [0usize, 100, pmr, 3 * pmr] {
                let keys: Vec<i64> =
                    (0..n as i64).map(|i| (i * 37) % 11 - 5).collect();
                let vals: Vec<f64> = (0..n).map(|i| i as f64).collect();
                let t = table(keys, vals);
                for key in [SortKey::asc(0), SortKey::desc(0)] {
                    let par = sort_table_par(&t, key, &pool).unwrap();
                    let seq = sort_table_comparator(&t, &[key]).unwrap();
                    assert_eq!(
                        par, seq,
                        "threads={threads} n={n} asc={}",
                        key.ascending
                    );
                }
            }
        }
    }

    #[test]
    fn merge_matches_global_sort() {
        let a = table(vec![1, 4, 9], vec![0.0; 3]);
        let b = table(vec![2, 3, 10], vec![0.0; 3]);
        let c = table(vec![], vec![]);
        let m = merge_sorted(&[a, b, c], 0).unwrap();
        assert_eq!(m.column(0).as_i64().unwrap(), &[1, 2, 3, 4, 9, 10]);
        assert!(is_sorted_by_key(&m, 0).unwrap());
    }

    #[test]
    fn prop_run_merge_is_bit_identical_to_per_row_merge() {
        // Run-heavy parts (tiny key space => long duplicate runs).
        testkit::check("run merge == per-row merge", 24, |rng| {
            let parts: Vec<Table> = (0..4)
                .map(|_| {
                    let n = rng.gen_range(120) as usize;
                    let mut keys: Vec<i64> =
                        (0..n).map(|_| rng.gen_i64(0, 5)).collect();
                    keys.sort_unstable();
                    let vals: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
                    table(keys, vals)
                })
                .collect();
            let fast = merge_sorted(&parts, 0).unwrap();
            let oracle = merge_sorted_per_row(&parts, 0).unwrap();
            assert_eq!(fast, oracle);
        });
    }

    #[test]
    fn parallel_merge_is_bit_identical_to_sequential() {
        // Straddle the morsel threshold; interleaved duplicate keys make
        // the part-index tie-break observable, and one part stays empty.
        let pmr = par_min_rows();
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            for per_part in [0usize, 50, pmr, 2 * pmr] {
                let parts: Vec<Table> = (0..4)
                    .map(|p| {
                        let n = if p == 3 { 0 } else { per_part };
                        let mut keys: Vec<i64> = (0..n as i64)
                            .map(|i| (i * 13 + p) % 97)
                            .collect();
                        keys.sort_unstable();
                        let vals: Vec<f64> =
                            (0..n).map(|i| i as f64 + p as f64 * 0.5).collect();
                        table(keys, vals)
                    })
                    .collect();
                let par = merge_sorted_par(&parts, 0, &pool).unwrap();
                let seq = merge_sorted_per_row(&parts, 0).unwrap();
                assert_eq!(par, seq, "threads={threads} per_part={per_part}");
            }
        }
    }

    #[test]
    fn parallel_merge_handles_all_equal_keys() {
        // Every splitter collapses onto the single key value: one range
        // gets everything, the rest are empty — still bit-identical.
        let pmr = par_min_rows();
        let pool = ThreadPool::new(4);
        let parts: Vec<Table> = (0..3)
            .map(|p| {
                table(
                    vec![7i64; pmr],
                    (0..pmr).map(|i| i as f64 + p as f64).collect(),
                )
            })
            .collect();
        let par = merge_sorted_par(&parts, 0, &pool).unwrap();
        let seq = merge_sorted_per_row(&parts, 0).unwrap();
        assert_eq!(par, seq);
    }

    #[test]
    fn errors_on_misuse() {
        let t = table(vec![1], vec![0.0]);
        assert!(sort_table_multi(&t, &[]).is_err());
        assert!(sort_table(&t, SortKey::asc(9)).is_err());
        assert!(sort_table_comparator(&t, &[]).is_err());
        assert!(merge_sorted(&[], 0).is_err());
        assert!(merge_sorted_per_row(&[], 0).is_err());
    }

    #[test]
    fn budgeted_sort_spills_and_matches_in_memory() {
        // 8 chunks of 64 rows with wrapped duplicate-heavy keys; stability
        // observable through the value column.
        let mut parts = Vec::new();
        for c in 0..8i64 {
            let keys: Vec<i64> = (0..64).map(|i| (i * 7 + c) % 23).collect();
            let vals: Vec<f64> =
                (0..64).map(|i| (c * 64 + i) as f64).collect();
            parts.push(table(keys, vals));
        }
        let input = ChunkedTable::from_tables(parts).unwrap();
        let expect = sort_table(&input.compact(), SortKey::asc(0)).unwrap();
        let total = input.byte_size() as u64;
        for frac in [4u64, 16] {
            let budget = MemoryBudget::new((total / frac).max(1));
            let out =
                sort_table_budgeted(&input, SortKey::asc(0), &budget).unwrap();
            assert!(
                out.chunk_list().iter().any(Chunk::is_spilled),
                "budget {total}/{frac} must force spilling"
            );
            assert_eq!(out.compact(), expect, "frac={frac}");
            // Spilled output chunks carry ascending key ranges.
            let ranges: Vec<(i64, i64)> = out
                .chunk_list()
                .iter()
                .filter_map(Chunk::key_range)
                .collect();
            assert!(ranges.windows(2).all(|w| w[0].1 <= w[1].0));
        }
        // Unbounded: stays in RAM, same output.
        let out = sort_table_budgeted(
            &input,
            SortKey::asc(0),
            &MemoryBudget::unbounded(),
        )
        .unwrap();
        assert!(out.chunk_list().iter().all(|c| !c.is_spilled()));
        assert_eq!(out.compact(), expect);
    }

    #[test]
    fn budgeted_sort_edge_shapes() {
        // Empty input.
        let empty = ChunkedTable::empty(
            table(vec![], vec![]).schema().clone(),
        );
        let b = MemoryBudget::new(1);
        let out = sort_table_budgeted(&empty, SortKey::asc(0), &b).unwrap();
        assert_eq!(out.num_rows(), 0);
        // All-equal keys: stability across runs (values keep input order).
        let parts: Vec<Table> = (0..4)
            .map(|c| {
                table(
                    vec![5i64; 32],
                    (0..32).map(|i| (c * 32 + i) as f64).collect(),
                )
            })
            .collect();
        let input = ChunkedTable::from_tables(parts).unwrap();
        let budget = MemoryBudget::new(input.byte_size() as u64 / 8);
        let out = sort_table_budgeted(&input, SortKey::asc(0), &budget).unwrap();
        let vals: Vec<f64> =
            out.compact().column(1).as_f64().unwrap().to_vec();
        let expect: Vec<f64> = (0..128).map(|i| i as f64).collect();
        assert_eq!(vals, expect, "equal keys must keep input order");
        // Descending key: falls back to in-memory, still correct.
        let desc =
            sort_table_budgeted(&input, SortKey::desc(0), &budget).unwrap();
        assert_eq!(
            desc.compact(),
            sort_table(&input.compact(), SortKey::desc(0)).unwrap()
        );
        // Errors propagate.
        assert!(sort_table_budgeted(&input, SortKey::asc(9), &b).is_err());
    }

    #[test]
    fn budgeted_sort_peak_stays_under_ceiling() {
        let mut parts = Vec::new();
        for c in 0..16i64 {
            let keys: Vec<i64> = (0..128).map(|i| (i * 31 + c * 7) % 257).collect();
            parts.push(table(keys, vec![0.25; 128]));
        }
        let input = ChunkedTable::from_tables(parts).unwrap();
        let chunk_bytes = input.chunk_list()[0].byte_size() as u64;
        let limit = input.byte_size() as u64 / 4;
        let budget = MemoryBudget::new(limit);
        let out = sort_table_budgeted(&input, SortKey::asc(0), &budget).unwrap();
        assert_eq!(out.num_rows(), input.num_rows());
        // Ceiling: budget + slack (run batching may overshoot by up to
        // 2x one input chunk: the chunk that trips the flush plus its
        // sorted copy). Sized so the MIN_RUN_BYTES floor equals
        // limit / 2 here and MIN_BLOCK_BYTES doesn't bind.
        assert!(
            budget.peak() <= limit + 2 * chunk_bytes,
            "peak {} > limit {} + 2*chunk {}",
            budget.peak(),
            limit,
            chunk_bytes
        );
    }

    #[test]
    fn prop_sort_is_permutation_and_sorted() {
        testkit::check("sort perm+sorted", 32, |rng| {
            let n = rng.gen_range(200) as usize;
            let keys: Vec<i64> = (0..n).map(|_| rng.gen_i64(-50, 50)).collect();
            let vals: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let t = table(keys, vals);
            if n == 0 {
                return;
            }
            let s = sort_table(&t, SortKey::asc(0)).unwrap();
            assert!(is_sorted_by_key(&s, 0).unwrap());
            assert_eq!(s.multiset_fingerprint(), t.multiset_fingerprint());
        });
    }

    #[test]
    fn prop_merge_equals_concat_sort() {
        testkit::check("merge == sort(concat)", 24, |rng| {
            let parts: Vec<Table> = (0..3)
                .map(|_| {
                    let n = rng.gen_range(40) as usize;
                    let mut keys: Vec<i64> =
                        (0..n).map(|_| rng.gen_i64(0, 30)).collect();
                    keys.sort_unstable();
                    table(keys, vec![0.0; n])
                })
                .collect();
            let merged = merge_sorted(&parts, 0).unwrap();
            let concat = Table::concat(&parts).unwrap();
            let sorted = sort_table(&concat, SortKey::asc(0)).unwrap();
            assert_eq!(
                merged.column(0).as_i64().unwrap(),
                sorted.column(0).as_i64().unwrap()
            );
        });
    }
}
