//! Local sort and k-way merge.

use crate::df::{Column, Table, Utf8Builder};
use crate::error::{Error, Result};

/// A sort key: column index + direction.
#[derive(Clone, Copy, Debug)]
pub struct SortKey {
    pub col: usize,
    pub ascending: bool,
}

impl SortKey {
    pub fn asc(col: usize) -> SortKey {
        SortKey { col, ascending: true }
    }
    pub fn desc(col: usize) -> SortKey {
        SortKey { col, ascending: false }
    }
}

fn cmp_values(c: &Column, a: usize, b: usize) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match c {
        Column::Int64(v) => v[a].cmp(&v[b]),
        Column::Float64(v) => v[a].partial_cmp(&v[b]).unwrap_or(Ordering::Equal),
        Column::Utf8(v) => v.get(a).cmp(v.get(b)),
        Column::Bool(v) => v[a].cmp(&v[b]),
    }
}

/// Stable sort by a single int64/utf8/float column.
pub fn sort_table(t: &Table, key: SortKey) -> Result<Table> {
    sort_table_multi(t, &[key])
}

/// Stable sort by multiple keys (lexicographic).
pub fn sort_table_multi(t: &Table, keys: &[SortKey]) -> Result<Table> {
    if keys.is_empty() {
        return Err(Error::DataFrame("sort with zero keys".into()));
    }
    for k in keys {
        if k.col >= t.num_columns() {
            return Err(Error::DataFrame(format!(
                "sort key column {} out of range ({} columns)",
                k.col,
                t.num_columns()
            )));
        }
    }
    // Fast path (perf pass, EXPERIMENTS.md §Perf): single ascending int64
    // key — sort (key, row) pairs contiguously instead of indirecting into
    // the column per comparison. Pairing with the row index keeps it
    // stable under `sort_unstable` (all pairs distinct).
    if let [k] = keys {
        if k.ascending {
            if let Column::Int64(v) = t.column(k.col) {
                let mut pairs: Vec<(i64, u32)> = v
                    .iter()
                    .enumerate()
                    .map(|(i, &key)| (key, i as u32))
                    .collect();
                pairs.sort_unstable();
                let idx: Vec<usize> =
                    pairs.into_iter().map(|(_, i)| i as usize).collect();
                return Ok(t.take(&idx));
            }
        }
    }
    let mut idx: Vec<usize> = (0..t.num_rows()).collect();
    idx.sort_by(|&a, &b| {
        for k in keys {
            let ord = cmp_values(t.column(k.col), a, b);
            let ord = if k.ascending { ord } else { ord.reverse() };
            if ord != std::cmp::Ordering::Equal {
                return ord;
            }
        }
        std::cmp::Ordering::Equal
    });
    Ok(t.take(&idx))
}

/// Is the table sorted ascending on the given int64 column?
pub fn is_sorted_by_key(t: &Table, col: usize) -> Result<bool> {
    let keys = t.column(col).as_i64()?;
    Ok(keys.windows(2).all(|w| w[0] <= w[1]))
}

/// K-way merge of tables each already sorted ascending on int64 `col`
/// (the merge phase of distributed sample-sort).
pub fn merge_sorted(parts: &[Table], col: usize) -> Result<Table> {
    if parts.is_empty() {
        return Err(Error::DataFrame("merge of zero tables".into()));
    }
    // Binary-heap k-way merge over (key, part, row) cursors.
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    for p in parts {
        if p.schema() != parts[0].schema() {
            return Err(Error::DataFrame(format!(
                "merge schema mismatch: {} vs {}",
                p.schema(),
                parts[0].schema()
            )));
        }
    }
    let keys: Vec<&[i64]> = parts
        .iter()
        .map(|p| p.column(col).as_i64())
        .collect::<Result<_>>()?;
    let total: usize = parts.iter().map(|p| p.num_rows()).sum();

    let mut heap: BinaryHeap<Reverse<(i64, usize, usize)>> = BinaryHeap::new();
    for (pi, k) in keys.iter().enumerate() {
        if !k.is_empty() {
            heap.push(Reverse((k[0], pi, 0)));
        }
    }
    // Global interleave order as (part, row) cursors.
    let mut order: Vec<(u32, u32)> = Vec::with_capacity(total);
    while let Some(Reverse((_, pi, ri))) = heap.pop() {
        order.push((pi as u32, ri as u32));
        let next = ri + 1;
        if next < keys[pi].len() {
            heap.push(Reverse((keys[pi][next], pi, next)));
        }
    }

    // Columnar gather straight from the order vector (perf pass,
    // EXPERIMENTS.md §Perf: replaces a row-at-a-time slice+extend stitch
    // that allocated one Column per row).
    let ncols = parts[0].num_columns();
    let mut out_cols: Vec<Column> = Vec::with_capacity(ncols);
    for j in 0..ncols {
        let col = match parts[0].column(j) {
            Column::Int64(_) => {
                let srcs: Vec<&[i64]> =
                    parts.iter().map(|p| p.column(j).as_i64().unwrap()).collect();
                let mut v = Vec::with_capacity(total);
                for &(pi, ri) in &order {
                    v.push(srcs[pi as usize][ri as usize]);
                }
                Column::from_i64(v)
            }
            Column::Float64(_) => {
                let srcs: Vec<&[f64]> =
                    parts.iter().map(|p| p.column(j).as_f64().unwrap()).collect();
                let mut v = Vec::with_capacity(total);
                for &(pi, ri) in &order {
                    v.push(srcs[pi as usize][ri as usize]);
                }
                Column::from_f64(v)
            }
            Column::Utf8(_) => {
                // Gather straight into one output arena.
                let srcs: Vec<&crate::df::Utf8Buffer> = parts
                    .iter()
                    .map(|p| p.column(j).as_utf8().unwrap())
                    .collect();
                let bytes: usize = srcs.iter().map(|s| s.str_bytes()).sum();
                let mut b = Utf8Builder::with_capacity(total, bytes);
                for &(pi, ri) in &order {
                    b.push(srcs[pi as usize].get(ri as usize));
                }
                Column::Utf8(b.finish())
            }
            Column::Bool(_) => {
                let mut v = Vec::with_capacity(total);
                for &(pi, ri) in &order {
                    match parts[pi as usize].column(j) {
                        Column::Bool(b) => v.push(b[ri as usize]),
                        _ => unreachable!("schemas validated identical"),
                    }
                }
                Column::from_bool(v)
            }
        };
        out_cols.push(col);
    }
    Table::new(parts[0].schema().clone(), out_cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::df::{DataType, Schema};
    use crate::util::testkit;

    fn table(keys: Vec<i64>, vals: Vec<f64>) -> Table {
        Table::new(
            Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)]),
            vec![Column::from_i64(keys), Column::from_f64(vals)],
        )
        .unwrap()
    }

    #[test]
    fn sorts_ascending_and_descending() {
        let t = table(vec![3, 1, 2], vec![0.3, 0.1, 0.2]);
        let asc = sort_table(&t, SortKey::asc(0)).unwrap();
        assert_eq!(asc.column(0).as_i64().unwrap(), &[1, 2, 3]);
        assert_eq!(asc.column(1).as_f64().unwrap(), &[0.1, 0.2, 0.3]);
        let desc = sort_table(&t, SortKey::desc(0)).unwrap();
        assert_eq!(desc.column(0).as_i64().unwrap(), &[3, 2, 1]);
    }

    #[test]
    fn multi_key_breaks_ties() {
        let t = Table::new(
            Schema::of(&[("a", DataType::Int64), ("b", DataType::Int64)]),
            vec![
                Column::from_i64(vec![1, 1, 0]),
                Column::from_i64(vec![5, 3, 9]),
            ],
        )
        .unwrap();
        let s = sort_table_multi(&t, &[SortKey::asc(0), SortKey::desc(1)]).unwrap();
        assert_eq!(s.column(0).as_i64().unwrap(), &[0, 1, 1]);
        assert_eq!(s.column(1).as_i64().unwrap(), &[9, 5, 3]);
    }

    #[test]
    fn stability() {
        // Equal keys keep original relative order of the value column.
        let t = table(vec![1, 1, 1], vec![0.1, 0.2, 0.3]);
        let s = sort_table(&t, SortKey::asc(0)).unwrap();
        assert_eq!(s.column(1).as_f64().unwrap(), &[0.1, 0.2, 0.3]);
    }

    #[test]
    fn merge_matches_global_sort() {
        let a = table(vec![1, 4, 9], vec![0.0; 3]);
        let b = table(vec![2, 3, 10], vec![0.0; 3]);
        let c = table(vec![], vec![]);
        let m = merge_sorted(&[a, b, c], 0).unwrap();
        assert_eq!(m.column(0).as_i64().unwrap(), &[1, 2, 3, 4, 9, 10]);
        assert!(is_sorted_by_key(&m, 0).unwrap());
    }

    #[test]
    fn errors_on_misuse() {
        let t = table(vec![1], vec![0.0]);
        assert!(sort_table_multi(&t, &[]).is_err());
        assert!(sort_table(&t, SortKey::asc(9)).is_err());
        assert!(merge_sorted(&[], 0).is_err());
    }

    #[test]
    fn prop_sort_is_permutation_and_sorted() {
        testkit::check("sort perm+sorted", 32, |rng| {
            let n = rng.gen_range(200) as usize;
            let keys: Vec<i64> = (0..n).map(|_| rng.gen_i64(-50, 50)).collect();
            let vals: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let t = table(keys, vals);
            if n == 0 {
                return;
            }
            let s = sort_table(&t, SortKey::asc(0)).unwrap();
            assert!(is_sorted_by_key(&s, 0).unwrap());
            assert_eq!(s.multiset_fingerprint(), t.multiset_fingerprint());
        });
    }

    #[test]
    fn prop_merge_equals_concat_sort() {
        testkit::check("merge == sort(concat)", 24, |rng| {
            let parts: Vec<Table> = (0..3)
                .map(|_| {
                    let n = rng.gen_range(40) as usize;
                    let mut keys: Vec<i64> =
                        (0..n).map(|_| rng.gen_i64(0, 30)).collect();
                    keys.sort_unstable();
                    table(keys, vec![0.0; n])
                })
                .collect();
            let merged = merge_sorted(&parts, 0).unwrap();
            let concat = Table::concat(&parts).unwrap();
            let sorted = sort_table(&concat, SortKey::asc(0)).unwrap();
            assert_eq!(
                merged.column(0).as_i64().unwrap(),
                sorted.column(0).as_i64().unwrap()
            );
        });
    }
}
