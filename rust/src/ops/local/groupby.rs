//! Group-by aggregation on an int64 key column.

use std::collections::HashMap;

use crate::df::{Column, DataType, Schema, Table};
use crate::error::{Error, Result};

/// Aggregations over a float64 value column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggFn {
    Sum,
    Count,
    Min,
    Max,
    Mean,
}

impl AggFn {
    /// Column-suffix name of the aggregation (`sum`, `count`, ...).
    pub fn name(&self) -> &'static str {
        match self {
            AggFn::Sum => "sum",
            AggFn::Count => "count",
            AggFn::Min => "min",
            AggFn::Max => "max",
            AggFn::Mean => "mean",
        }
    }
}

/// `SELECT key, agg(val) GROUP BY key` — output sorted by key for
/// determinism.
pub fn groupby_agg(
    t: &Table,
    key_col: usize,
    val_col: usize,
    agg: AggFn,
) -> Result<Table> {
    let keys = t.column(key_col).as_i64()?;
    let vals = t.column(val_col).as_f64()?;
    if keys.len() != vals.len() {
        return Err(Error::DataFrame("ragged groupby input".into()));
    }

    #[derive(Default, Clone, Copy)]
    struct Acc {
        sum: f64,
        count: u64,
        min: f64,
        max: f64,
    }
    let mut groups: HashMap<i64, Acc, crate::util::hash::SplitMixBuild> =
        HashMap::with_capacity_and_hasher(
            keys.len().min(1 << 16),
            crate::util::hash::SplitMixBuild,
        );
    for (&k, &v) in keys.iter().zip(vals) {
        let acc = groups.entry(k).or_insert(Acc {
            sum: 0.0,
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        });
        acc.sum += v;
        acc.count += 1;
        acc.min = acc.min.min(v);
        acc.max = acc.max.max(v);
    }

    let mut out_keys: Vec<i64> = groups.keys().copied().collect();
    out_keys.sort_unstable();
    let out_vals: Vec<f64> = out_keys
        .iter()
        .map(|k| {
            let a = groups[k];
            match agg {
                AggFn::Sum => a.sum,
                AggFn::Count => a.count as f64,
                AggFn::Min => a.min,
                AggFn::Max => a.max,
                AggFn::Mean => a.sum / a.count as f64,
            }
        })
        .collect();

    let key_name = &t.schema().field(key_col).name;
    let val_name = &t.schema().field(val_col).name;
    Table::new(
        Schema::of(&[
            (key_name, DataType::Int64),
            (&format!("{val_name}_{}", agg.name()), DataType::Float64),
        ]),
        vec![Column::from_i64(out_keys), Column::from_f64(out_vals)],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit;

    fn t(keys: Vec<i64>, vals: Vec<f64>) -> Table {
        Table::new(
            Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)]),
            vec![Column::from_i64(keys), Column::from_f64(vals)],
        )
        .unwrap()
    }

    #[test]
    fn all_aggs() {
        let tbl = t(vec![1, 2, 1, 2, 1], vec![1.0, 10.0, 2.0, 20.0, 3.0]);
        let sum = groupby_agg(&tbl, 0, 1, AggFn::Sum).unwrap();
        assert_eq!(sum.column(0).as_i64().unwrap(), &[1, 2]);
        assert_eq!(sum.column(1).as_f64().unwrap(), &[6.0, 30.0]);
        let cnt = groupby_agg(&tbl, 0, 1, AggFn::Count).unwrap();
        assert_eq!(cnt.column(1).as_f64().unwrap(), &[3.0, 2.0]);
        let min = groupby_agg(&tbl, 0, 1, AggFn::Min).unwrap();
        assert_eq!(min.column(1).as_f64().unwrap(), &[1.0, 10.0]);
        let max = groupby_agg(&tbl, 0, 1, AggFn::Max).unwrap();
        assert_eq!(max.column(1).as_f64().unwrap(), &[3.0, 20.0]);
        let mean = groupby_agg(&tbl, 0, 1, AggFn::Mean).unwrap();
        assert_eq!(mean.column(1).as_f64().unwrap(), &[2.0, 15.0]);
    }

    #[test]
    fn schema_names() {
        let tbl = t(vec![1], vec![1.0]);
        let g = groupby_agg(&tbl, 0, 1, AggFn::Sum).unwrap();
        assert_eq!(g.schema().field(1).name, "val_sum");
    }

    #[test]
    fn empty_input() {
        let tbl = t(vec![], vec![]);
        let g = groupby_agg(&tbl, 0, 1, AggFn::Sum).unwrap();
        assert_eq!(g.num_rows(), 0);
    }

    #[test]
    fn prop_sum_preserved() {
        testkit::check("groupby sum == total sum", 32, |rng| {
            let n = rng.gen_range(100) as usize;
            let keys: Vec<i64> = (0..n).map(|_| rng.gen_i64(0, 10)).collect();
            let vals: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let total: f64 = vals.iter().sum();
            let tbl = t(keys, vals);
            if n == 0 {
                return;
            }
            let g = groupby_agg(&tbl, 0, 1, AggFn::Sum).unwrap();
            let gsum: f64 = g.column(1).as_f64().unwrap().iter().sum();
            assert!((gsum - total).abs() < 1e-9);
        });
    }
}
