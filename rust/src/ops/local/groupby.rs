//! Group-by aggregation on an int64 key column.
//!
//! The aggregation runs over a flat [`CsrIndex`] — rows are counted,
//! prefix-summed, and scattered into hash buckets, then each bucket is
//! aggregated in one sweep — instead of a `HashMap<i64, Acc>` (CSR perf
//! pass, EXPERIMENTS.md §Perf). The map-based build survives as
//! [`groupby_agg_hashmap`], the bench baseline and bit-identical oracle.

use std::collections::HashMap;

use crate::df::{Column, DataType, Schema, Table};
use crate::error::{Error, Result};
use crate::util::hash::CsrIndex;
use crate::util::pool::{self, ThreadPool};

use super::sort::{morsel_ranges, par_min_rows};

/// Aggregations over a float64 value column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggFn {
    Sum,
    Count,
    Min,
    Max,
    Mean,
}

impl AggFn {
    /// Column-suffix name of the aggregation (`sum`, `count`, ...).
    pub fn name(&self) -> &'static str {
        match self {
            AggFn::Sum => "sum",
            AggFn::Count => "count",
            AggFn::Min => "min",
            AggFn::Max => "max",
            AggFn::Mean => "mean",
        }
    }
}

/// Running accumulator for one group. Updates happen in ascending row
/// order on both the CSR and map paths, so float sums agree bit-for-bit.
#[derive(Clone, Copy)]
struct Acc {
    sum: f64,
    count: u64,
    min: f64,
    max: f64,
}

impl Acc {
    fn new() -> Acc {
        Acc { sum: 0.0, count: 0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    fn update(&mut self, v: f64) {
        self.sum += v;
        self.count += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    fn finish(&self, agg: AggFn) -> f64 {
        match agg {
            AggFn::Sum => self.sum,
            AggFn::Count => self.count as f64,
            AggFn::Min => self.min,
            AggFn::Max => self.max,
            AggFn::Mean => self.sum / self.count as f64,
        }
    }
}

fn agg_input<'a>(
    t: &'a Table,
    key_col: usize,
    val_col: usize,
) -> Result<(&'a [i64], &'a [f64])> {
    let keys = t.column(key_col).as_i64()?;
    let vals = t.column(val_col).as_f64()?;
    if keys.len() != vals.len() {
        return Err(Error::DataFrame("ragged groupby input".into()));
    }
    Ok((keys, vals))
}

/// Build the `(key, {val}_{agg})` output table from per-group results,
/// sorted by key for determinism.
fn agg_output(
    t: &Table,
    key_col: usize,
    val_col: usize,
    agg: AggFn,
    out_keys: Vec<i64>,
    out_vals: Vec<f64>,
) -> Result<Table> {
    let key_name = &t.schema().field(key_col).name;
    let val_name = &t.schema().field(val_col).name;
    Table::new(
        Schema::of(&[
            (key_name, DataType::Int64),
            (&format!("{val_name}_{}", agg.name()), DataType::Float64),
        ]),
        vec![Column::from_i64(out_keys), Column::from_f64(out_vals)],
    )
}

/// `SELECT key, agg(val) GROUP BY key` — output sorted by key for
/// determinism.
///
/// Flat CSR aggregation: rows are scattered into hash buckets by a
/// [`CsrIndex`] (two allocations), then each bucket is swept once,
/// accumulating into dense group vectors. With load factor <= 1 the
/// expected distinct-key scan per bucket is ~1 entry, so the whole
/// aggregation is dense array traffic with no per-key heap allocations.
pub fn groupby_agg(
    t: &Table,
    key_col: usize,
    val_col: usize,
    agg: AggFn,
) -> Result<Table> {
    let (keys, vals) = agg_input(t, key_col, val_col)?;
    if keys.len() >= u32::MAX as usize {
        // Row ids no longer fit the CSR index; the map path has no such
        // limit.
        return groupby_agg_hashmap(t, key_col, val_col, agg);
    }
    if keys.len() >= par_min_rows() && pool::parallelism() > 1 {
        return groupby_agg_par(t, key_col, val_col, agg, pool::global());
    }

    let index = CsrIndex::build(keys);
    let (gkeys, accs) =
        sweep_buckets(&index, keys, vals, 0, index.num_buckets());
    finish_groups(t, key_col, val_col, agg, gkeys, accs)
}

/// Aggregate buckets `lo..hi` of the CSR index in order, returning the
/// groups discovered (keys + accumulators) in first-seen order. Buckets
/// are independent — a key hashes to exactly one bucket — so the
/// sequential whole-table sweep is exactly the concatenation of any
/// partition of its bucket range.
fn sweep_buckets(
    index: &CsrIndex,
    keys: &[i64],
    vals: &[f64],
    lo: usize,
    hi: usize,
) -> (Vec<i64>, Vec<Acc>) {
    let mut gkeys: Vec<i64> = Vec::new();
    let mut accs: Vec<Acc> = Vec::new();
    for b in lo..hi {
        // Groups emitted for this bucket start here; distinct keys that
        // share the bucket are found by scanning only this tail.
        let bucket_groups = gkeys.len();
        for &row in index.bucket_rows(b) {
            let (k, v) = (keys[row as usize], vals[row as usize]);
            match gkeys[bucket_groups..].iter().position(|&g| g == k) {
                Some(g) => accs[bucket_groups + g].update(v),
                None => {
                    let mut acc = Acc::new();
                    acc.update(v);
                    gkeys.push(k);
                    accs.push(acc);
                }
            }
        }
    }
    (gkeys, accs)
}

/// Deterministic output order: permute groups by key (keys are globally
/// distinct — one bucket per key — so the unstable sort is total).
fn finish_groups(
    t: &Table,
    key_col: usize,
    val_col: usize,
    agg: AggFn,
    gkeys: Vec<i64>,
    accs: Vec<Acc>,
) -> Result<Table> {
    let mut perm: Vec<u32> = (0..gkeys.len() as u32).collect();
    perm.sort_unstable_by_key(|&g| gkeys[g as usize]);
    let out_keys: Vec<i64> = perm.iter().map(|&g| gkeys[g as usize]).collect();
    let out_vals: Vec<f64> =
        perm.iter().map(|&g| accs[g as usize].finish(agg)).collect();
    agg_output(t, key_col, val_col, agg, out_keys, out_vals)
}

/// [`groupby_agg`] on an explicit thread pool: parallel CSR build, then
/// contiguous **bucket-range** morsels swept concurrently.
///
/// **Determinism:** each bucket's rows are visited in ascending row
/// order (CSR scatter stability), so per-group accumulation — float sums
/// included — is bit-identical to the sequential sweep; and since every
/// key lives in exactly one bucket, concatenating per-morsel group lists
/// in morsel order reproduces the sequential first-seen group order for
/// any split. The final by-key permutation is over globally distinct
/// keys, hence fully deterministic.
pub fn groupby_agg_par(
    t: &Table,
    key_col: usize,
    val_col: usize,
    agg: AggFn,
    pool: &ThreadPool,
) -> Result<Table> {
    let (keys, vals) = agg_input(t, key_col, val_col)?;
    if keys.len() >= u32::MAX as usize {
        return groupby_agg_hashmap(t, key_col, val_col, agg);
    }
    let index = CsrIndex::build_par(keys, pool);
    let nt = pool.size().min(keys.len() / par_min_rows()).max(1);
    let (gkeys, accs) = if nt <= 1 {
        sweep_buckets(&index, keys, vals, 0, index.num_buckets())
    } else {
        // 4 morsels per worker: bucket ranges carry uneven row counts
        // under skew; finer morsels rebalance at no determinism cost.
        let morsels = morsel_ranges(index.num_buckets(), nt * 4);
        let parts = pool.run_indexed(morsels.len(), |m| {
            let (lo, hi) = morsels[m];
            sweep_buckets(&index, keys, vals, lo, hi)
        });
        let total = parts.iter().map(|(g, _)| g.len()).sum();
        let mut gkeys: Vec<i64> = Vec::with_capacity(total);
        let mut accs: Vec<Acc> = Vec::with_capacity(total);
        for (g, a) in parts {
            gkeys.extend_from_slice(&g);
            accs.extend_from_slice(&a);
        }
        (gkeys, accs)
    };
    finish_groups(t, key_col, val_col, agg, gkeys, accs)
}

/// Pre-CSR groupby: `HashMap<i64, Acc>` accumulation. Kept as the
/// `kernel_hotpaths` bench baseline and bit-identical oracle for
/// [`groupby_agg`] (both accumulate each group in ascending row order, so
/// even float sums match exactly).
pub fn groupby_agg_hashmap(
    t: &Table,
    key_col: usize,
    val_col: usize,
    agg: AggFn,
) -> Result<Table> {
    let (keys, vals) = agg_input(t, key_col, val_col)?;

    let mut groups: HashMap<i64, Acc, crate::util::hash::SplitMixBuild> =
        HashMap::with_capacity_and_hasher(
            keys.len().min(1 << 16),
            crate::util::hash::SplitMixBuild,
        );
    for (&k, &v) in keys.iter().zip(vals) {
        groups.entry(k).or_insert_with(Acc::new).update(v);
    }

    let mut out_keys: Vec<i64> = groups.keys().copied().collect();
    out_keys.sort_unstable();
    let out_vals: Vec<f64> =
        out_keys.iter().map(|k| groups[k].finish(agg)).collect();
    agg_output(t, key_col, val_col, agg, out_keys, out_vals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit;

    fn t(keys: Vec<i64>, vals: Vec<f64>) -> Table {
        Table::new(
            Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)]),
            vec![Column::from_i64(keys), Column::from_f64(vals)],
        )
        .unwrap()
    }

    #[test]
    fn all_aggs() {
        let tbl = t(vec![1, 2, 1, 2, 1], vec![1.0, 10.0, 2.0, 20.0, 3.0]);
        let sum = groupby_agg(&tbl, 0, 1, AggFn::Sum).unwrap();
        assert_eq!(sum.column(0).as_i64().unwrap(), &[1, 2]);
        assert_eq!(sum.column(1).as_f64().unwrap(), &[6.0, 30.0]);
        let cnt = groupby_agg(&tbl, 0, 1, AggFn::Count).unwrap();
        assert_eq!(cnt.column(1).as_f64().unwrap(), &[3.0, 2.0]);
        let min = groupby_agg(&tbl, 0, 1, AggFn::Min).unwrap();
        assert_eq!(min.column(1).as_f64().unwrap(), &[1.0, 10.0]);
        let max = groupby_agg(&tbl, 0, 1, AggFn::Max).unwrap();
        assert_eq!(max.column(1).as_f64().unwrap(), &[3.0, 20.0]);
        let mean = groupby_agg(&tbl, 0, 1, AggFn::Mean).unwrap();
        assert_eq!(mean.column(1).as_f64().unwrap(), &[2.0, 15.0]);
    }

    #[test]
    fn schema_names() {
        let tbl = t(vec![1], vec![1.0]);
        let g = groupby_agg(&tbl, 0, 1, AggFn::Sum).unwrap();
        assert_eq!(g.schema().field(1).name, "val_sum");
    }

    #[test]
    fn empty_input() {
        let tbl = t(vec![], vec![]);
        let g = groupby_agg(&tbl, 0, 1, AggFn::Sum).unwrap();
        assert_eq!(g.num_rows(), 0);
        let g = groupby_agg_hashmap(&tbl, 0, 1, AggFn::Sum).unwrap();
        assert_eq!(g.num_rows(), 0);
    }

    #[test]
    fn prop_csr_groupby_is_bit_identical_to_hashmap() {
        // Same groups, same order, bit-identical float aggregates (both
        // paths accumulate each group in ascending row order).
        testkit::check("csr groupby == hashmap groupby", 24, |rng| {
            let n = rng.gen_range(150) as usize;
            let keys: Vec<i64> = (0..n).map(|_| rng.gen_i64(-8, 8)).collect();
            let vals: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let tbl = t(keys, vals);
            for agg in
                [AggFn::Sum, AggFn::Count, AggFn::Min, AggFn::Max, AggFn::Mean]
            {
                let csr = groupby_agg(&tbl, 0, 1, agg).unwrap();
                let legacy = groupby_agg_hashmap(&tbl, 0, 1, agg).unwrap();
                assert_eq!(csr, legacy, "{agg:?}");
            }
        });
    }

    #[test]
    fn parallel_groupby_is_bit_identical_to_sequential() {
        let pmr = par_min_rows();
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            for n in [0usize, 100, pmr, 3 * pmr] {
                // Irrational-step values make float-sum order observable.
                let keys: Vec<i64> =
                    (0..n as i64).map(|i| (i * 31) % 257).collect();
                let vals: Vec<f64> =
                    (0..n).map(|i| (i as f64) * 0.7 + 0.1).collect();
                let tbl = t(keys, vals);
                for agg in [
                    AggFn::Sum,
                    AggFn::Count,
                    AggFn::Min,
                    AggFn::Max,
                    AggFn::Mean,
                ] {
                    let par =
                        groupby_agg_par(&tbl, 0, 1, agg, &pool).unwrap();
                    let seq = groupby_agg_hashmap(&tbl, 0, 1, agg).unwrap();
                    assert_eq!(par, seq, "threads={threads} n={n} {agg:?}");
                }
            }
        }
    }

    #[test]
    fn prop_sum_preserved() {
        testkit::check("groupby sum == total sum", 32, |rng| {
            let n = rng.gen_range(100) as usize;
            let keys: Vec<i64> = (0..n).map(|_| rng.gen_i64(0, 10)).collect();
            let vals: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let total: f64 = vals.iter().sum();
            let tbl = t(keys, vals);
            if n == 0 {
                return;
            }
            let g = groupby_agg(&tbl, 0, 1, AggFn::Sum).unwrap();
            let gsum: f64 = g.column(1).as_f64().unwrap().iter().sum();
            assert!((gsum - total).abs() < 1e-9);
        });
    }
}
