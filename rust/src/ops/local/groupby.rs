//! Group-by aggregation on an int64 key column.
//!
//! The aggregation runs over a flat [`CsrIndex`] — rows are counted,
//! prefix-summed, and scattered into hash buckets, then each bucket is
//! aggregated in one sweep — instead of a `HashMap<i64, Acc>` (CSR perf
//! pass, EXPERIMENTS.md §Perf). The map-based build survives as
//! [`groupby_agg_hashmap`], the bench baseline and bit-identical oracle.

use std::collections::HashMap;

use crate::df::{Column, DataType, Schema, Table};
use crate::error::{Error, Result};
use crate::util::hash::CsrIndex;

/// Aggregations over a float64 value column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggFn {
    Sum,
    Count,
    Min,
    Max,
    Mean,
}

impl AggFn {
    /// Column-suffix name of the aggregation (`sum`, `count`, ...).
    pub fn name(&self) -> &'static str {
        match self {
            AggFn::Sum => "sum",
            AggFn::Count => "count",
            AggFn::Min => "min",
            AggFn::Max => "max",
            AggFn::Mean => "mean",
        }
    }
}

/// Running accumulator for one group. Updates happen in ascending row
/// order on both the CSR and map paths, so float sums agree bit-for-bit.
#[derive(Clone, Copy)]
struct Acc {
    sum: f64,
    count: u64,
    min: f64,
    max: f64,
}

impl Acc {
    fn new() -> Acc {
        Acc { sum: 0.0, count: 0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    fn update(&mut self, v: f64) {
        self.sum += v;
        self.count += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    fn finish(&self, agg: AggFn) -> f64 {
        match agg {
            AggFn::Sum => self.sum,
            AggFn::Count => self.count as f64,
            AggFn::Min => self.min,
            AggFn::Max => self.max,
            AggFn::Mean => self.sum / self.count as f64,
        }
    }
}

fn agg_input<'a>(
    t: &'a Table,
    key_col: usize,
    val_col: usize,
) -> Result<(&'a [i64], &'a [f64])> {
    let keys = t.column(key_col).as_i64()?;
    let vals = t.column(val_col).as_f64()?;
    if keys.len() != vals.len() {
        return Err(Error::DataFrame("ragged groupby input".into()));
    }
    Ok((keys, vals))
}

/// Build the `(key, {val}_{agg})` output table from per-group results,
/// sorted by key for determinism.
fn agg_output(
    t: &Table,
    key_col: usize,
    val_col: usize,
    agg: AggFn,
    out_keys: Vec<i64>,
    out_vals: Vec<f64>,
) -> Result<Table> {
    let key_name = &t.schema().field(key_col).name;
    let val_name = &t.schema().field(val_col).name;
    Table::new(
        Schema::of(&[
            (key_name, DataType::Int64),
            (&format!("{val_name}_{}", agg.name()), DataType::Float64),
        ]),
        vec![Column::from_i64(out_keys), Column::from_f64(out_vals)],
    )
}

/// `SELECT key, agg(val) GROUP BY key` — output sorted by key for
/// determinism.
///
/// Flat CSR aggregation: rows are scattered into hash buckets by a
/// [`CsrIndex`] (two allocations), then each bucket is swept once,
/// accumulating into dense group vectors. With load factor <= 1 the
/// expected distinct-key scan per bucket is ~1 entry, so the whole
/// aggregation is dense array traffic with no per-key heap allocations.
pub fn groupby_agg(
    t: &Table,
    key_col: usize,
    val_col: usize,
    agg: AggFn,
) -> Result<Table> {
    let (keys, vals) = agg_input(t, key_col, val_col)?;
    if keys.len() >= u32::MAX as usize {
        // Row ids no longer fit the CSR index; the map path has no such
        // limit.
        return groupby_agg_hashmap(t, key_col, val_col, agg);
    }

    let index = CsrIndex::build(keys);
    let mut gkeys: Vec<i64> = Vec::new();
    let mut accs: Vec<Acc> = Vec::new();
    for b in 0..index.num_buckets() {
        // Groups emitted for this bucket start here; distinct keys that
        // share the bucket are found by scanning only this tail.
        let bucket_groups = gkeys.len();
        for &row in index.bucket_rows(b) {
            let (k, v) = (keys[row as usize], vals[row as usize]);
            match gkeys[bucket_groups..].iter().position(|&g| g == k) {
                Some(g) => accs[bucket_groups + g].update(v),
                None => {
                    let mut acc = Acc::new();
                    acc.update(v);
                    gkeys.push(k);
                    accs.push(acc);
                }
            }
        }
    }

    // Deterministic output order: permute groups by key.
    let mut perm: Vec<u32> = (0..gkeys.len() as u32).collect();
    perm.sort_unstable_by_key(|&g| gkeys[g as usize]);
    let out_keys: Vec<i64> = perm.iter().map(|&g| gkeys[g as usize]).collect();
    let out_vals: Vec<f64> =
        perm.iter().map(|&g| accs[g as usize].finish(agg)).collect();
    agg_output(t, key_col, val_col, agg, out_keys, out_vals)
}

/// Pre-CSR groupby: `HashMap<i64, Acc>` accumulation. Kept as the
/// `kernel_hotpaths` bench baseline and bit-identical oracle for
/// [`groupby_agg`] (both accumulate each group in ascending row order, so
/// even float sums match exactly).
pub fn groupby_agg_hashmap(
    t: &Table,
    key_col: usize,
    val_col: usize,
    agg: AggFn,
) -> Result<Table> {
    let (keys, vals) = agg_input(t, key_col, val_col)?;

    let mut groups: HashMap<i64, Acc, crate::util::hash::SplitMixBuild> =
        HashMap::with_capacity_and_hasher(
            keys.len().min(1 << 16),
            crate::util::hash::SplitMixBuild,
        );
    for (&k, &v) in keys.iter().zip(vals) {
        groups.entry(k).or_insert_with(Acc::new).update(v);
    }

    let mut out_keys: Vec<i64> = groups.keys().copied().collect();
    out_keys.sort_unstable();
    let out_vals: Vec<f64> =
        out_keys.iter().map(|k| groups[k].finish(agg)).collect();
    agg_output(t, key_col, val_col, agg, out_keys, out_vals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit;

    fn t(keys: Vec<i64>, vals: Vec<f64>) -> Table {
        Table::new(
            Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)]),
            vec![Column::from_i64(keys), Column::from_f64(vals)],
        )
        .unwrap()
    }

    #[test]
    fn all_aggs() {
        let tbl = t(vec![1, 2, 1, 2, 1], vec![1.0, 10.0, 2.0, 20.0, 3.0]);
        let sum = groupby_agg(&tbl, 0, 1, AggFn::Sum).unwrap();
        assert_eq!(sum.column(0).as_i64().unwrap(), &[1, 2]);
        assert_eq!(sum.column(1).as_f64().unwrap(), &[6.0, 30.0]);
        let cnt = groupby_agg(&tbl, 0, 1, AggFn::Count).unwrap();
        assert_eq!(cnt.column(1).as_f64().unwrap(), &[3.0, 2.0]);
        let min = groupby_agg(&tbl, 0, 1, AggFn::Min).unwrap();
        assert_eq!(min.column(1).as_f64().unwrap(), &[1.0, 10.0]);
        let max = groupby_agg(&tbl, 0, 1, AggFn::Max).unwrap();
        assert_eq!(max.column(1).as_f64().unwrap(), &[3.0, 20.0]);
        let mean = groupby_agg(&tbl, 0, 1, AggFn::Mean).unwrap();
        assert_eq!(mean.column(1).as_f64().unwrap(), &[2.0, 15.0]);
    }

    #[test]
    fn schema_names() {
        let tbl = t(vec![1], vec![1.0]);
        let g = groupby_agg(&tbl, 0, 1, AggFn::Sum).unwrap();
        assert_eq!(g.schema().field(1).name, "val_sum");
    }

    #[test]
    fn empty_input() {
        let tbl = t(vec![], vec![]);
        let g = groupby_agg(&tbl, 0, 1, AggFn::Sum).unwrap();
        assert_eq!(g.num_rows(), 0);
        let g = groupby_agg_hashmap(&tbl, 0, 1, AggFn::Sum).unwrap();
        assert_eq!(g.num_rows(), 0);
    }

    #[test]
    fn prop_csr_groupby_is_bit_identical_to_hashmap() {
        // Same groups, same order, bit-identical float aggregates (both
        // paths accumulate each group in ascending row order).
        testkit::check("csr groupby == hashmap groupby", 24, |rng| {
            let n = rng.gen_range(150) as usize;
            let keys: Vec<i64> = (0..n).map(|_| rng.gen_i64(-8, 8)).collect();
            let vals: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let tbl = t(keys, vals);
            for agg in
                [AggFn::Sum, AggFn::Count, AggFn::Min, AggFn::Max, AggFn::Mean]
            {
                let csr = groupby_agg(&tbl, 0, 1, agg).unwrap();
                let legacy = groupby_agg_hashmap(&tbl, 0, 1, agg).unwrap();
                assert_eq!(csr, legacy, "{agg:?}");
            }
        });
    }

    #[test]
    fn prop_sum_preserved() {
        testkit::check("groupby sum == total sum", 32, |rng| {
            let n = rng.gen_range(100) as usize;
            let keys: Vec<i64> = (0..n).map(|_| rng.gen_i64(0, 10)).collect();
            let vals: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let total: f64 = vals.iter().sum();
            let tbl = t(keys, vals);
            if n == 0 {
                return;
            }
            let g = groupby_agg(&tbl, 0, 1, AggFn::Sum).unwrap();
            let gsum: f64 = g.column(1).as_f64().unwrap().iter().sum();
            assert!((gsum - total).abs() < 1e-9);
        });
    }
}
