//! Vectorized column compute: arithmetic, comparisons, casts, and the
//! zero-copy [`filter_view`] — the element-wise operator family of Cylon's
//! local-operator set (Fig 1).

use crate::df::{ChunkedTable, Column, DataType, Schema, Table};
use crate::error::{Error, Result};

/// Binary arithmetic over numeric columns (elementwise).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
}

impl BinOp {
    fn f64(self, a: f64, b: f64) -> f64 {
        match self {
            BinOp::Add => a + b,
            BinOp::Sub => a - b,
            BinOp::Mul => a * b,
            BinOp::Div => a / b,
        }
    }
    fn i64(self, a: i64, b: i64) -> i64 {
        match self {
            BinOp::Add => a.wrapping_add(b),
            BinOp::Sub => a.wrapping_sub(b),
            BinOp::Mul => a.wrapping_mul(b),
            BinOp::Div => {
                if b == 0 {
                    0
                } else {
                    a / b
                }
            }
        }
    }
}

/// Comparison predicates producing boolean masks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpOp {
    fn ord(self, o: std::cmp::Ordering) -> bool {
        use std::cmp::Ordering::*;
        match self {
            CmpOp::Eq => o == Equal,
            CmpOp::Ne => o != Equal,
            CmpOp::Lt => o == Less,
            CmpOp::Le => o != Greater,
            CmpOp::Gt => o == Greater,
            CmpOp::Ge => o != Less,
        }
    }
}

/// Elementwise `lhs op rhs` over two same-typed numeric columns.
pub fn binary_op(lhs: &Column, rhs: &Column, op: BinOp) -> Result<Column> {
    if lhs.len() != rhs.len() {
        return Err(Error::DataFrame("binary_op length mismatch".into()));
    }
    match (lhs, rhs) {
        (Column::Int64(a), Column::Int64(b)) => Ok(Column::from_i64(
            a.iter().zip(b.iter()).map(|(&x, &y)| op.i64(x, y)).collect(),
        )),
        (Column::Float64(a), Column::Float64(b)) => Ok(Column::from_f64(
            a.iter().zip(b.iter()).map(|(&x, &y)| op.f64(x, y)).collect(),
        )),
        (a, b) => Err(Error::DataFrame(format!(
            "binary_op on {}/{} is not supported",
            a.dtype(),
            b.dtype()
        ))),
    }
}

/// Elementwise `col op scalar` (int64 scalar broadcast).
pub fn scalar_op_i64(col: &Column, scalar: i64, op: BinOp) -> Result<Column> {
    match col {
        Column::Int64(a) => Ok(Column::from_i64(
            a.iter().map(|&x| op.i64(x, scalar)).collect(),
        )),
        other => Err(Error::DataFrame(format!(
            "scalar_op_i64 on {}",
            other.dtype()
        ))),
    }
}

/// Compare a column against an int64/float64 scalar, producing a mask that
/// feeds `Table::filter`.
pub fn compare_scalar(col: &Column, scalar: f64, op: CmpOp) -> Result<Vec<bool>> {
    match col {
        Column::Int64(v) => Ok(v
            .iter()
            .map(|&x| op.ord((x as f64).partial_cmp(&scalar).unwrap()))
            .collect()),
        Column::Float64(v) => Ok(v
            .iter()
            .map(|&x| {
                op.ord(x.partial_cmp(&scalar).unwrap_or(std::cmp::Ordering::Greater))
            })
            .collect()),
        other => Err(Error::DataFrame(format!(
            "compare_scalar on {}",
            other.dtype()
        ))),
    }
}

/// Cast a column to another numeric type.
pub fn cast(col: &Column, to: DataType) -> Result<Column> {
    match (col, to) {
        // Same-type cast: an Arc clone, no copy.
        (c, t) if c.dtype() == t => Ok(c.clone()),
        (Column::Int64(v), DataType::Float64) => {
            Ok(Column::from_f64(v.iter().map(|&x| x as f64).collect()))
        }
        (Column::Float64(v), DataType::Int64) => {
            Ok(Column::from_i64(v.iter().map(|&x| x as i64).collect()))
        }
        (Column::Bool(v), DataType::Int64) => {
            Ok(Column::from_i64(v.iter().map(|&x| x as i64).collect()))
        }
        (c, t) => Err(Error::DataFrame(format!(
            "cast {} -> {t} is not supported",
            c.dtype()
        ))),
    }
}

/// Zero-copy filter: keep rows where `mask` is true, returned as a
/// [`ChunkedTable`] of **maximal contiguous runs** of kept rows — every
/// chunk is an O(1) window ([`Table::slice`]) over `t`'s buffers, so the
/// filter itself materializes zero bytes no matter how selective it is.
/// The copy is deferred to `compact()`, exactly like shuffle receives and
/// gathered pipeline outputs; a consumer that can iterate chunks never
/// pays it. ([`Table::filter`] remains the eager, contiguous variant.)
pub fn filter_view(t: &Table, mask: &[bool]) -> Result<ChunkedTable> {
    if mask.len() != t.num_rows() {
        return Err(Error::DataFrame(format!(
            "filter_view mask length {} != row count {}",
            mask.len(),
            t.num_rows()
        )));
    }
    let mut out = ChunkedTable::empty(t.schema().clone());
    let mut run_start: Option<usize> = None;
    for (i, &keep) in mask.iter().enumerate() {
        match (keep, run_start) {
            (true, None) => run_start = Some(i),
            (false, Some(s)) => {
                out.push(t.slice(s, i - s)).expect("same schema");
                run_start = None;
            }
            _ => {}
        }
    }
    if let Some(s) = run_start {
        out.push(t.slice(s, mask.len() - s)).expect("same schema");
    }
    Ok(out)
}

/// Append a derived column to a table under `name`.
pub fn with_column(t: &Table, name: &str, col: Column) -> Result<Table> {
    if col.len() != t.num_rows() {
        return Err(Error::DataFrame(format!(
            "with_column length {} != {}",
            col.len(),
            t.num_rows()
        )));
    }
    let mut fields: Vec<_> = t.schema().fields().to_vec();
    fields.push(crate::df::Field::new(name, col.dtype()));
    let mut cols: Vec<Column> = t.columns().to_vec();
    cols.push(col);
    Table::new(Schema::new(fields), cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::df::{DataType, Schema};
    use crate::metrics::mem;

    fn table() -> Table {
        Table::new(
            Schema::of(&[("k", DataType::Int64), ("v", DataType::Float64)]),
            vec![
                Column::from_i64(vec![1, 2, 3, 4]),
                Column::from_f64(vec![0.5, 1.5, 2.5, 3.5]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn arithmetic() {
        let a = Column::from_i64(vec![10, 20]);
        let b = Column::from_i64(vec![3, 4]);
        assert_eq!(
            binary_op(&a, &b, BinOp::Add).unwrap(),
            Column::from_i64(vec![13, 24])
        );
        assert_eq!(
            binary_op(&a, &b, BinOp::Div).unwrap(),
            Column::from_i64(vec![3, 5])
        );
        let z = Column::from_i64(vec![0, 0]);
        assert_eq!(
            binary_op(&a, &z, BinOp::Div).unwrap(),
            Column::from_i64(vec![0, 0]) // div-by-zero -> 0 (null-free model)
        );
        assert!(binary_op(&a, &Column::from_f64(vec![1.0, 2.0]), BinOp::Add).is_err());
    }

    #[test]
    fn scalar_and_compare() {
        let t = table();
        let doubled = scalar_op_i64(t.column(0), 2, BinOp::Mul).unwrap();
        assert_eq!(doubled, Column::from_i64(vec![2, 4, 6, 8]));
        let mask = compare_scalar(t.column(1), 2.0, CmpOp::Gt).unwrap();
        assert_eq!(mask, vec![false, false, true, true]);
        let filtered = t.filter(&mask).unwrap();
        assert_eq!(filtered.num_rows(), 2);
    }

    #[test]
    fn casts() {
        let c = cast(&Column::from_i64(vec![1, 2]), DataType::Float64).unwrap();
        assert_eq!(c, Column::from_f64(vec![1.0, 2.0]));
        let back = cast(&c, DataType::Int64).unwrap();
        assert_eq!(back, Column::from_i64(vec![1, 2]));
        let b = cast(&Column::from_bool(vec![true, false]), DataType::Int64).unwrap();
        assert_eq!(b, Column::from_i64(vec![1, 0]));
        assert!(cast(&Column::from_utf8(&["x"]), DataType::Int64).is_err());
    }

    #[test]
    fn filter_view_is_zero_copy_and_matches_eager_filter() {
        let t = table();
        let mask = vec![true, false, true, true];
        let before = mem::thread();
        let v = filter_view(&t, &mask).unwrap();
        assert_eq!(
            mem::thread().since(before).materialized,
            0,
            "run-sliced filter must not copy rows"
        );
        // Two maximal runs: [0,1) and [2,4).
        assert_eq!(v.num_chunks(), 2);
        assert!(v.chunks()[0].column(0).shares_buffer(t.column(0)));
        assert_eq!(v.compact(), t.filter(&mask).unwrap());
        // Degenerate masks.
        assert_eq!(filter_view(&t, &[false; 4]).unwrap().num_rows(), 0);
        assert_eq!(filter_view(&t, &[true; 4]).unwrap().num_chunks(), 1);
        assert!(filter_view(&t, &[true]).is_err());
    }

    #[test]
    fn filter_view_on_chunked_view_stays_zero_copy() {
        // A chunked (gathered-shape) view filtered chunk-by-chunk — the
        // shape a piped consumer sees — materializes nothing either.
        let t = table();
        let ct = ChunkedTable::from_tables(vec![t.slice(0, 2), t.slice(2, 2)]).unwrap();
        let before = mem::thread();
        let mut out = ChunkedTable::empty(ct.schema().clone());
        for chunk in ct.chunks() {
            let mask = compare_scalar(chunk.column(0), 2.0, CmpOp::Ge).unwrap();
            for run in filter_view(chunk, &mask).unwrap().chunks() {
                out.push(run.clone()).unwrap();
            }
        }
        assert_eq!(mem::thread().since(before).materialized, 0);
        assert_eq!(out.num_rows(), 3);
        assert_eq!(out.compact().column(0).as_i64().unwrap(), &[2, 3, 4]);
    }

    #[test]
    fn derived_column() {
        let t = table();
        let sum = binary_op(
            &cast(t.column(0), DataType::Float64).unwrap(),
            t.column(1),
            BinOp::Add,
        )
        .unwrap();
        let t2 = with_column(&t, "k_plus_v", sum).unwrap();
        assert_eq!(t2.num_columns(), 3);
        assert_eq!(t2.schema().field(2).name, "k_plus_v");
        assert_eq!(
            t2.column(2).as_f64().unwrap(),
            &[1.5, 3.5, 5.5, 7.5]
        );
        assert!(with_column(&t, "bad", Column::from_i64(vec![1])).is_err());
    }
}
