//! Vectorized column compute: arithmetic, comparisons, casts, the
//! zero-copy [`filter_view`], and the [`Expr`](crate::plan::expr::Expr)
//! evaluator — the element-wise operator family of Cylon's local-operator
//! set (Fig 1).
//!
//! # Expression evaluation
//!
//! [`eval_expr`] walks a typed [`Expr`] bottom-up over one table chunk,
//! producing flat value buffers (one kernel dispatch per AST node, never
//! per row): column leaves are O(1) `Arc` clones, literals stay scalars
//! until a parent needs a buffer, and every arithmetic/comparison node
//! runs one tight loop over `&[i64]`/`&[f64]` slices with scalar
//! operands broadcast inside the loop. [`filter_view_expr`] applies a
//! boolean expression chunk-at-a-time over a
//! [`ChunkedTable`], keeping the kept rows as zero-copy windows.
//!
//! ## Numeric semantics
//!
//! * `Int64 op Int64` stays `Int64`; any `Float64` operand promotes the
//!   operation to `Float64` (int inputs are cast once per chunk, not per
//!   row).
//! * Int64 arithmetic wraps on overflow (`wrapping_add` family — the
//!   null-free analogue of Arrow's unchecked kernels); **division by
//!   zero is a real error** ([`Error::Compute`]), not a silent `0`.
//! * Float64 arithmetic follows IEEE 754: `x / 0.0` is `±inf`,
//!   `0.0 / 0.0` is `NaN`, and no float operation errors.
//! * Float comparisons are IEEE partial-order: every comparison with
//!   `NaN` is `false` except `!=`, which is `true`.
//! * `and`/`or` evaluate **eagerly** on both sides, except that a side
//!   is skipped when the other is uniformly decisive (an all-false left
//!   mask short-circuits `and`; all-true short-circuits `or`). Do not
//!   rely on them to guard the other side against evaluation errors such
//!   as division by zero.

use crate::df::{ChunkedTable, Column, DataType, Schema, Table};
use crate::error::{Error, Result};
use crate::plan::expr::{Expr, Scalar};
use crate::util::pool::{self, ThreadPool};

use super::sort::par_min_rows;

/// Binary arithmetic over numeric columns (elementwise).
///
/// Int64 uses wrapping semantics on overflow; Int64 division by zero is
/// [`Error::Compute`]. Float64 follows IEEE 754 (`±inf`/`NaN`, never an
/// error) — see the [module docs](self).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
}

impl BinOp {
    fn f64(self, a: f64, b: f64) -> f64 {
        match self {
            BinOp::Add => a + b,
            BinOp::Sub => a - b,
            BinOp::Mul => a * b,
            BinOp::Div => a / b,
        }
    }

    fn i64(self, a: i64, b: i64) -> Result<i64> {
        match self {
            BinOp::Add => Ok(a.wrapping_add(b)),
            BinOp::Sub => Ok(a.wrapping_sub(b)),
            BinOp::Mul => Ok(a.wrapping_mul(b)),
            BinOp::Div => {
                if b == 0 {
                    Err(Error::Compute(format!(
                        "int64 division by zero ({a} / 0)"
                    )))
                } else {
                    // wrapping_div: i64::MIN / -1 wraps instead of
                    // panicking, matching the wrapping add/sub/mul family.
                    Ok(a.wrapping_div(b))
                }
            }
        }
    }
}

/// Comparison predicates producing boolean masks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpOp {
    fn ord(self, o: std::cmp::Ordering) -> bool {
        use std::cmp::Ordering::*;
        match self {
            CmpOp::Eq => o == Equal,
            CmpOp::Ne => o != Equal,
            CmpOp::Lt => o == Less,
            CmpOp::Le => o != Greater,
            CmpOp::Gt => o == Greater,
            CmpOp::Ge => o != Less,
        }
    }

    /// IEEE partial-order float comparison: every comparison with `NaN`
    /// is `false` except [`CmpOp::Ne`], which is `true`.
    fn f64(self, a: f64, b: f64) -> bool {
        match self {
            CmpOp::Eq => a == b,
            CmpOp::Ne => a != b,
            CmpOp::Lt => a < b,
            CmpOp::Le => a <= b,
            CmpOp::Gt => a > b,
            CmpOp::Ge => a >= b,
        }
    }
}

/// Elementwise `lhs op rhs` over two same-typed numeric columns.
///
/// Int64 division by zero is [`Error::Compute`]; the float path follows
/// IEEE 754 and never errors (see the [module docs](self)).
pub fn binary_op(lhs: &Column, rhs: &Column, op: BinOp) -> Result<Column> {
    if lhs.len() != rhs.len() {
        return Err(Error::DataFrame("binary_op length mismatch".into()));
    }
    match (lhs, rhs) {
        (Column::Int64(a), Column::Int64(b)) => Ok(Column::from_i64(
            a.iter()
                .zip(b.iter())
                .map(|(&x, &y)| op.i64(x, y))
                .collect::<Result<Vec<i64>>>()?,
        )),
        (Column::Float64(a), Column::Float64(b)) => Ok(Column::from_f64(
            a.iter().zip(b.iter()).map(|(&x, &y)| op.f64(x, y)).collect(),
        )),
        (a, b) => Err(Error::DataFrame(format!(
            "binary_op on {}/{} is not supported",
            a.dtype(),
            b.dtype()
        ))),
    }
}

/// Elementwise `col op scalar` (int64 scalar broadcast). Division by
/// zero is [`Error::Compute`].
pub fn scalar_op_i64(col: &Column, scalar: i64, op: BinOp) -> Result<Column> {
    match col {
        Column::Int64(a) => Ok(Column::from_i64(
            a.iter()
                .map(|&x| op.i64(x, scalar))
                .collect::<Result<Vec<i64>>>()?,
        )),
        other => Err(Error::DataFrame(format!(
            "scalar_op_i64 on {}",
            other.dtype()
        ))),
    }
}

/// Compare a column against an int64/float64 scalar, producing a mask that
/// feeds `Table::filter`.
///
/// Legacy kernel (pre-`Expr`): floats compare via
/// `partial_cmp(..).unwrap_or(Greater)`, so a `NaN` cell counts as
/// *greater than* any scalar — unlike the IEEE semantics of the
/// expression evaluator ([`eval_expr`]), where every `NaN` comparison
/// except `!=` is `false`. Kept for the scalar-filter shim and existing
/// callers; new code should build an `Expr`.
pub fn compare_scalar(col: &Column, scalar: f64, op: CmpOp) -> Result<Vec<bool>> {
    match col {
        Column::Int64(v) => Ok(v
            .iter()
            .map(|&x| op.ord((x as f64).partial_cmp(&scalar).unwrap()))
            .collect()),
        Column::Float64(v) => Ok(v
            .iter()
            .map(|&x| {
                op.ord(x.partial_cmp(&scalar).unwrap_or(std::cmp::Ordering::Greater))
            })
            .collect()),
        other => Err(Error::DataFrame(format!(
            "compare_scalar on {}",
            other.dtype()
        ))),
    }
}

/// Cast a column to another numeric type.
pub fn cast(col: &Column, to: DataType) -> Result<Column> {
    match (col, to) {
        // Same-type cast: an Arc clone, no copy.
        (c, t) if c.dtype() == t => Ok(c.clone()),
        (Column::Int64(v), DataType::Float64) => {
            Ok(Column::from_f64(v.iter().map(|&x| x as f64).collect()))
        }
        (Column::Float64(v), DataType::Int64) => {
            Ok(Column::from_i64(v.iter().map(|&x| x as i64).collect()))
        }
        (Column::Bool(v), DataType::Int64) => {
            Ok(Column::from_i64(v.iter().map(|&x| x as i64).collect()))
        }
        (c, t) => Err(Error::DataFrame(format!(
            "cast {} -> {t} is not supported",
            c.dtype()
        ))),
    }
}

// ---------------------------------------------------------------------------
// Expression evaluator
// ---------------------------------------------------------------------------

/// One evaluated sub-expression: a column view or a still-unbroadcast
/// scalar (literals and scalar folds stay scalar until a parent kernel
/// needs elementwise access, so `col("a") * lit(2)` runs one
/// column-times-constant loop, not a constant-column materialization).
#[derive(Clone, Debug)]
enum Evaluated {
    Col(Column),
    I64(i64),
    F64(f64),
    Bool(bool),
}

impl Evaluated {
    fn type_name(&self) -> String {
        match self {
            Evaluated::Col(c) => c.dtype().to_string(),
            Evaluated::I64(_) => "int64".into(),
            Evaluated::F64(_) => "float64".into(),
            Evaluated::Bool(_) => "bool".into(),
        }
    }

    fn is_int(&self) -> bool {
        matches!(self, Evaluated::I64(_))
            || matches!(self, Evaluated::Col(c) if c.dtype() == DataType::Int64)
    }

    fn num_scalar(&self) -> Option<Scalar> {
        match self {
            Evaluated::I64(k) => Some(Scalar::Int64(*k)),
            Evaluated::F64(k) => Some(Scalar::Float64(*k)),
            _ => None,
        }
    }
}

/// Int64 operand: a flat slice or a broadcast constant.
enum SrcI<'a> {
    V(&'a [i64]),
    K(i64),
}

/// Float64 operand: a flat slice or a broadcast constant.
enum SrcF<'a> {
    V(&'a [f64]),
    K(f64),
}

/// Bool operand: a flat mask or a broadcast constant.
enum SrcB<'a> {
    M(&'a [bool]),
    K(bool),
}

fn i64_src(v: &Evaluated) -> Result<SrcI<'_>> {
    match v {
        Evaluated::Col(c) => Ok(SrcI::V(c.as_i64()?)),
        Evaluated::I64(k) => Ok(SrcI::K(*k)),
        other => Err(Error::Config(format!(
            "int64 operand required, got {}",
            other.type_name()
        ))),
    }
}

/// Float64 operand view; an int64 column is cast once per chunk into
/// `store` (the only materialization the promotion pays).
fn f64_src<'a>(v: &'a Evaluated, store: &'a mut Option<Column>) -> Result<SrcF<'a>> {
    match v {
        Evaluated::Col(c) => match c.dtype() {
            DataType::Float64 => Ok(SrcF::V(c.as_f64()?)),
            DataType::Int64 => {
                *store = Some(cast(c, DataType::Float64)?);
                Ok(SrcF::V(store.as_ref().expect("just stored").as_f64()?))
            }
            other => Err(Error::Config(format!(
                "numeric operand required, got {other} column"
            ))),
        },
        Evaluated::I64(k) => Ok(SrcF::K(*k as f64)),
        Evaluated::F64(k) => Ok(SrcF::K(*k)),
        Evaluated::Bool(_) => {
            Err(Error::Config("numeric operand required, got bool".into()))
        }
    }
}

fn bool_src(v: &Evaluated) -> Result<SrcB<'_>> {
    match v {
        Evaluated::Col(c) => Ok(SrcB::M(c.as_bool().map_err(|_| {
            Error::Config(format!(
                "bool operand required, got {} column",
                c.dtype()
            ))
        })?)),
        Evaluated::Bool(k) => Ok(SrcB::K(*k)),
        other => Err(Error::Config(format!(
            "bool operand required, got {}",
            other.type_name()
        ))),
    }
}

/// `f` over two int64 operands, monomorphized per operand shape so the
/// inner loops stay branch-free.
fn map2_i64<F: Fn(i64, i64) -> Result<i64>>(
    a: SrcI<'_>,
    b: SrcI<'_>,
    n: usize,
    f: F,
) -> Result<Vec<i64>> {
    match (a, b) {
        (SrcI::V(x), SrcI::V(y)) => {
            x.iter().zip(y).map(|(&p, &q)| f(p, q)).collect()
        }
        (SrcI::V(x), SrcI::K(q)) => x.iter().map(|&p| f(p, q)).collect(),
        (SrcI::K(p), SrcI::V(y)) => y.iter().map(|&q| f(p, q)).collect(),
        (SrcI::K(p), SrcI::K(q)) => {
            let v = f(p, q)?;
            Ok(vec![v; n])
        }
    }
}

fn map2_f64<F: Fn(f64, f64) -> f64>(
    a: SrcF<'_>,
    b: SrcF<'_>,
    n: usize,
    f: F,
) -> Vec<f64> {
    match (a, b) {
        (SrcF::V(x), SrcF::V(y)) => {
            x.iter().zip(y).map(|(&p, &q)| f(p, q)).collect()
        }
        (SrcF::V(x), SrcF::K(q)) => x.iter().map(|&p| f(p, q)).collect(),
        (SrcF::K(p), SrcF::V(y)) => y.iter().map(|&q| f(p, q)).collect(),
        (SrcF::K(p), SrcF::K(q)) => vec![f(p, q); n],
    }
}

fn cmp2_i64(op: CmpOp, a: SrcI<'_>, b: SrcI<'_>, n: usize) -> Vec<bool> {
    match (a, b) {
        (SrcI::V(x), SrcI::V(y)) => x
            .iter()
            .zip(y)
            .map(|(&p, &q)| op.ord(p.cmp(&q)))
            .collect(),
        (SrcI::V(x), SrcI::K(q)) => {
            x.iter().map(|&p| op.ord(p.cmp(&q))).collect()
        }
        (SrcI::K(p), SrcI::V(y)) => {
            y.iter().map(|&q| op.ord(p.cmp(&q))).collect()
        }
        (SrcI::K(p), SrcI::K(q)) => vec![op.ord(p.cmp(&q)); n],
    }
}

fn cmp2_f64(op: CmpOp, a: SrcF<'_>, b: SrcF<'_>, n: usize) -> Vec<bool> {
    match (a, b) {
        (SrcF::V(x), SrcF::V(y)) => {
            x.iter().zip(y).map(|(&p, &q)| op.f64(p, q)).collect()
        }
        (SrcF::V(x), SrcF::K(q)) => x.iter().map(|&p| op.f64(p, q)).collect(),
        (SrcF::K(p), SrcF::V(y)) => y.iter().map(|&q| op.f64(p, q)).collect(),
        (SrcF::K(p), SrcF::K(q)) => vec![op.f64(p, q); n],
    }
}

fn eval_arith(op: BinOp, l: &Evaluated, r: &Evaluated, n: usize) -> Result<Evaluated> {
    // Scalar ⊕ scalar folds stay scalar (broadcast deferred to the top).
    if let (Some(a), Some(b)) = (l.num_scalar(), r.num_scalar()) {
        return match (a, b) {
            (Scalar::Int64(a), Scalar::Int64(b)) => {
                op.i64(a, b).map(Evaluated::I64)
            }
            (a, b) => {
                let (a, b) = (scalar_f64(a), scalar_f64(b));
                Ok(Evaluated::F64(op.f64(a, b)))
            }
        };
    }
    if l.is_int() && r.is_int() {
        let (a, b) = (i64_src(l)?, i64_src(r)?);
        let out = map2_i64(a, b, n, |x, y| op.i64(x, y))?;
        Ok(Evaluated::Col(Column::from_i64(out)))
    } else {
        let (mut ls, mut rs) = (None, None);
        let a = f64_src(l, &mut ls)?;
        let b = f64_src(r, &mut rs)?;
        Ok(Evaluated::Col(Column::from_f64(map2_f64(a, b, n, |x, y| {
            op.f64(x, y)
        }))))
    }
}

fn scalar_f64(s: Scalar) -> f64 {
    match s {
        Scalar::Int64(v) => v as f64,
        Scalar::Float64(v) => v,
        Scalar::Bool(v) => v as u8 as f64,
    }
}

fn eval_cmp(op: CmpOp, l: &Evaluated, r: &Evaluated, n: usize) -> Result<Evaluated> {
    if let (Some(a), Some(b)) = (l.num_scalar(), r.num_scalar()) {
        return Ok(match (a, b) {
            (Scalar::Int64(a), Scalar::Int64(b)) => {
                Evaluated::Bool(op.ord(a.cmp(&b)))
            }
            (a, b) => Evaluated::Bool(op.f64(scalar_f64(a), scalar_f64(b))),
        });
    }
    let mask = if l.is_int() && r.is_int() {
        cmp2_i64(op, i64_src(l)?, i64_src(r)?, n)
    } else {
        let (mut ls, mut rs) = (None, None);
        let a = f64_src(l, &mut ls)?;
        let b = f64_src(r, &mut rs)?;
        cmp2_f64(op, a, b, n)
    };
    Ok(Evaluated::Col(Column::from_bool(mask)))
}

fn eval_node(t: &Table, e: &Expr) -> Result<Evaluated> {
    let n = t.num_rows();
    match e {
        Expr::Col(name) => match t.schema().index_of(name) {
            Ok(i) => Ok(Evaluated::Col(t.column(i).clone())),
            Err(err) => Err(Error::Config(format!("in expression: {err}"))),
        },
        Expr::Idx(i) if *i < t.num_columns() => {
            Ok(Evaluated::Col(t.column(*i).clone()))
        }
        Expr::Idx(i) => Err(Error::Config(format!(
            "in expression: column index {i} out of bounds for schema {}",
            t.schema()
        ))),
        Expr::Lit(Scalar::Int64(v)) => Ok(Evaluated::I64(*v)),
        Expr::Lit(Scalar::Float64(v)) => Ok(Evaluated::F64(*v)),
        Expr::Lit(Scalar::Bool(v)) => Ok(Evaluated::Bool(*v)),
        Expr::Bin { op, lhs, rhs } => {
            let (l, r) = (eval_node(t, lhs)?, eval_node(t, rhs)?);
            eval_arith(*op, &l, &r, n)
        }
        Expr::Cmp { op, lhs, rhs } => {
            let (l, r) = (eval_node(t, lhs)?, eval_node(t, rhs)?);
            eval_cmp(*op, &l, &r, n)
        }
        Expr::And(p, q) => {
            let l = eval_node(t, p)?;
            match bool_src(&l)? {
                SrcB::K(false) => return Ok(Evaluated::Bool(false)),
                SrcB::K(true) => {
                    let r = eval_node(t, q)?;
                    bool_src(&r)?; // type check
                    return Ok(r);
                }
                SrcB::M(m) => {
                    // Uniformly-false left mask short-circuits the right
                    // side entirely (see the module docs' caveat).
                    if !m.iter().any(|&x| x) {
                        return Ok(l.clone());
                    }
                }
            }
            let r = eval_node(t, q)?;
            combine_bool(&l, &r, false)
        }
        Expr::Or(p, q) => {
            let l = eval_node(t, p)?;
            match bool_src(&l)? {
                SrcB::K(true) => return Ok(Evaluated::Bool(true)),
                SrcB::K(false) => {
                    let r = eval_node(t, q)?;
                    bool_src(&r)?; // type check
                    return Ok(r);
                }
                SrcB::M(m) => {
                    // Uniformly-true left mask short-circuits the right.
                    if m.iter().all(|&x| x) {
                        return Ok(l.clone());
                    }
                }
            }
            let r = eval_node(t, q)?;
            combine_bool(&l, &r, true)
        }
        Expr::Not(p) => {
            let v = eval_node(t, p)?;
            match bool_src(&v)? {
                SrcB::K(k) => Ok(Evaluated::Bool(!k)),
                SrcB::M(m) => Ok(Evaluated::Col(Column::from_bool(
                    m.iter().map(|&x| !x).collect(),
                ))),
            }
        }
    }
}

/// Combine two bool operands elementwise (`or = false` → AND, `true` →
/// OR). The left side is always a mask here (scalar lefts short-circuit
/// in the caller).
fn combine_bool(l: &Evaluated, r: &Evaluated, or: bool) -> Result<Evaluated> {
    let lm = match bool_src(l)? {
        SrcB::M(m) => m,
        SrcB::K(_) => unreachable!("scalar left handled by caller"),
    };
    let out: Vec<bool> = match bool_src(r)? {
        // mask ∧ true = mask; mask ∨ false = mask.
        SrcB::K(k) if k == or => return Ok(Evaluated::Bool(or)),
        SrcB::K(_) => return Ok(l.clone()),
        SrcB::M(rm) => {
            if or {
                lm.iter().zip(rm).map(|(&x, &y)| x || y).collect()
            } else {
                lm.iter().zip(rm).map(|(&x, &y)| x && y).collect()
            }
        }
    };
    Ok(Evaluated::Col(Column::from_bool(out)))
}

/// Evaluate `expr` over one table chunk into a flat column (scalar
/// results broadcast to the chunk's row count). Column references
/// resolve against `t.schema()`; see the [module docs](self) for the
/// numeric semantics.
pub fn eval_expr(t: &Table, expr: &Expr) -> Result<Column> {
    let n = t.num_rows();
    Ok(match eval_node(t, expr)? {
        Evaluated::Col(c) => c,
        Evaluated::I64(k) => Column::from_i64(vec![k; n]),
        Evaluated::F64(k) => Column::from_f64(vec![k; n]),
        Evaluated::Bool(k) => Column::from_bool(vec![k; n]),
    })
}

/// Evaluate a boolean `expr` into a flat mask column (`Column::Bool`,
/// one buffer, no copies beyond the evaluation itself). Non-bool
/// expressions are an [`Error::Config`]. This is the filter hot path;
/// [`eval_predicate`] is the `Vec<bool>` convenience wrapper.
pub fn eval_mask(t: &Table, expr: &Expr) -> Result<Column> {
    match eval_node(t, expr)? {
        Evaluated::Bool(k) => Ok(Column::from_bool(vec![k; t.num_rows()])),
        Evaluated::Col(c @ Column::Bool(_)) => Ok(c),
        Evaluated::Col(other) => Err(Error::Config(format!(
            "filter predicate must be bool, got {} (wrap it in a \
             comparison, e.g. .gt(lit(0)))",
            other.dtype()
        ))),
        other => Err(Error::Config(format!(
            "filter predicate must be bool, got scalar {}",
            other.type_name()
        ))),
    }
}

/// [`eval_mask`] copied out into an owned `Vec<bool>` — convenient for
/// oracles and one-off callers; the filter operators borrow the mask
/// column directly instead.
pub fn eval_predicate(t: &Table, expr: &Expr) -> Result<Vec<bool>> {
    Ok(eval_mask(t, expr)?.as_bool()?.to_vec())
}

/// Chunk-at-a-time boolean filter over a [`ChunkedTable`]: each chunk
/// evaluates the predicate into a flat mask and keeps its matching rows
/// as maximal zero-copy runs ([`filter_view`]) — no chunk is ever
/// concatenated, so the filter materializes only the masks.
pub fn filter_view_expr(ct: &ChunkedTable, pred: &Expr) -> Result<ChunkedTable> {
    if ct.num_rows() >= par_min_rows()
        && ct.num_chunks() > 1
        && pool::parallelism() > 1
    {
        return filter_view_expr_par(ct, pred, pool::global());
    }
    let mut out = ChunkedTable::empty(ct.schema().clone());
    for chunk in ct.chunks() {
        let mask = eval_mask(chunk, pred)?;
        for run in filter_view(chunk, mask.as_bool()?)?.into_chunks() {
            out.push(run)?;
        }
    }
    Ok(out)
}

/// [`filter_view_expr`] on an explicit thread pool: chunks are the
/// morsels — each evaluates its mask and slices its kept-row runs
/// concurrently (still zero-copy windows), and the per-chunk run lists
/// are stitched back **in chunk order**, so the output is bit-identical
/// to the sequential walk. On error the lowest-chunk-index failure is
/// returned, matching the sequential early-exit's reported error.
pub fn filter_view_expr_par(
    ct: &ChunkedTable,
    pred: &Expr,
    pool: &ThreadPool,
) -> Result<ChunkedTable> {
    let chunks = ct.chunks();
    let parts: Vec<Result<Vec<Table>>> =
        pool.run_indexed(chunks.len(), |i| {
            let mask = eval_mask(&chunks[i], pred)?;
            Ok(filter_view(&chunks[i], mask.as_bool()?)?.into_chunks())
        });
    let mut out = ChunkedTable::empty(ct.schema().clone());
    for part in parts {
        for run in part? {
            out.push(run)?;
        }
    }
    Ok(out)
}

/// Zero-copy filter: keep rows where `mask` is true, returned as a
/// [`ChunkedTable`] of **maximal contiguous runs** of kept rows — every
/// chunk is an O(1) window ([`Table::slice`]) over `t`'s buffers, so the
/// filter itself materializes zero bytes no matter how selective it is.
/// The copy is deferred to `compact()`, exactly like shuffle receives and
/// gathered pipeline outputs; a consumer that can iterate chunks never
/// pays it. ([`Table::filter`] remains the eager, contiguous variant.)
pub fn filter_view(t: &Table, mask: &[bool]) -> Result<ChunkedTable> {
    if mask.len() != t.num_rows() {
        return Err(Error::DataFrame(format!(
            "filter_view mask length {} != row count {}",
            mask.len(),
            t.num_rows()
        )));
    }
    let mut out = ChunkedTable::empty(t.schema().clone());
    let mut run_start: Option<usize> = None;
    for (i, &keep) in mask.iter().enumerate() {
        match (keep, run_start) {
            (true, None) => run_start = Some(i),
            (false, Some(s)) => {
                out.push(t.slice(s, i - s)).expect("same schema");
                run_start = None;
            }
            _ => {}
        }
    }
    if let Some(s) = run_start {
        out.push(t.slice(s, mask.len() - s)).expect("same schema");
    }
    Ok(out)
}

/// Append a derived column to a table under `name`. Rejects names that
/// already exist: duplicate columns would make every later name lookup
/// silently resolve to the original.
pub fn with_column(t: &Table, name: &str, col: Column) -> Result<Table> {
    if col.len() != t.num_rows() {
        return Err(Error::DataFrame(format!(
            "with_column length {} != {}",
            col.len(),
            t.num_rows()
        )));
    }
    if t.schema().index_of(name).is_ok() {
        return Err(Error::DataFrame(format!(
            "with_column '{name}' would shadow an existing column of \
             schema {}",
            t.schema()
        )));
    }
    let mut fields: Vec<_> = t.schema().fields().to_vec();
    fields.push(crate::df::Field::new(name, col.dtype()));
    let mut cols: Vec<Column> = t.columns().to_vec();
    cols.push(col);
    Table::new(Schema::new(fields), cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::df::{DataType, Schema};
    use crate::metrics::mem;
    use crate::plan::expr::{col, idx, lit};

    fn table() -> Table {
        Table::new(
            Schema::of(&[("k", DataType::Int64), ("v", DataType::Float64)]),
            vec![
                Column::from_i64(vec![1, 2, 3, 4]),
                Column::from_f64(vec![0.5, 1.5, 2.5, 3.5]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn arithmetic() {
        let a = Column::from_i64(vec![10, 20]);
        let b = Column::from_i64(vec![3, 4]);
        assert_eq!(
            binary_op(&a, &b, BinOp::Add).unwrap(),
            Column::from_i64(vec![13, 24])
        );
        assert_eq!(
            binary_op(&a, &b, BinOp::Div).unwrap(),
            Column::from_i64(vec![3, 5])
        );
        let z = Column::from_i64(vec![0, 0]);
        let err = binary_op(&a, &z, BinOp::Div).unwrap_err();
        assert!(matches!(err, Error::Compute(_)), "{err}");
        assert!(err.to_string().contains("division by zero"), "{err}");
        assert!(binary_op(&a, &Column::from_f64(vec![1.0, 2.0]), BinOp::Add).is_err());
        // Floats follow IEEE: div-by-zero is inf, not an error.
        let f = Column::from_f64(vec![1.0, 0.0]);
        let fz = Column::from_f64(vec![0.0, 0.0]);
        let q = binary_op(&f, &fz, BinOp::Div).unwrap();
        let q = q.as_f64().unwrap();
        assert_eq!(q[0], f64::INFINITY);
        assert!(q[1].is_nan());
    }

    #[test]
    fn scalar_and_compare() {
        let t = table();
        let doubled = scalar_op_i64(t.column(0), 2, BinOp::Mul).unwrap();
        assert_eq!(doubled, Column::from_i64(vec![2, 4, 6, 8]));
        assert!(matches!(
            scalar_op_i64(t.column(0), 0, BinOp::Div).unwrap_err(),
            Error::Compute(_)
        ));
        let mask = compare_scalar(t.column(1), 2.0, CmpOp::Gt).unwrap();
        assert_eq!(mask, vec![false, false, true, true]);
        let filtered = t.filter(&mask).unwrap();
        assert_eq!(filtered.num_rows(), 2);
    }

    #[test]
    fn casts() {
        let c = cast(&Column::from_i64(vec![1, 2]), DataType::Float64).unwrap();
        assert_eq!(c, Column::from_f64(vec![1.0, 2.0]));
        let back = cast(&c, DataType::Int64).unwrap();
        assert_eq!(back, Column::from_i64(vec![1, 2]));
        let b = cast(&Column::from_bool(vec![true, false]), DataType::Int64).unwrap();
        assert_eq!(b, Column::from_i64(vec![1, 0]));
        assert!(cast(&Column::from_utf8(&["x"]), DataType::Int64).is_err());
    }

    #[test]
    fn eval_arithmetic_and_promotion() {
        let t = table();
        // Pure int64 stays int64.
        let e = col("k") * lit(2) + lit(1);
        assert_eq!(
            eval_expr(&t, &e).unwrap(),
            Column::from_i64(vec![3, 5, 7, 9])
        );
        // Mixed int/float promotes to float64.
        let e = col("k") + col("v");
        assert_eq!(
            eval_expr(&t, &e).unwrap(),
            Column::from_f64(vec![1.5, 3.5, 5.5, 7.5])
        );
        // Scalar-scalar folds stay scalar until the final broadcast.
        let e = lit(2) * lit(3) + col("k");
        assert_eq!(
            eval_expr(&t, &e).unwrap(),
            Column::from_i64(vec![7, 8, 9, 10])
        );
        // A scalar-only expression broadcasts to the chunk length.
        let e = lit(2) + lit(3);
        assert_eq!(eval_expr(&t, &e).unwrap(), Column::from_i64(vec![5; 4]));
        // Positional addressing works (legacy shim path).
        assert_eq!(eval_expr(&t, &idx(0)).unwrap(), *t.column(0));
    }

    #[test]
    fn eval_comparisons_and_bools() {
        let t = table();
        let mask = eval_predicate(&t, &col("k").ge(lit(3))).unwrap();
        assert_eq!(mask, vec![false, false, true, true]);
        // Mixed int/float comparison goes through f64.
        let mask = eval_predicate(&t, &col("k").gt(col("v"))).unwrap();
        assert_eq!(mask, vec![true, true, true, true]);
        let e = col("k").ge(lit(2)).and(col("v").lt(lit(3.0)));
        assert_eq!(
            eval_predicate(&t, &e).unwrap(),
            vec![false, true, true, false]
        );
        let e = col("k").le(lit(1)).or(col("k").ge(lit(4)));
        assert_eq!(
            eval_predicate(&t, &e).unwrap(),
            vec![true, false, false, true]
        );
        let e = !col("k").ge(lit(2));
        assert_eq!(
            eval_predicate(&t, &e).unwrap(),
            vec![true, false, false, false]
        );
        // Scalar predicates broadcast.
        assert_eq!(eval_predicate(&t, &lit(true)).unwrap(), vec![true; 4]);
        assert_eq!(
            eval_predicate(&t, &lit(1).gt(lit(2))).unwrap(),
            vec![false; 4]
        );
    }

    #[test]
    fn eval_short_circuits_are_value_transparent() {
        let t = table();
        // All-false left mask: right side skipped, result all false.
        let e = col("k").gt(lit(100)).and(col("v").ge(lit(0.0)));
        assert_eq!(eval_predicate(&t, &e).unwrap(), vec![false; 4]);
        // All-true left mask on or: result all true.
        let e = col("k").ge(lit(0)).or(col("v").gt(lit(100.0)));
        assert_eq!(eval_predicate(&t, &e).unwrap(), vec![true; 4]);
        // Scalar-true left keeps the right mask unchanged.
        let e = lit(true).and(col("k").ge(lit(3)));
        assert_eq!(
            eval_predicate(&t, &e).unwrap(),
            vec![false, false, true, true]
        );
    }

    #[test]
    fn eval_div_by_zero_and_nan() {
        let t = table();
        // Int64 division by zero is a Compute error...
        let err = eval_expr(&t, &(col("k") / lit(0))).unwrap_err();
        assert!(matches!(err, Error::Compute(_)), "{err}");
        // ...including via a zero column cell.
        let z = Table::new(
            Schema::of(&[("a", DataType::Int64), ("b", DataType::Int64)]),
            vec![
                Column::from_i64(vec![10, 20]),
                Column::from_i64(vec![2, 0]),
            ],
        )
        .unwrap();
        assert!(eval_expr(&z, &(col("a") / col("b"))).is_err());
        // Float division by zero is IEEE inf/NaN, not an error.
        let q = eval_expr(&t, &(col("v") / lit(0.0))).unwrap();
        assert!(q.as_f64().unwrap().iter().all(|x| x.is_infinite()));
        let nan = eval_expr(&t, &(lit(0.0) / lit(0.0))).unwrap();
        assert!(nan.as_f64().unwrap().iter().all(|x| x.is_nan()));
        // NaN comparisons: false except Ne.
        let withnan = with_column(&t, "n", nan).unwrap();
        assert_eq!(
            eval_predicate(&withnan, &col("n").ge(lit(0.0))).unwrap(),
            vec![false; 4]
        );
        assert_eq!(
            eval_predicate(&withnan, &col("n").ne(col("n"))).unwrap(),
            vec![true; 4]
        );
    }

    #[test]
    fn eval_type_errors_are_config() {
        let t = table();
        let err = eval_expr(&t, &col("nope")).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(eval_expr(&t, &(col("k") + lit(true))).is_err());
        assert!(eval_predicate(&t, &col("k")).is_err());
        assert!(eval_predicate(&t, &lit(1)).is_err());
        assert!(eval_expr(&t, &col("k").and(lit(true))).is_err());
        assert!(eval_expr(&t, &idx(9)).is_err());
    }

    #[test]
    fn filter_view_expr_is_chunk_at_a_time_zero_copy() {
        let t = table();
        let ct = ChunkedTable::from_tables(vec![t.slice(0, 2), t.slice(2, 2)]).unwrap();
        let before = mem::thread();
        let out = filter_view_expr(&ct, &col("k").ge(lit(2)).and(col("v").lt(lit(3.0))))
            .unwrap();
        // Only the masks materialize; every kept row is a window.
        assert_eq!(out.num_rows(), 2);
        assert!(out.chunks()[0].column(0).shares_buffer(t.column(0)));
        let delta = mem::thread().since(before);
        assert!(
            delta.materialized <= 64,
            "only mask-sized scratch may materialize, got {}",
            delta.materialized
        );
        assert_eq!(out.compact().column(0).as_i64().unwrap(), &[2, 3]);
    }

    #[test]
    fn filter_view_is_zero_copy_and_matches_eager_filter() {
        let t = table();
        let mask = vec![true, false, true, true];
        let before = mem::thread();
        let v = filter_view(&t, &mask).unwrap();
        assert_eq!(
            mem::thread().since(before).materialized,
            0,
            "run-sliced filter must not copy rows"
        );
        // Two maximal runs: [0,1) and [2,4).
        assert_eq!(v.num_chunks(), 2);
        assert!(v.chunks()[0].column(0).shares_buffer(t.column(0)));
        assert_eq!(v.compact(), t.filter(&mask).unwrap());
        // Degenerate masks.
        assert_eq!(filter_view(&t, &[false; 4]).unwrap().num_rows(), 0);
        assert_eq!(filter_view(&t, &[true; 4]).unwrap().num_chunks(), 1);
        assert!(filter_view(&t, &[true]).is_err());
    }

    #[test]
    fn filter_view_on_chunked_view_stays_zero_copy() {
        // A chunked (gathered-shape) view filtered chunk-by-chunk — the
        // shape a piped consumer sees — materializes nothing either.
        let t = table();
        let ct = ChunkedTable::from_tables(vec![t.slice(0, 2), t.slice(2, 2)]).unwrap();
        let before = mem::thread();
        let mut out = ChunkedTable::empty(ct.schema().clone());
        for chunk in ct.chunks() {
            let mask = compare_scalar(chunk.column(0), 2.0, CmpOp::Ge).unwrap();
            for run in filter_view(chunk, &mask).unwrap().chunks() {
                out.push(run.clone()).unwrap();
            }
        }
        assert_eq!(mem::thread().since(before).materialized, 0);
        assert_eq!(out.num_rows(), 3);
        assert_eq!(out.compact().column(0).as_i64().unwrap(), &[2, 3, 4]);
    }

    #[test]
    fn parallel_filter_is_bit_identical_to_sequential() {
        // Many small chunks so several morsels exist per thread count.
        let chunks: Vec<Table> = (0..16i64)
            .map(|c| {
                Table::new(
                    Schema::of(&[
                        ("k", DataType::Int64),
                        ("v", DataType::Float64),
                    ]),
                    vec![
                        Column::from_i64(
                            (0..50i64).map(|i| (c * 50 + i) % 7).collect(),
                        ),
                        Column::from_f64(
                            (0..50i64)
                                .map(|i| (c * 50 + i) as f64 * 0.5)
                                .collect(),
                        ),
                    ],
                )
                .unwrap()
            })
            .collect();
        let ct = ChunkedTable::from_tables(chunks).unwrap();
        let pred = col("k").ge(lit(2)).and(col("v").lt(lit(300.0)));
        let seq = filter_view_expr(&ct, &pred).unwrap();
        for threads in [1usize, 2, 4] {
            let pool = crate::util::pool::ThreadPool::new(threads);
            let par = filter_view_expr_par(&ct, &pred, &pool).unwrap();
            assert_eq!(par.num_chunks(), seq.num_chunks(), "threads={threads}");
            assert_eq!(par.compact(), seq.compact(), "threads={threads}");
        }
        // Errors surface from the lowest failing chunk, like sequential.
        let pool = crate::util::pool::ThreadPool::new(4);
        let bad = col("k") / lit(0);
        assert!(filter_view_expr_par(&ct, &bad.ge(lit(0)), &pool).is_err());
    }

    #[test]
    fn derived_column() {
        let t = table();
        let sum = eval_expr(&t, &(col("k") + col("v"))).unwrap();
        let t2 = with_column(&t, "k_plus_v", sum).unwrap();
        assert_eq!(t2.num_columns(), 3);
        assert_eq!(t2.schema().field(2).name, "k_plus_v");
        assert_eq!(t2.column(2).as_f64().unwrap(), &[1.5, 3.5, 5.5, 7.5]);
        assert!(with_column(&t, "bad", Column::from_i64(vec![1])).is_err());
        // Shadowing an existing column is rejected, not silently accepted.
        let err = with_column(&t, "v", Column::from_i64(vec![0; 4]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("shadow"), "{err}");
    }
}
