//! Duplicate elimination (local) — with the distributed variant composed in
//! `ops::dist` (shuffle co-locates equal keys, then local dedup is global).
//!
//! [`unique_by_key`] dispatches to a morsel-parallel twin above the
//! morsel threshold: a parallel [`CsrIndex`] build groups equal keys
//! into buckets (per-thread histogram + disjoint scatter), then bucket
//! ranges are swept concurrently to mark each key's first occurrence —
//! buckets partition the rows, so the keep-flag scatter is collision-free
//! by construction, and the final ascending index scan reproduces the
//! sequential first-occurrence order exactly.

use std::collections::HashSet;

use crate::df::Table;
use crate::error::Result;
use crate::util::hash::CsrIndex;
use crate::util::pool::{self, SharedSlice, ThreadPool};

use super::sort::{morsel_ranges, par_min_rows};

/// Keep the first row for every distinct key in `key_col` (int64).
/// Large inputs dispatch to [`unique_by_key_par`] on the global pool —
/// bit-identical either way.
pub fn unique_by_key(t: &Table, key_col: usize) -> Result<Table> {
    let keys = t.column(key_col).as_i64()?;
    if keys.len() >= par_min_rows()
        && keys.len() < u32::MAX as usize
        && pool::parallelism() > 1
    {
        return unique_by_key_par(t, key_col, pool::global());
    }
    unique_by_key_seq(t, key_col)
}

fn unique_by_key_seq(t: &Table, key_col: usize) -> Result<Table> {
    let keys = t.column(key_col).as_i64()?;
    let mut seen = HashSet::with_capacity_and_hasher(
        keys.len(),
        crate::util::hash::SplitMixBuild,
    );
    let mut idx = Vec::new();
    for (i, &k) in keys.iter().enumerate() {
        if seen.insert(k) {
            idx.push(i);
        }
    }
    Ok(t.take(&idx))
}

/// [`unique_by_key`] on an explicit thread pool, using the same
/// per-thread-histogram + disjoint-scatter pattern as
/// [`CsrIndex::build_par`].
///
/// **Determinism:** equal keys always share a CSR bucket, and bucket
/// rows are ascending, so "no earlier candidate in the bucket carries my
/// key" is exactly "I am the key's first occurrence". Every row belongs
/// to one bucket and one sweep morsel, so the keep-flag writes are
/// disjoint; the final ascending scan over the flags rebuilds the
/// sequential first-occurrence index list bit-for-bit.
pub fn unique_by_key_par(
    t: &Table,
    key_col: usize,
    pool: &ThreadPool,
) -> Result<Table> {
    let keys = t.column(key_col).as_i64()?;
    let nt = pool.size().min(keys.len() / par_min_rows()).max(1);
    if nt <= 1 || keys.len() >= u32::MAX as usize {
        return unique_by_key_seq(t, key_col);
    }
    let index = CsrIndex::build_par(keys, pool);
    let mut keep = vec![false; keys.len()];
    {
        let shared = SharedSlice::new(&mut keep);
        // 4 morsels per worker: bucket occupancy is uneven under skew.
        let morsels = morsel_ranges(index.num_buckets(), nt * 4);
        pool.run_indexed(morsels.len(), |m| {
            let (lo, hi) = morsels[m];
            for b in lo..hi {
                let rows = index.bucket_rows(b);
                for (i, &r) in rows.iter().enumerate() {
                    let k = keys[r as usize];
                    // `all` short-circuits on the first equal key, so a
                    // long duplicate run costs O(1) per row.
                    if rows[..i].iter().all(|&p| keys[p as usize] != k) {
                        // SAFETY: buckets partition the rows and morsels
                        // partition the buckets, so no two writers share
                        // an index; reads only after the join.
                        unsafe { shared.write(r as usize, true) };
                    }
                }
            }
        });
    }
    let idx: Vec<usize> =
        keep.iter().enumerate().filter(|&(_, &k)| k).map(|(i, _)| i).collect();
    Ok(t.take(&idx))
}

/// Keep fully-distinct rows (all columns participate in identity).
pub fn unique_rows(t: &Table) -> Result<Table> {
    let mut seen: HashSet<u64> = HashSet::with_capacity(t.num_rows());
    let mut idx = Vec::new();
    for r in 0..t.num_rows() {
        let mut h = 0x9E37_79B9_7F4A_7C15u64;
        for c in t.columns() {
            h = crate::util::hash::splitmix64(h ^ c.value_hash(r));
        }
        if seen.insert(h) {
            idx.push(r);
        }
    }
    Ok(t.take(&idx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::df::{Column, DataType, Schema};
    use crate::util::testkit;

    fn t(keys: Vec<i64>, vals: Vec<i64>) -> Table {
        Table::new(
            Schema::of(&[("k", DataType::Int64), ("v", DataType::Int64)]),
            vec![Column::from_i64(keys), Column::from_i64(vals)],
        )
        .unwrap()
    }

    #[test]
    fn by_key_keeps_first() {
        let tbl = t(vec![1, 2, 1, 3, 2], vec![10, 20, 11, 30, 21]);
        let u = unique_by_key(&tbl, 0).unwrap();
        assert_eq!(u.column(0).as_i64().unwrap(), &[1, 2, 3]);
        assert_eq!(u.column(1).as_i64().unwrap(), &[10, 20, 30]);
    }

    #[test]
    fn full_rows() {
        let tbl = t(vec![1, 1, 1], vec![10, 10, 11]);
        let u = unique_rows(&tbl).unwrap();
        assert_eq!(u.num_rows(), 2);
    }

    #[test]
    fn parallel_unique_is_bit_identical_to_sequential() {
        // Straddle the morsel threshold; duplicate-heavy and all-equal
        // keys make the first-occurrence choice observable.
        let pmr = par_min_rows();
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            for n in [0usize, 100, pmr, 3 * pmr] {
                let dup: Vec<i64> =
                    (0..n as i64).map(|i| (i * 37) % 613).collect();
                let all_equal = vec![7i64; n];
                for keys in [dup, all_equal] {
                    let vals: Vec<i64> = (0..n as i64).collect();
                    let tbl = t(keys, vals);
                    let par = unique_by_key_par(&tbl, 0, &pool).unwrap();
                    let seq = unique_by_key_seq(&tbl, 0).unwrap();
                    assert_eq!(par, seq, "threads={threads} n={n}");
                }
            }
        }
    }

    #[test]
    fn prop_unique_idempotent() {
        testkit::check("unique idempotent", 24, |rng| {
            let n = rng.gen_range(80) as usize;
            let keys: Vec<i64> = (0..n).map(|_| rng.gen_i64(0, 15)).collect();
            let vals: Vec<i64> = (0..n).map(|_| rng.gen_i64(0, 3)).collect();
            let tbl = t(keys, vals);
            let once = unique_rows(&tbl).unwrap();
            let twice = unique_rows(&once).unwrap();
            assert_eq!(once, twice);
            let by_key = unique_by_key(&tbl, 0).unwrap();
            let k = by_key.column(0).as_i64().unwrap();
            let set: std::collections::HashSet<_> = k.iter().collect();
            assert_eq!(set.len(), k.len(), "keys must be distinct");
        });
    }
}
