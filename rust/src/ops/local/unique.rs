//! Duplicate elimination (local) — with the distributed variant composed in
//! `ops::dist` (shuffle co-locates equal keys, then local dedup is global).

use std::collections::HashSet;

use crate::df::Table;
use crate::error::Result;

/// Keep the first row for every distinct key in `key_col` (int64).
pub fn unique_by_key(t: &Table, key_col: usize) -> Result<Table> {
    let keys = t.column(key_col).as_i64()?;
    let mut seen = HashSet::with_capacity_and_hasher(
        keys.len(),
        crate::util::hash::SplitMixBuild,
    );
    let mut idx = Vec::new();
    for (i, &k) in keys.iter().enumerate() {
        if seen.insert(k) {
            idx.push(i);
        }
    }
    Ok(t.take(&idx))
}

/// Keep fully-distinct rows (all columns participate in identity).
pub fn unique_rows(t: &Table) -> Result<Table> {
    let mut seen: HashSet<u64> = HashSet::with_capacity(t.num_rows());
    let mut idx = Vec::new();
    for r in 0..t.num_rows() {
        let mut h = 0x9E37_79B9_7F4A_7C15u64;
        for c in t.columns() {
            h = crate::util::hash::splitmix64(h ^ c.value_hash(r));
        }
        if seen.insert(h) {
            idx.push(r);
        }
    }
    Ok(t.take(&idx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::df::{Column, DataType, Schema};
    use crate::util::testkit;

    fn t(keys: Vec<i64>, vals: Vec<i64>) -> Table {
        Table::new(
            Schema::of(&[("k", DataType::Int64), ("v", DataType::Int64)]),
            vec![Column::from_i64(keys), Column::from_i64(vals)],
        )
        .unwrap()
    }

    #[test]
    fn by_key_keeps_first() {
        let tbl = t(vec![1, 2, 1, 3, 2], vec![10, 20, 11, 30, 21]);
        let u = unique_by_key(&tbl, 0).unwrap();
        assert_eq!(u.column(0).as_i64().unwrap(), &[1, 2, 3]);
        assert_eq!(u.column(1).as_i64().unwrap(), &[10, 20, 30]);
    }

    #[test]
    fn full_rows() {
        let tbl = t(vec![1, 1, 1], vec![10, 10, 11]);
        let u = unique_rows(&tbl).unwrap();
        assert_eq!(u.num_rows(), 2);
    }

    #[test]
    fn prop_unique_idempotent() {
        testkit::check("unique idempotent", 24, |rng| {
            let n = rng.gen_range(80) as usize;
            let keys: Vec<i64> = (0..n).map(|_| rng.gen_i64(0, 15)).collect();
            let vals: Vec<i64> = (0..n).map(|_| rng.gen_i64(0, 3)).collect();
            let tbl = t(keys, vals);
            let once = unique_rows(&tbl).unwrap();
            let twice = unique_rows(&once).unwrap();
            assert_eq!(once, twice);
            let by_key = unique_by_key(&tbl, 0).unwrap();
            let k = by_key.column(0).as_i64().unwrap();
            let set: std::collections::HashSet<_> = k.iter().collect();
            assert_eq!(set.len(), k.len(), "keys must be distinct");
        });
    }
}
