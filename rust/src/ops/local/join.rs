//! Local joins on int64 keys: hash join (CSR build/probe), sort-merge
//! join, and a nested-loop oracle for tests.
//!
//! The hash join's build side is a flat [`CsrIndex`] (count →
//! prefix-sum → scatter; two allocations total) and its probe output is a
//! pair of `u32` index vectors with a `u32::MAX` miss sentinel for
//! unmatched outer rows — no per-key `Vec` buckets, no `Option<usize>`
//! slots, half the index memory. The pre-CSR map-based build survives as
//! [`hash_join_hashmap`], the bench baseline and bit-identical oracle
//! (EXPERIMENTS.md §Perf).

use std::collections::HashMap;

use crate::df::{ChunkedTable, Column, DataType, Schema, Table, Utf8Builder};
use crate::error::{Error, Result};
use crate::spill::{MemoryBudget, RunWriter, SpilledTable};
use crate::util::hash::{splitmix64, CsrIndex, SplitMixBuild};
use crate::util::pool::{self, ThreadPool};

use super::sort::{
    merge_block_streams, morsel_ranges, par_min_rows, sort_table,
    spill_in_blocks, BlockStream, MergeSpec, SortKey, MIN_BLOCK_BYTES,
};

/// Miss sentinel in right-side probe index vectors: the row had no match
/// and takes the [`FillPolicy`] values. Real row ids are `< MISS`, which
/// [`hash_join_filled`] enforces on its inputs.
const MISS: u32 = u32::MAX;

/// Join variants supported by the local operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JoinType {
    Inner,
    /// Left outer — unmatched left rows take the [`FillPolicy`]'s values on
    /// the right side.
    Left,
}

/// Per-dtype values written into the right side of unmatched rows in outer
/// joins.
///
/// This table has no validity bitmap (null-free synthetic workloads, per
/// the paper), so an outer join **must** fabricate something for unmatched
/// rows — and whatever it fabricates is indistinguishable from real data
/// downstream. This policy makes that choice explicit at the API level
/// instead of burying a hard-coded `unwrap_or_default()` in the gather:
/// callers that need to tell fill from data pick sentinels outside their
/// domain (e.g. `i64::MIN`, `f64::NAN`, `"<null>"`).
///
/// [`FillPolicy::zeros`] (the `Default`) matches Cylon's null-free
/// evaluation setup: `0` / `0.0` / `""` / `false`.
#[derive(Clone, Debug, PartialEq)]
pub struct FillPolicy {
    pub int64: i64,
    pub float64: f64,
    pub utf8: String,
    pub bool_: bool,
}

impl FillPolicy {
    /// Zero-values fill (`0` / `0.0` / `""` / `false`) — indistinguishable
    /// from real zeros; fine for workloads that never read unmatched rows.
    pub fn zeros() -> FillPolicy {
        FillPolicy { int64: 0, float64: 0.0, utf8: String::new(), bool_: false }
    }

    /// Out-of-band sentinels (`i64::MIN` / `-inf` / `"<null>"` / `false`):
    /// unmatched rows stay recognizably synthetic downstream. `-inf` rather
    /// than `NaN` so sentinel-filled outputs keep reflexive equality
    /// (`Table`/`Column`/`FillPolicy` derive `PartialEq`; a NaN cell would
    /// make a result compare unequal to itself).
    pub fn sentinels() -> FillPolicy {
        FillPolicy {
            int64: i64::MIN,
            float64: f64::NEG_INFINITY,
            utf8: "<null>".to_string(),
            bool_: false,
        }
    }
}

impl Default for FillPolicy {
    fn default() -> FillPolicy {
        FillPolicy::zeros()
    }
}

fn key_col(t: &Table, col: usize) -> Result<&[i64]> {
    if col >= t.num_columns() {
        return Err(Error::DataFrame(format!(
            "join key column {col} out of range"
        )));
    }
    t.column(col).as_i64()
}

fn assemble(
    left: &Table,
    right: &Table,
    right_key: usize,
    pairs_l: Vec<u32>,
    pairs_r: Vec<u32>,
    fill: &FillPolicy,
) -> Result<Table> {
    let schema = left.schema().join(drop_field(right, right_key).0.schema());
    let mut cols: Vec<Column> = Vec::with_capacity(schema.len());
    for c in left.columns() {
        cols.push(c.take_u32(&pairs_l));
    }
    let (rt, _) = drop_field(right, right_key);
    for c in rt_columns(&rt) {
        cols.push(take_optional(c, &pairs_r, fill));
    }
    Table::new(schema, cols)
}

/// Right table minus its key column (the key survives via the left side).
/// Projection is `Arc` clones — no column data moves.
fn drop_field(t: &Table, key: usize) -> (Table, usize) {
    let names: Vec<&str> = t
        .schema()
        .fields()
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != key)
        .map(|(_, f)| f.name.as_str())
        .collect();
    (t.project(&names).expect("projection of existing fields"), key)
}

fn rt_columns(t: &Table) -> &[Column] {
    t.columns()
}

/// Gather with sentinel indices: [`MISS`] slots take the fill value.
fn take_optional(c: &Column, idx: &[u32], fill: &FillPolicy) -> Column {
    match c {
        Column::Int64(v) => Column::from_i64(
            idx.iter()
                .map(|&i| if i == MISS { fill.int64 } else { v[i as usize] })
                .collect(),
        ),
        Column::Float64(v) => Column::from_f64(
            idx.iter()
                .map(|&i| if i == MISS { fill.float64 } else { v[i as usize] })
                .collect(),
        ),
        Column::Utf8(v) => {
            let bytes: usize = idx
                .iter()
                .map(|&i| {
                    if i == MISS {
                        fill.utf8.len()
                    } else {
                        v.get(i as usize).len()
                    }
                })
                .sum();
            let mut b = Utf8Builder::with_capacity(idx.len(), bytes);
            for &i in idx {
                if i == MISS {
                    b.push(&fill.utf8);
                } else {
                    b.push(v.get(i as usize));
                }
            }
            Column::Utf8(b.finish())
        }
        Column::Bool(v) => Column::from_bool(
            idx.iter()
                .map(|&i| if i == MISS { fill.bool_ } else { v[i as usize] })
                .collect(),
        ),
    }
}

/// Both sides' row ids (and the [`MISS`] sentinel) must fit `u32`.
fn check_u32_rows(left: &Table, right: &Table) -> Result<()> {
    if left.num_rows() >= MISS as usize || right.num_rows() >= MISS as usize {
        return Err(Error::DataFrame(format!(
            "join sides exceed the u32 row-id range ({} x {} rows)",
            left.num_rows(),
            right.num_rows()
        )));
    }
    Ok(())
}

/// Hash join with the default [`FillPolicy::zeros`] fill for outer rows.
pub fn hash_join(
    left: &Table,
    right: &Table,
    left_key: usize,
    right_key: usize,
    how: JoinType,
) -> Result<Table> {
    hash_join_filled(left, right, left_key, right_key, how, &FillPolicy::zeros())
}

/// Hash join: build on the right table, probe with the left. Unmatched
/// left rows (outer joins only) take `fill`'s per-dtype values on the
/// right side.
///
/// The build side is a flat [`CsrIndex`] — count occurrences per hash
/// bucket, exclusive prefix-sum into one offsets array, scatter row ids
/// into one flat `u32` array — so the build performs two allocations
/// total instead of one `Vec` per distinct key, and the probe emits `u32`
/// index vectors (`u32::MAX`-sentinel misses) instead of
/// `Vec<Option<usize>>` (CSR perf pass, EXPERIMENTS.md §Perf; the
/// map-based baseline survives as [`hash_join_hashmap`]).
pub fn hash_join_filled(
    left: &Table,
    right: &Table,
    left_key: usize,
    right_key: usize,
    how: JoinType,
    fill: &FillPolicy,
) -> Result<Table> {
    if left.num_rows().max(right.num_rows()) >= par_min_rows()
        && pool::parallelism() > 1
    {
        return hash_join_filled_par(
            left,
            right,
            left_key,
            right_key,
            how,
            fill,
            pool::global(),
        );
    }
    check_u32_rows(left, right)?;
    let lk = key_col(left, left_key)?;
    let rk = key_col(right, right_key)?;
    let index = CsrIndex::build(rk);
    let (pairs_l, pairs_r) = probe_pairs(lk, rk, &index, how, 0);
    assemble(left, right, right_key, pairs_l, pairs_r, fill)
}

/// Probe `lk[lo..]` against the CSR build side; row ids are absolute
/// (`lo +` local offset). Candidates share the hash bucket; re-checking
/// the key in ascending candidate order keeps the output bit-identical
/// to the legacy map-based probe.
fn probe_pairs(
    lk: &[i64],
    rk: &[i64],
    index: &CsrIndex,
    how: JoinType,
    lo: usize,
) -> (Vec<u32>, Vec<u32>) {
    let mut pairs_l: Vec<u32> = Vec::new();
    let mut pairs_r: Vec<u32> = Vec::new();
    for (i, &k) in lk.iter().enumerate() {
        let mut matched = false;
        for &j in index.candidates(k) {
            if rk[j as usize] == k {
                pairs_l.push((lo + i) as u32);
                pairs_r.push(j);
                matched = true;
            }
        }
        if !matched && how == JoinType::Left {
            pairs_l.push((lo + i) as u32);
            pairs_r.push(MISS);
        }
    }
    (pairs_l, pairs_r)
}

/// [`hash_join_filled`] on an explicit thread pool: the CSR build runs
/// [`CsrIndex::build_par`] and the probe walks contiguous left-row
/// morsels concurrently.
///
/// **Determinism:** each morsel probes its left rows in ascending order
/// and emits a local pair list; concatenating the lists in morsel order
/// reproduces the sequential probe's output exactly, for any morsel
/// split — so the join is bit-identical to the single-threaded kernel.
#[allow(clippy::too_many_arguments)]
pub fn hash_join_filled_par(
    left: &Table,
    right: &Table,
    left_key: usize,
    right_key: usize,
    how: JoinType,
    fill: &FillPolicy,
    pool: &ThreadPool,
) -> Result<Table> {
    check_u32_rows(left, right)?;
    let lk = key_col(left, left_key)?;
    let rk = key_col(right, right_key)?;
    let index = CsrIndex::build_par(rk, pool);
    let nt = pool.size().min(lk.len() / par_min_rows()).max(1);
    let (pairs_l, pairs_r) = if nt <= 1 {
        probe_pairs(lk, rk, &index, how, 0)
    } else {
        // 4 morsels per worker: skewed keys make probe cost per morsel
        // uneven, and finer morsels rebalance without hurting the
        // deterministic merge (order is by morsel index either way).
        let morsels = morsel_ranges(lk.len(), nt * 4);
        let parts = pool.run_indexed(morsels.len(), |m| {
            let (lo, hi) = morsels[m];
            probe_pairs(&lk[lo..hi], rk, &index, how, lo)
        });
        let total = parts.iter().map(|(l, _)| l.len()).sum();
        let mut pairs_l: Vec<u32> = Vec::with_capacity(total);
        let mut pairs_r: Vec<u32> = Vec::with_capacity(total);
        for (l, r) in parts {
            pairs_l.extend_from_slice(&l);
            pairs_r.extend_from_slice(&r);
        }
        (pairs_l, pairs_r)
    };
    assemble(left, right, right_key, pairs_l, pairs_r, fill)
}

/// [`hash_join`] on an explicit thread pool (zeros fill).
pub fn hash_join_par(
    left: &Table,
    right: &Table,
    left_key: usize,
    right_key: usize,
    how: JoinType,
    pool: &ThreadPool,
) -> Result<Table> {
    hash_join_filled_par(
        left,
        right,
        left_key,
        right_key,
        how,
        &FillPolicy::zeros(),
        pool,
    )
}

/// Pre-CSR hash join: `HashMap<i64, Vec<u32>>` build side (one heap
/// allocation per distinct key). Kept as the `kernel_hotpaths` bench
/// baseline and as a bit-identical oracle for [`hash_join`] — same output
/// rows in the same order. Inner/left with the zeros fill.
pub fn hash_join_hashmap(
    left: &Table,
    right: &Table,
    left_key: usize,
    right_key: usize,
    how: JoinType,
) -> Result<Table> {
    check_u32_rows(left, right)?;
    let lk = key_col(left, left_key)?;
    let rk = key_col(right, right_key)?;

    let mut build: HashMap<i64, Vec<u32>, SplitMixBuild> =
        HashMap::with_capacity_and_hasher(rk.len(), SplitMixBuild);
    for (i, &k) in rk.iter().enumerate() {
        build.entry(k).or_default().push(i as u32);
    }

    let mut pairs_l: Vec<u32> = Vec::new();
    let mut pairs_r: Vec<u32> = Vec::new();
    for (i, &k) in lk.iter().enumerate() {
        match build.get(&k) {
            Some(matches) => {
                for &j in matches {
                    pairs_l.push(i as u32);
                    pairs_r.push(j);
                }
            }
            None => {
                if how == JoinType::Left {
                    pairs_l.push(i as u32);
                    pairs_r.push(MISS);
                }
            }
        }
    }
    assemble(left, right, right_key, pairs_l, pairs_r, &FillPolicy::zeros())
}

/// Sort-merge join (inner only): sorts both sides then merges match runs.
pub fn sort_merge_join(
    left: &Table,
    right: &Table,
    left_key: usize,
    right_key: usize,
) -> Result<Table> {
    check_u32_rows(left, right)?;
    let ls = sort_table(left, SortKey::asc(left_key))?;
    let rs = sort_table(right, SortKey::asc(right_key))?;
    let lk = key_col(&ls, left_key)?;
    let rk = key_col(&rs, right_key)?;

    let mut pairs_l: Vec<u32> = Vec::new();
    let mut pairs_r: Vec<u32> = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    while i < lk.len() && j < rk.len() {
        match lk[i].cmp(&rk[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                let key = lk[i];
                let i_end = i + lk[i..].iter().take_while(|&&k| k == key).count();
                let j_end = j + rk[j..].iter().take_while(|&&k| k == key).count();
                for ii in i..i_end {
                    for jj in j..j_end {
                        pairs_l.push(ii as u32);
                        pairs_r.push(jj as u32);
                    }
                }
                i = i_end;
                j = j_end;
            }
        }
    }
    assemble(&ls, &rs, right_key, pairs_l, pairs_r, &FillPolicy::zeros())
}

/// O(n·m) oracle used by the property tests.
pub fn nested_loop_join(
    left: &Table,
    right: &Table,
    left_key: usize,
    right_key: usize,
) -> Result<Table> {
    check_u32_rows(left, right)?;
    let lk = key_col(left, left_key)?;
    let rk = key_col(right, right_key)?;
    let mut pairs_l: Vec<u32> = Vec::new();
    let mut pairs_r: Vec<u32> = Vec::new();
    for (i, &a) in lk.iter().enumerate() {
        for (j, &b) in rk.iter().enumerate() {
            if a == b {
                pairs_l.push(i as u32);
                pairs_r.push(j as u32);
            }
        }
    }
    assemble(left, right, right_key, pairs_l, pairs_r, &FillPolicy::zeros())
}

/// Merge-key column the grace join prepends to its left partitions: the
/// global left row id, used to restore the in-memory probe's emission
/// order after partition-wise joins. Reserved — inputs may not use it.
const LROW: &str = "__lrow";

/// Budget-aware hash join over chunked inputs: joins in memory when the
/// sides fit the [`MemoryBudget`], and falls back to an out-of-core
/// **grace hash join** when they don't — hash-partition both sides into
/// spilled buckets, join bucket-pairs with the in-memory CSR kernel, and
/// k-way-merge the partition outputs back into global order.
///
/// **Bit-identity (partition-order argument).** The in-memory probe emits
/// left rows in ascending order, each with its matches in ascending
/// original right-row order (stable CSR bucket order). The grace path
/// reproduces that exactly:
///
/// 1. All matches of a left row live in exactly **one** partition (both
///    sides are partitioned by the same key hash).
/// 2. Partitioning is stable, so each partition holds its rows in
///    ascending original order on both sides; the partition-local CSR
///    bucket order therefore equals the global sub-order restricted to
///    the partition, and each per-partition join emits its rows in
///    ascending `__lrow` with matches in ascending original right order.
/// 3. The final k-way merge keyed on `__lrow` (unique per left row, and
///    present on [`JoinType::Left`] fill rows too) interleaves the
///    partition outputs back into ascending global left-row order; a
///    left row's contiguous match group never ties across streams, so
///    its internal order survives the merge untouched.
///
/// Hence the output is bit-identical to
/// [`hash_join_filled`]`(left.compact(), right.compact(), ..)` for every
/// budget, which the property tests assert.
///
/// Skew caveat: partitions are not recursively re-split, so an all-equal
/// key column degenerates to one partition and the budget is overdrafted
/// (recorded honestly in the peak) — the same rows would be resident for
/// the cross-product output anyway.
pub fn hash_join_budgeted(
    left: &ChunkedTable,
    right: &ChunkedTable,
    left_key: usize,
    right_key: usize,
    how: JoinType,
    fill: &FillPolicy,
    budget: &MemoryBudget,
) -> Result<ChunkedTable> {
    for (side, key) in [(left, left_key), (right, right_key)] {
        if key >= side.schema().len() {
            return Err(Error::DataFrame(format!(
                "join key column {key} out of range"
            )));
        }
        if side.schema().field(key).dtype != DataType::Int64 {
            return Err(Error::DataFrame(format!(
                "join key column {key} must be Int64, got {}",
                side.schema().field(key).dtype
            )));
        }
    }
    let l_bytes = left.byte_size() as u64;
    let r_bytes = right.byte_size() as u64;
    // Trip to grace when the build side alone would eat a quarter of the
    // budget, or both sides together half — the join also materializes
    // its output and the CSR index, so "fits" needs real headroom.
    let grace = match budget.limit() {
        Some(limit) => 4 * r_bytes > limit || 2 * (l_bytes + r_bytes) > limit,
        None => false,
    };
    if !grace {
        let _res = budget.reserve(2 * (l_bytes + r_bytes));
        let lt = left.compact();
        let rt = right.compact();
        return hash_join_filled(&lt, &rt, left_key, right_key, how, fill)
            .map(ChunkedTable::from);
    }
    grace_hash_join(left, right, left_key, right_key, how, fill, budget)
}

/// Hash-partition one side into per-partition spilled runs, streaming the
/// input chunk-by-chunk (one resident chunk plus its partition copies at
/// a time). `with_lrow` prepends the global row id column for the left
/// side; partitioning is stable (ascending row order within each chunk,
/// chunks in order), which the bit-identity argument relies on.
fn grace_partition_side(
    side: &ChunkedTable,
    key: usize,
    out_schema: &Schema,
    with_lrow: bool,
    npart: usize,
    budget: &MemoryBudget,
) -> Result<Vec<Option<SpilledTable>>> {
    let mask = (npart - 1) as u64;
    let mut writers: Vec<Option<RunWriter>> = (0..npart).map(|_| None).collect();
    let mut base = 0i64;
    for i in 0..side.chunk_list().len() {
        let t = side.load_chunk(i)?;
        // The chunk plus its partition sub-tables (~one copy of the chunk).
        let _res = budget.reserve(2 * t.byte_size() as u64);
        let keys = key_col(&t, key)?;
        let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); npart];
        for (row, &k) in keys.iter().enumerate() {
            buckets[(splitmix64(k as u64) & mask) as usize].push(row as u32);
        }
        for (p, rows) in buckets.iter().enumerate() {
            if rows.is_empty() {
                continue;
            }
            let sub = t.take_u32(rows);
            let part = if with_lrow {
                let lrow: Vec<i64> =
                    rows.iter().map(|&r| base + r as i64).collect();
                let mut cols = vec![Column::from_i64(lrow)];
                cols.extend(sub.columns().iter().cloned());
                Table::new(out_schema.clone(), cols)?
            } else {
                sub
            };
            if writers[p].is_none() {
                writers[p] = Some(RunWriter::create(out_schema.clone())?);
            }
            writers[p].as_mut().expect("just created").write_table(&part)?;
        }
        base += t.num_rows() as i64;
    }
    writers
        .into_iter()
        .map(|w| w.map(RunWriter::finish).transpose())
        .collect()
}

fn grace_hash_join(
    left: &ChunkedTable,
    right: &ChunkedTable,
    left_key: usize,
    right_key: usize,
    how: JoinType,
    fill: &FillPolicy,
    budget: &MemoryBudget,
) -> Result<ChunkedTable> {
    for s in [left.schema(), right.schema()] {
        if s.fields().iter().any(|f| f.name == LROW) {
            return Err(Error::DataFrame(format!(
                "grace join reserves the column name {LROW:?}"
            )));
        }
    }
    let limit = budget.limit().expect("grace path requires a bounded budget");
    let l_bytes = left.byte_size() as u64;
    let r_bytes = right.byte_size() as u64;
    // Size partitions so a bucket pair (~(l+r)/npart) fits in a quarter of
    // the budget, leaving room for the CSR index and the pair's output.
    let npart = (4 * (l_bytes + r_bytes))
        .div_ceil(limit.max(1))
        .next_power_of_two()
        .clamp(2, 256) as usize;

    // Left partition schema: global row id prepended.
    let mut lfields: Vec<(&str, DataType)> = vec![(LROW, DataType::Int64)];
    for f in left.schema().fields() {
        lfields.push((f.name.as_str(), f.dtype));
    }
    let lschema = Schema::of(&lfields);
    let rschema = right.schema().clone();

    let lruns =
        grace_partition_side(left, left_key, &lschema, true, npart, budget)?;
    let rruns =
        grace_partition_side(right, right_key, &rschema, false, npart, budget)?;

    // Per-partition join output schema (before the merge strips `__lrow`):
    // identical to what `hash_join_filled` produces for each bucket pair.
    let rm_fields: Vec<(&str, DataType)> = rschema
        .fields()
        .iter()
        .enumerate()
        .filter(|(j, _)| *j != right_key)
        .map(|(_, f)| (f.name.as_str(), f.dtype))
        .collect();
    let joined_schema = lschema.join(&Schema::of(&rm_fields));

    let out_block = (limit / (4 * npart as u64)).max(MIN_BLOCK_BYTES);
    let mut out_runs: Vec<SpilledTable> = Vec::new();
    let mut out_rows = 0u64;
    let mut out_bytes = 0u64;
    for p in 0..npart {
        // No left rows → no output rows (both join types are left-driven).
        let lst = match &lruns[p] {
            Some(st) => st,
            None => continue,
        };
        if how == JoinType::Inner && rruns[p].is_none() {
            continue;
        }
        let pair_bytes = lst.byte_size()
            + rruns[p].as_ref().map_or(0, SpilledTable::byte_size);
        let mut res = budget.reserve(pair_bytes as u64);
        let lp = lst.restore()?;
        let rp = match &rruns[p] {
            Some(st) => st.restore()?,
            None => Table::empty(rschema.clone()),
        };
        check_u32_rows(&lp, &rp)?;
        let joined =
            hash_join_filled(&lp, &rp, left_key + 1, right_key, how, fill)?;
        res.grow(joined.byte_size() as u64);
        if joined.num_rows() > 0 {
            // Already ascending in `__lrow`: the probe walks left rows in
            // partition order, which is ascending global order (stability).
            out_rows += joined.num_rows() as u64;
            out_bytes += joined.byte_size() as u64;
            out_runs.push(spill_in_blocks(&joined, out_block)?);
        }
    }

    let avg_row = (out_bytes / out_rows.max(1)).max(1);
    let spec = MergeSpec {
        key_col: 0,
        strip_key: true,
        out_chunk_rows: ((limit / 8) / avg_row).max(1) as usize,
        spill_outputs: true,
    };
    let streams = out_runs
        .into_iter()
        .map(|st| st.reader().map(BlockStream::Reader))
        .collect::<Result<Vec<_>>>()?;
    merge_block_streams(&joined_schema, streams, &spec, budget)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::df::{DataType, GenSpec, Schema, gen_two_tables};
    use crate::util::testkit;

    fn t(keys: Vec<i64>, vals: Vec<i64>) -> Table {
        Table::new(
            Schema::of(&[("key", DataType::Int64), ("v", DataType::Int64)]),
            vec![Column::from_i64(keys), Column::from_i64(vals)],
        )
        .unwrap()
    }

    #[test]
    fn inner_hash_join_basic() {
        let l = t(vec![1, 2, 3], vec![10, 20, 30]);
        let r = t(vec![2, 3, 3, 4], vec![200, 300, 301, 400]);
        let j = hash_join(&l, &r, 0, 0, JoinType::Inner).unwrap();
        assert_eq!(j.num_rows(), 3); // 2x1 + 3x2
        let names: Vec<&str> = j
            .schema()
            .fields()
            .iter()
            .map(|f| f.name.as_str())
            .collect();
        assert_eq!(names, vec!["key", "v", "v_right"]);
    }

    #[test]
    fn left_join_keeps_unmatched() {
        let l = t(vec![1, 5], vec![10, 50]);
        let r = t(vec![1], vec![100]);
        let j = hash_join(&l, &r, 0, 0, JoinType::Left).unwrap();
        assert_eq!(j.num_rows(), 2);
        // unmatched right value takes the default zero fill
        assert_eq!(j.column(2).as_i64().unwrap(), &[100, 0]);
    }

    #[test]
    fn left_join_fill_policy_is_explicit() {
        let l = t(vec![1, 5], vec![10, 50]);
        let r = t(vec![1], vec![100]);
        // Sentinel fill keeps unmatched rows recognizable.
        let j =
            hash_join_filled(&l, &r, 0, 0, JoinType::Left, &FillPolicy::sentinels())
                .unwrap();
        assert_eq!(j.column(2).as_i64().unwrap(), &[100, i64::MIN]);
        // Custom fill value.
        let fill = FillPolicy { int64: -7, ..FillPolicy::zeros() };
        let j = hash_join_filled(&l, &r, 0, 0, JoinType::Left, &fill).unwrap();
        assert_eq!(j.column(2).as_i64().unwrap(), &[100, -7]);
        // Inner joins never consult the policy.
        let a = hash_join_filled(
            &l, &r, 0, 0,
            JoinType::Inner,
            &FillPolicy::sentinels(),
        )
        .unwrap();
        let b = hash_join(&l, &r, 0, 0, JoinType::Inner).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn fill_policy_covers_all_dtypes() {
        let l = t(vec![1, 5], vec![10, 50]);
        let r = Table::new(
            Schema::of(&[
                ("key", DataType::Int64),
                ("f", DataType::Float64),
                ("s", DataType::Utf8),
                ("b", DataType::Bool),
            ]),
            vec![
                Column::from_i64(vec![1]),
                Column::from_f64(vec![1.25]),
                Column::from_utf8(&["hit"]),
                Column::from_bool(vec![true]),
            ],
        )
        .unwrap();
        let fill = FillPolicy {
            int64: -1,
            float64: -2.5,
            utf8: "<miss>".into(),
            bool_: false,
        };
        let j = hash_join_filled(&l, &r, 0, 0, JoinType::Left, &fill).unwrap();
        assert_eq!(j.num_rows(), 2);
        assert_eq!(j.column(2).as_f64().unwrap(), &[1.25, -2.5]);
        let s = j.column(3).as_utf8().unwrap();
        assert_eq!(s.iter().collect::<Vec<_>>(), vec!["hit", "<miss>"]);
        assert_eq!(j.column(4).as_bool().unwrap(), &[true, false]);
    }

    #[test]
    fn sort_merge_matches_hash() {
        let l = t(vec![5, 1, 5, 2], vec![1, 2, 3, 4]);
        let r = t(vec![5, 5, 2, 9], vec![7, 8, 9, 10]);
        let a = hash_join(&l, &r, 0, 0, JoinType::Inner).unwrap();
        let b = sort_merge_join(&l, &r, 0, 0).unwrap();
        assert_eq!(a.num_rows(), b.num_rows());
        assert_eq!(a.multiset_fingerprint(), b.multiset_fingerprint());
    }

    #[test]
    fn empty_sides() {
        let l = t(vec![], vec![]);
        let r = t(vec![1], vec![2]);
        assert_eq!(hash_join(&l, &r, 0, 0, JoinType::Inner).unwrap().num_rows(), 0);
        assert_eq!(hash_join(&r, &l, 0, 0, JoinType::Inner).unwrap().num_rows(), 0);
        assert_eq!(hash_join(&r, &l, 0, 0, JoinType::Left).unwrap().num_rows(), 1);
    }

    #[test]
    fn prop_joins_agree_with_oracle() {
        testkit::check("hash/smj == nested-loop", 24, |rng| {
            let n = 1 + rng.gen_range(60) as usize;
            let keys_l: Vec<i64> = (0..n).map(|_| rng.gen_i64(0, 20)).collect();
            let keys_r: Vec<i64> = (0..n).map(|_| rng.gen_i64(0, 20)).collect();
            let vals: Vec<i64> = (0..n as i64).collect();
            let l = t(keys_l, vals.clone());
            let r = t(keys_r, vals);
            let oracle = nested_loop_join(&l, &r, 0, 0).unwrap();
            let hj = hash_join(&l, &r, 0, 0, JoinType::Inner).unwrap();
            let smj = sort_merge_join(&l, &r, 0, 0).unwrap();
            assert_eq!(hj.num_rows(), oracle.num_rows());
            assert_eq!(smj.num_rows(), oracle.num_rows());
            assert_eq!(hj.multiset_fingerprint(), oracle.multiset_fingerprint());
            assert_eq!(smj.multiset_fingerprint(), oracle.multiset_fingerprint());
        });
    }

    #[test]
    fn prop_csr_join_is_bit_identical_to_hashmap_join() {
        // The CSR build/probe must reproduce the legacy map-based join
        // exactly — same rows in the same order, inner and left.
        testkit::check("csr join == hashmap join", 24, |rng| {
            let n = 1 + rng.gen_range(80) as usize;
            let keys_l: Vec<i64> = (0..n).map(|_| rng.gen_i64(-5, 15)).collect();
            let keys_r: Vec<i64> = (0..n).map(|_| rng.gen_i64(-5, 15)).collect();
            let vals: Vec<i64> = (0..n as i64).collect();
            let l = t(keys_l, vals.clone());
            let r = t(keys_r, vals);
            for how in [JoinType::Inner, JoinType::Left] {
                let csr = hash_join(&l, &r, 0, 0, how).unwrap();
                let legacy = hash_join_hashmap(&l, &r, 0, 0, how).unwrap();
                assert_eq!(csr, legacy, "{how:?}");
            }
        });
    }

    #[test]
    fn parallel_join_is_bit_identical_to_sequential() {
        // Straddle the morsel threshold; duplicate-heavy keys make the
        // pair order observable.
        let pmr = par_min_rows();
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            for n in [0usize, 64, pmr, 3 * pmr] {
                // ~6 duplicates per key at the largest n (order matters)
                // without exploding the inner-join output size.
                let keys_l: Vec<i64> =
                    (0..n as i64).map(|i| (i * 7) % 2048).collect();
                let keys_r: Vec<i64> =
                    (0..n as i64).map(|i| (i * 5) % 2048).collect();
                let vals: Vec<i64> = (0..n as i64).collect();
                let l = t(keys_l, vals.clone());
                let r = t(keys_r, vals);
                for how in [JoinType::Inner, JoinType::Left] {
                    let par = hash_join_par(&l, &r, 0, 0, how, &pool).unwrap();
                    let seq = hash_join_hashmap(&l, &r, 0, 0, how).unwrap();
                    assert_eq!(par, seq, "threads={threads} n={n} {how:?}");
                }
            }
        }
    }

    #[test]
    fn generated_tables_join() {
        let spec = GenSpec::uniform(300, 50, 11);
        let (l, r) = gen_two_tables(&spec, 0);
        let j = hash_join(&l, &r, 0, 0, JoinType::Inner).unwrap();
        assert!(j.num_rows() > 0, "overlapping key space must produce matches");
    }

    /// `chunks` chunks of `rows` rows with keys `(global_row * step) % modulus`
    /// — duplicate-heavy for small moduli, near-unique for large ones.
    fn chunked(step: i64, modulus: i64, chunks: usize, rows: usize) -> ChunkedTable {
        let parts: Vec<Table> = (0..chunks)
            .map(|c| {
                let base = (c * rows) as i64;
                let keys: Vec<i64> = (0..rows as i64)
                    .map(|i| ((base + i) * step) % modulus)
                    .collect();
                let vals: Vec<i64> = (0..rows as i64).map(|i| base + i).collect();
                t(keys, vals)
            })
            .collect();
        ChunkedTable::from_tables(parts).unwrap()
    }

    #[test]
    fn budgeted_join_graces_and_matches_in_memory() {
        let l = chunked(7, 97, 8, 64);
        let r = chunked(5, 97, 8, 64);
        let total = (l.byte_size() + r.byte_size()) as u64;
        let fill = FillPolicy::sentinels();
        for how in [JoinType::Inner, JoinType::Left] {
            let base = hash_join_filled(
                &l.compact(), &r.compact(), 0, 0, how, &fill,
            )
            .unwrap();
            // Unbounded: stays on the in-memory path, resident output.
            let unbounded = MemoryBudget::unbounded();
            let out =
                hash_join_budgeted(&l, &r, 0, 0, how, &fill, &unbounded).unwrap();
            assert!(out.chunk_list().iter().all(|c| !c.is_spilled()));
            assert_eq!(out.compact(), base, "{how:?} unbounded");
            // Bounded: the grace path must spill and stay bit-identical.
            for frac in [4u64, 16] {
                let budget = MemoryBudget::new(total / frac);
                let out =
                    hash_join_budgeted(&l, &r, 0, 0, how, &fill, &budget)
                        .unwrap();
                assert!(
                    out.chunk_list().iter().any(|c| c.is_spilled()),
                    "{how:?} 1/{frac} budget should spill its output"
                );
                assert_eq!(out.compact(), base, "{how:?} 1/{frac} budget");
            }
        }
    }

    #[test]
    fn budgeted_join_edge_shapes() {
        let fill = FillPolicy::zeros();
        let tight = MemoryBudget::new(64);

        // Empty left side: grace trips (right alone busts the budget) but
        // the output is empty with the joined schema intact.
        let schema =
            Schema::of(&[("key", DataType::Int64), ("v", DataType::Int64)]);
        let empty = ChunkedTable::empty(schema);
        let r = chunked(5, 97, 4, 32);
        let out = hash_join_budgeted(
            &empty, &r, 0, 0, JoinType::Inner, &fill, &tight,
        )
        .unwrap();
        assert_eq!(out.num_rows(), 0);
        let names: Vec<&str> = out
            .schema()
            .fields()
            .iter()
            .map(|f| f.name.as_str())
            .collect();
        assert_eq!(names, vec!["key", "v", "v_right"]);

        // All-equal keys collapse to one partition (documented overdraft)
        // but the cross product still matches the in-memory join exactly.
        let l1 = chunked(0, 97, 4, 32); // every key = 0
        let r1 = chunked(0, 97, 4, 32);
        let budget =
            MemoryBudget::new((l1.byte_size() + r1.byte_size()) as u64 / 8);
        let out = hash_join_budgeted(
            &l1, &r1, 0, 0, JoinType::Inner, &fill, &budget,
        )
        .unwrap();
        let base = hash_join(&l1.compact(), &r1.compact(), 0, 0, JoinType::Inner)
            .unwrap();
        assert_eq!(out.num_rows(), 128 * 128);
        assert_eq!(out.compact(), base);

        // The merge-key column name is reserved on the grace path only.
        let clash = ChunkedTable::from(
            Table::new(
                Schema::of(&[("key", DataType::Int64), (LROW, DataType::Int64)]),
                vec![Column::from_i64(vec![1; 64]), Column::from_i64(vec![2; 64])],
            )
            .unwrap(),
        );
        let r2 = chunked(5, 97, 2, 32);
        assert!(hash_join_budgeted(
            &clash, &r2, 0, 0, JoinType::Inner, &fill, &tight
        )
        .is_err());
        assert!(hash_join_budgeted(
            &clash,
            &r2,
            0,
            0,
            JoinType::Inner,
            &fill,
            &MemoryBudget::unbounded()
        )
        .is_ok());

        // Key validation happens before any spilling.
        let f = ChunkedTable::from(
            Table::new(
                Schema::of(&[("key", DataType::Float64)]),
                vec![Column::from_f64(vec![1.0])],
            )
            .unwrap(),
        );
        assert!(
            hash_join_budgeted(&f, &r2, 0, 0, JoinType::Inner, &fill, &tight)
                .is_err()
        );
        assert!(
            hash_join_budgeted(&r2, &r2, 9, 0, JoinType::Inner, &fill, &tight)
                .is_err()
        );
    }

    #[test]
    fn budgeted_join_peak_stays_under_ceiling() {
        // Near-unique keys keep partitions uniform so the ceiling is the
        // design's promise, not skew luck: budget + ~two input chunks of
        // working slack (resident chunk + its partition copies).
        let l = chunked(7, 4096, 8, 64);
        let r = chunked(5, 4096, 8, 64);
        let chunk_bytes = l.chunk(0).byte_size() as u64;
        let total = (l.byte_size() + r.byte_size()) as u64;
        let limit = total / 4;
        let budget = MemoryBudget::new(limit);
        let out = hash_join_budgeted(
            &l, &r, 0, 0, JoinType::Inner, &FillPolicy::zeros(), &budget,
        )
        .unwrap();
        let base = hash_join(&l.compact(), &r.compact(), 0, 0, JoinType::Inner)
            .unwrap();
        assert_eq!(out.compact(), base);
        assert!(
            budget.peak() <= limit + 2 * chunk_bytes,
            "peak {} exceeds ceiling {} (limit {limit} + 2x chunk {chunk_bytes})",
            budget.peak(),
            limit + 2 * chunk_bytes
        );
    }
}
