//! Local operators: execute solely on locally accessible data (paper §3.2).

pub(crate) use sort::{
    merge_block_streams, morsel_ranges, par_min_rows, BlockStream, MergeSpec,
    MIN_BLOCK_BYTES,
};

mod compute;
mod groupby;
mod join;
mod sort;
mod unique;

pub use compute::{
    binary_op, cast, compare_scalar, eval_expr, eval_mask, eval_predicate,
    filter_view, filter_view_expr, filter_view_expr_par, scalar_op_i64,
    with_column, BinOp, CmpOp,
};
pub use groupby::{groupby_agg, groupby_agg_hashmap, groupby_agg_par, AggFn};
pub use join::{
    hash_join, hash_join_budgeted, hash_join_filled, hash_join_filled_par,
    hash_join_hashmap, hash_join_par, nested_loop_join, sort_merge_join,
    FillPolicy, JoinType,
};
pub use sort::{
    is_sorted_by_key, merge_sorted, merge_sorted_par, merge_sorted_per_row,
    sort_table, sort_table_budgeted, sort_table_comparator, sort_table_multi,
    sort_table_par, SortKey,
};
pub use unique::{unique_by_key, unique_by_key_par, unique_rows};
