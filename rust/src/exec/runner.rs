//! Experiment runner: maps [`ExperimentConfig`]s onto engines and collects
//! paper-comparable statistics. Shared by the CLI (`radical-cylon run`) and
//! every bench target.

use crate::config::ExperimentConfig;
use crate::error::Result;
use crate::metrics::Stats;
use crate::ops::dist::KernelBackend;
use crate::ops::operator::{join_op, registry, sort_op, OpHandle};
use crate::pilot::{DataDist, TaskDescription};

use super::{
    BareMetalEngine, BatchEngine, Engine, EngineKind, HeterogeneousEngine,
    SuiteResult,
};

/// One row of a scaling sweep (one parallelism, `iterations` samples).
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub engine: EngineKind,
    pub parallelism: usize,
    pub rows_per_rank: usize,
    /// Per-iteration execution time (wall + simulated network), seconds.
    pub total: Stats,
    /// Per-iteration RP overhead (0 for bare-metal/batch), seconds.
    pub overhead: Stats,
    /// Tasks per second of overhead-free throughput (paper Table 2 col 4
    /// reports overhead as tasks/second of the overhead activity).
    pub output_rows: u64,
}

/// Resolve the experiment's operator through the process-wide registry.
/// An unknown name is a configuration error (`Error::Config`), never a
/// panic — the CLI surfaces it with the registered names listed.
fn op_of(config: &ExperimentConfig) -> Result<OpHandle> {
    registry().resolve(&config.op)
}

/// Task for one iteration of a single-op experiment at parallelism `p`.
/// Errors when the config names an operator the registry does not know.
pub fn task_for(
    config: &ExperimentConfig,
    p: usize,
    iter: usize,
) -> Result<TaskDescription> {
    let rows = config.rows_at(p);
    let mut td = TaskDescription::new(
        &format!("{}-{}-p{p}-i{iter}", config.op, config.scaling.name()),
        op_of(config)?,
        p,
        rows,
    );
    td.dist = DataDist::Uniform;
    td.seed = config.seed ^ (iter as u64) << 32 ^ p as u64;
    Ok(td)
}

/// Run a single-op scaling sweep on one engine kind.
pub fn run_scaling(
    config: &ExperimentConfig,
    kind: EngineKind,
    backend: &KernelBackend,
) -> Result<Vec<SweepRow>> {
    let machine = config.machine_spec()?;
    let mut rows = Vec::with_capacity(config.parallelisms.len());
    for &p in &config.parallelisms {
        let tasks: Vec<TaskDescription> = (0..config.iterations)
            .map(|i| task_for(config, p, i))
            .collect::<Result<_>>()?;
        let suite: SuiteResult = match kind {
            EngineKind::BareMetal => {
                BareMetalEngine::new(machine.clone(), backend.clone())
                    .run_suite(&tasks)?
            }
            EngineKind::Batch => BatchEngine::new(machine.clone(), backend.clone())
                .run_suite(&tasks)?,
            EngineKind::Heterogeneous => {
                HeterogeneousEngine::new(machine.clone(), backend.clone(), p)
                    .run_suite(&tasks)?
            }
        };
        let totals: Vec<f64> = suite
            .per_task
            .iter()
            .map(|r| r.measurement.total_s())
            .collect();
        let overheads: Vec<f64> = suite
            .per_task
            .iter()
            .map(|r| r.measurement.overhead.total())
            .collect();
        rows.push(SweepRow {
            engine: kind,
            parallelism: p,
            rows_per_rank: config.rows_at(p),
            total: Stats::from_samples(&totals),
            overhead: Stats::from_samples(&overheads),
            output_rows: suite.per_task.first().map(|r| r.output_rows).unwrap_or(0),
        });
    }
    Ok(rows)
}

/// Fig 5–8 comparison: BM-Cylon vs Radical-Cylon over the same sweep.
/// Returns `(bm_row, rp_row)` per parallelism.
pub fn run_bm_vs_rp(
    config: &ExperimentConfig,
    backend: &KernelBackend,
) -> Result<Vec<(SweepRow, SweepRow)>> {
    let bm = run_scaling(config, EngineKind::BareMetal, backend)?;
    let rp = run_scaling(config, EngineKind::Heterogeneous, backend)?;
    Ok(bm.into_iter().zip(rp).collect())
}

/// The heterogeneous 4-op workload of Fig 9 (join/sort × WS/SS) at
/// parallelism `p`, all inside one pilot.
pub fn hetero_workload(config: &ExperimentConfig, p: usize, iter: usize) -> Vec<TaskDescription> {
    let weak_rows = config.rows_per_rank;
    let strong_rows = config.total_rows.div_ceil(p.max(1));
    let seed = config.seed ^ (iter as u64) << 24;
    vec![
        TaskDescription::join(&format!("join-ws-i{iter}"), p, weak_rows, DataDist::Uniform)
            .with_seed(seed ^ 1),
        TaskDescription::sort(&format!("sort-ws-i{iter}"), p, weak_rows, DataDist::Uniform)
            .with_seed(seed ^ 2),
        TaskDescription::strong(&format!("join-ss-i{iter}"), join_op(), p, strong_rows * p)
            .with_seed(seed ^ 3),
        TaskDescription::strong(&format!("sort-ss-i{iter}"), sort_op(), p, strong_rows * p)
            .with_seed(seed ^ 4),
    ]
}

/// Heterogeneous-vs-batch comparison at one parallelism (Fig 10/11):
/// the same join+sort pair run through one pilot vs separate batch jobs.
#[derive(Clone, Debug)]
pub struct HeteroVsBatch {
    pub parallelism: usize,
    pub hetero_makespan: Stats,
    pub batch_makespan: Stats,
}

impl HeteroVsBatch {
    /// Paper Fig 11: improvement of heterogeneous over batch, percent.
    pub fn improvement_pct(&self) -> f64 {
        100.0 * (self.batch_makespan.mean - self.hetero_makespan.mean)
            / self.batch_makespan.mean
    }
}

/// Run the Fig 10 comparison: `reps` repetitions of (join+sort) through
/// both engines at each parallelism.
pub fn run_hetero_vs_batch(
    config: &ExperimentConfig,
    backend: &KernelBackend,
    reps: usize,
) -> Result<Vec<HeteroVsBatch>> {
    let machine = config.machine_spec()?;
    let mut out = Vec::new();
    for &p in &config.parallelisms {
        let mut hetero_samples = Vec::with_capacity(reps);
        let mut batch_samples = Vec::with_capacity(reps);
        for rep in 0..reps {
            let rows = config.rows_at(p);
            let pair = vec![
                TaskDescription::new(&format!("join-p{p}-r{rep}"), join_op(), p, rows)
                    .with_seed(config.seed ^ rep as u64),
                TaskDescription::new(&format!("sort-p{p}-r{rep}"), sort_op(), p, rows)
                    .with_seed(config.seed ^ rep as u64 ^ 0xABCD),
            ];
            let hetero =
                HeterogeneousEngine::new(machine.clone(), backend.clone(), p)
                    .run_suite(&pair)?;
            let batch = BatchEngine::new(machine.clone(), backend.clone())
                .run_suite(&pair)?;
            hetero_samples.push(hetero.makespan_s);
            batch_samples.push(batch.makespan_s);
        }
        out.push(HeteroVsBatch {
            parallelism: p,
            hetero_makespan: Stats::from_samples(&hetero_samples),
            batch_makespan: Stats::from_samples(&batch_samples),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    fn tiny(id: &str) -> ExperimentConfig {
        let mut c = preset(id).expect("preset");
        c.parallelisms = vec![2, 4];
        c.iterations = 2;
        c.rows_per_rank = 500;
        c.total_rows = 2000;
        c
    }

    #[test]
    fn scaling_sweep_runs_both_engines() {
        let c = tiny("fig5-weak");
        let backend = KernelBackend::Native;
        let bm = run_scaling(&c, EngineKind::BareMetal, &backend).unwrap();
        let rp = run_scaling(&c, EngineKind::Heterogeneous, &backend).unwrap();
        assert_eq!(bm.len(), 2);
        assert_eq!(rp.len(), 2);
        // BM carries no RP overhead; RP carries some.
        assert_eq!(bm[0].overhead.mean, 0.0);
        assert!(rp[0].overhead.mean >= 0.0);
        assert!(rp[0].total.mean > 0.0);
    }

    #[test]
    fn strong_scaling_rows_shrink() {
        let c = tiny("fig5-strong");
        assert!(c.rows_at(4) < c.rows_at(2));
        let row_tasks = task_for(&c, 4, 0).unwrap();
        assert_eq!(row_tasks.rows_per_rank, c.rows_at(4));
    }

    #[test]
    fn unknown_op_is_an_error_not_a_panic() {
        let mut c = tiny("fig5-weak");
        c.op = "frobnicate".into();
        let err = task_for(&c, 2, 0).unwrap_err().to_string();
        assert!(err.contains("unknown operator 'frobnicate'"), "{err}");
        let err = run_scaling(&c, EngineKind::BareMetal, &KernelBackend::Native)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown operator"), "{err}");
    }

    #[test]
    fn registry_ops_run_through_the_sweep() {
        // filter/project resolve from the registry and run end-to-end
        // distributed through the same sweep machinery as join/sort.
        for opname in ["filter", "project", "groupby"] {
            let mut c = tiny("fig5-weak");
            c.op = opname.into();
            c.parallelisms = vec![2];
            c.iterations = 1;
            let rows =
                run_scaling(&c, EngineKind::Heterogeneous, &KernelBackend::Native)
                    .unwrap();
            assert_eq!(rows.len(), 1, "{opname}");
            assert!(rows[0].output_rows > 0, "{opname} produced no rows");
        }
    }

    #[test]
    fn hetero_vs_batch_produces_improvement() {
        let c = tiny("fig10-weak");
        let rows =
            run_hetero_vs_batch(&c, &KernelBackend::Native, 2).unwrap();
        assert_eq!(rows.len(), 2);
        for r in rows {
            // hetero must not be slower than batch in the model
            assert!(
                r.improvement_pct() > -5.0,
                "p={} improvement {}",
                r.parallelism,
                r.improvement_pct()
            );
        }
    }

    #[test]
    fn hetero_workload_is_four_ops() {
        let c = tiny("fig9");
        let w = hetero_workload(&c, 4, 0);
        assert_eq!(w.len(), 4);
        assert!(w.iter().all(|t| t.ranks == 4));
    }
}
