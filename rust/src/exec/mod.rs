//! Execution engines — the paper's three comparison modes:
//!
//! * [`BareMetalEngine`] — BM-Cylon: each task launched directly on its own
//!   communicator (the `mpirun`/`srun` path), no pilot layer.
//! * [`BatchEngine`] — batch execution via the resource manager: every
//!   task is a separate job (LSF `bsub` semantics on Summit: whole nodes,
//!   queue latency per job, no resource sharing across jobs) — §4.3's
//!   baseline.
//! * [`HeterogeneousEngine`] — Radical-Cylon: one pilot, many tasks,
//!   private communicators, immediate rank reuse (§4.3's contribution).
//!
//! All engines consume the same [`TaskDescription`]s and produce
//! [`SuiteResult`]s with a comparable makespan model: real compute wall
//! time + simulated network seconds + modeled resource-manager latencies.
//!
//! Beyond flat task suites, the heterogeneous engine also drives task
//! *DAGs*: [`HeterogeneousEngine::run_pipeline`] executes a
//! [`crate::pipeline::Pipeline`] through the event-driven dataflow
//! scheduler (and [`HeterogeneousEngine::run_pipeline_waves`] through the
//! wave-barrier baseline), returning a [`PipelineSuite`] with per-node
//! scheduling metrics.

mod bare_metal;
mod batch;
mod hetero;
pub mod runner;

pub use bare_metal::BareMetalEngine;
pub use batch::BatchEngine;
pub use hetero::{HeterogeneousEngine, PipelineSuite};
pub use runner::{
    run_bm_vs_rp, run_hetero_vs_batch, run_scaling, HeteroVsBatch, SweepRow,
};

use crate::error::Result;
use crate::pilot::{TaskDescription, TaskResult};

/// Which engine produced a result (for report labels).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    BareMetal,
    Batch,
    Heterogeneous,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::BareMetal => "bare-metal",
            EngineKind::Batch => "batch",
            EngineKind::Heterogeneous => "radical-cylon",
        }
    }
}

/// Outcome of running a task suite through an engine.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub engine: EngineKind,
    pub per_task: Vec<TaskResult>,
    /// End-to-end modeled seconds: RM latencies + compute wall + simulated
    /// network time (see each engine's makespan docs).
    pub makespan_s: f64,
    /// Total modeled RM startup seconds paid (pilot or per-job).
    pub startup_s: f64,
}

impl SuiteResult {
    /// Sum of per-task execution times (wall + simulated network).
    pub fn total_exec_s(&self) -> f64 {
        self.per_task.iter().map(|r| r.measurement.total_s()).sum()
    }

    /// Mean per-task overhead (the paper's Table 2 "Overheads" column).
    pub fn mean_overhead_s(&self) -> f64 {
        if self.per_task.is_empty() {
            return 0.0;
        }
        self.per_task
            .iter()
            .map(|r| r.measurement.overhead.total())
            .sum::<f64>()
            / self.per_task.len() as f64
    }
}

/// Common engine interface used by benches and the CLI.
pub trait Engine {
    fn kind(&self) -> EngineKind;

    /// Run the suite to completion and report.
    fn run_suite(&self, tasks: &[TaskDescription]) -> Result<SuiteResult>;

    /// Run a single task (convenience).
    fn run_task(&self, task: &TaskDescription) -> Result<TaskResult> {
        let suite = self.run_suite(std::slice::from_ref(task))?;
        Ok(suite.per_task.into_iter().next().expect("one result"))
    }
}
