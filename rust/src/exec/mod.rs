//! Execution engines — the paper's three comparison modes:
//!
//! * [`BareMetalEngine`] — BM-Cylon: each task launched directly on its own
//!   communicator (the `mpirun`/`srun` path), no pilot layer.
//! * [`BatchEngine`] — batch execution via the resource manager: every
//!   task is a separate job (LSF `bsub` semantics on Summit: whole nodes,
//!   queue latency per job, no resource sharing across jobs) — §4.3's
//!   baseline.
//! * [`HeterogeneousEngine`] — Radical-Cylon: one pilot, many tasks,
//!   private communicators, immediate rank reuse (§4.3's contribution).
//!
//! All engines consume the same [`TaskDescription`]s and produce
//! [`SuiteResult`]s with a comparable makespan model: real compute wall
//! time + simulated network seconds + modeled resource-manager latencies.
//!
//! Beyond flat task suites, the heterogeneous engine also drives task
//! *DAGs*: [`HeterogeneousEngine::run_pipeline`] executes a
//! [`crate::pipeline::Pipeline`] through the event-driven dataflow
//! scheduler (and [`HeterogeneousEngine::run_pipeline_waves`] through the
//! wave-barrier baseline), returning a [`PipelineSuite`] with per-node
//! scheduling metrics.
//!
//! Logical plans ([`crate::plan::Plan`]) run on **any** engine through
//! [`Engine::run_plan`]: the default lowers the plan and executes the DAG
//! through the pooled dependency-counting executor when a thread pool is
//! configured (independent launches overlap on the driver host; handoff
//! threaded across launches), degrading to the serial topological walk at
//! parallelism 1 — identical results either way. The heterogeneous engine
//! overrides it with the dataflow scheduler on one pilot.

mod bare_metal;
mod batch;
mod hetero;
pub mod runner;

pub use bare_metal::BareMetalEngine;
pub use batch::BatchEngine;
pub use hetero::{HeterogeneousEngine, PipelineSuite};
pub use runner::{
    run_bm_vs_rp, run_hetero_vs_batch, run_scaling, HeteroVsBatch, SweepRow,
};

use std::sync::Arc;

use crate::df::ChunkedTable;
use crate::error::Result;
use crate::metrics::PipelineMetrics;
use crate::pilot::{TaskDescription, TaskResult};
use crate::plan::Plan;

/// Which engine produced a result (for report labels).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    BareMetal,
    Batch,
    Heterogeneous,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::BareMetal => "bare-metal",
            EngineKind::Batch => "batch",
            EngineKind::Heterogeneous => "radical-cylon",
        }
    }
}

/// Outcome of running a task suite through an engine.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub engine: EngineKind,
    pub per_task: Vec<TaskResult>,
    /// End-to-end modeled seconds: RM latencies + compute wall + simulated
    /// network time (see each engine's makespan docs).
    pub makespan_s: f64,
    /// Total modeled RM startup seconds paid (pilot or per-job).
    pub startup_s: f64,
}

impl SuiteResult {
    /// Sum of per-task execution times (wall + simulated network).
    pub fn total_exec_s(&self) -> f64 {
        self.per_task.iter().map(|r| r.measurement.total_s()).sum()
    }

    /// Mean per-task overhead (the paper's Table 2 "Overheads" column).
    pub fn mean_overhead_s(&self) -> f64 {
        if self.per_task.is_empty() {
            return 0.0;
        }
        self.per_task
            .iter()
            .map(|r| r.measurement.overhead.total())
            .sum::<f64>()
            / self.per_task.len() as f64
    }
}

/// Outcome of running a logical [`Plan`] through an engine.
#[derive(Clone, Debug)]
pub struct PlanRun {
    /// Per-node results in lowered-DAG node-id order.
    pub results: Vec<TaskResult>,
    /// The sink's gathered output table, present when the plan ended with
    /// [`Plan::collect`].
    pub output: Option<Arc<ChunkedTable>>,
    /// Scheduler accounting — `Some` on engines that drive the DAG through
    /// a pilot pipeline executor (heterogeneous), `None` on the
    /// independent-launch bare-metal/batch path (pooled or serial).
    pub metrics: Option<PipelineMetrics>,
}

/// Common engine interface used by benches and the CLI.
pub trait Engine {
    fn kind(&self) -> EngineKind;

    /// Run the suite to completion and report.
    fn run_suite(&self, tasks: &[TaskDescription]) -> Result<SuiteResult>;

    /// Run a single task (convenience).
    fn run_task(&self, task: &TaskDescription) -> Result<TaskResult> {
        let suite = self.run_suite(std::slice::from_ref(task))?;
        Ok(suite.per_task.into_iter().next().expect("one result"))
    }

    /// Lower a logical [`Plan`] and execute it on this engine.
    ///
    /// With a thread pool configured (`pool::parallelism() > 1`), the
    /// default drives the lowered DAG through the dependency-counting
    /// pooled executor ([`crate::pipeline::Pipeline::run_pooled`]):
    /// independent branches launch concurrently through
    /// [`Engine::run_task`], each still an independent launch with the
    /// table handoff wired on the scheduler thread — the right model for
    /// engines without a shared pilot (bare-metal, batch) on a
    /// multi-core driver host. At parallelism 1 it falls back to the
    /// serial topological walk
    /// ([`crate::pipeline::Pipeline::run_sequential`]); both paths return
    /// node-id-ordered results, so a deterministic engine yields identical
    /// `PlanRun`s either way. The heterogeneous engine overrides this with
    /// the event-driven dataflow scheduler on one pilot.
    fn run_plan(&self, plan: &Plan) -> Result<PlanRun>
    where
        Self: Sync,
    {
        let lowered = plan.lower()?;
        let results = if crate::util::pool::parallelism() > 1 {
            lowered.pipeline.run_pooled(
                crate::util::pool::global(),
                crate::raptor::ReadyPolicy::Fifo,
                |td| self.run_task(&td),
            )?
        } else {
            lowered
                .pipeline
                .run_sequential(|td| self.run_task(&td))?
        };
        let output = results[lowered.sink].output.clone();
        Ok(PlanRun { results, output, metrics: None })
    }
}
