//! Batch execution (§4.3's baseline): every task is an independent
//! resource-manager job — whole-node (exclusive) allocation, per-job queue
//! latency, and no resource sharing: "Each operation lacks control over the
//! hardware resources of the other operation, even if some workers finish
//! their tasks".

use crate::cluster::{rm_for, MachineSpec};
use crate::comm::CommWorld;
use crate::error::{Error, Result};
use crate::metrics::{ExecMeasurement, OverheadBreakdown};
use crate::ops::dist::KernelBackend;
use crate::pilot::{TaskDescription, TaskResult, TaskState};
use crate::raptor::run_cylon_task_full;

use super::{Engine, EngineKind, SuiteResult};

/// LSF-script-style batch engine. Jobs are serialized against the same
/// resource budget (the paper holds total resources equal between batch and
/// heterogeneous execution), so the makespan is the sum of per-job queue
/// latency + execution time. Plan DAGs go through [`Engine::run_plan`]'s
/// pooled default — independent jobs overlap on the driver host, while the
/// modeled makespan stays a per-job sum.
pub struct BatchEngine {
    machine: MachineSpec,
    backend: KernelBackend,
    /// Whole-node allocations (true = LSF `bsub` semantics; the default).
    exclusive: bool,
}

impl BatchEngine {
    pub fn new(machine: MachineSpec, backend: KernelBackend) -> BatchEngine {
        BatchEngine { machine, backend, exclusive: true }
    }

    pub fn core_granular(mut self) -> BatchEngine {
        self.exclusive = false;
        self
    }
}

impl Engine for BatchEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Batch
    }

    fn run_suite(&self, tasks: &[TaskDescription]) -> Result<SuiteResult> {
        let rm = rm_for(self.machine.clone());
        let mut per_task = Vec::with_capacity(tasks.len());
        let mut makespan = 0.0;
        let mut startup_total = 0.0;
        for (i, td) in tasks.iter().enumerate() {
            let alloc = rm.allocate(td.ranks, self.exclusive)?;
            let world = CommWorld::new(td.ranks, self.machine.netmodel());
            let td_owned = td.clone();
            let backend = self.backend.clone();
            let outcome = world
                .run(move |c| run_cylon_task_full(&c, &td_owned, &backend))?
                .into_iter()
                .next()
                .ok_or_else(|| Error::TaskFailed("empty world".into()))??;
            let stats = outcome.stats;
            rm.release(&alloc);
            let m = ExecMeasurement {
                label: td.name.clone(),
                parallelism: td.ranks,
                wall_s: stats.wall_s,
                sim_net_s: stats.sim_net_s,
                overhead: OverheadBreakdown::default(),
            };
            // Batch pays the queue for *every* job; idle tail cores of the
            // exclusive allocation are simply wasted (no reuse).
            makespan += alloc.startup_latency + m.total_s();
            startup_total += alloc.startup_latency;
            per_task.push(TaskResult {
                task_id: i as u64 + 1,
                name: td.name.clone(),
                state: TaskState::Done,
                measurement: m,
                output_rows: stats.output_rows,
                output: outcome.output.map(std::sync::Arc::new),
                error: None,
            });
        }
        Ok(SuiteResult {
            engine: EngineKind::Batch,
            per_task,
            makespan_s: makespan,
            startup_s: startup_total,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pilot::DataDist;

    #[test]
    fn runs_suite_with_per_job_latency() {
        let eng = BatchEngine::new(MachineSpec::summit(), KernelBackend::Native);
        let suite = eng
            .run_suite(&[
                TaskDescription::join("j", 8, 50, DataDist::Uniform),
                TaskDescription::sort("s", 8, 50, DataDist::Uniform),
            ])
            .unwrap();
        assert_eq!(suite.per_task.len(), 2);
        // Two jobs -> two queue latencies.
        assert!(suite.startup_s > 0.0);
        assert!(suite.makespan_s > suite.total_exec_s());
    }

    #[test]
    fn exclusive_vs_core_granular() {
        // Exclusive on a 1-node machine cannot run two jobs if the node is
        // dirty; core-granular can pack. Here we just verify both modes run.
        let m = MachineSpec::summit();
        let a = BatchEngine::new(m.clone(), KernelBackend::Native);
        let b = BatchEngine::new(m, KernelBackend::Native).core_granular();
        let td = TaskDescription::sort("s", 4, 30, DataDist::Uniform);
        assert!(a.run_suite(std::slice::from_ref(&td)).is_ok());
        assert!(b.run_suite(std::slice::from_ref(&td)).is_ok());
    }
}
