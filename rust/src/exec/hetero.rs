//! Heterogeneous execution — Radical-Cylon proper (§4.3): one pilot, many
//! Cylon tasks as RP tasks, private communicators, immediate rank reuse.
//!
//! "Immediate rank reuse" is delivered by two cooperating layers: the
//! RAPTOR master recycles a retiring task's ranks into its queue the moment
//! the completion report lands ([`crate::raptor`]), and the dataflow
//! pipeline executor ([`crate::pipeline`]) feeds that queue the instant a
//! DAG node's dependencies resolve — no wave barrier ever holds ready work
//! back from freed ranks.

use std::time::Instant;

use crate::cluster::MachineSpec;
use crate::error::Result;
use crate::metrics::PipelineMetrics;
use crate::ops::dist::KernelBackend;
use crate::pilot::{PilotDescription, Session, TaskDescription, TaskResult};
use crate::pipeline::Pipeline;
use crate::raptor::{ReadyPolicy, SchedPolicy};

use super::{Engine, EngineKind, PlanRun, SuiteResult};
use crate::plan::Plan;

/// Outcome of driving a [`Pipeline`] through the heterogeneous engine.
#[derive(Clone, Debug)]
pub struct PipelineSuite {
    /// Per-node results in node-id order.
    pub per_task: Vec<TaskResult>,
    /// Scheduler accounting (per-node timings, critical path, idle share).
    pub metrics: PipelineMetrics,
    /// End-to-end modeled seconds: pilot startup + real makespan +
    /// resource-share-weighted simulated network seconds.
    pub makespan_s: f64,
    pub startup_s: f64,
    /// Ranks the backing pilot held (for idle-fraction accounting).
    pub pilot_ranks: usize,
}

impl PipelineSuite {
    /// Idle fraction of the pilot over the DAG's makespan.
    pub fn idle_fraction(&self) -> f64 {
        self.metrics.idle_fraction(self.pilot_ranks)
    }
}

/// One-pilot heterogeneous engine.
///
/// Makespan model: single pilot queue latency + real suite wall time
/// (captures task overlap on disjoint rank groups) + resource-share-weighted
/// simulated network seconds (`sim_i * ranks_i / pilot_ranks`, which reduces
/// to the sequential sum when tasks span the whole pilot).
pub struct HeterogeneousEngine {
    machine: MachineSpec,
    backend: KernelBackend,
    pilot_ranks: usize,
    policy: SchedPolicy,
    ready_policy: ReadyPolicy,
}

impl HeterogeneousEngine {
    pub fn new(
        machine: MachineSpec,
        backend: KernelBackend,
        pilot_ranks: usize,
    ) -> HeterogeneousEngine {
        HeterogeneousEngine {
            machine,
            backend,
            pilot_ranks,
            policy: SchedPolicy::Backfill,
            ready_policy: ReadyPolicy::Fifo,
        }
    }

    pub fn with_policy(mut self, policy: SchedPolicy) -> HeterogeneousEngine {
        self.policy = policy;
        self
    }

    /// Ready-set ordering used by [`HeterogeneousEngine::run_pipeline`].
    pub fn with_ready_policy(mut self, policy: ReadyPolicy) -> HeterogeneousEngine {
        self.ready_policy = policy;
        self
    }

    pub fn pilot_ranks(&self) -> usize {
        self.pilot_ranks
    }

    /// Submit this engine's pilot into `session`.
    fn submit_pilot(&self, session: &Session) -> Result<std::sync::Arc<crate::pilot::Pilot>> {
        // Core-granular pilot sized to the workload; the pilot itself is
        // still one RM job (exclusive whole-node on LSF machines).
        let mut pd = PilotDescription::with_cores(self.machine.clone(), self.pilot_ranks);
        pd.exclusive = self.machine.name == "summit";
        session
            .pilot_manager()
            .submit_with(pd, self.backend.clone(), self.policy)
    }

    /// Resource-share-weighted simulated seconds (see struct docs).
    fn sim_weighted(&self, per_task: &[TaskResult], pilot_cores: f64) -> f64 {
        per_task
            .iter()
            .map(|r| {
                r.measurement.sim_net_s * r.measurement.parallelism as f64
                    / pilot_cores
            })
            .sum()
    }

    /// Drive a task DAG through one pilot with the event-driven dataflow
    /// scheduler (§4.4's "independent branches ... executed parallelly").
    pub fn run_pipeline(&self, dag: &Pipeline) -> Result<PipelineSuite> {
        self.run_pipeline_inner(dag, true)
    }

    /// Same DAG through the wave-barrier baseline executor — kept so
    /// `benches/pipeline_dataflow.rs` can measure what the barrier costs.
    pub fn run_pipeline_waves(&self, dag: &Pipeline) -> Result<PipelineSuite> {
        self.run_pipeline_inner(dag, false)
    }

    fn run_pipeline_inner(&self, dag: &Pipeline, dataflow: bool) -> Result<PipelineSuite> {
        let session = Session::new("hetero-pipeline");
        let pilot = self.submit_pilot(&session)?;
        let startup = pilot.startup_latency();
        let tm = session.task_manager(&pilot);
        let run = if dataflow {
            dag.run_dataflow(&tm, self.ready_policy)?
        } else {
            dag.run_waves(&tm)?
        };
        pilot.shutdown();
        let sim = self.sim_weighted(&run.results, pilot.cores() as f64);
        Ok(PipelineSuite {
            makespan_s: startup + run.metrics.makespan_s + sim,
            startup_s: startup,
            pilot_ranks: self.pilot_ranks,
            per_task: run.results,
            metrics: run.metrics,
        })
    }
}

impl Engine for HeterogeneousEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Heterogeneous
    }

    /// Lower the plan and drive it through the event-driven dataflow
    /// scheduler on one pilot (piped handoff, immediate rank reuse) —
    /// overriding the default independent-launch walk. Tasks here are
    /// **not** run sequentially: the RAPTOR master overlaps every ready
    /// node on free pilot ranks, and inside each task the rank loop and
    /// the data-plane kernels are morsel-parallel on the shared pool.
    fn run_plan(&self, plan: &Plan) -> Result<PlanRun> {
        let lowered = plan.lower()?;
        let suite = self.run_pipeline(&lowered.pipeline)?;
        Ok(PlanRun {
            output: suite.per_task[lowered.sink].output.clone(),
            results: suite.per_task,
            metrics: Some(suite.metrics),
        })
    }

    fn run_suite(&self, tasks: &[TaskDescription]) -> Result<SuiteResult> {
        let session = Session::new("hetero-engine");
        let pilot = self.submit_pilot(&session)?;
        let startup = pilot.startup_latency();

        let tm = session.task_manager(&pilot);
        let t0 = Instant::now();
        let handles = tm.submit_all(tasks.to_vec())?;
        let mut per_task = tm.wait_all(&handles)?;
        let suite_wall = t0.elapsed().as_secs_f64();
        pilot.shutdown();

        let sim_weighted = self.sim_weighted(&per_task, pilot.cores() as f64);
        // Keep task ids aligned with submission order for reporting.
        for (i, r) in per_task.iter_mut().enumerate() {
            r.task_id = i as u64 + 1;
        }
        Ok(SuiteResult {
            engine: EngineKind::Heterogeneous,
            per_task,
            makespan_s: startup + suite_wall + sim_weighted,
            startup_s: startup,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pilot::DataDist;

    fn tasks(ranks: usize) -> Vec<TaskDescription> {
        vec![
            TaskDescription::join("join-ws", ranks, 80, DataDist::Uniform),
            TaskDescription::sort("sort-ws", ranks, 80, DataDist::Uniform),
        ]
    }

    #[test]
    fn one_pilot_many_tasks() {
        let eng = HeterogeneousEngine::new(
            MachineSpec::local(4),
            KernelBackend::Native,
            4,
        );
        let suite = eng.run_suite(&tasks(4)).unwrap();
        assert_eq!(suite.per_task.len(), 2);
        assert!(suite.per_task.iter().all(|r| r.is_done()));
        // RP overhead exists but is small relative to execution.
        assert!(suite.mean_overhead_s() >= 0.0);
    }

    #[test]
    fn pays_one_startup_for_many_tasks() {
        let machine = MachineSpec::summit();
        let hetero =
            HeterogeneousEngine::new(machine.clone(), KernelBackend::Native, 8);
        let suite = hetero.run_suite(&tasks(8)).unwrap();
        // Single pilot => a single startup charge.
        let batch = super::super::BatchEngine::new(machine, KernelBackend::Native);
        let bsuite = batch.run_suite(&tasks(8)).unwrap();
        assert!(
            suite.startup_s < bsuite.startup_s,
            "hetero {} !< batch {}",
            suite.startup_s,
            bsuite.startup_s
        );
    }

    #[test]
    fn concurrent_small_tasks_overlap() {
        // Two 2-rank tasks on a 4-rank pilot should overlap in real time.
        let eng = HeterogeneousEngine::new(
            MachineSpec::local(4),
            KernelBackend::Native,
            4,
        );
        let tds = vec![
            TaskDescription::sort("a", 2, 2000, DataDist::Uniform),
            TaskDescription::sort("b", 2, 2000, DataDist::Uniform),
        ];
        let suite = eng.run_suite(&tds).unwrap();
        assert!(suite.per_task.iter().all(|r| r.is_done()));
    }

    #[test]
    fn plan_through_all_engines_agrees() {
        use crate::df::GenSpec;
        use crate::plan::expr::{col, lit};

        let plan = || {
            Plan::generate(2, GenSpec::uniform(200, 128, 0xE71))
                .filter(col("val").ge(lit(0.5)))
                .sort("key")
                .collect()
        };
        let machine = MachineSpec::local(4);
        let hetero =
            HeterogeneousEngine::new(machine.clone(), KernelBackend::Native, 4);
        let h = hetero.run_plan(&plan()).unwrap();
        assert!(h.metrics.is_some(), "pipeline path reports metrics");
        let bm = super::super::BareMetalEngine::new(machine.clone(), KernelBackend::Native);
        let b = bm.run_plan(&plan()).unwrap();
        assert!(b.metrics.is_none(), "sequential path has no DAG metrics");
        let batch = super::super::BatchEngine::new(machine, KernelBackend::Native)
            .core_granular();
        let q = batch.run_plan(&plan()).unwrap();

        let fp = |run: &PlanRun| {
            run.output
                .as_ref()
                .expect("collected sink output")
                .multiset_fingerprint()
        };
        assert!(fp(&h) > 0);
        assert_eq!(fp(&h), fp(&b), "hetero vs bare-metal");
        assert_eq!(fp(&h), fp(&q), "hetero vs batch");
        assert_eq!(h.results.len(), 3);
    }

    #[test]
    fn pipeline_through_engine() {
        let eng = HeterogeneousEngine::new(
            MachineSpec::local(4),
            KernelBackend::Native,
            4,
        );
        let mut dag = Pipeline::new();
        let a = dag.add(TaskDescription::sort("a", 2, 100, DataDist::Uniform), &[]);
        let b = dag.add(TaskDescription::sort("b", 2, 100, DataDist::Uniform), &[]);
        let _c = dag.add(
            TaskDescription::join("c", 4, 100, DataDist::Uniform),
            &[a, b],
        );
        let suite = eng.run_pipeline(&dag).unwrap();
        assert_eq!(suite.per_task.len(), 3);
        assert!(suite.per_task.iter().all(|r| r.is_done()));
        assert!(suite.makespan_s >= suite.metrics.makespan_s);
        assert!((0.0..=1.0).contains(&suite.idle_fraction()));

        // The wave baseline produces the same outputs on the same DAG.
        let wave = eng.run_pipeline_waves(&dag).unwrap();
        for (d, w) in suite.per_task.iter().zip(&wave.per_task) {
            assert_eq!(d.output_rows, w.output_rows, "node {}", d.name);
        }
    }
}
