//! Heterogeneous execution — Radical-Cylon proper (§4.3): one pilot, many
//! Cylon tasks as RP tasks, private communicators, immediate rank reuse.

use std::time::Instant;

use crate::cluster::MachineSpec;
use crate::error::Result;
use crate::ops::dist::KernelBackend;
use crate::pilot::{PilotDescription, Session, TaskDescription};
use crate::raptor::SchedPolicy;

use super::{Engine, EngineKind, SuiteResult};

/// One-pilot heterogeneous engine.
///
/// Makespan model: single pilot queue latency + real suite wall time
/// (captures task overlap on disjoint rank groups) + resource-share-weighted
/// simulated network seconds (`sim_i * ranks_i / pilot_ranks`, which reduces
/// to the sequential sum when tasks span the whole pilot).
pub struct HeterogeneousEngine {
    machine: MachineSpec,
    backend: KernelBackend,
    pilot_ranks: usize,
    policy: SchedPolicy,
}

impl HeterogeneousEngine {
    pub fn new(
        machine: MachineSpec,
        backend: KernelBackend,
        pilot_ranks: usize,
    ) -> HeterogeneousEngine {
        HeterogeneousEngine {
            machine,
            backend,
            pilot_ranks,
            policy: SchedPolicy::Backfill,
        }
    }

    pub fn with_policy(mut self, policy: SchedPolicy) -> HeterogeneousEngine {
        self.policy = policy;
        self
    }

    pub fn pilot_ranks(&self) -> usize {
        self.pilot_ranks
    }
}

impl Engine for HeterogeneousEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Heterogeneous
    }

    fn run_suite(&self, tasks: &[TaskDescription]) -> Result<SuiteResult> {
        let session = Session::new("hetero-engine");
        // Core-granular pilot sized to the workload; the pilot itself is
        // still one RM job (exclusive whole-node on LSF machines).
        let mut pd = PilotDescription::with_cores(self.machine.clone(), self.pilot_ranks);
        pd.exclusive = self.machine.name == "summit";
        let pilot = session.pilot_manager().submit_with(
            pd,
            self.backend.clone(),
            self.policy,
        )?;
        let startup = pilot.startup_latency();

        let tm = session.task_manager(&pilot);
        let t0 = Instant::now();
        let handles = tm.submit_all(tasks.to_vec())?;
        let mut per_task = tm.wait_all(&handles)?;
        let suite_wall = t0.elapsed().as_secs_f64();
        pilot.shutdown();

        // Resource-share-weighted simulated seconds (see struct docs).
        let pilot_cores = pilot.cores() as f64;
        let sim_weighted: f64 = per_task
            .iter()
            .map(|r| {
                r.measurement.sim_net_s * r.measurement.parallelism as f64
                    / pilot_cores
            })
            .sum();
        // Keep task ids aligned with submission order for reporting.
        for (i, r) in per_task.iter_mut().enumerate() {
            r.task_id = i as u64 + 1;
        }
        Ok(SuiteResult {
            engine: EngineKind::Heterogeneous,
            per_task,
            makespan_s: startup + suite_wall + sim_weighted,
            startup_s: startup,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pilot::DataDist;

    fn tasks(ranks: usize) -> Vec<TaskDescription> {
        vec![
            TaskDescription::join("join-ws", ranks, 80, DataDist::Uniform),
            TaskDescription::sort("sort-ws", ranks, 80, DataDist::Uniform),
        ]
    }

    #[test]
    fn one_pilot_many_tasks() {
        let eng = HeterogeneousEngine::new(
            MachineSpec::local(4),
            KernelBackend::Native,
            4,
        );
        let suite = eng.run_suite(&tasks(4)).unwrap();
        assert_eq!(suite.per_task.len(), 2);
        assert!(suite.per_task.iter().all(|r| r.is_done()));
        // RP overhead exists but is small relative to execution.
        assert!(suite.mean_overhead_s() >= 0.0);
    }

    #[test]
    fn pays_one_startup_for_many_tasks() {
        let machine = MachineSpec::summit();
        let hetero =
            HeterogeneousEngine::new(machine.clone(), KernelBackend::Native, 8);
        let suite = hetero.run_suite(&tasks(8)).unwrap();
        // Single pilot => a single startup charge.
        let batch = super::super::BatchEngine::new(machine, KernelBackend::Native);
        let bsuite = batch.run_suite(&tasks(8)).unwrap();
        assert!(
            suite.startup_s < bsuite.startup_s,
            "hetero {} !< batch {}",
            suite.startup_s,
            bsuite.startup_s
        );
    }

    #[test]
    fn concurrent_small_tasks_overlap() {
        // Two 2-rank tasks on a 4-rank pilot should overlap in real time.
        let eng = HeterogeneousEngine::new(
            MachineSpec::local(4),
            KernelBackend::Native,
            4,
        );
        let tds = vec![
            TaskDescription::sort("a", 2, 2000, DataDist::Uniform),
            TaskDescription::sort("b", 2, 2000, DataDist::Uniform),
        ];
        let suite = eng.run_suite(&tds).unwrap();
        assert!(suite.per_task.iter().all(|r| r.is_done()));
    }
}
