//! BM-Cylon: direct BSP launch of each task on a dedicated world — the
//! baseline the paper compares Radical-Cylon against in §4.1/4.2.

use crate::cluster::{rm_for, MachineSpec};
use crate::comm::CommWorld;
use crate::error::{Error, Result};
use crate::metrics::{ExecMeasurement, OverheadBreakdown};
use crate::ops::dist::KernelBackend;
use crate::pilot::{TaskDescription, TaskResult, TaskState};
use crate::raptor::run_cylon_task_full;

use super::{Engine, EngineKind, SuiteResult};

/// Bare-metal engine: per-task `srun`-style launch (suite tasks run
/// sequentially, each on a fresh full-width communicator; each launch pays
/// the machine's dispatch latency, but there is no pilot/RAPTOR overhead).
/// Plan DAGs go through [`Engine::run_plan`]'s pooled default, which
/// overlaps independent launches on the driver host when a thread pool is
/// configured.
pub struct BareMetalEngine {
    machine: MachineSpec,
    backend: KernelBackend,
}

impl BareMetalEngine {
    pub fn new(machine: MachineSpec, backend: KernelBackend) -> BareMetalEngine {
        BareMetalEngine { machine, backend }
    }
}

impl Engine for BareMetalEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::BareMetal
    }

    fn run_suite(&self, tasks: &[TaskDescription]) -> Result<SuiteResult> {
        let rm = rm_for(self.machine.clone());
        let mut per_task = Vec::with_capacity(tasks.len());
        let mut makespan = 0.0;
        let mut startup_total = 0.0;
        for (i, td) in tasks.iter().enumerate() {
            // srun-equivalent: allocate, run BSP across all ranks, release.
            let alloc = rm.allocate(td.ranks, false)?;
            let world = CommWorld::new(td.ranks, self.machine.netmodel());
            let td_owned = td.clone();
            let backend = self.backend.clone();
            let outcome = world
                .run(move |c| run_cylon_task_full(&c, &td_owned, &backend))?
                .into_iter()
                .next()
                .ok_or_else(|| Error::TaskFailed("empty world".into()))??;
            let stats = outcome.stats;
            rm.release(&alloc);
            let m = ExecMeasurement {
                label: td.name.clone(),
                parallelism: td.ranks,
                wall_s: stats.wall_s,
                sim_net_s: stats.sim_net_s,
                overhead: OverheadBreakdown::default(), // no RP layer
            };
            makespan += alloc.startup_latency + m.total_s();
            startup_total += alloc.startup_latency;
            per_task.push(TaskResult {
                task_id: i as u64 + 1,
                name: td.name.clone(),
                state: TaskState::Done,
                measurement: m,
                output_rows: stats.output_rows,
                output: outcome.output.map(std::sync::Arc::new),
                error: None,
            });
        }
        Ok(SuiteResult {
            engine: EngineKind::BareMetal,
            per_task,
            makespan_s: makespan,
            startup_s: startup_total,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pilot::DataDist;

    #[test]
    fn runs_join_and_sort() {
        let eng = BareMetalEngine::new(MachineSpec::local(4), KernelBackend::Native);
        let suite = eng
            .run_suite(&[
                TaskDescription::join("j", 4, 100, DataDist::Uniform),
                TaskDescription::sort("s", 4, 100, DataDist::Uniform),
            ])
            .unwrap();
        assert_eq!(suite.per_task.len(), 2);
        assert!(suite.per_task.iter().all(|r| r.is_done()));
        assert!(suite.makespan_s > 0.0);
        // BM has zero RP overhead by construction.
        assert_eq!(suite.mean_overhead_s(), 0.0);
    }

    #[test]
    fn task_larger_than_machine_fails() {
        let eng = BareMetalEngine::new(MachineSpec::local(2), KernelBackend::Native);
        let err = eng
            .run_suite(&[TaskDescription::sort("big", 3, 10, DataDist::Uniform)])
            .unwrap_err();
        assert!(err.to_string().contains("cannot satisfy"));
    }
}
