//! The RAPTOR master: task intake, rank grouping, private-communicator
//! context allocation, dispatch, result collection, rank recycling —
//! plus the fault-tolerance duties layered on in the same event loop:
//! per-task **deadlines** (a watchdog scan marks overdue tasks Failed
//! with a transient [`Error::Timeout`]), **rank quarantine** (the ranks
//! of a timed-out task stay unavailable until their late report finally
//! arrives — they may still be wedged inside a collective), and
//! **re-planning** (a queued task that wants more ranks than are
//! currently healthy is narrowed onto the survivors via its operator's
//! `plan_ranks` hook instead of waiting forever).

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::Error;
use crate::metrics::OverheadBreakdown;
use crate::ops::dist::KernelBackend;
use crate::pilot::{RankClass, TaskDescription, TaskHandle, TaskState};
use crate::util::faults;

use super::agent::SchedPolicy;
use super::cylon_task::RankStats;

/// Watchdog granularity: how often the master wakes to scan for overdue
/// tasks while any running task carries a deadline. (With no deadlines
/// armed the master blocks indefinitely — zero idle wakeups.)
const WATCHDOG_TICK: Duration = Duration::from_millis(25);

/// Shared resource-usage tracker (paper §4.4 "resource tracking"):
/// busy-rank-nanoseconds accumulated by the master, readable from the
/// pilot while the agent runs.
#[derive(Default)]
pub struct Utilization {
    busy_rank_ns: AtomicU64,
    tasks_done: AtomicU64,
    /// Ranks currently quarantined after a deadline expiry (gauge).
    quarantined: AtomicU64,
}

impl Utilization {
    pub fn busy_rank_seconds(&self) -> f64 {
        self.busy_rank_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn tasks_done(&self) -> u64 {
        self.tasks_done.load(Ordering::Relaxed)
    }

    /// Ranks currently quarantined (held by a timed-out task whose late
    /// report has not yet arrived). Drops back as stragglers report in.
    pub fn quarantined_ranks(&self) -> u64 {
        self.quarantined.load(Ordering::Relaxed)
    }

    fn record(&self, ranks: usize, busy: std::time::Duration) {
        self.busy_rank_ns.fetch_add(
            (busy.as_nanos() as u64).saturating_mul(ranks as u64),
            Ordering::Relaxed,
        );
        self.tasks_done.fetch_add(1, Ordering::Relaxed);
    }
}

/// Work order delivered to every selected worker (paper Fig 3-6: the worker
/// "isolates a set of MPI-Ranks ... and groups them to construct a private
/// MPI-Communicator and deliver it to the task during runtime").
#[derive(Clone)]
pub struct WorkOrder {
    pub task_id: u64,
    pub td: TaskDescription,
    /// Fresh context id for the private communicator.
    pub ctx_id: u64,
    /// World ranks participating (sorted).
    pub world_ranks: Vec<usize>,
    pub backend: KernelBackend,
}

/// Group-rank-0's report back to the master.
#[derive(Clone, Debug)]
pub struct RankReport {
    pub task_id: u64,
    pub stats: RankStats,
    /// Private-communicator construction seconds (real rendezvous + modeled
    /// barrier), max across the group.
    pub comm_construction_s: f64,
    /// Gathered output table when the description requested `keep_output`
    /// (pipeline table handoff) — zero-copy chunks, one per group rank.
    pub output: Option<crate::df::ChunkedTable>,
    pub error: Option<String>,
}

/// Messages the master consumes (submissions, completions, shutdown).
pub enum MasterMsg {
    Submit {
        handle: TaskHandle,
        td: TaskDescription,
        /// Seconds the TaskManager spent describing/serializing the task.
        description_s: f64,
    },
    TaskComplete(RankReport),
    Shutdown,
}

/// Control messages to workers.
pub enum WorkerCtl {
    Exec(WorkOrder),
    Shutdown,
}

struct Pending {
    handle: TaskHandle,
    td: TaskDescription,
    description_s: f64,
    enqueued: Instant,
    seq: u64,
}

struct Running {
    handle: TaskHandle,
    overhead: OverheadBreakdown,
    parallelism: usize,
    ranks: Vec<usize>,
    name: String,
    dispatched: Instant,
    /// Resolved at dispatch: the description's own deadline, else the
    /// process default ([`faults::default_deadline`]), else none.
    deadline: Option<Duration>,
}

/// Master scheduler state + event loop. Runs on its own thread.
pub(super) struct Master {
    workers: Vec<Sender<WorkerCtl>>,
    rx: Receiver<MasterMsg>,
    backend: KernelBackend,
    policy: SchedPolicy,
    free: Vec<bool>,
    /// Rank class per world rank (CPU pool then GPU pool).
    classes: Vec<RankClass>,
    queue: VecDeque<Pending>,
    running: Vec<Option<Running>>, // indexed by task slot
    next_ctx: u64,
    next_seq: u64,
    utilization: Arc<Utilization>,
    /// World ranks held by timed-out tasks: neither free nor claimable
    /// until the straggling task finally reports (degraded mode).
    quarantined: HashSet<usize>,
    /// Timed-out task id → its quarantined ranks, so a late report can
    /// be recognized, its ranks recovered, and the (already finished)
    /// handle left untouched.
    timed_out: HashMap<u64, Vec<usize>>,
}

impl Master {
    pub(super) fn new(
        workers: Vec<Sender<WorkerCtl>>,
        rx: Receiver<MasterMsg>,
        backend: KernelBackend,
        policy: SchedPolicy,
        classes: Vec<RankClass>,
        utilization: Arc<Utilization>,
    ) -> Master {
        let n = workers.len();
        assert_eq!(classes.len(), n);
        Master {
            workers,
            rx,
            backend,
            policy,
            free: vec![true; n],
            classes,
            queue: VecDeque::new(),
            running: Vec::new(),
            next_ctx: 1, // 0 is WORLD_CTX
            next_seq: 0,
            utilization,
            quarantined: HashSet::new(),
            timed_out: HashMap::new(),
        }
    }

    fn free_count(&self, class: RankClass) -> usize {
        self.free
            .iter()
            .zip(&self.classes)
            .enumerate()
            .filter(|(r, (&f, &c))| {
                f && c == class && !self.quarantined.contains(r)
            })
            .count()
    }

    /// Ranks of `class` not quarantined (free or busy): the pool a queued
    /// task could *eventually* run on, used for degraded-mode re-planning.
    fn healthy_count(&self, class: RankClass) -> usize {
        self.classes
            .iter()
            .enumerate()
            .filter(|(r, &c)| c == class && !self.quarantined.contains(r))
            .count()
    }

    /// Pick the lowest `n` free, healthy world ranks of the given class.
    fn claim_ranks(&mut self, n: usize, class: RankClass) -> Vec<usize> {
        let mut out = Vec::with_capacity(n);
        for (r, f) in self.free.iter_mut().enumerate() {
            if out.len() == n {
                break;
            }
            if *f && self.classes[r] == class && !self.quarantined.contains(&r) {
                *f = false;
                out.push(r);
            }
        }
        debug_assert_eq!(out.len(), n);
        out
    }

    /// Dispatch every queued task that fits. Priority first (higher wins),
    /// submission order within a priority level; then the policy decides
    /// head-of-line behaviour: FIFO stops at the first task that does not
    /// fit; Backfill keeps scanning for smaller tasks that do (the
    /// rank-reuse optimization the heterogeneous engine's win comes from).
    fn schedule(&mut self) {
        loop {
            // Scan order: priority desc, then seq asc.
            let mut order: Vec<usize> = (0..self.queue.len()).collect();
            order.sort_by_key(|&i| {
                (std::cmp::Reverse(self.queue[i].td.priority), self.queue[i].seq)
            });
            let mut dispatched = false;
            for &i in &order {
                let td = &self.queue[i].td;
                // Degraded-mode re-planning: a task that wants more ranks
                // than are healthy (quarantine shrank the pool) would
                // otherwise queue forever. Narrow it onto the survivors
                // via the operator's plan_ranks hook. With *zero* healthy
                // ranks nothing can dispatch — the task waits for
                // stragglers to report back (quarantine is temporary; its
                // deadline, which covers queue wait too, bounds the wait).
                let healthy = self.healthy_count(td.rank_class);
                if healthy == 0 {
                    if self.policy == SchedPolicy::Fifo {
                        break;
                    }
                    continue;
                }
                if td.ranks > healthy {
                    let narrowed = td.op.plan_ranks(healthy).clamp(1, healthy);
                    self.queue[i].td.ranks = narrowed;
                }
                let td = &self.queue[i].td;
                let fits = td.ranks <= self.free_count(td.rank_class);
                if fits {
                    let p = self.queue.remove(i).unwrap();
                    self.dispatch(p);
                    dispatched = true;
                    break; // free set changed; recompute scan order
                } else if self.policy == SchedPolicy::Fifo {
                    break; // strict head-of-line blocking
                }
            }
            if !dispatched {
                break;
            }
        }
    }

    /// Watchdog sweep: finish every overdue running task as Failed with a
    /// transient [`Error::Timeout`] and quarantine its ranks — they are
    /// not recycled (the group may be wedged mid-collective) until the
    /// task's late report arrives in [`Master::complete`]. A task's
    /// deadline covers **queue wait too**: a queued task past its
    /// deadline (e.g. parked behind a fully-quarantined pool) is failed
    /// the same way, so degraded mode can never hang a client that set a
    /// deadline.
    fn reap_overdue(&mut self) {
        let mut overdue_queued: Vec<usize> = (0..self.queue.len())
            .filter(|&i| {
                let p = &self.queue[i];
                p.td.deadline
                    .or_else(faults::default_deadline)
                    .is_some_and(|d| p.enqueued.elapsed() > d)
            })
            .collect();
        while let Some(i) = overdue_queued.pop() {
            let p = self.queue.remove(i).unwrap();
            crate::metrics::faults::record_timed_out();
            let err = Error::Timeout(format!(
                "task '{}' queued past its deadline ({} rank(s) quarantined)",
                p.td.name,
                self.quarantined.len(),
            ));
            p.handle.finish(crate::pilot::TaskResult {
                task_id: p.handle.id,
                name: p.td.name.clone(),
                state: TaskState::Failed,
                measurement: crate::metrics::ExecMeasurement {
                    label: p.td.name.clone(),
                    parallelism: p.td.ranks,
                    wall_s: 0.0,
                    sim_net_s: 0.0,
                    overhead: OverheadBreakdown {
                        queue_wait: p.enqueued.elapsed().as_secs_f64(),
                        ..Default::default()
                    },
                },
                output_rows: 0,
                output: None,
                error: Some(err.to_string()),
            });
        }
        for slot in 0..self.running.len() {
            let overdue = matches!(
                &self.running[slot],
                Some(run) if run
                    .deadline
                    .is_some_and(|d| run.dispatched.elapsed() > d)
            );
            if !overdue {
                continue;
            }
            let run = self.running[slot].take().unwrap();
            let deadline = run.deadline.unwrap();
            for &r in &run.ranks {
                self.quarantined.insert(r);
            }
            self.timed_out.insert(run.handle.id, run.ranks.clone());
            crate::metrics::faults::record_timed_out();
            crate::metrics::faults::record_quarantined_ranks(run.ranks.len());
            self.utilization
                .quarantined
                .fetch_add(run.ranks.len() as u64, Ordering::Relaxed);
            let mut overhead = run.overhead;
            overhead.comm_construction = 0.0;
            let err = Error::Timeout(format!(
                "task '{}' exceeded its deadline of {:.3}s on ranks {:?}",
                run.name,
                deadline.as_secs_f64(),
                run.ranks,
            ));
            run.handle.finish(crate::pilot::TaskResult {
                task_id: run.handle.id,
                name: run.name.clone(),
                state: TaskState::Failed,
                measurement: crate::metrics::ExecMeasurement {
                    label: run.handle.name.clone(),
                    parallelism: run.parallelism,
                    wall_s: run.dispatched.elapsed().as_secs_f64(),
                    sim_net_s: 0.0,
                    overhead,
                },
                output_rows: 0,
                output: None,
                error: Some(err.to_string()),
            });
        }
        // Freed nothing, but re-planning may now let queued tasks fit the
        // shrunken healthy pool.
        self.schedule();
    }

    /// Does any running or queued task carry a deadline? Gates the
    /// watchdog tick.
    fn has_deadlines(&self) -> bool {
        self.running.iter().flatten().any(|run| run.deadline.is_some())
            || self.queue.iter().any(|p| {
                p.td.deadline.or_else(faults::default_deadline).is_some()
            })
    }

    fn dispatch(&mut self, p: Pending) {
        let queue_wait_s = p.enqueued.elapsed().as_secs_f64();
        let dispatch_t0 = Instant::now();
        let ranks = self.claim_ranks(p.td.ranks, p.td.rank_class);
        let ctx_id = self.next_ctx;
        self.next_ctx += 1;
        p.handle.advance(TaskState::AgentScheduling);
        let order = WorkOrder {
            task_id: p.handle.id,
            td: p.td.clone(),
            ctx_id,
            world_ranks: ranks.clone(),
            backend: self.backend.clone(),
        };
        let slot = self.running.iter().position(|r| r.is_none()).unwrap_or_else(|| {
            self.running.push(None);
            self.running.len() - 1
        });
        let slot_idx = slot;
        self.running[slot_idx] = Some(Running {
            handle: p.handle.clone(),
            overhead: OverheadBreakdown {
                task_description: p.description_s,
                comm_construction: 0.0, // filled from the report
                scheduling: 0.0,        // filled after delivery below
                queue_wait: queue_wait_s,
            },
            parallelism: p.td.ranks,
            ranks: ranks.clone(),
            name: p.td.name.clone(),
            dispatched: Instant::now(),
            deadline: p.td.deadline.or_else(faults::default_deadline),
        });
        p.handle.advance(TaskState::Executing);
        for &r in &ranks {
            self.workers[r]
                .send(WorkerCtl::Exec(order.clone()))
                .expect("worker channel alive");
        }
        // Master processing time: rank selection through work-order delivery.
        if let Some(run) = self.running[slot_idx].as_mut() {
            run.overhead.scheduling = dispatch_t0.elapsed().as_secs_f64();
        }
    }

    fn complete(&mut self, report: RankReport) {
        // A straggler reporting after its deadline expiry: the handle was
        // already finished by the watchdog, so only recover the resources
        // — free the quarantined ranks and rescan the queue.
        if let Some(ranks) = self.timed_out.remove(&report.task_id) {
            for &r in &ranks {
                self.quarantined.remove(&r);
                self.free[r] = true;
            }
            self.utilization
                .quarantined
                .fetch_sub(ranks.len() as u64, Ordering::Relaxed);
            self.schedule();
            return;
        }
        let slot = self
            .running
            .iter()
            .position(|r| {
                r.as_ref().map(|x| x.handle.id) == Some(report.task_id)
            })
            .expect("completion for unknown task");
        let run = self.running[slot].take().unwrap();
        for &r in &run.ranks {
            self.free[r] = true;
        }
        self.utilization
            .record(run.ranks.len(), run.dispatched.elapsed());
        let mut overhead = run.overhead;
        overhead.comm_construction = report.comm_construction_s;
        let (state, error) = match &report.error {
            None => (TaskState::Done, None),
            Some(e) => (TaskState::Failed, Some(e.clone())),
        };
        run.handle.finish(crate::pilot::TaskResult {
            task_id: report.task_id,
            name: run.name,
            state,
            measurement: crate::metrics::ExecMeasurement {
                label: run.handle.name.clone(),
                parallelism: run.parallelism,
                wall_s: report.stats.wall_s,
                sim_net_s: report.stats.sim_net_s,
                overhead,
            },
            output_rows: report.stats.output_rows,
            output: report.output.map(Arc::new),
            error,
        });
        self.schedule();
    }

    /// The master event loop (paper Fig 4: persistent scheduler daemon).
    /// While any running task carries a deadline the loop waits with a
    /// watchdog tick and reaps overdue tasks between messages; otherwise
    /// it blocks indefinitely (no idle wakeups).
    pub(super) fn run(mut self) {
        loop {
            let msg = if self.has_deadlines() {
                match self.rx.recv_timeout(WATCHDOG_TICK) {
                    Ok(m) => Ok(m),
                    Err(RecvTimeoutError::Timeout) => {
                        self.reap_overdue();
                        continue;
                    }
                    Err(RecvTimeoutError::Disconnected) => Err(()),
                }
            } else {
                self.rx.recv().map_err(|_| ())
            };
            match msg {
                Ok(MasterMsg::Submit { handle, td, description_s }) => {
                    let pool = self
                        .classes
                        .iter()
                        .filter(|&&c| c == td.rank_class)
                        .count();
                    assert!(
                        td.ranks <= pool,
                        "task '{}' wants {} {:?} ranks, pilot has {pool}",
                        td.name,
                        td.ranks,
                        td.rank_class,
                    );
                    let seq = self.next_seq;
                    self.next_seq += 1;
                    self.queue.push_back(Pending {
                        handle,
                        td,
                        description_s,
                        enqueued: Instant::now(),
                        seq,
                    });
                    self.schedule();
                }
                Ok(MasterMsg::TaskComplete(report)) => self.complete(report),
                Ok(MasterMsg::Shutdown) | Err(()) => break,
            }
        }
        for w in &self.workers {
            let _ = w.send(WorkerCtl::Shutdown);
        }
    }
}
