//! The RemoteAgent (paper §3.1, Fig 3-4/5): bootstraps on the pilot's
//! allocation, starts the worker threads (one per rank) and the RAPTOR
//! master, and exposes the control-plane channel the TaskManager submits
//! through.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::comm::{CommWorld, Communicator, ReduceOp};
use crate::ops::dist::KernelBackend;
use crate::pilot::RankClass;

use super::cylon_task::run_cylon_task_full;
use super::master::{Master, MasterMsg, RankReport, Utilization, WorkerCtl};

/// Master scheduling policy (ablation: DESIGN.md §4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Strict submission order; head-of-line blocking possible.
    Fifo,
    /// Skip over tasks that do not fit — maximizes rank reuse (the
    /// heterogeneous-execution advantage of §4.3).
    Backfill,
}

/// Ready-set ordering for the dataflow pipeline executor
/// ([`crate::pipeline::Pipeline::run_dataflow`]): when several DAG nodes
/// become runnable at once, which reaches the master's queue first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ReadyPolicy {
    /// Node-id order (submission order of the DAG builder).
    #[default]
    Fifo,
    /// Longest remaining work chain first (critical-path-first): under
    /// skewed task durations this keeps the long pole moving and lets short
    /// side branches backfill around it.
    CriticalPathFirst,
}

/// Handle on a bootstrapped agent: submit via [`Agent::master_tx`], then
/// [`Agent::shutdown`] to join everything.
pub struct Agent {
    master_tx: Sender<MasterMsg>,
    master_join: Option<std::thread::JoinHandle<()>>,
    worker_joins: Vec<std::thread::JoinHandle<()>>,
    size: usize,
    utilization: Arc<Utilization>,
}

impl Agent {
    /// Bootstrap the agent over an existing communication world, with every
    /// rank in the CPU pool.
    pub fn bootstrap(
        world: CommWorld,
        backend: KernelBackend,
        policy: SchedPolicy,
    ) -> Agent {
        let classes = vec![RankClass::Cpu; world.size()];
        Agent::bootstrap_with_classes(world, backend, policy, classes)
    }

    /// Bootstrap with an explicit rank-class layout (CPU/GPU pools, paper
    /// §4.4).
    ///
    /// Mirrors the paper's step sequence: RemoteAgent starts (Fig 3-4),
    /// RAPTOR master+workers spawn (Fig 3-5), workers wait for work orders
    /// and construct private communicators per task (Fig 3-6).
    pub fn bootstrap_with_classes(
        world: CommWorld,
        backend: KernelBackend,
        policy: SchedPolicy,
        classes: Vec<RankClass>,
    ) -> Agent {
        let size = world.size();
        assert_eq!(classes.len(), size, "one class per world rank");
        let (master_tx, master_rx) = mpsc::channel::<MasterMsg>();

        let mut worker_txs = Vec::with_capacity(size);
        let mut worker_joins = Vec::with_capacity(size);
        for rank in 0..size {
            let (tx, rx) = mpsc::channel::<WorkerCtl>();
            worker_txs.push(tx);
            let comm = world.communicator(rank);
            let events = master_tx.clone();
            let h = std::thread::Builder::new()
                .name(format!("raptor-worker-{rank}"))
                .spawn(move || worker_loop(comm, rx, events))
                .expect("spawn raptor worker");
            worker_joins.push(h);
        }

        let utilization = Arc::new(Utilization::default());
        let master = Master::new(
            worker_txs,
            master_rx,
            backend,
            policy,
            classes,
            utilization.clone(),
        );
        let master_join = std::thread::Builder::new()
            .name("raptor-master".into())
            .spawn(move || master.run())
            .expect("spawn raptor master");

        Agent {
            master_tx,
            master_join: Some(master_join),
            worker_joins,
            size,
            utilization,
        }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Resource-usage tracker (busy rank-seconds, completed tasks).
    pub fn utilization(&self) -> Arc<Utilization> {
        self.utilization.clone()
    }

    /// Control-plane channel for task submission.
    pub fn master_tx(&self) -> Sender<MasterMsg> {
        self.master_tx.clone()
    }

    /// Stop the master and join all threads (idempotent).
    pub fn shutdown(&mut self) {
        let _ = self.master_tx.send(MasterMsg::Shutdown);
        if let Some(h) = self.master_join.take() {
            let _ = h.join();
        }
        for h in self.worker_joins.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Agent {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Worker event loop: construct the private communicator, run the Cylon
/// task, report from group rank 0, recycle.
fn worker_loop(
    comm: Communicator,
    ctl: Receiver<WorkerCtl>,
    events: Sender<MasterMsg>,
) {
    while let Ok(msg) = ctl.recv() {
        match msg {
            WorkerCtl::Exec(order) => {
                // --- private communicator construction (measured) ---
                let t0 = Instant::now();
                let sub = match comm.subgroup(order.ctx_id, &order.world_ranks) {
                    Ok(s) => s,
                    Err(e) => {
                        let _ = events.send(MasterMsg::TaskComplete(RankReport {
                            task_id: order.task_id,
                            stats: Default::default(),
                            comm_construction_s: 0.0,
                            output: None,
                            error: Some(format!("subgroup construction: {e}")),
                        }));
                        continue;
                    }
                };
                let construct = t0.elapsed().as_secs_f64() + sub.sim_clock();

                // --- execute the Cylon task on the private communicator ---
                //
                // The whole collective section (stats allreduce, task, and
                // the dissolve barrier) runs under one catch_unwind: an
                // injected comm fault fires by panic, and it fires
                // *symmetrically* — every rank of the group panics at the
                // same collective point — so when a panic is caught here,
                // no peer is blocked inside the skipped barrier and the
                // group can dissolve safely. The caught rank still reports
                // (rank 0) and recycles instead of killing its worker
                // thread.
                let ran = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        let construct_max =
                            sub.allreduce_f64(construct, ReduceOp::Max);
                        let outcome =
                            run_cylon_task_full(&sub, &order.td, &order.backend);
                        // All ranks rendezvous before the group dissolves
                        // so ctx release cannot race a straggler's last
                        // collective.
                        sub.barrier();
                        (construct_max, outcome)
                    }),
                );
                let (construct_max, outcome) = match ran {
                    Ok(v) => v,
                    Err(payload) => {
                        let msg = payload
                            .downcast_ref::<String>()
                            .map(String::as_str)
                            .or_else(|| payload.downcast_ref::<&str>().copied())
                            .unwrap_or("opaque panic payload");
                        (
                            construct,
                            Err(crate::error::Error::TaskFailed(format!(
                                "rank panicked in task '{}': {msg}",
                                order.td.name
                            ))),
                        )
                    }
                };
                if sub.rank() == 0 {
                    let report = match outcome {
                        Ok(o) => RankReport {
                            task_id: order.task_id,
                            stats: o.stats,
                            comm_construction_s: construct_max,
                            output: o.output,
                            error: None,
                        },
                        Err(e) => RankReport {
                            task_id: order.task_id,
                            stats: Default::default(),
                            comm_construction_s: construct_max,
                            output: None,
                            error: Some(e.to_string()),
                        },
                    };
                    comm.release_ctx(order.ctx_id);
                    let _ = events.send(MasterMsg::TaskComplete(report));
                }
            }
            WorkerCtl::Shutdown => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::pilot::{DataDist, TaskDescription, TaskHandle, TaskState};

    fn submit(
        agent: &Agent,
        id: u64,
        td: TaskDescription,
    ) -> TaskHandle {
        let h = TaskHandle::new(id, &td.name);
        h.advance(TaskState::Submitted);
        agent
            .master_tx()
            .send(MasterMsg::Submit { handle: h.clone(), td, description_s: 0.0 })
            .unwrap();
        h
    }

    fn agent(p: usize, policy: SchedPolicy) -> Agent {
        Agent::bootstrap(
            CommWorld::new(p, NetModel::disabled()),
            KernelBackend::Native,
            policy,
        )
    }

    #[test]
    fn single_task_roundtrip() {
        let mut a = agent(4, SchedPolicy::Fifo);
        let td = TaskDescription::join("j", 4, 100, DataDist::Uniform);
        let h = submit(&a, 1, td);
        let r = h.wait().unwrap();
        assert!(r.is_done());
        assert!(r.output_rows > 0);
        assert!(r.measurement.overhead.comm_construction >= 0.0);
        a.shutdown();
    }

    #[test]
    fn concurrent_small_tasks_share_the_pilot() {
        let mut a = agent(6, SchedPolicy::Fifo);
        let h1 = submit(&a, 1, TaskDescription::sort("s1", 3, 80, DataDist::Uniform));
        let h2 = submit(&a, 2, TaskDescription::sort("s2", 3, 80, DataDist::Uniform));
        let (r1, r2) = (h1.wait().unwrap(), h2.wait().unwrap());
        assert!(r1.is_done() && r2.is_done());
        assert_eq!(r1.output_rows, 240);
        a.shutdown();
    }

    #[test]
    fn ranks_are_recycled_for_queued_tasks() {
        // 2-rank pilot, three 2-rank tasks: must run sequentially, all done.
        let mut a = agent(2, SchedPolicy::Fifo);
        let hs: Vec<_> = (0..3)
            .map(|i| {
                submit(
                    &a,
                    i + 1,
                    TaskDescription::sort(&format!("s{i}"), 2, 50, DataDist::Uniform),
                )
            })
            .collect();
        for h in hs {
            assert!(h.wait().unwrap().is_done());
        }
        a.shutdown();
    }

    #[test]
    fn failed_task_isolated_from_others() {
        // Paper §3.3: failures are contained; remaining tasks execute.
        use crate::util::faults::{self, FaultPlan, FireMode};
        let _guard = faults::test_guard();
        faults::arm(
            FaultPlan::new(47)
                .with_arm("agent.task", FireMode::Prob(1.0))
                .with_only("afail"),
        );
        let mut a = agent(4, SchedPolicy::Fifo);
        let bad = submit(
            &a,
            1,
            TaskDescription::sort("afail-bad", 2, 10, DataDist::Uniform),
        );
        let good = submit(&a, 2, TaskDescription::sort("ok", 2, 50, DataDist::Uniform));
        let rb = bad.wait().unwrap();
        let rg = good.wait().unwrap();
        assert_eq!(rb.state, TaskState::Failed);
        assert!(rb.error.as_ref().unwrap().contains("injected"));
        assert!(rg.is_done());
        a.shutdown();
        faults::disarm();
    }

    #[test]
    fn backfill_lets_small_task_jump_queue() {
        // Pilot of 4: running task holds 3 ranks; queue = [big(4), small(1)].
        // FIFO would block small behind big; backfill runs small on the free
        // rank immediately.
        let mut a = agent(4, SchedPolicy::Backfill);
        let hold = submit(&a, 1, TaskDescription::sort("hold", 3, 4000, DataDist::Uniform));
        let big = submit(&a, 2, TaskDescription::sort("big", 4, 10, DataDist::Uniform));
        let small = submit(&a, 3, TaskDescription::sort("small", 1, 10, DataDist::Uniform));
        let rs = small.wait().unwrap();
        assert!(rs.is_done());
        assert!(hold.wait().unwrap().is_done());
        assert!(big.wait().unwrap().is_done());
        a.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent() {
        let mut a = agent(2, SchedPolicy::Fifo);
        a.shutdown();
        a.shutdown();
    }

    /// Watchdog path end to end: an injected-latency task blows its
    /// deadline, fails with a transient `timeout:` error, and its ranks
    /// sit quarantined until the straggler's late report frees them —
    /// at which point a queued task dispatches and completes.
    #[test]
    fn deadline_expiry_quarantines_then_recovers_ranks() {
        use crate::util::faults::{self, FaultPlan, FireMode};
        let _g = faults::test_guard();
        faults::arm(
            FaultPlan::new(3)
                .with_arm("agent.task", FireMode::Prob(1.0))
                .with_delay_ms(600)
                .with_only("dl-slow"),
        );
        let mut a = agent(2, SchedPolicy::Fifo);
        let td = TaskDescription::sort("dl-slow", 2, 10, DataDist::Uniform)
            .with_deadline_s(0.05);
        let h = submit(&a, 1, td);
        let r = h.wait().unwrap();
        assert_eq!(r.state, TaskState::Failed);
        let err = r.error.unwrap();
        assert!(err.starts_with("timeout: "), "{err}");
        assert!(crate::error::Error::classify(&err).is_transient());
        assert_eq!(a.utilization().quarantined_ranks(), 2);
        // Queued behind a fully-quarantined pool; runs after recovery.
        let h2 =
            submit(&a, 2, TaskDescription::sort("after", 2, 10, DataDist::Uniform));
        let r2 = h2.wait().unwrap();
        assert!(r2.is_done());
        assert_eq!(a.utilization().quarantined_ranks(), 0);
        a.shutdown();
        faults::disarm();
    }

    /// Degraded-mode re-planning: with half the pilot quarantined, a
    /// queued task that wanted the full pilot is narrowed onto the
    /// healthy survivors instead of waiting for ranks that may never
    /// come back.
    #[test]
    fn replan_narrows_wide_task_onto_survivors() {
        use crate::util::faults::{self, FaultPlan, FireMode};
        let _g = faults::test_guard();
        faults::arm(
            FaultPlan::new(4)
                .with_arm("agent.task", FireMode::Prob(1.0))
                .with_delay_ms(600)
                .with_only("dl-slow"),
        );
        let mut a = agent(4, SchedPolicy::Fifo);
        let slow = submit(
            &a,
            1,
            TaskDescription::sort("dl-slow-half", 2, 10, DataDist::Uniform)
                .with_deadline_s(0.05),
        );
        let wide =
            submit(&a, 2, TaskDescription::sort("wide", 4, 40, DataDist::Uniform));
        assert_eq!(slow.wait().unwrap().state, TaskState::Failed);
        let rw = wide.wait().unwrap();
        assert!(rw.is_done());
        assert_eq!(
            rw.measurement.parallelism, 2,
            "wide task must be re-planned onto the 2 healthy ranks"
        );
        a.shutdown();
        faults::disarm();
    }
}
