//! RAPTOR analogue (paper §3.4, Fig 3-5/6): the master–worker subsystem the
//! RemoteAgent bootstraps on the pilot's allocation.
//!
//! * One **worker** thread per pilot rank, each holding its world
//!   [`Communicator`] and a control channel.
//! * One **master** thread that receives Cylon tasks, carves a **private
//!   communicator** out of free ranks (`Communicator::subgroup`), delivers
//!   work orders, collects results, and recycles freed ranks — the paper's
//!   key heterogeneity mechanism ("when any worker completes their task,
//!   the released resources become available to others", §4.3).
//!
//! Two scheduling knobs live here:
//!
//! * [`SchedPolicy`] — how the *master* drains its queue (strict FIFO vs
//!   backfill over tasks that do not currently fit).
//! * [`ReadyPolicy`] — how the *dataflow pipeline executor*
//!   ([`crate::pipeline`]) orders DAG nodes whose dependencies just
//!   resolved before handing them to the master.
//!
//! [`Communicator`]: crate::comm::Communicator

mod agent;
mod cylon_task;
mod master;

pub use agent::{Agent, ReadyPolicy, SchedPolicy};
pub use cylon_task::{run_cylon_task, run_cylon_task_full, RankStats, TaskOutcome};
pub use master::{MasterMsg, RankReport, Utilization, WorkOrder};
