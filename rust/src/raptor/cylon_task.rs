//! Execution of one Cylon task on a delivered private communicator —
//! the paper's Fig 4 steps 8–9 (executor invokes Cylon; data-plane
//! communication on the same framework).

use crate::comm::{Communicator, ReduceOp};
use crate::df::{gen_table, gen_two_tables, ChunkedTable, GenSpec, Table};
use crate::error::{Error, Result};
use crate::metrics::Timer;
use crate::ops::dist::{
    dist_groupby, dist_hash_join, dist_sort, gather_table_chunked,
    partition_slice, KernelBackend,
};
use crate::ops::local::{AggFn, JoinType};
use crate::pilot::{CylonOp, TaskDescription};

/// Per-rank statistics aggregated over the task's private communicator.
#[derive(Clone, Copy, Debug, Default)]
pub struct RankStats {
    /// Max wall-clock compute seconds across ranks.
    pub wall_s: f64,
    /// Max simulated network seconds across ranks.
    pub sim_net_s: f64,
    /// Total output rows across ranks.
    pub output_rows: u64,
}

/// Stats plus the gathered output table (group rank 0 only, and only when
/// the description requested `keep_output`). The output stays a
/// [`ChunkedTable`] of per-rank parts — the handoff path never flattens.
#[derive(Clone, Debug, Default)]
pub struct TaskOutcome {
    pub stats: RankStats,
    pub output: Option<ChunkedTable>,
}

/// Run `td`'s operation on this rank of the private communicator and
/// aggregate the task-level stats (every rank returns the same stats).
///
/// Input resolution (pipeline table handoff): when `td.input` is staged,
/// each rank consumes a contiguous window of the staged table instead of
/// generating synthetic data — for joins the staged table is the left side.
/// The window is carved zero-copy ([`partition_slice`]); it is compacted to
/// a contiguous table only if it straddles chunk boundaries, so a rank
/// materializes at most its own window, never the whole staged table.
///
/// Failure injection (`name` starting with `__fail__`) errors *before* any
/// collective so all ranks fail symmetrically — the fault-isolation tests
/// rely on this.
pub fn run_cylon_task_full(
    comm: &Communicator,
    td: &TaskDescription,
    backend: &KernelBackend,
) -> Result<TaskOutcome> {
    if td.name.starts_with("__fail__") {
        return Err(Error::TaskFailed(format!(
            "injected failure in task '{}'",
            td.name
        )));
    }
    comm.reset_sim_clock();
    let spec = GenSpec {
        rows: td.rows_per_rank,
        key_space: td.key_space,
        dist: td.dist,
        seed: td.seed,
    };
    let staged: Option<Table> = td
        .input
        .as_ref()
        .map(|t| partition_slice(t, comm.rank(), comm.size()).into_table());
    let timer = Timer::start();
    let out = match td.op {
        CylonOp::Join => {
            let (l, r) = match staged {
                Some(l) => (l, gen_table(&spec, comm.rank())),
                None => gen_two_tables(&spec, comm.rank()),
            };
            dist_hash_join(comm, &l, &r, 0, 0, JoinType::Inner, backend)?
        }
        CylonOp::Sort => {
            let t = staged.unwrap_or_else(|| gen_table(&spec, comm.rank()));
            dist_sort(comm, &t, 0, backend)?
        }
        CylonOp::Groupby => {
            let t = staged.unwrap_or_else(|| gen_table(&spec, comm.rank()));
            dist_groupby(comm, &t, 0, 1, AggFn::Sum, backend)?
        }
    };
    // The handoff gather is part of the task's measured execution (it holds
    // the ranks), so it runs inside the timer window.
    let out_rows = out.num_rows() as u64;
    let output = if td.keep_output {
        // Collective; Some at group rank 0 only. Chunked: the per-rank
        // parts are adopted as-is, no flattening copy.
        gather_table_chunked(comm, out)?
    } else {
        None
    };
    let wall = timer.elapsed_s();
    let sim = comm.sim_clock();
    // Task-level aggregation (the trailing allgather the paper notes adds
    // cost at high rank counts in weak scaling).
    let wall_max = comm.allreduce_f64(wall, ReduceOp::Max);
    let sim_max = comm.allreduce_f64(sim, ReduceOp::Max);
    let rows_total = comm.allreduce_u64(out_rows, ReduceOp::Sum);
    Ok(TaskOutcome {
        stats: RankStats {
            wall_s: wall_max,
            sim_net_s: sim_max,
            output_rows: rows_total,
        },
        output,
    })
}

/// Stats-only variant (the engines' common path).
pub fn run_cylon_task(
    comm: &Communicator,
    td: &TaskDescription,
    backend: &KernelBackend,
) -> Result<RankStats> {
    run_cylon_task_full(comm, td, backend).map(|o| o.stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, NetModel};
    use crate::df::{Column, DataType, Schema};
    use crate::pilot::DataDist;
    use std::sync::Arc;

    fn run(td: TaskDescription, p: usize) -> Vec<Result<RankStats>> {
        let w = CommWorld::new(p, NetModel::disabled());
        w.run(move |c| run_cylon_task(&c, &td, &KernelBackend::Native))
            .unwrap()
    }

    #[test]
    fn join_task_produces_rows() {
        let td = TaskDescription::join("j", 4, 200, DataDist::Uniform)
            .with_key_space(100);
        let out = run(td, 4);
        let first = out[0].as_ref().unwrap();
        assert!(first.output_rows > 0);
        assert!(first.wall_s > 0.0);
        // All ranks agree on aggregates.
        for r in &out {
            let r = r.as_ref().unwrap();
            assert_eq!(r.output_rows, first.output_rows);
        }
    }

    #[test]
    fn sort_task_preserves_row_count() {
        let td = TaskDescription::sort("s", 3, 150, DataDist::Uniform);
        let out = run(td, 3);
        assert_eq!(out[0].as_ref().unwrap().output_rows, 450);
    }

    #[test]
    fn groupby_task_bounded_by_keyspace() {
        let td = TaskDescription::new("g", CylonOp::Groupby, 2, 300).with_key_space(20);
        let out = run(td, 2);
        assert!(out[0].as_ref().unwrap().output_rows <= 20);
    }

    #[test]
    fn injected_failure_is_symmetric() {
        let td = TaskDescription::sort("__fail__s", 2, 10, DataDist::Uniform);
        let out = run(td, 2);
        for r in out {
            assert!(r.is_err());
        }
    }

    #[test]
    fn staged_input_replaces_generation() {
        // A 6-row staged table sorted across 2 ranks: output rows must equal
        // the staged rows, not the description's synthetic 500/rank.
        let staged = Table::new(
            Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)]),
            vec![
                Column::from_i64(vec![5, 3, 9, 1, 7, 2]),
                Column::from_f64(vec![0.0; 6]),
            ],
        )
        .unwrap();
        let td = TaskDescription::sort("staged", 2, 500, DataDist::Uniform)
            .with_input_table(staged)
            .collect_output();
        let w = CommWorld::new(2, NetModel::disabled());
        let out = w
            .run(move |c| run_cylon_task_full(&c, &td, &KernelBackend::Native))
            .unwrap();
        let o0 = out[0].as_ref().unwrap();
        assert_eq!(o0.stats.output_rows, 6);
        let chunked = o0.output.as_ref().expect("rank 0 gathers the output");
        // The gather keeps one chunk per rank; compact for row access.
        assert_eq!(chunked.num_chunks(), 2);
        let table = chunked.compact();
        assert_eq!(table.column(0).as_i64().unwrap(), &[1, 2, 3, 5, 7, 9]);
        // Non-root ranks do not carry the gathered table.
        assert!(out[1].as_ref().unwrap().output.is_none());
    }

    #[test]
    fn staged_chunked_input_consumed_across_ranks() {
        // A staged input arriving as multiple chunks (the gathered-output
        // shape) is windowed across ranks without loss.
        let chunk = |keys: Vec<i64>| {
            let n = keys.len();
            Table::new(
                Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)]),
                vec![Column::from_i64(keys), Column::from_f64(vec![0.0; n])],
            )
            .unwrap()
        };
        let staged = crate::df::ChunkedTable::from_tables(vec![
            chunk(vec![6, 4]),
            chunk(vec![2, 8, 0]),
        ])
        .unwrap();
        let td = TaskDescription::sort("staged-chunks", 2, 500, DataDist::Uniform)
            .with_input(Arc::new(staged))
            .collect_output();
        let w = CommWorld::new(2, NetModel::disabled());
        let out = w
            .run(move |c| run_cylon_task_full(&c, &td, &KernelBackend::Native))
            .unwrap();
        let o0 = out[0].as_ref().unwrap();
        assert_eq!(o0.stats.output_rows, 5);
        let table = o0.output.as_ref().unwrap().compact();
        assert_eq!(table.column(0).as_i64().unwrap(), &[0, 2, 4, 6, 8]);
    }

    #[test]
    fn output_not_collected_by_default() {
        let td = TaskDescription::sort("plain", 2, 40, DataDist::Uniform);
        let w = CommWorld::new(2, NetModel::disabled());
        let out = w
            .run(move |c| run_cylon_task_full(&c, &td, &KernelBackend::Native))
            .unwrap();
        assert!(out.iter().all(|o| o.as_ref().unwrap().output.is_none()));
    }
}
