//! Execution of one Cylon task on a delivered private communicator —
//! the paper's Fig 4 steps 8–9 (executor invokes Cylon; data-plane
//! communication on the same framework).

use crate::comm::{Communicator, ReduceOp};
use crate::df::{gen_table, gen_two_tables, GenSpec};
use crate::error::{Error, Result};
use crate::metrics::Timer;
use crate::ops::dist::{dist_groupby, dist_hash_join, dist_sort, KernelBackend};
use crate::ops::local::{AggFn, JoinType};
use crate::pilot::{CylonOp, TaskDescription};

/// Per-rank statistics aggregated over the task's private communicator.
#[derive(Clone, Copy, Debug, Default)]
pub struct RankStats {
    /// Max wall-clock compute seconds across ranks.
    pub wall_s: f64,
    /// Max simulated network seconds across ranks.
    pub sim_net_s: f64,
    /// Total output rows across ranks.
    pub output_rows: u64,
}

/// Run `td`'s operation on this rank of the private communicator and
/// aggregate the task-level stats (every rank returns the same values).
///
/// Failure injection (`name` starting with `__fail__`) errors *before* any
/// collective so all ranks fail symmetrically — the fault-isolation tests
/// rely on this.
pub fn run_cylon_task(
    comm: &Communicator,
    td: &TaskDescription,
    backend: &KernelBackend,
) -> Result<RankStats> {
    if td.name.starts_with("__fail__") {
        return Err(Error::TaskFailed(format!(
            "injected failure in task '{}'",
            td.name
        )));
    }
    comm.reset_sim_clock();
    let spec = GenSpec {
        rows: td.rows_per_rank,
        key_space: td.key_space,
        dist: td.dist,
        seed: td.seed,
    };
    let timer = Timer::start();
    let out_rows = match td.op {
        CylonOp::Join => {
            let (l, r) = gen_two_tables(&spec, comm.rank());
            let j = dist_hash_join(comm, &l, &r, 0, 0, JoinType::Inner, backend)?;
            j.num_rows() as u64
        }
        CylonOp::Sort => {
            let t = gen_table(&spec, comm.rank());
            let s = dist_sort(comm, &t, 0, backend)?;
            s.num_rows() as u64
        }
        CylonOp::Groupby => {
            let t = gen_table(&spec, comm.rank());
            let g = dist_groupby(comm, &t, 0, 1, AggFn::Sum, backend)?;
            g.num_rows() as u64
        }
    };
    let wall = timer.elapsed_s();
    let sim = comm.sim_clock();
    // Task-level aggregation (the trailing allgather the paper notes adds
    // cost at high rank counts in weak scaling).
    let wall_max = comm.allreduce_f64(wall, ReduceOp::Max);
    let sim_max = comm.allreduce_f64(sim, ReduceOp::Max);
    let rows_total = comm.allreduce_u64(out_rows, ReduceOp::Sum);
    Ok(RankStats { wall_s: wall_max, sim_net_s: sim_max, output_rows: rows_total })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, NetModel};
    use crate::pilot::DataDist;

    fn run(td: TaskDescription, p: usize) -> Vec<Result<RankStats>> {
        let w = CommWorld::new(p, NetModel::disabled());
        w.run(move |c| run_cylon_task(&c, &td, &KernelBackend::Native))
            .unwrap()
    }

    #[test]
    fn join_task_produces_rows() {
        let td = TaskDescription::join("j", 4, 200, DataDist::Uniform)
            .with_key_space(100);
        let out = run(td, 4);
        let first = out[0].as_ref().unwrap();
        assert!(first.output_rows > 0);
        assert!(first.wall_s > 0.0);
        // All ranks agree on aggregates.
        for r in &out {
            let r = r.as_ref().unwrap();
            assert_eq!(r.output_rows, first.output_rows);
        }
    }

    #[test]
    fn sort_task_preserves_row_count() {
        let td = TaskDescription::sort("s", 3, 150, DataDist::Uniform);
        let out = run(td, 3);
        assert_eq!(out[0].as_ref().unwrap().output_rows, 450);
    }

    #[test]
    fn groupby_task_bounded_by_keyspace() {
        let td = TaskDescription::new("g", CylonOp::Groupby, 2, 300).with_key_space(20);
        let out = run(td, 2);
        assert!(out[0].as_ref().unwrap().output_rows <= 20);
    }

    #[test]
    fn injected_failure_is_symmetric() {
        let td = TaskDescription::sort("__fail__s", 2, 10, DataDist::Uniform);
        let out = run(td, 2);
        for r in out {
            assert!(r.is_err());
        }
    }
}
