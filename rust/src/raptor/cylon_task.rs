//! Execution of one Cylon task on a delivered private communicator —
//! the paper's Fig 4 steps 8–9 (executor invokes the operator; data-plane
//! communication on the same framework).
//!
//! Dispatch is **open**: a task carries an
//! [`OpHandle`](crate::ops::operator::OpHandle) and the executor calls
//! [`Operator::execute`](crate::ops::operator::Operator::execute) — there
//! is no operation enum to extend here. This module only supplies the
//! scaffolding every operator shares: staged-input windowing, synthetic
//! fallback, output gather, and task-level stats aggregation.

use crate::comm::{Communicator, ReduceOp};
use crate::df::{gen_table, ChunkedTable, GenSpec, Table};
use crate::error::{Error, Result};
use crate::metrics::Timer;
use crate::ops::dist::{gather_chunked, partition_slice, KernelBackend};
use crate::pilot::TaskDescription;
use crate::util::faults;

/// Per-rank statistics aggregated over the task's private communicator.
#[derive(Clone, Copy, Debug, Default)]
pub struct RankStats {
    /// Max wall-clock compute seconds across ranks.
    pub wall_s: f64,
    /// Max simulated network seconds across ranks.
    pub sim_net_s: f64,
    /// Total output rows across ranks.
    pub output_rows: u64,
}

/// Stats plus the gathered output table (group rank 0 only, and only when
/// the description requested `keep_output`). The output stays a
/// [`ChunkedTable`] of per-rank parts — the handoff path never flattens.
#[derive(Clone, Debug, Default)]
pub struct TaskOutcome {
    pub stats: RankStats,
    pub output: Option<ChunkedTable>,
}

/// The seed offset between successively generated operator inputs —
/// synthetic input `i` draws from `seed + i * SYNTH_SEED_STRIDE`, which for
/// a two-input join reproduces the historical left/right pair
/// ([`crate::df::gen_two_tables`]).
const SYNTH_SEED_STRIDE: u64 = 0x5eed;

/// Synthetic partition for operator input `index` on `rank`.
fn synthetic_input(spec: &GenSpec, rank: usize, index: usize) -> Table {
    let shifted = GenSpec {
        seed: spec.seed.wrapping_add(SYNTH_SEED_STRIDE * index as u64),
        ..spec.clone()
    };
    gen_table(&shifted, rank)
}

/// Resolve this rank's operator inputs from the staged handoff tables.
///
/// Policy (identical on every rank, so failures are symmetric):
/// * nothing staged → every input is a synthetic partition (the pure
///   benchmark workload path);
/// * all inputs staged → each rank consumes its zero-copy window of each;
/// * *some* inputs staged → an error, unless the description opted into
///   [`TaskDescription::allow_synthetic_fill`] — a partially-piped
///   operator never silently regenerates its missing inputs;
/// * more inputs staged than the operator consumes → always an error.
fn resolve_inputs(
    td: &TaskDescription,
    spec: &GenSpec,
    rank: usize,
    size: usize,
) -> Result<Vec<Table>> {
    let want = td.op.num_inputs();
    let staged = td.inputs.len();
    if staged > want {
        return Err(Error::Config(format!(
            "task '{}': operator '{}' consumes {want} input(s) but {staged} were staged",
            td.name,
            td.op.name(),
        )));
    }
    if staged < want && staged != 0 && !td.synthetic_fill {
        return Err(Error::Config(format!(
            "task '{}': operator '{}' consumes {want} inputs but only {staged} \
             were staged; pipe every input (Pipeline::add_piped_multi) or opt \
             in with TaskDescription::allow_synthetic_fill()",
            td.name,
            td.op.name(),
        )));
    }
    let mut inputs = Vec::with_capacity(want);
    for t in &td.inputs {
        // Zero-copy window of the staged table; compacted to a contiguous
        // table only if it straddles chunk boundaries, so a rank
        // materializes at most its own window, never the whole table.
        inputs.push(partition_slice(t, rank, size).into_table());
    }
    for i in staged..want {
        inputs.push(synthetic_input(spec, rank, i));
    }
    Ok(inputs)
}

/// Run `td`'s operator on this rank of the private communicator and
/// aggregate the task-level stats (every rank returns the same stats).
///
/// Input resolution (pipeline table handoff): staged tables are consumed
/// as zero-copy per-rank windows ([`partition_slice`]) — one per operator
/// input, so a join consumes both sides staged. Nothing staged means every
/// input is synthetic; a *partial* staging is rejected unless the
/// description opted into [`TaskDescription::allow_synthetic_fill`].
///
/// Failure injection goes through the structured `util::faults` sites:
/// `agent.task` fires at task entry and `op.execute` around the operator
/// call, both keyed by (task name, attempt) so every rank of the task
/// reaches the same verdict *before* any collective — the fault-isolation
/// tests rely on this symmetry. (The magic `__fail__` task-name shim is
/// gone; arm a scoped `agent.task` fault instead.)
pub fn run_cylon_task_full(
    comm: &Communicator,
    td: &TaskDescription,
    backend: &KernelBackend,
) -> Result<TaskOutcome> {
    let fault_key = faults::task_key(&td.name, td.attempt);
    faults::inject_keyed("agent.task", fault_key, &td.name)?;
    comm.reset_sim_clock();
    let spec = GenSpec {
        rows: td.rows_per_rank,
        key_space: td.key_space,
        dist: td.dist,
        seed: td.seed,
    };
    let timer = Timer::start();
    // Input resolution runs *inside* the timer window: synthetic workload
    // generation and staged-window compaction are part of a task's
    // measured execution, exactly as before the operator-registry refactor
    // (keeping the bench trajectory comparable). Errors here are computed
    // from `td` alone, identical on every rank, so a mis-staged task still
    // fails symmetrically before any collective runs.
    let inputs = resolve_inputs(td, &spec, comm.rank(), comm.size())?;
    faults::inject_keyed("op.execute", fault_key, &td.name)?;
    let out = td.op.execute(comm, td, inputs, backend)?;
    // The handoff gather is part of the task's measured execution (it holds
    // the ranks), so it runs inside the timer window.
    let out_rows = out.num_rows() as u64;
    let output = if td.keep_output {
        // Collective; Some at group rank 0 only. Chunked: the per-rank
        // parts (and any sub-windows a zero-copy operator produced) are
        // adopted as-is, no flattening copy — disk-backed chunks stay on
        // disk through the gather.
        let mut gathered = gather_chunked(comm, out)?;
        if let Some(g) = gathered.as_mut() {
            // The root now holds every rank's output; push resident chunks
            // back out under the global budget so the stage handoff never
            // re-accumulates more than the governor allows.
            g.spill_over(crate::spill::global())?;
        }
        gathered
    } else {
        None
    };
    let wall = timer.elapsed_s();
    let sim = comm.sim_clock();
    // Task-level aggregation (the trailing allgather the paper notes adds
    // cost at high rank counts in weak scaling).
    let wall_max = comm.allreduce_f64(wall, ReduceOp::Max);
    let sim_max = comm.allreduce_f64(sim, ReduceOp::Max);
    let rows_total = comm.allreduce_u64(out_rows, ReduceOp::Sum);
    Ok(TaskOutcome {
        stats: RankStats {
            wall_s: wall_max,
            sim_net_s: sim_max,
            output_rows: rows_total,
        },
        output,
    })
}

/// Stats-only variant (the engines' common path).
pub fn run_cylon_task(
    comm: &Communicator,
    td: &TaskDescription,
    backend: &KernelBackend,
) -> Result<RankStats> {
    run_cylon_task_full(comm, td, backend).map(|o| o.stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, NetModel};
    use crate::df::{gen_two_tables, Column, DataType, Schema};
    use crate::pilot::DataDist;
    use std::sync::Arc;

    fn run(td: TaskDescription, p: usize) -> Vec<Result<RankStats>> {
        let w = CommWorld::new(p, NetModel::disabled());
        w.run(move |c| run_cylon_task(&c, &td, &KernelBackend::Native))
            .unwrap()
    }

    fn run_full(td: TaskDescription, p: usize) -> Vec<Result<TaskOutcome>> {
        let w = CommWorld::new(p, NetModel::disabled());
        w.run(move |c| run_cylon_task_full(&c, &td, &KernelBackend::Native))
            .unwrap()
    }

    #[test]
    fn join_task_produces_rows() {
        let td = TaskDescription::join("j", 4, 200, DataDist::Uniform)
            .with_key_space(100);
        let out = run(td, 4);
        let first = out[0].as_ref().unwrap();
        assert!(first.output_rows > 0);
        assert!(first.wall_s > 0.0);
        // All ranks agree on aggregates.
        for r in &out {
            let r = r.as_ref().unwrap();
            assert_eq!(r.output_rows, first.output_rows);
        }
    }

    #[test]
    fn sort_task_preserves_row_count() {
        let td = TaskDescription::sort("s", 3, 150, DataDist::Uniform);
        let out = run(td, 3);
        assert_eq!(out[0].as_ref().unwrap().output_rows, 450);
    }

    #[test]
    fn groupby_task_bounded_by_keyspace() {
        let td = TaskDescription::groupby("g", 2, 300).with_key_space(20);
        let out = run(td, 2);
        assert!(out[0].as_ref().unwrap().output_rows <= 20);
    }

    #[test]
    fn synthetic_inputs_match_historical_pair() {
        // The two synthetic join inputs must reproduce gen_two_tables,
        // keeping pre-refactor workloads bit-identical.
        let spec = GenSpec::uniform(64, 32, 0xC71);
        let (l, r) = gen_two_tables(&spec, 1);
        assert_eq!(synthetic_input(&spec, 1, 0), l);
        assert_eq!(synthetic_input(&spec, 1, 1), r);
    }

    #[test]
    fn injected_failure_is_symmetric() {
        // Scoped fault arm (the replacement for the old `__fail__`
        // task-name shim): every rank fails at entry, symmetrically.
        let _guard = faults::test_guard();
        faults::arm(
            crate::util::FaultPlan::new(11)
                .with_arm("agent.task", crate::util::faults::FireMode::Prob(1.0))
                .with_only("cyl-inject"),
        );
        let td = TaskDescription::sort("cyl-inject-s", 2, 10, DataDist::Uniform);
        let out = run(td, 2);
        for r in out {
            assert!(r.is_err());
        }
        faults::disarm();
    }

    #[test]
    fn structured_fault_sites_fail_symmetrically_and_redraw_on_retry() {
        let _guard = faults::test_guard();
        faults::arm(
            crate::util::FaultPlan::new(3)
                .with_arm("agent.task", crate::util::faults::FireMode::Prob(1.0))
                .with_only("cyl-chaos"),
        );
        // Armed site: every rank fails, transiently, before any collective.
        let td = TaskDescription::sort("cyl-chaos-s", 2, 10, DataDist::Uniform);
        let out = run(td, 2);
        for r in out {
            let e = r.unwrap_err();
            assert!(e.is_transient());
            assert!(e.to_string().contains("agent.task"), "{e}");
        }
        // The `only` filter scopes the arm: other names run clean.
        let td = TaskDescription::sort("clean", 2, 10, DataDist::Uniform);
        assert!(run(td, 2).into_iter().all(|r| r.is_ok()));
        // A p=0.5 arm decides per (name, attempt): some attempt of some
        // name must survive, some must fail — and re-running the same
        // (name, attempt) decides identically.
        faults::arm(
            crate::util::FaultPlan::new(3)
                .with_arm("agent.task", crate::util::faults::FireMode::Prob(0.5))
                .with_only("cyl-chaos"),
        );
        let verdict = |attempt: u32| {
            let mut td =
                TaskDescription::sort("cyl-chaos-r", 1, 5, DataDist::Uniform);
            td.attempt = attempt;
            run(td, 1).pop().unwrap().is_ok()
        };
        let first: Vec<bool> = (1..=16).map(verdict).collect();
        assert!(first.iter().any(|&ok| ok), "{first:?}");
        assert!(first.iter().any(|&ok| !ok), "{first:?}");
        assert_eq!(first, (1..=16).map(verdict).collect::<Vec<_>>());
        faults::disarm();
    }

    fn staged_table(keys: Vec<i64>) -> Table {
        let n = keys.len();
        Table::new(
            Schema::of(&[("key", DataType::Int64), ("val", DataType::Float64)]),
            vec![Column::from_i64(keys), Column::from_f64(vec![0.0; n])],
        )
        .unwrap()
    }

    #[test]
    fn staged_input_replaces_generation() {
        // A 6-row staged table sorted across 2 ranks: output rows must equal
        // the staged rows, not the description's synthetic 500/rank.
        let td = TaskDescription::sort("staged", 2, 500, DataDist::Uniform)
            .with_input_table(staged_table(vec![5, 3, 9, 1, 7, 2]))
            .collect_output();
        let out = run_full(td, 2);
        let o0 = out[0].as_ref().unwrap();
        assert_eq!(o0.stats.output_rows, 6);
        let chunked = o0.output.as_ref().expect("rank 0 gathers the output");
        // The gather keeps one chunk per rank; compact for row access.
        assert_eq!(chunked.num_chunks(), 2);
        let table = chunked.compact();
        assert_eq!(table.column(0).as_i64().unwrap(), &[1, 2, 3, 5, 7, 9]);
        // Non-root ranks do not carry the gathered table.
        assert!(out[1].as_ref().unwrap().output.is_none());
    }

    #[test]
    fn staged_chunked_input_consumed_across_ranks() {
        // A staged input arriving as multiple chunks (the gathered-output
        // shape) is windowed across ranks without loss.
        let staged = crate::df::ChunkedTable::from_tables(vec![
            staged_table(vec![6, 4]),
            staged_table(vec![2, 8, 0]),
        ])
        .unwrap();
        let td = TaskDescription::sort("staged-chunks", 2, 500, DataDist::Uniform)
            .with_input(Arc::new(staged))
            .collect_output();
        let out = run_full(td, 2);
        let o0 = out[0].as_ref().unwrap();
        assert_eq!(o0.stats.output_rows, 5);
        let table = o0.output.as_ref().unwrap().compact();
        assert_eq!(table.column(0).as_i64().unwrap(), &[0, 2, 4, 6, 8]);
    }

    #[test]
    fn join_with_both_sides_staged_consumes_both() {
        // left: keys 0..4 ; right: keys 2..6 — inner join keys {2, 3}.
        // Neither side may be regenerated from the synthetic spec.
        let td = TaskDescription::join("j2", 2, 9999, DataDist::Uniform)
            .with_input_table(staged_table(vec![0, 1, 2, 3]))
            .with_input_table(staged_table(vec![2, 3, 4, 5]))
            .collect_output();
        let out = run_full(td, 2);
        let o0 = out[0].as_ref().unwrap();
        assert_eq!(o0.stats.output_rows, 2);
        let mut keys: Vec<i64> = o0
            .output
            .as_ref()
            .unwrap()
            .compact()
            .column(0)
            .as_i64()
            .unwrap()
            .to_vec();
        keys.sort_unstable();
        assert_eq!(keys, vec![2, 3]);
    }

    #[test]
    fn partially_staged_join_fails_loudly() {
        // One staged side + no opt-in: a configuration error on every rank,
        // never a silent right-side regeneration.
        let td = TaskDescription::join("half", 2, 100, DataDist::Uniform)
            .with_input_table(staged_table(vec![1, 2, 3, 4]));
        let out = run_full(td, 2);
        for r in &out {
            let err = r.as_ref().unwrap_err().to_string();
            assert!(err.contains("allow_synthetic_fill"), "{err}");
            assert!(err.contains("only 1"), "{err}");
        }
    }

    #[test]
    fn partially_staged_join_with_synthetic_fill_opt_in() {
        // The explicit opt-in: staged left, synthetic right. The right
        // side is the same partition the fully-synthetic path would
        // generate for input 1 (seed + 0x5eed), independent of staging.
        let td = TaskDescription::join("half-ok", 2, 50, DataDist::Uniform)
            .with_key_space(64)
            .with_input_table(staged_table((0..64).collect()))
            .allow_synthetic_fill();
        let out = run(td, 2);
        let r = out[0].as_ref().unwrap();
        // Right side is synthetic over key space 64, so every right row
        // matches exactly one staged left key.
        assert_eq!(r.output_rows, 2 * 50);
    }

    #[test]
    fn overstaged_task_rejected() {
        let td = TaskDescription::sort("over", 1, 10, DataDist::Uniform)
            .with_input_table(staged_table(vec![1]))
            .with_input_table(staged_table(vec![2]));
        let out = run_full(td, 1);
        let err = out[0].as_ref().unwrap_err().to_string();
        assert!(err.contains("consumes 1 input(s) but 2 were staged"), "{err}");
    }

    #[test]
    fn output_not_collected_by_default() {
        let td = TaskDescription::sort("plain", 2, 40, DataDist::Uniform);
        let out = run_full(td, 2);
        assert!(out.iter().all(|o| o.as_ref().unwrap().output.is_none()));
    }
}
