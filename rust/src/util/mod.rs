//! Shared utilities: hashing, PRNG, statistics, the in-repo property-test
//! runner, and the bench harness (offline substitutes for `rand`,
//! `proptest`, and `criterion` — see DESIGN.md §2).

pub mod bench_harness;
pub mod faults;
pub mod hash;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod testkit;

pub use faults::{lock_recover, FaultPlan, RetryPolicy};
pub use hash::splitmix64;
pub use rng::Rng;
pub use stats::Stats;
