//! Sample statistics used throughout the metrics and bench layers.

/// Summary statistics over a sample of f64 measurements.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    /// Sample standard deviation (Bessel-corrected); 0 for n < 2.
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    /// Compute stats over a non-empty sample.
    pub fn from_samples(xs: &[f64]) -> Stats {
        assert!(!xs.is_empty(), "Stats::from_samples on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in xs {
            min = min.min(x);
            max = max.max(x);
        }
        Stats { n, mean, std: var.sqrt(), min, max }
    }

    /// `mean ± std` rendering used by the report tables (paper Table 2 style).
    pub fn pm(&self) -> String {
        format!("{:.2} ± {:.2}", self.mean, self.std)
    }

    /// Do two measurements overlap within one standard deviation each?
    /// (The paper's "overlapping error bars" criterion.)
    pub fn overlaps(&self, other: &Stats) -> bool {
        (self.mean - other.mean).abs() <= self.std + other.std
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - 1.290_994_448_735_805_6).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn single_sample_has_zero_std() {
        let s = Stats::from_samples(&[5.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.mean, 5.0);
    }

    #[test]
    fn overlap_criterion() {
        let a = Stats::from_samples(&[10.0, 11.0, 12.0]);
        let b = Stats::from_samples(&[11.5, 12.5, 13.5]);
        assert!(a.overlaps(&b));
        let c = Stats::from_samples(&[100.0, 100.1]);
        assert!(!a.overlaps(&c));
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        let _ = Stats::from_samples(&[]);
    }
}
