//! Micro/macro-bench harness — the offline substitute for `criterion`
//! (unavailable in this environment; see DESIGN.md §2).
//!
//! Each `benches/*.rs` target (built with `harness = false`) uses
//! [`BenchSet`] to run warmups + measured iterations and print
//! paper-comparable rows. Times are wall-clock per iteration; the network
//! cost model contributes *simulated* seconds which callers fold in
//! explicitly (reported in separate columns so real vs modeled time stays
//! auditable).

use std::time::Instant;

use super::stats::Stats;

/// Iterations per bench configuration: `RC_BENCH_ITERS` env override, else
/// `default`. The paper uses 10; benches default lower to keep `cargo
/// bench` wall time reasonable on laptop-class hosts.
pub fn bench_iters(default: usize) -> usize {
    std::env::var("RC_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// One measured configuration (e.g. "join WS p=16").
#[derive(Clone, Debug)]
pub struct BenchRow {
    pub label: String,
    /// Wall-clock stats per iteration, in seconds.
    pub wall: Stats,
    /// Optional modeled (virtual network) seconds per iteration.
    pub simulated: Option<Stats>,
    /// Optional paper-reported value for side-by-side display.
    pub paper: Option<f64>,
    /// Free-form extra columns (throughput, overhead, ...).
    pub extra: Vec<(String, String)>,
}

/// Collects rows and renders a fixed-width table.
#[derive(Default)]
pub struct BenchSet {
    pub title: String,
    pub rows: Vec<BenchRow>,
}

impl BenchSet {
    pub fn new(title: &str) -> Self {
        BenchSet { title: title.to_string(), rows: Vec::new() }
    }

    /// Run `f` for `warmup` unmeasured + `iters` measured iterations and
    /// record wall-clock stats. `f` returns an optional simulated-seconds
    /// figure for the iteration.
    pub fn bench<F: FnMut() -> Option<f64>>(
        &mut self,
        label: &str,
        warmup: usize,
        iters: usize,
        mut f: F,
    ) -> &mut BenchRow {
        for _ in 0..warmup {
            let _ = f();
        }
        let mut wall = Vec::with_capacity(iters);
        let mut sim = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            let s = f();
            wall.push(t0.elapsed().as_secs_f64());
            if let Some(s) = s {
                sim.push(s);
            }
        }
        self.rows.push(BenchRow {
            label: label.to_string(),
            wall: Stats::from_samples(&wall),
            simulated: if sim.is_empty() {
                None
            } else {
                Some(Stats::from_samples(&sim))
            },
            paper: None,
            extra: Vec::new(),
        });
        self.rows.last_mut().unwrap()
    }

    /// Render the table to stdout.
    pub fn report(&self) {
        println!("\n=== {} ===", self.title);
        let mut header = vec![
            "config".to_string(),
            "wall mean±std (s)".to_string(),
            "sim (s)".to_string(),
            "paper (s)".to_string(),
        ];
        let extra_cols: Vec<String> = self
            .rows
            .iter()
            .flat_map(|r| r.extra.iter().map(|(k, _)| k.clone()))
            .fold(Vec::new(), |mut acc, k| {
                if !acc.contains(&k) {
                    acc.push(k);
                }
                acc
            });
        header.extend(extra_cols.iter().cloned());

        let mut lines: Vec<Vec<String>> = vec![header];
        for r in &self.rows {
            let mut line = vec![
                r.label.clone(),
                r.wall.pm(),
                r.simulated.map(|s| s.pm()).unwrap_or_else(|| "-".into()),
                r.paper.map(|p| format!("{p:.2}")).unwrap_or_else(|| "-".into()),
            ];
            for col in &extra_cols {
                line.push(
                    r.extra
                        .iter()
                        .find(|(k, _)| k == col)
                        .map(|(_, v)| v.clone())
                        .unwrap_or_else(|| "-".into()),
                );
            }
            lines.push(line);
        }
        let ncols = lines[0].len();
        let widths: Vec<usize> = (0..ncols)
            .map(|c| lines.iter().map(|l| l[c].len()).max().unwrap_or(0))
            .collect();
        for (i, line) in lines.iter().enumerate() {
            let row: Vec<String> = line
                .iter()
                .zip(&widths)
                .map(|(cell, w)| format!("{cell:<w$}"))
                .collect();
            println!("  {}", row.join("  "));
            if i == 0 {
                println!(
                    "  {}",
                    widths
                        .iter()
                        .map(|w| "-".repeat(*w))
                        .collect::<Vec<_>>()
                        .join("  ")
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_iterations() {
        let mut set = BenchSet::new("t");
        set.bench("noop", 1, 5, || Some(1.5));
        assert_eq!(set.rows.len(), 1);
        let r = &set.rows[0];
        assert_eq!(r.wall.n, 5);
        assert_eq!(r.simulated.unwrap().mean, 1.5);
    }

    #[test]
    fn report_does_not_panic_with_mixed_columns() {
        let mut set = BenchSet::new("t");
        set.bench("a", 0, 1, || None);
        let row = set.bench("b", 0, 1, || Some(2.0));
        row.paper = Some(215.64);
        row.extra.push(("ovh".into(), "2.9".into()));
        set.report();
    }
}
