//! Micro/macro-bench harness — the offline substitute for `criterion`
//! (unavailable in this environment; see DESIGN.md §2).
//!
//! Each `benches/*.rs` target (built with `harness = false`) uses
//! [`BenchSet`] to run warmups + measured iterations and print
//! paper-comparable rows. Times are wall-clock per iteration; the network
//! cost model contributes *simulated* seconds which callers fold in
//! explicitly (reported in separate columns so real vs modeled time stays
//! auditable).
//!
//! [`BenchSet::bench_mem`] additionally samples the process-wide
//! bytes-materialized / bytes-viewed counters ([`crate::metrics::mem`])
//! around the measured loop, so the perf trajectory captures copy
//! reduction, not just wall time. Set `RC_BENCH_JSON=<path>` to also emit
//! the whole set — including the memory counters — as machine-readable
//! JSON ([`BenchSet::maybe_write_json`]).

use std::time::Instant;

use crate::metrics::mem::{self, MemCounters};

use super::stats::Stats;

/// Iterations per bench configuration: `RC_BENCH_ITERS` env override, else
/// `default`. The paper uses 10; benches default lower to keep `cargo
/// bench` wall time reasonable on laptop-class hosts.
pub fn bench_iters(default: usize) -> usize {
    std::env::var("RC_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// One measured configuration (e.g. "join WS p=16").
#[derive(Clone, Debug)]
pub struct BenchRow {
    pub label: String,
    /// Wall-clock stats per iteration, in seconds.
    pub wall: Stats,
    /// Optional modeled (virtual network) seconds per iteration.
    pub simulated: Option<Stats>,
    /// Optional paper-reported value for side-by-side display.
    pub paper: Option<f64>,
    /// Per-iteration bytes materialized/viewed (process-wide delta over
    /// the measured loop, divided by iterations) when recorded via
    /// [`BenchSet::bench_mem`].
    pub mem: Option<MemCounters>,
    /// Free-form extra columns (throughput, overhead, ...).
    pub extra: Vec<(String, String)>,
}

/// Collects rows and renders a fixed-width table.
#[derive(Default)]
pub struct BenchSet {
    pub title: String,
    pub rows: Vec<BenchRow>,
}

impl BenchSet {
    pub fn new(title: &str) -> Self {
        BenchSet { title: title.to_string(), rows: Vec::new() }
    }

    /// Run `f` for `warmup` unmeasured + `iters` measured iterations and
    /// record wall-clock stats. `f` returns an optional simulated-seconds
    /// figure for the iteration.
    pub fn bench<F: FnMut() -> Option<f64>>(
        &mut self,
        label: &str,
        warmup: usize,
        iters: usize,
        mut f: F,
    ) -> &mut BenchRow {
        for _ in 0..warmup {
            let _ = f();
        }
        let mut wall = Vec::with_capacity(iters);
        let mut sim = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            let s = f();
            wall.push(t0.elapsed().as_secs_f64());
            if let Some(s) = s {
                sim.push(s);
            }
        }
        self.rows.push(BenchRow {
            label: label.to_string(),
            wall: Stats::from_samples(&wall),
            simulated: if sim.is_empty() {
                None
            } else {
                Some(Stats::from_samples(&sim))
            },
            paper: None,
            mem: None,
            extra: Vec::new(),
        });
        self.rows.last_mut().unwrap()
    }

    /// [`BenchSet::bench`] plus copy accounting: samples the global
    /// bytes-materialized / bytes-viewed counters around the measured loop
    /// and stores the per-iteration averages on the row (also surfaced as
    /// `mat MiB` / `view MiB` report columns). Process-wide counters —
    /// exact for single-workload bench binaries, including work done on
    /// rank threads the bench spawns.
    pub fn bench_mem<F: FnMut() -> Option<f64>>(
        &mut self,
        label: &str,
        warmup: usize,
        iters: usize,
        f: F,
    ) -> &mut BenchRow {
        let before = mem::global();
        let row = self.bench(label, warmup, iters, f);
        // Warmup iterations also move the counters; accept the small
        // overcount rather than re-running f between snapshots.
        let delta = mem::global().since(before);
        let per_iter = MemCounters {
            materialized: delta.materialized / (warmup + iters).max(1) as u64,
            viewed: delta.viewed / (warmup + iters).max(1) as u64,
        };
        row.mem = Some(per_iter);
        let mib = |b: u64| format!("{:.2}", b as f64 / (1024.0 * 1024.0));
        row.extra.push(("mat MiB".into(), mib(per_iter.materialized)));
        row.extra.push(("view MiB".into(), mib(per_iter.viewed)));
        row
    }

    /// Render the table to stdout.
    pub fn report(&self) {
        println!("\n=== {} ===", self.title);
        let mut header = vec![
            "config".to_string(),
            "wall mean±std (s)".to_string(),
            "sim (s)".to_string(),
            "paper (s)".to_string(),
        ];
        let extra_cols: Vec<String> = self
            .rows
            .iter()
            .flat_map(|r| r.extra.iter().map(|(k, _)| k.clone()))
            .fold(Vec::new(), |mut acc, k| {
                if !acc.contains(&k) {
                    acc.push(k);
                }
                acc
            });
        header.extend(extra_cols.iter().cloned());

        let mut lines: Vec<Vec<String>> = vec![header];
        for r in &self.rows {
            let mut line = vec![
                r.label.clone(),
                r.wall.pm(),
                r.simulated.map(|s| s.pm()).unwrap_or_else(|| "-".into()),
                r.paper.map(|p| format!("{p:.2}")).unwrap_or_else(|| "-".into()),
            ];
            for col in &extra_cols {
                line.push(
                    r.extra
                        .iter()
                        .find(|(k, _)| k == col)
                        .map(|(_, v)| v.clone())
                        .unwrap_or_else(|| "-".into()),
                );
            }
            lines.push(line);
        }
        let ncols = lines[0].len();
        let widths: Vec<usize> = (0..ncols)
            .map(|c| lines.iter().map(|l| l[c].len()).max().unwrap_or(0))
            .collect();
        for (i, line) in lines.iter().enumerate() {
            let row: Vec<String> = line
                .iter()
                .zip(&widths)
                .map(|(cell, w)| format!("{cell:<w$}"))
                .collect();
            println!("  {}", row.join("  "));
            if i == 0 {
                println!(
                    "  {}",
                    widths
                        .iter()
                        .map(|w| "-".repeat(*w))
                        .collect::<Vec<_>>()
                        .join("  ")
                );
            }
        }
    }

    /// Serialize the set (hand-rolled JSON; no deps) — one object per row
    /// with wall/sim stats, the memory counters, and the extra columns.
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            let mut out = String::with_capacity(s.len());
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        out.push_str(&format!("\\u{:04x}", c as u32))
                    }
                    c => out.push(c),
                }
            }
            out
        }
        fn stats_json(s: &Stats) -> String {
            format!(
                "{{\"n\":{},\"mean\":{},\"std\":{},\"min\":{},\"max\":{}}}",
                s.n, s.mean, s.std, s.min, s.max
            )
        }
        let rows: Vec<String> = self
            .rows
            .iter()
            .map(|r| {
                let sim = r
                    .simulated
                    .as_ref()
                    .map(stats_json)
                    .unwrap_or_else(|| "null".into());
                let paper = r
                    .paper
                    .map(|p| p.to_string())
                    .unwrap_or_else(|| "null".into());
                let (mat, viewed) = r
                    .mem
                    .map(|m| (m.materialized.to_string(), m.viewed.to_string()))
                    .unwrap_or_else(|| ("null".into(), "null".into()));
                let extra: Vec<String> = r
                    .extra
                    .iter()
                    .map(|(k, v)| format!("\"{}\":\"{}\"", esc(k), esc(v)))
                    .collect();
                format!(
                    "{{\"label\":\"{}\",\"wall_s\":{},\"sim_s\":{},\"paper_s\":{},\
                     \"bytes_materialized_per_iter\":{},\"bytes_viewed_per_iter\":{},\
                     \"extra\":{{{}}}}}",
                    esc(&r.label),
                    stats_json(&r.wall),
                    sim,
                    paper,
                    mat,
                    viewed,
                    extra.join(",")
                )
            })
            .collect();
        format!(
            "{{\"title\":\"{}\",\"rows\":[{}]}}\n",
            esc(&self.title),
            rows.join(",")
        )
    }

    /// Write [`BenchSet::to_json`] to the path named by `RC_BENCH_JSON`
    /// (no-op when unset); benches call this after `report()` so CI can
    /// archive the trajectory.
    pub fn maybe_write_json(&self) {
        if let Ok(path) = std::env::var("RC_BENCH_JSON") {
            if path.is_empty() {
                return;
            }
            match std::fs::write(&path, self.to_json()) {
                Ok(()) => eprintln!("bench json -> {path}"),
                Err(e) => eprintln!("bench json write failed ({path}): {e}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_iterations() {
        let mut set = BenchSet::new("t");
        set.bench("noop", 1, 5, || Some(1.5));
        assert_eq!(set.rows.len(), 1);
        let r = &set.rows[0];
        assert_eq!(r.wall.n, 5);
        assert_eq!(r.simulated.unwrap().mean, 1.5);
        assert!(r.mem.is_none());
    }

    #[test]
    fn report_does_not_panic_with_mixed_columns() {
        let mut set = BenchSet::new("t");
        set.bench("a", 0, 1, || None);
        let row = set.bench("b", 0, 1, || Some(2.0));
        row.paper = Some(215.64);
        row.extra.push(("ovh".into(), "2.9".into()));
        set.report();
    }

    #[test]
    fn bench_mem_records_copy_counters() {
        let mut set = BenchSet::new("t");
        let row = set.bench_mem("copies", 0, 2, || {
            // Materialize ~8 KiB per iteration through the df layer.
            let _c = crate::df::Column::from_i64(vec![0i64; 1024]);
            None
        });
        let m = row.mem.expect("mem counters recorded");
        assert!(m.materialized >= 8 * 1024, "{m:?}");
        assert!(row.extra.iter().any(|(k, _)| k == "mat MiB"));
    }

    #[test]
    fn json_round_trip_shape() {
        let mut set = BenchSet::new("quote\"me");
        let row = set.bench_mem("r1", 0, 1, || Some(0.5));
        row.paper = Some(1.0);
        row.extra.push(("k".into(), "v".into()));
        set.bench("r2", 0, 1, || None);
        let js = set.to_json();
        assert!(js.contains("\"title\":\"quote\\\"me\""), "{js}");
        assert!(js.contains("\"label\":\"r1\""));
        assert!(js.contains("\"bytes_materialized_per_iter\":"));
        // Row without mem counters serializes nulls, not garbage.
        assert!(js.contains("\"bytes_materialized_per_iter\":null"));
        assert!(js.contains("\"k\":\"v\""));
        // Balanced braces/brackets (cheap structural sanity).
        assert_eq!(js.matches('{').count(), js.matches('}').count());
        assert_eq!(js.matches('[').count(), js.matches(']').count());
    }
}
