//! Shared-injector thread pool for the data plane (offline substitute
//! for `rayon` — see DESIGN.md §2).
//!
//! Workers pull jobs from one mutex-protected deque (a shared injector
//! queue). That is deliberately simpler than per-worker stealing deques:
//! data-plane jobs are coarse morsels (thousands of rows each), so the
//! single queue is never the bottleneck, and one lock keeps the pool
//! auditable under ThreadSanitizer.
//!
//! Three layers build on the raw pool:
//!
//! * [`ThreadPool::scope`] — structured parallelism: borrow the caller's
//!   stack, wait for every spawned job, re-raise panics. The scope's
//!   waiting thread *helps* by draining queued jobs, so nested scopes
//!   (a pooled pipeline node that itself runs a pooled kernel) cannot
//!   deadlock even on a single-worker pool.
//! * [`ThreadPool::run_indexed`] — the morsel primitive: run `f(0..n)`
//!   across the pool and return the results **in index order**, which is
//!   what makes every parallel kernel bit-identical to its sequential
//!   twin (concatenating per-morsel outputs in morsel order reproduces
//!   the sequential iteration order exactly).
//! * [`SharedSlice`] — disjoint-index parallel scatter into one output
//!   buffer, for kernels (radix partition, CSR build) whose merge step
//!   has already assigned every writer a private range.
//!
//! Memory accounting: jobs run on pool threads, but
//! [`crate::metrics::mem::thread`] is thread-local. Each scope job
//! snapshots the worker's counters around the job body and *transfers*
//! the delta out of the worker and into the scope; `scope` credits the
//! total to the calling thread before returning. Net effect:
//! `mem::thread()` on the caller sees exactly what a sequential run
//! would have seen, and `mem::global()` is untouched (it was always
//! exact). See `metrics::mem::transfer_out` / `transfer_in`.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

use crate::metrics::mem;

use super::faults::lock_recover;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<(VecDeque<Job>, bool)>, // (queue, shutdown flag)
    ready: Condvar,
}

impl Queue {
    fn push(&self, job: Job) {
        let mut guard = lock_recover(&self.jobs);
        guard.0.push_back(job);
        drop(guard);
        self.ready.notify_one();
    }

    fn try_pop(&self) -> Option<Job> {
        lock_recover(&self.jobs).0.pop_front()
    }

    /// Blocking pop for workers; `None` means the pool is shutting down.
    fn pop(&self) -> Option<Job> {
        let mut guard = lock_recover(&self.jobs);
        loop {
            if let Some(job) = guard.0.pop_front() {
                return Some(job);
            }
            if guard.1 {
                return None;
            }
            guard = self.ready.wait(guard).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn shutdown(&self) {
        lock_recover(&self.jobs).1 = true;
        self.ready.notify_all();
    }
}

/// A fixed-size pool of OS worker threads fed by one shared injector
/// queue. Dropping the pool drains nothing: workers finish the job they
/// hold, see the shutdown flag, and exit; `Drop` joins them all.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// A pool with `size` workers. `size == 0` is clamped to 1; note
    /// that a 1-worker pool still parallelizes nothing by itself — the
    /// scope's caller-helping makes it equivalent to sequential.
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let queue = Arc::new(Queue {
            jobs: Mutex::new((VecDeque::new(), false)),
            ready: Condvar::new(),
        });
        let workers = (0..size)
            .map(|i| {
                let queue = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("rc-pool-{i}"))
                    .spawn(move || {
                        while let Some(job) = queue.pop() {
                            // Scope jobs carry their own catch_unwind;
                            // this backstop keeps a panicking detached
                            // job from killing the worker.
                            let _ = catch_unwind(AssertUnwindSafe(job));
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { queue, workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Fire-and-forget a `'static` job onto the pool.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.queue.push(Box::new(job));
    }

    /// Structured parallelism: run `f` with a [`Scope`] that can spawn
    /// jobs borrowing from the caller's stack. Returns only after every
    /// spawned job finished; re-raises a panic if any job panicked.
    ///
    /// The caller participates while waiting (it pops and runs queued
    /// jobs), so a scope never deadlocks waiting for pool capacity —
    /// even nested inside another scope on a 1-worker pool.
    pub fn scope<'env, R>(&self, f: impl FnOnce(&Scope<'env>) -> R) -> R {
        let latch = Arc::new(Latch {
            state: Mutex::new(0usize),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
            mem_materialized: AtomicU64::new(0),
            mem_viewed: AtomicU64::new(0),
        });
        let scope = Scope {
            queue: Arc::clone(&self.queue),
            latch: Arc::clone(&latch),
            _env: std::marker::PhantomData,
        };
        let out = f(&scope);
        // Help drain the queue while jobs remain in flight. We cannot
        // wait on the queue's condvar and the latch's at once, so help
        // opportunistically and fall back to a short timed latch wait.
        loop {
            if *lock_recover(&latch.state) == 0 {
                break;
            }
            if let Some(job) = self.queue.try_pop() {
                let _ = catch_unwind(AssertUnwindSafe(job));
                continue;
            }
            let guard = lock_recover(&latch.state);
            if *guard == 0 {
                break;
            }
            let _ = latch
                .done
                .wait_timeout(guard, Duration::from_millis(1))
                .unwrap_or_else(|e| e.into_inner());
        }
        // Credit memory recorded on worker threads back to the caller,
        // so `mem::thread()` matches the sequential run.
        mem::transfer_in(mem::MemCounters {
            materialized: latch.mem_materialized.load(Ordering::Relaxed),
            viewed: latch.mem_viewed.load(Ordering::Relaxed),
        });
        if latch.panicked.load(Ordering::Relaxed) {
            panic!("a task spawned in ThreadPool::scope panicked");
        }
        out
    }

    /// The morsel primitive: evaluate `f(i)` for every `i in 0..n` on
    /// the pool (the caller helps) and return the results in index
    /// order. Falls back to a plain sequential loop when the pool has
    /// one worker or `n <= 1` — same results, zero overhead.
    pub fn run_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.size() <= 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let slots: Vec<Mutex<Option<T>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let worker = |scope_f: &F, slots: &[Mutex<Option<T>>]| {
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = scope_f(i);
                *lock_recover(&slots[i]) = Some(out);
            }
        };
        self.scope(|s| {
            // One closure per worker; the caller becomes the +1th via
            // the scope's help-while-waiting loop running these jobs.
            for _ in 0..self.size().min(n) {
                s.spawn(|| worker(&f, &slots));
            }
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap_or_else(|e| e.into_inner())
                    .expect("run_indexed slot filled")
            })
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.queue.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

struct Latch {
    /// Number of spawned-but-unfinished scope jobs.
    state: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
    /// Memory recorded by scope jobs on worker threads, drained here so
    /// the scope can credit it to the calling thread.
    mem_materialized: AtomicU64,
    mem_viewed: AtomicU64,
}

/// Spawning handle passed to [`ThreadPool::scope`] closures. Jobs may
/// borrow anything that outlives the scope (`'env`).
pub struct Scope<'env> {
    queue: Arc<Queue>,
    latch: Arc<Latch>,
    _env: std::marker::PhantomData<&'env mut &'env ()>,
}

impl<'env> Scope<'env> {
    /// Spawn a job that may borrow from the enclosing stack frame. The
    /// scope will not return until the job has run.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        *lock_recover(&self.latch.state) += 1;
        let latch = Arc::clone(&self.latch);
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            let before = mem::thread();
            let result = catch_unwind(AssertUnwindSafe(f));
            // Move this job's memory delta from the executing thread to
            // the scope accumulator (the executing thread may be a pool
            // worker *or* the helping caller — transfer keeps both
            // correct and double-count-free).
            let delta = mem::thread().since(before);
            mem::transfer_out(delta);
            latch
                .mem_materialized
                .fetch_add(delta.materialized, Ordering::Relaxed);
            latch.mem_viewed.fetch_add(delta.viewed, Ordering::Relaxed);
            if result.is_err() {
                latch.panicked.store(true, Ordering::Relaxed);
            }
            let mut pending = lock_recover(&latch.state);
            *pending -= 1;
            if *pending == 0 {
                latch.done.notify_all();
            }
        });
        // SAFETY: `scope` joins every spawned job (the pending-count
        // latch) before returning, so the `'env` borrows inside `job`
        // are live for as long as the job can run. This transmute only
        // erases the lifetime to satisfy the queue's `'static` bound —
        // the structured join is what makes it sound.
        let job: Job = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + 'env>,
                Box<dyn FnOnce() + Send + 'static>,
            >(job)
        };
        self.queue.push(job);
    }
}

/// A shared mutable slice for disjoint-index parallel scatter.
///
/// Kernels that have partitioned an output buffer into per-writer
/// ranges (radix scatter, CSR row placement) write through this to skip
/// per-element locking. All synchronization comes from the enclosing
/// scope join.
pub struct SharedSlice<T> {
    ptr: *mut T,
    len: usize,
}

// SAFETY: `SharedSlice` only allows writes via the unsafe `write`,
// whose contract demands disjoint indices across threads; with that
// upheld, sharing the raw pointer across `Send` elements is sound.
unsafe impl<T: Send> Sync for SharedSlice<T> {}
unsafe impl<T: Send> Send for SharedSlice<T> {}

impl<T> SharedSlice<T> {
    pub fn new(slice: &mut [T]) -> SharedSlice<T> {
        SharedSlice { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `val` to index `i`.
    ///
    /// # Safety
    ///
    /// No two threads may write the same index during one scope, and no
    /// one may read the slice until the scope has joined. `i` must be
    /// `< len()` (checked only by debug assertion).
    pub unsafe fn write(&self, i: usize, val: T) {
        debug_assert!(i < self.len);
        unsafe { self.ptr.add(i).write(val) }
    }
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
static CONFIGURED: AtomicUsize = AtomicUsize::new(0);

fn resolve_size(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Pre-size the global pool before first use (e.g. from the
/// `parallelism` config knob). A no-op once [`global`] has run; later
/// calls cannot resize a live pool. `0` means "auto" (one worker per
/// available core).
pub fn configure(parallelism: usize) {
    CONFIGURED.store(resolve_size(parallelism), Ordering::Relaxed);
}

/// The process-wide data-plane pool, created on first use.
///
/// Size precedence: [`configure`] if called first, else the
/// `RC_PARALLELISM` environment variable (`0` = auto-detect cores,
/// `k` = k workers), else **1** — the conservative default keeps the
/// untuned path byte-identical to the sequential kernels.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| {
        let configured = CONFIGURED.load(Ordering::Relaxed);
        let size = if configured > 0 {
            configured
        } else {
            match std::env::var("RC_PARALLELISM") {
                Ok(v) => v.trim().parse::<usize>().map(resolve_size).unwrap_or(1),
                Err(_) => 1,
            }
        };
        ThreadPool::new(size)
    })
}

/// Worker count of the global pool (1 = effectively sequential).
pub fn parallelism() -> usize {
    global().size()
}

/// Default morsel threshold: kernels dispatch to their parallel twin
/// only at or above this many rows (below it, morsel bookkeeping costs
/// more than it saves).
pub const DEFAULT_PAR_MIN_ROWS: usize = 4096;

static PAR_MIN_ROWS: OnceLock<usize> = OnceLock::new();
static PAR_MIN_ROWS_CONFIGURED: AtomicUsize = AtomicUsize::new(0);

/// Pre-set the morsel threshold before first use (e.g. from the
/// `par_min_rows` config knob). Values are clamped to ≥ 1 so kernels
/// may divide by the threshold; a no-op once [`par_min_rows`] has run.
pub fn configure_par_min_rows(rows: usize) {
    PAR_MIN_ROWS_CONFIGURED.store(rows.max(1), Ordering::Relaxed);
}

/// The process-wide morsel threshold.
///
/// Precedence mirrors the pool size: [`configure_par_min_rows`] if
/// called first, else the `RC_PAR_MIN_ROWS` environment variable, else
/// [`DEFAULT_PAR_MIN_ROWS`]. Tests set a small value to force the
/// parallel kernels on small fixtures instead of building ≥ 8192-row
/// inputs everywhere.
pub fn par_min_rows() -> usize {
    *PAR_MIN_ROWS.get_or_init(|| {
        let configured = PAR_MIN_ROWS_CONFIGURED.load(Ordering::Relaxed);
        if configured > 0 {
            return configured;
        }
        match std::env::var("RC_PAR_MIN_ROWS") {
            Ok(v) => v
                .trim()
                .parse::<usize>()
                .map(|n| n.max(1))
                .unwrap_or(DEFAULT_PAR_MIN_ROWS),
            Err(_) => DEFAULT_PAR_MIN_ROWS,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_indexed_returns_in_index_order() {
        let pool = ThreadPool::new(4);
        let out = pool.run_indexed(100, |i| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn run_indexed_matches_sequential_on_one_worker() {
        let pool = ThreadPool::new(1);
        let out = pool.run_indexed(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn scope_joins_all_jobs() {
        let pool = ThreadPool::new(3);
        let hits = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..50 {
                s.spawn(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        // Worst case: one worker, outer scope jobs each open an inner
        // scope. The caller-helping wait keeps everything moving.
        let pool = ThreadPool::new(1);
        let hits = AtomicUsize::new(0);
        pool.scope(|outer| {
            for _ in 0..4 {
                outer.spawn(|| {
                    pool.scope(|inner| {
                        for _ in 0..4 {
                            inner.spawn(|| {
                                hits.fetch_add(1, Ordering::Relaxed);
                            });
                        }
                    });
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn scope_repanics_on_job_panic() {
        let pool = ThreadPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                s.spawn(|| panic!("boom"));
            });
        }));
        assert!(result.is_err(), "scope must re-raise job panics");
    }

    #[test]
    fn shared_slice_disjoint_writes() {
        let pool = ThreadPool::new(4);
        let mut buf = vec![0u32; 64];
        {
            let shared = SharedSlice::new(&mut buf);
            pool.scope(|s| {
                for t in 0..4usize {
                    let shared = &shared;
                    s.spawn(move || {
                        for i in (t * 16)..((t + 1) * 16) {
                            // SAFETY: thread t owns exactly
                            // [t*16, (t+1)*16) — disjoint ranges.
                            unsafe { shared.write(i, i as u32) };
                        }
                    });
                }
            });
        }
        assert_eq!(buf, (0..64).collect::<Vec<u32>>());
    }

    #[test]
    fn par_min_rows_is_positive_and_stable() {
        // Whatever the env/config say, the resolved threshold must be
        // ≥ 1 (kernels divide by it) and identical across calls (it is
        // latched on first use, like the pool size).
        let a = par_min_rows();
        let b = par_min_rows();
        assert!(a >= 1);
        assert_eq!(a, b);
    }

    #[test]
    fn global_pool_defaults_to_one_worker_without_env() {
        // The suite does not set RC_PARALLELISM for this binary by
        // default; either way the pool must be usable.
        let p = parallelism();
        assert!(p >= 1);
        assert_eq!(global().run_indexed(3, |i| i), vec![0, 1, 2]);
    }
}
