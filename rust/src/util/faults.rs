//! Deterministic fault injection + retry policy — the chaos substrate
//! behind the fault-tolerance layer (ARCHITECTURE.md §Fault tolerance).
//!
//! A seeded [`FaultPlan`] arms **named injection sites** compiled into the
//! runtime's seams. When no plan is armed the per-site check is a single
//! relaxed atomic load — effectively free (gated by the `fault-inject`
//! rows in `BENCH_kernels.json`). Sites:
//!
//! | site            | where it fires | semantics |
//! |-----------------|----------------|-----------|
//! | `agent.task`    | task entry in the raptor executor | keyed by (task name, attempt) — identical decision on every rank of the task |
//! | `op.execute`    | around `Operator::execute`        | keyed by (task name, attempt) |
//! | `comm.alltoall` | entry of `Communicator::alltoall_with` | keyed by (ctx, tag): symmetric across the group |
//! | `comm.send`     | `send`/`recv` entry               | keyed by ctx: the *whole* private channel fails — every rank panics at its first point-to-point touch, so no peer is ever left blocking on a message that will never arrive |
//! | `pool.job`      | entry of each pooled pipeline node job (`Pipeline::run_pooled`) | trigger-counted |
//!
//! Keyed sites decide from `(seed, site, key)` alone — no shared counter —
//! which is what keeps collective-adjacent injections *symmetric*: every
//! rank of a task fails (or survives) together, so a fault can never
//! deadlock the surviving peers of a collective. (`comm.send` keys on the
//! communicator context rather than the individual message for the same
//! reason: a single dropped point-to-point message would strand third
//! ranks of the group that were waiting on the panicked pair's *other*
//! traffic; failing the whole channel keeps every rank's first p2p touch
//! the failure point.) On top of the keying, a fired comm fault
//! *poisons* the communicator context before panicking — any rank
//! already blocked on that context wakes and panics too — so comm faults
//! can never hang a group whatever its traffic pattern.
//! Trigger-counted sites (`pool.job`) use a per-arm
//! atomic counter instead — they sit above the collective layer where
//! asymmetry is already contained.
//!
//! Per-arm semantics, configured via the `[faults]` INI section or the
//! `RC_FAULTS` env var (comma-separated `key=value` spec, same grammar):
//!
//! ```text
//! seed = 42                   # decision stream seed
//! agent.task = 0.25           # fail with probability 0.25 per decision
//! pool.job = @3               # fire exactly on the 3rd trigger
//! op.execute.delay_ms = 50    # inject latency instead of failure
//! agent.task.only = chaosq    # restrict to task names with this prefix
//! ```
//!
//! On a keyed site `@N` fires for the deterministic 1-in-N subset of keys
//! (there is no global trigger order across ranks to count). The `only`
//! name filter applies to the task-name sites (`agent.task`,
//! `op.execute`); it lets a test arm the process-global plan without
//! perturbing unrelated concurrent work.
//!
//! [`RetryPolicy`] is the consumer side: capped exponential backoff with
//! deterministic jitter, used at the pipeline-node boundary
//! (`Pipeline::run_dataflow`/`run_pooled`) and at the query level
//! (`service::QueryService`). Process defaults come from `[faults]`
//! `retry_max_attempts`/`retry_base_ms` (env `RC_RETRY_MAX` /
//! `RC_RETRY_BASE_MS`); the built-in default is 1 attempt — no retry, and
//! byte-identical behaviour to the pre-fault-tolerance build.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Once};

use crate::error::{Error, Result};
use crate::metrics;

use super::hash::splitmix64;

/// The injection sites compiled into the runtime. Arming any other name
/// is rejected at parse time (typo protection).
pub const SITES: &[&str] =
    &["agent.task", "op.execute", "comm.alltoall", "comm.send", "pool.job"];

/// When an armed site fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FireMode {
    /// Fire with this probability per decision.
    Prob(f64),
    /// Fire exactly on the Nth trigger (1-based) of a counted site; on a
    /// keyed site, fire for the deterministic 1-in-N subset of keys.
    Nth(u64),
}

/// One armed site.
#[derive(Debug)]
pub struct Arm {
    pub site: String,
    pub mode: FireMode,
    /// `> 0`: inject this much latency instead of failing.
    pub delay_ms: u64,
    /// Restrict to task names with this prefix (task-name sites only).
    pub only: Option<String>,
    count: AtomicU64,
}

/// A seeded set of armed sites. Decisions are pure functions of
/// `(seed, site, key-or-trigger)`, so the same plan over the same
/// workload injects the same faults — the property the chaos suite's
/// oracle comparison rests on.
#[derive(Debug, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub arms: Vec<Arm>,
}

fn str_hash(s: &str) -> u64 {
    s.bytes().fold(0xFA17u64, |h, b| splitmix64(h ^ b as u64))
}

/// Decision key for the task-name sites (`agent.task`, `op.execute`):
/// every rank of a task computes the same key, and the retry layer's
/// attempt bump re-draws the decision on each re-submission. The site
/// name is mixed into the draw separately, so both sites decide
/// independently from the same key.
pub fn task_key(name: &str, attempt: u32) -> u64 {
    splitmix64(str_hash(name) ^ (attempt as u64).rotate_left(32))
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, arms: Vec::new() }
    }

    /// Arm `site` with the given mode (builder-style; panics on unknown
    /// site names — config parsing returns typed errors instead).
    pub fn with_arm(mut self, site: &str, mode: FireMode) -> FaultPlan {
        assert!(SITES.contains(&site), "unknown fault site '{site}'");
        self.arms.push(Arm {
            site: site.to_string(),
            mode,
            delay_ms: 0,
            only: None,
            count: AtomicU64::new(0),
        });
        self
    }

    /// Turn the most recently added arm into a latency injection.
    pub fn with_delay_ms(mut self, ms: u64) -> FaultPlan {
        self.arms.last_mut().expect("with_delay_ms before any arm").delay_ms =
            ms;
        self
    }

    /// Restrict the most recently added arm to task names with `prefix`.
    pub fn with_only(mut self, prefix: &str) -> FaultPlan {
        self.arms.last_mut().expect("with_only before any arm").only =
            Some(prefix.to_string());
        self
    }

    /// Parse the `key=value` spec grammar (shared by `RC_FAULTS` and the
    /// `[faults]` INI section — see the module docs for the grammar).
    pub fn parse_spec(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::new(0xC4A05);
        for item in spec.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let Some((key, value)) = item.split_once('=') else {
                return Err(Error::Config(format!(
                    "fault spec item '{item}' is not key=value"
                )));
            };
            plan.apply_key(key.trim(), value.trim())?;
        }
        Ok(plan)
    }

    /// Apply one `key = value` pair (also the `[faults]` INI entry point;
    /// `retry_*`/`task_deadline_s` keys are handled by the config layer,
    /// not here).
    pub fn apply_key(&mut self, key: &str, value: &str) -> Result<()> {
        let bad = |what: &str| {
            Error::Config(format!("fault key '{key}': bad {what} '{value}'"))
        };
        if key == "seed" {
            self.seed = value.parse().map_err(|_| bad("seed"))?;
            return Ok(());
        }
        if let Some(site) = key.strip_suffix(".delay_ms") {
            let ms: u64 = value.parse().map_err(|_| bad("delay"))?;
            self.arm_entry(site)?.delay_ms = ms;
            return Ok(());
        }
        if let Some(site) = key.strip_suffix(".only") {
            self.arm_entry(site)?.only = Some(value.to_string());
            return Ok(());
        }
        let mode = if let Some(n) = value.strip_prefix('@') {
            let n: u64 = n.parse().map_err(|_| bad("@N trigger"))?;
            if n == 0 {
                return Err(bad("@N trigger (must be >= 1)"));
            }
            FireMode::Nth(n)
        } else {
            let p: f64 = value.parse().map_err(|_| bad("probability"))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(bad("probability (want [0,1])"));
            }
            FireMode::Prob(p)
        };
        self.arm_entry(key)?.mode = mode;
        Ok(())
    }

    fn arm_entry(&mut self, site: &str) -> Result<&mut Arm> {
        if !SITES.contains(&site) {
            return Err(Error::Config(format!(
                "unknown fault site '{site}' (known: {})",
                SITES.join(", ")
            )));
        }
        if let Some(i) = self.arms.iter().position(|a| a.site == site) {
            return Ok(&mut self.arms[i]);
        }
        self.arms.push(Arm {
            site: site.to_string(),
            // A site first mentioned via `.delay_ms`/`.only` defaults to
            // firing always; a base `site = <mode>` key overwrites this.
            mode: FireMode::Prob(1.0),
            delay_ms: 0,
            only: None,
            count: AtomicU64::new(0),
        });
        Ok(self.arms.last_mut().unwrap())
    }

    /// Decide whether `site` fires for `trigger` (a symmetric key on keyed
    /// sites, a 0-based trigger index on counted sites).
    fn fires(&self, arm: &Arm, trigger: u64, keyed: bool) -> bool {
        let draw = splitmix64(
            self.seed ^ str_hash(&arm.site).rotate_left(17) ^ trigger,
        );
        match arm.mode {
            FireMode::Prob(p) => {
                ((draw >> 11) as f64 * (1.0 / (1u64 << 53) as f64)) < p
            }
            FireMode::Nth(n) if keyed => draw % n == 0,
            FireMode::Nth(n) => trigger + 1 == n,
        }
    }

    fn check(&self, site: &str, trigger: impl Fn(&Arm) -> (u64, bool), name: &str) -> Option<u64> {
        for arm in self.arms.iter().filter(|a| a.site == site) {
            if let Some(prefix) = &arm.only {
                if !name.starts_with(prefix.as_str()) {
                    continue;
                }
            }
            let (t, keyed) = trigger(arm);
            if self.fires(arm, t, keyed) {
                return Some(arm.delay_ms);
            }
        }
        None
    }
}

// ---------------------------------------------------------------------------
// Process-global arming.
//
// `ARMED` is the fast-path gate: when false (the default), every inject
// call is one relaxed load + branch. The plan itself lives behind a
// mutex so tests can arm/disarm repeatedly; the mutex is only touched
// when armed. `ENV_INIT` reads `RC_FAULTS` (and the retry/deadline env
// knobs) exactly once, on the first inject/retry-policy call.
// ---------------------------------------------------------------------------

static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);
static ENV_INIT: Once = Once::new();

static RETRY_MAX: AtomicU64 = AtomicU64::new(1);
static RETRY_BASE_MS: AtomicU64 = AtomicU64::new(10);
static RETRY_CAP_MS: AtomicU64 = AtomicU64::new(500);
static RETRY_SEED: AtomicU64 = AtomicU64::new(0x9E37);
/// Default per-task deadline in milliseconds; 0 = none.
static DEADLINE_MS: AtomicU64 = AtomicU64::new(0);

fn env_init() {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("RC_FAULTS") {
            if !spec.is_empty() {
                match FaultPlan::parse_spec(&spec) {
                    Ok(plan) => arm(plan),
                    Err(e) => eprintln!("ignoring bad RC_FAULTS: {e}"),
                }
            }
        }
        let env_u64 = |k: &str| -> Option<u64> {
            std::env::var(k).ok().and_then(|v| v.parse().ok())
        };
        if let Some(n) = env_u64("RC_RETRY_MAX") {
            RETRY_MAX.store(n.max(1), Ordering::Relaxed);
        }
        if let Some(ms) = env_u64("RC_RETRY_BASE_MS") {
            RETRY_BASE_MS.store(ms, Ordering::Relaxed);
        }
        if let Some(s) = std::env::var("RC_TASK_DEADLINE_S")
            .ok()
            .and_then(|v| v.parse::<f64>().ok())
        {
            if s > 0.0 {
                DEADLINE_MS.store((s * 1e3) as u64, Ordering::Relaxed);
            }
        }
    });
}

static TEST_GUARD: Mutex<()> = Mutex::new(());

/// Serialize tests that arm/disarm the process-global plan: hold the
/// returned guard for the whole armed section. Production code never
/// needs this — arming is a test/chaos-harness operation.
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    lock_recover(&TEST_GUARD)
}

/// Arm `plan` process-wide (replacing any armed plan). Tests that arm and
/// disarm must serialize with each other (see [`test_guard`]) — the plan
/// is global state.
pub fn arm(plan: FaultPlan) {
    let mut slot = lock_recover(&PLAN);
    *slot = Some(Arc::new(plan));
    ARMED.store(true, Ordering::Release);
}

/// Disarm: every site reverts to the free no-op path.
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
    *lock_recover(&PLAN) = None;
}

/// Is a fault plan currently armed?
pub fn armed() -> bool {
    env_init();
    ARMED.load(Ordering::Relaxed)
}

fn current_plan() -> Option<Arc<FaultPlan>> {
    lock_recover(&PLAN).clone()
}

/// Lock a mutex, recovering the guard from a poisoned lock. Used on
/// shared state whose invariants hold at every await-free lock release
/// (counters, queues with external latches, state machines) — a tenant
/// panicking while holding such a lock must not wedge every other tenant
/// behind a `PoisonError`.
pub fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[inline]
fn fault_err(site: &str, name: &str) -> Error {
    if name.is_empty() {
        Error::TaskFailed(format!("injected fault at {site}"))
    } else {
        Error::TaskFailed(format!("injected fault at {site} in '{name}'"))
    }
}

fn apply(delay_ms: u64, site: &str, name: &str) -> Result<()> {
    metrics::faults::record_injected();
    if delay_ms > 0 {
        std::thread::sleep(std::time::Duration::from_millis(delay_ms));
        return Ok(());
    }
    Err(fault_err(site, name))
}

/// Trigger-counted injection (e.g. `pool.job`). Free when unarmed.
#[inline]
pub fn inject(site: &str, name: &str) -> Result<()> {
    if !armed() {
        return Ok(());
    }
    inject_slow(site, None, name)
}

/// Keyed injection: the decision is a pure function of the armed plan and
/// `key`, so every caller presenting the same key — every rank of a task,
/// both endpoints of a send — reaches the same verdict. Free when
/// unarmed.
#[inline]
pub fn inject_keyed(site: &str, key: u64, name: &str) -> Result<()> {
    if !armed() {
        return Ok(());
    }
    inject_slow(site, Some(key), name)
}

#[cold]
fn inject_slow(site: &str, key: Option<u64>, name: &str) -> Result<()> {
    let Some(plan) = current_plan() else { return Ok(()) };
    let delay = plan.check(
        site,
        |arm| match key {
            Some(k) => (k, true),
            None => (arm.count.fetch_add(1, Ordering::Relaxed), false),
        },
        name,
    );
    match delay {
        Some(ms) => apply(ms, site, name),
        None => Ok(()),
    }
}

/// Comm-layer check. The communicator's `send`/`recv`/`alltoall` return
/// values, not `Result`s, so a fired failure there propagates by
/// **panic** — but the communicator must first poison the faulted context
/// so every peer blocked on it wakes and panics too (no rank is ever left
/// waiting on a message that will never arrive). This hook therefore only
/// renders the verdict; the caller applies it:
///
/// * `None` — no fault; proceed.
/// * `Some(0)` — fail: poison the context, then panic.
/// * `Some(ms)` — latency arm: sleep `ms` on the initiating side.
///
/// Records the injection counter on every `Some`. Free when unarmed.
#[inline]
pub fn comm_verdict(site: &str, key: u64) -> Option<u64> {
    if !armed() {
        return None;
    }
    comm_verdict_slow(site, key)
}

#[cold]
fn comm_verdict_slow(site: &str, key: u64) -> Option<u64> {
    let plan = current_plan()?;
    let delay_ms = plan.check(site, |_| (key, true), "")?;
    metrics::faults::record_injected();
    Some(delay_ms)
}

/// Default per-task deadline the raptor master applies when a
/// `TaskDescription` carries none. Configured via `[faults]`
/// `task_deadline_s` or `RC_TASK_DEADLINE_S`; `None` by default.
pub fn default_deadline() -> Option<std::time::Duration> {
    env_init();
    match DEADLINE_MS.load(Ordering::Relaxed) {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms)),
    }
}

/// Set the process-default task deadline (0 or negative clears it).
pub fn configure_deadline(seconds: f64) {
    env_init();
    let ms = if seconds > 0.0 { (seconds * 1e3) as u64 } else { 0 };
    DEADLINE_MS.store(ms, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Retry policy
// ---------------------------------------------------------------------------

/// Bounded retry with capped exponential backoff and deterministic
/// jitter. `max_attempts = 1` means "no retry" — the default, keeping
/// un-configured builds byte-identical to the pre-retry executor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts including the first (>= 1).
    pub max_attempts: u32,
    /// Backoff before attempt 2, doubling per attempt.
    pub base_ms: u64,
    /// Backoff ceiling.
    pub cap_ms: u64,
    /// Jitter stream seed.
    pub seed: u64,
}

impl RetryPolicy {
    /// Single attempt, no retry.
    pub fn none() -> RetryPolicy {
        RetryPolicy { max_attempts: 1, base_ms: 0, cap_ms: 0, seed: 0 }
    }

    pub fn new(max_attempts: u32, base_ms: u64) -> RetryPolicy {
        RetryPolicy {
            max_attempts: max_attempts.max(1),
            base_ms,
            cap_ms: 500,
            seed: 0x9E37,
        }
    }

    /// Backoff before attempt `attempt + 1` (attempts are 1-based):
    /// `base * 2^(attempt-1)` capped at `cap_ms`, jittered to
    /// `[half, full]` by a draw that is a pure function of
    /// `(seed, key, attempt)` — deterministic, but decorrelated across
    /// tasks so retry storms do not synchronize.
    pub fn backoff_ms(&self, attempt: u32, key: u64) -> u64 {
        if self.base_ms == 0 {
            return 0;
        }
        let exp = self
            .base_ms
            .saturating_mul(1u64 << (attempt.saturating_sub(1)).min(16))
            .min(self.cap_ms.max(self.base_ms));
        let half = exp / 2;
        let span = exp - half + 1;
        let draw = splitmix64(self.seed ^ splitmix64(key) ^ attempt as u64);
        half + draw % span
    }

    /// Run `f(attempt)` (1-based) until it succeeds, exhausts
    /// `max_attempts`, or fails permanently ([`Error::is_transient`] is
    /// the gate). Sleeps `backoff_ms` between attempts and keeps the
    /// `metrics::faults` retried/recovered/exhausted counters.
    pub fn run<T>(
        &self,
        key: u64,
        mut f: impl FnMut(u32) -> Result<T>,
    ) -> Result<T> {
        let mut attempt = 1u32;
        loop {
            match f(attempt) {
                Ok(v) => {
                    if attempt > 1 {
                        metrics::faults::record_recovered();
                    }
                    return Ok(v);
                }
                Err(e) if e.is_transient() && attempt < self.max_attempts => {
                    metrics::faults::record_retried();
                    let ms = self.backoff_ms(attempt, key);
                    if ms > 0 {
                        std::thread::sleep(std::time::Duration::from_millis(
                            ms,
                        ));
                    }
                    attempt += 1;
                }
                Err(e) => {
                    if e.is_transient() && attempt > 1 {
                        metrics::faults::record_exhausted();
                    }
                    return Err(e);
                }
            }
        }
    }
}

/// The process-default retry policy used at the pipeline-node boundary.
/// Configured via [`configure_retry`], `[faults]` retry keys, or env
/// (`RC_RETRY_MAX`, `RC_RETRY_BASE_MS`); defaults to no retry.
pub fn retry_policy() -> RetryPolicy {
    env_init();
    RetryPolicy {
        max_attempts: RETRY_MAX.load(Ordering::Relaxed) as u32,
        base_ms: RETRY_BASE_MS.load(Ordering::Relaxed),
        cap_ms: RETRY_CAP_MS.load(Ordering::Relaxed),
        seed: RETRY_SEED.load(Ordering::Relaxed),
    }
}

/// Install `policy` as the process default.
pub fn configure_retry(policy: RetryPolicy) {
    env_init();
    RETRY_MAX.store(policy.max_attempts.max(1) as u64, Ordering::Relaxed);
    RETRY_BASE_MS.store(policy.base_ms, Ordering::Relaxed);
    RETRY_CAP_MS.store(policy.cap_ms, Ordering::Relaxed);
    RETRY_SEED.store(policy.seed, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spec_grammar() {
        let p = FaultPlan::parse_spec(
            "seed=7, agent.task=0.5, pool.job=@3, \
             op.execute.delay_ms=20, agent.task.only=chaos",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        let task = p.arms.iter().find(|a| a.site == "agent.task").unwrap();
        assert_eq!(task.mode, FireMode::Prob(0.5));
        assert_eq!(task.only.as_deref(), Some("chaos"));
        let job = p.arms.iter().find(|a| a.site == "pool.job").unwrap();
        assert_eq!(job.mode, FireMode::Nth(3));
        let op = p.arms.iter().find(|a| a.site == "op.execute").unwrap();
        assert_eq!(op.delay_ms, 20);
        assert_eq!(op.mode, FireMode::Prob(1.0)); // delay-only arm fires always
    }

    #[test]
    fn parse_spec_rejects_nonsense() {
        for bad in [
            "nope.site=0.5",
            "agent.task=1.5",
            "agent.task=-0.1",
            "pool.job=@0",
            "agent.task",
            "seed=zebra",
        ] {
            assert!(
                FaultPlan::parse_spec(bad).is_err(),
                "'{bad}' should be rejected"
            );
        }
    }

    #[test]
    fn keyed_decisions_are_deterministic_and_symmetric() {
        let plan = FaultPlan::new(42).with_arm("agent.task", FireMode::Prob(0.5));
        let again = FaultPlan::new(42).with_arm("agent.task", FireMode::Prob(0.5));
        let mut fired = 0;
        for key in 0..200u64 {
            let a = plan.check("agent.task", |_| (key, true), "t").is_some();
            let b = again.check("agent.task", |_| (key, true), "t").is_some();
            assert_eq!(a, b, "same plan+key must decide identically");
            fired += a as u32;
        }
        // ~50% of keys fire; the draw is uniform.
        assert!((60..140).contains(&fired), "{fired}");
        // A different seed gives a different subset.
        let other = FaultPlan::new(43).with_arm("agent.task", FireMode::Prob(0.5));
        let differs = (0..200u64).any(|key| {
            plan.check("agent.task", |_| (key, true), "t").is_some()
                != other.check("agent.task", |_| (key, true), "t").is_some()
        });
        assert!(differs);
    }

    #[test]
    fn nth_counted_fires_exactly_once() {
        let plan = FaultPlan::new(1).with_arm("pool.job", FireMode::Nth(3));
        let arm = &plan.arms[0];
        let fires: Vec<bool> = (0..6)
            .map(|_| {
                let t = arm.count.fetch_add(1, Ordering::Relaxed);
                plan.fires(arm, t, false)
            })
            .collect();
        assert_eq!(fires, vec![false, false, true, false, false, false]);
    }

    #[test]
    fn only_filter_scopes_by_name() {
        let plan = FaultPlan::new(9)
            .with_arm("agent.task", FireMode::Prob(1.0))
            .with_only("chaos");
        assert!(plan.check("agent.task", |_| (1, true), "chaos-sort").is_some());
        assert!(plan.check("agent.task", |_| (1, true), "normal").is_none());
    }

    #[test]
    fn arm_disarm_round_trip() {
        let _guard = test_guard();
        assert!(inject_keyed("agent.task", 5, "t").is_ok());
        arm(FaultPlan::new(2).with_arm("agent.task", FireMode::Prob(1.0)));
        let err = inject_keyed("agent.task", 5, "t").unwrap_err();
        assert!(err.is_transient());
        assert!(err.to_string().contains("agent.task"), "{err}");
        disarm();
        assert!(inject_keyed("agent.task", 5, "t").is_ok());
    }

    #[test]
    fn retry_recovers_then_exhausts() {
        let policy = RetryPolicy { max_attempts: 3, base_ms: 0, cap_ms: 0, seed: 1 };
        // Fails twice, succeeds on the 3rd attempt.
        let mut calls = 0;
        let out = policy.run(7, |attempt| {
            calls += 1;
            if attempt < 3 {
                Err(Error::TaskFailed("flaky".into()))
            } else {
                Ok(attempt)
            }
        });
        assert_eq!(out.unwrap(), 3);
        assert_eq!(calls, 3);
        // Permanent errors do not retry.
        let mut calls = 0;
        let out: Result<()> = policy.run(7, |_| {
            calls += 1;
            Err(Error::Config("bad".into()))
        });
        assert!(out.is_err());
        assert_eq!(calls, 1);
        // Transient errors exhaust at max_attempts.
        let mut calls = 0;
        let out: Result<()> = policy.run(7, |_| {
            calls += 1;
            Err(Error::Timeout("slow".into()))
        });
        assert!(matches!(out, Err(Error::Timeout(_))));
        assert_eq!(calls, 3);
    }

    #[test]
    fn backoff_caps_and_jitters_deterministically() {
        let p = RetryPolicy { max_attempts: 9, base_ms: 10, cap_ms: 80, seed: 5 };
        for attempt in 1..9 {
            let exp = (10u64 << (attempt - 1) as u64).min(80);
            let ms = p.backoff_ms(attempt, 42);
            assert!(ms >= exp / 2 && ms <= exp, "attempt {attempt}: {ms}");
            assert_eq!(ms, p.backoff_ms(attempt, 42), "deterministic");
        }
        assert_eq!(RetryPolicy::none().backoff_ms(1, 0), 0);
    }

    #[test]
    fn lock_recover_survives_poison() {
        let m = std::sync::Arc::new(Mutex::new(17u64));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_recover(&m), 17);
    }
}
