//! Seeded PRNG (xoshiro256** seeded via SplitMix64) — the offline stand-in
//! for the `rand` crate. Deterministic across runs and platforms, which the
//! experiment harness relies on for reproducible synthetic datasets.

use super::hash::splitmix64;

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 stream expansion, per the xoshiro authors' guidance.
        let mut x = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            *slot = splitmix64(x);
        }
        Rng { s }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire rejection-free multiply-shift; tiny
    /// bias at 64-bit bounds is irrelevant for workload generation).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform signed key in `[lo, hi)`.
    #[inline]
    pub fn gen_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo.wrapping_add(self.gen_range((hi - lo) as u64) as i64)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponentially-distributed sample with the given mean (used by the
    /// cluster model for queue delays and task-duration jitter).
    pub fn gen_exp(&mut self, mean: f64) -> f64 {
        let u = self.gen_f64().max(1e-12);
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_respected() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.gen_range(37) < 37);
            let k = r.gen_i64(-5, 5);
            assert!((-5..5).contains(&k));
            let f = r.gen_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.gen_range(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{c}");
        }
    }

    #[test]
    fn exp_mean_rough() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| r.gen_exp(2.0)).sum();
        let mean = sum / n as f64;
        assert!((1.9..2.1).contains(&mean), "{mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>()); // astronomically unlikely
    }
}
